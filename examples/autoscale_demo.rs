//! Autoscaling demo (paper §V-D2 / Fig. 10-11): replay the
//! RPS-rescaled trace over the TP1/TP2/TP4 Llama2-13B scale set under
//! the four policies of the comparison matrix, then print a runtime
//! timeline of engine states, frequencies and power.
//!
//! Run with:
//!   cargo run --release --example autoscale_demo [-- --duration 1200]

use throttllem::cli::Args;
use throttllem::config::models::llama2_13b;
use throttllem::config::ServingConfig;
use throttllem::coordinator::{serve_trace, PerfModel, Policy};
use throttllem::workload::trace::{rps_bins, synth_trace_rps_range, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let duration = args.get_f64("duration", 1200.0)?;
    let seed = args.get_u64("seed", 0)?;

    let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];
    let model = PerfModel::train(&set, 100, seed);
    // §V-D2: RPS rescaled to [0.75, 7.5] to exercise every engine.
    let mut reqs = synth_trace_rps_range(
        &TraceParams::short(duration, 8.25, seed),
        0.75,
        7.5,
    );
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    println!("trace: {} requests over {duration:.0} s\n", reqs.len());

    let combos = [
        ("triton (TP4)", Policy::triton()),
        ("triton+autoscale", Policy::triton_autoscale()),
        ("throttle-only (TP4)", Policy::throttle_only()),
        ("throttllem (full)", Policy::throttllem()),
    ];
    println!(
        "{:<20} {:>9} {:>10} {:>8} {:>9} {:>9}",
        "policy", "E2E p99", "energy", "TPJ", "switches", "shadow"
    );
    println!(
        "{:<20} {:>9} {:>10} {:>8} {:>9} {:>9}",
        "", "[s]", "[kJ]", "[tok/J]", "", "[kJ]"
    );
    let mut full_timeline = None;
    for (name, policy) in combos {
        let cfg = if policy.autoscaling {
            ServingConfig::autoscaled(set.clone())
        } else if policy.throttling {
            ServingConfig::throttllem(set[2].clone())
        } else {
            ServingConfig::triton(set[2].clone())
        };
        let out = serve_trace(&cfg, policy, &model, &reqs);
        println!(
            "{:<20} {:>9.2} {:>10.1} {:>8.3} {:>9} {:>9.2}",
            name,
            out.stats.e2e.p99(),
            out.stats.total_energy_j / 1e3,
            out.stats.tokens_per_joule(),
            out.engine_switches,
            out.shadow_energy_j / 1e3,
        );
        if policy == Policy::throttllem() {
            full_timeline = Some(out);
        }
    }

    // Runtime timeline of the full system (Fig. 11, textual form).
    let out = full_timeline.unwrap();
    let bin = 30.0;
    let rps = rps_bins(&reqs, duration, bin);
    println!("\n-- runtime timeline (30 s bins) --");
    println!(
        "{:>6} {:>6} {:>4} {:>7} {:>8} {:>8}",
        "t[s]", "RPS", "TP", "f[MHz]", "P[W]", "batch"
    );
    let n_bins = (duration / bin).ceil() as usize;
    for b in 0..n_bins {
        let lo = b as f64 * bin;
        let hi = lo + bin;
        let pts: Vec<_> = out
            .timeline
            .iter()
            .filter(|p| p.t >= lo && p.t < hi)
            .collect();
        if pts.is_empty() {
            continue;
        }
        let mean = |f: &dyn Fn(&&throttllem::coordinator::server::TimelinePoint) -> f64| {
            pts.iter().map(|p| f(&p)).sum::<f64>() / pts.len() as f64
        };
        println!(
            "{:>6.0} {:>6.2} {:>4.0} {:>7.0} {:>8.0} {:>8.1}",
            lo,
            rps.get(b).copied().unwrap_or(0.0),
            mean(&|p| p.engine_tp as f64),
            mean(&|p| p.freq_mhz as f64),
            mean(&|p| p.power_w + p.shadow_power_w),
            mean(&|p| p.batch as f64),
        );
    }
    Ok(())
}
