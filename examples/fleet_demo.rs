//! Fleet coordinator demo: horizontal replication with per-replica
//! frequency control (the GreenLLM/AGFT-style fleet extension of the
//! paper's single-engine throttLL'eM).
//!
//! Two modes:
//!   * default — N identical llama2-13b TP2 replicas, served under
//!     every admission-router policy against a Triton fleet at max
//!     frequency;
//!   * `--mixed` — a heterogeneous fleet (1×TP4 + 1×TP2 + 2×TP1) with
//!     occasional long prompts only the large replicas can hold, where
//!     capacity-aware `projected-headroom` routing visibly beats
//!     round-robin on SLO attainment (the §IV-B projection signal is
//!     load-bearing on the main path).
//!
//! Run with:
//!   cargo run --release --example fleet_demo [-- --replicas 4 --duration 600]
//!   cargo run --release --example fleet_demo -- --mixed [--duration 600]

use throttllem::cli::Args;
use throttllem::config::models::llama2_13b;
use throttllem::config::{ReplicaSpec, ServingConfig};
use throttllem::coordinator::{
    serve_fleet_plan, FleetOutcome, FleetPlan, PerfModel, Policy, RouterPolicy,
};
use throttllem::workload::trace::{inject_long_prompts, synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let duration = args.get_f64("duration", 600.0)?;
    let seed = args.get_u64("seed", 0)?;
    if args.flag("mixed") {
        mixed_demo(duration, seed)
    } else {
        homogeneous_demo(args.get_u64("replicas", 4)? as usize, duration, seed)
    }
}

fn print_header() {
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "deployment", "E2E p99", "E2E att.", "TBT att.", "freq", "energy", "TPJ"
    );
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "", "[s]", "[%]", "[%]", "[MHz]", "[kJ]", "[tok/J]"
    );
}

fn print_row(name: &str, cfg: &ServingConfig, out: &FleetOutcome) {
    let s = &out.total.stats;
    println!(
        "{:<34} {:>9.2} {:>9.1} {:>9.1} {:>9.0} {:>10.1} {:>8.3}",
        name,
        s.e2e.p99(),
        s.e2e_slo_attainment(cfg.slo.e2e_p99) * 100.0,
        s.tbt_slo_attainment(cfg.slo.tbt_avg) * 100.0,
        s.freq.mean(),
        s.total_energy_j / 1e3,
        s.tokens_per_joule(),
    );
}

fn print_replica_breakdown(out: &FleetOutcome) {
    println!(
        "{:<8} {:<16} {:>8} {:>10} {:>8} {:>10} {:>11}",
        "replica", "engine", "routed", "completed", "dropped", "freq[MHz]", "energy[kJ]"
    );
    for (i, r) in out.replicas.iter().enumerate() {
        println!(
            "{:<8} {:<16} {:>8} {:>10} {:>8} {:>10.0} {:>11.1}",
            i,
            r.engine,
            r.routed,
            r.stats.completed,
            r.stats.dropped,
            r.stats.freq.mean(),
            r.stats.total_energy_j / 1e3,
        );
    }
}

fn homogeneous_demo(replicas: usize, duration: f64, seed: u64) -> anyhow::Result<()> {
    let spec = llama2_13b(2);
    let model = PerfModel::train(&[spec.clone()], 100, seed);
    // Right-scale to ~80% of the fleet's aggregate rated load.
    let peak = 0.8 * spec.max_load_rps * replicas as f64;
    let mut reqs = synth_trace(&TraceParams::short(duration, peak, seed));
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    println!(
        "fleet of {replicas} x {} | {} requests over {duration:.0} s (peak ~{peak:.1} RPS)\n",
        spec.name,
        reqs.len()
    );

    let combos: Vec<(String, Policy, ServingConfig, RouterPolicy)> = vec![
        (
            format!("triton x{replicas} (rr)"),
            Policy::triton(),
            ServingConfig::triton(spec.clone()),
            RouterPolicy::RoundRobin,
        ),
        (
            format!("throttllem x{replicas} (rr)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::RoundRobin,
        ),
        (
            format!("throttllem x{replicas} (least-loaded)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::LeastLoaded,
        ),
        (
            format!("throttllem x{replicas} (headroom)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::ProjectedHeadroom,
        ),
    ];

    print_header();
    let mut detailed: Option<FleetOutcome> = None;
    for (name, policy, cfg, router) in combos {
        let plan = FleetPlan::homogeneous(replicas, router, &cfg, policy, false);
        let out = serve_fleet_plan(&cfg, policy, &model, &reqs, &plan);
        print_row(&name, &cfg, &out);
        if router == RouterPolicy::LeastLoaded {
            detailed = Some(out);
        }
    }

    // Per-replica breakdown of the least-loaded throttLL'eM fleet.
    let out = detailed.expect("least-loaded run present");
    println!("\n-- per-replica breakdown (throttllem, least-loaded) --");
    print_replica_breakdown(&out);
    println!(
        "rerouted on universal rejection: {} | aggregate energy {:.1} kJ",
        out.rerouted,
        out.total.stats.total_energy_j / 1e3
    );
    Ok(())
}

fn mixed_demo(duration: f64, seed: u64) -> anyhow::Result<()> {
    let specs = vec![
        ReplicaSpec::fixed(llama2_13b(4)),
        ReplicaSpec::fixed(llama2_13b(2)),
        ReplicaSpec::fixed(llama2_13b(1)),
        ReplicaSpec::fixed(llama2_13b(1)),
    ];
    let base = FleetPlan::heterogeneous(specs, RouterPolicy::RoundRobin);
    let rated = base.rated_rps();
    let peak = 0.6 * rated;
    let cfg = ServingConfig::throttllem(llama2_13b(4));
    // Train on the fleet's unique engines (two replicas share TP1).
    let model = PerfModel::train(&base.engines(), 100, seed);

    let mut reqs = synth_trace(&TraceParams::short(duration, peak, seed));
    // 10k tokens -> 157 KV blocks: impossible on TP1 (120 blocks),
    // comfortable on TP2 (439) and TP4 (1050).
    inject_long_prompts(&mut reqs, duration, 20.0, 10_000, 64);
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    println!(
        "mixed fleet (1xTP4 + 1xTP2 + 2xTP1, rated {rated:.1} RPS) | {} requests \
         over {duration:.0} s (peak ~{peak:.1} RPS, long 10k-token prompt every 20 s)\n",
        reqs.len()
    );

    print_header();
    let mut best: Option<FleetOutcome> = None;
    for router in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::ProjectedHeadroom,
    ] {
        let plan = FleetPlan {
            router,
            ..base.clone()
        };
        let out =
            serve_fleet_plan(&cfg, Policy::throttle_only(), &model, &reqs, &plan);
        print_row(&format!("throttllem mixed ({})", router.name()), &cfg, &out);
        if router == RouterPolicy::ProjectedHeadroom {
            best = Some(out);
        }
    }

    let out = best.expect("projected-headroom run present");
    println!("\n-- per-replica breakdown (throttllem mixed, projected-headroom) --");
    print_replica_breakdown(&out);
    println!(
        "rerouted on universal rejection: {} (capacity-aware routing places long \
         prompts on the large replicas up front)",
        out.rerouted
    );
    Ok(())
}
