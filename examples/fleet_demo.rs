//! Fleet coordinator demo: horizontal replication with per-replica
//! frequency control (the GreenLLM/AGFT-style fleet extension of the
//! paper's single-engine throttLL'eM).
//!
//! Three modes:
//!   * default — N identical llama2-13b TP2 replicas, served under
//!     every admission-router policy against a Triton fleet at max
//!     frequency;
//!   * `--mixed` — a heterogeneous fleet (1×TP4 + 1×TP2 + 2×TP1) with
//!     occasional long prompts only the large replicas can hold, where
//!     capacity-aware `projected-headroom` routing visibly beats
//!     round-robin on SLO attainment (the §IV-B projection signal is
//!     load-bearing on the main path);
//!   * `--scenario <steady|burst|flash|diurnal|session|replay:<file>>` — the
//!     fleet-level workload engine: ONE shared arrival stream with
//!     correlated bursts / flash crowds / diurnal idle, served under
//!     every router policy (combinable with `--mixed`).  `--record
//!     <file>` writes the generated trace as replayable JSONL;
//!     `--replay <file>` (= `--scenario replay:<file>`) replays one
//!     bit-exactly; `--min-attainment <frac>` exits non-zero when the
//!     best router misses the E2E-attainment bar (the CI scenario
//!     matrix gate); `--faults on [--fault-seed <n>]` turns on the
//!     deterministic fault schedule (crashes, thermal throttles, link
//!     degradation, preemption notices) and `--require-recoveries`
//!     exits non-zero unless at least one crash recovery happened
//!     (the CI chaos gate); `--prefix-share on|off` toggles CoW prefix
//!     sharing for the whole matrix;
//!   * `--migrate-compare` — the CI migration gate: the same scenario
//!     trace (diurnal by default) served with `--migration off` vs
//!     `on` on a fleet-autoscaled deployment, asserting migrations
//!     happen, scale-in completes earlier (fewer engine iterations)
//!     and SLO attainment is no worse;
//!   * `--predict-compare` — the CI predictive gate: the same scenario
//!     trace served reactive (`--predict off`) vs predictive
//!     (`--predict on`), asserting predictive attainment is no worse
//!     at energy within `--energy-tolerance` (default 2%);
//!   * `--prefix-compare` — the CI prefix-sharing gate: the same
//!     multi-turn session scenario served with `--prefix-share off` vs
//!     `on`, asserting sharing stores prefixes once (strictly lower
//!     peak KV blocks), completes at least as many requests, and
//!     spends no more energy (cached prefill skips real work).
//!
//! Every mode accepts `--threads <n>` (RUN-phase worker threads,
//! 0 = auto): any value is bit-identical to `--threads 1`, so the flag
//! only changes wall-clock time, never results.
//!
//! Run with:
//!   cargo run --release --example fleet_demo [-- --replicas 4 --duration 600]
//!   cargo run --release --example fleet_demo -- --mixed [--duration 600]
//!   cargo run --release --example fleet_demo -- --scenario burst --record t.jsonl
//!   cargo run --release --example fleet_demo -- --replay t.jsonl --threads 4
//!   cargo run --release --example fleet_demo -- --migrate-compare --duration 600
//!   cargo run --release --example fleet_demo -- --prefix-compare --duration 600

use throttllem::cli::Args;
use throttllem::config::models::llama2_13b;
use throttllem::config::{
    FaultSpec, MigrationSpec, PredictSpec, PrefixSpec, ReplicaSpec, ServingConfig,
};
use throttllem::coordinator::{
    serve_fleet_plan, FleetOutcome, FleetPlan, PerfModel, Policy, RouterPolicy, Workload,
};
use throttllem::workload::fleet_trace::{
    record_fleet_trace, scenario_requests, Scenario,
};
use throttllem::workload::trace::{inject_long_prompts, synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let duration = args.get_f64("duration", 600.0)?;
    let seed = args.get_u64("seed", 0)?;
    let threads = args.get_u64("threads", 1)? as usize;
    if args.flag("prefix-compare") {
        prefix_compare(&args)
    } else if args.flag("predict-compare") {
        predict_compare(&args)
    } else if args.flag("migrate-compare") {
        migrate_compare(&args)
    } else if args.get("scenario").is_some() || args.get("replay").is_some() {
        scenario_mode(&args)
    } else if args.flag("mixed") {
        mixed_demo(duration, seed, threads)
    } else {
        homogeneous_demo(
            args.get_u64("replicas", 4)? as usize,
            duration,
            seed,
            threads,
        )
    }
}

/// The CI migration gate (`--migrate-compare`): serve the SAME
/// scenario trace (diurnal cold-start by default) on the same
/// fleet-autoscaled deployment twice — drain-based scale-in
/// (`--migration off`) vs live migration (`--migration on`) — and
/// enforce the migration contract:
///
///   1. live migrations actually happened on this trace,
///   2. scale-in completed earlier: strictly fewer engine iterations
///      executed across the fleet (drained victims stop iterating
///      instead of serving out their residents), and
///   3. E2E SLO attainment with migration is no worse than without
///      (the destination-side SLO guard's whole point).
///
/// Exits non-zero when any leg of the contract fails.
fn migrate_compare(args: &Args) -> anyhow::Result<()> {
    let duration = args.get_f64("duration", 600.0)?;
    let seed = args.get_u64("seed", 0)?;
    let replicas = args.get_u64("replicas", 4)? as usize;
    let scenario = Scenario::parse(args.get_or("scenario", "diurnal"))?;
    // An autoscaling policy activates the fleet (replica-count) axis;
    // cfg.scale_set stays empty, so replicas are fixed-TP and ONLY the
    // axis migration serves is in play.
    let policy = Policy::throttllem();
    let cfg = ServingConfig::throttllem(llama2_13b(2));
    let base = FleetPlan::homogeneous(replicas, RouterPolicy::RoundRobin, &cfg, policy, true)
        .with_threads(args.get_u64("threads", 1)? as usize);
    let model = PerfModel::train(&base.engines(), 100, seed);
    let peak = args.get_f64("peak", 0.55 * base.rated_rps())?;
    let (meta, mut reqs) =
        scenario_requests(&scenario, replicas, peak, duration, seed)?;
    LengthPredictor::oracle().apply(&mut reqs, cfg.max_tokens);
    println!(
        "migration gate: scenario {} on {replicas} x {} | {} requests \
         (peak ~{:.1} RPS over {:.0} s)\n",
        meta.scenario,
        cfg.engine.name,
        reqs.len(),
        meta.peak_rps,
        meta.duration_s
    );

    let run = |migration: Option<MigrationSpec>| {
        let plan = base.clone().with_migration(migration);
        serve_fleet_plan(&cfg, policy, &model, &reqs, &plan)
    };
    let off = run(None);
    let on = run(Some(MigrationSpec::enabled_default()));

    let att = |o: &FleetOutcome| {
        let a = o.total.stats.e2e_slo_attainment(cfg.slo.e2e_p99);
        if a.is_nan() {
            0.0
        } else {
            a
        }
    };
    let (att_off, att_on) = (att(&off), att(&on));
    let (it_off, it_on) = (off.total.timeline.len(), on.total.timeline.len());
    // Sum of per-replica serving windows: a scale-in victim's window
    // ends at deactivation once its residents are migrated away,
    // instead of stretching through its drain.
    let walls = |o: &FleetOutcome| -> f64 {
        o.replicas.iter().map(|r| r.stats.wall_s).sum()
    };
    let (wall_off, wall_on) = (walls(&off), walls(&on));
    print_header();
    print_row("scale-in by drain (--migration off)", &cfg, &off);
    print_row("live migration    (--migration on)", &cfg, &on);
    println!(
        "\nmigrations {} ok / {} slo-refused / {} capacity-refused | \
         engine iterations {} -> {} | summed replica windows {:.1} -> {:.1} s",
        on.migrations.migrations,
        on.migrations.refused_slo,
        on.migrations.refused_capacity,
        it_off,
        it_on,
        wall_off,
        wall_on,
    );
    anyhow::ensure!(
        off.migrations.migrations == 0,
        "migration gate: --migration off must never migrate"
    );
    anyhow::ensure!(
        on.migrations.migrations > 0,
        "migration gate: scenario produced no live migrations \
         (scale-in victims were all idle — retune peak/duration)"
    );
    // "Scale-in completes earlier" must show up as a strict win in at
    // least one of the two observable forms: fewer engine iterations
    // across the fleet (victims stop serving out residents), or a
    // strictly shorter summed per-replica serving window (victims
    // power off at deactivation).  Requiring one specific metric to
    // be strict would let a tie on that metric mask a real win on the
    // other (e.g. transfer-stall spin on an idle destination).
    anyhow::ensure!(
        it_on < it_off || wall_on < wall_off - 1e-9,
        "migration gate: scale-in did not complete earlier \
         (iterations {it_on} vs {it_off}, summed windows \
         {wall_on:.2} vs {wall_off:.2} s)"
    );
    anyhow::ensure!(
        att_on >= att_off - 1e-9,
        "migration gate: attainment regressed ({:.3}% with migration \
         vs {:.3}% without)",
        att_on * 100.0,
        att_off * 100.0
    );
    println!(
        "migration gate: OK (attainment {:.1}% >= {:.1}%, iterations {} vs {}, \
         windows {:.1} vs {:.1} s)",
        att_on * 100.0,
        att_off * 100.0,
        it_on,
        it_off,
        wall_on,
        wall_off,
    );
    Ok(())
}

/// The CI prefix-sharing gate (`--prefix-compare`): serve the SAME
/// multi-turn session workload on the same fleet twice —
/// `--prefix-share off` vs `on` — and enforce the sharing contract:
///
///   1. the off leg reports zero cached-prefix telemetry (the switch
///      really is the `Option<PrefixSpec>` on the plan),
///   2. sharing actually reused prefixes (cached prefill tokens > 0),
///   3. the fleet's peak KV-block footprint is STRICTLY lower with
///      sharing (each shared system prompt is stored once per replica
///      instead of once per resident turn),
///   4. sharing completes at least as many requests (freed blocks can
///      only widen admission), and
///   5. total energy is no higher (cached prefill skips real prefill
///      work; it cannot add any).
///
/// Exits non-zero when any leg of the contract fails.
fn prefix_compare(args: &Args) -> anyhow::Result<()> {
    let duration = args.get_f64("duration", 600.0)?;
    let seed = args.get_u64("seed", 0)?;
    let replicas = args.get_u64("replicas", 4)? as usize;
    let policy = Policy::throttle_only();
    let cfg = ServingConfig::throttllem(llama2_13b(2));
    let base = FleetPlan::homogeneous(replicas, RouterPolicy::RoundRobin, &cfg, policy, false)
        .with_threads(args.get_u64("threads", 1)? as usize);
    let model = PerfModel::train(&base.engines(), 100, seed);
    // Push utilization high enough that KV residency is the binding
    // constraint — the regime prefix sharing is for.
    let session = Scenario::session()
        .duration(duration)
        .utilization(args.get_f64("utilization", 0.7)?)
        .seed(seed)
        .turns(args.get_f64("session-turns", 4.0)?)
        .think_time(args.get_f64("session-think", 20.0)?)
        .shared_prefix(args.get_u64("session-prefix", 1024)? as u32);
    println!(
        "prefix gate: session scenario on {replicas} x {} \
         (~{:.1} turns/session, {} shared prefix tokens, {:.0} s)\n",
        cfg.engine.name,
        session.turns_mean,
        session.shared_prefix_tokens,
        session.duration_s
    );

    let run = |prefix: Option<PrefixSpec>| {
        let plan = base.clone().with_prefix_sharing(prefix);
        plan.serve(&cfg, policy, &model, Workload::Session(session))
    };
    let off = run(None);
    let on = run(Some(PrefixSpec::enabled_default()));

    print_header();
    print_row("per-turn prefill (--prefix-share off)", &cfg, &off);
    print_row("CoW prefix cache (--prefix-share on)", &cfg, &on);
    let (so, sn) = (&off.total.stats, &on.total.stats);
    println!(
        "\ncompleted {} -> {} | peak KV blocks {} -> {} | cached prefill \
         tokens {} -> {} | energy {:.1} -> {:.1} kJ",
        so.completed,
        sn.completed,
        so.peak_kv_blocks,
        sn.peak_kv_blocks,
        so.prefix_cached_tokens,
        sn.prefix_cached_tokens,
        so.total_energy_j / 1e3,
        sn.total_energy_j / 1e3,
    );
    anyhow::ensure!(
        so.prefix_cached_tokens == 0,
        "prefix gate: --prefix-share off leaked cached-prefix telemetry"
    );
    anyhow::ensure!(
        sn.prefix_cached_tokens > 0,
        "prefix gate: sharing never reused a prefix \
         (retune session turns / shared prefix length)"
    );
    anyhow::ensure!(
        sn.peak_kv_blocks < so.peak_kv_blocks,
        "prefix gate: peak KV blocks did not drop ({} with sharing vs \
         {} without)",
        sn.peak_kv_blocks,
        so.peak_kv_blocks
    );
    anyhow::ensure!(
        sn.completed >= so.completed,
        "prefix gate: sharing completed fewer requests ({} vs {})",
        sn.completed,
        so.completed
    );
    anyhow::ensure!(
        sn.total_energy_j <= so.total_energy_j + 1e-6,
        "prefix gate: sharing spent more energy ({:.1} kJ vs {:.1} kJ)",
        sn.total_energy_j / 1e3,
        so.total_energy_j / 1e3
    );
    println!(
        "prefix gate: OK (peak KV {} < {}, completed {} >= {}, energy \
         {:.1} kJ <= {:.1} kJ)",
        sn.peak_kv_blocks,
        so.peak_kv_blocks,
        sn.completed,
        so.completed,
        sn.total_energy_j / 1e3,
        so.total_energy_j / 1e3
    );
    Ok(())
}

/// The CI predictive gate (`--predict-compare`): serve the SAME
/// scenario trace (diurnal by default; CI also runs flash) on the same
/// fleet-autoscaled deployment twice — reactive (`--predict off`) vs
/// predictive (`--predict on`), with live migration enabled on BOTH
/// legs so the only delta is the forecaster — and enforce the
/// ROADMAP's "beat the reactive baseline" contract:
///
///   1. the reactive leg reports zero predictive telemetry,
///   2. the predictive leg actually decided something (pre-warm,
///      proactive migration, or cost-aware scale-in victim),
///   3. E2E SLO attainment is no worse than reactive, and
///   4. energy stays within `--energy-tolerance` (default 2%) of the
///      reactive leg.
///
/// Exits non-zero when any leg of the contract fails.
fn predict_compare(args: &Args) -> anyhow::Result<()> {
    let duration = args.get_f64("duration", 600.0)?;
    let seed = args.get_u64("seed", 0)?;
    let replicas = args.get_u64("replicas", 4)? as usize;
    let scenario = Scenario::parse(args.get_or("scenario", "diurnal"))?;
    let tolerance = args.get_f64("energy-tolerance", 0.02)?;
    let policy = Policy::throttllem();
    let cfg = ServingConfig::throttllem(llama2_13b(2));
    let base = FleetPlan::homogeneous(replicas, RouterPolicy::RoundRobin, &cfg, policy, true)
        .with_migration(Some(MigrationSpec::enabled_default()))
        .with_threads(args.get_u64("threads", 1)? as usize);
    let model = PerfModel::train(&base.engines(), 100, seed);
    let peak = args.get_f64("peak", 0.55 * base.rated_rps())?;
    let (meta, mut reqs) =
        scenario_requests(&scenario, replicas, peak, duration, seed)?;
    LengthPredictor::oracle().apply(&mut reqs, cfg.max_tokens);
    println!(
        "predictive gate: scenario {} on {replicas} x {} | {} requests \
         (peak ~{:.1} RPS over {:.0} s)\n",
        meta.scenario,
        cfg.engine.name,
        reqs.len(),
        meta.peak_rps,
        meta.duration_s
    );

    let run = |predict: Option<PredictSpec>| {
        let plan = base.clone().with_prediction(predict);
        plan.serve(&cfg, policy, &model, Workload::Trace(&reqs))
    };
    // The forecaster's assumed day length is the scenario duration
    // (the synthetic diurnal cycle spans exactly the trace).
    let mut spec = PredictSpec::enabled_default();
    spec.period_s = args.get_f64("predict-period", duration)?;
    let reactive = run(None);
    let predictive = run(Some(spec));

    let att = |o: &FleetOutcome| {
        let a = o.total.stats.e2e_slo_attainment(cfg.slo.e2e_p99);
        if a.is_nan() {
            0.0
        } else {
            a
        }
    };
    let (att_r, att_p) = (att(&reactive), att(&predictive));
    let (e_r, e_p) = (
        reactive.total.stats.total_energy_j,
        predictive.total.stats.total_energy_j,
    );
    print_header();
    print_row("reactive   (--predict off)", &cfg, &reactive);
    print_row("predictive (--predict on)", &cfg, &predictive);
    let pc = &predictive.predict;
    println!(
        "\npredictive: {} forecast ticks, {} pre-warmed, {} proactive \
         migrations ({} refused), {} cost-aware scale-ins",
        pc.forecast_ticks,
        pc.prewarmed,
        pc.proactive_migrations,
        pc.proactive_refused,
        pc.predictive_scale_ins
    );
    anyhow::ensure!(
        reactive.predict == Default::default(),
        "predictive gate: --predict off leaked predictive telemetry"
    );
    anyhow::ensure!(
        pc.forecast_ticks > 0,
        "predictive gate: forecaster never ran (no fleet ticks?)"
    );
    anyhow::ensure!(
        pc.prewarmed + pc.proactive_migrations + pc.predictive_scale_ins > 0,
        "predictive gate: predictive control never made a decision \
         (retune peak/duration)"
    );
    anyhow::ensure!(
        att_p >= att_r - 1e-9,
        "predictive gate: attainment regressed ({:.3}% predictive vs \
         {:.3}% reactive)",
        att_p * 100.0,
        att_r * 100.0
    );
    anyhow::ensure!(
        e_p <= e_r * (1.0 + tolerance),
        "predictive gate: energy blew the {:.0}% budget ({:.1} kJ \
         predictive vs {:.1} kJ reactive)",
        tolerance * 100.0,
        e_p / 1e3,
        e_r / 1e3
    );
    println!(
        "predictive gate: OK (attainment {:.1}% >= {:.1}%, energy \
         {:.1} kJ <= {:.1} kJ + {:.0}%)",
        att_p * 100.0,
        att_r * 100.0,
        e_p / 1e3,
        e_r / 1e3,
        tolerance * 100.0
    );
    Ok(())
}

/// The scenario matrix entry point: one shared fleet trace (generated
/// or replayed) served under every router policy.
fn scenario_mode(args: &Args) -> anyhow::Result<()> {
    let duration = args.get_f64("duration", 600.0)?;
    let seed = args.get_u64("seed", 0)?;
    let scenario = match (args.get("scenario"), args.get("replay")) {
        (Some(s), None) => Scenario::parse(s)?,
        (None, Some(f)) => Scenario::Replay(f.to_string()),
        (Some(_), Some(_)) => {
            anyhow::bail!("--scenario and --replay are mutually exclusive")
        }
        (None, None) => unreachable!("scenario_mode needs --scenario/--replay"),
    };
    let threads = args.get_u64("threads", 1)? as usize;
    let faults: Option<FaultSpec> = {
        let mut f = match args.get("faults") {
            Some(v) => FaultSpec::parse_enabled(v)?,
            None => None,
        };
        if let Some(f) = f.as_mut() {
            f.seed = args.get_u64("fault-seed", f.seed)?;
        }
        f
    };
    let prefix: Option<PrefixSpec> = match args.get("prefix-share") {
        Some(v) => PrefixSpec::parse_enabled(v)?,
        None => None,
    };
    let policy = Policy::throttle_only();
    let (plan, cfg, label) = if args.flag("mixed") {
        let specs = vec![
            ReplicaSpec::fixed(llama2_13b(4)),
            ReplicaSpec::fixed(llama2_13b(2)),
            ReplicaSpec::fixed(llama2_13b(1)),
            ReplicaSpec::fixed(llama2_13b(1)),
        ];
        (
            FleetPlan::heterogeneous(specs, RouterPolicy::RoundRobin)
                .with_faults(faults)
                .with_prefix_sharing(prefix)
                .with_threads(threads),
            ServingConfig::throttllem(llama2_13b(4)),
            "mixed fleet (1xTP4 + 1xTP2 + 2xTP1)".to_string(),
        )
    } else {
        let replicas = args.get_u64("replicas", 4)? as usize;
        let cfg = ServingConfig::throttllem(llama2_13b(2));
        let plan = FleetPlan::homogeneous(replicas, RouterPolicy::RoundRobin, &cfg, policy, false)
            .with_faults(faults)
            .with_prefix_sharing(prefix)
            .with_threads(threads);
        (plan, cfg, format!("{replicas} x llama2-13b-tp2"))
    };
    let model = PerfModel::train(&plan.engines(), 100, seed);
    // Right-scale to 60% of the fleet's aggregate rated load: bursts
    // and flash crowds then push PAST rated capacity, which is the
    // point of the exercise.
    let peak = args.get_f64("peak", 0.6 * plan.rated_rps())?;
    let (meta, mut reqs) =
        scenario_requests(&scenario, plan.replicas.len(), peak, duration, seed)?;
    if let Some(path) = args.get("record") {
        record_fleet_trace(path, &meta, &reqs)?;
        eprintln!("recorded fleet trace: {path}");
    }
    println!(
        "scenario {} on {label}: {} requests (peak ~{:.1} RPS over {:.0} s)\n",
        meta.scenario,
        reqs.len(),
        meta.peak_rps,
        meta.duration_s
    );
    LengthPredictor::oracle().apply(&mut reqs, cfg.max_tokens);

    print_header();
    let mut best_att = f64::NEG_INFINITY;
    let mut total_recoveries = 0u64;
    let mut rr = None;
    let mut ph = None;
    for router in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::ProjectedHeadroom,
    ] {
        let plan = FleetPlan {
            router,
            ..plan.clone()
        };
        let out = serve_fleet_plan(&cfg, policy, &model, &reqs, &plan);
        print_row(&format!("{} ({})", meta.scenario, router.name()), &cfg, &out);
        if faults.is_some() {
            let fc = &out.faults;
            println!(
                "  faults: {} crashes ({} recovered / {} requeued, {} retries), \
                 {} throttles, {} preemptions, {} link failures | \
                 shed {} / fault-lost {} / respawns {}",
                fc.crashes,
                fc.crash_recoveries,
                fc.crash_requeues,
                fc.retries,
                fc.throttle_events,
                fc.preemptions,
                fc.link_failures,
                fc.shed,
                fc.faulted_lost,
                fc.respawns
            );
            total_recoveries += fc.crash_recoveries;
        }
        let s = &out.total.stats;
        let att = s.e2e_slo_attainment(cfg.slo.e2e_p99);
        let att = if att.is_nan() { 0.0 } else { att };
        let jpt = if s.total_tokens > 0 {
            s.total_energy_j / s.total_tokens as f64
        } else {
            f64::INFINITY
        };
        best_att = best_att.max(att);
        match router {
            RouterPolicy::RoundRobin => rr = Some((att, jpt)),
            RouterPolicy::ProjectedHeadroom => ph = Some((att, jpt)),
            _ => {}
        }
    }
    if let (Some((rra, rrj)), Some((pha, phj))) = (rr, ph) {
        println!(
            "\nprojected-headroom vs round-robin: attainment {:.1}% vs {:.1}%, \
             J/token {:.3} vs {:.3} ({})",
            pha * 100.0,
            rra * 100.0,
            phj,
            rrj,
            if pha >= rra || phj <= rrj {
                "ok"
            } else {
                "REGRESSION"
            }
        );
    }
    if args.get("min-attainment").is_some() {
        let min = args.get_f64("min-attainment", 0.0)?;
        anyhow::ensure!(
            best_att >= min,
            "SLO attainment gate: best router reached {:.1}% < required {:.1}%",
            best_att * 100.0,
            min * 100.0
        );
        println!(
            "attainment gate: best {:.1}% >= required {:.1}%",
            best_att * 100.0,
            min * 100.0
        );
    }
    if args.flag("require-recoveries") {
        anyhow::ensure!(
            faults.is_some(),
            "--require-recoveries needs --faults on"
        );
        anyhow::ensure!(
            total_recoveries > 0,
            "chaos gate: no crash recoveries happened on this schedule \
             (retune --fault-seed / fault rates / duration)"
        );
        println!("chaos gate: {total_recoveries} crash recoveries across routers");
    }
    Ok(())
}

fn print_header() {
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "deployment", "E2E p99", "E2E att.", "TBT att.", "freq", "energy", "TPJ"
    );
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "", "[s]", "[%]", "[%]", "[MHz]", "[kJ]", "[tok/J]"
    );
}

fn print_row(name: &str, cfg: &ServingConfig, out: &FleetOutcome) {
    let s = &out.total.stats;
    println!(
        "{:<34} {:>9.2} {:>9.1} {:>9.1} {:>9.0} {:>10.1} {:>8.3}",
        name,
        s.e2e.p99(),
        s.e2e_slo_attainment(cfg.slo.e2e_p99) * 100.0,
        s.tbt_slo_attainment(cfg.slo.tbt_avg) * 100.0,
        s.freq.mean(),
        s.total_energy_j / 1e3,
        s.tokens_per_joule(),
    );
}

fn print_replica_breakdown(out: &FleetOutcome) {
    println!(
        "{:<8} {:<16} {:>8} {:>10} {:>8} {:>10} {:>11}",
        "replica", "engine", "routed", "completed", "dropped", "freq[MHz]", "energy[kJ]"
    );
    for (i, r) in out.replicas.iter().enumerate() {
        println!(
            "{:<8} {:<16} {:>8} {:>10} {:>8} {:>10.0} {:>11.1}",
            i,
            r.engine,
            r.routed,
            r.stats.completed,
            r.stats.dropped,
            r.stats.freq.mean(),
            r.stats.total_energy_j / 1e3,
        );
    }
}

fn homogeneous_demo(
    replicas: usize,
    duration: f64,
    seed: u64,
    threads: usize,
) -> anyhow::Result<()> {
    let spec = llama2_13b(2);
    let model = PerfModel::train(&[spec.clone()], 100, seed);
    // Right-scale to ~80% of the fleet's aggregate rated load.
    let peak = 0.8 * spec.max_load_rps * replicas as f64;
    let mut reqs = synth_trace(&TraceParams::short(duration, peak, seed));
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    println!(
        "fleet of {replicas} x {} | {} requests over {duration:.0} s (peak ~{peak:.1} RPS)\n",
        spec.name,
        reqs.len()
    );

    let combos: Vec<(String, Policy, ServingConfig, RouterPolicy)> = vec![
        (
            format!("triton x{replicas} (rr)"),
            Policy::triton(),
            ServingConfig::triton(spec.clone()),
            RouterPolicy::RoundRobin,
        ),
        (
            format!("throttllem x{replicas} (rr)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::RoundRobin,
        ),
        (
            format!("throttllem x{replicas} (least-loaded)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::LeastLoaded,
        ),
        (
            format!("throttllem x{replicas} (headroom)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::ProjectedHeadroom,
        ),
    ];

    print_header();
    let mut detailed: Option<FleetOutcome> = None;
    for (name, policy, cfg, router) in combos {
        let plan = FleetPlan::homogeneous(replicas, router, &cfg, policy, false)
            .with_threads(threads);
        let out = serve_fleet_plan(&cfg, policy, &model, &reqs, &plan);
        print_row(&name, &cfg, &out);
        if router == RouterPolicy::LeastLoaded {
            detailed = Some(out);
        }
    }

    // Per-replica breakdown of the least-loaded throttLL'eM fleet.
    let out = detailed.expect("least-loaded run present");
    println!("\n-- per-replica breakdown (throttllem, least-loaded) --");
    print_replica_breakdown(&out);
    println!(
        "rerouted on universal rejection: {} | aggregate energy {:.1} kJ",
        out.rerouted,
        out.total.stats.total_energy_j / 1e3
    );
    Ok(())
}

fn mixed_demo(duration: f64, seed: u64, threads: usize) -> anyhow::Result<()> {
    let specs = vec![
        ReplicaSpec::fixed(llama2_13b(4)),
        ReplicaSpec::fixed(llama2_13b(2)),
        ReplicaSpec::fixed(llama2_13b(1)),
        ReplicaSpec::fixed(llama2_13b(1)),
    ];
    let base = FleetPlan::heterogeneous(specs, RouterPolicy::RoundRobin).with_threads(threads);
    let rated = base.rated_rps();
    let peak = 0.6 * rated;
    let cfg = ServingConfig::throttllem(llama2_13b(4));
    // Train on the fleet's unique engines (two replicas share TP1).
    let model = PerfModel::train(&base.engines(), 100, seed);

    let mut reqs = synth_trace(&TraceParams::short(duration, peak, seed));
    // 10k tokens -> 157 KV blocks: impossible on TP1 (120 blocks),
    // comfortable on TP2 (439) and TP4 (1050).
    inject_long_prompts(&mut reqs, duration, 20.0, 10_000, 64);
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    println!(
        "mixed fleet (1xTP4 + 1xTP2 + 2xTP1, rated {rated:.1} RPS) | {} requests \
         over {duration:.0} s (peak ~{peak:.1} RPS, long 10k-token prompt every 20 s)\n",
        reqs.len()
    );

    print_header();
    let mut best: Option<FleetOutcome> = None;
    for router in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::ProjectedHeadroom,
    ] {
        let plan = FleetPlan {
            router,
            ..base.clone()
        };
        let out =
            serve_fleet_plan(&cfg, Policy::throttle_only(), &model, &reqs, &plan);
        print_row(&format!("throttllem mixed ({})", router.name()), &cfg, &out);
        if router == RouterPolicy::ProjectedHeadroom {
            best = Some(out);
        }
    }

    let out = best.expect("projected-headroom run present");
    println!("\n-- per-replica breakdown (throttllem mixed, projected-headroom) --");
    print_replica_breakdown(&out);
    println!(
        "rerouted on universal rejection: {} (capacity-aware routing places long \
         prompts on the large replicas up front)",
        out.rerouted
    );
    Ok(())
}
