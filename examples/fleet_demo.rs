//! Fleet coordinator demo: horizontal replication with per-replica
//! frequency control (the GreenLLM/AGFT-style fleet extension of the
//! paper's single-engine throttLL'eM).
//!
//! Serves a trace right-scaled to N replicas' aggregate capacity under
//! every admission-router policy, against a fleet of Triton replicas
//! at max frequency, and prints per-replica plus fleet-aggregate
//! energy, TBT and E2E attainment.
//!
//! Run with:
//!   cargo run --release --example fleet_demo [-- --replicas 4 --duration 600]

use throttllem::cli::Args;
use throttllem::config::models::llama2_13b;
use throttllem::config::ServingConfig;
use throttllem::coordinator::{
    serve_fleet, FleetOutcome, FleetSpec, PerfModel, Policy, RouterPolicy,
};
use throttllem::workload::trace::{synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let replicas = args.get_u64("replicas", 4)? as usize;
    let duration = args.get_f64("duration", 600.0)?;
    let seed = args.get_u64("seed", 0)?;

    let spec = llama2_13b(2);
    let model = PerfModel::train(&[spec.clone()], 100, seed);
    // Right-scale to ~80% of the fleet's aggregate rated load.
    let peak = 0.8 * spec.max_load_rps * replicas as f64;
    let mut reqs = synth_trace(&TraceParams::short(duration, peak, seed));
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    println!(
        "fleet of {replicas} x {} | {} requests over {duration:.0} s (peak ~{peak:.1} RPS)\n",
        spec.name,
        reqs.len()
    );

    let combos: Vec<(String, Policy, ServingConfig, RouterPolicy)> = vec![
        (
            format!("triton x{replicas} (rr)"),
            Policy::triton(),
            ServingConfig::triton(spec.clone()),
            RouterPolicy::RoundRobin,
        ),
        (
            format!("throttllem x{replicas} (rr)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::RoundRobin,
        ),
        (
            format!("throttllem x{replicas} (least-loaded)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::LeastLoaded,
        ),
        (
            format!("throttllem x{replicas} (headroom)"),
            Policy::throttle_only(),
            ServingConfig::throttllem(spec.clone()),
            RouterPolicy::ProjectedHeadroom,
        ),
    ];

    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "deployment", "E2E p99", "E2E att.", "TBT att.", "freq", "energy", "TPJ"
    );
    println!(
        "{:<34} {:>9} {:>9} {:>9} {:>9} {:>10} {:>8}",
        "", "[s]", "[%]", "[%]", "[MHz]", "[kJ]", "[tok/J]"
    );
    let mut detailed: Option<FleetOutcome> = None;
    for (name, policy, cfg, router) in combos {
        let fleet = FleetSpec {
            replicas,
            router,
            autoscale_replicas: false,
        };
        let out = serve_fleet(&cfg, policy, &model, &reqs, &fleet);
        let s = &out.total.stats;
        println!(
            "{:<34} {:>9.2} {:>9.1} {:>9.1} {:>9.0} {:>10.1} {:>8.3}",
            name,
            s.e2e.p99(),
            s.e2e_slo_attainment(cfg.slo.e2e_p99) * 100.0,
            s.tbt_slo_attainment(cfg.slo.tbt_avg) * 100.0,
            s.freq.mean(),
            s.total_energy_j / 1e3,
            s.tokens_per_joule(),
        );
        if router == RouterPolicy::LeastLoaded {
            detailed = Some(out);
        }
    }

    // Per-replica breakdown of the least-loaded throttLL'eM fleet.
    let out = detailed.expect("least-loaded run present");
    println!("\n-- per-replica breakdown (throttllem, least-loaded) --");
    println!(
        "{:<8} {:>8} {:>10} {:>8} {:>10} {:>11}",
        "replica", "routed", "completed", "dropped", "freq[MHz]", "energy[kJ]"
    );
    for (i, r) in out.replicas.iter().enumerate() {
        println!(
            "{:<8} {:>8} {:>10} {:>8} {:>10.0} {:>11.1}",
            i,
            r.routed,
            r.stats.completed,
            r.stats.dropped,
            r.stats.freq.mean(),
            r.stats.total_energy_j / 1e3,
        );
    }
    println!(
        "rerouted on universal rejection: {} | aggregate energy {:.1} kJ",
        out.rerouted,
        out.total.stats.total_energy_j / 1e3
    );
    Ok(())
}
