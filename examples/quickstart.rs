//! Quickstart: stand up throttLL'eM on a Llama2-13B TP2 engine, serve
//! a short Azure-like trace, and compare against the Triton baseline.
//!
//! Run with: `cargo run --release --example quickstart`

use throttllem::config::models::llama2_13b;
use throttllem::config::ServingConfig;
use throttllem::coordinator::{serve_trace, PerfModel, Policy};
use throttllem::workload::trace::{synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() -> anyhow::Result<()> {
    // 1. Pick an engine (Table II descriptor) and SLOs: TBT <= 200 ms
    //    (human reading rate), E2E p99 <= the engine's rated profile.
    let engine = llama2_13b(2);
    println!(
        "engine {}: {} KV blocks, E2E SLO {:.1} s",
        engine.name, engine.kv_blocks, engine.e2e_slo_p99
    );

    // 2. Train the iteration-level performance model M on profiling
    //    data (engine size, batch, KV, frequency) -> IPS.
    println!("training performance model M ...");
    let model = PerfModel::train(&[engine.clone()], 100, 0);

    // 3. Synthesize a 5-minute Azure-like trace right-scaled to ~60%
    //    of the engine's rated max load, with an oracle length
    //    predictor (swap in `LengthPredictor::noisy(0.15, 0)` to see
    //    the degraded-predictor behaviour).
    let mut reqs = synth_trace(&TraceParams::short(300.0, 0.6 * engine.max_load_rps, 42));
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    println!("trace: {} requests over 300 s", reqs.len());

    // 4. Serve under both policies.
    let triton = serve_trace(
        &ServingConfig::triton(engine.clone()),
        Policy::triton(),
        &model,
        &reqs,
    );
    let ours = serve_trace(
        &ServingConfig::throttllem(engine.clone()),
        Policy::throttle_only(),
        &model,
        &reqs,
    );

    // 5. Report.
    println!("\n{:<22} {:>12} {:>12}", "metric", "triton", "throttLL'eM");
    let row = |name: &str, a: f64, b: f64| {
        println!("{name:<22} {a:>12.3} {b:>12.3}");
    };
    row("E2E p99 [s]", triton.stats.e2e.p99(), ours.stats.e2e.p99());
    row(
        "TBT avg [ms]",
        triton.stats.tbt.mean() * 1e3,
        ours.stats.tbt.mean() * 1e3,
    );
    row(
        "mean frequency [MHz]",
        triton.stats.freq.mean(),
        ours.stats.freq.mean(),
    );
    row(
        "mean power [W]",
        triton.stats.power.mean(),
        ours.stats.power.mean(),
    );
    row(
        "energy [kJ]",
        triton.stats.total_energy_j / 1e3,
        ours.stats.total_energy_j / 1e3,
    );
    row(
        "tokens per Joule",
        triton.stats.tokens_per_joule(),
        ours.stats.tokens_per_joule(),
    );
    let savings = 1.0 - ours.stats.total_energy_j / triton.stats.total_energy_j;
    println!(
        "\nthrottLL'eM saved {:.1}% energy while meeting the {:.1} s E2E SLO \
         (p99 achieved: {:.1} s)",
        savings * 100.0,
        engine.e2e_slo_p99,
        ours.stats.e2e.p99()
    );
    Ok(())
}
