//! END-TO-END VALIDATION: serve real batched requests through the
//! PJRT-compiled tiny-llama-sim artifacts — all three layers compose:
//!   L1 Pallas flash-decode kernel (lowered inside the HLO),
//!   L2 JAX transformer (AOT-compiled to artifacts/*.hlo.txt),
//!   L3 Rust coordinator + runtime (this binary; Python not running).
//!
//! The driver batches a stream of prompt requests into the available
//! batch buckets, runs prefill + decode iterations, verifies the greedy
//! generations against the golden outputs recorded by `aot.py`, and
//! reports latency/throughput.
//!
//! Requires `make artifacts`. Run with:
//!   cargo run --release --example real_model_serving [-- --requests 24 --steps 24]

// Reviewed wall-clock use: this example times a real PJRT execution;
// nothing here feeds simulated outcomes.
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use throttllem::cli::Args;
use throttllem::jsonl::parse;
use throttllem::runtime::ModelRuntime;
use throttllem::sim::Pcg64;

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let n_requests = args.get_u64("requests", 24)? as usize;
    let steps = args.get_u64("steps", 24)? as usize;

    let t0 = Instant::now();
    let rt = ModelRuntime::load(&dir)?;
    println!(
        "loaded + compiled {} artifacts on {} in {:.2} s",
        rt.manifest.batches.len() * 2,
        rt.platform(),
        t0.elapsed().as_secs_f64()
    );
    let cfg = *rt.config();
    println!(
        "model: {} layers, d={}, {} heads, vocab {}, max_seq {}",
        cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.vocab, cfg.max_seq
    );

    // -- golden parity check (cross-language numerics) ----------------
    let manifest_text = std::fs::read_to_string(dir.join("manifest.json"))?;
    let manifest = parse(&manifest_text)?;
    if let Some(golden) = manifest.get("golden") {
        let prompts: Vec<Vec<i32>> = golden
            .get("prompts")
            .and_then(|p| p.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_f64())
                    .map(|x| x as i32)
                    .collect()
            })
            .collect();
        let g_steps = golden.get("steps").and_then(|s| s.as_u64()).unwrap_or(0) as usize;
        let want: Vec<Vec<i32>> = golden
            .get("tokens")
            .and_then(|t| t.as_arr())
            .unwrap_or(&[])
            .iter()
            .map(|row| {
                row.as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .filter_map(|x| x.as_f64())
                    .map(|x| x as i32)
                    .collect()
            })
            .collect();
        let got = rt.greedy_generate(&prompts, g_steps)?;
        anyhow::ensure!(
            got == want,
            "golden parity FAILED:\n  rust: {got:?}\n  jax:  {want:?}"
        );
        println!(
            "golden parity OK: {} rows x {} greedy tokens match the JAX reference",
            want.len(),
            g_steps
        );
    }

    // -- batched serving run ------------------------------------------
    let mut rng = Pcg64::new(args.get_u64("seed", 1)?);
    let requests: Vec<Vec<i32>> = (0..n_requests)
        .map(|_| {
            let len = rng.uniform_usize(3, cfg.prompt_len as usize);
            (0..len)
                .map(|_| rng.uniform_u64(1, cfg.vocab as u64 - 1) as i32)
                .collect()
        })
        .collect();

    let max_bucket = *rt.manifest.batches.iter().max().unwrap() as usize;
    let mut served = 0usize;
    let mut total_tokens = 0usize;
    let mut prefill_ms = Vec::new();
    let mut decode_ms = Vec::new();
    let wall = Instant::now();
    for chunk in requests.chunks(max_bucket) {
        let t = Instant::now();
        let (mut state, first) = rt.prefill(chunk)?;
        prefill_ms.push(t.elapsed().as_secs_f64() * 1e3);
        let mut last = first;
        total_tokens += last.len();
        for _ in 1..steps {
            let t = Instant::now();
            last = rt.decode_step(&mut state, &last)?;
            decode_ms.push(t.elapsed().as_secs_f64() * 1e3);
            total_tokens += last.len();
        }
        served += chunk.len();
    }
    let wall_s = wall.elapsed().as_secs_f64();
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    println!("\nserved {served} requests, {total_tokens} tokens in {wall_s:.2} s");
    println!("  throughput       : {:.1} tok/s", total_tokens as f64 / wall_s);
    println!("  prefill latency  : {:.2} ms avg (batch bucket {max_bucket})", mean(&prefill_ms));
    println!("  decode iteration : {:.2} ms avg (TBT per token)", mean(&decode_ms));
    println!("  python on request path: NO (PJRT artifacts only)");
    Ok(())
}
