//! Headline driver (paper §V-D1 / Fig. 8): replay the scaled
//! Azure-like trace on a chosen engine under Triton and throttLL'eM at
//! 0% / 15% / 30% predictor error, and print the E2E/TBT/power/TPJ
//! comparison the paper reports.
//!
//! Run with:
//!   cargo run --release --example serve_trace [-- --engine llama2-13b-tp2 --duration 900]

use throttllem::cli::Args;
use throttllem::config::models::{llama2_13b, llama3_8b};
use throttllem::config::{EngineSpec, ServingConfig};
use throttllem::coordinator::{serve_trace, PerfModel, Policy, ServeOutcome};
use throttllem::workload::trace::{synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn engine_by_name(name: &str) -> EngineSpec {
    match name {
        "llama3-8b-tp1" => llama3_8b(1),
        "llama2-13b-tp1" => llama2_13b(1),
        "llama2-13b-tp2" => llama2_13b(2),
        "llama2-13b-tp4" => llama2_13b(4),
        other => panic!("unsupported engine {other}"),
    }
}

fn main() -> anyhow::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let engine = engine_by_name(args.get_or("engine", "llama2-13b-tp2"));
    let duration = args.get_f64("duration", 900.0)?;
    let seed = args.get_u64("seed", 0)?;
    // Fraction of the paper's rated max load to replay at. The paper's
    // Table II loads were measured on ITS testbed; this substrate
    // saturates earlier (see `cargo bench --bench table2`), so the
    // default targets ~80% of the paper's rated point. Pass --load 1.0
    // to reproduce the at-capacity regime.
    let load = args.get_f64("load", 0.8)?;

    println!("== serve_trace: {} over {duration:.0} s ==", engine.name);
    let model = PerfModel::train(&[engine.clone()], 120, seed);
    // Right-scale the trace to the engine's max load (§V-A).
    let peak = load * engine.max_load_rps;
    let base = synth_trace(&TraceParams::short(duration, peak, seed));
    println!("trace: {} requests (peak ~{peak:.2} RPS)", base.len());

    let mut rows: Vec<(String, ServeOutcome)> = Vec::new();

    let cfg_t = ServingConfig::triton(engine.clone());
    let mut reqs = base.clone();
    LengthPredictor::oracle().apply(&mut reqs, cfg_t.max_tokens);
    rows.push((
        "triton".into(),
        serve_trace(&cfg_t, Policy::triton(), &model, &reqs),
    ));

    for err in [0.0, 0.15, 0.30] {
        let mut cfg = ServingConfig::throttllem(engine.clone());
        cfg.predictor_p95_error = err;
        let mut reqs = base.clone();
        let pred = if err == 0.0 {
            LengthPredictor::oracle()
        } else {
            LengthPredictor::noisy(err, seed)
        };
        pred.apply(&mut reqs, cfg.max_tokens);
        rows.push((
            format!("throttllem@{:.0}%", err * 100.0),
            serve_trace(&cfg, Policy::throttle_only(), &model, &reqs),
        ));
    }

    println!(
        "\n{:<16} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "policy", "E2E p99", "TBT avg", "TTFT p50", "queue99", "freq", "energy", "TPJ"
    );
    println!(
        "{:<16} {:>9} {:>9} {:>9} {:>9} {:>9} {:>9} {:>8}",
        "", "[s]", "[ms]", "[ms]", "[s]", "[MHz]", "[kJ]", "[tok/J]"
    );
    let triton_energy = rows[0].1.stats.total_energy_j;
    for (name, out) in &rows {
        let s = &out.stats;
        println!(
            "{:<16} {:>9.2} {:>9.1} {:>9.0} {:>9.2} {:>9.0} {:>9.1} {:>8.3}",
            name,
            s.e2e.p99(),
            s.tbt.mean() * 1e3,
            s.ttft.p50() * 1e3,
            s.queue.p99(),
            s.freq.mean(),
            s.total_energy_j / 1e3,
            s.tokens_per_joule(),
        );
    }
    for (name, out) in rows.iter().skip(1) {
        println!(
            "{name}: energy -{:.1}% vs triton, SLO p99 {} (limit {:.1} s)",
            (1.0 - out.stats.total_energy_j / triton_energy) * 100.0,
            if out.stats.e2e.p99() <= engine.e2e_slo_p99 {
                "MET"
            } else {
                "MISSED"
            },
            engine.e2e_slo_p99,
        );
    }
    Ok(())
}
