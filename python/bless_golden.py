#!/usr/bin/env python3
"""Cross-language oracle for the fleet-trace golden hash.

Bit-exact Python port of the Rust deterministic generation chain
(`sim/rng.rs` PCG-64 XSL-RR, `sim/detmath.rs` IEEE-basic-ops
transcendentals, `workload/fleet_trace.rs` scenario synthesis —
including the multi-turn session synthesizer — and `jsonl.rs`'s
canonical writer), used to bless
`rust/tests/golden/fleet_trace_burst.hash` and
`rust/tests/golden/fleet_trace_session.hash` from a workspace that has
no Rust toolchain.  Python floats are IEEE-754 doubles and every operation
used here (+ - * / sqrt, bit manipulation) is exactly specified, so a
faithful transcription produces the same bits as the Rust code on any
platform.

The only non-arithmetic dependency is float formatting: Rust's
`Display` and Python's `repr` both emit the shortest decimal string
that round-trips to the same double (Ryu and David Gay's algorithm
agree on this output); Python's scientific-notation spelling for
|x| < 1e-4 is reformatted positionally to match Rust.

Usage:
    python3 python/bless_golden.py           # self-check + print hashes
    python3 python/bless_golden.py --write   # also write the golden files

CI's golden-guard job independently verifies the committed hash against
the real Rust generator; a mismatch there (with both values in the job
log) means this port drifted and the Rust value wins.
"""

import math
import os
import struct
import sys

M64 = (1 << 64) - 1
M128 = (1 << 128) - 1
PCG_MULT = 0x2360ED051FC65DA44385DF649FCCF645
LN2 = 0.6931471805599453  # std::f64::consts::LN_2
PI = math.pi
TAU = 2.0 * PI
SQRT_2 = math.sqrt(2.0)
MIN_POSITIVE = 2.2250738585072014e-308
INV_2P53 = 1.0 / 9007199254740992.0  # 1 / 2^53 (exact power of two)


def f64_to_bits(x: float) -> int:
    return struct.unpack("<Q", struct.pack("<d", x))[0]


def bits_to_f64(b: int) -> float:
    return struct.unpack("<d", struct.pack("<Q", b))[0]


# ---- sim/rng.rs: PCG-64 XSL-RR ---------------------------------------


class Pcg64:
    def __init__(self, seed: int, stream: int = 0xDA3E39CB94B95BDB):
        self.state = 0
        self.inc = ((stream << 1) | 1) & M128
        self.next_u64()
        self.state = (self.state + seed) & M128
        self.next_u64()

    def next_u64(self) -> int:
        self.state = (self.state * PCG_MULT + self.inc) & M128
        rot = self.state >> 122
        xored = ((self.state >> 64) ^ self.state) & M64
        return ((xored >> rot) | (xored << ((64 - rot) % 64))) & M64

    def next_f64(self) -> float:
        return float(self.next_u64() >> 11) * INV_2P53

    def uniform_f64(self, lo: float, hi: float) -> float:
        return lo + (hi - lo) * self.next_f64()


# ---- sim/detmath.rs ---------------------------------------------------


def rust_round(x: float) -> float:
    """f64::round — round half AWAY from zero, exactly."""
    f = math.floor(x)
    d = x - f  # exact: f <= x < f+1 and Sterbenz / small-range cases
    if d > 0.5:
        return float(f + 1)
    if d < 0.5:
        return float(f)
    return float(f + 1) if x > 0.0 else float(f)


def pow2i(k: int) -> float:
    if k > 1023:
        return math.inf
    if k < -1074:
        return 0.0
    if k < -1022:
        return bits_to_f64(1 << (52 - (-1022 - k)))
    return bits_to_f64((k + 1023) << 52)


def exp_det(x: float) -> float:
    if math.isnan(x):
        return math.nan
    if x > 709.8:
        return math.inf
    if x < -745.0:
        return 0.0
    k = rust_round(x / LN2)
    r = x - k * LN2
    acc = 1.0
    n = 14.0
    while n >= 1.0:
        acc = 1.0 + acc * r / n
        n -= 1.0
    ki = int(k)
    if ki > 1023:
        return acc * pow2i(1023) * pow2i(ki - 1023)
    if ki < -1022:
        return acc * pow2i(-1022) * pow2i(ki + 1022)
    return acc * pow2i(ki)


def ln_det(x: float) -> float:
    if math.isnan(x) or x < 0.0:
        return math.nan
    if x == 0.0:
        return -math.inf
    if math.isinf(x):
        return math.inf
    sub_adj = 0.0
    if x < MIN_POSITIVE:
        x = x * pow2i(54)
        sub_adj = -54.0
    bits = f64_to_bits(x)
    e = ((bits >> 52) & 0x7FF) - 1023
    m = bits_to_f64((bits & 0x000F_FFFF_FFFF_FFFF) | (1023 << 52))
    if m > SQRT_2:
        m *= 0.5
        e += 1
    s = (m - 1.0) / (m + 1.0)
    s2 = s * s
    acc = 0.0
    k = 17.0
    while k >= 1.0:
        acc = acc * s2 + 1.0 / k
        k -= 2.0
    return 2.0 * s * acc + (float(e) + sub_adj) * LN2


def reduce_tau(x: float) -> float:
    return x - TAU * float(math.floor((x + PI) / TAU))


def cos_det(x: float) -> float:
    if not math.isfinite(x):
        return math.nan
    r = reduce_tau(x)
    r2 = r * r
    term = 1.0
    total = 1.0
    k = 1.0
    while k <= 12.0:
        term = -term * r2 / ((2.0 * k - 1.0) * (2.0 * k))
        total += term
        k += 1.0
    return total


# ---- workload/fleet_trace.rs samplers --------------------------------


def exponential_det(rng: Pcg64, lam: float) -> float:
    return -ln_det(max(rng.next_f64(), 1e-300)) / lam


def normal_det(rng: Pcg64) -> float:
    while True:
        u1 = rng.next_f64()
        if u1 > 1e-300:
            u2 = rng.next_f64()
            return math.sqrt(-2.0 * ln_det(u1)) * cos_det(2.0 * PI * u2)


def lognormal_det(rng: Pcg64, mu: float, sigma: float) -> float:
    return exp_det(mu + sigma * normal_det(rng))


def rust_clamp(x: float, lo: float, hi: float) -> float:
    if x < lo:
        return lo
    if x > hi:
        return hi
    return x


def draw_lengths_det(rng: Pcg64):
    # TraceParams::default() marginals (workload/trace.rs).
    prompt = rust_round(rust_clamp(lognormal_det(rng, 5.9, 0.95), 1.0, 4000.0))
    gen = rust_round(rust_clamp(lognormal_det(rng, 5.35, 0.55), 10.0, 700.0))
    return max(int(prompt), 1), max(int(gen), 1)


# ---- the golden scenario: FleetTraceParams::scenario(Burst, 4, 12, 600, 0)


SLOT_S = 1.0
REPLICAS = 4
PEAK_RPS = 12.0
MIN_RPS = 1.0  # 1.0f64.min(peak_rps)
DURATION_S = 600.0
SEED = 0
BURST_BOOST = 3.5
BURST_CORRELATION = 0.85
BURST_ON_S = 45.0
BURST_OFF_S = 150.0
SLOTS = max(int(math.ceil(DURATION_S / SLOT_S)), 1)


def markov_series(rng: Pcg64, slots: int, p_on: float, p_off: float, pi: float):
    s = rng.next_f64() < pi
    out = []
    for _ in range(slots):
        out.append(s)
        u = rng.next_f64()
        s = (u >= p_off) if s else (u < p_on)
    return out


def burst_states():
    n = SLOTS
    rng = Pcg64(SEED, 0xB425)
    p_on = min(SLOT_S / BURST_OFF_S, 1.0)
    p_off = min(SLOT_S / BURST_ON_S, 1.0)
    pi = p_on / (p_on + p_off)
    fleet = markov_series(rng, n, p_on, p_off, pi)
    c = math.sqrt(rust_clamp(BURST_CORRELATION, 0.0, 1.0))
    chans = []
    for _ in range(REPLICAS):
        idio = markov_series(rng, n, p_on, p_off, pi)
        chans.append([fleet[t] if rng.next_f64() < c else idio[t] for t in range(n)])
    return chans


def baseline_burst(t_norm: float) -> float:
    bump = exp_det(-((t_norm - 0.5) * (t_norm - 0.5)) / (2.0 * 0.18 * 0.18))
    return 0.45 + 0.25 * bump


def intensity_series():
    n = SLOTS
    wobble_rng = Pcg64(SEED, 0x0B1E)
    wobble = [wobble_rng.uniform_f64(0.85, 1.12) for _ in range(15)]
    base = []
    for t in range(n):
        mid_s = (float(t) + 0.5) * SLOT_S
        t_norm = rust_clamp(mid_s / DURATION_S, 0.0, 1.0)
        bin_i = min(int(t_norm * float(len(wobble))), len(wobble) - 1)
        v = baseline_burst(t_norm) * wobble[bin_i]
        base.append(v if v > 0.0 else 0.0)  # .max(0.0); v >= 0 here
    base_max = 0.0
    for v in base:
        base_max = v if v > base_max else base_max
    if base_max > 0.0:
        base = [v / base_max for v in base]
    bursts = burst_states()  # burst_boost > 1 for the Burst scenario
    out = []
    for t in range(n):
        v = base[t]
        ssum = 0.0
        for ch in bursts:
            ssum += BURST_BOOST if ch[t] else 1.0
        v *= ssum / float(len(bursts))
        # flash_boost == 1.0 and idle window disabled for Burst.
        out.append(v)
    return out


def fleet_rate_series():
    return [MIN_RPS + (PEAK_RPS - MIN_RPS) * v for v in intensity_series()]


def synth_fleet_trace():
    rate = fleet_rate_series()
    lambda_max = 0.0
    for v in rate:
        lambda_max = v if v > lambda_max else lambda_max
    assert lambda_max > 0.0
    rng = Pcg64(SEED, 0xF1EE)
    out = []
    t = 0.0
    rid = 0
    while True:
        t += exponential_det(rng, lambda_max)
        if t >= DURATION_S:
            break
        slot = min(int(t / SLOT_S), len(rate) - 1)
        if rng.next_f64() * lambda_max <= rate[slot]:
            prompt, gen = draw_lengths_det(rng)
            out.append((rid, t, prompt, gen, gen))
            rid += 1
    return out


# ---- the session golden: FleetTraceParams::scenario(Session, 4, 12, 600, 0)


S_TURNS_MEAN = 3.0
S_THINK_S = 20.0
S_PREFIX_TOKENS = 1024
S_PROMPT_MAX = 4000
MAX_TURNS = 16
STREAM_SESSION = 0x5E55


def session_rate_series():
    # Session envelope: baseline 0.40 + 0.60 * bump, wobbled and
    # normalized; no bursts (burst_boost == 1), no flash, no idle.
    n = SLOTS
    wobble_rng = Pcg64(SEED, 0x0B1E)
    wobble = [wobble_rng.uniform_f64(0.85, 1.12) for _ in range(15)]
    base = []
    for t in range(n):
        mid_s = (float(t) + 0.5) * SLOT_S
        t_norm = rust_clamp(mid_s / DURATION_S, 0.0, 1.0)
        bin_i = min(int(t_norm * float(len(wobble))), len(wobble) - 1)
        bump = exp_det(-((t_norm - 0.5) * (t_norm - 0.5)) / (2.0 * 0.18 * 0.18))
        v = (0.40 + 0.60 * bump) * wobble[bin_i]
        base.append(v if v > 0.0 else 0.0)
    base_max = 0.0
    for v in base:
        base_max = v if v > base_max else base_max
    if base_max > 0.0:
        base = [v / base_max for v in base]
    return [MIN_RPS + (PEAK_RPS - MIN_RPS) * v for v in base]


def synth_session_trace():
    """Port of `synth_session_trace`: thinned Poisson session starts at
    1/turns_mean of the envelope, per-session turn counts, history
    regrowth, exponential think gaps, then a stable (arrival, group)
    sort with dense re-idling."""
    rate = session_rate_series()
    lambda_max = 0.0
    for v in rate:
        lambda_max = v if v > lambda_max else lambda_max
    assert lambda_max > 0.0
    rng = Pcg64(SEED, STREAM_SESSION)
    out = []  # (arrival, prompt, gen, group, pfx)
    t = 0.0
    group = 0
    while True:
        t += exponential_det(rng, lambda_max / S_TURNS_MEAN)
        if t >= DURATION_S:
            break
        slot = min(int(t / SLOT_S), len(rate) - 1)
        if rng.next_f64() * lambda_max > rate[slot]:
            continue
        group += 1
        turns = 1 + min(
            int(rust_round(exponential_det(rng, 1.0 / (S_TURNS_MEAN - 1.0)))),
            MAX_TURNS - 1,
        )
        history = 0
        at = t
        for k in range(turns):
            user, gen = draw_lengths_det(rng)
            prompt = max(min(S_PREFIX_TOKENS + history + user, S_PROMPT_MAX), 1)
            out.append((at, prompt, gen, group, min(S_PREFIX_TOKENS, prompt)))
            history += user + gen
            if k + 1 < turns and S_THINK_S > 0.0:
                at += exponential_det(rng, 1.0 / S_THINK_S)
    out.sort(key=lambda r: (r[0], r[3]))  # stable, like Rust sort_by
    return out


# ---- jsonl.rs canonical writer ---------------------------------------


def sci_to_positional(s: str) -> str:
    mant, exp = s.split("e")
    neg = mant.startswith("-")
    if neg:
        mant = mant[1:]
    ip, _, fp = mant.partition(".")
    digits = ip + fp
    point = len(ip) + int(exp)
    if point <= 0:
        out = "0." + "0" * (-point) + digits
    elif point >= len(digits):
        out = digits + "0" * (point - len(digits))
    else:
        out = digits[:point] + "." + digits[point:]
    return ("-" + out) if neg else out


def fmt_num(x: float) -> str:
    # Json::Num writer: integral |x| < 1e15 prints as i64, everything
    # else through Rust f64 Display (shortest round-trip, positional).
    if x == math.floor(x) and abs(x) < 1e15:
        return str(int(x))
    s = repr(x)
    if "e" in s or "E" in s:
        s = sci_to_positional(s.lower())
    assert float(s) == x, f"formatter does not round-trip: {s!r}"
    return s


def golden_jsonl(reqs) -> str:
    # BTreeMap order: keys sorted lexicographically.
    header = (
        "{"
        + f'"duration_s":{fmt_num(DURATION_S)},'
        + '"kind":"fleet-trace",'
        + f'"min_rps":{fmt_num(MIN_RPS)},'
        + f'"peak_rps":{fmt_num(PEAK_RPS)},'
        + f'"replicas":{REPLICAS},'
        + f'"requests":{len(reqs)},'
        + '"scenario":"burst",'
        + f'"seed":"{SEED}",'
        + '"v":1'
        + "}"
    )
    lines = [header]
    for rid, arrival, prompt, gen, pred in reqs:
        lines.append(
            "{"
            + f'"arrival_s":{fmt_num(arrival)},'
            + f'"gen":{gen},'
            + f'"id":{rid},'
            + f'"pred":{pred},'
            + f'"prompt":{prompt}'
            + "}"
        )
    return "\n".join(lines) + "\n"


def session_jsonl(reqs) -> str:
    # Same canonical writer, session header; request lines gain the
    # "grp"/"pfx" keys (emitted only when nonzero — always, here),
    # slotted in BTreeMap (lexicographic) key order.
    header = (
        "{"
        + f'"duration_s":{fmt_num(DURATION_S)},'
        + '"kind":"fleet-trace",'
        + f'"min_rps":{fmt_num(MIN_RPS)},'
        + f'"peak_rps":{fmt_num(PEAK_RPS)},'
        + f'"replicas":{REPLICAS},'
        + f'"requests":{len(reqs)},'
        + '"scenario":"session",'
        + f'"seed":"{SEED}",'
        + '"v":1'
        + "}"
    )
    lines = [header]
    for rid, (arrival, prompt, gen, group, pfx) in enumerate(reqs):
        lines.append(
            "{"
            + f'"arrival_s":{fmt_num(arrival)},'
            + f'"gen":{gen},'
            + f'"grp":{group},'
            + f'"id":{rid},'
            + f'"pfx":{pfx},'
            + f'"pred":{gen},'
            + f'"prompt":{prompt}'
            + "}"
        )
    return "\n".join(lines) + "\n"


def fnv1a64(data: bytes) -> int:
    h = 0xCBF29CE484222325
    for b in data:
        h ^= b
        h = (h * 0x100000001B3) & M64
    return h


# ---- self-checks ------------------------------------------------------


def close(a: float, b: float, tol: float) -> bool:
    if b == 0.0:
        return abs(a) < tol
    return abs((a - b) / b) < tol or abs(a - b) < tol


def self_check():
    # FNV vectors pinned by the Rust unit tests.
    assert fnv1a64(b"") == 0xCBF29CE484222325
    assert fnv1a64(b"a") == 0xAF63DC4C8601EC8C
    # detmath vs the platform libm, at the Rust tests' tolerances.
    for i in range(-200, 201):
        x = float(i) * 0.173
        assert close(exp_det(x), math.exp(x), 1e-11), f"exp({x})"
    assert exp_det(0.0) == 1.0
    for i in range(1, 401):
        x = float(i) * 0.37
        assert close(ln_det(x), math.log(x), 1e-11), f"ln({x})"
    for i in range(1, 61):
        x = 2.0 ** (-i)
        assert close(ln_det(x), math.log(x), 1e-11), f"ln(2^-{i})"
    assert ln_det(1.0) == 0.0
    for i in range(-300, 301):
        x = float(i) * 0.217
        assert close(cos_det(x), math.cos(x), 1e-9), f"cos({x})"
    assert cos_det(0.0) == 1.0
    # PCG sanity: deterministic, uniform in [0, 1).
    a, b = Pcg64(42), Pcg64(42)
    for _ in range(100):
        assert a.next_u64() == b.next_u64()
    r = Pcg64(7)
    for _ in range(10_000):
        v = r.next_f64()
        assert 0.0 <= v < 1.0
    # Formatter: positional conversion of scientific spellings.
    assert sci_to_positional("9.23e-05") == "0.0000923"
    assert sci_to_positional("1.5e-07") == "0.00000015"
    assert fmt_num(600.0) == "600"
    assert fmt_num(0.5) == "0.5"


def golden_dir() -> str:
    return os.path.join(
        os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
        "rust",
        "tests",
        "golden",
    )


def main():
    self_check()
    reqs = synth_fleet_trace()
    # The Rust test suite pins these invariants for this exact config.
    assert len(reqs) > 500, f"suspicious request count {len(reqs)}"
    assert all(reqs[i][1] <= reqs[i + 1][1] for i in range(len(reqs) - 1))
    assert all(r[0] == i for i, r in enumerate(reqs))
    assert all(1 <= r[2] <= 4000 and 10 <= r[3] <= 700 for r in reqs)
    text = golden_jsonl(reqs)
    h = f"{fnv1a64(text.encode('utf-8')):016x}"
    print(f"requests: {len(reqs)}")
    print(f"fleet-trace golden hash: {h}")

    sreqs = synth_session_trace()
    # Mirror of `session_trace_carries_prefix_structure` in
    # tests/fleet_trace_determinism.rs.
    assert len(sreqs) > 200, f"suspicious session request count {len(sreqs)}"
    assert all(
        sreqs[i][0] <= sreqs[i + 1][0] for i in range(len(sreqs) - 1)
    ), "session trace must be arrival-sorted"
    assert all(r[3] >= 1 for r in sreqs), "every session request is grouped"
    assert all(0 < r[4] <= r[1] for r in sreqs), "pfx bounded by prompt"
    assert all(1 <= r[1] <= 4000 and 10 <= r[2] <= 700 for r in sreqs)
    from collections import Counter

    turns = Counter(r[3] for r in sreqs)
    assert any(n >= 2 for n in turns.values()), "no multi-turn session"
    stext = session_jsonl(sreqs)
    sh = f"{fnv1a64(stext.encode('utf-8')):016x}"
    print(f"session requests: {len(sreqs)} ({len(turns)} sessions)")
    print(f"session-trace golden hash: {sh}")

    if "--write" in sys.argv:
        for name, value in [
            ("fleet_trace_burst.hash", h),
            ("fleet_trace_session.hash", sh),
        ]:
            path = os.path.join(golden_dir(), name)
            with open(path, "w") as f:
                f.write(value + "\n")
            print(f"wrote {path}")


if __name__ == "__main__":
    main()
