"""AOT pipeline: lower the L2 model (with its L1 Pallas kernels) to HLO
text artifacts for the Rust PJRT runtime.

HLO *text* (not a serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` 0.1.6 crate) rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and
round-trips cleanly.  See /opt/xla-example/gen_hlo.py.

Outputs (under --out, default ../artifacts):
  decode_b{B}.hlo.txt    one decode iteration, batch bucket B
  prefill_b{B}.hlo.txt   prompt phase, batch bucket B
  weights.bin            flat f32 little-endian weight vector
  manifest.json          config + artifact/arg-shape inventory

Run once at build time (`make artifacts`); never on the request path.
"""

from __future__ import annotations

import argparse
import dataclasses
import hashlib
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .model import ModelConfig, decode_step, flatten_params, init_params, prefill

DEFAULT_BATCHES = (1, 2, 4, 8)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (the 0.5.1-safe route)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_decode(cfg: ModelConfig, batch: int) -> str:
    """HLO text for one decode iteration at batch bucket `batch`."""
    nw = cfg.num_params()
    cache = jax.ShapeDtypeStruct(
        (cfg.n_layers, batch, cfg.n_heads, cfg.max_seq, cfg.head_dim), jnp.float32
    )
    args = (
        jax.ShapeDtypeStruct((nw,), jnp.float32),
        cache,
        cache,
        jax.ShapeDtypeStruct((batch,), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    fn = lambda w, kc, vc, t, p: decode_step(cfg, w, kc, vc, t, p)
    return to_hlo_text(jax.jit(fn).lower(*args))


def lower_prefill(cfg: ModelConfig, batch: int) -> str:
    """HLO text for the prompt phase at batch bucket `batch`."""
    nw = cfg.num_params()
    args = (
        jax.ShapeDtypeStruct((nw,), jnp.float32),
        jax.ShapeDtypeStruct((batch, cfg.prompt_len), jnp.int32),
        jax.ShapeDtypeStruct((batch,), jnp.int32),
    )
    fn = lambda w, t, l: prefill(cfg, w, t, l)
    return to_hlo_text(jax.jit(fn).lower(*args))


def build_artifacts(
    cfg: ModelConfig,
    out_dir: str,
    batches=DEFAULT_BATCHES,
    seed: int = 0,
) -> dict:
    """Write all artifacts; returns the manifest dict."""
    os.makedirs(out_dir, exist_ok=True)
    weights = np.asarray(
        flatten_params(cfg, init_params(cfg, seed)), dtype=np.float32
    )
    wpath = os.path.join(out_dir, "weights.bin")
    weights.tofile(wpath)

    artifacts = {}
    for b in batches:
        for kind, lower in (("decode", lower_decode), ("prefill", lower_prefill)):
            name = f"{kind}_b{b}.hlo.txt"
            text = lower(cfg, b)
            with open(os.path.join(out_dir, name), "w") as f:
                f.write(text)
            artifacts[name] = {
                "kind": kind,
                "batch": b,
                "sha256": hashlib.sha256(text.encode()).hexdigest(),
            }
            print(f"wrote {name}: {len(text)} chars")

    manifest = {
        "model": "tiny-llama-sim",
        "config": dataclasses.asdict(cfg),
        "num_params": int(weights.size),
        "weights": {
            "file": "weights.bin",
            "dtype": "f32",
            "count": int(weights.size),
            "sha256": hashlib.sha256(weights.tobytes()).hexdigest(),
        },
        "batches": list(batches),
        "seed": seed,
        "artifacts": artifacts,
    }
    # Golden outputs for cross-language parity: the Rust runtime must
    # reproduce these greedy generations bit-exactly (argmax is robust
    # to sub-ulp float divergence).
    golden = golden_generations(cfg, seed)
    manifest["golden"] = golden

    mpath = os.path.join(out_dir, "manifest.json")
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest.json ({len(artifacts)} artifacts, "
          f"{weights.size} weights)")
    return manifest


def golden_generations(cfg: ModelConfig, seed: int, steps: int = 12) -> dict:
    """Greedy generations from fixed prompts (jax reference)."""
    from .model import greedy_generate

    flat_w = flatten_params(cfg, init_params(cfg, seed))
    prompts = [
        [1, 2, 3, 4, 5],
        [7, 11, 13],
    ]
    plen = cfg.prompt_len
    toks = np.zeros((len(prompts), plen), dtype=np.int32)
    lens = np.zeros((len(prompts),), dtype=np.int32)
    for i, p in enumerate(prompts):
        toks[i, : len(p)] = p
        lens[i] = len(p)
    out = greedy_generate(
        cfg, flat_w, jnp.asarray(toks), jnp.asarray(lens), steps
    )
    return {
        "prompts": prompts,
        "steps": steps,
        "tokens": np.asarray(out).tolist(),
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--batches",
        default=",".join(str(b) for b in DEFAULT_BATCHES),
        help="comma-separated batch buckets",
    )
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--vocab", type=int, default=ModelConfig.vocab)
    ap.add_argument("--d-model", type=int, default=ModelConfig.d_model)
    ap.add_argument("--n-heads", type=int, default=ModelConfig.n_heads)
    ap.add_argument("--n-layers", type=int, default=ModelConfig.n_layers)
    ap.add_argument("--d-ff", type=int, default=ModelConfig.d_ff)
    ap.add_argument("--max-seq", type=int, default=ModelConfig.max_seq)
    ap.add_argument("--prompt-len", type=int, default=ModelConfig.prompt_len)
    args = ap.parse_args()

    cfg = ModelConfig(
        vocab=args.vocab,
        d_model=args.d_model,
        n_heads=args.n_heads,
        n_layers=args.n_layers,
        d_ff=args.d_ff,
        max_seq=args.max_seq,
        prompt_len=args.prompt_len,
    )
    batches = tuple(int(b) for b in args.batches.split(","))
    build_artifacts(cfg, args.out, batches, args.seed)


if __name__ == "__main__":
    main()
