"""L1 Pallas kernel: single-token (decode-phase) flash attention.

This is the throttLL'eM serving hot-spot: each decode iteration reads the
whole KV cache of every request in the batch (memory-bound on A100 —
paper §III-B shows TBT grows linearly with allocated KV blocks).  On TPU
we re-think the CUDA formulation:

  * the KV-cache *page* becomes a VMEM tile: the grid is
    ``(batch, heads, kv_blocks)`` and ``BlockSpec`` streams
    ``[block_kv, head_dim]`` K/V tiles HBM -> VMEM, taking the role the
    CUDA threadblock's shared-memory staging played;
  * score/value contractions are MXU-shaped matmuls
    (``[1, d] x [d, block_kv]``) accumulated in f32;
  * a running (m, l, acc) online-softmax accumulator in VMEM scratch is
    carried across KV tiles, reproducing FlashAttention's streaming
    reduction without shared-memory cross-thread reductions;
  * per-row live lengths mask ragged batches (the inflight batcher mixes
    requests at different generation depths in one dense batch).

``interpret=True`` is mandatory on this CPU-only image (real TPU
lowering emits a Mosaic custom call the CPU PJRT plugin cannot run); the
kernel is structured exactly as it would be for a real TPU target.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Default KV tile length.  With head_dim 64 a (K, V) pair of tiles is
# 2 * 128 * 64 * 4 B = 64 KiB — far under the ~16 MiB VMEM budget, and a
# multiple of the 8x128 VREG tile.
DEFAULT_BLOCK_KV = 128

_NEG_INF = -1.0e30


def _decode_attention_kernel(
    q_ref,  # [head_dim]            (b, h) query row
    k_ref,  # [block_kv, head_dim]  K tile
    v_ref,  # [block_kv, head_dim]  V tile
    len_ref,  # [1]                 live length of row b
    o_ref,  # [head_dim]            output row
    m_ref,  # VMEM scratch [1]      running max
    l_ref,  # VMEM scratch [1]      running normalizer
    acc_ref,  # VMEM scratch [1, head_dim] running weighted V sum
    *,
    block_kv: int,
    scale: float,
):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)[None, :] * scale  # [1, d]
    k = k_ref[...].astype(jnp.float32)  # [bk, d]
    v = v_ref[...].astype(jnp.float32)  # [bk, d]
    live = len_ref[0]

    # Positions covered by this tile; mask the dead tail of the row.
    pos = j * block_kv + jax.lax.iota(jnp.int32, block_kv)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # [1, bk]
    s = jnp.where((pos < live)[None, :], s, _NEG_INF)

    # Online softmax update (FlashAttention streaming rule).
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(j == pl.num_programs(2) - 1)
    def _finish():
        # A fully-masked row (live == 0) never occurs: the engine only
        # schedules rows with at least the prompt in cache.  Guard anyway
        # so NaNs cannot leak into downstream layers.
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None])[0].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_kv",))
def decode_attention(
    q: jax.Array,  # [B, H, d]
    k: jax.Array,  # [B, H, L, d]
    v: jax.Array,  # [B, H, L, d]
    lengths: jax.Array,  # [B] int32, live KV length per row
    block_kv: int = DEFAULT_BLOCK_KV,
) -> jax.Array:  # [B, H, d]
    """Single-token attention of `q` against the first `lengths[b]` cache
    entries of each row, computed by the Pallas flash-decode kernel."""
    batch, heads, head_dim = q.shape
    seq_len = k.shape[2]
    if k.shape != (batch, heads, seq_len, head_dim):
        raise ValueError(f"bad k shape {k.shape}")
    if v.shape != k.shape:
        raise ValueError(f"bad v shape {v.shape}")
    block_kv = min(block_kv, seq_len)
    if seq_len % block_kv != 0:
        raise ValueError(f"seq_len {seq_len} not a multiple of block_kv {block_kv}")
    num_blocks = seq_len // block_kv
    scale = 1.0 / (head_dim**0.5)

    kernel = functools.partial(
        _decode_attention_kernel, block_kv=block_kv, scale=scale
    )
    return pl.pallas_call(
        kernel,
        grid=(batch, heads, num_blocks),
        in_specs=[
            pl.BlockSpec((None, None, head_dim), lambda b, h, j: (b, h, 0)),
            pl.BlockSpec(
                (None, None, block_kv, head_dim), lambda b, h, j: (b, h, j, 0)
            ),
            pl.BlockSpec(
                (None, None, block_kv, head_dim), lambda b, h, j: (b, h, j, 0)
            ),
            pl.BlockSpec((1,), lambda b, h, j: (b,)),
        ],
        out_specs=pl.BlockSpec((None, None, head_dim), lambda b, h, j: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, heads, head_dim), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, head_dim), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, lengths.astype(jnp.int32))
