"""Pure-jnp oracles for the Pallas kernels (the CORE correctness signal).

Every kernel in this package must agree with its oracle here to within
float tolerance; ``python/tests/test_kernel.py`` sweeps shapes, dtypes
and lengths with hypothesis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

_NEG_INF = -1.0e30


def decode_attention_ref(
    q: jax.Array,  # [B, H, d]
    k: jax.Array,  # [B, H, L, d]
    v: jax.Array,  # [B, H, L, d]
    lengths: jax.Array,  # [B] int32
) -> jax.Array:  # [B, H, d]
    """Masked single-token attention, materializing full score rows."""
    head_dim = q.shape[-1]
    seq_len = k.shape[2]
    scale = 1.0 / (head_dim**0.5)
    s = jnp.einsum(
        "bhd,bhld->bhl",
        q.astype(jnp.float32),
        k.astype(jnp.float32),
    ) * scale
    mask = jax.lax.iota(jnp.int32, seq_len)[None, None, :] < lengths[:, None, None]
    s = jnp.where(mask, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhl,bhld->bhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rmsnorm_ref(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Row-wise RMSNorm oracle."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * weight.astype(jnp.float32)).astype(x.dtype)


def causal_attention_ref(
    q: jax.Array,  # [B, H, P, d]
    k: jax.Array,  # [B, H, P, d]
    v: jax.Array,  # [B, H, P, d]
    lengths: jax.Array,  # [B] int32 — live prompt length per row
) -> jax.Array:  # [B, H, P, d]
    """Causal full attention used by the (compute-bound) prefill phase."""
    head_dim = q.shape[-1]
    prompt = q.shape[2]
    scale = 1.0 / (head_dim**0.5)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32),
        k.astype(jnp.float32),
    ) * scale
    pos = jax.lax.iota(jnp.int32, prompt)
    causal = pos[None, :] <= pos[:, None]  # [q, k]
    live = pos[None, None, None, :] < lengths[:, None, None, None]
    s = jnp.where(causal[None, None, :, :] & live, s, _NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
