"""L1 Pallas kernel: fused RMSNorm (normalize + elementwise scale).

Small companion kernel to the decode-attention kernel: every decode
iteration runs 2 * n_layers + 1 RMSNorms over [B, d] activations.  The
fused kernel computes the row RMS and the scaled output in one VMEM
pass (one HBM read + one HBM write per row) instead of the four
HBM-roundtrip ops (square, mean, rsqrt-mul, weight-mul) of the naive
lowering.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rmsnorm_kernel(x_ref, w_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)  # [d]
    w = w_ref[...].astype(jnp.float32)  # [d]
    ms = jnp.mean(x * x)
    o_ref[...] = (x * jax.lax.rsqrt(ms + eps) * w).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("eps",))
def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Row-wise RMSNorm of ``x`` ([B, d]) scaled by ``weight`` ([d])."""
    batch, dim = x.shape
    if weight.shape != (dim,):
        raise ValueError(f"bad weight shape {weight.shape}, want ({dim},)")
    return pl.pallas_call(
        functools.partial(_rmsnorm_kernel, eps=eps),
        grid=(batch,),
        in_specs=[
            pl.BlockSpec((None, dim), lambda b: (b, 0)),
            pl.BlockSpec((dim,), lambda b: (0,)),
        ],
        out_specs=pl.BlockSpec((None, dim), lambda b: (b, 0)),
        out_shape=jax.ShapeDtypeStruct((batch, dim), x.dtype),
        interpret=True,
    )(x, weight)
