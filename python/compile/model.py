"""L2: the served model — a small Llama-style decoder-only transformer.

The paper evaluates Llama-family models on A100s; weights and the
hardware are unavailable here, so the *runnable* serving path uses
"tiny-llama-sim": the same architecture (RMSNorm, multi-head attention
over a KV cache, SwiGLU MLP, tied output head) at a size the CPU PJRT
client executes in milliseconds.  The decode step calls the L1 Pallas
flash-decode kernel (`kernels.attention`) and fused RMSNorm kernel, so
the AOT HLO that the Rust runtime loads contains the lowered kernels.

Everything in this file is build-time Python: `aot.py` lowers
`decode_step` / `prefill` once per batch bucket to HLO text; the Rust
coordinator executes those artifacts via PJRT with Python out of the
request path.

Weights are passed as ONE flat f32 vector (runtime input), so the Rust
side loads `artifacts/weights.bin` and feeds it as the first argument —
mirroring real engines that keep weights resident on device.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Dict, List, Tuple

import jax
import jax.numpy as jnp

from .kernels.attention import decode_attention
from .kernels.ref import causal_attention_ref
from .kernels.rmsnorm import rmsnorm


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture of the served model (defaults: tiny-llama-sim)."""

    vocab: int = 256
    d_model: int = 64
    n_heads: int = 4
    n_layers: int = 2
    d_ff: int = 128
    max_seq: int = 256
    prompt_len: int = 32  # static prefill bucket
    rope_theta: float = 10000.0

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Deterministic (name, shape) list defining the flat layout."""
        shapes: List[Tuple[str, Tuple[int, ...]]] = [
            ("embed", (self.vocab, self.d_model)),
        ]
        for i in range(self.n_layers):
            p = f"layer{i}."
            shapes += [
                (p + "attn_norm", (self.d_model,)),
                (p + "wq", (self.d_model, self.d_model)),
                (p + "wk", (self.d_model, self.d_model)),
                (p + "wv", (self.d_model, self.d_model)),
                (p + "wo", (self.d_model, self.d_model)),
                (p + "mlp_norm", (self.d_model,)),
                (p + "w_gate", (self.d_model, self.d_ff)),
                (p + "w_up", (self.d_model, self.d_ff)),
                (p + "w_down", (self.d_ff, self.d_model)),
            ]
        shapes.append(("final_norm", (self.d_model,)))
        return shapes

    def num_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_shapes())


def init_params(cfg: ModelConfig, seed: int = 0) -> Dict[str, jax.Array]:
    """Deterministic scaled-normal initialization."""
    params: Dict[str, jax.Array] = {}
    key = jax.random.PRNGKey(seed)
    for name, shape in cfg.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith("norm"):
            params[name] = jnp.ones(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            params[name] = (
                jax.random.normal(sub, shape, jnp.float32) / jnp.sqrt(fan_in)
            )
    return params


def flatten_params(cfg: ModelConfig, params: Dict[str, jax.Array]) -> jax.Array:
    """Concatenate params into the flat vector layout of `param_shapes`."""
    return jnp.concatenate(
        [params[name].reshape(-1) for name, _ in cfg.param_shapes()]
    )


def _slices(cfg: ModelConfig) -> Dict[str, Tuple[int, Tuple[int, ...]]]:
    out, off = {}, 0
    for name, shape in cfg.param_shapes():
        n = 1
        for s in shape:
            n *= s
        out[name] = (off, shape)
        off += n
    return out


def _param(flat: jax.Array, layout, name: str) -> jax.Array:
    off, shape = layout[name]
    n = 1
    for s in shape:
        n *= s
    return jax.lax.dynamic_slice(flat, (off,), (n,)).reshape(shape)


def _rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary position embedding. x: [B, H, d], positions: [B]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[:, None] * freqs[None, :]  # [B, half]
    cos, sin = jnp.cos(ang)[:, None, :], jnp.sin(ang)[:, None, :]  # [B,1,half]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _rope_seq(x: jax.Array, theta: float) -> jax.Array:
    """RoPE over a full sequence. x: [B, H, P, d]."""
    d = x.shape[-1]
    half = d // 2
    pos = jnp.arange(x.shape[2], dtype=jnp.float32)
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = pos[:, None] * freqs[None, :]  # [P, half]
    cos, sin = jnp.cos(ang)[None, None], jnp.sin(ang)[None, None]
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def decode_step(
    cfg: ModelConfig,
    flat_w: jax.Array,  # [num_params] f32
    k_cache: jax.Array,  # [n_layers, B, H, max_seq, head_dim]
    v_cache: jax.Array,  # like k_cache
    tokens: jax.Array,  # [B] int32 — token generated last iteration
    positions: jax.Array,  # [B] int32 — cache slot this token writes to
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """One autoregressive iteration for the whole batch.

    Returns (logits [B, vocab], new_k_cache, new_v_cache).  Row `b`
    attends over cache positions [0, positions[b]] after writing its
    current K/V at slot positions[b].
    """
    layout = _slices(cfg)
    h, dh = cfg.n_heads, cfg.head_dim
    batch = tokens.shape[0]

    embed = _param(flat_w, layout, "embed")
    x = embed[tokens]  # [B, d_model]

    new_k, new_v = k_cache, v_cache
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        xn = rmsnorm(x, _param(flat_w, layout, p + "attn_norm"))
        q = (xn @ _param(flat_w, layout, p + "wq")).reshape(batch, h, dh)
        k = (xn @ _param(flat_w, layout, p + "wk")).reshape(batch, h, dh)
        v = (xn @ _param(flat_w, layout, p + "wv")).reshape(batch, h, dh)
        q = _rope(q, positions, cfg.rope_theta)
        k = _rope(k, positions, cfg.rope_theta)

        # Scatter this token's K/V into its cache slot (per row).
        def write(cache, val):
            # cache: [B, H, L, dh], val: [B, H, dh]
            def one(c, x, pos):
                return jax.lax.dynamic_update_slice(c, x[:, None, :], (0, pos, 0))

            return jax.vmap(one)(cache, val, positions)

        lk = write(new_k[i], k)
        lv = write(new_v[i], v)
        new_k = new_k.at[i].set(lk)
        new_v = new_v.at[i].set(lv)

        # L1 Pallas flash-decode kernel over the live cache prefix.
        attn = decode_attention(q, lk, lv, positions + 1)  # [B, H, dh]
        x = x + attn.reshape(batch, -1) @ _param(flat_w, layout, p + "wo")

        xn = rmsnorm(x, _param(flat_w, layout, p + "mlp_norm"))
        gate = jax.nn.silu(xn @ _param(flat_w, layout, p + "w_gate"))
        up = xn @ _param(flat_w, layout, p + "w_up")
        x = x + (gate * up) @ _param(flat_w, layout, p + "w_down")

    x = rmsnorm(x, _param(flat_w, layout, "final_norm"))
    logits = x @ embed.T  # tied output head
    return logits, new_k, new_v


def prefill(
    cfg: ModelConfig,
    flat_w: jax.Array,  # [num_params]
    tokens: jax.Array,  # [B, P] int32, right-padded
    lengths: jax.Array,  # [B] int32 — live prompt length per row
) -> Tuple[jax.Array, jax.Array, jax.Array]:
    """Prompt phase: process the (padded) prompts, build the KV cache.

    Returns (logits of the last live token [B, vocab], k_cache, v_cache)
    with caches of shape [n_layers, B, H, max_seq, head_dim], populated
    in [0, lengths[b]).  Prefill is compute-bound (paper §II) and uses a
    dense causal attention; the decode hot loop is what the Pallas
    kernel accelerates.
    """
    layout = _slices(cfg)
    h, dh = cfg.n_heads, cfg.head_dim
    batch, prompt = tokens.shape

    embed = _param(flat_w, layout, "embed")
    x = embed[tokens]  # [B, P, d]

    k_cache = jnp.zeros((cfg.n_layers, batch, h, cfg.max_seq, dh), jnp.float32)
    v_cache = jnp.zeros_like(k_cache)

    for i in range(cfg.n_layers):
        p = f"layer{i}."
        w = _param(flat_w, layout, p + "attn_norm")
        xn = _rmsnorm_seq(x, w)
        q = (xn @ _param(flat_w, layout, p + "wq")).reshape(batch, prompt, h, dh)
        k = (xn @ _param(flat_w, layout, p + "wk")).reshape(batch, prompt, h, dh)
        v = (xn @ _param(flat_w, layout, p + "wv")).reshape(batch, prompt, h, dh)
        q = q.transpose(0, 2, 1, 3)  # [B, H, P, dh]
        k = k.transpose(0, 2, 1, 3)
        v = v.transpose(0, 2, 1, 3)
        q = _rope_seq(q, cfg.rope_theta)
        k = _rope_seq(k, cfg.rope_theta)

        k_cache = k_cache.at[i, :, :, :prompt, :].set(k)
        v_cache = v_cache.at[i, :, :, :prompt, :].set(v)

        attn = causal_attention_ref(q, k, v, lengths)  # [B, H, P, dh]
        attn = attn.transpose(0, 2, 1, 3).reshape(batch, prompt, -1)
        x = x + attn @ _param(flat_w, layout, p + "wo")

        xn = _rmsnorm_seq(x, _param(flat_w, layout, p + "mlp_norm"))
        gate = jax.nn.silu(xn @ _param(flat_w, layout, p + "w_gate"))
        up = xn @ _param(flat_w, layout, p + "w_up")
        x = x + (gate * up) @ _param(flat_w, layout, p + "w_down")

    x = _rmsnorm_seq(x, _param(flat_w, layout, "final_norm"))
    # Logits of each row's last live token.
    last = jnp.clip(lengths - 1, 0, prompt - 1)
    x_last = jnp.take_along_axis(x, last[:, None, None], axis=1)[:, 0, :]
    logits = x_last @ embed.T
    return logits, k_cache, v_cache


def _rmsnorm_seq(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    """RMSNorm over [B, P, d] (prefill path; plain jnp — XLA fuses it)."""
    ms = jnp.mean(x * x, axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(ms + eps) * w


@functools.partial(jax.jit, static_argnames=("cfg", "steps"))
def greedy_generate(
    cfg: ModelConfig,
    flat_w: jax.Array,
    prompt_tokens: jax.Array,  # [B, P]
    lengths: jax.Array,  # [B]
    steps: int,
) -> jax.Array:
    """Reference greedy decoding loop (tests + parity with Rust runtime)."""
    logits, kc, vc = prefill(cfg, flat_w, prompt_tokens, lengths)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    out = [tok]
    pos = lengths.astype(jnp.int32)
    for _ in range(steps - 1):
        logits, kc, vc = decode_step(cfg, flat_w, kc, vc, tok, pos)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        out.append(tok)
        pos = pos + 1
    return jnp.stack(out, axis=1)  # [B, steps]
