"""AOT pipeline tests: HLO text emission + manifest integrity.

Uses a miniature config so lowering stays fast; the shipping config is
exercised by `make artifacts` + the Rust runtime integration tests.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import build_artifacts, lower_decode, lower_prefill, to_hlo_text
from compile.model import ModelConfig

MINI = ModelConfig(vocab=32, d_model=16, n_heads=2, n_layers=1, d_ff=32,
                   max_seq=32, prompt_len=8)


def test_lower_decode_is_parseable_hlo_text():
    text = lower_decode(MINI, batch=2)
    assert text.startswith("HloModule")
    assert "ROOT" in text
    # tuple return of (logits, k_cache, v_cache)
    assert "f32[2,32]" in text  # logits [B, vocab]


def test_lower_prefill_is_parseable_hlo_text():
    text = lower_prefill(MINI, batch=1)
    assert text.startswith("HloModule")
    assert "f32[1,32]" in text  # logits


def test_hlo_has_no_64bit_proto_serialization():
    # guard: we ship text, never .serialize() output
    text = lower_decode(MINI, batch=1)
    assert isinstance(text, str) and len(text) > 100


def test_build_artifacts_manifest(tmp_path):
    out = str(tmp_path / "artifacts")
    manifest = build_artifacts(MINI, out, batches=(1, 2), seed=0)
    with open(os.path.join(out, "manifest.json")) as f:
        on_disk = json.load(f)
    assert on_disk == manifest
    assert set(manifest["artifacts"]) == {
        "decode_b1.hlo.txt", "prefill_b1.hlo.txt",
        "decode_b2.hlo.txt", "prefill_b2.hlo.txt",
    }
    assert manifest["config"]["vocab"] == 32
    weights = np.fromfile(os.path.join(out, "weights.bin"), dtype=np.float32)
    assert weights.size == manifest["num_params"] == MINI.num_params()
    assert np.all(np.isfinite(weights))


def test_artifacts_deterministic(tmp_path):
    a = build_artifacts(MINI, str(tmp_path / "a"), batches=(1,), seed=0)
    b = build_artifacts(MINI, str(tmp_path / "b"), batches=(1,), seed=0)
    assert a["weights"]["sha256"] == b["weights"]["sha256"]
    assert (
        a["artifacts"]["decode_b1.hlo.txt"]["sha256"]
        == b["artifacts"]["decode_b1.hlo.txt"]["sha256"]
    )


def test_hlo_text_round_trips_through_parser(tmp_path):
    """The emitted text must parse back into an HloModule — the same
    parser path the Rust runtime uses (`HloModuleProto::from_text_file`).
    Numeric parity of the compiled artifact against the traced function
    is covered by the Rust integration test `runtime_matches_jax`."""
    from jax._src.lib import xla_client as xc

    text = lower_decode(MINI, batch=2)
    module = xc._xla.hlo_module_from_text(text)
    proto = module.as_serialized_hlo_module_proto()
    assert len(proto) > 100
    # Entry computation has 5 params: weights, k_cache, v_cache, tokens,
    # positions — the ABI the Rust runtime relies on.
    assert text.count("parameter(") >= 5
