"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes, dtypes, lengths and block sizes; explicit
cases pin the shipping configuration (tiny-llama-sim) and edge cases.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.attention import decode_attention
from compile.kernels.ref import decode_attention_ref, rmsnorm_ref
from compile.kernels.rmsnorm import rmsnorm

jax.config.update("jax_enable_x64", False)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 else dict(
        atol=2e-5, rtol=2e-5
    )


def _mk_qkv(seed, batch, heads, seq, dim, dtype):
    k0, k1, k2 = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(k0, (batch, heads, dim), dtype)
    k = jax.random.normal(k1, (batch, heads, seq, dim), dtype)
    v = jax.random.normal(k2, (batch, heads, seq, dim), dtype)
    return q, k, v


# ---------------------------------------------------------------- attention

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "batch,heads,seq,dim,block",
    [
        (1, 1, 8, 4, 8),     # minimal
        (4, 4, 256, 16, 128),  # tiny-llama-sim shipping shape
        (8, 4, 256, 16, 64),   # max batch bucket, smaller tile
        (2, 8, 64, 32, 16),    # many tiles
        (3, 2, 96, 8, 32),     # non-pow2 batch
    ],
)
def test_decode_attention_matches_ref(batch, heads, seq, dim, block, dtype):
    q, k, v = _mk_qkv(0, batch, heads, seq, dim, dtype)
    lengths = jnp.arange(1, batch + 1, dtype=jnp.int32) * (seq // (batch + 1)) + 1
    lengths = jnp.clip(lengths, 1, seq)
    got = decode_attention(q, k, v, lengths, block_kv=block)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_decode_attention_full_length_rows():
    q, k, v = _mk_qkv(1, 4, 2, 32, 8, jnp.float32)
    lengths = jnp.full((4,), 32, jnp.int32)
    got = decode_attention(q, k, v, lengths, block_kv=8)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_decode_attention_single_live_token():
    # With exactly one live position, attention must return that V row.
    q, k, v = _mk_qkv(2, 2, 3, 16, 8, jnp.float32)
    lengths = jnp.ones((2,), jnp.int32)
    got = decode_attention(q, k, v, lengths, block_kv=8)
    np.testing.assert_allclose(got, v[:, :, 0, :], atol=1e-5, rtol=1e-5)


def test_decode_attention_ignores_dead_tail():
    # Values beyond `lengths` must not affect the output.
    q, k, v = _mk_qkv(3, 2, 2, 64, 8, jnp.float32)
    lengths = jnp.array([10, 40], jnp.int32)
    base = decode_attention(q, k, v, lengths, block_kv=16)
    k2 = k.at[:, :, 50:, :].set(1e6)
    v2 = v.at[:, :, 50:, :].set(-1e6)
    poisoned = decode_attention(q, k2, v2, lengths, block_kv=16)
    np.testing.assert_allclose(base, poisoned, atol=1e-6)


def test_decode_attention_rejects_bad_shapes():
    q, k, v = _mk_qkv(4, 2, 2, 16, 8, jnp.float32)
    with pytest.raises(ValueError):
        decode_attention(q, k[:, :, :15, :], v[:, :, :15, :],
                         jnp.ones(2, jnp.int32), block_kv=8)
    with pytest.raises(ValueError):
        decode_attention(q, k, v[:1], jnp.ones(2, jnp.int32))


@settings(max_examples=20, deadline=None)
@given(
    batch=st.integers(1, 6),
    heads=st.integers(1, 4),
    log_seq=st.integers(3, 7),
    dim=st.sampled_from([4, 8, 16, 32]),
    seed=st.integers(0, 2**16),
    data=st.data(),
)
def test_decode_attention_hypothesis(batch, heads, log_seq, dim, seed, data):
    seq = 2**log_seq
    block = data.draw(
        st.sampled_from([b for b in (8, 16, 32, 64, 128) if seq % b == 0])
    )
    lengths = jnp.array(
        data.draw(
            st.lists(st.integers(1, seq), min_size=batch, max_size=batch)
        ),
        jnp.int32,
    )
    q, k, v = _mk_qkv(seed, batch, heads, seq, dim, jnp.float32)
    got = decode_attention(q, k, v, lengths, block_kv=block)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), data=st.data())
def test_decode_attention_hypothesis_bf16(seed, data):
    batch = data.draw(st.integers(1, 4))
    lengths = jnp.array(
        data.draw(st.lists(st.integers(1, 64), min_size=batch, max_size=batch)),
        jnp.int32,
    )
    q, k, v = _mk_qkv(seed, batch, 2, 64, 16, jnp.bfloat16)
    got = decode_attention(q, k, v, lengths, block_kv=32)
    want = decode_attention_ref(q, k, v, lengths)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        atol=3e-2, rtol=3e-2,
    )


# ----------------------------------------------------------------- rmsnorm

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("batch,dim", [(1, 8), (4, 64), (8, 64), (3, 128)])
def test_rmsnorm_matches_ref(batch, dim, dtype):
    k0, k1 = jax.random.split(jax.random.PRNGKey(7))
    x = jax.random.normal(k0, (batch, dim), dtype)
    w = jax.random.normal(k1, (dim,), dtype)
    got = rmsnorm(x, w)
    want = rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), **_tol(dtype)
    )


def test_rmsnorm_unit_weight_normalizes():
    x = jnp.full((2, 16), 3.0)
    out = rmsnorm(x, jnp.ones(16))
    np.testing.assert_allclose(out, jnp.ones((2, 16)), atol=1e-5)


def test_rmsnorm_rejects_bad_weight():
    with pytest.raises(ValueError):
        rmsnorm(jnp.ones((2, 16)), jnp.ones(8))


@settings(max_examples=25, deadline=None)
@given(
    batch=st.integers(1, 8),
    dim=st.sampled_from([4, 16, 64, 128, 256]),
    scale=st.floats(0.01, 100.0),
    seed=st.integers(0, 2**16),
)
def test_rmsnorm_hypothesis(batch, dim, scale, seed):
    k0, k1 = jax.random.split(jax.random.PRNGKey(seed))
    x = jax.random.normal(k0, (batch, dim)) * scale
    w = jax.random.normal(k1, (dim,))
    np.testing.assert_allclose(
        rmsnorm(x, w), rmsnorm_ref(x, w), atol=1e-4, rtol=1e-4
    )
