"""L2 correctness: tiny-llama-sim model semantics.

Checks shapes, prefill/decode consistency (the property the serving
path relies on: prefill-then-decode must equal a longer prefill),
masking of padded rows, and determinism.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.model import (
    ModelConfig,
    decode_step,
    flatten_params,
    greedy_generate,
    init_params,
    prefill,
)

CFG = ModelConfig(vocab=64, d_model=32, n_heads=2, n_layers=2, d_ff=64,
                  max_seq=64, prompt_len=16)


@pytest.fixture(scope="module")
def flat_w():
    return flatten_params(CFG, init_params(CFG, seed=0))


def _prompt(batch, length, seed=0):
    key = jax.random.PRNGKey(seed)
    toks = jax.random.randint(key, (batch, CFG.prompt_len), 0, CFG.vocab,
                              dtype=jnp.int32)
    lengths = jnp.full((batch,), length, jnp.int32)
    return toks, lengths


def test_param_layout_count(flat_w):
    assert flat_w.shape == (CFG.num_params(),)
    # embed + final_norm + 9 tensors per layer
    assert len(CFG.param_shapes()) == 2 + 9 * CFG.n_layers


def test_prefill_shapes(flat_w):
    toks, lens = _prompt(4, 10)
    logits, kc, vc = prefill(CFG, flat_w, toks, lens)
    assert logits.shape == (4, CFG.vocab)
    assert kc.shape == (CFG.n_layers, 4, CFG.n_heads, CFG.max_seq, CFG.head_dim)
    assert vc.shape == kc.shape
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_decode_step_shapes(flat_w):
    toks, lens = _prompt(2, 8)
    _, kc, vc = prefill(CFG, flat_w, toks, lens)
    logits, kc2, vc2 = decode_step(
        CFG, flat_w, kc, vc, jnp.array([1, 2], jnp.int32), lens
    )
    assert logits.shape == (2, CFG.vocab)
    assert kc2.shape == kc.shape


def test_decode_writes_only_its_slot(flat_w):
    toks, lens = _prompt(2, 8)
    _, kc, vc = prefill(CFG, flat_w, toks, lens)
    _, kc2, _ = decode_step(CFG, flat_w, kc, vc,
                            jnp.array([1, 2], jnp.int32), lens)
    # Positions below `lens` and above `lens` are untouched.
    np.testing.assert_allclose(kc2[:, :, :, :8, :], kc[:, :, :, :8, :])
    np.testing.assert_allclose(kc2[:, :, :, 9:, :], kc[:, :, :, 9:, :])


def test_prefill_decode_consistency(flat_w):
    """prefill(P) + decode(token) must equal prefill(P+1) logits."""
    batch, plen = 2, 8
    toks, lens = _prompt(batch, plen, seed=3)
    logits_p, kc, vc = prefill(CFG, flat_w, toks, lens)
    nxt = jnp.argmax(logits_p, axis=-1).astype(jnp.int32)

    # Path A: one decode step after prefill.
    logits_a, _, _ = decode_step(CFG, flat_w, kc, vc, nxt, lens)

    # Path B: prefill over the extended prompt.
    toks_ext = toks.at[jnp.arange(batch), plen].set(nxt)
    logits_b, _, _ = prefill(CFG, flat_w, toks_ext, lens + 1)

    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), atol=2e-4, rtol=2e-4
    )


def test_padded_tail_does_not_change_logits(flat_w):
    toks, lens = _prompt(2, 6, seed=5)
    logits_a, _, _ = prefill(CFG, flat_w, toks, lens)
    # Poison the padding region (>= lens); logits must be unchanged.
    poisoned = toks.at[:, 6:].set(CFG.vocab - 1)
    logits_b, _, _ = prefill(CFG, flat_w, poisoned, lens)
    np.testing.assert_allclose(
        np.asarray(logits_a), np.asarray(logits_b), atol=1e-5
    )


def test_rows_are_independent(flat_w):
    """Batching must not couple rows: row 0 of a b=2 batch equals b=1."""
    toks, lens = _prompt(2, 8, seed=7)
    logits2, _, _ = prefill(CFG, flat_w, toks, lens)
    logits1, _, _ = prefill(CFG, flat_w, toks[:1], lens[:1])
    np.testing.assert_allclose(
        np.asarray(logits2[0]), np.asarray(logits1[0]), atol=2e-4, rtol=2e-4
    )


def test_greedy_generate_deterministic(flat_w):
    toks, lens = _prompt(2, 8, seed=9)
    a = greedy_generate(CFG, flat_w, toks, lens, steps=6)
    b = greedy_generate(CFG, flat_w, toks, lens, steps=6)
    assert a.shape == (2, 6)
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert int(jnp.max(a)) < CFG.vocab and int(jnp.min(a)) >= 0


def test_different_weights_give_different_logits(flat_w):
    toks, lens = _prompt(1, 8, seed=11)
    other = flatten_params(CFG, init_params(CFG, seed=1))
    la, _, _ = prefill(CFG, flat_w, toks, lens)
    lb, _, _ = prefill(CFG, other, toks, lens)
    assert not np.allclose(np.asarray(la), np.asarray(lb))
