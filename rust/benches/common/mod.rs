//! Shared helpers for the per-figure bench binaries.

#![allow(dead_code)]

use throttllem::config::EngineSpec;
use throttllem::engine::request::Request;
use throttllem::engine::sim::EngineSim;

/// Measure a full batch lifetime at a fixed frequency: admit `batch`
/// identical (prompt, gen) requests at t=0 and run to completion.
/// Returns (tps, e2e_s, mean_tbt_s, mean_power_w, tokens_per_joule).
pub fn batch_lifetime(
    spec: &EngineSpec,
    batch: u32,
    prompt: u32,
    gen: u32,
    freq_mhz: u32,
) -> (f64, f64, f64, f64, f64) {
    let mut e = EngineSim::new(spec.clone(), freq_mhz);
    for i in 0..batch {
        e.admit(
            Request {
                id: i as u64,
                prompt_tokens: prompt,
                gen_tokens: gen,
                predicted_gen: gen,
                arrival_s: 0.0,
                prefix_group: 0,
                shared_prefix_tokens: 0,
            },
            0.0,
            false,
        )
        .expect("batch must fit");
    }
    let mut t = 0.0;
    let mut tokens = 0u64;
    let mut tbt_sum = 0.0;
    let mut decode_iters = 0u64;
    while !e.is_idle() {
        let r = e.run_iteration(t);
        t = r.start_s + r.duration_s;
        tokens += r.tokens as u64;
        if r.prefills == 0 {
            tbt_sum += r.duration_s;
            decode_iters += 1;
        }
    }
    let energy = e.total_energy_j();
    let tps = tokens as f64 / t;
    let tbt = tbt_sum / decode_iters.max(1) as f64;
    let power = energy / t;
    (tps, t, tbt, power, tokens as f64 / energy)
}

/// Render a float cell.
pub fn c(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Saturation profiling (paper §V-A / Table II methodology): ramp the
/// request rate on the Triton baseline at max frequency until long tail
/// latencies appear; returns (max sustainable RPS, p99 E2E at that
/// load) **on this substrate**. The paper right-scales its trace to the
/// evaluated engine's measured max load and defines the E2E SLO as the
/// p99 at that load — benches do the same with these derived values.
pub fn saturation_profile(
    spec: &EngineSpec,
    model: &throttllem::coordinator::PerfModel,
    secs: f64,
    seed: u64,
) -> (f64, f64) {
    use throttllem::config::ServingConfig;
    use throttllem::coordinator::{serve_trace, Policy};
    use throttllem::workload::trace::{synth_trace, TraceParams};
    use throttllem::workload::LengthPredictor;

    let fracs = [0.2, 0.35, 0.5, 0.65, 0.8, 1.0, 1.2];
    let mut p99s = Vec::new();
    for &f in &fracs {
        let rps = f * spec.max_load_rps;
        let mut reqs = synth_trace(&TraceParams::short(secs, rps, seed));
        LengthPredictor::oracle().apply(&mut reqs, 1024);
        let cfg = ServingConfig::triton(spec.clone());
        let out = serve_trace(&cfg, Policy::triton(), model, &reqs);
        p99s.push(out.stats.e2e.p99());
    }
    let min_p99 = p99s.iter().cloned().fold(f64::INFINITY, f64::min);
    // Max load = highest ramp point whose p99 stays within 2x of the
    // unloaded tail (before the "long tail latencies" knee).
    let idx = p99s
        .iter()
        .rposition(|&p| p.is_finite() && p <= 2.0 * min_p99)
        .unwrap_or(0);
    (fracs[idx] * spec.max_load_rps, p99s[idx])
}

/// Precharacterize a scale set on this substrate (§IV-D: autoscaling
/// decisions use "precharacterized performance profiles"): returns the
/// specs with `max_load_rps` replaced by the measured sustainable load
/// (with a small headroom factor), plus the deployment E2E SLO — the
/// loosest per-engine p99-at-max-load, so every engine in the set can
/// honor it at its rated point (the paper's per-engine SLOs are
/// mutually consistent this way; on our substrate the KV-starved TP1
/// dominates).
pub fn derived_scale_set(
    set: &[EngineSpec],
    model: &throttllem::coordinator::PerfModel,
    secs: f64,
    seed: u64,
) -> (Vec<EngineSpec>, f64) {
    let mut out = Vec::new();
    let mut slo: f64 = 0.0;
    for spec in set {
        let (rps, p99) = saturation_profile(spec, model, secs, seed);
        eprintln!(
            "   profile {}: max {:.2} RPS (rated {:.2}), p99 {:.1} s",
            spec.name, rps, spec.max_load_rps, p99
        );
        let mut s = spec.clone();
        s.max_load_rps = rps * 0.85; // headroom for spikes during spawn
        out.push(s);
        slo = slo.max(p99);
    }
    (out, slo)
}
