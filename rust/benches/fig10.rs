//! Fig. 10 reproduction: the §V-D2 ablation matrix on the RPS-rescaled
//! trace (Llama2-13B TP1/TP2/TP4 scale set) — E2E latency, energy and
//! energy efficiency for Triton, Triton+autoscaling, throttling-only
//! and full throttLL'eM at multiple predictor error levels.
//!
//! Paper anchors: autoscaling-only -20.8% energy, throttling-only
//! -30.6%; full system -43.8% (0% err) / -41.7% (30% err); TPJ 0.69
//! (Triton) -> 0.87 / 0.99 -> 1.19-1.23 (1.71x-1.78x).

mod common;

use common::derived_scale_set;
use throttllem::bench_util::{print_table, section};
use throttllem::config::models::llama2_13b;
use throttllem::config::ServingConfig;
use throttllem::coordinator::{serve_trace, PerfModel, Policy};
use throttllem::workload::trace::{synth_trace_rps_range, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() {
    let secs: f64 = std::env::var("THROTTLLEM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(900.0);
    let seed = 0u64;
    let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];
    let _ = &set;

    eprintln!("training shared model over the scale set...");
    let model = PerfModel::train(&set, 100, seed);
    // §V-D2: RPS rescaled from a tenth of TP4's max load up to TP4's
    // max load — derived on THIS substrate by saturation profiling, as
    // the paper derived its 7.5 RPS on its testbed.
    let (set, slo_e2e) = derived_scale_set(&set, &model, 240.0, 11);
    let tp4 = set[2].clone();
    let tp4_max = tp4.max_load_rps / 0.85;
    eprintln!("derived: TP4 max {tp4_max:.2} RPS, deployment SLO {slo_e2e:.1} s");
    let base = synth_trace_rps_range(
        &TraceParams::short(secs, 8.25, seed),
        0.1 * tp4_max,
        tp4_max,
    );
    eprintln!("{} requests over {secs:.0} s", base.len());

    struct Row {
        name: String,
        e2e_p99: f64,
        energy_kj: f64,
        tpj: f64,
        switches: u32,
    }
    let mut rows: Vec<Row> = vec![];
    let mut run = |name: &str, policy: Policy, err: f64| {
        let mut cfg = if policy.autoscaling {
            ServingConfig::autoscaled(set.clone())
        } else if policy.throttling {
            ServingConfig::throttllem(tp4.clone())
        } else {
            ServingConfig::triton(tp4.clone())
        };
        cfg.slo.e2e_p99 = slo_e2e;
        cfg.predictor_p95_error = err;
        let mut reqs = base.clone();
        let pred = if err == 0.0 {
            LengthPredictor::oracle()
        } else {
            LengthPredictor::noisy(err, seed)
        };
        pred.apply(&mut reqs, cfg.max_tokens);
        eprintln!("running {name}...");
        let out = serve_trace(&cfg, policy, &model, &reqs);
        rows.push(Row {
            name: name.into(),
            e2e_p99: out.stats.e2e.p99(),
            energy_kj: out.stats.total_energy_j / 1e3,
            tpj: out.stats.tokens_per_joule(),
            switches: out.engine_switches,
        });
    };

    run("triton (TP4)", Policy::triton(), 0.0);
    run("triton+autoscale", Policy::triton_autoscale(), 0.0);
    run("throttle-only (TP4)", Policy::throttle_only(), 0.0);
    run("throttllem @0%", Policy::throttllem(), 0.0);
    run("throttllem @15%", Policy::throttllem(), 0.15);
    run("throttllem @30%", Policy::throttllem(), 0.30);

    let triton_energy = rows[0].energy_kj;
    let triton_tpj = rows[0].tpj;
    section("Fig. 10 — E2E / energy / efficiency across implementations");
    let table: Vec<Vec<String>> = rows
        .iter()
        .map(|r| {
            vec![
                r.name.clone(),
                format!("{:.1}", r.e2e_p99),
                format!("{:.0}", r.energy_kj),
                format!("{:+.1}%", (1.0 - r.energy_kj / triton_energy) * 100.0),
                format!("{:.3}", r.tpj),
                format!("{:.2}x", r.tpj / triton_tpj),
                format!("{}", r.switches),
            ]
        })
        .collect();
    print_table(
        &[
            "implementation", "E2Ep99[s]", "energy[kJ]", "saved", "TPJ", "TPJx",
            "switches",
        ],
        &table,
    );
    println!("\nE2E SLO (derived TP4 profile): {slo_e2e:.1} s");
    println!("paper anchors: AS-only -20.8%, throttle-only -30.6%, full -43.8%/-41.7%;");
    println!("TPJ 0.69 -> 0.87 / 0.99 -> 1.19-1.23 (1.71x-1.78x).");
}
