//! Fig. 11 reproduction: runtime analysis of full throttLL'eM on the
//! RPS-rescaled trace — RPS, engine states (with shadow instancing),
//! applied frequencies, power draw (hatched = serving, solid = shadow)
//! and p99 E2E per time window, with transient SLO violations marked.

mod common;

use common::derived_scale_set;
use throttllem::bench_util::section;
use throttllem::config::models::llama2_13b;
use throttllem::config::ServingConfig;
use throttllem::coordinator::{serve_trace, PerfModel, Policy};
use throttllem::metrics::Series;
use throttllem::workload::trace::{rps_bins, synth_trace_rps_range, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() {
    let secs: f64 = std::env::var("THROTTLLEM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(1200.0);
    let seed = 3u64;
    let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];

    let model = PerfModel::train(&set, 100, 0);
    // Precharacterize the scale set on this substrate (§IV-D).
    let (set, slo) = derived_scale_set(&set, &model, 240.0, 11);
    let tp4_max = set[2].max_load_rps / 0.85;
    eprintln!("derived: TP4 max {tp4_max:.2} RPS, deployment SLO {slo:.1} s");
    let mut reqs = synth_trace_rps_range(
        &TraceParams::short(secs, 8.25, seed),
        0.1 * tp4_max,
        tp4_max,
    );
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    let mut cfg = ServingConfig::autoscaled(set.clone());
    cfg.slo.e2e_p99 = slo;
    eprintln!("running full throttLL'eM on {} requests...", reqs.len());
    let out = serve_trace(&cfg, Policy::throttllem(), &model, &reqs);

    section("Fig. 11 — runtime timeline (60 s windows)");
    println!(
        "{:>6} {:>6} {:>7} {:>8} {:>9} {:>9} {:>9}  flags",
        "t[s]", "RPS", "engine", "f[MHz]", "P[W]", "Pshad[W]", "p99E2E[s]"
    );
    let win = 60.0;
    let rps = rps_bins(&reqs, secs, win);
    let wall = out.stats.wall_s;
    let n = (wall / win).ceil() as usize;
    for b in 0..n {
        let lo = b as f64 * win;
        let hi = lo + win;
        let pts: Vec<_> = out.timeline.iter().filter(|p| p.t >= lo && p.t < hi).collect();
        if pts.is_empty() {
            continue;
        }
        let mean =
            |f: &dyn Fn(&&throttllem::coordinator::server::TimelinePoint) -> f64| {
                pts.iter().map(|p| f(&p)).sum::<f64>() / pts.len() as f64
            };
        // p99 E2E of requests finishing in this window.
        let mut e2e = Series::new();
        for o in &out.outcomes {
            let fin = o.arrival_s + o.e2e_s;
            if fin >= lo && fin < hi {
                e2e.push(o.e2e_s);
            }
        }
        let p99 = e2e.p99();
        let shadow = mean(&|p| p.shadow_power_w);
        let tps: Vec<u32> = pts.iter().map(|p| p.engine_tp).collect();
        let switching = tps.windows(2).any(|w| w[0] != w[1]);
        let mut flags = String::new();
        if !p99.is_nan() && p99 > slo {
            flags.push_str("*VIOLATION* "); // red star in the paper
        }
        if shadow > 0.0 {
            flags.push_str("shadowing ");
        }
        if switching {
            flags.push_str("switch ");
        }
        println!(
            "{:>6.0} {:>6.2} {:>7.0} {:>8.0} {:>9.0} {:>9.0} {:>9.2}  {}",
            lo,
            rps.get(b).copied().unwrap_or(0.0),
            mean(&|p| p.engine_tp as f64),
            mean(&|p| p.freq_mhz as f64),
            mean(&|p| p.power_w),
            shadow,
            p99,
            flags
        );
    }
    section("whole-trace summary");
    println!("p99 E2E over full trace : {:.1} s (SLO {:.1})", out.stats.e2e.p99(), slo);
    println!("engine switches         : {}", out.engine_switches);
    println!("shadow energy           : {:.1} kJ", out.shadow_energy_j / 1e3);
    println!("mean frequency          : {:.0} MHz", out.stats.freq.mean());
    println!(
        "takeaway: autoscaling = coarse right-sizing; throttling = fine-grained\n\
         adjustment on top (paper §V-E)."
    );
}
