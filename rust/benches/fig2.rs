//! Fig. 2 reproduction: impact of batch size and GPU frequency on
//! throughput, E2E latency, TBT, power and energy efficiency
//! (Llama2-13B TP2; identical queries, 1 prompt / 1024 gen tokens).

mod common;

use common::{batch_lifetime, c};
use throttllem::bench_util::{print_table, section};
use throttllem::config::models::llama2_13b;

fn main() {
    let spec = llama2_13b(2);
    let batches = [1u32, 2, 4, 8, 16, 32];
    let freqs = [210u32, 510, 810, 1050, 1260, 1410];

    let mut tps_rows = vec![];
    let mut e2e_rows = vec![];
    let mut tbt_rows = vec![];
    let mut pow_rows = vec![];
    let mut tpj_rows = vec![];
    for &b in &batches {
        let mut tps_r = vec![format!("B={b}")];
        let mut e2e_r = tps_r.clone();
        let mut tbt_r = tps_r.clone();
        let mut pow_r = tps_r.clone();
        let mut tpj_r = tps_r.clone();
        for &f in &freqs {
            let (tps, e2e, tbt, pow, tpj) = batch_lifetime(&spec, b, 1, 1024, f);
            tps_r.push(c(tps, 0));
            e2e_r.push(c(e2e, 1));
            tbt_r.push(c(tbt * 1e3, 1));
            pow_r.push(c(pow, 0));
            tpj_r.push(c(tpj, 3));
        }
        tps_rows.push(tps_r);
        e2e_rows.push(e2e_r);
        tbt_rows.push(tbt_r);
        pow_rows.push(pow_r);
        tpj_rows.push(tpj_r);
    }
    let headers: Vec<String> = std::iter::once("batch".to_string())
        .chain(freqs.iter().map(|f| format!("{f}MHz")))
        .collect();
    let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();

    section("Fig. 2a — throughput (tokens/s)");
    print_table(&h, &tps_rows);
    section("Fig. 2b — E2E latency (s, 1024 tokens)");
    print_table(&h, &e2e_rows);
    section("Fig. 2c — TBT (ms)");
    print_table(&h, &tbt_rows);
    section("Fig. 2d — power (W)");
    print_table(&h, &pow_rows);
    section("Fig. 2e — energy efficiency (tokens/J)");
    print_table(&h, &tpj_rows);

    // Paper anchor points (§III-A1).
    let (_, e2e_hi, tbt_hi, pow_hi, tpj_hi) = batch_lifetime(&spec, 32, 1, 1024, 1410);
    let (_, e2e_sw, tbt_sw, _, tpj_sw) = batch_lifetime(&spec, 32, 1, 1024, 1050);
    let (_, _, _, pow_lo, _) = batch_lifetime(&spec, 32, 1, 1024, 210);
    section("anchors vs paper");
    println!(
        "TPJ boost @1050 MHz, B=32 : {:+.1}%  (paper: +37.4%)",
        (tpj_sw / tpj_hi - 1.0) * 100.0
    );
    println!(
        "E2E impact @1050 MHz      : {:+.2}%  (paper: +8.26%)",
        (e2e_sw / e2e_hi - 1.0) * 100.0
    );
    println!(
        "TBT impact @1050 MHz      : {:+.2}%  (paper: +5.41%)",
        (tbt_sw / tbt_hi - 1.0) * 100.0
    );
    println!(
        "power span 210->1410 MHz  : {:.2}x  (paper: >2x)",
        pow_hi / pow_lo
    );
}
