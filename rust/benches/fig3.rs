//! Fig. 3 reproduction: implications of KV-cache usage on throughput,
//! TBT and power, plus the 200 s constant-batch correlation timeline
//! (Pearson(KV,TBT) ≈ 0.92, Pearson(KV,IPS) ≈ −0.92).

mod common;

use throttllem::bench_util::{print_table, section};
use throttllem::config::models::llama2_13b;
use throttllem::engine::request::Request;
use throttllem::engine::sim::EngineSim;
use throttllem::gpusim::dvfs::FREQ_MAX_MHZ;
use throttllem::gpusim::latency::{decode_latency_s, GpuState};
use throttllem::gpusim::power::power_w;
use throttllem::sim::dist::pearson;
use throttllem::sim::Pcg64;

fn main() {
    let spec = llama2_13b(2);

    // -- 3a/3b: IPS and TBT vs allocated KV blocks per batch size ----
    section("Fig. 3a/3b — IPS and TBT vs KV blocks, per batch size");
    let kv_grid: Vec<u32> = (0..=8).map(|i| i * spec.kv_blocks / 8).collect();
    let headers: Vec<String> = std::iter::once("batch".into())
        .chain(kv_grid.iter().map(|k| format!("KV={k}")))
        .collect();
    let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut ips_rows = vec![];
    let mut tbt_rows = vec![];
    for b in [4u32, 8, 16, 32] {
        let mut ips_r = vec![format!("B={b}")];
        let mut tbt_r = ips_r.clone();
        for &kv in &kv_grid {
            let st = GpuState {
                batch: b,
                kv_blocks: kv,
                freq_mhz: FREQ_MAX_MHZ,
            };
            let d = decode_latency_s(&spec, &st);
            ips_r.push(format!("{:.1}", 1.0 / d));
            tbt_r.push(format!("{:.2}", d * 1e3));
        }
        ips_rows.push(ips_r);
        tbt_rows.push(tbt_r);
    }
    println!("(IPS, iterations/s)");
    print_table(&h, &ips_rows);
    println!("(TBT, ms)");
    print_table(&h, &tbt_rows);

    // -- 3c: power vs KV blocks for different frequencies, B=32 -------
    section("Fig. 3c — power (W) vs KV blocks at batch 32");
    let mut rows = vec![];
    for f in [510u32, 810, 1110, 1410] {
        let mut r = vec![format!("{f}MHz")];
        for &kv in &kv_grid {
            r.push(format!("{:.0}", power_w(&spec, 32, kv, f)));
        }
        rows.push(r);
    }
    let headers2: Vec<String> = std::iter::once("freq".into())
        .chain(kv_grid.iter().map(|k| format!("KV={k}")))
        .collect();
    print_table(
        &headers2.iter().map(|s| s.as_str()).collect::<Vec<_>>(),
        &rows,
    );

    // -- 3d: 200 s constant-batch=32 timeline + Pearson ---------------
    section("Fig. 3d — 200 s constant-batch timeline correlations");
    let mut rng = Pcg64::new(7);
    let mut e = EngineSim::new(spec.clone(), FREQ_MAX_MHZ);
    let mut next_id = 0u64;
    let mut admit = |e: &mut EngineSim, rng: &mut Pcg64, now: f64| {
        let gen = rng.uniform_u64(64, 640) as u32;
        let req = Request {
            id: next_id,
            prompt_tokens: rng.uniform_u64(16, 256) as u32,
            gen_tokens: gen,
            predicted_gen: gen,
            arrival_s: now,
            prefix_group: 0,
            shared_prefix_tokens: 0,
        };
        next_id += 1;
        e.admit(req, now, false).ok()
    };
    for _ in 0..32 {
        admit(&mut e, &mut rng, 0.0);
    }
    let mut t = 0.0;
    let (mut kvs, mut tbts, mut ipss) = (vec![], vec![], vec![]);
    while t < 200.0 {
        // Maintain constant batch: replace completions immediately.
        while e.batch() < 32 {
            if admit(&mut e, &mut rng, t).is_none() {
                break;
            }
        }
        let r = e.run_iteration(t);
        t = r.start_s + r.duration_s;
        if r.prefills == 0 {
            kvs.push(r.kv_blocks as f64);
            tbts.push(r.duration_s * 1e3);
            ipss.push(1.0 / r.duration_s);
        }
    }
    let p_tbt = pearson(&kvs, &tbts);
    let p_ips = pearson(&kvs, &ipss);
    println!("samples                : {}", kvs.len());
    println!("Pearson(KV, TBT)       : {p_tbt:+.3}   (paper: +0.92)");
    println!("Pearson(KV, IPS)       : {p_ips:+.3}   (paper: -0.92)");
    println!(
        "KV range visited       : {:.0} .. {:.0} blocks",
        kvs.iter().cloned().fold(f64::INFINITY, f64::min),
        kvs.iter().cloned().fold(0.0, f64::max)
    );
}
