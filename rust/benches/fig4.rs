//! Fig. 4 reproduction: LLM partitioning (DDP / PP / TP at parallelism
//! 2 and 4) — throughput and energy efficiency across batch sizes.
//!
//! Paper anchors: TP outperforms DDP/PP by 1.54x/2.74x (n=2) and
//! 1.79x/6.26x (n=4) at the max batch all configurations support;
//! TP2 is up to ~9.66% more energy-efficient than TP4 near TP2's max
//! batch.

mod common;

use common::{batch_lifetime, c};
use throttllem::bench_util::{print_table, section};
use throttllem::config::models::llama2_13b_partitioned;
use throttllem::config::PartitionKind::{DataParallel, Pipeline, Tensor};
use throttllem::gpusim::dvfs::FREQ_MAX_MHZ;

fn main() {
    let configs = [
        ("ddp2", llama2_13b_partitioned(DataParallel, 2)),
        ("pp2", llama2_13b_partitioned(Pipeline, 2)),
        ("tp2", llama2_13b_partitioned(Tensor, 2)),
        ("ddp4", llama2_13b_partitioned(DataParallel, 4)),
        ("pp4", llama2_13b_partitioned(Pipeline, 4)),
        ("tp4", llama2_13b_partitioned(Tensor, 4)),
    ];
    let batches = [1u32, 2, 4, 8, 16, 32, 64];

    let headers: Vec<String> = std::iter::once("config".into())
        .chain(batches.iter().map(|b| format!("B={b}")))
        .collect();
    let h: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();

    let mut tps_rows = vec![];
    let mut tpj_rows = vec![];
    let mut results = std::collections::HashMap::new();
    for (name, spec) in &configs {
        let mut tps_r = vec![name.to_string()];
        let mut tpj_r = tps_r.clone();
        for &b in &batches {
            if b > spec.max_batch {
                tps_r.push("-".into());
                tpj_r.push("-".into());
                continue;
            }
            let (tps, _, _, _, tpj) = batch_lifetime(spec, b, 64, 512, FREQ_MAX_MHZ);
            results.insert((name.to_string(), b), (tps, tpj));
            tps_r.push(c(tps, 0));
            tpj_r.push(c(tpj, 3));
        }
        tps_rows.push(tps_r);
        tpj_rows.push(tpj_r);
    }
    section("Fig. 4a — throughput (tokens/s) by partitioning");
    print_table(&h, &tps_rows);
    section("Fig. 4b — energy efficiency (tokens/J) by partitioning");
    print_table(&h, &tpj_rows);

    section("anchors vs paper");
    // Max batch supported by ALL n=2 configs is PP2/DDP2's 16; for n=4
    // it is 32.
    let ratio = |a: &str, b: &str, batch: u32| {
        let ta = results[&(a.to_string(), batch)].0;
        let tb = results[&(b.to_string(), batch)].0;
        ta / tb
    };
    println!(
        "TP2/DDP2 @B=16 : {:.2}x  (paper: 1.54x)",
        ratio("tp2", "ddp2", 16)
    );
    println!(
        "TP2/PP2  @B=16 : {:.2}x  (paper: 2.74x)",
        ratio("tp2", "pp2", 16)
    );
    println!(
        "TP4/DDP4 @B=32 : {:.2}x  (paper: 1.79x)",
        ratio("tp4", "ddp4", 32)
    );
    println!(
        "TP4/PP4  @B=32 : {:.2}x  (paper: 6.26x)",
        ratio("tp4", "pp4", 32)
    );
    let tpj2 = results[&("tp2".to_string(), 32)].1;
    let tpj4 = results[&("tp4".to_string(), 32)].1;
    println!(
        "TP2 vs TP4 TPJ @B=32 : {:+.2}%  (paper: up to +9.66%)",
        (tpj2 / tpj4 - 1.0) * 100.0
    );
}
