//! Fig. 5 reproduction: analysis of the (synthetic) Azure LLM
//! inference trace — prompt/generated token distributions and the
//! arrival histogram with per-bin min/max RPS.

use throttllem::bench_util::{print_table, section};
use throttllem::sim::dist::Histogram;
use throttllem::workload::trace::{rps_bins, synth_trace, TraceParams};

fn ascii_hist(h: &Histogram, label: &str) {
    let max = h.counts.iter().copied().max().unwrap_or(1).max(1);
    println!("  {label}");
    for (i, (&count, center)) in h.counts.iter().zip(h.centers()).enumerate() {
        let bar = "#".repeat((count * 48 / max) as usize);
        println!("  {i:>2} [{center:>6.0}] {count:>6} {bar}");
    }
}

fn main() {
    let p = TraceParams::default();
    let reqs = synth_trace(&p);
    println!(
        "trace: {} requests over {:.0} min (peak {:.2} RPS target)",
        reqs.len(),
        p.duration_s / 60.0,
        p.peak_rps
    );

    section("Fig. 5a (top) — prompt token distribution");
    let mut hp = Histogram::new(0.0, 4000.0, 16);
    for r in &reqs {
        hp.add(r.prompt_tokens as f64);
    }
    ascii_hist(&hp, "prompt tokens (16 bins, 0..4000)");

    section("Fig. 5a (bottom) — generated token distribution");
    let mut hg = Histogram::new(0.0, 700.0, 14);
    for r in &reqs {
        hg.add(r.gen_tokens as f64);
    }
    ascii_hist(&hg, "generated tokens (14 bins, 0..700)");

    section("Fig. 5b — request histogram + min/max RPS per 4-min bin");
    let bins = rps_bins(&reqs, p.duration_s, 240.0);
    // Per-bin min/max of 10-second sub-bins.
    let fine = rps_bins(&reqs, p.duration_s, 10.0);
    let mut rows = vec![];
    for (i, &rps) in bins.iter().enumerate() {
        let lo = i * 24;
        let hi = ((i + 1) * 24).min(fine.len());
        let sub = &fine[lo..hi];
        let min = sub.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = sub.iter().cloned().fold(0.0, f64::max);
        rows.push(vec![
            format!("{}", i),
            format!("{:.0}", rps * 240.0),
            format!("{rps:.2}"),
            format!("{min:.1}"),
            format!("{max:.1}"),
        ]);
    }
    print_table(&["bin", "requests", "meanRPS", "minRPS", "maxRPS"], &rows);

    let max_rps = bins.iter().cloned().fold(0.0, f64::max);
    let min_rps = bins.iter().cloned().fold(f64::INFINITY, f64::min);
    println!("\npaper anchors: peak ~8.25 RPS (ours {max_rps:.2}), continuous (min bin {min_rps:.2} > 0),");
    println!("prompts <= 4000 tokens, generations 10..700 with mass in 100..400.");
}
