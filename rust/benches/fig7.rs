//! Fig. 7 reproduction: KV-cache / batch-size projection evaluation on
//! micro-traces — batch projection error, KV projection error, and
//! per-iteration timing drift of the T_R estimates.
//!
//! Paper anchors: batch error 0.19%, KV error 2.26%, drift 0.43 ms/iter.
//! NOTE (documented in EXPERIMENTS.md): our engine substrate follows
//! Eq. (1)-(2) deterministically, so batch/KV projection errors are
//! near-zero by construction (the paper's residuals come from
//! real-Triton scheduling noise); the ML-driven drift is the
//! non-trivial error channel here.

use throttllem::bench_util::{print_table, section};
use throttllem::config::models::llama2_13b;
use throttllem::coordinator::projection::project;
use throttllem::coordinator::scoreboard::{Entry, Scoreboard};
use throttllem::coordinator::PerfModel;
use throttllem::engine::request::Request;
use throttllem::engine::sim::EngineSim;
use throttllem::sim::Pcg64;

fn main() {
    let spec = llama2_13b(2);
    let model = PerfModel::train(&[spec.clone()], 120, 0);
    section("Fig. 7 — projection mechanism evaluation (micro-traces)");

    let mut rows = vec![];
    let (mut all_batch_err, mut all_kv_err, mut all_drift) = (vec![], vec![], vec![]);
    for (trace_id, (freq, batch)) in [
        (1410u32, 8u32),
        (1410, 24),
        (1050, 16),
        (810, 32),
        (510, 8),
    ]
    .iter()
    .enumerate()
    {
        let mut rng = Pcg64::new(trace_id as u64 + 1);
        let mut engine = EngineSim::new(spec.clone(), *freq);
        let mut sb = Scoreboard::new();
        // Spawn all queries simultaneously (paper protocol).
        for id in 0..*batch {
            let prompt = rng.uniform_u64(16, 512) as u32;
            let gen = rng.uniform_u64(32, 512) as u32;
            engine
                .admit(
                    Request {
                        id: id as u64,
                        prompt_tokens: prompt,
                        gen_tokens: gen,
                        predicted_gen: gen, // oracle
                        arrival_s: 0.0,
                        prefix_group: 0,
                        shared_prefix_tokens: 0,
                    },
                    0.0,
                    false,
                )
                .unwrap();
            sb.insert(Entry {
                id: id as u64,
                scheduled_iter: 0,
                prompt_tokens: prompt,
                predicted_gen: gen,
                deadline_s: f64::INFINITY,
                lost: false,
                kv_discount_blocks: 0,
            });
        }
        // Projection + predicted arrival times at the chosen frequency.
        let proj = project(&sb, 0, spec.block_tokens);
        let t = model.throughput_vector(&spec, &proj, *freq);
        let t_r = PerfModel::remaining_time_vector(&t);

        // Run and log actuals per iteration.  The first iteration
        // carries the fused prefills of the whole batch (seconds); the
        // paper's T_R models decode pacing, so timing drift is measured
        // from the post-prefill origin.
        let mut now = 0.0;
        let (mut b_err, mut kv_err, mut drift) = (vec![], vec![], vec![]);
        let mut j = 0usize;
        let mut origin: Option<(f64, f64)> = None; // (now0, t_r0)
        while !engine.is_idle() && j < proj.horizon() {
            let r = engine.run_iteration(now);
            now = r.start_s + r.duration_s;
            // Iteration r.iter_index ran; projection index for the
            // NEXT state is r.iter_index (0-based into vectors at k+1).
            let idx = r.iter_index as usize;
            if idx >= proj.horizon() {
                break;
            }
            // Compare projected vs actual state AFTER this iteration.
            let actual_batch = engine.batch() as f64;
            let actual_kv = engine.kv_blocks_used() as f64;
            if actual_batch > 0.0 {
                b_err.push(
                    (proj.batch[idx] as f64 - actual_batch).abs()
                        / actual_batch.max(1.0)
                        * 100.0,
                );
                kv_err.push(
                    (proj.kv_blocks[idx] as f64 - actual_kv).abs()
                        / actual_kv.max(1.0)
                        * 100.0,
                );
            }
            match origin {
                None => origin = Some((now, t_r[idx])),
                Some((now0, tr0)) => {
                    let predicted = t_r[idx] - tr0;
                    let actual = now - now0;
                    drift.push(((predicted - actual).abs() / (idx + 1) as f64) * 1e3);
                }
            }
            j += 1;
        }
        let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
        rows.push(vec![
            format!("trace{}", trace_id + 1),
            format!("{freq}"),
            format!("{batch}"),
            format!("{:.3}", mean(&b_err)),
            format!("{:.3}", mean(&kv_err)),
            format!("{:.3}", mean(&drift)),
        ]);
        all_batch_err.extend(b_err);
        all_kv_err.extend(kv_err);
        all_drift.extend(drift);
    }
    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len().max(1) as f64;
    print_table(
        &["microtrace", "freq", "batch", "Berr%", "KVerr%", "drift ms/iter"],
        &rows,
    );
    println!(
        "\noverall: batch err {:.3}% (paper 0.19%), KV err {:.3}% (paper 2.26%), drift {:.3} ms/iter (paper 0.43)",
        mean(&all_batch_err),
        mean(&all_kv_err),
        mean(&all_drift)
    );
}
