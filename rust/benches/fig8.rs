//! Fig. 8 reproduction: end-to-end comparison of Triton vs throttLL'eM
//! at 0% / 15% / 30% predictor error across engines — E2E latency
//! distributions (a), TBT distributions (b), power distributions and
//! energy efficiency (c).
//!
//! Paper anchors (§V-D1): p99 E2E SLO met for all engines except
//! llama2-13b-TP1; TBT SLO met everywhere; +36.3% TPJ avg with oracle
//! predictions (30.0% at 30% error); up to +44.3% TPJ on 13B-TP2;
//! energy -24.7% avg / -30.7% max.

mod common;

use common::saturation_profile;
use throttllem::bench_util::{print_table, section};
use throttllem::config::models::{llama2_13b, llama3_8b};
use throttllem::config::{EngineSpec, ServingConfig};
use throttllem::coordinator::{serve_trace, PerfModel, Policy};
use throttllem::metrics::ServingStats;
use throttllem::workload::trace::{synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn engines() -> Vec<EngineSpec> {
    vec![llama3_8b(1), llama2_13b(1), llama2_13b(2), llama2_13b(4)]
}

#[allow(clippy::too_many_arguments)]
fn run(
    engine: &EngineSpec,
    model: &PerfModel,
    base: &[throttllem::engine::request::Request],
    policy: Policy,
    err: f64,
    seed: u64,
    slo_e2e: f64,
) -> ServingStats {
    let mut cfg = if policy.throttling {
        ServingConfig::throttllem(engine.clone())
    } else {
        ServingConfig::triton(engine.clone())
    };
    cfg.slo.e2e_p99 = slo_e2e;
    cfg.predictor_p95_error = err;
    let mut reqs = base.to_vec();
    let pred = if err == 0.0 {
        LengthPredictor::oracle()
    } else {
        LengthPredictor::noisy(err, seed)
    };
    pred.apply(&mut reqs, cfg.max_tokens);
    serve_trace(&cfg, policy, model, &reqs).stats
}

fn main() {
    let secs: f64 = std::env::var("THROTTLLEM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(600.0);
    let seed = 0u64;

    let mut e2e_rows = vec![];
    let mut tbt_rows = vec![];
    let mut pow_rows = vec![];
    let (mut tpj_gains_oracle, mut tpj_gains_30, mut energy_red) = (vec![], vec![], vec![]);

    for engine in engines() {
        eprintln!("== {} ==", engine.name);
        let model = PerfModel::train(&[engine.clone()], 100, seed);
        // §V-A methodology on THIS substrate: right-scale the trace to
        // the engine's measured max load; E2E SLO = p99 at that load.
        let (max_rps, slo_e2e) =
            saturation_profile(&engine, &model, (secs * 0.4).max(180.0), 11);
        eprintln!("   derived: max load {max_rps:.2} RPS, E2E SLO {slo_e2e:.1} s");
        let base = synth_trace(&TraceParams::short(secs, max_rps, seed));

        let triton = run(&engine, &model, &base, Policy::triton(), 0.0, seed, slo_e2e);
        let ours: Vec<(f64, ServingStats)> = [0.0, 0.15, 0.30]
            .iter()
            .map(|&e| {
                (
                    e,
                    run(&engine, &model, &base, Policy::throttle_only(), e, seed, slo_e2e),
                )
            })
            .collect();

        // Fig. 8a: p99 E2E per approach.
        let mut row = vec![engine.name.clone(), format!("{:.1}", slo_e2e)];
        row.push(format!("{:.1}", triton.e2e.p99()));
        for (_, s) in &ours {
            row.push(format!("{:.1}", s.e2e.p99()));
        }
        e2e_rows.push(row);

        // Fig. 8b: average TBT (ms) per approach.
        let mut row = vec![engine.name.clone()];
        row.push(format!("{:.1}", triton.tbt.mean() * 1e3));
        for (_, s) in &ours {
            row.push(format!("{:.1}", s.tbt.mean() * 1e3));
        }
        tbt_rows.push(row);

        // Fig. 8c: mean power + TPJ per approach.
        let mut row = vec![engine.name.clone()];
        row.push(format!(
            "{:.0}/{:.3}",
            triton.power.mean(),
            triton.tokens_per_joule()
        ));
        for (_, s) in &ours {
            row.push(format!(
                "{:.0}/{:.3}",
                s.power.mean(),
                s.tokens_per_joule()
            ));
        }
        pow_rows.push(row);

        tpj_gains_oracle
            .push(ours[0].1.tokens_per_joule() / triton.tokens_per_joule() - 1.0);
        tpj_gains_30.push(ours[2].1.tokens_per_joule() / triton.tokens_per_joule() - 1.0);
        energy_red.push(1.0 - ours[0].1.total_energy_j / triton.total_energy_j);
    }

    let hdr = ["engine", "SLO[s]", "triton", "ours@0%", "ours@15%", "ours@30%"];
    section("Fig. 8a — p99 E2E latency [s] (red line = SLO)");
    print_table(&hdr, &e2e_rows);
    section("Fig. 8b — average TBT [ms] (SLO 200 ms)");
    print_table(
        &["engine", "triton", "ours@0%", "ours@15%", "ours@30%"],
        &tbt_rows,
    );
    section("Fig. 8c — mean power [W] / energy efficiency [tok/J]");
    print_table(
        &["engine", "triton", "ours@0%", "ours@15%", "ours@30%"],
        &pow_rows,
    );

    let mean = |v: &[f64]| v.iter().sum::<f64>() / v.len() as f64;
    section("anchors vs paper");
    println!(
        "TPJ gain (oracle)  : avg {:+.1}% / max {:+.1}%   (paper: +36.3% avg, +44.3% max)",
        mean(&tpj_gains_oracle) * 100.0,
        tpj_gains_oracle.iter().cloned().fold(f64::MIN, f64::max) * 100.0
    );
    println!(
        "TPJ gain (30% err) : avg {:+.1}%               (paper: +30.0%)",
        mean(&tpj_gains_30) * 100.0
    );
    println!(
        "energy reduction   : avg {:.1}% / max {:.1}%     (paper: 24.7% avg, 30.7% max)",
        mean(&energy_red) * 100.0,
        energy_red.iter().cloned().fold(f64::MIN, f64::max) * 100.0
    );
}
