//! Fig. 9 reproduction: average selected GPU frequencies, queue times
//! and TTFT for Triton vs throttLL'eM at 0/15/30% predictor error.
//!
//! Paper anchors: mean selected frequencies 950-1260 MHz (higher error
//! -> higher frequency); llama3-8b-TP1 and llama2-13b-TP1 show
//! pronounced queueing; throttLL'eM's TTFT exceeds Triton's (queueing
//! + slower compute-bound prefill at reduced frequency).
//!
//! Traces are right-scaled to each engine's max load as measured on
//! THIS substrate (§V-A methodology; see table2), with the E2E SLO set
//! to the p99 at that load.

mod common;

use common::saturation_profile;
use throttllem::bench_util::{print_table, section};
use throttllem::config::models::{llama2_13b, llama3_8b};
use throttllem::config::{EngineSpec, ServingConfig};
use throttllem::coordinator::{serve_trace, PerfModel, Policy};
use throttllem::workload::trace::{synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn main() {
    let secs: f64 = std::env::var("THROTTLLEM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(480.0);
    let seed = 0u64;
    let engines: Vec<EngineSpec> =
        vec![llama3_8b(1), llama2_13b(1), llama2_13b(2), llama2_13b(4)];

    let mut freq_rows = vec![];
    let mut queue_rows = vec![];
    let mut ttft_rows = vec![];
    for engine in engines {
        eprintln!("== {} ==", engine.name);
        let model = PerfModel::train(&[engine.clone()], 100, seed);
        let (max_rps, slo_e2e) =
            saturation_profile(&engine, &model, (secs * 0.4).max(180.0), 11);
        eprintln!("   derived: max load {max_rps:.2} RPS, E2E SLO {slo_e2e:.1} s");
        let base = synth_trace(&TraceParams::short(secs, max_rps, seed));

        let mut freq_r = vec![engine.name.clone(), "1410".to_string()];
        let mut queue_r = vec![engine.name.clone()];
        let mut ttft_r = vec![engine.name.clone()];

        // Triton reference for queue/TTFT.
        let mut reqs = base.clone();
        LengthPredictor::oracle().apply(&mut reqs, 1024);
        let cfg = ServingConfig::triton(engine.clone());
        let t = serve_trace(&cfg, Policy::triton(), &model, &reqs).stats;
        queue_r.push(format!("{:.2}", t.queue.mean()));
        ttft_r.push(format!("{:.0}", t.ttft.p50() * 1e3));

        for err in [0.0, 0.15, 0.30] {
            let mut cfg = ServingConfig::throttllem(engine.clone());
            cfg.slo.e2e_p99 = slo_e2e;
            cfg.predictor_p95_error = err;
            let mut reqs = base.clone();
            let pred = if err == 0.0 {
                LengthPredictor::oracle()
            } else {
                LengthPredictor::noisy(err, seed)
            };
            pred.apply(&mut reqs, cfg.max_tokens);
            let s = serve_trace(&cfg, Policy::throttle_only(), &model, &reqs).stats;
            freq_r.push(format!("{:.0}", s.freq.mean()));
            queue_r.push(format!("{:.2}", s.queue.mean()));
            ttft_r.push(format!("{:.0}", s.ttft.p50() * 1e3));
        }
        freq_rows.push(freq_r);
        queue_rows.push(queue_r);
        ttft_rows.push(ttft_r);
    }

    section("Fig. 9a — average applied GPU frequency [MHz]");
    print_table(
        &["engine", "triton", "ours@0%", "ours@15%", "ours@30%"],
        &freq_rows,
    );
    section("Fig. 9b — mean queue time [s]");
    print_table(
        &["engine", "triton", "ours@0%", "ours@15%", "ours@30%"],
        &queue_rows,
    );
    section("Fig. 9c — TTFT p50 [ms]");
    print_table(
        &["engine", "triton", "ours@0%", "ours@15%", "ours@30%"],
        &ttft_rows,
    );
    println!("\npaper anchors: ours selects 950-1260 MHz avg; error ^ -> frequency ^;");
    println!("TTFT higher than Triton due to queueing + throttled prefill.");
}
