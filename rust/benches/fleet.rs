//! Fleet bench: fleet-throttLL'eM (per-replica frequency control +
//! SLO-aware admission + least-loaded routing) against N independent
//! Triton replicas (round-robin split, max frequency) on the same
//! N-times-right-scaled trace, plus the single-replica reference the
//! fleet's admitted-RPS scaling is measured against.
//!
//! Expectation (ISSUE acceptance): at equal SLO attainment a fleet of
//! 4 sustains >= 3x the single replica's admitted RPS, while
//! fleet-throttLL'eM burns measurably less energy than the Triton
//! fleet at matched attainment.
//!
//! Run with: cargo bench --bench fleet
//! (THROTTLLEM_BENCH_SECS overrides the trace length.)

use std::time::Instant;

use throttllem::bench_util::{
    print_table, section, single_run_result, write_bench_json, BenchResult,
};
use throttllem::config::models::llama2_13b;
use throttllem::config::{ReplicaSpec, ServingConfig};
use throttllem::coordinator::{
    outcome_digest, serve_fleet, serve_fleet_plan, FleetPlan, FleetSpec, PerfModel, Policy,
    RouterPolicy,
};
use throttllem::metrics::ServingStats;
use throttllem::workload::trace::{inject_long_prompts, synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

fn row(name: &str, s: &ServingStats, slo_e2e: f64, slo_tbt: f64) -> Vec<String> {
    let admitted_rps = s.completed as f64 / s.wall_s;
    vec![
        name.to_string(),
        format!("{}", s.completed),
        format!("{:.2}", admitted_rps),
        format!("{:.2}", s.e2e.p99()),
        format!("{:.1}", s.e2e_slo_attainment(slo_e2e) * 100.0),
        format!("{:.1}", s.tbt_slo_attainment(slo_tbt) * 100.0),
        format!("{:.0}", s.freq.mean()),
        format!("{:.1}", s.total_energy_j / 1e3),
        format!("{:.3}", s.tokens_per_joule()),
    ]
}

fn main() {
    let secs: f64 = std::env::var("THROTTLLEM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(900.0);
    let n = 4usize;
    let seed = 0u64;
    let spec = llama2_13b(2);
    let slo = throttllem::config::SloSpec::for_engine(&spec);

    eprintln!("training performance model...");
    let model = PerfModel::train(&[spec.clone()], 120, seed);

    // One trace, right-scaled to ~80% of the FLEET's aggregate rated
    // load; the single-replica reference serves the same stream.
    let peak = 0.8 * spec.max_load_rps * n as f64;
    let mut reqs = synth_trace(&TraceParams::short(secs, peak, seed));
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    eprintln!(
        "trace: {} requests over {secs:.0} s (peak ~{peak:.1} RPS)",
        reqs.len()
    );

    let triton_cfg = ServingConfig::triton(spec.clone());
    let ours_cfg = ServingConfig::throttllem(spec.clone());

    // Wall-clock per scenario feeds the machine-readable report: the
    // serve loop's own speed is the fleet-scale view of the hot-path
    // work perf_hotpath measures in isolation.
    let mut report: Vec<BenchResult> = Vec::new();
    let t0 = Instant::now();
    let single = serve_fleet(
        &triton_cfg,
        Policy::triton(),
        &model,
        &reqs,
        &FleetSpec {
            replicas: 1,
            router: RouterPolicy::RoundRobin,
            autoscale_replicas: false,
        },
    );
    report.push(single_run_result("serve triton x1", t0.elapsed()));
    let t0 = Instant::now();
    let triton_fleet = serve_fleet(
        &triton_cfg,
        Policy::triton(),
        &model,
        &reqs,
        &FleetSpec {
            replicas: n,
            router: RouterPolicy::RoundRobin,
            autoscale_replicas: false,
        },
    );
    report.push(single_run_result("serve triton x4 (rr)", t0.elapsed()));
    let t0 = Instant::now();
    let ours_fleet = serve_fleet(
        &ours_cfg,
        Policy::throttle_only(),
        &model,
        &reqs,
        &FleetSpec {
            replicas: n,
            router: RouterPolicy::LeastLoaded,
            autoscale_replicas: false,
        },
    );
    report.push(single_run_result("serve throttllem x4 (ll)", t0.elapsed()));

    section(&format!(
        "Fleet comparison: {n} x {} vs 1 x (same {peak:.1}-RPS-peak trace)",
        spec.name
    ));
    let rows = vec![
        row("triton x1", &single.total.stats, slo.e2e_p99, slo.tbt_avg),
        row(
            &format!("triton x{n} (rr)"),
            &triton_fleet.total.stats,
            slo.e2e_p99,
            slo.tbt_avg,
        ),
        row(
            &format!("throttllem x{n} (ll)"),
            &ours_fleet.total.stats,
            slo.e2e_p99,
            slo.tbt_avg,
        ),
    ];
    print_table(
        &[
            "deployment",
            "completed",
            "adm.RPS",
            "E2Ep99[s]",
            "E2Eatt[%]",
            "TBTatt[%]",
            "freq[MHz]",
            "energy[kJ]",
            "TPJ",
        ],
        &rows,
    );

    let single_rps = single.total.stats.completed as f64 / single.total.stats.wall_s;
    let fleet_rps =
        ours_fleet.total.stats.completed as f64 / ours_fleet.total.stats.wall_s;
    let att_single = single.total.stats.e2e_slo_attainment(slo.e2e_p99);
    let att_fleet = ours_fleet.total.stats.e2e_slo_attainment(slo.e2e_p99);
    println!(
        "\nadmitted RPS: fleet {fleet_rps:.2} vs single {single_rps:.2} \
         -> {:.2}x (target >= 3x at equal-or-better attainment: \
         fleet {:.1}% vs single {:.1}%)",
        fleet_rps / single_rps,
        att_fleet * 100.0,
        att_single * 100.0
    );
    println!(
        "energy: throttllem fleet {:.1} kJ vs triton fleet {:.1} kJ \
         ({:+.1}%)",
        ours_fleet.total.stats.total_energy_j / 1e3,
        triton_fleet.total.stats.total_energy_j / 1e3,
        (ours_fleet.total.stats.total_energy_j
            / triton_fleet.total.stats.total_energy_j
            - 1.0)
            * 100.0
    );

    section("Per-replica breakdown (throttllem fleet)");
    let rrows: Vec<Vec<String>> = ours_fleet
        .replicas
        .iter()
        .enumerate()
        .map(|(i, r)| {
            vec![
                format!("{i}"),
                format!("{}", r.routed),
                format!("{}", r.stats.completed),
                format!("{}", r.stats.dropped),
                format!("{:.0}", r.stats.freq.mean()),
                format!("{:.1}", r.stats.total_energy_j / 1e3),
                format!("{}", r.engine_switches),
            ]
        })
        .collect();
    print_table(
        &[
            "replica", "routed", "completed", "dropped", "freq[MHz]", "energy[kJ]",
            "switches",
        ],
        &rrows,
    );
    println!("rerouted on universal rejection: {}", ours_fleet.rerouted);

    hetero_bench(secs, seed, &mut report);
    threads_bench(secs, seed, &mut report);
    write_bench_json("fleet", &report);
}

/// Sharded-coordinator speedup: the SAME 64-replica homogeneous fleet
/// and trace served at 1 / 2 / 4 RUN-phase worker threads.  The
/// outcome digest must be identical across thread counts (the
/// determinism contract `fleet_threads.rs` pins at test scale); only
/// wall clock may move.  Acceptance target: >= 1.5x at 4 threads.
fn threads_bench(secs: f64, seed: u64, report: &mut Vec<BenchResult>) {
    let n = 64usize;
    let spec = llama2_13b(2);
    let cfg = ServingConfig::throttllem(spec.clone());
    let policy = Policy::throttle_only();
    let base = FleetPlan::homogeneous(n, RouterPolicy::RoundRobin, &cfg, policy, false);
    let peak = 0.5 * base.rated_rps();
    eprintln!("training performance model for the {n}-replica fleet...");
    let model = PerfModel::train(&base.engines(), 120, seed);
    let mut reqs = synth_trace(&TraceParams::short(secs, peak, seed));
    LengthPredictor::oracle().apply(&mut reqs, 1024);

    section(&format!(
        "Sharded coordinator: {n} x {} at 1/2/4 threads (same trace)",
        spec.name
    ));
    let mut walls = Vec::new();
    let mut digest = None;
    for threads in [1usize, 2, 4] {
        let plan = base.clone().with_threads(threads);
        let t0 = Instant::now();
        let out = serve_fleet_plan(&cfg, policy, &model, &reqs, &plan);
        let wall = t0.elapsed();
        let d = outcome_digest(&out);
        println!(
            "threads={threads}: {:.2} s wall, digest {d:016x}, {} completed",
            wall.as_secs_f64(),
            out.total.stats.completed
        );
        match digest {
            None => digest = Some(d),
            Some(first) => {
                assert_eq!(first, d, "threads={threads} broke bit-identity");
            }
        }
        report.push(single_run_result(
            &format!("serve fleet64 (threads={threads})"),
            wall,
        ));
        walls.push(wall.as_secs_f64());
    }
    // Recorded as a pseudo-bench in milli-x (1500 = 1.50x) so the
    // speedup trajectory lands in BENCH_perf.json next to the wall
    // times; logged, not hard-asserted — CI smoke runners vary.
    let speedup = walls[0] / walls[2];
    let mx = speedup * 1000.0;
    report.push(BenchResult {
        name: "fleet64 threads=4 speedup (milli-x)".to_string(),
        iters: 1,
        mean_ns: mx,
        p50_ns: mx,
        p95_ns: mx,
        p99_ns: mx,
        min_ns: mx,
        max_ns: mx,
    });
    let verdict = if speedup >= 1.5 {
        "meets"
    } else {
        "MISSES (this machine/run)"
    };
    println!(
        "speedup at 4 threads: {speedup:.2}x — {verdict} the >= 1.5x target \
         on the {n}-replica fleet"
    );
}

/// Heterogeneous fleet: mixed TP sizes with occasional long prompts
/// only the large replicas can hold.  Acceptance (ISSUE 2):
/// `projected-headroom` must achieve strictly better SLO attainment or
/// lower energy than round-robin on the same trace — round-robin parks
/// long prompts on TP1 replicas (120 KV blocks < the prompt), blocking
/// their queue heads until the replica drains and the request reroutes.
fn hetero_bench(secs: f64, seed: u64, report: &mut Vec<BenchResult>) {
    let specs = vec![
        ReplicaSpec::fixed(llama2_13b(1)),
        ReplicaSpec::fixed(llama2_13b(2)),
        ReplicaSpec::fixed(llama2_13b(2)),
        ReplicaSpec::fixed(llama2_13b(4)),
    ];
    let base = FleetPlan::heterogeneous(specs, RouterPolicy::RoundRobin);
    let rated = base.rated_rps();
    let peak = 0.6 * rated;
    let cfg = ServingConfig::throttllem(llama2_13b(4));
    let slo = cfg.slo;
    // Train on the fleet's unique engines (two replicas share TP2).
    eprintln!("training performance model for the mixed fleet...");
    let model = PerfModel::train(&base.engines(), 120, seed);

    let mut reqs = synth_trace(&TraceParams::short(secs, peak, seed));
    // A 10k-token prompt every 20 s: 157 KV blocks, impossible on the
    // TP1 replica, comfortable on TP2/TP4.
    inject_long_prompts(&mut reqs, secs, 20.0, 10_000, 64);
    LengthPredictor::oracle().apply(&mut reqs, 1024);

    section(&format!(
        "Heterogeneous fleet (TP1+2xTP2+TP4, rated {rated:.1} RPS): \
         round-robin vs capacity-aware routing"
    ));
    let mut rows = Vec::new();
    let mut results = Vec::new();
    for router in [
        RouterPolicy::RoundRobin,
        RouterPolicy::LeastLoaded,
        RouterPolicy::ProjectedHeadroom,
    ] {
        let plan = FleetPlan {
            router,
            ..base.clone()
        };
        let t0 = Instant::now();
        let out =
            serve_fleet_plan(&cfg, Policy::throttle_only(), &model, &reqs, &plan);
        report.push(single_run_result(
            &format!("serve mixed ({})", router.name()),
            t0.elapsed(),
        ));
        rows.push(row(
            &format!("mixed ({})", router.name()),
            &out.total.stats,
            slo.e2e_p99,
            slo.tbt_avg,
        ));
        results.push((router, out));
    }
    print_table(
        &[
            "deployment",
            "completed",
            "adm.RPS",
            "E2Ep99[s]",
            "E2Eatt[%]",
            "TBTatt[%]",
            "freq[MHz]",
            "energy[kJ]",
            "TPJ",
        ],
        &rows,
    );
    let rr = &results[0].1;
    let ph = &results[2].1;
    let rr_att = rr.total.stats.e2e_slo_attainment(slo.e2e_p99);
    let ph_att = ph.total.stats.e2e_slo_attainment(slo.e2e_p99);
    println!(
        "\nprojected-headroom vs round-robin: E2E attainment {:.1}% vs {:.1}%, \
         energy {:.1} kJ vs {:.1} kJ, rerouted {} vs {}  \
         (acceptance: ph strictly better attainment OR lower energy: {})",
        ph_att * 100.0,
        rr_att * 100.0,
        ph.total.stats.total_energy_j / 1e3,
        rr.total.stats.total_energy_j / 1e3,
        ph.rerouted,
        rr.rerouted,
        ph_att > rr_att
            || ph.total.stats.total_energy_j < rr.total.stats.total_energy_j
    );
}
