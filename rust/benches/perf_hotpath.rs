//! §Perf instrument: micro-benchmarks of the L3 hot paths.
//!
//! Paper budgets: projection < 2 ms; M inference ~3 ms; scheduler +
//! throttling combined 35 ms under heavy load. Our targets (DESIGN.md
//! §8): well under those budgets at batch 64 / 1024-iteration horizon.
//!
//! The admission / throttle / projection benches come in two variants:
//! "from-scratch" is the pre-tracker hot path (rebuild the projection
//! per use, allocate throughput / remaining-time vectors per probe),
//! the plain name is the serving loop's actual path (incremental
//! `ProjectionTracker` + reusable `EvalScratch`).  Results are also
//! emitted to `BENCH_perf.json` (suite `perf_hotpath`) so CI tracks
//! the trajectory.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::Instant;

use throttllem::bench_util::{
    bench, black_box, section, single_run_result, write_bench_json, BenchResult,
};
use throttllem::config::models::llama2_13b;
use throttllem::config::{ServingConfig, SloSpec};
use throttllem::coordinator::projection::{project, project_entries, ProjectionTracker};
use throttllem::coordinator::router::{headroom_score, HeadroomCache};
use throttllem::coordinator::scheduler::{
    entry_for, evaluate_slo, evaluate_slo_entries, EvalScratch, Scheduler,
};
use throttllem::coordinator::scoreboard::{Entry, Scoreboard};
use throttllem::coordinator::shard::steady_state_sweep;
use throttllem::coordinator::throttle::{min_slo_frequency, min_slo_frequency_with};
use throttllem::coordinator::{
    outcome_digest, serve_fleet_plan, FleetPlan, PerfModel, Policy, RouterPolicy,
};
use throttllem::engine::request::Request;
use throttllem::engine::sim::EngineSim;
use throttllem::gpusim::dvfs::{frequency_grid, FREQ_MAX_MHZ};
use throttllem::sim::Pcg64;
use throttllem::workload::trace::{synth_trace, TraceParams};
use throttllem::workload::LengthPredictor;

/// Counting allocator: tallies every heap allocation (alloc, zeroed,
/// realloc) so the steady-state sweep below can assert the RUN-phase
/// hot path performs no per-iteration allocations beyond amortized
/// telemetry growth.  Deallocation is free of bookkeeping: the audit
/// only cares about allocation pressure.
struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn scoreboard(n: u32, rng: &mut Pcg64) -> Scoreboard {
    let mut sb = Scoreboard::new();
    for id in 0..n {
        sb.insert(Entry {
            id: id as u64,
            scheduled_iter: rng.uniform_u64(0, 50),
            prompt_tokens: rng.uniform_u64(16, 2000) as u32,
            predicted_gen: rng.uniform_u64(32, 1024) as u32,
            deadline_s: 30.0 + rng.next_f64() * 10.0,
            lost: false,
            kv_discount_blocks: 0,
        });
    }
    sb
}

fn main() {
    let spec = llama2_13b(4); // 64-wide batches: the heavy case
    let slo = SloSpec::new(0.2, 31.3);
    eprintln!("training model...");
    let model = PerfModel::train(&[spec.clone()], 100, 0);
    let mut rng = Pcg64::new(0);
    let mut report: Vec<BenchResult> = Vec::new();

    section("L3 hot-path microbenchmarks (budgets: paper §IV)");

    for n in [8u32, 32, 64] {
        let sb = scoreboard(n, &mut rng);
        let r = bench(
            &format!("projection from-scratch (Eq.1-2), {n} queries"),
            300,
            || {
                black_box(project(&sb, 60, spec.block_tokens));
            },
        );
        println!("{r}");
        report.push(r);
    }

    // Incremental tracker: steady-state materialization (the serving
    // loop's per-use cost once deltas are applied) and with per-use
    // scoreboard churn (one strike + one insert between projections).
    {
        let sb = scoreboard(64, &mut rng);
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        let r = bench("projection via tracker, 64 queries", 300, || {
            black_box(tracker.project(&sb, 60, None).peak_kv());
        });
        println!("{r}");
        report.push(r);

        let mut sb = scoreboard(64, &mut rng);
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        let mut flip = false;
        let churn = *sb.committed().first().unwrap();
        let r = bench("projection via tracker + churn, 64 queries", 300, || {
            if flip {
                sb.insert(churn);
            } else {
                sb.strike(churn.id);
            }
            flip = !flip;
            black_box(tracker.project(&sb, 60, None).peak_kv());
        });
        println!("{r}");
        report.push(r);
    }

    let r = bench("M single inference (GBDT)", 300, || {
        black_box(model.predict_ips(&spec, 32, 500, 1050));
    });
    println!("{r}");
    report.push(r);

    let sb = scoreboard(64, &mut rng);
    let proj = project(&sb, 60, spec.block_tokens);
    println!("(horizon = {} iterations)", proj.horizon());
    let r = bench("throughput vector T (stride 4)", 300, || {
        black_box(model.throughput_vector(&spec, &proj, 1410));
    });
    println!("{r}");
    report.push(r);
    let mut exact = model.clone();
    exact.stride = 1;
    let r = bench("throughput vector T (stride 1)", 300, || {
        black_box(exact.throughput_vector(&spec, &proj, 1410));
    });
    println!("{r}");
    report.push(r);

    // §IV-E frequency search: from-scratch allocates entry/throughput/
    // remaining-time vectors per probe and re-runs GBDT inference; the
    // serving path reuses EvalScratch buffers and memoizes inferences
    // per (freq, batch, kv-bucket) for as long as the committed entry
    // set and iteration stay put.
    let grid = frequency_grid();
    let r = bench("throttle binary search (§IV-E), from-scratch", 500, || {
        black_box(min_slo_frequency(&model, &spec, &slo, &sb, &proj, 0.0, 1.0));
    });
    println!("{r}");
    report.push(r);
    let mut scratch = EvalScratch::new();
    let r = bench("throttle binary search (§IV-E)", 500, || {
        black_box(min_slo_frequency_with(
            &grid,
            &model,
            &spec,
            &slo,
            &sb,
            &proj,
            0.0,
            1.0,
            &mut scratch,
        ));
    });
    println!("{r}");
    report.push(r);

    // Fleet router scoring: the projected-headroom signal per arrival.
    // Uncached rebuilds the §IV-B projection every time (the pre-cache
    // hot path, O(arrivals x replicas) builds); cached reuses the
    // memoized summary until an admission/completion/iteration moves
    // the key.  The cached path must be orders of magnitude cheaper —
    // and bit-identical (Replica::headroom_for cross-checks in debug).
    let sb64 = scoreboard(64, &mut rng);
    let r = bench("router headroom score, uncached", 300, || {
        let proj = project(&sb64, 60, spec.block_tokens);
        black_box(headroom_score(
            spec.kv_blocks,
            proj.peak_kv(),
            40,
            spec.max_batch,
            32,
            3,
        ));
    });
    println!("{r}");
    report.push(r);
    let mut cache = HeadroomCache::new();
    let r = bench("router headroom score, cached", 300, || {
        let (peak, qb, qr) = cache.fetch((60, 7, 9), || {
            let proj = project(&sb64, 60, spec.block_tokens);
            (proj.peak_kv(), 40, 3)
        });
        black_box(headroom_score(
            spec.kv_blocks,
            peak,
            qb,
            spec.max_batch,
            32,
            qr,
        ));
    });
    println!("{r}");
    report.push(r);

    // §IV-C2 admission: from-scratch replicates the pre-tracker
    // algorithm (projection rebuild + entry collection per world); the
    // plain variant is Scheduler::admission_check on the serving
    // loop's per-engine tracker + scratch.
    let sched = Scheduler::new(slo);
    let mut sb2 = sb.clone();
    let r = bench("full admission check (§IV-C2), from-scratch", 500, || {
        sb2.virtual_append(entry_for(999, 500, 300, 60.0, 60, &slo));
        let proj = project(&sb2, 60, spec.block_tokens);
        let decision = if proj.peak_kv() > spec.kv_blocks {
            0
        } else {
            let eval =
                evaluate_slo(&model, &spec, &slo, &sb2, &proj, FREQ_MAX_MHZ, 60.0);
            let blamed: Vec<u64> = eval
                .e2e_violators
                .iter()
                .copied()
                .filter(|&id| id != 999)
                .collect();
            if !blamed.is_empty() {
                let committed: Vec<Entry> = sb2.committed().to_vec();
                let proj_wo = project_entries(&committed, 60, spec.block_tokens);
                let eval_wo = evaluate_slo_entries(
                    &model,
                    &spec,
                    &slo,
                    &committed,
                    &proj_wo,
                    FREQ_MAX_MHZ,
                    60.0,
                    1.0,
                );
                eval_wo.e2e_violators.len()
            } else {
                1
            }
        };
        black_box(decision);
        sb2.rollback_virtual();
    });
    println!("{r}");
    report.push(r);
    let mut tracker = ProjectionTracker::new(spec.block_tokens);
    let mut scratch = EvalScratch::new();
    let r = bench("full admission check (§IV-C2)", 500, || {
        sb2.virtual_append(entry_for(999, 500, 300, 60.0, 60, &slo));
        black_box(sched.admission_check(
            &model,
            &spec,
            &sb2,
            &mut tracker,
            &mut scratch,
            60,
            60.0,
            999,
        ));
        sb2.rollback_virtual();
    });
    println!("{r}");
    report.push(r);

    // Engine iteration cost (simulation substrate, not the paper's
    // system — bounds trace-replay wall time). Rows are re-admitted on
    // completion so the batch never drains or exhausts the KV pool.
    let mut engine = EngineSim::new(spec.clone(), 1410);
    let mut next_id = 0u64;
    let mut admit48 = |engine: &mut EngineSim, t: f64| {
        while engine.batch() < 48 {
            engine
                .admit(
                    Request {
                        id: next_id,
                        prompt_tokens: 64,
                        gen_tokens: 512,
                        predicted_gen: 512,
                        arrival_s: t,
                        prefix_group: 0,
                        shared_prefix_tokens: 0,
                    },
                    t,
                    false,
                )
                .unwrap();
            next_id += 1;
        }
    };
    admit48(&mut engine, 0.0);
    let mut t = 0.0;
    engine.run_iteration(t); // absorb initial prefill
    let r = bench("engine iteration (batch 48)", 300, || {
        admit48(&mut engine, t);
        t += engine.run_iteration(t).duration_s;
    });
    println!("{r}");
    report.push(r);

    // Steady-state allocation audit: one warm replica driven through
    // repeated RUN-phase rounds; past the warm-up mark, the serving
    // hot path reuses per-replica scratch (EvalScratch, the DVFS grid,
    // headroom cache, queue ring), so allocations must stay bounded by
    // amortized telemetry-Vec growth.  Advisory by default; a hard
    // gate in debug builds and under THROTTLLEM_STRICT_ALLOC=1 (the
    // CI bench job sets it).
    section("steady-state allocation audit (coordinator/shard.rs)");
    let audit_cfg = ServingConfig::throttllem(spec.clone());
    let mut marked = 0u64;
    let iters = steady_state_sweep(&audit_cfg, Policy::throttle_only(), &model, 64, 256, &mut || {
        marked = ALLOCS.load(Ordering::Relaxed)
    });
    let allocs = ALLOCS.load(Ordering::Relaxed) - marked;
    let budget = 2 * iters + 64;
    println!(
        "{iters} engine iterations after warm-up: {allocs} heap allocations \
         ({:.3}/iter, budget {budget})",
        allocs as f64 / iters.max(1) as f64
    );
    if cfg!(debug_assertions) || std::env::var("THROTTLLEM_STRICT_ALLOC").is_ok() {
        assert!(
            allocs <= budget,
            "steady-state sweep allocated {allocs} times over {iters} \
             iterations (budget {budget}): the RUN-phase hot path has \
             grown a per-iteration allocation"
        );
        println!("strict allocation gate: PASS ({allocs} <= {budget})");
    }

    // Sharded-coordinator wall time at micro scale: an 8-replica fleet
    // on one short trace at 1 vs 4 RUN-phase worker threads, with the
    // bit-identity contract cross-checked via the outcome digest (the
    // fleet bench runs the 64-replica version).  Neither entry is
    // gate-tracked — these are wall times, not hot-path budgets.
    section("sharded coordinator wall time (8 replicas, threads 1 vs 4)");
    let fleet_spec = llama2_13b(2);
    let fleet_cfg = ServingConfig::throttllem(fleet_spec.clone());
    let policy = Policy::throttle_only();
    let plan8 = FleetPlan::homogeneous(8, RouterPolicy::RoundRobin, &fleet_cfg, policy, false);
    eprintln!("training model for the 8-replica fleet...");
    let fleet_model = PerfModel::train(&plan8.engines(), 60, 0);
    let peak = 0.5 * plan8.rated_rps();
    let mut reqs = synth_trace(&TraceParams::short(120.0, peak, 0));
    LengthPredictor::oracle().apply(&mut reqs, 1024);
    let mut digests = Vec::new();
    for threads in [1usize, 4] {
        let plan = plan8.clone().with_threads(threads);
        let t0 = Instant::now();
        let out = serve_fleet_plan(&fleet_cfg, policy, &fleet_model, &reqs, &plan);
        let r = single_run_result(&format!("serve fleet8 (threads={threads})"), t0.elapsed());
        println!("{r}");
        digests.push(outcome_digest(&out));
        report.push(r);
    }
    assert_eq!(digests[0], digests[1], "threads=4 broke bit-identity");

    println!(
        "\nbudget check: admission+throttle mean must be << 35 ms; projection << 2 ms."
    );
    let speedup = |new_name: &str, old_name: &str| {
        let get = |n: &str| report.iter().find(|r| r.name == n).map(|r| r.mean_ns);
        if let (Some(new), Some(old)) = (get(new_name), get(old_name)) {
            println!("{new_name}: {:.1}x vs from-scratch", old / new);
        }
    };
    speedup(
        "full admission check (§IV-C2)",
        "full admission check (§IV-C2), from-scratch",
    );
    speedup(
        "throttle binary search (§IV-E)",
        "throttle binary search (§IV-E), from-scratch",
    );
    // Machine-speed yardstick: the perf-regression gate (bench_gate)
    // normalizes cross-machine ns/op ratios by this bench's ratio.
    let r = throttllem::bench_util::calibration_result();
    println!("{r}");
    report.push(r);
    write_bench_json("perf_hotpath", &report);
}
