//! §Perf instrument: micro-benchmarks of the L3 hot paths.
//!
//! Paper budgets: projection < 2 ms; M inference ~3 ms; scheduler +
//! throttling combined 35 ms under heavy load. Our targets (DESIGN.md
//! §8): well under those budgets at batch 64 / 1024-iteration horizon.

use throttllem::bench_util::{bench, black_box, section};
use throttllem::config::models::llama2_13b;
use throttllem::config::SloSpec;
use throttllem::coordinator::projection::project;
use throttllem::coordinator::router::{headroom_score, HeadroomCache};
use throttllem::coordinator::scheduler::{entry_for, Scheduler};
use throttllem::coordinator::scoreboard::{Entry, Scoreboard};
use throttllem::coordinator::throttle::min_slo_frequency;
use throttllem::coordinator::PerfModel;
use throttllem::engine::request::Request;
use throttllem::engine::sim::EngineSim;
use throttllem::sim::Pcg64;

fn scoreboard(n: u32, rng: &mut Pcg64) -> Scoreboard {
    let mut sb = Scoreboard::new();
    for id in 0..n {
        sb.insert(Entry {
            id: id as u64,
            scheduled_iter: rng.uniform_u64(0, 50),
            prompt_tokens: rng.uniform_u64(16, 2000) as u32,
            predicted_gen: rng.uniform_u64(32, 1024) as u32,
            deadline_s: 30.0 + rng.next_f64() * 10.0,
            lost: false,
        });
    }
    sb
}

fn main() {
    let spec = llama2_13b(4); // 64-wide batches: the heavy case
    let slo = SloSpec::new(0.2, 31.3);
    eprintln!("training model...");
    let model = PerfModel::train(&[spec.clone()], 100, 0);
    let mut rng = Pcg64::new(0);

    section("L3 hot-path microbenchmarks (budgets: paper §IV)");

    for n in [8u32, 32, 64] {
        let sb = scoreboard(n, &mut rng);
        let r = bench(&format!("projection (Eq.1-2), {n} queries"), 300, || {
            black_box(project(&sb, 60, spec.block_tokens));
        });
        println!("{r}");
    }

    let r = bench("M single inference (GBDT)", 300, || {
        black_box(model.predict_ips(&spec, 32, 500, 1050));
    });
    println!("{r}");

    let sb = scoreboard(64, &mut rng);
    let proj = project(&sb, 60, spec.block_tokens);
    println!("(horizon = {} iterations)", proj.horizon());
    let r = bench("throughput vector T (stride 4)", 300, || {
        black_box(model.throughput_vector(&spec, &proj, 1410));
    });
    println!("{r}");
    let mut exact = model.clone();
    exact.stride = 1;
    let r = bench("throughput vector T (stride 1)", 300, || {
        black_box(exact.throughput_vector(&spec, &proj, 1410));
    });
    println!("{r}");

    let r = bench("throttle binary search (§IV-E)", 500, || {
        black_box(min_slo_frequency(&model, &spec, &slo, &sb, &proj, 0.0, 1.0));
    });
    println!("{r}");

    // Fleet router scoring: the projected-headroom signal per arrival.
    // Uncached rebuilds the §IV-B projection every time (the pre-cache
    // hot path, O(arrivals x replicas) builds); cached reuses the
    // memoized summary until an admission/completion/iteration moves
    // the key.  The cached path must be orders of magnitude cheaper —
    // and bit-identical (Replica::headroom_for cross-checks in debug).
    let sb64 = scoreboard(64, &mut rng);
    let r = bench("router headroom score, uncached", 300, || {
        let proj = project(&sb64, 60, spec.block_tokens);
        black_box(headroom_score(
            spec.kv_blocks,
            proj.peak_kv(),
            40,
            spec.max_batch,
            32,
            3,
        ));
    });
    println!("{r}");
    let mut cache = HeadroomCache::new();
    let r = bench("router headroom score, cached", 300, || {
        let (peak, qb, qr) = cache.fetch((60, 7, 9), || {
            let proj = project(&sb64, 60, spec.block_tokens);
            (proj.peak_kv(), 40, 3)
        });
        black_box(headroom_score(
            spec.kv_blocks,
            peak,
            qb,
            spec.max_batch,
            32,
            qr,
        ));
    });
    println!("{r}");

    let sched = Scheduler::new(slo);
    let r = bench("full admission check (§IV-C2)", 500, || {
        let mut sb2 = sb.clone();
        sb2.virtual_append(entry_for(999, 500, 300, 60.0, 60, &slo));
        black_box(sched.admission_check(&model, &spec, &sb2, 60, 60.0, 999));
        sb2.rollback_virtual();
    });
    println!("{r}");

    // Engine iteration cost (simulation substrate, not the paper's
    // system — bounds trace-replay wall time). Rows are re-admitted on
    // completion so the batch never drains or exhausts the KV pool.
    let mut engine = EngineSim::new(spec.clone(), 1410);
    let mut next_id = 0u64;
    let mut admit48 = |engine: &mut EngineSim, t: f64| {
        while engine.batch() < 48 {
            engine
                .admit(
                    Request {
                        id: next_id,
                        prompt_tokens: 64,
                        gen_tokens: 512,
                        predicted_gen: 512,
                        arrival_s: t,
                    },
                    t,
                    false,
                )
                .unwrap();
            next_id += 1;
        }
    };
    admit48(&mut engine, 0.0);
    let mut t = 0.0;
    engine.run_iteration(t); // absorb initial prefill
    let r = bench("engine iteration (batch 48)", 300, || {
        admit48(&mut engine, t);
        t += engine.run_iteration(t).duration_s;
    });
    println!("{r}");

    println!(
        "\nbudget check: admission+throttle mean must be << 35 ms; projection << 2 ms."
    );
}
