//! Fleet scenario matrix: every generated scenario (steady / burst /
//! flash / diurnal) served under every router policy on the same
//! shared arrival stream, reporting SLO attainment and J/token per
//! cell.  This is the workload the ROADMAP's "Trace realism" item
//! asked for: correlated bursts hit every replica at once, so the
//! router and admission control face fleet-wide pressure instead of
//! conveniently decorrelated per-replica load.
//!
//! Acceptance (ISSUE 4): projected-headroom must match or beat
//! round-robin on E2E attainment OR J/token in EVERY scenario — the
//! process exits non-zero otherwise, so the CI smoke run enforces it.
//!
//! Run with: cargo bench --bench scenarios
//! (THROTTLLEM_BENCH_SECS overrides the per-scenario trace length.)

use throttllem::bench_util::{
    headroom_regressions, print_scenario_table, section, write_bench_json,
    BenchResult, ScenarioSuite,
};
use throttllem::config::models::llama2_13b;
use throttllem::config::ServingConfig;
use throttllem::coordinator::{FleetPlan, PerfModel, Policy, RouterPolicy};

fn main() {
    let secs: f64 = std::env::var("THROTTLLEM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(600.0);
    let seed = 0u64;
    let replicas = 4usize;
    let spec = llama2_13b(2);
    let cfg = ServingConfig::throttllem(spec.clone());
    let policy = Policy::throttle_only();

    eprintln!("training performance model...");
    let model = PerfModel::train(&[spec.clone()], 120, seed);
    let plan =
        FleetPlan::homogeneous(replicas, RouterPolicy::RoundRobin, &cfg, policy, false);

    let suite = ScenarioSuite::full(secs, seed);
    eprintln!(
        "running {} scenarios x {} routers on {replicas} x {} ({secs:.0} s each)...",
        suite.scenarios.len(),
        suite.routers.len(),
        spec.name
    );
    let runs = suite.run(&cfg, policy, &model, &plan);

    section(&format!(
        "Scenario matrix: {replicas} x {} at {:.0}% of rated fleet load",
        spec.name,
        suite.utilization * 100.0
    ));
    print_scenario_table(&runs);

    let report: Vec<BenchResult> = runs.iter().map(|r| r.wall.clone()).collect();
    write_bench_json("scenarios", &report);

    let regressions = headroom_regressions(&runs);
    if regressions.is_empty() {
        println!(
            "\nprojected-headroom matches or beats round-robin on attainment \
             or J/token in every scenario"
        );
    } else {
        for r in &regressions {
            println!("ROUTER REGRESSION: {r}");
        }
        std::process::exit(1);
    }
}
