//! Table II reproduction: per-engine performance profiles — the rated
//! max load (RPS before long tail latencies appear) and the p99 E2E
//! at that load (which becomes the E2E SLO), derived by saturation
//! profiling exactly as §V-A describes (MLPerf-style RPS ramp).
//!
//! KV-block capacities and the paper's rated numbers are configuration
//! ground truth; the derived columns are this substrate's equivalents
//! and feed the fig8/fig9 right-scaling (the paper likewise scales its
//! trace to ITS testbed's measured max load).

mod common;

use common::saturation_profile;
use throttllem::bench_util::{print_table, section};
use throttllem::config::models::table2_engines;
use throttllem::coordinator::PerfModel;

fn main() {
    section("Table II — engine performance profiles (derived by saturation ramp)");
    let secs: f64 = std::env::var("THROTTLLEM_BENCH_SECS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(240.0);
    let mut rows = vec![];
    for engine in table2_engines() {
        let model = PerfModel::train(&[engine.clone()], 30, 0);
        let (derived_rps, derived_slo) = saturation_profile(&engine, &model, secs, 11);
        rows.push(vec![
            engine.name.clone(),
            format!("{}", engine.tensor_parallel),
            format!("{:.3}", derived_rps),
            format!("{:.3}", engine.max_load_rps),
            format!("{:.1}", derived_slo),
            format!("{:.1}", engine.e2e_slo_p99),
            format!("{}", engine.kv_blocks),
        ]);
    }
    print_table(
        &[
            "engine", "TP", "maxRPS*", "maxRPS(paper)", "E2E SLO*", "E2E SLO(paper)",
            "KVblocks",
        ],
        &rows,
    );
    println!("\n* derived on this substrate ({secs:.0} s ramps); paper columns = Table II ground truth");
    println!("  (KV blocks are configuration inputs, reproduced exactly.)");
}
