//! Table III reproduction: performance-prediction model evaluation —
//! R², MAPE, MAE per engine under 90/10 and 10/90 train/test splits.
//!
//! Paper anchors: R² >= 0.97 (90/10) and >= 0.96 (10/90); MAPE <= 5.8%;
//! MAE < 1 IPS on average; sparse training stays robust.

use throttllem::bench_util::{print_table, section};
use throttllem::config::models::table2_engines;
use throttllem::coordinator::PerfModel;
use throttllem::mlmodel::{mae, mape, r2_score};
use throttllem::sim::Pcg64;
use throttllem::workload::collect_training_data;

fn main() {
    section("Table III — performance prediction model (M) evaluation");
    let mut rows = vec![];
    for engine in table2_engines() {
        let data = collect_training_data(&engine, 300, 0);
        let mut cells = vec![engine.name.clone()];
        for frac in [0.9, 0.1] {
            let mut rng = Pcg64::new(1);
            let (train, test) = data.split(frac, &mut rng);
            let model = PerfModel::train_on(&train);
            let pred: Vec<f64> =
                test.features.iter().map(|f| model.predict_raw(f)).collect();
            cells.push(format!("{:.3}", r2_score(&test.targets, &pred)));
            cells.push(format!("{:.1}", mape(&test.targets, &pred)));
            cells.push(format!("{:.2}", mae(&test.targets, &pred)));
        }
        rows.push(cells);
    }
    print_table(
        &[
            "engine",
            "R2(90/10)", "MAPE%(90/10)", "MAE(90/10)",
            "R2(10/90)", "MAPE%(10/90)", "MAE(10/90)",
        ],
        &rows,
    );
    println!("\npaper anchors: R2 >= 0.97 / 0.96, MAPE 2.8-5.8% / +0.7%, MAE < 1.01 IPS");
}
