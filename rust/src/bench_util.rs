//! Benchmark harness (criterion substitute, offline build).
//!
//! Provides wall-clock timing loops with warm-up, robust summary
//! statistics, and table/series printers shared by the per-figure
//! bench binaries under `rust/benches/`.

use std::time::Instant;

/// Timing summary of a benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} us/iter  (p50 {:>9.3}, p95 {:>9.3}, n={})",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p95_ns / 1e3,
            self.iters
        )
    }
}

/// Time `f` with warm-up; runs until ~`budget_ms` of samples or
/// `max_iters`, whichever first.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warm-up: a few calls to populate caches/allocators.
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let started = Instant::now();
    let mut samples_ns: Vec<f64> = Vec::new();
    let max_iters = 100_000u64;
    while started.elapsed() < budget && (samples_ns.len() as u64) < max_iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, &mut samples_ns)
}

fn summarize(name: &str, samples_ns: &mut [f64]) -> BenchResult {
    assert!(!samples_ns.is_empty());
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let pct = |p: f64| crate::metrics::percentile_of_sorted(samples_ns, p);
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean_ns: mean,
        p50_ns: pct(50.0),
        p95_ns: pct(95.0),
        min_ns: samples_ns[0],
        max_ns: samples_ns[n - 1],
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Print a section header for a paper figure/table reproduction.
pub fn section(title: &str) {
    println!();
    println!("==== {title} ====");
}

/// Print an aligned table: header row + rows of cells.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Format helper: fixed-precision cell.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let r = bench("noop-ish", 20, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.max_ns);
    }

    #[test]
    fn table_prints_without_panic() {
        print_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }

    #[test]
    fn fixed_precision_format() {
        assert_eq!(f(1.23456, 2), "1.23");
    }
}
