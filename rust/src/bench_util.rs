//! Benchmark harness (criterion substitute, offline build).
//!
//! Provides wall-clock timing loops with warm-up, robust summary
//! statistics, and table/series printers shared by the per-figure
//! bench binaries under `rust/benches/`.

use std::time::Instant;

/// Timing summary of a benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} us/iter  (p50 {:>9.3}, p95 {:>9.3}, n={})",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p95_ns / 1e3,
            self.iters
        )
    }
}

/// Time `f` with warm-up; runs until ~`budget_ms` of samples or
/// `max_iters`, whichever first.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warm-up: a few calls to populate caches/allocators.
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let started = Instant::now();
    let mut samples_ns: Vec<f64> = Vec::new();
    let max_iters = 100_000u64;
    while started.elapsed() < budget && (samples_ns.len() as u64) < max_iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, &mut samples_ns)
}

fn summarize(name: &str, samples_ns: &mut [f64]) -> BenchResult {
    assert!(!samples_ns.is_empty());
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let pct = |p: f64| crate::metrics::percentile_of_sorted(samples_ns, p);
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean_ns: mean,
        p50_ns: pct(50.0),
        p95_ns: pct(95.0),
        min_ns: samples_ns[0],
        max_ns: samples_ns[n - 1],
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write this suite's results into a machine-readable JSON report at
/// `path` (`{"benches":[{suite,name,ns_per_op,p50_ns,p95_ns,iters}]}`).
/// Entries from OTHER suites already present in the file are
/// preserved, so one report accumulates across bench binaries (the CI
/// smoke job runs `fleet` then `perf_hotpath` into the same file).
pub fn write_bench_json_to(path: &str, suite: &str, results: &[BenchResult]) {
    use crate::jsonl::Json;
    let mut entries: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        match crate::jsonl::parse(&text) {
            Ok(doc) => {
                if let Some(arr) = doc.get("benches").and_then(|b| b.as_arr()) {
                    for e in arr {
                        if e.get("suite").and_then(|s| s.as_str()) != Some(suite) {
                            entries.push(e.clone());
                        }
                    }
                }
            }
            Err(e) => eprintln!(
                "warning: existing {path} is unreadable ({e}); \
                 previously accumulated suites will be dropped"
            ),
        }
    }
    for r in results {
        entries.push(Json::obj(vec![
            ("suite", Json::Str(suite.to_string())),
            ("name", Json::Str(r.name.clone())),
            ("ns_per_op", Json::Num(r.mean_ns)),
            ("p50_ns", Json::Num(r.p50_ns)),
            ("p95_ns", Json::Num(r.p95_ns)),
            ("iters", Json::Num(r.iters as f64)),
        ]));
    }
    let doc = Json::obj(vec![("benches", Json::Arr(entries))]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => eprintln!("bench report: {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

/// [`write_bench_json_to`] at `$THROTTLLEM_BENCH_JSON` (default
/// `BENCH_perf.json` in the working directory).
pub fn write_bench_json(suite: &str, results: &[BenchResult]) {
    let path = std::env::var("THROTTLLEM_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_perf.json".to_string());
    write_bench_json_to(&path, suite, results);
}

/// A [`BenchResult`] from a single timed run (fleet-scale scenarios
/// are too slow to repeat; one wall-clock sample is the datum).
pub fn single_run_result(name: &str, elapsed: std::time::Duration) -> BenchResult {
    let ns = elapsed.as_nanos() as f64;
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: ns,
        p50_ns: ns,
        p95_ns: ns,
        min_ns: ns,
        max_ns: ns,
    }
}

/// Print a section header for a paper figure/table reproduction.
pub fn section(title: &str) {
    println!();
    println!("==== {title} ====");
}

/// Print an aligned table: header row + rows of cells.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Format helper: fixed-precision cell.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let r = bench("noop-ish", 20, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.max_ns);
    }

    #[test]
    fn table_prints_without_panic() {
        print_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }

    #[test]
    fn fixed_precision_format() {
        assert_eq!(f(1.23456, 2), "1.23");
    }

    #[test]
    fn bench_json_merges_suites_and_replaces_own() {
        let dir = std::env::temp_dir().join("throttllem_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_perf.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        let r = |name: &str, ns: f64| BenchResult {
            name: name.to_string(),
            iters: 10,
            mean_ns: ns,
            p50_ns: ns,
            p95_ns: ns,
            min_ns: ns,
            max_ns: ns,
        };
        write_bench_json_to(path, "alpha", &[r("a1", 100.0)]);
        write_bench_json_to(path, "beta", &[r("b1", 200.0)]);
        // Re-running a suite replaces its entries, keeps the other's.
        write_bench_json_to(path, "alpha", &[r("a1", 150.0), r("a2", 50.0)]);
        let doc = crate::jsonl::parse(&std::fs::read_to_string(path).unwrap())
            .unwrap();
        let arr = doc.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        let find = |suite: &str, name: &str| {
            arr.iter().find(|e| {
                e.get("suite").and_then(|s| s.as_str()) == Some(suite)
                    && e.get("name").and_then(|s| s.as_str()) == Some(name)
            })
        };
        assert!(find("beta", "b1").is_some());
        let a1 = find("alpha", "a1").unwrap();
        assert_eq!(a1.get("ns_per_op").and_then(|v| v.as_f64()), Some(150.0));
        assert!(find("alpha", "a2").is_some());
        let _ = std::fs::remove_file(path);
    }
}
