//! Benchmark harness (criterion substitute, offline build).
//!
//! Provides wall-clock timing loops with warm-up, robust summary
//! statistics, and table/series printers shared by the per-figure
//! bench binaries under `rust/benches/`, plus:
//!
//!   * [`ScenarioSuite`] — the fleet scenario matrix (steady / burst /
//!     flash / diurnal x router policy) reporting SLO attainment and
//!     J/token per scenario (`cargo bench --bench scenarios`, the CI
//!     scenario jobs, and `tests/fleet_trace_determinism.rs`);
//!   * the perf-regression gate ([`gate_bench_report`]) that diffs a
//!     `BENCH_perf.json` run against the committed
//!     `BENCH_baseline.json` (driven by the `bench_gate` binary in
//!     CI: fail > 25% ns/op regression on tracked hot-path benches,
//!     warn > 10%, the same bands on p50 and doubled bands on p99
//!     when both files carry percentiles, cross-machine ratios
//!     normalized by the [`CALIBRATION_BENCH`] fixed-work loop).

// Reviewed wall-clock/env use: this module's whole purpose is timing
// real executions and reading bench-harness knobs; nothing here feeds
// simulated outcomes (it is outside detlint's r3 scope).
#![allow(clippy::disallowed_methods)]

use std::time::Instant;

use crate::config::ServingConfig;
use crate::coordinator::{
    scenario_params, serve_fleet_plan, FleetPlan, PerfModel, Policy,
    RouterPolicy,
};
use crate::jsonl::Json;
use crate::workload::fleet_trace::{synth_fleet_trace, ScenarioKind};
use crate::workload::LengthPredictor;

/// Timing summary of a benchmarked closure.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub p95_ns: f64,
    pub p99_ns: f64,
    pub min_ns: f64,
    pub max_ns: f64,
}

impl BenchResult {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn mean_ms(&self) -> f64 {
        self.mean_ns / 1e6
    }
}

impl std::fmt::Display for BenchResult {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "{:<40} {:>10.3} us/iter  (p50 {:>9.3}, p95 {:>9.3}, p99 {:>9.3}, n={})",
            self.name,
            self.mean_ns / 1e3,
            self.p50_ns / 1e3,
            self.p95_ns / 1e3,
            self.p99_ns / 1e3,
            self.iters
        )
    }
}

/// Time `f` with warm-up; runs until ~`budget_ms` of samples or
/// `max_iters`, whichever first.
pub fn bench<F: FnMut()>(name: &str, budget_ms: u64, mut f: F) -> BenchResult {
    // Warm-up: a few calls to populate caches/allocators.
    for _ in 0..3 {
        f();
    }
    let budget = std::time::Duration::from_millis(budget_ms);
    let started = Instant::now();
    let mut samples_ns: Vec<f64> = Vec::new();
    let max_iters = 100_000u64;
    while started.elapsed() < budget && (samples_ns.len() as u64) < max_iters {
        let t0 = Instant::now();
        f();
        samples_ns.push(t0.elapsed().as_nanos() as f64);
    }
    summarize(name, &mut samples_ns)
}

fn summarize(name: &str, samples_ns: &mut [f64]) -> BenchResult {
    assert!(!samples_ns.is_empty());
    samples_ns.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples_ns.len();
    let mean = samples_ns.iter().sum::<f64>() / n as f64;
    let pct = |p: f64| crate::metrics::percentile_of_sorted(samples_ns, p);
    BenchResult {
        name: name.to_string(),
        iters: n as u64,
        mean_ns: mean,
        p50_ns: pct(50.0),
        p95_ns: pct(95.0),
        p99_ns: pct(99.0),
        min_ns: samples_ns[0],
        max_ns: samples_ns[n - 1],
    }
}

/// Prevent the optimizer from deleting a computed value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

/// Write this suite's results into a machine-readable JSON report at
/// `path`
/// (`{"benches":[{suite,name,ns_per_op,p50_ns,p95_ns,p99_ns,iters}]}`).
/// Entries from OTHER suites already present in the file are
/// preserved, so one report accumulates across bench binaries (the CI
/// smoke job runs `fleet` then `perf_hotpath` into the same file).
pub fn write_bench_json_to(path: &str, suite: &str, results: &[BenchResult]) {
    let mut entries: Vec<Json> = Vec::new();
    if let Ok(text) = std::fs::read_to_string(path) {
        match crate::jsonl::parse(&text) {
            Ok(doc) => {
                if let Some(arr) = doc.get("benches").and_then(|b| b.as_arr()) {
                    for e in arr {
                        if e.get("suite").and_then(|s| s.as_str()) != Some(suite) {
                            entries.push(e.clone());
                        }
                    }
                }
            }
            Err(e) => eprintln!(
                "warning: existing {path} is unreadable ({e}); \
                 previously accumulated suites will be dropped"
            ),
        }
    }
    for r in results {
        entries.push(Json::obj(vec![
            ("suite", Json::Str(suite.to_string())),
            ("name", Json::Str(r.name.clone())),
            ("ns_per_op", Json::Num(r.mean_ns)),
            ("p50_ns", Json::Num(r.p50_ns)),
            ("p95_ns", Json::Num(r.p95_ns)),
            ("p99_ns", Json::Num(r.p99_ns)),
            ("iters", Json::Num(r.iters as f64)),
        ]));
    }
    let doc = Json::obj(vec![("benches", Json::Arr(entries))]);
    match std::fs::write(path, doc.to_string()) {
        Ok(()) => eprintln!("bench report: {path}"),
        Err(e) => eprintln!("warning: could not write {path}: {e}"),
    }
}

/// [`write_bench_json_to`] at `$THROTTLLEM_BENCH_JSON` (default
/// `BENCH_perf.json` in the working directory).
pub fn write_bench_json(suite: &str, results: &[BenchResult]) {
    let path = std::env::var("THROTTLLEM_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_perf.json".to_string());
    write_bench_json_to(&path, suite, results);
}

/// A [`BenchResult`] from a single timed run (fleet-scale scenarios
/// are too slow to repeat; one wall-clock sample is the datum).
pub fn single_run_result(name: &str, elapsed: std::time::Duration) -> BenchResult {
    let ns = elapsed.as_nanos() as f64;
    BenchResult {
        name: name.to_string(),
        iters: 1,
        mean_ns: ns,
        p50_ns: ns,
        p95_ns: ns,
        p99_ns: ns,
        min_ns: ns,
        max_ns: ns,
    }
}

/// Print a section header for a paper figure/table reproduction.
pub fn section(title: &str) {
    println!();
    println!("==== {title} ====");
}

/// Print an aligned table: header row + rows of cells.
pub fn print_table(headers: &[&str], rows: &[Vec<String>]) {
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        cells
            .iter()
            .enumerate()
            .map(|(i, c)| format!("{:>w$}", c, w = widths.get(i).copied().unwrap_or(8)))
            .collect::<Vec<_>>()
            .join("  ")
    };
    let head: Vec<String> = headers.iter().map(|s| s.to_string()).collect();
    println!("{}", fmt_row(&head));
    println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * widths.len()));
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Format helper: fixed-precision cell.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

// ---- fleet scenario suite -------------------------------------------

/// One (scenario, router) cell of the scenario matrix.
#[derive(Debug, Clone)]
pub struct ScenarioRun {
    pub scenario: String,
    pub router: RouterPolicy,
    pub requests: usize,
    pub completed: u64,
    pub dropped: u64,
    pub rerouted: u64,
    /// E2E SLO attainment (0 when nothing completed).
    pub e2e_attainment: f64,
    pub tbt_attainment: f64,
    pub energy_kj: f64,
    /// Joules per generated token (lower is better; infinity when no
    /// tokens were produced).
    pub j_per_token: f64,
    /// Serve-loop wall clock (feeds `BENCH_perf.json`, suite
    /// `scenarios`).
    pub wall: BenchResult,
}

/// The fleet scenario matrix: each scenario's shared arrival stream is
/// generated ONCE and served under every router policy, so router
/// comparisons are on identical traces.
#[derive(Debug, Clone)]
pub struct ScenarioSuite {
    pub duration_s: f64,
    /// Trace peak as a fraction of the fleet's aggregate rated load.
    pub utilization: f64,
    pub seed: u64,
    pub scenarios: Vec<ScenarioKind>,
    pub routers: Vec<RouterPolicy>,
}

impl ScenarioSuite {
    /// CI smoke configuration: short traces, the round-robin vs
    /// projected-headroom comparison the acceptance gate checks.
    pub fn smoke(seed: u64) -> Self {
        Self {
            duration_s: 120.0,
            utilization: 0.6,
            seed,
            scenarios: vec![
                ScenarioKind::Steady,
                ScenarioKind::Burst,
                ScenarioKind::Flash,
            ],
            routers: vec![RouterPolicy::RoundRobin, RouterPolicy::ProjectedHeadroom],
        }
    }

    /// Full matrix: every scenario under every router policy.
    pub fn full(duration_s: f64, seed: u64) -> Self {
        Self {
            duration_s,
            utilization: 0.6,
            seed,
            scenarios: ScenarioKind::all().to_vec(),
            routers: vec![
                RouterPolicy::RoundRobin,
                RouterPolicy::LeastLoaded,
                RouterPolicy::ProjectedHeadroom,
            ],
        }
    }

    /// Run the matrix on `base_plan` (its router field is overridden
    /// per cell).
    pub fn run(
        &self,
        cfg: &ServingConfig,
        policy: Policy,
        model: &PerfModel,
        base_plan: &FleetPlan,
    ) -> Vec<ScenarioRun> {
        let mut out = Vec::new();
        for &kind in &self.scenarios {
            let params = scenario_params(
                base_plan,
                kind,
                self.duration_s,
                self.utilization,
                self.seed,
            );
            let mut reqs = synth_fleet_trace(&params);
            LengthPredictor::oracle().apply(&mut reqs, cfg.max_tokens);
            for &router in &self.routers {
                let plan = FleetPlan {
                    router,
                    ..base_plan.clone()
                };
                let t0 = Instant::now();
                let fo = serve_fleet_plan(cfg, policy, model, &reqs, &plan);
                let wall = single_run_result(
                    &format!("scenario {} ({})", kind.name(), router.name()),
                    t0.elapsed(),
                );
                let s = &fo.total.stats;
                let att = |x: f64| if x.is_nan() { 0.0 } else { x };
                out.push(ScenarioRun {
                    scenario: kind.name().to_string(),
                    router,
                    requests: reqs.len(),
                    completed: s.completed,
                    dropped: s.dropped,
                    rerouted: fo.rerouted,
                    e2e_attainment: att(s.e2e_slo_attainment(cfg.slo.e2e_p99)),
                    tbt_attainment: att(s.tbt_slo_attainment(cfg.slo.tbt_avg)),
                    energy_kj: s.total_energy_j / 1e3,
                    j_per_token: if s.total_tokens > 0 {
                        s.total_energy_j / s.total_tokens as f64
                    } else {
                        f64::INFINITY
                    },
                    wall,
                });
            }
        }
        out
    }
}

/// Print the matrix as an aligned table.
pub fn print_scenario_table(runs: &[ScenarioRun]) {
    let rows: Vec<Vec<String>> = runs
        .iter()
        .map(|r| {
            vec![
                r.scenario.clone(),
                r.router.name().to_string(),
                format!("{}", r.requests),
                format!("{}", r.completed),
                format!("{}", r.dropped),
                format!("{}", r.rerouted),
                format!("{:.1}", r.e2e_attainment * 100.0),
                format!("{:.1}", r.tbt_attainment * 100.0),
                format!("{:.1}", r.energy_kj),
                format!("{:.3}", r.j_per_token),
            ]
        })
        .collect();
    print_table(
        &[
            "scenario", "router", "requests", "completed", "dropped", "rerouted",
            "E2Eatt[%]", "TBTatt[%]", "energy[kJ]", "J/token",
        ],
        &rows,
    );
}

/// Scenarios where projected-headroom fails to match-or-beat
/// round-robin on E2E attainment OR J/token (the acceptance bar:
/// `ph >= rr` on at least one of the two, per scenario).  Empty means
/// the suite passes.  A 1-percentage-point attainment / 2% J/token
/// measurement-noise band keeps statistical ties from flaking the
/// gate: a real routing regression moves both metrics far past it.
pub fn headroom_regressions(runs: &[ScenarioRun]) -> Vec<String> {
    let mut bad = Vec::new();
    for rr in runs.iter().filter(|r| r.router == RouterPolicy::RoundRobin) {
        let Some(ph) = runs.iter().find(|r| {
            r.router == RouterPolicy::ProjectedHeadroom && r.scenario == rr.scenario
        }) else {
            continue;
        };
        let att_ok = ph.e2e_attainment >= rr.e2e_attainment - 0.01;
        let jpt_ok = ph.j_per_token <= rr.j_per_token * 1.02 + 1e-12;
        if !(att_ok || jpt_ok) {
            bad.push(format!(
                "{}: headroom att {:.1}% vs rr {:.1}%, J/token {:.3} vs {:.3}",
                rr.scenario,
                ph.e2e_attainment * 100.0,
                rr.e2e_attainment * 100.0,
                ph.j_per_token,
                rr.j_per_token
            ));
        }
    }
    bad
}

// ---- perf-regression gate -------------------------------------------

/// The fixed-work bench whose ns/op measures machine speed; the gate
/// normalizes cross-machine ns/op ratios by its ratio.
pub const CALIBRATION_BENCH: &str = "calibration fixed-work";

/// The suite whose benches the gate enforces (micro-benchmarks with
/// averaged samples; the single-run `fleet`/`scenarios` wall clocks
/// are informational only).
pub const TRACKED_SUITE: &str = "perf_hotpath";

/// Measure the calibration workload (FNV over 4096 words) — emitted
/// into every `perf_hotpath` report so the gate can normalize.
pub fn calibration_result() -> BenchResult {
    let mut x = 0u64;
    bench(CALIBRATION_BENCH, 200, || {
        let mut h = 0xcbf29ce484222325u64;
        for i in 0u64..4096 {
            h ^= i.wrapping_add(x);
            h = h.wrapping_mul(0x100000001b3);
        }
        x = black_box(h);
    })
}

/// Gate thresholds (percent regression over baseline, after
/// calibration normalization).
#[derive(Debug, Clone, Copy)]
pub struct GateConfig {
    pub fail_pct: f64,
    pub warn_pct: f64,
}

impl Default for GateConfig {
    fn default() -> Self {
        Self {
            fail_pct: 25.0,
            warn_pct: 10.0,
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateLevel {
    Ok,
    Warn,
    Fail,
    /// Tracked in the baseline but absent from the current report
    /// (renamed or dropped bench) — warn, never silently pass.
    MissingCurrent,
}

/// Which statistic of a tracked bench a [`GateFinding`] judges.  The
/// tail gate gets doubled thresholds: p99 is the noisiest statistic a
/// CI runner produces, and a real regression that ONLY moves the tail
/// past 2x the warn band is still caught.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GateMetric {
    MeanNs,
    P50Ns,
    P99Ns,
}

impl GateMetric {
    pub fn name(&self) -> &'static str {
        match self {
            GateMetric::MeanNs => "ns/op",
            GateMetric::P50Ns => "p50",
            GateMetric::P99Ns => "p99",
        }
    }

    /// Threshold multiplier over [`GateConfig`] percentages.
    fn slack(&self) -> f64 {
        match self {
            GateMetric::MeanNs | GateMetric::P50Ns => 1.0,
            GateMetric::P99Ns => 2.0,
        }
    }
}

/// One tracked bench statistic's verdict.
#[derive(Debug, Clone)]
pub struct GateFinding {
    pub name: String,
    pub metric: GateMetric,
    pub base_ns: f64,
    pub cur_ns: f64,
    /// Normalized cur/base ns ratio (1.0 = unchanged; NaN when
    /// missing).
    pub ratio: f64,
    pub level: GateLevel,
}

/// Full gate verdict for one baseline/current pair.
#[derive(Debug, Clone)]
pub struct GateReport {
    pub findings: Vec<GateFinding>,
    /// cur/base calibration ratio the bench ratios were divided by
    /// (None: calibration bench missing from either file, raw ratios
    /// used).
    pub calibration: Option<f64>,
    /// The baseline declares itself a bootstrap placeholder (padded
    /// values committed before the first measured refresh).
    pub bootstrap: bool,
    /// Tracked benches whose p50/p99 could not be gated because one
    /// side predates the percentile fields — counted as warnings (the
    /// gate warns, never fails, on baselines lacking percentiles).
    pub missing_percentiles: usize,
}

impl GateReport {
    pub fn failed(&self) -> bool {
        self.findings.iter().any(|f| f.level == GateLevel::Fail)
    }

    pub fn warnings(&self) -> usize {
        self.findings
            .iter()
            .filter(|f| matches!(f.level, GateLevel::Warn | GateLevel::MissingCurrent))
            .count()
            + self.missing_percentiles
    }
}

struct BenchEntry {
    suite: String,
    name: String,
    ns: f64,
    p50_ns: Option<f64>,
    p99_ns: Option<f64>,
}

fn bench_entries(doc: &Json) -> Vec<BenchEntry> {
    let mut out = Vec::new();
    if let Some(arr) = doc.get("benches").and_then(|b| b.as_arr()) {
        for e in arr {
            if let (Some(suite), Some(name), Some(ns)) = (
                e.get("suite").and_then(|s| s.as_str()),
                e.get("name").and_then(|s| s.as_str()),
                e.get("ns_per_op").and_then(|v| v.as_f64()),
            ) {
                out.push(BenchEntry {
                    suite: suite.to_string(),
                    name: name.to_string(),
                    ns,
                    p50_ns: e.get("p50_ns").and_then(|v| v.as_f64()),
                    p99_ns: e.get("p99_ns").and_then(|v| v.as_f64()),
                });
            }
        }
    }
    out
}

fn find_entry<'a>(
    entries: &'a [BenchEntry],
    suite: &str,
    name: &str,
) -> Option<&'a BenchEntry> {
    entries.iter().find(|e| e.suite == suite && e.name == name)
}

/// Diff `current` against `baseline` (both parsed `BENCH_perf.json`
/// documents): every tracked hot-path bench in the baseline must stay
/// within `cfg.fail_pct` of its baseline ns/op — and of its baseline
/// p50/p99 when both sides carry percentile fields (p99 at doubled
/// thresholds; pre-percentile baselines WARN, never fail) — with
/// ratios normalized by the [`CALIBRATION_BENCH`] ratio when both
/// files carry it.
pub fn gate_bench_report(
    baseline: &Json,
    current: &Json,
    cfg: &GateConfig,
) -> anyhow::Result<GateReport> {
    let base = bench_entries(baseline);
    let cur = bench_entries(current);
    anyhow::ensure!(!base.is_empty(), "baseline has no bench entries");
    anyhow::ensure!(!cur.is_empty(), "current report has no bench entries");
    let calibration = match (
        find_entry(&base, TRACKED_SUITE, CALIBRATION_BENCH),
        find_entry(&cur, TRACKED_SUITE, CALIBRATION_BENCH),
    ) {
        (Some(b), Some(c)) if b.ns > 0.0 && c.ns > 0.0 => Some(c.ns / b.ns),
        _ => None,
    };
    let bootstrap = baseline
        .get("meta")
        .and_then(|m| m.get("mode"))
        .and_then(|m| m.as_str())
        == Some("bootstrap");
    let mut findings = Vec::new();
    let mut missing_percentiles = 0usize;
    let judge = |name: &str, metric: GateMetric, base_ns: f64, cur_ns: f64| {
        let ratio = (cur_ns / base_ns) / calibration.unwrap_or(1.0);
        let level = if ratio > 1.0 + metric.slack() * cfg.fail_pct / 100.0 {
            GateLevel::Fail
        } else if ratio > 1.0 + metric.slack() * cfg.warn_pct / 100.0 {
            GateLevel::Warn
        } else {
            GateLevel::Ok
        };
        GateFinding {
            name: name.to_string(),
            metric,
            base_ns,
            cur_ns,
            ratio,
            level,
        }
    };
    for b in &base {
        if b.suite != TRACKED_SUITE || b.name == CALIBRATION_BENCH || b.ns <= 0.0 {
            continue;
        }
        match find_entry(&cur, &b.suite, &b.name) {
            None => findings.push(GateFinding {
                name: b.name.clone(),
                metric: GateMetric::MeanNs,
                base_ns: b.ns,
                cur_ns: f64::NAN,
                ratio: f64::NAN,
                level: GateLevel::MissingCurrent,
            }),
            Some(c) => {
                findings.push(judge(&b.name, GateMetric::MeanNs, b.ns, c.ns));
                let pcts = [
                    (GateMetric::P50Ns, b.p50_ns, c.p50_ns),
                    (GateMetric::P99Ns, b.p99_ns, c.p99_ns),
                ];
                for (metric, base_p, cur_p) in pcts {
                    match (base_p, cur_p) {
                        (Some(bp), Some(cp)) if bp > 0.0 => {
                            findings.push(judge(&b.name, metric, bp, cp));
                        }
                        _ => missing_percentiles += 1,
                    }
                }
            }
        }
    }
    anyhow::ensure!(
        !findings.is_empty(),
        "baseline tracks no {TRACKED_SUITE} benches"
    );
    Ok(GateReport {
        findings,
        calibration,
        bootstrap,
        missing_percentiles,
    })
}

/// Clone a report document with one tracked bench slowed by `factor`
/// (the gate's self-test injects a >25% slowdown and asserts the gate
/// trips — run by CI on every build, so the failure path is
/// demonstrated continuously, not just once in a PR description).
pub fn inject_slowdown(doc: &Json, factor: f64) -> anyhow::Result<Json> {
    let arr = doc
        .get("benches")
        .and_then(|b| b.as_arr())
        .ok_or_else(|| anyhow::anyhow!("report has no benches array"))?;
    let mut injected = false;
    let mut out = Vec::with_capacity(arr.len());
    for e in arr {
        let is_tracked = e.get("suite").and_then(|s| s.as_str())
            == Some(TRACKED_SUITE)
            && e.get("name").and_then(|s| s.as_str()) != Some(CALIBRATION_BENCH);
        if !injected && is_tracked {
            if let (Json::Obj(m), Some(ns)) =
                (e, e.get("ns_per_op").and_then(|v| v.as_f64()))
            {
                let mut m = m.clone();
                m.insert("ns_per_op".to_string(), Json::Num(ns * factor));
                out.push(Json::Obj(m));
                injected = true;
                continue;
            }
        }
        out.push(e.clone());
    }
    anyhow::ensure!(injected, "no tracked bench to inject a slowdown into");
    let mut root = match doc {
        Json::Obj(m) => m.clone(),
        _ => anyhow::bail!("report is not a JSON object"),
    };
    root.insert("benches".to_string(), Json::Arr(out));
    Ok(Json::Obj(root))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_sane_summary() {
        let r = bench("noop-ish", 20, || {
            black_box((0..100).sum::<u64>());
        });
        assert!(r.iters > 10);
        assert!(r.mean_ns > 0.0);
        assert!(r.min_ns <= r.p50_ns && r.p50_ns <= r.max_ns);
    }

    #[test]
    fn table_prints_without_panic() {
        print_table(
            &["a", "bb"],
            &[vec!["1".into(), "2".into()], vec!["333".into(), "4".into()]],
        );
    }

    #[test]
    fn fixed_precision_format() {
        assert_eq!(f(1.23456, 2), "1.23");
    }

    #[test]
    fn bench_json_merges_suites_and_replaces_own() {
        let dir = std::env::temp_dir().join("throttllem_bench_json_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_perf.json");
        let path = path.to_str().unwrap();
        let _ = std::fs::remove_file(path);
        let r = |name: &str, ns: f64| BenchResult {
            name: name.to_string(),
            iters: 10,
            mean_ns: ns,
            p50_ns: ns,
            p95_ns: ns,
            p99_ns: ns,
            min_ns: ns,
            max_ns: ns,
        };
        write_bench_json_to(path, "alpha", &[r("a1", 100.0)]);
        write_bench_json_to(path, "beta", &[r("b1", 200.0)]);
        // Re-running a suite replaces its entries, keeps the other's.
        write_bench_json_to(path, "alpha", &[r("a1", 150.0), r("a2", 50.0)]);
        let doc = crate::jsonl::parse(&std::fs::read_to_string(path).unwrap())
            .unwrap();
        let arr = doc.get("benches").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        let find = |suite: &str, name: &str| {
            arr.iter().find(|e| {
                e.get("suite").and_then(|s| s.as_str()) == Some(suite)
                    && e.get("name").and_then(|s| s.as_str()) == Some(name)
            })
        };
        assert!(find("beta", "b1").is_some());
        let a1 = find("alpha", "a1").unwrap();
        assert_eq!(a1.get("ns_per_op").and_then(|v| v.as_f64()), Some(150.0));
        assert!(find("alpha", "a2").is_some());
        let _ = std::fs::remove_file(path);
    }

    /// Test report with percentile fields derived from ns (p50 = ns,
    /// p99 = 2ns, both scaling with the mean).
    fn report(entries: &[(&str, &str, f64)], meta_mode: Option<&str>) -> Json {
        let benches: Vec<Json> = entries
            .iter()
            .map(|(s, n, ns)| {
                Json::obj(vec![
                    ("suite", Json::Str(s.to_string())),
                    ("name", Json::Str(n.to_string())),
                    ("ns_per_op", Json::Num(*ns)),
                    ("p50_ns", Json::Num(*ns)),
                    ("p99_ns", Json::Num(2.0 * ns)),
                ])
            })
            .collect();
        let mut pairs = vec![("benches", Json::Arr(benches))];
        if let Some(m) = meta_mode {
            pairs.push(("meta", Json::obj(vec![("mode", Json::Str(m.to_string()))])));
        }
        Json::obj(pairs)
    }

    /// Pre-percentile report format (ns/op only).
    fn legacy_report(entries: &[(&str, &str, f64)]) -> Json {
        let benches: Vec<Json> = entries
            .iter()
            .map(|(s, n, ns)| {
                Json::obj(vec![
                    ("suite", Json::Str(s.to_string())),
                    ("name", Json::Str(n.to_string())),
                    ("ns_per_op", Json::Num(*ns)),
                ])
            })
            .collect();
        Json::obj(vec![("benches", Json::Arr(benches))])
    }

    #[test]
    fn gate_passes_on_identical_reports() {
        let doc = report(
            &[
                (TRACKED_SUITE, CALIBRATION_BENCH, 1000.0),
                (TRACKED_SUITE, "admission", 5000.0),
                ("fleet", "serve x4", 9e9), // untracked, ignored
            ],
            None,
        );
        let r = gate_bench_report(&doc, &doc, &GateConfig::default()).unwrap();
        assert!(!r.failed());
        assert_eq!(r.warnings(), 0);
        // One tracked bench x {ns/op, p50, p99}.
        assert_eq!(r.findings.len(), 3);
        assert!(r.findings.iter().all(|f| (f.ratio - 1.0).abs() < 1e-12));
        assert_eq!(r.calibration, Some(1.0));
        assert!(!r.bootstrap);
        assert_eq!(r.missing_percentiles, 0);
    }

    #[test]
    fn gate_warns_not_fails_on_baseline_lacking_percentiles() {
        // A pre-percentile baseline still gates ns/op, and the absent
        // p50/p99 are surfaced as warnings, never failures.
        let base = legacy_report(&[
            (TRACKED_SUITE, CALIBRATION_BENCH, 1000.0),
            (TRACKED_SUITE, "admission", 5000.0),
        ]);
        let cur = report(
            &[
                (TRACKED_SUITE, CALIBRATION_BENCH, 1000.0),
                (TRACKED_SUITE, "admission", 5000.0),
            ],
            None,
        );
        let r = gate_bench_report(&base, &cur, &GateConfig::default()).unwrap();
        assert!(!r.failed());
        assert_eq!(r.missing_percentiles, 2, "p50 and p99 ungateable");
        assert_eq!(r.warnings(), 2);
        assert_eq!(r.findings.len(), 1, "only ns/op judged");
        assert_eq!(r.findings[0].metric, GateMetric::MeanNs);
    }

    #[test]
    fn gate_fails_on_p50_regression_and_tail_gets_slack() {
        let mk = |p50: f64, p99: f64| {
            Json::obj(vec![(
                "benches",
                Json::Arr(vec![Json::obj(vec![
                    ("suite", Json::Str(TRACKED_SUITE.to_string())),
                    ("name", Json::Str("admission".to_string())),
                    ("ns_per_op", Json::Num(5000.0)),
                    ("p50_ns", Json::Num(p50)),
                    ("p99_ns", Json::Num(p99)),
                ])]),
            )])
        };
        let base = mk(4000.0, 9000.0);
        // p50 +30% with the mean unchanged: the median gate trips.
        let r = gate_bench_report(&base, &mk(5200.0, 9000.0), &GateConfig::default())
            .unwrap();
        assert!(r.failed(), "p50 regression must fail: {:?}", r.findings);
        assert!(r
            .findings
            .iter()
            .any(|f| f.metric == GateMetric::P50Ns && f.level == GateLevel::Fail));
        // p99 +30%: inside the doubled tail band — warn territory only.
        let r = gate_bench_report(&base, &mk(4000.0, 11700.0), &GateConfig::default())
            .unwrap();
        assert!(!r.failed(), "tail noise within 2x band: {:?}", r.findings);
        assert!(r
            .findings
            .iter()
            .any(|f| f.metric == GateMetric::P99Ns && f.level == GateLevel::Warn));
        // p99 +60%: past even the doubled band — a real tail regression.
        let r = gate_bench_report(&base, &mk(4000.0, 14400.0), &GateConfig::default())
            .unwrap();
        assert!(r.failed(), "p99 blowup must fail: {:?}", r.findings);
    }

    #[test]
    fn gate_fails_on_injected_25pct_slowdown() {
        // The acceptance demonstration: a >25% slowdown of a tracked
        // hot-path bench MUST trip the gate (CI re-runs this through
        // `bench_gate selftest` on the real report every build).
        let base = report(
            &[
                (TRACKED_SUITE, CALIBRATION_BENCH, 1000.0),
                (TRACKED_SUITE, "admission", 5000.0),
                (TRACKED_SUITE, "throttle", 3000.0),
            ],
            None,
        );
        let slowed = inject_slowdown(&base, 1.30).unwrap();
        let r = gate_bench_report(&base, &slowed, &GateConfig::default()).unwrap();
        assert!(r.failed(), "30% slowdown must fail: {:?}", r.findings);
        // 15%: warn, not fail.
        let warned = inject_slowdown(&base, 1.15).unwrap();
        let r = gate_bench_report(&base, &warned, &GateConfig::default()).unwrap();
        assert!(!r.failed());
        assert_eq!(r.warnings(), 1);
        // 5%: clean.
        let ok = inject_slowdown(&base, 1.05).unwrap();
        let r = gate_bench_report(&base, &ok, &GateConfig::default()).unwrap();
        assert!(!r.failed());
        assert_eq!(r.warnings(), 0);
    }

    #[test]
    fn gate_normalizes_by_calibration_ratio() {
        let base = report(
            &[
                (TRACKED_SUITE, CALIBRATION_BENCH, 1000.0),
                (TRACKED_SUITE, "admission", 5000.0),
            ],
            Some("bootstrap"),
        );
        // A uniformly 2x slower machine: every bench doubles, the
        // calibration ratio absorbs it.
        let cur = report(
            &[
                (TRACKED_SUITE, CALIBRATION_BENCH, 2000.0),
                (TRACKED_SUITE, "admission", 10000.0),
            ],
            None,
        );
        let r = gate_bench_report(&base, &cur, &GateConfig::default()).unwrap();
        assert!(!r.failed());
        assert_eq!(r.calibration, Some(2.0));
        assert!((r.findings[0].ratio - 1.0).abs() < 1e-12);
        assert!(r.bootstrap);
        // Without the calibration bench the raw 2x ratio fails.
        let base_nocal = report(&[(TRACKED_SUITE, "admission", 5000.0)], None);
        let cur_nocal = report(&[(TRACKED_SUITE, "admission", 10000.0)], None);
        let r = gate_bench_report(&base_nocal, &cur_nocal, &GateConfig::default())
            .unwrap();
        assert!(r.failed());
        assert_eq!(r.calibration, None);
    }

    #[test]
    fn gate_warns_on_missing_tracked_bench() {
        let base = report(
            &[
                (TRACKED_SUITE, "admission", 5000.0),
                (TRACKED_SUITE, "renamed-away", 2000.0),
            ],
            None,
        );
        let cur = report(&[(TRACKED_SUITE, "admission", 5000.0)], None);
        let r = gate_bench_report(&base, &cur, &GateConfig::default()).unwrap();
        assert!(!r.failed());
        assert_eq!(r.warnings(), 1);
        assert!(r
            .findings
            .iter()
            .any(|f| f.level == GateLevel::MissingCurrent));
        // Empty inputs are an error, not a silent pass.
        let empty = report(&[], None);
        assert!(gate_bench_report(&empty, &cur, &GateConfig::default()).is_err());
        assert!(gate_bench_report(&base, &empty, &GateConfig::default()).is_err());
        // A baseline tracking nothing is an error too.
        let untracked = report(&[("fleet", "serve x4", 1.0)], None);
        assert!(gate_bench_report(&untracked, &cur, &GateConfig::default()).is_err());
    }
}
