//! `bench_gate` — the CI perf-regression comparator.
//!
//! Diffs a freshly produced `BENCH_perf.json` against the committed
//! `BENCH_baseline.json`: tracked hot-path benches (suite
//! `perf_hotpath`) must stay within 25% of their baseline ns/op (warn
//! at 10%) — and of their baseline p50/p99 when both files carry the
//! percentile fields (p99 at doubled thresholds; baselines lacking
//! percentiles warn, never fail) — with cross-machine speed
//! differences normalized by the `calibration fixed-work` bench's
//! ratio.
//!
//! Subcommands:
//!   check     — gate the current report against the baseline
//!               (non-zero exit on any >fail-pct regression)
//!   promote   — refresh the baseline from a measured report
//!               (the one-command baseline refresh; see README)
//!   selftest  — prove the gate trips: clone the current report as its
//!               own baseline, inject a 30% slowdown into one tracked
//!               bench, and assert `check` fails on it (and passes on
//!               the unmodified clone).  CI runs this on every build,
//!               so the failure path is demonstrated continuously.
//!
//! Usage:
//!   bench_gate check   [--baseline BENCH_baseline.json] [--current BENCH_perf.json]
//!                      [--fail-pct 25] [--warn-pct 10]
//!   bench_gate promote [--current BENCH_perf.json] [--out BENCH_baseline.json]
//!   bench_gate selftest [--current BENCH_perf.json]

use throttllem::bench_util::{
    gate_bench_report, inject_slowdown, GateConfig, GateLevel, GateMetric, GateReport,
};
use throttllem::cli::Args;
use throttllem::jsonl::{self, Json};

const USAGE: &str = "bench_gate <check|promote|selftest> [--options]
  check:    --baseline <file> --current <file> [--fail-pct 25] [--warn-pct 10]
  promote:  --current <file> --out <file>
  selftest: --current <file>";

fn main() {
    match run() {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("bench_gate: error: {e:#}");
            std::process::exit(2);
        }
    }
}

fn run() -> anyhow::Result<i32> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("check") => cmd_check(&args),
        Some("promote") => cmd_promote(&args),
        Some("selftest") => cmd_selftest(&args),
        Some(other) => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(0)
        }
    }
}

fn load(path: &str) -> anyhow::Result<Json> {
    let text = std::fs::read_to_string(path)
        .map_err(|e| anyhow::anyhow!("{path}: {e}"))?;
    jsonl::parse(&text).map_err(|e| anyhow::anyhow!("{path}: {e:#}"))
}

fn print_report(r: &GateReport, cfg: &GateConfig) {
    match r.calibration {
        Some(c) => println!(
            "calibration ratio (current/baseline machine speed): {c:.3}"
        ),
        None => println!(
            "calibration bench missing from one side: raw ns/op ratios \
             (cross-machine noise NOT normalized)"
        ),
    }
    if r.bootstrap {
        println!(
            "note: baseline is a BOOTSTRAP placeholder (padded values); \
             refresh it from a measured run: see README \"Refreshing the \
             perf baseline\""
        );
    }
    if r.missing_percentiles > 0 {
        println!(
            "note: {} p50/p99 statistics ungated (one side predates \
             percentile fields; re-bless the baseline from a measured \
             run to enable them) — counted as warnings",
            r.missing_percentiles
        );
    }
    for f in &r.findings {
        let tag = match f.level {
            GateLevel::Ok => "ok  ",
            GateLevel::Warn => "WARN",
            GateLevel::Fail => "FAIL",
            GateLevel::MissingCurrent => "GONE",
        };
        if f.level == GateLevel::MissingCurrent {
            println!(
                "[{tag}] {:<44} baseline {:>12.1} ns/op, missing from current report",
                f.name, f.base_ns
            );
        } else {
            // p99 gets doubled thresholds (tail noise); the printed
            // bands reflect the metric actually judged.
            let slack = if f.metric == GateMetric::P99Ns {
                2.0
            } else {
                1.0
            };
            println!(
                "[{tag}] {:<44} {:>5} {:>12.1} -> {:>12.1} ns  (x{:.3}, fail >x{:.2}, warn >x{:.2})",
                f.name,
                f.metric.name(),
                f.base_ns,
                f.cur_ns,
                f.ratio,
                1.0 + slack * cfg.fail_pct / 100.0,
                1.0 + slack * cfg.warn_pct / 100.0
            );
        }
    }
}

fn cmd_check(args: &Args) -> anyhow::Result<i32> {
    let baseline = load(args.get_or("baseline", "BENCH_baseline.json"))?;
    let current = load(args.get_or("current", "BENCH_perf.json"))?;
    let cfg = GateConfig {
        fail_pct: args.get_f64("fail-pct", 25.0)?,
        warn_pct: args.get_f64("warn-pct", 10.0)?,
    };
    let report = gate_bench_report(&baseline, &current, &cfg)?;
    print_report(&report, &cfg);
    if report.failed() {
        println!(
            "bench gate: FAILED — hot-path regression above {}% \
             (refresh the baseline only for intentional changes)",
            cfg.fail_pct
        );
        Ok(1)
    } else {
        println!(
            "bench gate: passed ({} tracked, {} warnings)",
            report.findings.len(),
            report.warnings()
        );
        Ok(0)
    }
}

fn cmd_promote(args: &Args) -> anyhow::Result<i32> {
    let current_path = args.get_or("current", "BENCH_perf.json");
    let out_path = args.get_or("out", "BENCH_baseline.json");
    let current = load(current_path)?;
    let benches = current
        .get("benches")
        .cloned()
        .ok_or_else(|| anyhow::anyhow!("{current_path}: no benches array"))?;
    let doc = Json::obj(vec![
        ("benches", benches),
        (
            "meta",
            Json::obj(vec![
                ("mode", Json::Str("measured".to_string())),
                ("source", Json::Str(current_path.to_string())),
            ]),
        ),
    ]);
    std::fs::write(out_path, doc.to_string())
        .map_err(|e| anyhow::anyhow!("{out_path}: {e}"))?;
    println!("baseline refreshed: {current_path} -> {out_path}");
    Ok(0)
}

fn cmd_selftest(args: &Args) -> anyhow::Result<i32> {
    let current = load(args.get_or("current", "BENCH_perf.json"))?;
    let cfg = GateConfig::default();
    // 1. A report gates cleanly against itself.
    let clean = gate_bench_report(&current, &current, &cfg)?;
    anyhow::ensure!(
        !clean.failed() && clean.warnings() == 0,
        "selftest: report does not gate cleanly against itself"
    );
    // 2. A 30% slowdown of one tracked bench MUST trip the gate.
    let slowed = inject_slowdown(&current, 1.30)?;
    let tripped = gate_bench_report(&current, &slowed, &cfg)?;
    anyhow::ensure!(
        tripped.failed(),
        "selftest: injected 30% slowdown did not trip the gate"
    );
    // 3. A 15% slowdown warns without failing.
    let warned = gate_bench_report(&current, &inject_slowdown(&current, 1.15)?, &cfg)?;
    anyhow::ensure!(
        !warned.failed() && warned.warnings() >= 1,
        "selftest: 15% slowdown should warn, not fail"
    );
    println!(
        "bench gate selftest: ok ({} tracked benches; injected 30% slowdown \
         trips, 15% warns)",
        clean.findings.len()
    );
    Ok(0)
}
