//! `detlint` — determinism & hot-path static analysis for this repo.
//!
//! Walks `rust/src`, `rust/tests`, `rust/benches`, and `examples` and
//! enforces the five source-level determinism rules (see README
//! "Static analysis" for the catalog and the
//! `// detlint: allow(<rule>, reason = "...")` annotation syntax):
//!
//!   r1  no std float transcendentals outside sim/detmath.rs
//!   r2  no HashMap/HashSet iteration in outcome-affecting modules
//!   r3  no wall-clock / OS entropy in deterministic modules
//!   r4  no allocating constructs in `// detlint: hot` functions
//!   r5  no `unsafe` outside the reviewed whitelist
//!
//! Subcommands:
//!   (none)    — lint the repo; non-zero exit on any diagnostic
//!   selftest  — lint the committed fixtures in rust/src/lint/fixtures/
//!               and check each produces exactly its expected
//!               diagnostics (CI runs this on every build)
//!
//! Usage:
//!   detlint [--root .] [--fix-annotations]
//!   detlint selftest [--root .]

use std::path::PathBuf;
use throttllem::cli::Args;
use throttllem::lint::{run_lint, selftest, RULE_NAMES};

const USAGE: &str = "detlint [--root <repo-root>] [--fix-annotations]
  (default)  lint the repo; exits non-zero on any diagnostic
             --fix-annotations: print paste-ready allow() scaffolding
  selftest   lint the committed fixtures against their expectations";

fn main() {
    match run() {
        Ok(code) => std::process::exit(code),
        Err(e) => {
            eprintln!("detlint: error: {e:#}");
            std::process::exit(2);
        }
    }
}

fn run() -> anyhow::Result<i32> {
    let args = Args::from_env()?;
    let root = PathBuf::from(args.get_or("root", "."));
    match args.subcommand.as_deref() {
        None => cmd_lint(&root, args.flag("fix-annotations")),
        Some("selftest") => cmd_selftest(&root),
        Some(other) => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
    }
}

fn cmd_lint(root: &PathBuf, fix_annotations: bool) -> anyhow::Result<i32> {
    let report = run_lint(root)?;
    for d in &report.diags {
        println!("{}", d.render());
    }
    if fix_annotations {
        // Paste-ready scaffolding: one allow per lintable diagnostic,
        // to be placed on the line ABOVE the offending line (or at the
        // end of it) — the TODO reason intentionally fails the lint
        // until a real justification is written.
        let lintable: Vec<_> = report
            .diags
            .iter()
            .filter(|d| RULE_NAMES.contains(&d.rule))
            .collect();
        if !lintable.is_empty() {
            println!("\n--fix-annotations scaffolding (reasons are mandatory):");
            for d in lintable {
                println!("{}:{}: insert above the offending line:", d.path, d.line);
                println!(
                    "    // detlint: allow({}, reason = \"TODO: why is this safe \
                     for the determinism contract?\")",
                    d.rule
                );
            }
        }
    }
    if report.clean() {
        println!("detlint: {} files scanned, no violations", report.files);
        Ok(0)
    } else {
        println!(
            "detlint: {} violation(s) in {} files scanned",
            report.diags.len(),
            report.files
        );
        Ok(1)
    }
}

fn cmd_selftest(root: &PathBuf) -> anyhow::Result<i32> {
    let results = selftest(root)?;
    let mut failed = 0usize;
    for r in &results {
        if r.ok {
            let kind = if r.expects == 0 {
                "clean".to_string()
            } else {
                format!("{} expected diagnostic(s)", r.expects)
            };
            println!("ok   {} ({kind}, as {})", r.file, r.virtual_path);
        } else {
            failed += 1;
            println!("FAIL {}: {}", r.file, r.detail);
        }
    }
    if failed == 0 {
        println!("detlint selftest: {} fixtures ok", results.len());
        Ok(0)
    } else {
        println!(
            "detlint selftest: {failed}/{} fixtures FAILED",
            results.len()
        );
        Ok(1)
    }
}
