//! Command-line argument parsing (clap substitute, offline build).
//!
//! Supports `program <subcommand> [--flag] [--key value] [--key=value]`
//! with typed accessors and an auto-generated usage string.

use std::collections::BTreeMap;

/// Parsed arguments: a subcommand plus `--key value` options.
/// Options are repeatable: every occurrence is kept in order
/// ([`Args::get_all`]); the scalar accessors read the last one, so
/// `--seed 1 --seed 2` means seed 2.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    opts: BTreeMap<String, Vec<String>>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of argument strings (no program name).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> anyhow::Result<Self> {
        let mut out = Args::default();
        let mut iter = args.into_iter().peekable();
        while let Some(arg) = iter.next() {
            if let Some(stripped) = arg.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    out.opts
                        .entry(k.to_string())
                        .or_default()
                        .push(v.to_string());
                } else if iter
                    .peek()
                    .map(|n| !n.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = iter.next().unwrap();
                    out.opts.entry(stripped.to_string()).or_default().push(v);
                } else {
                    out.flags.push(stripped.to_string());
                }
            } else if out.subcommand.is_none() {
                out.subcommand = Some(arg);
            } else {
                out.positional.push(arg);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn from_env() -> anyhow::Result<Self> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts
            .get(name)
            .and_then(|v| v.last())
            .map(|s| s.as_str())
    }

    /// Every occurrence of a repeatable `--key value` option, in the
    /// order given (empty when absent).
    pub fn get_all(&self, name: &str) -> &[String] {
        self.opts.get(name).map(|v| v.as_slice()).unwrap_or(&[])
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_f64(&self, name: &str, default: f64) -> anyhow::Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad float {s:?}: {e}")),
        }
    }

    pub fn get_u64(&self, name: &str, default: u64) -> anyhow::Result<u64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name}: bad integer {s:?}: {e}")),
        }
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["serve", "--engine", "llama2-13b-tp2", "--seed=7"]);
        assert_eq!(a.subcommand.as_deref(), Some("serve"));
        assert_eq!(a.get("engine"), Some("llama2-13b-tp2"));
        assert_eq!(a.get_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn flags_without_values() {
        let a = parse(&["replay", "--autoscale", "--rps", "4.0"]);
        assert!(a.flag("autoscale"));
        assert!(!a.flag("missing"));
        assert_eq!(a.get_f64("rps", 1.0).unwrap(), 4.0);
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&["x"]);
        assert_eq!(a.get_or("engine", "default"), "default");
        assert_eq!(a.get_f64("rps", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn bad_numbers_error() {
        let a = parse(&["x", "--n", "abc"]);
        assert!(a.get_u64("n", 0).is_err());
    }

    #[test]
    fn positional_after_subcommand() {
        let a = parse(&["run", "file1", "file2"]);
        assert_eq!(a.positional(), &["file1".to_string(), "file2".into()]);
    }

    #[test]
    fn bad_float_error_names_flag_and_value() {
        let a = parse(&["serve", "--peak", "fast"]);
        let err = a.get_f64("peak", 1.0).unwrap_err().to_string();
        assert!(err.contains("--peak"), "{err}");
        assert!(err.contains("fast"), "{err}");
    }

    #[test]
    fn bad_integer_error_names_flag_and_value() {
        let a = parse(&["serve", "--fault-seed", "-3"]);
        let err = a.get_u64("fault-seed", 0).unwrap_err().to_string();
        assert!(err.contains("--fault-seed"), "{err}");
        assert!(err.contains("-3"), "{err}");
    }

    #[test]
    fn empty_equals_value_is_kept_and_rejected_by_typed_accessors() {
        let a = parse(&["serve", "--threads="]);
        assert_eq!(a.get("threads"), Some(""));
        assert!(a.get_u64("threads", 1).is_err());
    }

    #[test]
    fn repeated_options_accumulate() {
        let a = parse(&[
            "serve",
            "--replica-spec",
            "tp=1",
            "--replica-spec=tp=2,count=2",
            "--seed",
            "1",
            "--seed",
            "2",
        ]);
        assert_eq!(
            a.get_all("replica-spec"),
            &["tp=1".to_string(), "tp=2,count=2".into()]
        );
        // Scalar accessors read the LAST occurrence.
        assert_eq!(a.get_u64("seed", 0).unwrap(), 2);
        assert!(a.get_all("missing").is_empty());
    }
}
