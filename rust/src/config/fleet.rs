//! Per-replica deployment descriptions for heterogeneous fleets.
//!
//! The fleet coordinator originally replicated ONE [`ServingConfig`]
//! across every replica, so the router's capacity-aware scoring never
//! faced a real trade-off (ROADMAP "Heterogeneous fleets").  A
//! [`ReplicaSpec`] describes one replica on its own terms — boot
//! engine, its own TP autoscaling ladder, and an optional per-replica
//! SLO override — so one fleet can mix TP sizes and model families,
//! the direction *Offline Energy-Optimal LLM Serving* (2407.04014) and
//! *GreenLLM* (2508.16449) motivate for heterogeneous serving systems.
//!
//! Two CLI surfaces parse into `ReplicaSpec` lists:
//!   * a repeatable `--replica-spec tp=2,model=llama2-13b,count=2`
//!     key-value flag ([`parse_replica_spec`]);
//!   * a `--fleet <file>` JSONL file, one replica group per line
//!     ([`parse_fleet_jsonl`]).

use crate::config::models::{default_tp, engine_by_name, family_engine};
use crate::config::{EngineSpec, ServingConfig, SloSpec};
use crate::jsonl::Json;

/// Shared parser for every boolean `--<flag> on|off` CLI surface
/// (`--migration`, `--faults`, `--predict`, `--prefix-share`): one
/// grammar, one error style (flag + offending value + usage hint), no
/// per-spec copies.
pub fn parse_on_off(flag: &str, s: &str) -> anyhow::Result<bool> {
    match s {
        "on" | "true" | "1" => Ok(true),
        "off" | "false" | "0" => Ok(false),
        other => anyhow::bail!("--{flag} {other:?} (expected on | off)"),
    }
}

/// The `--<flag> on|off` grammar lifted to the `Option<Spec>`
/// convention every optional fleet subsystem now uses: `on` yields the
/// spec's defaults, `off` yields `None` (the subsystem's code path is
/// not entered at all — the byte-identity contract).
fn parse_opt_spec<T>(flag: &str, s: &str, default: T) -> anyhow::Result<Option<T>> {
    Ok(parse_on_off(flag, s)?.then_some(default))
}

/// One replica's deployment description: which engine it boots, which
/// TP ladder its own autoscaler may climb, and which SLO it enforces.
#[derive(Debug, Clone, PartialEq)]
pub struct ReplicaSpec {
    /// Engine this replica serves on when it has no TP ladder (and the
    /// spec the fleet's capacity estimates use).
    pub engine: EngineSpec,
    /// TP ladder this replica's own autoscaler may pick from (ordered
    /// by rated max load, ascending).  Empty disables TP autoscaling
    /// for THIS replica even when the fleet policy enables it.
    pub scale_set: Vec<EngineSpec>,
    /// Per-replica SLO override; `None` inherits the fleet-wide SLO.
    pub slo: Option<SloSpec>,
}

impl ReplicaSpec {
    /// A replica pinned to one engine (no TP autoscaling).
    pub fn fixed(engine: EngineSpec) -> Self {
        Self {
            engine,
            scale_set: vec![],
            slo: None,
        }
    }

    /// A replica autoscaling over its own TP ladder (ordered by rated
    /// max load); capacity estimates use the largest rung.
    pub fn autoscaled(scale_set: Vec<EngineSpec>) -> Self {
        assert!(!scale_set.is_empty(), "a TP ladder needs at least one engine");
        let engine = scale_set.last().unwrap().clone();
        Self {
            engine,
            scale_set,
            slo: None,
        }
    }

    /// Override the SLO this replica enforces.
    pub fn with_slo(mut self, slo: SloSpec) -> Self {
        self.slo = Some(slo);
        self
    }

    /// Enforce the replica engine's own Table II SLO instead of the
    /// fleet-wide one.
    pub fn with_engine_slo(mut self) -> Self {
        self.slo = Some(SloSpec::for_engine(&self.engine));
        self
    }

    /// The replica a homogeneous fleet boots from `cfg` — exactly the
    /// derivation the pre-heterogeneous coordinator used (autoscaling
    /// replicas ran `cfg.scale_set`, fixed ones `cfg.engine`).
    pub fn from_config(cfg: &ServingConfig, autoscaling: bool) -> Self {
        if autoscaling && !cfg.scale_set.is_empty() {
            Self {
                engine: cfg.engine.clone(),
                scale_set: cfg.scale_set.clone(),
                slo: None,
            }
        } else {
            Self {
                engine: cfg.engine.clone(),
                scale_set: vec![],
                slo: None,
            }
        }
    }

    /// Every engine this replica may ever run (the TP ladder, or just
    /// the boot engine) — the performance-model training set.
    pub fn engines(&self) -> Vec<EngineSpec> {
        if self.scale_set.is_empty() {
            vec![self.engine.clone()]
        } else {
            self.scale_set.clone()
        }
    }
}

/// Live KV-migration policy + modeled transfer costs (the
/// `--migration on|off` surface).  When present on a [`FleetPlan`]
/// (`Option<MigrationSpec>` — `None` means off), fleet-axis scale-in
/// live-migrates the victim's resident requests to other replicas
/// instead of waiting for them to drain; the move pays a modeled
/// latency (base orchestration cost plus KV bytes over the link
/// bandwidth) during which the migrated request holds KV on the
/// destination but produces no tokens, and a modeled link/host energy
/// cost.  `None` is the default and leaves the serving loop
/// byte-identical to drain-based scale-in.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MigrationSpec {
    /// Fixed per-migration orchestration latency, seconds (checkpoint
    /// metadata exchange, destination block reservation).
    pub base_latency_s: f64,
    /// Effective KV transfer bandwidth, GB/s (NVLink/PCIe-class).
    pub gb_per_s: f64,
    /// KV footprint per block, MB (13B-class: ~40 layers x 5120 dim x
    /// 2 (K,V) x 2 B x 64 tokens ≈ 52 MB).
    pub mb_per_block: f64,
    /// Link + host power drawn while a transfer is in flight, W.
    pub link_power_w: f64,
}

impl MigrationSpec {
    /// Migration on with the default modeled costs.
    pub fn enabled_default() -> Self {
        Self {
            base_latency_s: 0.05,
            gb_per_s: 16.0,
            mb_per_block: 52.0,
            link_power_w: 60.0,
        }
    }

    /// Parse the `--migration` CLI value into the `Option<Spec>`
    /// convention (`on` -> defaults, `off` -> `None`).
    pub fn parse_enabled(s: &str) -> anyhow::Result<Option<Self>> {
        parse_opt_spec("migration", s, Self::enabled_default())
    }

    /// Modeled wall-clock cost of moving `blocks` KV blocks.
    pub fn transfer_seconds(&self, blocks: u32) -> f64 {
        self.base_latency_s + blocks as f64 * self.mb_per_block * 1e6 / (self.gb_per_s * 1e9)
    }

    /// Modeled link/host energy of a transfer that took `transfer_s`.
    pub fn transfer_energy_j(&self, transfer_s: f64) -> f64 {
        self.link_power_w * transfer_s
    }
}

impl Default for MigrationSpec {
    fn default() -> Self {
        Self::enabled_default()
    }
}

/// Deterministic fault-injection policy (the `--faults on|off` /
/// `--fault-seed` surface).  When enabled, a reproducible fault
/// schedule is generated up front from `seed` (PCG64 + `detmath` only,
/// the same byte-identical contract as the fleet trace generator) and
/// replayed by the coordinator: replica crashes, thermal throttle
/// windows, migration-link outages and preemption notices.  `None` on
/// the [`FleetPlan`] is the default and leaves the serving loop
/// byte-identical to the fault-free path (the `--migration off`
/// pattern).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultSpec {
    /// Fault-schedule seed, independent of the workload seed so the
    /// same trace can be replayed under different fault histories.
    pub seed: u64,
    /// Mean time between replica crashes, seconds (fleet-wide; <= 0
    /// disables the family).
    pub crash_mtbf_s: f64,
    /// Mean time between thermal-throttle onsets, seconds.
    pub throttle_mtbf_s: f64,
    /// Forced DVFS ceiling during a throttle window, MHz.
    pub throttle_cap_mhz: u32,
    /// Thermal-throttle window length, seconds.
    pub throttle_window_s: f64,
    /// Mean time between migration-link outages, seconds.
    pub link_mtbf_s: f64,
    /// Link-outage window length, seconds (fleet-wide fabric).
    pub link_window_s: f64,
    /// Mean time between preemption notices, seconds.
    pub preempt_mtbf_s: f64,
    /// Drain deadline granted by a preemption notice, seconds.
    pub preempt_notice_s: f64,
    /// Cadence of periodic best-effort KV checkpoints, seconds.
    pub checkpoint_interval_s: f64,
    /// Re-admission attempts granted to a requeued request before it
    /// is counted as faulted loss.
    pub retry_budget: u32,
    /// Base retry backoff, seconds (doubles per attempt).
    pub retry_backoff_s: f64,
    /// Crash/preemption respawn latency, seconds (same provisioning
    /// cost as a fleet-axis activation).
    pub respawn_s: f64,
}

impl FaultSpec {
    /// Faults on with the default chaos mix.
    pub fn enabled_default() -> Self {
        Self {
            seed: 0,
            crash_mtbf_s: 180.0,
            throttle_mtbf_s: 150.0,
            throttle_cap_mhz: 600,
            throttle_window_s: 40.0,
            link_mtbf_s: 200.0,
            link_window_s: 25.0,
            preempt_mtbf_s: 360.0,
            preempt_notice_s: 12.0,
            checkpoint_interval_s: 5.0,
            retry_budget: 3,
            retry_backoff_s: 2.0,
            respawn_s: 25.0,
        }
    }

    /// Parse the `--faults` CLI value into the `Option<Spec>`
    /// convention (`on` -> defaults, `off` -> `None`).
    pub fn parse_enabled(s: &str) -> anyhow::Result<Option<Self>> {
        parse_opt_spec("faults", s, Self::enabled_default())
    }
}

impl Default for FaultSpec {
    fn default() -> Self {
        Self::enabled_default()
    }
}

/// Predictive fleet-control policy (the `--predict on|off` surface).
/// When enabled, the coordinator feeds a deterministic arrival
/// forecaster ([`crate::workload::ArrivalForecaster`]) from the
/// per-tick arrival counts and uses it for three decisions: pre-warm
/// replicas ahead of forecast ramps, proactively migrate residents off
/// KV-pressured replicas before requests must queue, and rank
/// scale-in victims by how cheap their residents are to move.  `None`
/// on the [`FleetPlan`] is the default and leaves the serving loop
/// byte-identical to the reactive path (the `--migration off` /
/// `--faults off` pattern).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PredictSpec {
    /// Pre-warm horizon, seconds: how far ahead the forecast is
    /// evaluated when deciding to spawn ahead of a ramp.  Default is
    /// one spawn window plus one scaler interval, so a replica warmed
    /// on a forecast is ready when the ramp lands.
    pub lead_s: f64,
    /// EWMA smoothing factor of the forecaster's Holt level in (0, 1].
    pub alpha: f64,
    /// Assumed diurnal period of the harmonic term, seconds.
    pub period_s: f64,
    /// Proactive-offload trigger: fraction of a replica's KV pool the
    /// §IV-B projected peak must reach before residents are moved off.
    pub kv_pressure: f64,
}

impl PredictSpec {
    /// Prediction on with the default forecaster knobs.
    pub fn enabled_default() -> Self {
        Self {
            lead_s: 35.0,
            alpha: 0.35,
            period_s: 600.0,
            kv_pressure: 0.85,
        }
    }

    /// Parse the `--predict` CLI value into the `Option<Spec>`
    /// convention (`on` -> defaults, `off` -> `None`).
    pub fn parse_enabled(s: &str) -> anyhow::Result<Option<Self>> {
        parse_opt_spec("predict", s, Self::enabled_default())
    }
}

impl Default for PredictSpec {
    fn default() -> Self {
        Self::enabled_default()
    }
}

/// Copy-on-write prefix-sharing policy (the `--prefix-share on|off`
/// surface, ISSUE 10).  When present on a [`FleetPlan`], engines store
/// the full blocks of a session's shared system prompt once
/// (ref-counted CoW in [`crate::engine`]'s `KvAllocator`), admissions
/// whose prefix is already resident skip the cached prefill tokens,
/// the §IV-B projection discounts resident shared blocks, and the
/// router prefers replicas where a session's prefix is resident.
/// `None` is the default and leaves the serving loop byte-identical to
/// the pre-sharing path.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PrefixSpec {
    /// Smallest shared prefix (tokens) worth sharing; requests whose
    /// declared prefix is shorter are served privately.  One KV block
    /// by default — a shorter prefix has no full block to share.
    pub min_prefix_tokens: u32,
}

impl PrefixSpec {
    /// Sharing on with the default threshold.
    pub fn enabled_default() -> Self {
        Self {
            min_prefix_tokens: 64,
        }
    }

    /// Parse the `--prefix-share` CLI value into the `Option<Spec>`
    /// convention (`on` -> defaults, `off` -> `None`).
    pub fn parse_enabled(s: &str) -> anyhow::Result<Option<Self>> {
        parse_opt_spec("prefix-share", s, Self::enabled_default())
    }
}

impl Default for PrefixSpec {
    fn default() -> Self {
        Self::enabled_default()
    }
}

/// A strictly-integral JSON number in u32 range (`Json::as_u64` would
/// silently truncate 2.5 to 2 and wrap out-of-range values).
fn json_u32(j: &Json) -> Option<u32> {
    match j {
        Json::Num(x) if x.fract() == 0.0 && *x >= 0.0 && *x <= u32::MAX as f64 => {
            Some(*x as u32)
        }
        _ => None,
    }
}

/// Order a TP ladder by rated max load (what [`crate::coordinator`]'s
/// `Autoscaler` requires).
fn sort_ladder(mut specs: Vec<EngineSpec>) -> Vec<EngineSpec> {
    specs.sort_by(|a, b| {
        a.max_load_rps
            .partial_cmp(&b.max_load_rps)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    specs
}

/// Parse one `--replica-spec` value into (possibly `count` repeated)
/// replica descriptions.
///
/// Grammar: comma-separated `key=value` pairs.
///   * `engine=<name>` — an exact engine (`throttllem engines` lists
///     them); mutually exclusive with `model`/`tp`;
///   * `model=<family>` — model family (default `llama2-13b`);
///   * `tp=<n>` — tensor parallelism; `tp=1+2+4` declares a TP
///     autoscaling ladder for this replica;
///   * `count=<n>` — replicate this description n times (default 1);
///   * `slo=engine|fleet` — enforce the engine's own Table II SLO or
///     the fleet-wide one (default `fleet`).
///
/// Examples: `tp=2`, `model=llama3-8b,count=2`, `tp=1+2+4,slo=engine`.
pub fn parse_replica_spec(s: &str) -> anyhow::Result<Vec<ReplicaSpec>> {
    let mut engine: Option<EngineSpec> = None;
    let mut model: Option<String> = None;
    let mut tps: Vec<u32> = vec![];
    let mut count: usize = 1;
    let mut engine_slo = false;
    for part in s.split(',').map(str::trim).filter(|p| !p.is_empty()) {
        let Some((k, v)) = part.split_once('=') else {
            anyhow::bail!("replica-spec part {part:?} is not key=value (in {s:?})");
        };
        match k {
            "engine" => engine = Some(engine_by_name(v)?),
            "model" => model = Some(v.to_string()),
            "tp" => {
                tps = v
                    .split('+')
                    .map(|t| {
                        t.parse::<u32>().map_err(|e| {
                            anyhow::anyhow!("replica-spec tp {t:?}: {e} (in {s:?})")
                        })
                    })
                    .collect::<anyhow::Result<Vec<u32>>>()?;
            }
            "count" => {
                count = v.parse::<usize>().map_err(|e| {
                    anyhow::anyhow!("replica-spec count {v:?}: {e} (in {s:?})")
                })?;
            }
            "slo" => match v {
                "engine" => engine_slo = true,
                "fleet" => engine_slo = false,
                other => anyhow::bail!(
                    "replica-spec slo {other:?} (expected engine | fleet)"
                ),
            },
            other => anyhow::bail!(
                "unknown replica-spec key {other:?} \
                 (expected engine | model | tp | count | slo)"
            ),
        }
    }
    anyhow::ensure!(count >= 1, "replica-spec count must be >= 1 (in {s:?})");
    let spec = match engine {
        Some(e) => {
            anyhow::ensure!(
                model.is_none() && tps.is_empty(),
                "replica-spec: engine= is mutually exclusive with model=/tp= (in {s:?})"
            );
            ReplicaSpec::fixed(e)
        }
        None => {
            let model = model.as_deref().unwrap_or("llama2-13b");
            if tps.is_empty() {
                tps = vec![default_tp(model)];
            }
            if tps.len() == 1 {
                ReplicaSpec::fixed(family_engine(model, tps[0])?)
            } else {
                let ladder = tps
                    .iter()
                    .map(|&tp| family_engine(model, tp))
                    .collect::<anyhow::Result<Vec<EngineSpec>>>()?;
                ReplicaSpec::autoscaled(sort_ladder(ladder))
            }
        }
    };
    let spec = if engine_slo { spec.with_engine_slo() } else { spec };
    Ok(vec![spec; count])
}

/// Parse a JSONL fleet file: one replica group per line (blank lines
/// and `#` comments skipped).  Keys per line:
///   * `"engine"`: exact engine name — or `"model"` (+ `"tp"`);
///   * `"tp"`: a number, or an array declaring a TP ladder;
///   * `"count"`: replicas with this description (default 1);
///   * `"slo"`: `"engine"` or `"fleet"` (default).
///
/// Example:
/// ```text
/// {"engine": "llama2-13b-tp4"}
/// {"model": "llama2-13b", "tp": [1, 2], "count": 2, "slo": "engine"}
/// ```
pub fn parse_fleet_jsonl(text: &str) -> anyhow::Result<Vec<ReplicaSpec>> {
    let mut out: Vec<ReplicaSpec> = vec![];
    for (i, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let v = crate::jsonl::parse(line)
            .map_err(|e| anyhow::anyhow!("fleet file line {}: {e:#}", i + 1))?;
        // Reject misspelled keys instead of silently deploying the
        // default replica (the --replica-spec parser does the same).
        let Json::Obj(obj) = &v else {
            anyhow::bail!("fleet file line {}: expected a JSON object", i + 1);
        };
        for key in obj.keys() {
            anyhow::ensure!(
                matches!(key.as_str(), "engine" | "model" | "tp" | "count" | "slo"),
                "fleet file line {}: unknown key {key:?} \
                 (expected engine | model | tp | count | slo)",
                i + 1
            );
        }
        let count = match v.get("count") {
            None => 1usize,
            Some(c) => json_u32(c).filter(|&c| c >= 1).ok_or_else(|| {
                anyhow::anyhow!(
                    "fleet file line {}: count must be a positive integer",
                    i + 1
                )
            })? as usize,
        };
        let engine_slo = match v.get("slo").and_then(Json::as_str) {
            None | Some("fleet") => false,
            Some("engine") => true,
            Some(other) => anyhow::bail!(
                "fleet file line {}: slo {other:?} (expected engine | fleet)",
                i + 1
            ),
        };
        let spec = if let Some(name) = v.get("engine").and_then(Json::as_str) {
            anyhow::ensure!(
                v.get("model").is_none() && v.get("tp").is_none(),
                "fleet file line {}: \"engine\" is mutually exclusive with \
                 \"model\"/\"tp\"",
                i + 1
            );
            ReplicaSpec::fixed(engine_by_name(name)?)
        } else {
            let model = v.get("model").and_then(Json::as_str).unwrap_or("llama2-13b");
            match v.get("tp") {
                Some(Json::Arr(arr)) => {
                    anyhow::ensure!(
                        !arr.is_empty(),
                        "fleet file line {}: empty tp ladder",
                        i + 1
                    );
                    let ladder = arr
                        .iter()
                        .map(|t| {
                            let tp = json_u32(t).ok_or_else(|| {
                                anyhow::anyhow!(
                                    "fleet file line {}: tp entries must be small \
                                     non-negative integers",
                                    i + 1
                                )
                            })?;
                            family_engine(model, tp)
                        })
                        .collect::<anyhow::Result<Vec<EngineSpec>>>()?;
                    if ladder.len() == 1 {
                        ReplicaSpec::fixed(ladder.into_iter().next().unwrap())
                    } else {
                        ReplicaSpec::autoscaled(sort_ladder(ladder))
                    }
                }
                Some(t) => {
                    let tp = json_u32(t).ok_or_else(|| {
                        anyhow::anyhow!(
                            "fleet file line {}: tp must be a small \
                             non-negative integer",
                            i + 1
                        )
                    })?;
                    ReplicaSpec::fixed(family_engine(model, tp)?)
                }
                None => ReplicaSpec::fixed(family_engine(model, default_tp(model))?),
            }
        };
        let spec = if engine_slo { spec.with_engine_slo() } else { spec };
        for _ in 0..count {
            out.push(spec.clone());
        }
    }
    anyhow::ensure!(!out.is_empty(), "fleet file defines no replicas");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::{llama2_13b, llama3_8b};

    #[test]
    fn migration_spec_costs_and_parse() {
        let m = MigrationSpec::enabled_default();
        // 10 blocks at 52 MB over 16 GB/s: 32.5 ms + 50 ms base.
        let t = m.transfer_seconds(10);
        assert!((t - (0.05 + 10.0 * 52e6 / 16e9)).abs() < 1e-12);
        assert!(m.transfer_seconds(100) > t);
        assert!((m.transfer_energy_j(1.0) - m.link_power_w).abs() < 1e-12);
        assert_eq!(MigrationSpec::default(), m);
        assert_eq!(MigrationSpec::parse_enabled("on").unwrap(), Some(m));
        assert_eq!(MigrationSpec::parse_enabled("off").unwrap(), None);
        assert!(MigrationSpec::parse_enabled("maybe").is_err());
    }

    #[test]
    fn fault_spec_defaults_and_parse() {
        let f = FaultSpec::enabled_default();
        assert!(f.crash_mtbf_s > 0.0 && f.respawn_s > 0.0);
        assert!(f.throttle_cap_mhz >= 210 && f.throttle_cap_mhz < 1410);
        assert_eq!(FaultSpec::default(), f);
        assert_eq!(FaultSpec::parse_enabled("on").unwrap(), Some(f));
        assert_eq!(FaultSpec::parse_enabled("1").unwrap(), Some(f));
        assert_eq!(FaultSpec::parse_enabled("off").unwrap(), None);
        assert_eq!(FaultSpec::parse_enabled("false").unwrap(), None);
        // Unknown values surface as errors with a usage hint, never a
        // panic (CLI robustness contract).
        let e = FaultSpec::parse_enabled("chaos").unwrap_err();
        assert!(format!("{e}").contains("expected on | off"), "{e}");
        assert!(FaultSpec::parse_enabled("").is_err());
        assert!(FaultSpec::parse_enabled("On").is_err());
    }

    #[test]
    fn predict_spec_defaults_and_parse() {
        let p = PredictSpec::enabled_default();
        assert!(p.lead_s > 0.0 && p.period_s > 0.0);
        assert!(p.alpha > 0.0 && p.alpha <= 1.0);
        assert!(p.kv_pressure > 0.0 && p.kv_pressure <= 1.0);
        assert_eq!(PredictSpec::default(), p);
        assert_eq!(PredictSpec::parse_enabled("on").unwrap(), Some(p));
        assert_eq!(PredictSpec::parse_enabled("0").unwrap(), None);
        let e = PredictSpec::parse_enabled("soon").unwrap_err();
        assert!(format!("{e}").contains("expected on | off"), "{e}");
    }

    #[test]
    fn prefix_spec_defaults_and_parse() {
        let p = PrefixSpec::enabled_default();
        assert_eq!(p.min_prefix_tokens, 64);
        assert_eq!(PrefixSpec::default(), p);
        assert_eq!(PrefixSpec::parse_enabled("on").unwrap(), Some(p));
        assert_eq!(PrefixSpec::parse_enabled("off").unwrap(), None);
        let e = PrefixSpec::parse_enabled("shared").unwrap_err();
        assert!(format!("{e}").contains("--prefix-share"), "{e}");
    }

    /// The shared on|off parser names the flag it was parsing in its
    /// error, so every `--<flag>` surface keeps the PR 8 error style.
    #[test]
    fn on_off_errors_name_their_flag() {
        let cases: [(&str, Box<dyn Fn(&str) -> Option<String>>); 4] = [
            (
                "migration",
                Box::new(|s| MigrationSpec::parse_enabled(s).err().map(|e| format!("{e}"))),
            ),
            (
                "faults",
                Box::new(|s| FaultSpec::parse_enabled(s).err().map(|e| format!("{e}"))),
            ),
            (
                "predict",
                Box::new(|s| PredictSpec::parse_enabled(s).err().map(|e| format!("{e}"))),
            ),
            (
                "prefix-share",
                Box::new(|s| PrefixSpec::parse_enabled(s).err().map(|e| format!("{e}"))),
            ),
        ];
        for (flag, parse) in cases {
            let msg = parse("sideways").expect("must error");
            assert!(msg.contains(&format!("--{flag}")), "{flag}: {msg}");
            assert!(msg.contains("expected on | off"), "{flag}: {msg}");
        }
    }

    #[test]
    fn parse_single_tp() {
        let specs = parse_replica_spec("tp=2").unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0], ReplicaSpec::fixed(llama2_13b(2)));
    }

    #[test]
    fn parse_model_count_and_slo() {
        let specs = parse_replica_spec("model=llama3-8b,count=2,slo=engine").unwrap();
        assert_eq!(specs.len(), 2);
        assert_eq!(specs[0].engine, llama3_8b(1));
        assert_eq!(specs[0].slo, Some(SloSpec::for_engine(&llama3_8b(1))));
        assert_eq!(specs[0], specs[1]);
    }

    #[test]
    fn parse_tp_ladder_sorts_by_capacity() {
        let specs = parse_replica_spec("tp=4+1+2").unwrap();
        assert_eq!(specs.len(), 1);
        let tps: Vec<u32> = specs[0]
            .scale_set
            .iter()
            .map(|e| e.tensor_parallel)
            .collect();
        assert_eq!(tps, vec![1, 2, 4]);
        // Capacity estimates anchor on the largest rung.
        assert_eq!(specs[0].engine, llama2_13b(4));
    }

    #[test]
    fn parse_engine_name_directly() {
        let specs = parse_replica_spec("engine=llama2-13b-tp4").unwrap();
        assert_eq!(specs[0].engine, llama2_13b(4));
        assert!(specs[0].scale_set.is_empty());
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(parse_replica_spec("tp").is_err());
        assert!(parse_replica_spec("tp=banana").is_err());
        assert!(parse_replica_spec("model=gpt-5").is_err());
        assert!(parse_replica_spec("model=llama3-8b,tp=2").is_err());
        assert!(parse_replica_spec("flavor=spicy").is_err());
        assert!(parse_replica_spec("engine=llama2-13b-tp2,tp=2").is_err());
        assert!(parse_replica_spec("count=0").is_err());
        assert!(parse_replica_spec("slo=maybe").is_err());
    }

    #[test]
    fn parse_jsonl_fleet() {
        let text = r#"
# mixed fleet
{"engine": "llama2-13b-tp4"}
{"model": "llama2-13b", "tp": 1, "count": 2}
{"tp": [1, 2], "slo": "engine"}
"#;
        let specs = parse_fleet_jsonl(text).unwrap();
        assert_eq!(specs.len(), 4);
        assert_eq!(specs[0].engine, llama2_13b(4));
        assert_eq!(specs[1].engine, llama2_13b(1));
        assert_eq!(specs[1], specs[2]);
        assert_eq!(specs[3].scale_set.len(), 2);
        assert_eq!(specs[3].slo, Some(SloSpec::for_engine(&llama2_13b(2))));
    }

    #[test]
    fn parse_jsonl_rejects_bad_lines() {
        assert!(parse_fleet_jsonl("").is_err());
        assert!(parse_fleet_jsonl("{\"tp\": \"two\"}").is_err());
        assert!(parse_fleet_jsonl("{\"engine\": \"nope\"}").is_err());
        assert!(parse_fleet_jsonl("{\"count\": 0}").is_err());
        assert!(parse_fleet_jsonl("not json").is_err());
        // Misspelled keys must error, not silently deploy the default.
        assert!(parse_fleet_jsonl("{\"egnine\": \"llama2-13b-tp4\"}").is_err());
        assert!(parse_fleet_jsonl("{\"modle\": \"llama3-8b\", \"tp\": 1}").is_err());
        assert!(parse_fleet_jsonl("[1, 2]").is_err());
        // Out-of-u32-range / non-integral tp must error, not wrap or
        // truncate to a valid engine.
        assert!(parse_fleet_jsonl("{\"tp\": 4294967298}").is_err());
        assert!(parse_fleet_jsonl("{\"tp\": [1, 4294967298]}").is_err());
        assert!(parse_fleet_jsonl("{\"tp\": 2.5}").is_err());
        // Non-integer count must error, not silently deploy 1 replica.
        assert!(parse_fleet_jsonl("{\"tp\": 2, \"count\": \"4\"}").is_err());
        assert!(parse_fleet_jsonl("{\"tp\": 2, \"count\": 1.5}").is_err());
        // engine + model/tp on one line is a contradiction, not a
        // silent precedence rule (same as --replica-spec).
        assert!(
            parse_fleet_jsonl("{\"engine\": \"llama2-13b-tp1\", \"tp\": 4}").is_err()
        );
    }

    #[test]
    fn from_config_mirrors_homogeneous_derivation() {
        let fixed_cfg = ServingConfig::throttllem(llama2_13b(2));
        let rs = ReplicaSpec::from_config(&fixed_cfg, false);
        assert_eq!(rs.engine, fixed_cfg.engine);
        assert!(rs.scale_set.is_empty() && rs.slo.is_none());

        let auto_cfg =
            ServingConfig::autoscaled(vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)]);
        let rs = ReplicaSpec::from_config(&auto_cfg, true);
        assert_eq!(rs.scale_set, auto_cfg.scale_set);
        assert_eq!(rs.engine, auto_cfg.engine);
        assert_eq!(rs.engines().len(), 3);
    }
}
