//! Engine descriptors, SLO definitions and serving configuration.
//!
//! Table II of the paper defines the evaluated engines (model × tensor
//! parallelism) with their rated max load, p99 E2E SLO, and KV-cache
//! capacity.  Those numbers are reproduced here as configuration ground
//! truth; the `table2` bench re-derives max load / E2E SLO from our own
//! saturation profiling to mirror the paper's methodology.

pub mod fleet;
pub mod models;

pub use fleet::{
    parse_fleet_jsonl, parse_on_off, parse_replica_spec, FaultSpec, MigrationSpec, PredictSpec,
    PrefixSpec, ReplicaSpec,
};
pub use models::{EngineSpec, ModelFamily, PartitionKind};

/// Service-level objectives the coordinator enforces (paper §V-A).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SloSpec {
    /// Average time-between-tokens bound, seconds (paper: 200 ms — the
    /// human reading rate, adopted by MLPerf).
    pub tbt_avg: f64,
    /// End-to-end p99 deadline, seconds (per-engine, from Table II or
    /// re-derived by saturation profiling).
    pub e2e_p99: f64,
}

impl SloSpec {
    pub fn new(tbt_avg: f64, e2e_p99: f64) -> Self {
        assert!(tbt_avg > 0.0 && e2e_p99 > 0.0);
        Self { tbt_avg, e2e_p99 }
    }

    /// The paper's TBT SLO: 200 ms average between tokens.
    pub const HUMAN_READING_TBT: f64 = 0.200;

    /// SLO for an engine using its Table II E2E profile.
    pub fn for_engine(spec: &EngineSpec) -> Self {
        Self::new(Self::HUMAN_READING_TBT, spec.e2e_slo_p99)
    }
}

/// Top-level serving configuration.
#[derive(Debug, Clone)]
pub struct ServingConfig {
    /// Engine to serve on (ignored when autoscaling over `scale_set`).
    pub engine: EngineSpec,
    /// SLOs to enforce.
    pub slo: SloSpec,
    /// Enable the GPU frequency throttling controller.
    pub throttling: bool,
    /// Enable the TP autoscaler over `scale_set`.
    pub autoscaling: bool,
    /// Engines the autoscaler may pick from (ordered by capacity).
    pub scale_set: Vec<EngineSpec>,
    /// Generation-length predictor p95 relative error (0.0 = oracle).
    pub predictor_p95_error: f64,
    /// Autoscaler monitoring interval, seconds (paper: 10 s).
    pub autoscale_interval: f64,
    /// Maximum generation length supported by the deployment
    /// (`max_tokens`); Scoreboard entries are bumped to this when a
    /// query outlives its predicted length (paper §IV-F).
    pub max_tokens: u32,
    /// RNG seed for anything stochastic downstream.
    pub seed: u64,
}

impl ServingConfig {
    /// throttLL'eM defaults on a given engine (throttling on,
    /// autoscaling off — the paper's §V-D1 configuration).
    pub fn throttllem(engine: EngineSpec) -> Self {
        let slo = SloSpec::for_engine(&engine);
        Self {
            engine,
            slo,
            throttling: true,
            autoscaling: false,
            scale_set: vec![],
            predictor_p95_error: 0.0,
            autoscale_interval: 10.0,
            max_tokens: 1024,
            seed: 0,
        }
    }

    /// Triton-like baseline: max frequency, no throttling/autoscaling.
    pub fn triton(engine: EngineSpec) -> Self {
        Self {
            throttling: false,
            ..Self::throttllem(engine)
        }
    }

    /// Full throttLL'eM (§V-D2): throttling + autoscaling over a set.
    pub fn autoscaled(scale_set: Vec<EngineSpec>) -> Self {
        assert!(!scale_set.is_empty());
        let largest = scale_set.last().unwrap().clone();
        Self {
            autoscaling: true,
            scale_set,
            ..Self::throttllem(largest)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use models::llama2_13b;

    #[test]
    fn slo_for_engine_uses_table2() {
        let e = llama2_13b(2);
        let slo = SloSpec::for_engine(&e);
        assert_eq!(slo.tbt_avg, 0.2);
        assert!((slo.e2e_p99 - 30.2).abs() < 1e-9);
    }

    #[test]
    fn triton_config_disables_throttling() {
        let c = ServingConfig::triton(llama2_13b(2));
        assert!(!c.throttling && !c.autoscaling);
    }

    #[test]
    fn autoscaled_config_targets_largest() {
        let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];
        let c = ServingConfig::autoscaled(set);
        assert!(c.autoscaling && c.throttling);
        assert_eq!(c.engine.tensor_parallel, 4);
    }
}
