//! Engine specifications: the paper's evaluated LLM engines (Table II)
//! plus the DDP/PP partition variants of §III-C (Fig. 4).
//!
//! `latency_scale` calibrates the per-iteration latency of an engine
//! relative to the Llama2-13B TP2 reference the paper characterizes in
//! §III-A; it tracks per-GPU weight bytes (decode is memory-bound) plus
//! tensor-parallel communication overheads.  See `gpusim::latency` for
//! the full model and DESIGN.md §1 for the calibration anchors.

/// LLM families examined by the paper (§V-A, Table II).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ModelFamily {
    Llama3_8B,
    Llama2_13B,
    Llama3_70B,
    /// The runnable tiny model served for real through PJRT.
    TinyLlamaSim,
}

impl ModelFamily {
    pub fn params_b(&self) -> f64 {
        match self {
            ModelFamily::Llama3_8B => 8.0,
            ModelFamily::Llama2_13B => 13.0,
            ModelFamily::Llama3_70B => 70.0,
            ModelFamily::TinyLlamaSim => 0.0001,
        }
    }

    /// CLI / report spelling (heterogeneous-fleet stat breakdown).
    pub fn name(&self) -> &'static str {
        match self {
            ModelFamily::Llama3_8B => "llama3-8b",
            ModelFamily::Llama2_13B => "llama2-13b",
            ModelFamily::Llama3_70B => "llama3-70b",
            ModelFamily::TinyLlamaSim => "tiny-llama-sim",
        }
    }
}

/// Multi-GPU partitioning approach (§II / §III-C).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PartitionKind {
    /// Tensor parallelism: weight tensors sharded across GPUs.
    Tensor,
    /// Distributed data parallelism: full model replicas.
    DataParallel,
    /// Pipeline parallelism: consecutive layers per GPU.
    Pipeline,
}

/// A deployable engine configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSpec {
    pub name: String,
    pub family: ModelFamily,
    pub partition: PartitionKind,
    /// Parallelism level (GPUs for TP/PP; replicas for DDP).
    pub tensor_parallel: u32,
    /// Physical GPUs occupied.
    pub n_gpus: u32,
    /// Paged-KV capacity in blocks (Table II).
    pub kv_blocks: u32,
    /// Tokens per KV block (TensorRT-LLM compile-time parameter N).
    pub block_tokens: u32,
    /// Largest batch the engine schedules.
    pub max_batch: u32,
    /// Rated max load before long tail latencies, requests/s (Table II).
    pub max_load_rps: f64,
    /// p99 E2E at rated max load, seconds (Table II) — the E2E SLO.
    pub e2e_slo_p99: f64,
    /// Iteration-latency multiplier vs the Llama2-13B TP2 reference.
    pub latency_scale: f64,
    /// Pipeline-bubble overhead fraction (PP only; 0 otherwise).
    pub pipeline_bubble: f64,
}

impl EngineSpec {
    /// KV capacity in tokens.
    pub fn kv_capacity_tokens(&self) -> u64 {
        self.kv_blocks as u64 * self.block_tokens as u64
    }
}

/// Tokens per KV block used across the deployment.
pub const BLOCK_TOKENS: u32 = 64;

/// Llama3-8B (Table II row 1). Only TP1 is evaluated by the paper.
pub fn llama3_8b(tp: u32) -> EngineSpec {
    assert_eq!(tp, 1, "paper evaluates Llama3-8B at TP1 only");
    EngineSpec {
        name: "llama3-8b-tp1".into(),
        family: ModelFamily::Llama3_8B,
        partition: PartitionKind::Tensor,
        tensor_parallel: 1,
        n_gpus: 1,
        kv_blocks: 1033,
        block_tokens: BLOCK_TOKENS,
        max_batch: 64,
        max_load_rps: 13.0,
        e2e_slo_p99: 37.7,
        latency_scale: 0.75,
        pipeline_bubble: 0.0,
    }
}

/// Llama2-13B at TP 1, 2 or 4 (Table II rows 2-4).
pub fn llama2_13b(tp: u32) -> EngineSpec {
    let (kv_blocks, max_batch, max_load, e2e, scale) = match tp {
        1 => (120, 8, 1.125, 22.7, 1.8),
        2 => (439, 32, 4.0, 30.2, 1.0),
        4 => (1050, 64, 7.5, 31.3, 0.65),
        _ => panic!("llama2-13b supports TP in {{1,2,4}}, got {tp}"),
    };
    EngineSpec {
        name: format!("llama2-13b-tp{tp}"),
        family: ModelFamily::Llama2_13B,
        partition: PartitionKind::Tensor,
        tensor_parallel: tp,
        n_gpus: tp,
        kv_blocks,
        block_tokens: BLOCK_TOKENS,
        max_batch,
        max_load_rps: max_load,
        e2e_slo_p99: e2e,
        latency_scale: scale,
        pipeline_bubble: 0.0,
    }
}

/// Llama3-70B TP8 (Table II row 5).
pub fn llama3_70b(tp: u32) -> EngineSpec {
    assert_eq!(tp, 8, "paper evaluates Llama3-70B at TP8 only");
    EngineSpec {
        name: "llama3-70b-tp8".into(),
        family: ModelFamily::Llama3_70B,
        partition: PartitionKind::Tensor,
        tensor_parallel: 8,
        n_gpus: 8,
        kv_blocks: 2205,
        block_tokens: BLOCK_TOKENS,
        max_batch: 48,
        max_load_rps: 7.0,
        e2e_slo_p99: 44.0,
        latency_scale: 1.6,
        pipeline_bubble: 0.0,
    }
}

/// Llama2-13B partition variants for the §III-C study (Fig. 4).
///
/// DDP(n): n independent TP1 replicas (n x 13B weights, n x TP1 KV).
/// PP(n): layers split over n GPUs; per-iteration pipeline bubbles make
/// it the slowest option (calibrated to the paper's 2.74x / 6.26x TP
/// advantage at n = 2 / 4).
pub fn llama2_13b_partitioned(kind: PartitionKind, n: u32) -> EngineSpec {
    assert!(n == 2 || n == 4, "Fig. 4 evaluates parallelism 2 and 4");
    match kind {
        PartitionKind::Tensor => llama2_13b(n),
        PartitionKind::DataParallel => {
            let tp1 = llama2_13b(1);
            EngineSpec {
                name: format!("llama2-13b-ddp{n}"),
                partition: PartitionKind::DataParallel,
                tensor_parallel: n,
                n_gpus: n,
                kv_blocks: tp1.kv_blocks * n,
                max_batch: tp1.max_batch * n,
                // DDP replicas split the arrival stream.
                max_load_rps: tp1.max_load_rps * n as f64,
                latency_scale: tp1.latency_scale,
                ..tp1
            }
        }
        PartitionKind::Pipeline => {
            let tp1 = llama2_13b(1);
            let bubble = if n == 2 { 0.55 } else { 1.30 };
            EngineSpec {
                name: format!("llama2-13b-pp{n}"),
                partition: PartitionKind::Pipeline,
                tensor_parallel: n,
                n_gpus: n,
                kv_blocks: tp1.kv_blocks * n,
                max_batch: tp1.max_batch * n,
                max_load_rps: tp1.max_load_rps * 1.3,
                latency_scale: tp1.latency_scale,
                pipeline_bubble: bubble,
                ..tp1
            }
        }
    }
}

/// The runnable PJRT-served model (artifacts built by `make artifacts`).
pub fn tiny_llama_sim() -> EngineSpec {
    EngineSpec {
        name: "tiny-llama-sim".into(),
        family: ModelFamily::TinyLlamaSim,
        partition: PartitionKind::Tensor,
        tensor_parallel: 1,
        n_gpus: 1,
        // 256-token max_seq, 64-token blocks, 8-wide max bucket.
        kv_blocks: 32,
        block_tokens: BLOCK_TOKENS,
        max_batch: 8,
        max_load_rps: 16.0,
        e2e_slo_p99: 10.0,
        latency_scale: 0.02,
        pipeline_bubble: 0.0,
    }
}

/// Resolve an engine descriptor by its CLI spelling.
pub fn engine_by_name(name: &str) -> anyhow::Result<EngineSpec> {
    Ok(match name {
        "llama3-8b-tp1" => llama3_8b(1),
        "llama2-13b-tp1" => llama2_13b(1),
        "llama2-13b-tp2" => llama2_13b(2),
        "llama2-13b-tp4" => llama2_13b(4),
        "llama3-70b-tp8" => llama3_70b(8),
        "tiny-llama-sim" => tiny_llama_sim(),
        other => anyhow::bail!("unknown engine {other:?}; see `throttllem engines`"),
    })
}

/// Resolve a (family, tensor-parallelism) pair to its engine
/// descriptor, rejecting combinations the paper does not characterize
/// instead of panicking like the raw constructors.
pub fn family_engine(model: &str, tp: u32) -> anyhow::Result<EngineSpec> {
    Ok(match (model, tp) {
        ("llama3-8b", 1) => llama3_8b(1),
        ("llama2-13b", 1 | 2 | 4) => llama2_13b(tp),
        ("llama3-70b", 8) => llama3_70b(8),
        ("tiny-llama-sim", 1) => tiny_llama_sim(),
        (m @ ("llama3-8b" | "llama2-13b" | "llama3-70b" | "tiny-llama-sim"), t) => {
            anyhow::bail!("model {m:?} is not characterized at tp={t}")
        }
        (other, _) => anyhow::bail!(
            "unknown model {other:?} \
             (expected llama3-8b | llama2-13b | llama3-70b | tiny-llama-sim)"
        ),
    })
}

/// Default tensor parallelism for a family (Table II's evaluated
/// points; llama2-13b defaults to the TP2 reference engine).
pub fn default_tp(model: &str) -> u32 {
    match model {
        "llama2-13b" => 2,
        "llama3-70b" => 8,
        _ => 1,
    }
}

/// The five engines of Table II, in paper order.
pub fn table2_engines() -> Vec<EngineSpec> {
    vec![
        llama3_8b(1),
        llama2_13b(1),
        llama2_13b(2),
        llama2_13b(4),
        llama3_70b(8),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_matches_paper() {
        let engines = table2_engines();
        assert_eq!(engines.len(), 5);
        let blocks: Vec<u32> = engines.iter().map(|e| e.kv_blocks).collect();
        assert_eq!(blocks, vec![1033, 120, 439, 1050, 2205]);
        let rps: Vec<f64> = engines.iter().map(|e| e.max_load_rps).collect();
        assert_eq!(rps, vec![13.0, 1.125, 4.0, 7.5, 7.0]);
        let slo: Vec<f64> = engines.iter().map(|e| e.e2e_slo_p99).collect();
        assert_eq!(slo, vec![37.7, 22.7, 30.2, 31.3, 44.0]);
    }

    #[test]
    fn higher_tp_means_lower_latency_scale() {
        assert!(llama2_13b(4).latency_scale < llama2_13b(2).latency_scale);
        assert!(llama2_13b(2).latency_scale < llama2_13b(1).latency_scale);
    }

    #[test]
    fn kv_capacity_tokens() {
        assert_eq!(llama2_13b(2).kv_capacity_tokens(), 439 * 64);
    }

    #[test]
    fn ddp_scales_replica_resources() {
        let ddp2 = llama2_13b_partitioned(PartitionKind::DataParallel, 2);
        assert_eq!(ddp2.kv_blocks, 240);
        assert_eq!(ddp2.max_batch, 16);
        assert_eq!(ddp2.n_gpus, 2);
    }

    #[test]
    fn pp_has_bubble_overhead() {
        let pp2 = llama2_13b_partitioned(PartitionKind::Pipeline, 2);
        let pp4 = llama2_13b_partitioned(PartitionKind::Pipeline, 4);
        assert!(pp2.pipeline_bubble > 0.0);
        assert!(pp4.pipeline_bubble > pp2.pipeline_bubble);
    }

    #[test]
    #[should_panic]
    fn llama2_13b_rejects_bad_tp() {
        llama2_13b(3);
    }

    #[test]
    fn engine_lookup_by_name_and_family() {
        for e in table2_engines() {
            assert_eq!(engine_by_name(&e.name).unwrap(), e);
            assert_eq!(family_engine(e.family.name(), e.tensor_parallel).unwrap(), e);
        }
        assert!(engine_by_name("gpt-5").is_err());
        assert!(family_engine("llama2-13b", 3).is_err());
        assert!(family_engine("llama3-8b", 2).is_err());
        assert_eq!(default_tp("llama2-13b"), 2);
        assert_eq!(default_tp("llama3-70b"), 8);
    }
}
