//! LLM instance autoscaling (paper §IV-D).
//!
//! A 10-second monitoring agent right-sizes the engine's tensor
//! parallelism against precharacterized per-engine max loads
//! (Table II).  Provisioning a new inference server takes >20 s, so
//! switching uses "shadow instancing": a warm-up phase (old engine
//! keeps serving while the new one boots) followed by a transition
//! (old engine drains, new engine takes all new requests).  A grace
//! period equal to the spawn time prevents premature down-scaling:
//! scale-up is always allowed, scale-down only once the grace period
//! expires; the period renews whenever measured RPS is within the
//! current engine's constraints.

use crate::config::EngineSpec;

/// Provisioning latency for a new engine instance, seconds
/// (paper: "significant provisioning latency (>20 s)").
pub const SPAWN_TIME_S: f64 = 25.0;

/// What the autoscaler decided at a tick.
#[derive(Debug, Clone, PartialEq)]
pub enum ScaleDecision {
    /// Keep the current engine.
    Hold,
    /// Begin shadow instancing toward `target` (index into the set).
    StartShadow { target: usize },
    /// Already shadowing; keep waiting.
    Shadowing,
}

/// In-flight shadow instance.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Shadow {
    pub target: usize,
    pub started_at: f64,
    pub ready_at: f64,
}

/// The autoscaler state machine.
#[derive(Debug, Clone)]
pub struct Autoscaler {
    specs: Vec<EngineSpec>,
    current: usize,
    shadow: Option<Shadow>,
    grace_until: f64,
    pub spawn_time_s: f64,
    pub interval_s: f64,
}

impl Autoscaler {
    /// `specs` ordered by capacity (ascending max load); serving starts
    /// on `initial`.
    pub fn new(specs: Vec<EngineSpec>, initial: usize) -> Self {
        assert!(!specs.is_empty() && initial < specs.len());
        assert!(
            specs
                .windows(2)
                .all(|w| w[0].max_load_rps <= w[1].max_load_rps),
            "scale set must be ordered by max load"
        );
        Self {
            specs,
            current: initial,
            shadow: None,
            // A fresh deployment gets a full grace period: scaling
            // DOWN before a single spawn time has elapsed would act on
            // less monitoring history than one provisioning takes.
            grace_until: SPAWN_TIME_S,
            spawn_time_s: SPAWN_TIME_S,
            interval_s: 10.0,
        }
    }

    pub fn specs(&self) -> &[EngineSpec] {
        &self.specs
    }

    pub fn current_index(&self) -> usize {
        self.current
    }

    pub fn current_spec(&self) -> &EngineSpec {
        &self.specs[self.current]
    }

    pub fn shadow(&self) -> Option<Shadow> {
        self.shadow
    }

    /// Smallest engine sustaining `rps` (falls back to the largest).
    pub fn desired_index(&self, rps: f64) -> usize {
        self.specs
            .iter()
            .position(|s| s.max_load_rps >= rps)
            .unwrap_or(self.specs.len() - 1)
    }

    /// Monitoring tick: measured RPS over the last interval.
    pub fn tick(&mut self, now: f64, measured_rps: f64) -> ScaleDecision {
        let desired = self.desired_index(measured_rps);

        // Renew the grace period while the current engine is the right
        // size for the load.
        if desired == self.current {
            self.grace_until = now + self.spawn_time_s;
        }

        if let Some(sh) = self.shadow {
            // May upgrade the in-flight target on a sudden spike
            // ("the autoscaler may switch to a larger engine ... but
            // may not switch to a smaller engine" during grace).
            if desired > sh.target {
                self.shadow = Some(Shadow {
                    target: desired,
                    started_at: now,
                    ready_at: now + self.spawn_time_s,
                });
                return ScaleDecision::StartShadow { target: desired };
            }
            return ScaleDecision::Shadowing;
        }

        if desired > self.current {
            // Scale-up: always allowed.
            self.shadow = Some(Shadow {
                target: desired,
                started_at: now,
                ready_at: now + self.spawn_time_s,
            });
            ScaleDecision::StartShadow { target: desired }
        } else if desired < self.current && now >= self.grace_until {
            // Scale-down: only after the grace period expires.
            self.shadow = Some(Shadow {
                target: desired,
                started_at: now,
                ready_at: now + self.spawn_time_s,
            });
            ScaleDecision::StartShadow { target: desired }
        } else {
            ScaleDecision::Hold
        }
    }

    /// Complete the transition if the shadow instance is ready;
    /// returns the new current index. The new engine receives a fresh
    /// grace period.
    pub fn poll_ready(&mut self, now: f64) -> Option<usize> {
        if let Some(sh) = self.shadow {
            if now >= sh.ready_at {
                self.current = sh.target;
                self.shadow = None;
                self.grace_until = now + self.spawn_time_s;
                return Some(self.current);
            }
        }
        None
    }

    /// Abort an in-flight shadow instance (used when the fleet axis
    /// deactivates a replica mid-transition: the warming engine is
    /// discarded, not adopted).
    pub fn cancel_shadow(&mut self) {
        self.shadow = None;
    }
}

/// What the fleet (replica-count) axis decided at a tick.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FleetDecision {
    /// Keep the current replica count.
    Hold,
    /// Spin up `count` more replicas (each pays the spawn time).
    Activate { count: usize },
    /// Drain and power off `count` replicas.
    Deactivate { count: usize },
}

/// The replica-count axis of the two-axis autoscaler (replica count x
/// TP size).  Each active replica still right-sizes its own tensor
/// parallelism through [`Autoscaler`] (shadow instancing per replica);
/// this state machine decides how many replicas should be active at
/// all, following the same grace-period discipline: scale-out is
/// immediate, scale-in only once a spawn time has elapsed without the
/// load justifying the current count.
#[derive(Debug, Clone)]
pub struct FleetScaler {
    pub max_replicas: usize,
    pub spawn_time_s: f64,
    pub interval_s: f64,
    grace_until: f64,
}

impl FleetScaler {
    pub fn new(max_replicas: usize) -> Self {
        assert!(max_replicas >= 1);
        Self {
            max_replicas,
            spawn_time_s: SPAWN_TIME_S,
            interval_s: 10.0,
            // Same boot-time grace as the TP axis: no scale-in before
            // one spawn time of history exists.
            grace_until: SPAWN_TIME_S,
        }
    }

    /// Replicas needed to sustain `rps` when one replica handles
    /// `per_replica_rps` (clamped to [1, max_replicas]).
    pub fn desired_replicas(&self, rps: f64, per_replica_rps: f64) -> usize {
        if per_replica_rps <= 0.0 {
            return self.max_replicas;
        }
        let need = (rps / per_replica_rps).ceil() as usize;
        need.clamp(1, self.max_replicas)
    }

    /// Monitoring tick: `provisioned` counts active replicas plus any
    /// already spinning up.
    pub fn tick(
        &mut self,
        now: f64,
        rps: f64,
        per_replica_rps: f64,
        provisioned: usize,
    ) -> FleetDecision {
        let desired = self.desired_replicas(rps, per_replica_rps);
        if desired >= provisioned {
            // The load justifies (at least) the current count: renew
            // the grace window — scale-in later must observe a full
            // spawn time of UNJUSTIFIED load, even right after a ramp
            // of consecutive Activate ticks.
            self.grace_until = now + self.spawn_time_s;
            return if desired > provisioned {
                FleetDecision::Activate {
                    count: desired - provisioned,
                }
            } else {
                FleetDecision::Hold
            };
        }
        if now >= self.grace_until {
            FleetDecision::Deactivate {
                count: provisioned - desired,
            }
        } else {
            FleetDecision::Hold
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;

    fn scaler() -> Autoscaler {
        Autoscaler::new(vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)], 0)
    }

    #[test]
    fn desired_index_matches_capacity() {
        let a = scaler();
        assert_eq!(a.desired_index(0.5), 0); // <= 1.125
        assert_eq!(a.desired_index(2.0), 1); // <= 4.0
        assert_eq!(a.desired_index(6.0), 2); // <= 7.5
        assert_eq!(a.desired_index(50.0), 2); // saturate at largest
    }

    #[test]
    fn scale_up_is_immediate() {
        let mut a = scaler();
        let d = a.tick(5.0, 3.0);
        assert_eq!(d, ScaleDecision::StartShadow { target: 1 });
        assert!(a.shadow().is_some());
        // Not current yet (warm-up).
        assert_eq!(a.current_index(), 0);
        assert!(a.poll_ready(10.0).is_none());
        assert_eq!(a.poll_ready(31.0), Some(1));
        assert_eq!(a.current_index(), 1);
    }

    #[test]
    fn scale_down_waits_for_grace_period() {
        let mut a = scaler();
        a.tick(0.0, 3.0); // start shadow to TP2
        a.poll_ready(25.0).unwrap();
        // load drops immediately; grace = 25 + 25 = until 50
        assert_eq!(a.tick(30.0, 0.5), ScaleDecision::Hold);
        assert_eq!(a.tick(40.0, 0.5), ScaleDecision::Hold);
        // Past the grace period: scale-down allowed.
        assert_eq!(
            a.tick(51.0, 0.5),
            ScaleDecision::StartShadow { target: 0 }
        );
    }

    #[test]
    fn grace_renewed_while_rightsized() {
        let mut a = scaler();
        a.tick(0.0, 3.0);
        a.poll_ready(25.0).unwrap(); // now TP2, grace until 50
        // At 40 s, the load matches TP2 -> grace renews to 65.
        assert_eq!(a.tick(40.0, 3.0), ScaleDecision::Hold);
        // At 55 (pre-65), a drop cannot downscale yet.
        assert_eq!(a.tick(55.0, 0.5), ScaleDecision::Hold);
        // At 66, it can.
        assert_eq!(
            a.tick(66.0, 0.5),
            ScaleDecision::StartShadow { target: 0 }
        );
    }

    #[test]
    fn spike_during_shadow_upgrades_target() {
        let mut a = scaler();
        a.tick(0.0, 3.0); // shadow -> TP2
        let d = a.tick(10.0, 7.0); // spike needing TP4
        assert_eq!(d, ScaleDecision::StartShadow { target: 2 });
        assert_eq!(a.poll_ready(36.0), Some(2));
    }

    #[test]
    fn shadowing_reported_while_warming() {
        let mut a = scaler();
        a.tick(0.0, 3.0);
        assert_eq!(a.tick(10.0, 3.0), ScaleDecision::Shadowing);
    }

    #[test]
    #[should_panic(expected = "ordered by max load")]
    fn rejects_unordered_scale_set() {
        Autoscaler::new(vec![llama2_13b(4), llama2_13b(1)], 0);
    }

    #[test]
    fn no_scale_down_before_spawn_time_even_at_boot() {
        // Start on the LARGEST engine: a load drop right after boot
        // must not trigger a down-scale before SPAWN_TIME_S elapses.
        let mut a = Autoscaler::new(vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)], 2);
        assert_eq!(a.tick(5.0, 0.5), ScaleDecision::Hold);
        assert_eq!(a.tick(SPAWN_TIME_S - 1.0, 0.5), ScaleDecision::Hold);
        assert_eq!(
            a.tick(SPAWN_TIME_S + 1.0, 0.5),
            ScaleDecision::StartShadow { target: 0 }
        );
    }

    #[test]
    fn cancel_shadow_discards_transition() {
        let mut a = scaler();
        a.tick(0.0, 3.0);
        assert!(a.shadow().is_some());
        a.cancel_shadow();
        assert!(a.shadow().is_none());
        assert!(a.poll_ready(100.0).is_none());
        assert_eq!(a.current_index(), 0);
    }

    #[test]
    fn fleet_desired_replicas_clamps() {
        let f = FleetScaler::new(4);
        assert_eq!(f.desired_replicas(0.0, 4.0), 1);
        assert_eq!(f.desired_replicas(3.9, 4.0), 1);
        assert_eq!(f.desired_replicas(4.1, 4.0), 2);
        assert_eq!(f.desired_replicas(100.0, 4.0), 4);
        assert_eq!(f.desired_replicas(1.0, 0.0), 4, "unknown capacity -> max");
    }

    #[test]
    fn fleet_scale_out_is_immediate_scale_in_waits() {
        let mut f = FleetScaler::new(4);
        // Load spike at boot: activate immediately.
        assert_eq!(
            f.tick(5.0, 16.0, 4.0, 1),
            FleetDecision::Activate { count: 3 }
        );
        // Load drop while all four run: no deactivation inside grace.
        assert_eq!(f.tick(10.0, 2.0, 4.0, 4), FleetDecision::Hold);
        // Right-sized tick renews the grace window.
        assert_eq!(f.tick(20.0, 15.0, 4.0, 4), FleetDecision::Hold);
        // Drop again: still inside the renewed grace (20 + 25 = 45).
        assert_eq!(f.tick(40.0, 2.0, 4.0, 4), FleetDecision::Hold);
        // Past it: drain three replicas.
        assert_eq!(
            f.tick(46.0, 2.0, 4.0, 4),
            FleetDecision::Deactivate { count: 3 }
        );
    }

    #[test]
    fn fleet_activate_ticks_renew_grace() {
        // A sustained ramp (every tick demanding MORE replicas) must
        // keep renewing the grace window: the load drop right after
        // the ramp may not trigger an immediate scale-in.
        let mut f = FleetScaler::new(4);
        assert_eq!(
            f.tick(30.0, 5.0, 4.0, 1),
            FleetDecision::Activate { count: 1 }
        );
        assert_eq!(
            f.tick(40.0, 9.0, 4.0, 2),
            FleetDecision::Activate { count: 1 }
        );
        assert_eq!(
            f.tick(50.0, 16.0, 4.0, 3),
            FleetDecision::Activate { count: 1 }
        );
        // Collapse at t=60: the last Activate renewed grace to 75.
        assert_eq!(f.tick(60.0, 0.5, 4.0, 4), FleetDecision::Hold);
        assert_eq!(
            f.tick(76.0, 0.5, 4.0, 4),
            FleetDecision::Deactivate { count: 3 }
        );
    }

    #[test]
    fn fleet_no_scale_in_before_spawn_time_at_boot() {
        let mut f = FleetScaler::new(4);
        assert_eq!(f.tick(5.0, 0.5, 4.0, 4), FleetDecision::Hold);
        assert_eq!(
            f.tick(SPAWN_TIME_S + 1.0, 0.5, 4.0, 4),
            FleetDecision::Deactivate { count: 3 }
        );
    }
}
