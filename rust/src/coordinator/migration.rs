//! Live KV migration of resident requests (fleet-axis scale-in).
//!
//! Drain-based scale-in keeps the victim replica powered (and often at
//! high frequency — lost residents pin it to the peak setting) until
//! its last resident finishes, burning the energy the instance-scaling
//! axis was supposed to save.  With migration enabled, the victim's
//! residents are checkpointed ([`crate::engine::KvCheckpoint`]: KV
//! block ownership + generation progress) and restored onto the
//! best-fit surviving replica, paying a modeled transfer latency and
//! link energy ([`crate::config::MigrationSpec`]); the victim goes
//! idle immediately and powers off.
//!
//! Every move is gated by an **SLO guard**: the request is migrated
//! only if the destination's §IV-B projection — with the migrated
//! entry applied as a candidate — predicts (at maximum frequency, the
//! same optimistic bound admission control uses) that
//!
//!   1. the destination's KV capacity is never exceeded,
//!   2. the destination's mean-TBT SLO still holds,
//!   3. the migrated request still meets its own E2E deadline AFTER
//!      the modeled transfer stall, and
//!   4. no destination resident that was previously on track is newly
//!      pushed past its deadline (residents already doomed without the
//!      candidate do not block the move, mirroring §IV-C2's
//!      blame-the-candidate rule).
//!
//! A modeled transfer stall at or beyond the destination's whole E2E
//! budget additionally refuses unconditionally — this also bounds
//! "lost" candidates, whose own deadline check is waived.
//!
//! A refused request simply stays on the victim and drains — migration
//! is an optimization, never a correctness requirement.  The scoreboard
//! moves ride the existing strike/insert paths, so the delta journal
//! and [`crate::coordinator::projection::ProjectionTracker`] stay
//! coherent on both ends without special cases.

use crate::config::{EngineSpec, SloSpec};
use crate::coordinator::perf_model::PerfModel;
use crate::coordinator::projection::ProjectionTracker;
use crate::coordinator::scoreboard::{Entry, Scoreboard};
use crate::gpusim::dvfs::FREQ_MAX_MHZ;

/// Fleet-level migration telemetry (one per `serve_fleet_plan` run).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct MigrationCounters {
    /// Requests successfully live-migrated.
    pub migrations: u64,
    /// Moves refused by the destination-side SLO guard.
    pub refused_slo: u64,
    /// Moves refused for lack of destination KV blocks / batch slots
    /// (or no eligible destination at all).
    pub refused_capacity: u64,
}

/// The scoreboard entry a migrated request carries on its destination.
///
/// Anchoring `scheduled_iter` at `dest_iter - generated` keeps the
/// entry in the same TOTAL-progress coordinates the engine reports
/// (`ceil((j - s_i + |q_i|)/N)` then matches the physical occupancy
/// `prompt + generated + (j - k)`, and §IV-F overrun syncs compare
/// like with like).  When the destination engine is younger than the
/// request's age in iterations the anchor saturates at 0 and the
/// projection under-counts the first `generated - dest_iter` tokens —
/// a bounded, conservative-in-batch corner documented here rather than
/// special-cased.
pub fn migration_entry(src: &Entry, generated: u32, dest_iter: u64) -> Entry {
    Entry {
        id: src.id,
        scheduled_iter: dest_iter.saturating_sub(generated as u64),
        prompt_tokens: src.prompt_tokens,
        // Keep the source's (conservatively adjusted, possibly bumped)
        // prediction, floored above the tokens already generated so the
        // entry still projects remaining work.
        predicted_gen: src.predicted_gen.max(generated.saturating_add(1)),
        deadline_s: src.deadline_s,
        lost: src.lost,
        // A migrated resident of a shared prefix COPIES its blocks to
        // the destination (it may re-share there, but the projection
        // stays conservative and books the full footprint).
        kv_discount_blocks: 0,
    }
}

/// The destination-side SLO guard (checks 1-4 of the module docs).
///
/// `cand` must be a [`migration_entry`] for the destination's current
/// iteration `k`; `stall_s` is the modeled transfer latency during
/// which the migrated request produces no tokens.  Runs off the
/// destination's incrementally maintained tracker (the candidate is
/// applied and exactly undone), so the guard itself leaves no state
/// behind.  This is the cold scale-in path — allocations here are
/// fine.
#[allow(clippy::too_many_arguments)]
pub fn migration_slo_guard(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    sb: &Scoreboard,
    tracker: &mut ProjectionTracker,
    k: u64,
    now: f64,
    cand: &Entry,
    stall_s: f64,
) -> bool {
    // A transfer stall longer than the destination's whole E2E budget
    // can never pay off — by then the victim could have drained the
    // request.  This also bounds "lost" candidates, whose own
    // deadline check below is waived.
    if stall_s >= slo.e2e_p99 {
        return false;
    }
    let proj = tracker.project(sb, k, Some(cand)).clone();
    // Check 1: projected KV never exceeds the destination pool.
    if proj.peak_kv() > spec.kv_blocks {
        return false;
    }
    if proj.horizon() == 0 {
        // Nothing projected to run (e.g. the candidate is all but
        // finished): nothing can be violated.
        return true;
    }
    let t = model.throughput_vector(spec, &proj, FREQ_MAX_MHZ);
    let t_r = PerfModel::remaining_time_vector(&t);
    // Check 2: mean TBT over the with-candidate horizon.
    let mean_tbt = t_r[t_r.len() - 1] / t_r.len() as f64;
    if mean_tbt > slo.tbt_avg {
        return false;
    }
    // Check 3: the migrated request's own deadline, transfer stall
    // included ("lost" requests have already waived it).
    if !cand.lost {
        if let Some(idx) = proj.completion_index(cand.scheduled_iter, cand.predicted_gen)
        {
            if now + stall_s + t_r[idx] >= cand.deadline_s {
                return false;
            }
        }
    }
    // Check 4: destination residents newly pushed past their deadlines.
    let broken: Vec<&Entry> = sb
        .committed()
        .iter()
        .filter(|e| !e.lost)
        .filter(|e| match proj.completion_index(e.scheduled_iter, e.predicted_gen) {
            Some(idx) => now + t_r[idx] >= e.deadline_s,
            None => false,
        })
        .collect();
    if broken.is_empty() {
        return true;
    }
    // Were they already doomed WITHOUT the candidate?  Only newly
    // caused violations block the move (§IV-C2 blame rule).
    let proj_wo = tracker.project(sb, k, None).clone();
    if proj_wo.horizon() == 0 {
        return false; // they ran fine alone: the candidate broke them
    }
    let t_wo = model.throughput_vector(spec, &proj_wo, FREQ_MAX_MHZ);
    let t_r_wo = PerfModel::remaining_time_vector(&t_wo);
    broken.into_iter().all(|e| {
        match proj_wo.completion_index(e.scheduled_iter, e.predicted_gen) {
            Some(idx) => now + t_r_wo[idx] >= e.deadline_s, // doomed anyway
            None => true,
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;

    fn entry(id: u64, s: u64, prompt: u32, pred: u32, deadline: f64) -> Entry {
        Entry {
            id,
            scheduled_iter: s,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: deadline,
            lost: false,
            kv_discount_blocks: 0,
        }
    }

    fn setup() -> (PerfModel, EngineSpec, SloSpec) {
        let e = llama2_13b(2);
        (
            PerfModel::train(&[e.clone()], 40, 0),
            e,
            SloSpec::new(0.2, 30.2),
        )
    }

    #[test]
    fn migration_entry_anchors_total_progress() {
        let src = entry(7, 100, 640, 200, 25.0);
        // 80 tokens generated, destination at iteration 500.
        let m = migration_entry(&src, 80, 500);
        assert_eq!(m.id, 7);
        assert_eq!(m.scheduled_iter, 420);
        assert_eq!(m.prompt_tokens, 640);
        assert_eq!(m.predicted_gen, 200);
        assert_eq!(m.end_iter(), 420 + 200); // 120 iterations remain
        assert_eq!(m.deadline_s, 25.0);
        // Prediction already outrun: floored above `generated`.
        let m = migration_entry(&entry(8, 0, 64, 50, 25.0), 90, 500);
        assert_eq!(m.predicted_gen, 91);
        // Young destination engine: anchor saturates at zero.
        let m = migration_entry(&src, 80, 10);
        assert_eq!(m.scheduled_iter, 0);
    }

    #[test]
    fn guard_accepts_easy_move_and_refuses_tight_deadline() {
        let (model, spec, slo) = setup();
        let mut sb = Scoreboard::new();
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        sb.insert(entry(1, 0, 200, 100, 1e9));
        // Comfortable deadline: the move passes even with a stall.
        let cand = migration_entry(&entry(9, 0, 400, 150, 1000.0), 40, 0);
        assert!(migration_slo_guard(
            &model, &spec, &slo, &sb, &mut tracker, 0, 0.0, &cand, 0.5,
        ));
        // Same request, deadline only just ahead: a 10 s transfer
        // stall pushes it past -> refused.
        let cand = migration_entry(&entry(9, 0, 400, 150, 8.0), 40, 0);
        assert!(!migration_slo_guard(
            &model, &spec, &slo, &sb, &mut tracker, 0, 0.0, &cand, 10.0,
        ));
        // The guard left no state behind: an unrelated easy candidate
        // still passes, and the tracker still matches from-scratch.
        let cand = migration_entry(&entry(10, 0, 100, 50, 1e9), 10, 0);
        assert!(migration_slo_guard(
            &model, &spec, &slo, &sb, &mut tracker, 0, 0.0, &cand, 0.1,
        ));
    }

    #[test]
    fn guard_refuses_kv_overflow() {
        let (model, spec, slo) = setup();
        let mut sb = Scoreboard::new();
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        // Destination already holds a large resident; the candidate's
        // projected KV would overflow the 439-block pool.
        sb.insert(entry(1, 0, 20_000, 900, 1e9));
        let cand = migration_entry(&entry(9, 0, 8_000, 900, 1e9), 10, 0);
        assert!(!migration_slo_guard(
            &model, &spec, &slo, &sb, &mut tracker, 0, 0.0, &cand, 0.1,
        ));
    }

    #[test]
    fn guard_protects_on_track_residents_but_not_doomed_ones() {
        let (model, spec, slo) = setup();
        // Eight residents finishing just inside their deadlines; a
        // large migrated batch-mate pushes them over -> refused.
        let mut sb = Scoreboard::new();
        for id in 0..8 {
            sb.insert(entry(id, 0, 1000, 600, 1e9));
        }
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        let proj = tracker.project(&sb, 0, None).clone();
        let t = model.throughput_vector(&spec, &proj, FREQ_MAX_MHZ);
        let t_r = PerfModel::remaining_time_vector(&t);
        let alone = *t_r.last().unwrap();
        let deadline = alone * 1.025;
        let mut sb = Scoreboard::new();
        for id in 0..8 {
            sb.insert(entry(id, 0, 1000, 600, deadline));
        }
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        let cand = migration_entry(&entry(99, 0, 4000, 1024, 1e9), 100, 0);
        assert!(!migration_slo_guard(
            &model, &spec, &slo, &sb, &mut tracker, 0, 0.0, &cand, 0.1,
        ));
        // The same residents with deadlines ALREADY hopeless do not
        // block the move (they are doomed with or without it).
        let mut sb = Scoreboard::new();
        for id in 0..8 {
            sb.insert(entry(id, 0, 1000, 600, 0.001));
        }
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        assert!(migration_slo_guard(
            &model, &spec, &slo, &sb, &mut tracker, 0, 5.0, &cand, 0.1,
        ));
    }

    #[test]
    fn lost_candidate_skips_own_deadline_check() {
        let (model, spec, slo) = setup();
        let mut sb = Scoreboard::new();
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        sb.insert(entry(1, 0, 200, 100, 1e9));
        // Deadline long gone, but the request is lost (SLO waived):
        // moving it off the victim is still allowed.
        let mut src = entry(9, 0, 400, 150, 0.001);
        src.lost = true;
        let cand = migration_entry(&src, 40, 0);
        assert!(cand.lost);
        assert!(migration_slo_guard(
            &model, &spec, &slo, &sb, &mut tracker, 0, 5.0, &cand, 1.0,
        ));
    }

    #[test]
    fn stall_beyond_e2e_budget_refuses_even_lost_candidates() {
        let (model, spec, slo) = setup();
        let sb = Scoreboard::new();
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        let mut src = entry(9, 0, 400, 150, 1e9);
        src.lost = true;
        let cand = migration_entry(&src, 40, 0);
        // At the budget (30.2 s): refused regardless of lost status.
        assert!(!migration_slo_guard(
            &model,
            &spec,
            &slo,
            &sb,
            &mut tracker,
            0,
            0.0,
            &cand,
            slo.e2e_p99,
        ));
        // Just under it: the lost candidate moves.
        assert!(migration_slo_guard(
            &model,
            &spec,
            &slo,
            &sb,
            &mut tracker,
            0,
            0.0,
            &cand,
            slo.e2e_p99 * 0.5,
        ));
    }

    #[test]
    fn empty_destination_accepts() {
        let (model, spec, slo) = setup();
        let sb = Scoreboard::new();
        let mut tracker = ProjectionTracker::new(spec.block_tokens);
        let cand = migration_entry(&entry(9, 0, 400, 150, 1000.0), 40, 0);
        assert!(migration_slo_guard(
            &model,
            &spec,
            &slo,
            &sb,
            &mut tracker,
            0,
            0.0,
            &cand,
            0.5,
        ));
    }
}
