//! The throttLL'eM coordinator (paper §IV) — the system contribution.
//!
//! Components, mirroring Fig. 6:
//!   * [`scoreboard`]: per-query metadata `(s_i, |q_i|, |r̂_i|)` with
//!     virtual append / commit / rollback for admission-control
//!     what-ifs (§IV-B);
//!   * [`projection`]: the analytical model producing the future batch
//!     (`B`) and KV-usage (`KV`) vectors — Eq. (1), (2);
//!   * [`perf_model`]: the GBDT `M` predicting iteration-level IPS
//!     from (engine size, batch, KV, frequency), plus the throughput /
//!     TBT / cumulative-time vectors `T`, `T'`, `T_R` — Eq. (3);
//!   * [`scheduler`]: three-check admission control (KV capacity, TBT
//!     SLO, E2E SLO) with "lost" marking (§IV-C2);
//!   * [`throttle`]: binary search for the minimum SLO-satisfying GPU
//!     frequency (§IV-E);
//!   * [`autoscaler`]: TP right-sizing with shadow instancing and the
//!     grace-period policy (§IV-D), plus the fleet (replica-count)
//!     axis of the two-axis autoscaler;
//!   * [`router`]: the fleet admission router (round-robin /
//!     least-loaded / projected-headroom);
//!   * [`migration`]: live KV migration of resident requests on
//!     fleet-axis scale-in (checkpoint/restore semantics with a
//!     destination-side SLO guard and modeled transfer costs);
//!   * [`server`]: the event loop wiring everything to the engine —
//!     generalized to an N-replica fleet coordinator — and the
//!     Triton-like baseline policies the paper compares against;
//!   * [`shard`]: the per-replica stepping state (`Replica`) and the
//!     deterministic worker pool that parallelizes the RUN phase
//!     across threads, bit-identical to single-threaded execution.

pub mod autoscaler;
pub mod migration;
pub mod perf_model;
pub mod projection;
pub mod router;
pub mod scheduler;
pub mod scoreboard;
pub mod server;
pub mod shard;
pub mod throttle;

pub use migration::MigrationCounters;
pub use perf_model::{PerfModel, PredMemo};
pub use projection::{Projection, ProjectionTracker};
pub use router::{HeadroomCache, RouterPolicy};
pub use scheduler::{AdmissionDecision, EvalScratch, Scheduler};
pub use scoreboard::Scoreboard;
pub use server::{
    outcome_digest, scenario_params, serve_fleet, serve_fleet_plan, serve_scenario, serve_trace,
    FamilyStats, FleetOutcome, FleetPlan, FleetSpec, Policy, PredictCounters, ReplicaOutcome,
    ServeOutcome, Workload,
};
pub use shard::effective_threads;
