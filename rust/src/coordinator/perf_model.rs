//! The performance-prediction model `M` (paper §IV-C1) and the derived
//! throughput/TBT/remaining-time vectors (Eq. 3).
//!
//! `M` is a GBDT over (engine size, batch, KV blocks, frequency) -> IPS,
//! trained on profiler data (`workload::profiler`).  The scheduler
//! queries it per projected future iteration; `t_r` cumulatively sums
//! predicted TBTs to estimate arrival times of future iterations.

// Reviewed HashMap use: the prediction memo is keyed lookup only with
// a deterministic custom hasher and is never iterated (detlint r2
// enforces that), so hash order cannot reach FleetOutcome.
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;
use std::hash::{BuildHasherDefault, Hasher};

use crate::config::EngineSpec;
use crate::coordinator::projection::Projection;
use crate::mlmodel::{Gbdt, GbdtParams};
use crate::workload::profiler::{collect_training_data, features};

/// Multiplicative hasher for the packed `(freq, batch, kv-bucket)`
/// memo keys (std's SipHash costs more than a small GBDT tree here).
#[derive(Debug, Clone, Default)]
pub struct PredKeyHasher(u64);

impl Hasher for PredKeyHasher {
    fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 = (self.0 ^ b as u64).wrapping_mul(0x100_0000_01b3);
        }
    }
    fn write_u64(&mut self, x: u64) {
        self.0 = (self.0 ^ x).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    }
    fn finish(&self) -> u64 {
        self.0 ^ (self.0 >> 31)
    }
}

/// Memoized GBDT inferences keyed by packed `(freq, batch,
/// kv-bucket)`.  Within one SLO evaluation this subsumes the
/// consecutive-run reuse `throughput_vector` always performed; held
/// across the probes of one §IV-E bisection (the projection is fixed
/// within a search, and the frequency is part of the key) it makes
/// repeated evaluations of the same operating state nearly free.
///
/// The kv-bucket quantization (~1.5% of capacity) is the SAME
/// approximation `throughput_vector` already applied; the memo only
/// widens its reuse window.  Owners must clear the memo whenever the
/// underlying committed entry set or iteration changes
/// (`EvalScratch::ensure_stamp` does this).
#[derive(Debug, Clone, Default)]
pub struct PredMemo {
    map: HashMap<u64, f64, BuildHasherDefault<PredKeyHasher>>,
}

impl PredMemo {
    pub fn clear(&mut self) {
        self.map.clear();
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    #[inline]
    fn key(freq_mhz: u32, batch: u32, kv_bucket_idx: u32) -> u64 {
        ((freq_mhz as u64) << 40) | ((batch as u64) << 20) | kv_bucket_idx as u64
    }
}

/// The wrapped model `M` for one deployment (covers every engine size
/// it was trained on — engine size is a feature).
#[derive(Debug, Clone)]
pub struct PerfModel {
    model: Gbdt,
    /// Predict every `stride`-th future iteration and interpolate —
    /// a hot-path optimization; 1 = exact.
    pub stride: usize,
}

impl PerfModel {
    pub fn from_gbdt(model: Gbdt) -> Self {
        Self { model, stride: 4 }
    }

    /// Train on profiling data from the given engines (paper: "this
    /// data collection process is repeated for all supported TP
    /// levels").
    pub fn train(engines: &[EngineSpec], samples_per_batch: u32, seed: u64) -> Self {
        let mut data = crate::mlmodel::Dataset::new();
        for e in engines {
            let d = collect_training_data(e, samples_per_batch, seed);
            for (f, t) in d.features.into_iter().zip(d.targets) {
                data.push(f, t);
            }
        }
        let params = GbdtParams {
            n_trees: 150,
            learning_rate: 0.12,
            ..Default::default()
        };
        Self::from_gbdt(Gbdt::fit(&data, &params))
    }

    /// Train directly on a prepared dataset (Table III protocol).
    pub fn train_on(data: &crate::mlmodel::Dataset) -> Self {
        let params = GbdtParams {
            n_trees: 150,
            learning_rate: 0.12,
            ..Default::default()
        };
        Self::from_gbdt(Gbdt::fit(data, &params))
    }

    /// Predict from a raw feature row
    /// [engine size, batch, kv_blocks, freq_mhz].
    pub fn predict_raw(&self, row: &[f64]) -> f64 {
        self.model.predict(row)
    }

    /// Predict IPS for one state.
    pub fn predict_ips(
        &self,
        spec: &EngineSpec,
        batch: u32,
        kv_blocks: u32,
        freq_mhz: u32,
    ) -> f64 {
        self.model
            .predict(&features(spec, batch, kv_blocks, freq_mhz))
            .max(1e-3)
    }

    /// Vector T: predicted IPS for each projected future iteration at
    /// frequency `freq_mhz` (paper §IV-C2 step 2). Iterations where
    /// the batch is empty inherit the previous prediction.
    ///
    /// Hot-path optimizations (EXPERIMENTS.md §Perf): predictions run
    /// at `stride` granularity, and consecutive stride points whose
    /// (batch, KV-bucket) state is unchanged reuse the previous GBDT
    /// inference — KV grows by ~batch/N blocks per iteration, so long
    /// stretches of the horizon share a prediction.
    pub fn throughput_vector(
        &self,
        spec: &EngineSpec,
        proj: &Projection,
        freq_mhz: u32,
    ) -> Vec<f64> {
        let mut memo = PredMemo::default();
        let mut t = Vec::new();
        self.throughput_vector_into(spec, proj, freq_mhz, &mut memo, &mut t);
        t
    }

    /// [`Self::throughput_vector`] into a reusable buffer, with GBDT
    /// inferences memoized per (freq, batch, kv-bucket) in `memo` —
    /// the allocation-free steady-path variant.  For serving-shaped
    /// projections (batch non-increasing, KV monotone within each
    /// constant-batch run) the memo reproduces the consecutive-run
    /// reuse exactly; held across calls under an unchanged entry set
    /// it additionally eliminates repeated inference entirely.
    pub fn throughput_vector_into(
        &self,
        spec: &EngineSpec,
        proj: &Projection,
        freq_mhz: u32,
        memo: &mut PredMemo,
        out: &mut Vec<f64>,
    ) {
        let n = proj.horizon();
        out.clear();
        if n == 0 {
            return;
        }
        out.resize(n, 0.0);
        // KV quantization for prediction reuse: ~1.5% of capacity.
        let kv_bucket = (spec.kv_blocks / 64).max(1);
        let stride = self.stride.max(1);
        let mut i = 0;
        let mut last_key = u64::MAX;
        let k0 = PredMemo::key(
            freq_mhz,
            proj.batch[0].max(1),
            proj.kv_blocks[0] / kv_bucket,
        );
        let mut last = match memo.map.get(&k0) {
            Some(&v) => v,
            None => {
                let v = self.predict_ips(
                    spec,
                    proj.batch[0].max(1),
                    proj.kv_blocks[0],
                    freq_mhz,
                );
                memo.map.insert(k0, v);
                v
            }
        };
        while i < n {
            let b = proj.batch[i];
            if b != 0 {
                let key = PredMemo::key(freq_mhz, b, proj.kv_blocks[i] / kv_bucket);
                if key != last_key {
                    last = match memo.map.get(&key) {
                        Some(&v) => v,
                        None => {
                            let v = self.predict_ips(
                                spec,
                                b,
                                proj.kv_blocks[i],
                                freq_mhz,
                            );
                            memo.map.insert(key, v);
                            v
                        }
                    };
                    last_key = key;
                }
            }
            let hi = (i + stride).min(n);
            for v in &mut out[i..hi] {
                *v = last;
            }
            i = hi;
        }
    }

    /// T' = 1/T (TBT per iteration) and T_R = cumulative sum of T'
    /// (estimated time to REACH each future iteration — Eq. 3).
    pub fn remaining_time_vector(t: &[f64]) -> Vec<f64> {
        let mut out = Vec::new();
        Self::remaining_time_into(t, &mut out);
        out
    }

    /// [`Self::remaining_time_vector`] into a reusable buffer.
    pub fn remaining_time_into(t: &[f64], out: &mut Vec<f64>) {
        out.clear();
        out.reserve(t.len());
        let mut acc = 0.0;
        for &ips in t {
            acc += 1.0 / ips;
            out.push(acc);
        }
    }

    /// Mean TBT over the horizon (the §IV-C2 TBT check statistic).
    pub fn mean_tbt(t: &[f64]) -> f64 {
        if t.is_empty() {
            return 0.0;
        }
        t.iter().map(|&ips| 1.0 / ips).sum::<f64>() / t.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::coordinator::projection::Projection;
    use crate::gpusim::latency::{ips, GpuState};

    fn model() -> (PerfModel, EngineSpec) {
        let e = llama2_13b(2);
        (PerfModel::train(&[e.clone()], 60, 0), e)
    }

    #[test]
    fn predictions_track_ground_truth() {
        let (m, e) = model();
        // Interior points: tight tolerance; the all-dims-extreme corner
        // (max batch, near-full KV, min frequency) is the sparsest part
        // of the profiling space and gets a looser bound.
        for (b, kv, f, tol) in [
            (1u32, 10u32, 1410u32, 0.15),
            (16, 200, 900, 0.15),
            (32, 420, 210, 0.30),
        ] {
            let truth = ips(
                &e,
                &GpuState {
                    batch: b,
                    kv_blocks: kv,
                    freq_mhz: f,
                },
            );
            let pred = m.predict_ips(&e, b, kv, f);
            let rel = (pred - truth).abs() / truth;
            assert!(rel < tol, "b={b} kv={kv} f={f}: {pred} vs {truth}");
        }
    }

    #[test]
    fn ips_increases_with_frequency() {
        let (m, e) = model();
        let lo = m.predict_ips(&e, 16, 200, 210);
        let hi = m.predict_ips(&e, 16, 200, 1410);
        assert!(hi > lo * 1.3, "hi={hi} lo={lo}");
    }

    #[test]
    fn throughput_vector_follows_projection() {
        let (m, e) = model();
        let proj = Projection {
            start_iter: 1,
            batch: vec![8; 16],
            kv_blocks: (0..16).map(|i| 20 * (i as u32 + 1)).collect(),
            ..Default::default()
        };
        let t = m.throughput_vector(&e, &proj, 1410);
        assert_eq!(t.len(), 16);
        // Growing KV -> falling throughput (weak monotonicity over
        // stride boundaries).
        assert!(t[0] >= t[15], "t0={} t15={}", t[0], t[15]);
        assert!(t.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn remaining_time_is_cumulative() {
        let t = vec![50.0, 25.0, 10.0];
        let tr = PerfModel::remaining_time_vector(&t);
        assert!((tr[0] - 0.02).abs() < 1e-12);
        assert!((tr[1] - 0.06).abs() < 1e-12);
        assert!((tr[2] - 0.16).abs() < 1e-12);
    }

    #[test]
    fn mean_tbt_matches_hand_calc() {
        let t = vec![50.0, 25.0];
        assert!((PerfModel::mean_tbt(&t) - 0.03).abs() < 1e-12);
        assert_eq!(PerfModel::mean_tbt(&[]), 0.0);
    }

    #[test]
    fn memoized_vector_matches_and_reuses_inferences() {
        let (m, e) = model();
        let proj = Projection {
            start_iter: 1,
            batch: vec![8; 64],
            kv_blocks: (0..64).map(|i| 6 * i as u32 + 40).collect(),
            ..Default::default()
        };
        let plain = m.throughput_vector(&e, &proj, 1050);
        let mut memo = PredMemo::default();
        let mut out = Vec::new();
        m.throughput_vector_into(&e, &proj, 1050, &mut memo, &mut out);
        assert_eq!(plain.len(), out.len());
        for (a, b) in plain.iter().zip(&out) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // Second pass over the same projection: every stride point
        // hits the memo, and the output is bit-identical.
        let before = memo.len();
        assert!(before > 0);
        let mut out2 = Vec::new();
        m.throughput_vector_into(&e, &proj, 1050, &mut memo, &mut out2);
        assert_eq!(memo.len(), before, "second pass must not re-infer");
        for (a, b) in out.iter().zip(&out2) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        // A different frequency keys separately.
        m.throughput_vector_into(&e, &proj, 800, &mut memo, &mut out2);
        assert!(memo.len() > before);
    }

    #[test]
    fn stride_one_and_four_agree_closely() {
        let (mut m, e) = model();
        let proj = Projection {
            start_iter: 1,
            batch: vec![16; 64],
            kv_blocks: (0..64).map(|i| 5 * i as u32 + 50).collect(),
            ..Default::default()
        };
        m.stride = 1;
        let exact = m.throughput_vector(&e, &proj, 1050);
        m.stride = 4;
        let fast = m.throughput_vector(&e, &proj, 1050);
        let tr_a = PerfModel::remaining_time_vector(&exact);
        let tr_b = PerfModel::remaining_time_vector(&fast);
        let rel = (tr_a.last().unwrap() - tr_b.last().unwrap()).abs()
            / tr_a.last().unwrap();
        assert!(rel < 0.02, "rel={rel}");
    }
}
