//! The performance-prediction model `M` (paper §IV-C1) and the derived
//! throughput/TBT/remaining-time vectors (Eq. 3).
//!
//! `M` is a GBDT over (engine size, batch, KV blocks, frequency) -> IPS,
//! trained on profiler data (`workload::profiler`).  The scheduler
//! queries it per projected future iteration; `t_r` cumulatively sums
//! predicted TBTs to estimate arrival times of future iterations.

use crate::config::EngineSpec;
use crate::coordinator::projection::Projection;
use crate::mlmodel::{Gbdt, GbdtParams};
use crate::workload::profiler::{collect_training_data, features};

/// The wrapped model `M` for one deployment (covers every engine size
/// it was trained on — engine size is a feature).
#[derive(Debug, Clone)]
pub struct PerfModel {
    model: Gbdt,
    /// Predict every `stride`-th future iteration and interpolate —
    /// a hot-path optimization; 1 = exact.
    pub stride: usize,
}

impl PerfModel {
    pub fn from_gbdt(model: Gbdt) -> Self {
        Self { model, stride: 4 }
    }

    /// Train on profiling data from the given engines (paper: "this
    /// data collection process is repeated for all supported TP
    /// levels").
    pub fn train(engines: &[EngineSpec], samples_per_batch: u32, seed: u64) -> Self {
        let mut data = crate::mlmodel::Dataset::new();
        for e in engines {
            let d = collect_training_data(e, samples_per_batch, seed);
            for (f, t) in d.features.into_iter().zip(d.targets) {
                data.push(f, t);
            }
        }
        let params = GbdtParams {
            n_trees: 150,
            learning_rate: 0.12,
            ..Default::default()
        };
        Self::from_gbdt(Gbdt::fit(&data, &params))
    }

    /// Train directly on a prepared dataset (Table III protocol).
    pub fn train_on(data: &crate::mlmodel::Dataset) -> Self {
        let params = GbdtParams {
            n_trees: 150,
            learning_rate: 0.12,
            ..Default::default()
        };
        Self::from_gbdt(Gbdt::fit(data, &params))
    }

    /// Predict from a raw feature row
    /// [engine size, batch, kv_blocks, freq_mhz].
    pub fn predict_raw(&self, row: &[f64]) -> f64 {
        self.model.predict(row)
    }

    /// Predict IPS for one state.
    pub fn predict_ips(
        &self,
        spec: &EngineSpec,
        batch: u32,
        kv_blocks: u32,
        freq_mhz: u32,
    ) -> f64 {
        self.model
            .predict(&features(spec, batch, kv_blocks, freq_mhz))
            .max(1e-3)
    }

    /// Vector T: predicted IPS for each projected future iteration at
    /// frequency `freq_mhz` (paper §IV-C2 step 2). Iterations where
    /// the batch is empty inherit the previous prediction.
    ///
    /// Hot-path optimizations (EXPERIMENTS.md §Perf): predictions run
    /// at `stride` granularity, and consecutive stride points whose
    /// (batch, KV-bucket) state is unchanged reuse the previous GBDT
    /// inference — KV grows by ~batch/N blocks per iteration, so long
    /// stretches of the horizon share a prediction.
    pub fn throughput_vector(
        &self,
        spec: &EngineSpec,
        proj: &Projection,
        freq_mhz: u32,
    ) -> Vec<f64> {
        let n = proj.horizon();
        let mut t = vec![0.0; n];
        if n == 0 {
            return t;
        }
        // KV quantization for prediction reuse: ~1.5% of capacity.
        let kv_bucket = (spec.kv_blocks / 64).max(1);
        let stride = self.stride.max(1);
        let mut i = 0;
        let mut last_key = (u32::MAX, u32::MAX);
        let mut last =
            self.predict_ips(spec, proj.batch[0].max(1), proj.kv_blocks[0], freq_mhz);
        while i < n {
            let b = proj.batch[i];
            if b != 0 {
                let key = (b, proj.kv_blocks[i] / kv_bucket);
                if key != last_key {
                    last = self.predict_ips(spec, b, proj.kv_blocks[i], freq_mhz);
                    last_key = key;
                }
            }
            let hi = (i + stride).min(n);
            for v in &mut t[i..hi] {
                *v = last;
            }
            i = hi;
        }
        t
    }

    /// T' = 1/T (TBT per iteration) and T_R = cumulative sum of T'
    /// (estimated time to REACH each future iteration — Eq. 3).
    pub fn remaining_time_vector(t: &[f64]) -> Vec<f64> {
        let mut out = Vec::with_capacity(t.len());
        let mut acc = 0.0;
        for &ips in t {
            acc += 1.0 / ips;
            out.push(acc);
        }
        out
    }

    /// Mean TBT over the horizon (the §IV-C2 TBT check statistic).
    pub fn mean_tbt(t: &[f64]) -> f64 {
        if t.is_empty() {
            return 0.0;
        }
        t.iter().map(|&ips| 1.0 / ips).sum::<f64>() / t.len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::coordinator::projection::Projection;
    use crate::gpusim::latency::{ips, GpuState};

    fn model() -> (PerfModel, EngineSpec) {
        let e = llama2_13b(2);
        (PerfModel::train(&[e.clone()], 60, 0), e)
    }

    #[test]
    fn predictions_track_ground_truth() {
        let (m, e) = model();
        // Interior points: tight tolerance; the all-dims-extreme corner
        // (max batch, near-full KV, min frequency) is the sparsest part
        // of the profiling space and gets a looser bound.
        for (b, kv, f, tol) in [
            (1u32, 10u32, 1410u32, 0.15),
            (16, 200, 900, 0.15),
            (32, 420, 210, 0.30),
        ] {
            let truth = ips(
                &e,
                &GpuState {
                    batch: b,
                    kv_blocks: kv,
                    freq_mhz: f,
                },
            );
            let pred = m.predict_ips(&e, b, kv, f);
            let rel = (pred - truth).abs() / truth;
            assert!(rel < tol, "b={b} kv={kv} f={f}: {pred} vs {truth}");
        }
    }

    #[test]
    fn ips_increases_with_frequency() {
        let (m, e) = model();
        let lo = m.predict_ips(&e, 16, 200, 210);
        let hi = m.predict_ips(&e, 16, 200, 1410);
        assert!(hi > lo * 1.3, "hi={hi} lo={lo}");
    }

    #[test]
    fn throughput_vector_follows_projection() {
        let (m, e) = model();
        let proj = Projection {
            start_iter: 1,
            batch: vec![8; 16],
            kv_blocks: (0..16).map(|i| 20 * (i as u32 + 1)).collect(),
            ..Default::default()
        };
        let t = m.throughput_vector(&e, &proj, 1410);
        assert_eq!(t.len(), 16);
        // Growing KV -> falling throughput (weak monotonicity over
        // stride boundaries).
        assert!(t[0] >= t[15], "t0={} t15={}", t[0], t[15]);
        assert!(t.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn remaining_time_is_cumulative() {
        let t = vec![50.0, 25.0, 10.0];
        let tr = PerfModel::remaining_time_vector(&t);
        assert!((tr[0] - 0.02).abs() < 1e-12);
        assert!((tr[1] - 0.06).abs() < 1e-12);
        assert!((tr[2] - 0.16).abs() < 1e-12);
    }

    #[test]
    fn mean_tbt_matches_hand_calc() {
        let t = vec![50.0, 25.0];
        assert!((PerfModel::mean_tbt(&t) - 0.03).abs() < 1e-12);
        assert_eq!(PerfModel::mean_tbt(&[]), 0.0);
    }

    #[test]
    fn stride_one_and_four_agree_closely() {
        let (mut m, e) = model();
        let proj = Projection {
            start_iter: 1,
            batch: vec![16; 64],
            kv_blocks: (0..64).map(|i| 5 * i as u32 + 50).collect(),
            ..Default::default()
        };
        m.stride = 1;
        let exact = m.throughput_vector(&e, &proj, 1050);
        m.stride = 4;
        let fast = m.throughput_vector(&e, &proj, 1050);
        let tr_a = PerfModel::remaining_time_vector(&exact);
        let tr_b = PerfModel::remaining_time_vector(&fast);
        let rel = (tr_a.last().unwrap() - tr_b.last().unwrap()).abs()
            / tr_a.last().unwrap();
        assert!(rel < 0.02, "rel={rel}");
    }
}
