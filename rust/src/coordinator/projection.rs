//! KV-usage & batch-size projection (paper §IV-B, Eq. 1-2).
//!
//! Given the Scoreboard and the current iteration `k`, produce vectors
//! `B` and `KV` over future iterations `j = k+1 .. n` (until the last
//! scheduled query completes), assuming no new arrivals:
//!
//!   KV_{q_i}[j] = ceil((j - s_i + |q_i|) / N)   for s_i <= j < s_i+|r̂_i|
//!   KV[j]       = sum_i KV_{q_i}[j]
//!   B[j]        = |{ i : s_i <= j < s_i + |r̂_i| }|
//!
//! The projection is exact under an oracle predictor; the paper
//! measures 0.19% batch and 2.26% KV mean absolute error under real
//! inflight conditions (Fig. 7), dominated by prefill-stall effects.

use crate::coordinator::scoreboard::Scoreboard;

/// Projected engine state per future iteration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Projection {
    /// First projected iteration index (k + 1).
    pub start_iter: u64,
    /// B[j]: projected batch size; index 0 <=> iteration `start_iter`.
    pub batch: Vec<u32>,
    /// KV[j]: projected allocated blocks.
    pub kv_blocks: Vec<u32>,
}

impl Projection {
    pub fn horizon(&self) -> usize {
        self.batch.len()
    }

    /// Largest projected KV usage (the capacity check input).
    pub fn peak_kv(&self) -> u32 {
        self.kv_blocks.iter().copied().max().unwrap_or(0)
    }

    /// Relative iteration offset (0-based) at which a query scheduled
    /// at `s_i` with prediction `pred` completes; `None` if already
    /// past. Offset indexes into `batch` / `kv_blocks` / `T_R`.
    pub fn completion_offset(&self, scheduled_iter: u64, pred: u32) -> Option<usize> {
        let end = scheduled_iter + pred as u64; // first iter NOT running
        if end < self.start_iter {
            return None;
        }
        Some((end - self.start_iter) as usize)
    }

    /// Bounds-safe index of the query's LAST running iteration into
    /// this projection's vectors (`batch` / `kv_blocks` / `T_R`).
    ///
    /// The raw [`Self::completion_offset`] can point at or past the
    /// horizon when the evaluated entry set differs from the one the
    /// projection was built from (admission control's with/without
    /// candidate worlds, §IV-C2) or when predictions were bumped after
    /// the projection was taken (§IV-F).  Such offsets clamp to the
    /// last projected iteration instead of indexing out of bounds.
    /// Returns `None` when the query already completed before the
    /// window, or when the projection is empty.
    pub fn completion_index(&self, scheduled_iter: u64, pred: u32) -> Option<usize> {
        let horizon = self.horizon();
        if horizon == 0 {
            return None;
        }
        let off = self.completion_offset(scheduled_iter, pred)?;
        Some(off.saturating_sub(1).min(horizon - 1))
    }
}

/// Compute the projection at current iteration `k` (vectors start at
/// k+1). `block_tokens` is the engine's N.
pub fn project(sb: &Scoreboard, k: u64, block_tokens: u32) -> Projection {
    let visible: Vec<crate::coordinator::scoreboard::Entry> =
        sb.visible().copied().collect();
    project_entries(&visible, k, block_tokens)
}

/// Projection over an explicit entry set (used by admission control to
/// compare "with candidate" vs "without candidate" worlds).
///
/// Implemented with difference arrays (EXPERIMENTS.md §Perf): a query
/// contributes a constant batch increment over its active range and a
/// KV step that grows by one block every `block_tokens` iterations, so
/// each query costs O(range / N) updates instead of O(range); a single
/// prefix-sum pass then materializes both vectors.
pub fn project_entries(
    entries: &[crate::coordinator::scoreboard::Entry],
    k: u64,
    block_tokens: u32,
) -> Projection {
    let start = k + 1;
    // Horizon: furthest end_iter among visible entries.
    let end = entries.iter().map(|e| e.end_iter()).max().unwrap_or(start);
    let n = end.saturating_sub(start) as usize;
    let mut batch_d = vec![0i64; n + 1];
    let mut kv_d = vec![0i64; n + 1];
    let bt = block_tokens as u64;
    for e in entries {
        // Active range of iterations [max(start, s_i), e.end_iter()).
        let lo = e.scheduled_iter.max(start);
        let hi = e.end_iter();
        if hi <= lo {
            continue;
        }
        let lo_idx = (lo - start) as usize;
        let hi_idx = (hi - start) as usize;
        batch_d[lo_idx] += 1;
        batch_d[hi_idx] -= 1;

        // Blocks at iteration j: ceil((j - s + prompt)/N). At j = lo:
        let tokens_lo = lo - e.scheduled_iter + e.prompt_tokens as u64;
        let blocks_lo = tokens_lo.div_ceil(bt) as i64;
        kv_d[lo_idx] += blocks_lo;
        kv_d[hi_idx] -= blocks_lo;
        // +1 block each time tokens crosses a multiple of N, i.e. at
        // tokens = m*N + 1 for m >= blocks_lo (tokens_lo < m*N + 1).
        let mut boundary_tokens = blocks_lo as u64 * bt + 1;
        while boundary_tokens <= tokens_lo {
            boundary_tokens += bt;
        }
        let mut j = lo + (boundary_tokens - tokens_lo);
        while j < hi {
            let idx = (j - start) as usize;
            kv_d[idx] += 1;
            kv_d[hi_idx] -= 1;
            j += bt;
        }
    }
    // Prefix sums.
    let mut batch = vec![0u32; n];
    let mut kv = vec![0u32; n];
    let (mut acc_b, mut acc_kv) = (0i64, 0i64);
    for i in 0..n {
        acc_b += batch_d[i];
        acc_kv += kv_d[i];
        batch[i] = acc_b as u32;
        kv[i] = acc_kv as u32;
    }
    Projection {
        start_iter: start,
        batch,
        kv_blocks: kv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scoreboard::Entry;

    fn entry(id: u64, s: u64, prompt: u32, pred: u32) -> Entry {
        Entry {
            id,
            scheduled_iter: s,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: f64::INFINITY,
            lost: false,
        }
    }

    #[test]
    fn empty_scoreboard_projects_nothing() {
        let p = project(&Scoreboard::new(), 5, 64);
        assert_eq!(p.horizon(), 0);
        assert_eq!(p.peak_kv(), 0);
    }

    #[test]
    fn single_query_projection_matches_eq1() {
        let mut sb = Scoreboard::new();
        // scheduled at iter 0, prompt 100, predicted 10 -> ends iter 10
        sb.insert(entry(1, 0, 100, 10));
        let p = project(&sb, 0, 64);
        // vectors cover iterations 1..=9 (horizon 9)
        assert_eq!(p.start_iter, 1);
        assert_eq!(p.horizon(), 9);
        assert!(p.batch.iter().all(|&b| b == 1));
        // Eq. 1: at iter j, tokens = (j - 0) + 100; blocks = ceil(t/64)
        assert_eq!(p.kv_blocks[0], (101u32).div_ceil(64)); // j=1
        assert_eq!(p.kv_blocks[8], (109u32).div_ceil(64)); // j=9
    }

    #[test]
    fn kv_grows_on_block_boundaries() {
        let mut sb = Scoreboard::new();
        // prompt 60, N=64: crosses to 2 blocks at j-s+prompt = 65 -> j=5
        sb.insert(entry(1, 0, 60, 20));
        let p = project(&sb, 0, 64);
        assert_eq!(p.kv_blocks[3], 1); // j=4 -> 64 tokens
        assert_eq!(p.kv_blocks[4], 2); // j=5 -> 65 tokens
    }

    #[test]
    fn batch_steps_down_as_queries_finish() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5)); // ends at iter 5
        sb.insert(entry(2, 0, 10, 12)); // ends at iter 12
        let p = project(&sb, 0, 64);
        assert_eq!(p.horizon(), 11); // iters 1..=11
        assert_eq!(p.batch[3], 2); // iter 4: both live
        assert_eq!(p.batch[4], 1); // iter 5: q1 finished (runs s..s+5)
        assert_eq!(p.batch[10], 1); // iter 11: q2 last iteration
    }

    #[test]
    fn total_kv_sums_queries() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 64, 10));
        sb.insert(entry(2, 0, 128, 10));
        let p = project(&sb, 0, 64);
        // At iter 1: q1 holds ceil(65/64)=2, q2 ceil(129/64)=3.
        assert_eq!(p.kv_blocks[0], 5);
    }

    #[test]
    fn virtual_entry_included_until_rollback() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 10));
        sb.virtual_append(entry(2, 3, 10, 10));
        let with = project(&sb, 3, 64);
        sb.rollback_virtual();
        let without = project(&sb, 3, 64);
        assert!(with.peak_kv() > without.peak_kv());
        assert!(with.batch[0] > without.batch[0]);
    }

    #[test]
    fn completion_offset_indexes_vectors() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 2, 10, 8)); // ends at iteration 10
        let p = project(&sb, 4, 64);
        // start_iter = 5; completion at iter 10 -> offset 5
        assert_eq!(p.completion_offset(2, 8), Some(5));
        // Entry ending before the window floor:
        assert_eq!(p.completion_offset(0, 3), None);
    }

    #[test]
    fn completion_index_clamps_to_horizon() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 100, 10)); // horizon: iters 1..=9 (len 9)
        let p = project(&sb, 0, 64);
        assert_eq!(p.horizon(), 9);
        // In-window: last running iteration of the same entry.
        assert_eq!(p.completion_index(0, 10), Some(8));
        // An entry evaluated against this projection but ending far
        // past its horizon clamps to the last projected iteration.
        assert_eq!(p.completion_index(0, 1000), Some(8));
        assert_eq!(p.completion_index(500, 1000), Some(8));
        // Offset 0 (ends exactly at the window start) stays in bounds.
        assert_eq!(p.completion_index(0, 1), Some(0));
        // Already completed before the window: no index.
        let late = project(&sb, 4, 64);
        assert_eq!(late.completion_index(0, 3), None);
        // Empty projection: no index at all.
        let empty = project(&Scoreboard::new(), 0, 64);
        assert_eq!(empty.completion_index(0, 10), None);
    }

    #[test]
    fn mid_generation_entries_project_remaining_only() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 100, 50));
        // Now at iteration k=40: only 9 more iterations produce tokens
        let p = project(&sb, 40, 64);
        assert_eq!(p.horizon(), 9); // iters 41..=49
        assert!(p.batch.iter().all(|&b| b == 1));
        // tokens at iter 41 = 41 + 100 = 141 -> 3 blocks
        assert_eq!(p.kv_blocks[0], 3);
    }
}
