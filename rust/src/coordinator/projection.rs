//! KV-usage & batch-size projection (paper §IV-B, Eq. 1-2).
//!
//! Given the Scoreboard and the current iteration `k`, produce vectors
//! `B` and `KV` over future iterations `j = k+1 .. n` (until the last
//! scheduled query completes), assuming no new arrivals:
//!
//!   KV_{q_i}[j] = ceil((j - s_i + |q_i|) / N)   for s_i <= j < s_i+|r̂_i|
//!   KV[j]       = sum_i KV_{q_i}[j]
//!   B[j]        = |{ i : s_i <= j < s_i + |r̂_i| }|
//!
//! The projection is exact under an oracle predictor; the paper
//! measures 0.19% batch and 2.26% KV mean absolute error under real
//! inflight conditions (Fig. 7), dominated by prefill-stall effects.

use std::collections::{BTreeMap, VecDeque};

use crate::coordinator::scoreboard::{Delta, Entry, Scoreboard};

/// Projected engine state per future iteration.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct Projection {
    /// First projected iteration index (k + 1).
    pub start_iter: u64,
    /// B[j]: projected batch size; index 0 <=> iteration `start_iter`.
    pub batch: Vec<u32>,
    /// KV[j]: projected allocated blocks.
    pub kv_blocks: Vec<u32>,
}

impl Projection {
    pub fn horizon(&self) -> usize {
        self.batch.len()
    }

    /// Largest projected KV usage (the capacity check input).
    pub fn peak_kv(&self) -> u32 {
        self.kv_blocks.iter().copied().max().unwrap_or(0)
    }

    /// Relative iteration offset (0-based) at which a query scheduled
    /// at `s_i` with prediction `pred` completes; `None` if already
    /// past. Offset indexes into `batch` / `kv_blocks` / `T_R`.
    pub fn completion_offset(&self, scheduled_iter: u64, pred: u32) -> Option<usize> {
        let end = scheduled_iter + pred as u64; // first iter NOT running
        if end < self.start_iter {
            return None;
        }
        Some((end - self.start_iter) as usize)
    }

    /// Bounds-safe index of the query's LAST running iteration into
    /// this projection's vectors (`batch` / `kv_blocks` / `T_R`).
    ///
    /// The raw [`Self::completion_offset`] can point at or past the
    /// horizon when the evaluated entry set differs from the one the
    /// projection was built from (admission control's with/without
    /// candidate worlds, §IV-C2) or when predictions were bumped after
    /// the projection was taken (§IV-F).  Such offsets clamp to the
    /// last projected iteration instead of indexing out of bounds.
    /// Returns `None` when the query already completed before the
    /// window, or when the projection is empty.
    pub fn completion_index(&self, scheduled_iter: u64, pred: u32) -> Option<usize> {
        let horizon = self.horizon();
        if horizon == 0 {
            return None;
        }
        let off = self.completion_offset(scheduled_iter, pred)?;
        Some(off.saturating_sub(1).min(horizon - 1))
    }
}

/// Compute the projection at current iteration `k` (vectors start at
/// k+1). `block_tokens` is the engine's N.
///
/// This is the from-scratch build; the serving hot path maintains the
/// same result incrementally through a [`ProjectionTracker`].
pub fn project(sb: &Scoreboard, k: u64, block_tokens: u32) -> Projection {
    let visible: Vec<Entry> = sb.visible().copied().collect();
    project_entries(&visible, k, block_tokens)
}

/// Incrementally-maintained §IV-B projection (closes the ROADMAP
/// "incremental projection update" item).
///
/// [`project_entries`] rebuilds the difference arrays from every
/// visible entry on every call — O(entries × range/N) per build, with
/// 1-2 builds per admission attempt plus one per throttle
/// re-evaluation and router probe.  The tracker keeps the difference
/// arrays LIVE across calls instead:
///
///   * admit / strike / prediction-bump apply one entry's contribution
///     with sign ±1 — O(range/N);
///   * advancing the window to a later iteration consumes one
///     difference slot per iteration — O(1) amortized;
///   * materializing the [`Projection`] is the single prefix-sum pass
///     `project_entries` ends with, over the remaining horizon only;
///     the admission candidate (`extra`) is applied and exactly undone
///     around the pass, so the with- and without-candidate worlds of
///     §IV-C2 come from ONE maintained structure.
///
/// Synchronization is journal-based: the tracker replays the
/// scoreboard's committed-entry [`Delta`] stream
/// ([`Scoreboard::journal`]) and falls back to a full rebuild when it
/// is further behind than the journal retains.  All arithmetic is
/// integer, so the result is bit-identical to a from-scratch
/// [`project_entries`] build — debug builds assert exactly that on
/// EVERY materialization.
///
/// The window only moves forward: `project` must be called with
/// non-decreasing `k` (per-engine iteration indices are monotone).
#[derive(Debug, Clone)]
pub struct ProjectionTracker {
    block_tokens: u32,
    /// Absolute iteration index of difference slot 0; also the start
    /// of the next materialized window.
    head: u64,
    /// Prefix sums of all difference mass at indices < `head`.
    acc_batch: i64,
    acc_kv: i64,
    batch_d: VecDeque<i64>,
    kv_d: VecDeque<i64>,
    /// Multiset of tracked entries' `end_iter`s (horizon = max), kept
    /// exact so the materialized vectors have the same length a
    /// from-scratch build would.
    ends: BTreeMap<u64, u32>,
    /// Next scoreboard delta sequence number to apply.
    synced_seq: u64,
    /// Reusable materialization target (no allocation in steady state).
    buf: Projection,
}

impl ProjectionTracker {
    pub fn new(block_tokens: u32) -> Self {
        Self {
            block_tokens,
            head: 0,
            acc_batch: 0,
            acc_kv: 0,
            batch_d: VecDeque::new(),
            kv_d: VecDeque::new(),
            ends: BTreeMap::new(),
            synced_seq: 0,
            buf: Projection::default(),
        }
    }

    fn ensure_slot(&mut self, rel: usize) {
        if self.batch_d.len() <= rel {
            self.batch_d.resize(rel + 1, 0);
            self.kv_d.resize(rel + 1, 0);
        }
    }

    /// Add difference mass at absolute index `idx`; mass behind the
    /// window head folds directly into the accumulators (that is
    /// exactly the truncation `project_entries` applies at its window
    /// start — prefix sums commute with it).
    fn add_at(&mut self, idx: u64, batch: i64, kv: i64) {
        if idx < self.head {
            self.acc_batch += batch;
            self.acc_kv += kv;
        } else {
            let rel = (idx - self.head) as usize;
            self.ensure_slot(rel);
            self.batch_d[rel] += batch;
            self.kv_d[rel] += kv;
        }
    }

    /// One entry's difference-array contribution with sign ±1 —
    /// mirrors the loop body of [`project_entries`] anchored at s_i.
    fn apply(&mut self, e: &Entry, sign: i64) {
        let bt = self.block_tokens as u64;
        let lo = e.scheduled_iter;
        let hi = e.end_iter();
        if hi <= lo {
            return;
        }
        self.add_at(lo, sign, 0);
        self.add_at(hi, -sign, 0);
        // Blocks at iteration j: ceil((j - s + prompt)/N); at j = lo
        // tokens = prompt, then +1 block per N-token boundary crossed.
        // A shared-prefix discount shifts the whole step function down
        // by a constant (the blocks a co-resident already pays for);
        // admission guarantees discount <= ceil(prompt/N), so the
        // contribution never goes negative.
        let tokens_lo = e.prompt_tokens as u64;
        let blocks_lo =
            tokens_lo.div_ceil(bt) as i64 - e.kv_discount_blocks as i64;
        debug_assert!(blocks_lo >= 0, "kv discount exceeds entry footprint");
        self.add_at(lo, 0, sign * blocks_lo);
        self.add_at(hi, 0, -sign * blocks_lo);
        // First boundary crossing: tokens hits blocks_lo*N + 1 (the
        // ceil guarantees blocks_lo*N + 1 > tokens_lo, so no further
        // adjustment is needed when anchored at s_i).
        let boundary_tokens = blocks_lo as u64 * bt + 1;
        let mut j = lo + (boundary_tokens - tokens_lo);
        while j < hi {
            self.add_at(j, 0, sign);
            self.add_at(hi, 0, -sign);
            j += bt;
        }
    }

    fn add_entry(&mut self, e: &Entry) {
        *self.ends.entry(e.end_iter()).or_insert(0) += 1;
        self.apply(e, 1);
    }

    fn remove_entry(&mut self, e: &Entry) {
        let end = e.end_iter();
        match self.ends.get_mut(&end) {
            Some(c) if *c > 1 => *c -= 1,
            Some(_) => {
                self.ends.remove(&end);
            }
            None => debug_assert!(false, "removing untracked end_iter {end}"),
        }
        self.apply(e, -1);
    }

    /// Rebuild from the scoreboard's committed set (journal history
    /// lost, or first sync after falling behind).
    fn rebuild(&mut self, sb: &Scoreboard, k: u64) {
        self.batch_d.clear();
        self.kv_d.clear();
        self.ends.clear();
        self.acc_batch = 0;
        self.acc_kv = 0;
        let mut head = k + 1;
        for e in sb.committed() {
            head = head.min(e.scheduled_iter);
        }
        self.head = head;
        for e in sb.committed() {
            self.add_entry(e);
        }
        let (_, _, next_seq) = sb.journal();
        self.synced_seq = next_seq;
    }

    /// Replay any scoreboard deltas the tracker has not seen yet.
    fn sync(&mut self, sb: &Scoreboard, k: u64) {
        let (start_seq, deltas, next_seq) = sb.journal();
        if self.synced_seq == next_seq {
            return;
        }
        if self.synced_seq > next_seq || self.synced_seq < start_seq {
            // Ahead of this scoreboard (tracker paired with a different
            // lineage) or behind the retained history: start over.
            debug_assert!(
                self.synced_seq <= next_seq,
                "tracker synced past its scoreboard: {} > {}",
                self.synced_seq,
                next_seq
            );
            self.rebuild(sb, k);
            return;
        }
        for d in &deltas[(self.synced_seq - start_seq) as usize..] {
            match d {
                Delta::Add(e) => self.add_entry(e),
                Delta::Remove(e) => self.remove_entry(e),
            }
        }
        self.synced_seq = next_seq;
    }

    /// Consume difference slots up to the new window start (O(1) per
    /// elapsed iteration; jumps past the horizon are O(remaining)).
    fn advance_to(&mut self, start: u64) {
        debug_assert!(
            start >= self.head,
            "projection window moved backwards: head {} -> start {}",
            self.head,
            start
        );
        while self.head < start {
            match (self.batch_d.pop_front(), self.kv_d.pop_front()) {
                (Some(b), Some(kv)) => {
                    self.acc_batch += b;
                    self.acc_kv += kv;
                    self.head += 1;
                }
                _ => {
                    // No difference mass beyond this point.
                    self.head = start;
                }
            }
        }
    }

    /// Materialize the projection at iteration `k` (window `k+1..`),
    /// optionally with `extra` (the §IV-C2 admission candidate)
    /// applied on top.  `extra` is added and exactly undone (integer
    /// adds), so the tracker state is unchanged by it.  Returns a
    /// reference into the tracker's reusable buffer.
    ///
    /// Debug builds bit-compare the result against a from-scratch
    /// [`project_entries`] build on every call.
    // detlint: hot
    pub fn project(
        &mut self,
        sb: &Scoreboard,
        k: u64,
        extra: Option<&Entry>,
    ) -> &Projection {
        self.sync(sb, k);
        let start = k + 1;
        self.advance_to(start);
        if let Some(x) = extra {
            self.apply(x, 1);
        }
        let mut max_end = self.ends.keys().next_back().copied().unwrap_or(start);
        if let Some(x) = extra {
            max_end = max_end.max(x.end_iter());
        }
        let n = max_end.saturating_sub(start) as usize;
        {
            let buf = &mut self.buf;
            buf.start_iter = start;
            buf.batch.clear();
            buf.kv_blocks.clear();
            buf.batch.reserve(n);
            buf.kv_blocks.reserve(n);
            let (mut acc_b, mut acc_kv) = (self.acc_batch, self.acc_kv);
            for off in 0..n {
                acc_b += self.batch_d.get(off).copied().unwrap_or(0);
                acc_kv += self.kv_d.get(off).copied().unwrap_or(0);
                buf.batch.push(acc_b as u32);
                buf.kv_blocks.push(acc_kv as u32);
            }
        }
        if let Some(x) = extra {
            self.apply(x, -1);
        }
        #[cfg(debug_assertions)]
        self.debug_check(sb, k, extra);
        &self.buf
    }

    /// Pin the incremental result to the from-scratch build: the
    /// correctness contract of the whole subsystem.
    #[cfg(debug_assertions)]
    fn debug_check(&self, sb: &Scoreboard, k: u64, extra: Option<&Entry>) {
        let mut v: Vec<Entry> = sb.committed().to_vec();
        if let Some(x) = extra {
            v.push(*x);
        }
        let fresh = project_entries(&v, k, self.block_tokens);
        assert_eq!(
            fresh, self.buf,
            "incremental projection diverged from project_entries at k={k}"
        );
    }
}

/// Projection over an explicit entry set (used by admission control to
/// compare "with candidate" vs "without candidate" worlds).
///
/// Implemented with difference arrays (EXPERIMENTS.md §Perf): a query
/// contributes a constant batch increment over its active range and a
/// KV step that grows by one block every `block_tokens` iterations, so
/// each query costs O(range / N) updates instead of O(range); a single
/// prefix-sum pass then materializes both vectors.
pub fn project_entries(
    entries: &[crate::coordinator::scoreboard::Entry],
    k: u64,
    block_tokens: u32,
) -> Projection {
    let start = k + 1;
    // Horizon: furthest end_iter among visible entries.
    let end = entries.iter().map(|e| e.end_iter()).max().unwrap_or(start);
    let n = end.saturating_sub(start) as usize;
    let mut batch_d = vec![0i64; n + 1];
    let mut kv_d = vec![0i64; n + 1];
    let bt = block_tokens as u64;
    for e in entries {
        // Active range of iterations [max(start, s_i), e.end_iter()).
        let lo = e.scheduled_iter.max(start);
        let hi = e.end_iter();
        if hi <= lo {
            continue;
        }
        let lo_idx = (lo - start) as usize;
        let hi_idx = (hi - start) as usize;
        batch_d[lo_idx] += 1;
        batch_d[hi_idx] -= 1;

        // Blocks at iteration j: ceil((j - s + prompt)/N), minus the
        // constant shared-prefix discount (blocks a co-resident pays
        // for — same subtraction as `ProjectionTracker::apply`, so the
        // debug bit-compare holds). At j = lo:
        let tokens_lo = lo - e.scheduled_iter + e.prompt_tokens as u64;
        let blocks_lo =
            tokens_lo.div_ceil(bt) as i64 - e.kv_discount_blocks as i64;
        debug_assert!(blocks_lo >= 0, "kv discount exceeds entry footprint");
        kv_d[lo_idx] += blocks_lo;
        kv_d[hi_idx] -= blocks_lo;
        // +1 block each time tokens crosses a multiple of N, i.e. at
        // tokens = m*N + 1 for m >= blocks_lo (tokens_lo < m*N + 1).
        let mut boundary_tokens = blocks_lo as u64 * bt + 1;
        while boundary_tokens <= tokens_lo {
            boundary_tokens += bt;
        }
        let mut j = lo + (boundary_tokens - tokens_lo);
        while j < hi {
            let idx = (j - start) as usize;
            kv_d[idx] += 1;
            kv_d[hi_idx] -= 1;
            j += bt;
        }
    }
    // Prefix sums.
    let mut batch = vec![0u32; n];
    let mut kv = vec![0u32; n];
    let (mut acc_b, mut acc_kv) = (0i64, 0i64);
    for i in 0..n {
        acc_b += batch_d[i];
        acc_kv += kv_d[i];
        batch[i] = acc_b as u32;
        kv[i] = acc_kv as u32;
    }
    Projection {
        start_iter: start,
        batch,
        kv_blocks: kv,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::scoreboard::Entry;

    fn entry(id: u64, s: u64, prompt: u32, pred: u32) -> Entry {
        Entry {
            id,
            scheduled_iter: s,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: f64::INFINITY,
            lost: false,
            kv_discount_blocks: 0,
        }
    }

    #[test]
    fn empty_scoreboard_projects_nothing() {
        let p = project(&Scoreboard::new(), 5, 64);
        assert_eq!(p.horizon(), 0);
        assert_eq!(p.peak_kv(), 0);
    }

    #[test]
    fn single_query_projection_matches_eq1() {
        let mut sb = Scoreboard::new();
        // scheduled at iter 0, prompt 100, predicted 10 -> ends iter 10
        sb.insert(entry(1, 0, 100, 10));
        let p = project(&sb, 0, 64);
        // vectors cover iterations 1..=9 (horizon 9)
        assert_eq!(p.start_iter, 1);
        assert_eq!(p.horizon(), 9);
        assert!(p.batch.iter().all(|&b| b == 1));
        // Eq. 1: at iter j, tokens = (j - 0) + 100; blocks = ceil(t/64)
        assert_eq!(p.kv_blocks[0], (101u32).div_ceil(64)); // j=1
        assert_eq!(p.kv_blocks[8], (109u32).div_ceil(64)); // j=9
    }

    #[test]
    fn kv_grows_on_block_boundaries() {
        let mut sb = Scoreboard::new();
        // prompt 60, N=64: crosses to 2 blocks at j-s+prompt = 65 -> j=5
        sb.insert(entry(1, 0, 60, 20));
        let p = project(&sb, 0, 64);
        assert_eq!(p.kv_blocks[3], 1); // j=4 -> 64 tokens
        assert_eq!(p.kv_blocks[4], 2); // j=5 -> 65 tokens
    }

    #[test]
    fn batch_steps_down_as_queries_finish() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5)); // ends at iter 5
        sb.insert(entry(2, 0, 10, 12)); // ends at iter 12
        let p = project(&sb, 0, 64);
        assert_eq!(p.horizon(), 11); // iters 1..=11
        assert_eq!(p.batch[3], 2); // iter 4: both live
        assert_eq!(p.batch[4], 1); // iter 5: q1 finished (runs s..s+5)
        assert_eq!(p.batch[10], 1); // iter 11: q2 last iteration
    }

    #[test]
    fn total_kv_sums_queries() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 64, 10));
        sb.insert(entry(2, 0, 128, 10));
        let p = project(&sb, 0, 64);
        // At iter 1: q1 holds ceil(65/64)=2, q2 ceil(129/64)=3.
        assert_eq!(p.kv_blocks[0], 5);
    }

    #[test]
    fn virtual_entry_included_until_rollback() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 10));
        sb.virtual_append(entry(2, 3, 10, 10));
        let with = project(&sb, 3, 64);
        sb.rollback_virtual();
        let without = project(&sb, 3, 64);
        assert!(with.peak_kv() > without.peak_kv());
        assert!(with.batch[0] > without.batch[0]);
    }

    #[test]
    fn completion_offset_indexes_vectors() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 2, 10, 8)); // ends at iteration 10
        let p = project(&sb, 4, 64);
        // start_iter = 5; completion at iter 10 -> offset 5
        assert_eq!(p.completion_offset(2, 8), Some(5));
        // Entry ending before the window floor:
        assert_eq!(p.completion_offset(0, 3), None);
    }

    #[test]
    fn completion_index_clamps_to_horizon() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 100, 10)); // horizon: iters 1..=9 (len 9)
        let p = project(&sb, 0, 64);
        assert_eq!(p.horizon(), 9);
        // In-window: last running iteration of the same entry.
        assert_eq!(p.completion_index(0, 10), Some(8));
        // An entry evaluated against this projection but ending far
        // past its horizon clamps to the last projected iteration.
        assert_eq!(p.completion_index(0, 1000), Some(8));
        assert_eq!(p.completion_index(500, 1000), Some(8));
        // Offset 0 (ends exactly at the window start) stays in bounds.
        assert_eq!(p.completion_index(0, 1), Some(0));
        // Already completed before the window: no index.
        let late = project(&sb, 4, 64);
        assert_eq!(late.completion_index(0, 3), None);
        // Empty projection: no index at all.
        let empty = project(&Scoreboard::new(), 0, 64);
        assert_eq!(empty.completion_index(0, 10), None);
    }

    #[test]
    fn tracker_matches_from_scratch_across_ops() {
        let mut sb = Scoreboard::new();
        let mut tr = ProjectionTracker::new(64);
        sb.insert(entry(1, 0, 100, 40));
        assert_eq!(tr.project(&sb, 0, None), &project(&sb, 0, 64));
        sb.insert(entry(2, 3, 500, 80));
        assert_eq!(tr.project(&sb, 3, None), &project(&sb, 3, 64));
        sb.strike(1);
        assert_eq!(tr.project(&sb, 10, None), &project(&sb, 10, 64));
        sb.bump_overrun(2, 500);
        assert_eq!(tr.project(&sb, 30, None), &project(&sb, 30, 64));
    }

    #[test]
    fn tracker_extra_entry_is_applied_and_undone() {
        let mut sb = Scoreboard::new();
        let mut tr = ProjectionTracker::new(64);
        sb.insert(entry(1, 0, 100, 40));
        let cand = entry(9, 5, 2000, 200);
        // With the candidate: equals a from-scratch build over both.
        let with = tr.project(&sb, 5, Some(&cand)).clone();
        let mut v: Vec<Entry> = sb.committed().to_vec();
        v.push(cand);
        assert_eq!(with, project_entries(&v, 5, 64));
        // The candidate extended the horizon past the resident's end.
        assert_eq!(with.horizon() as u64, cand.end_iter() - 6);
        // Without: the tracker state is unchanged by the what-if.
        let without = tr.project(&sb, 5, None);
        assert_eq!(without, &project(&sb, 5, 64));
    }

    #[test]
    fn shared_prefix_discount_lowers_kv_and_tracker_matches() {
        let mut sb = Scoreboard::new();
        // Two session followers: 1024-token shared prefix already
        // resident (16 blocks at N=64) -> each discounts 16.
        let mut a = entry(1, 0, 1100, 10);
        a.kv_discount_blocks = 16;
        let mut b = entry(2, 0, 1100, 10);
        b.kv_discount_blocks = 16;
        sb.insert(a);
        sb.insert(b);
        let p = project(&sb, 0, 64);
        // Undiscounted: 2 * ceil(1101/64) = 36. Discounted: 36 - 32.
        assert_eq!(p.kv_blocks[0], 2 * (1101u32).div_ceil(64) - 32);
        // The incremental tracker applies the same subtraction.
        let mut tr = ProjectionTracker::new(64);
        assert_eq!(tr.project(&sb, 0, None), &p);
        let mut cand = entry(3, 2, 1100, 50);
        cand.kv_discount_blocks = 16;
        let mut v: Vec<Entry> = sb.committed().to_vec();
        v.push(cand);
        assert_eq!(
            tr.project(&sb, 2, Some(&cand)).clone(),
            project_entries(&v, 2, 64)
        );
    }

    #[test]
    fn mid_generation_entries_project_remaining_only() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 100, 50));
        // Now at iteration k=40: only 9 more iterations produce tokens
        let p = project(&sb, 40, 64);
        assert_eq!(p.horizon(), 9); // iters 41..=49
        assert!(p.batch.iter().all(|&b| b == 1));
        // tokens at iter 41 = 41 + 100 = 141 -> 3 blocks
        assert_eq!(p.kv_blocks[0], 3);
    }
}
