//! Fleet admission router: picks the replica that receives each
//! arriving request.
//!
//! The fleet coordinator (GreenLLM/AGFT-style horizontal scaling on top
//! of the paper's single-engine controller) fronts N replicas with a
//! router.  Three policies are provided:
//!
//!   * `round-robin` — cycle over active replicas (the "N independent
//!     instances" baseline split);
//!   * `least-loaded` — fewest outstanding requests (resident batch
//!     rows + queued arrivals);
//!   * `projected-headroom` — most *projected* headroom: the minimum of
//!     the replica's KV headroom (capacity minus projected peak KV
//!     minus the blocks its queue will demand) and its batch-slot
//!     headroom, both normalized.  This reuses the paper's §IV-B
//!     projection as the load signal instead of instantaneous counts.

/// Router policy selecting a replica per arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    /// Cycle over active replicas.
    #[default]
    RoundRobin,
    /// Fewest outstanding (resident + queued) requests.
    LeastLoaded,
    /// Largest projected KV/batch headroom (§IV-B projection signal).
    ProjectedHeadroom,
}

impl RouterPolicy {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RouterPolicy::RoundRobin,
            "least-loaded" | "ll" => RouterPolicy::LeastLoaded,
            "projected-headroom" | "headroom" | "ph" => RouterPolicy::ProjectedHeadroom,
            other => anyhow::bail!(
                "unknown router policy {other:?} \
                 (expected round-robin | least-loaded | projected-headroom)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::ProjectedHeadroom => "projected-headroom",
        }
    }
}

/// Normalized headroom score: the binding constraint of KV and batch
/// headroom (each in (-inf, 1], 1 = completely free). Negative values
/// mean the replica is already over-committed.  Each replica is scored
/// against its OWN capacity grid, so heterogeneous fleets compare
/// fractions of capacity rather than raw block counts.
///
/// A replica with zero KV or batch capacity can never serve anything
/// and scores `NEG_INFINITY` — ranking strictly below any genuinely
/// over-committed healthy replica.  (The previous `max(1)` clamp
/// normalized such degenerate replicas to 0.0, OUTRANKING healthy
/// replicas with negative scores.)
pub fn headroom_score(
    kv_capacity: u32,
    projected_peak_kv: u32,
    queued_blocks: u32,
    max_batch: u32,
    resident_batch: u32,
    queued_requests: usize,
) -> f64 {
    if kv_capacity == 0 || max_batch == 0 {
        return f64::NEG_INFINITY;
    }
    let kv = (kv_capacity as f64 - projected_peak_kv as f64 - queued_blocks as f64)
        / kv_capacity as f64;
    let batch = (max_batch as f64 - resident_batch as f64 - queued_requests as f64)
        / max_batch as f64;
    kv.min(batch)
}

/// Session-affinity selection over scored replicas.
///
/// `scored` yields `(replica index, headroom score, prefix resident)`
/// triples — `resident` means the arriving request's prefix group
/// already has its shared blocks allocated on that replica's engine, so
/// landing there re-uses them (no prefix re-allocation, prefill skips
/// the cached tokens).  A session's next turn therefore prefers the
/// best-scoring replica where its prefix is resident *and* the score
/// signals genuine headroom (> 0), falling back to the plain best score
/// otherwise (ISSUE 10 / ROADMAP prefix-affinity item).  Ties keep the
/// lowest replica index — iteration order is the caller's replica
/// order, so the choice is deterministic and thread-count independent.
pub fn select_with_affinity<I>(scored: I) -> Option<usize>
where
    I: IntoIterator<Item = (usize, f64, bool)>,
{
    let mut best: Option<(usize, f64)> = None;
    let mut best_resident: Option<(usize, f64)> = None;
    for (idx, score, resident) in scored {
        if best.map_or(true, |(_, s)| score > s) {
            best = Some((idx, score));
        }
        if resident && score > 0.0 && best_resident.map_or(true, |(_, s)| score > s) {
            best_resident = Some((idx, score));
        }
    }
    best_resident.or(best).map(|(i, _)| i)
}

/// Cached §IV-B projection summary for router scoring.
///
/// `projected-headroom` used to rebuild the full projection for EVERY
/// arrival — O(arrivals × replicas) projection builds on the admission
/// hot path (ROADMAP "Router feedback").  The projection only changes
/// at admission/completion/iteration boundaries (any scoreboard
/// mutation or iteration advance) or when the replica's queue changes,
/// so the summary is memoized under a `(iteration, scoreboard epoch,
/// queue epoch)` key and recomputed only when the key moves.
#[derive(Debug, Clone, Default)]
pub struct HeadroomCache {
    key: Option<(u64, u64, u64)>,
    peak_kv: u32,
    queued_blocks: u32,
    queued_requests: usize,
}

impl HeadroomCache {
    pub fn new() -> Self {
        Self::default()
    }

    /// Drop the cached summary unconditionally.
    pub fn clear(&mut self) {
        self.key = None;
    }

    /// The cached summary for `key`, if current.
    pub fn get(&self, key: (u64, u64, u64)) -> Option<(u32, u32, usize)> {
        if self.key == Some(key) {
            Some((self.peak_kv, self.queued_blocks, self.queued_requests))
        } else {
            None
        }
    }

    /// Install the summary for `key`.
    pub fn store(&mut self, key: (u64, u64, u64), summary: (u32, u32, usize)) {
        self.key = Some(key);
        self.peak_kv = summary.0;
        self.queued_blocks = summary.1;
        self.queued_requests = summary.2;
    }

    /// The `(projected peak KV, queued blocks, queued requests)`
    /// summary for `key`, recomputing via `compute` on a miss.
    pub fn fetch(
        &mut self,
        key: (u64, u64, u64),
        compute: impl FnOnce() -> (u32, u32, usize),
    ) -> (u32, u32, usize) {
        if let Some(s) = self.get(key) {
            return s;
        }
        let s = compute();
        self.store(key, s);
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_spellings() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(
            RouterPolicy::parse("round-robin").unwrap(),
            RouterPolicy::RoundRobin
        );
        assert_eq!(
            RouterPolicy::parse("least-loaded").unwrap(),
            RouterPolicy::LeastLoaded
        );
        assert_eq!(
            RouterPolicy::parse("projected-headroom").unwrap(),
            RouterPolicy::ProjectedHeadroom
        );
        assert!(RouterPolicy::parse("nope").is_err());
    }

    #[test]
    fn names_round_trip() {
        for p in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::ProjectedHeadroom,
        ] {
            assert_eq!(RouterPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn headroom_score_binds_on_the_scarcer_resource() {
        // Plenty of KV, batch nearly full -> batch binds.
        let s = headroom_score(1000, 100, 0, 8, 7, 0);
        assert!((s - 0.125).abs() < 1e-12);
        // Plenty of batch, KV nearly full -> KV binds.
        let s = headroom_score(100, 90, 5, 64, 1, 0);
        assert!((s - 0.05).abs() < 1e-12);
        // Over-committed queues push the score negative.
        let s = headroom_score(100, 90, 20, 64, 1, 0);
        assert!(s < 0.0);
    }

    #[test]
    fn zero_capacity_replica_ranks_strictly_last() {
        // Regression: a degenerate replica (0 KV / 0 batch) used to be
        // normalized to 0.0 by the max(1) clamp, OUTRANKING genuinely
        // over-committed healthy replicas whose scores are negative.
        let degenerate_kv = headroom_score(0, 0, 0, 8, 0, 0);
        let degenerate_batch = headroom_score(100, 0, 0, 0, 0, 0);
        let overcommitted = headroom_score(100, 150, 30, 8, 8, 4);
        assert!(overcommitted < 0.0);
        assert_eq!(degenerate_kv, f64::NEG_INFINITY);
        assert_eq!(degenerate_batch, f64::NEG_INFINITY);
        assert!(degenerate_kv < overcommitted);
        assert!(headroom_score(0, 0, 0, 0, 0, 0) == f64::NEG_INFINITY);
    }

    #[test]
    fn affinity_prefers_resident_replica_with_headroom() {
        // Replica 2 has the prefix resident and positive headroom: it
        // wins even though replica 0 scores higher.
        let pick = select_with_affinity(vec![
            (0, 0.9, false),
            (1, 0.2, false),
            (2, 0.5, true),
        ]);
        assert_eq!(pick, Some(2));
    }

    #[test]
    fn affinity_falls_back_to_best_score() {
        // Resident replica is over-committed (score <= 0): plain
        // projected-headroom choice applies.
        let pick = select_with_affinity(vec![
            (0, 0.9, false),
            (1, -0.1, true),
        ]);
        assert_eq!(pick, Some(0));
        // No resident replica at all.
        let pick = select_with_affinity(vec![(0, 0.1, false), (1, 0.6, false)]);
        assert_eq!(pick, Some(1));
        // Empty fleet.
        assert_eq!(select_with_affinity(Vec::new()), None);
    }

    #[test]
    fn affinity_ties_keep_lowest_index() {
        let pick = select_with_affinity(vec![
            (0, 0.5, true),
            (1, 0.5, true),
            (2, 0.5, true),
        ]);
        assert_eq!(pick, Some(0));
    }

    #[test]
    fn headroom_cache_memoizes_until_key_moves() {
        let computes = std::cell::Cell::new(0u32);
        let compute = || {
            computes.set(computes.get() + 1);
            (40u32, 10u32, 3usize)
        };
        let mut cache = HeadroomCache::new();
        assert_eq!(cache.fetch((5, 1, 0), compute), (40, 10, 3));
        assert_eq!(cache.fetch((5, 1, 0), compute), (40, 10, 3));
        assert_eq!(computes.get(), 1, "second lookup must hit");
        // Any key component moving recomputes.
        cache.fetch((6, 1, 0), compute);
        assert_eq!(computes.get(), 2);
        cache.fetch((6, 2, 0), compute);
        assert_eq!(computes.get(), 3);
        cache.fetch((6, 2, 1), compute);
        assert_eq!(computes.get(), 4);
        // clear() forces the next fetch to recompute.
        cache.clear();
        cache.fetch((6, 2, 1), compute);
        assert_eq!(computes.get(), 5);
    }
}
