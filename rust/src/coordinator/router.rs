//! Fleet admission router: picks the replica that receives each
//! arriving request.
//!
//! The fleet coordinator (GreenLLM/AGFT-style horizontal scaling on top
//! of the paper's single-engine controller) fronts N replicas with a
//! router.  Three policies are provided:
//!
//!   * `round-robin` — cycle over active replicas (the "N independent
//!     instances" baseline split);
//!   * `least-loaded` — fewest outstanding requests (resident batch
//!     rows + queued arrivals);
//!   * `projected-headroom` — most *projected* headroom: the minimum of
//!     the replica's KV headroom (capacity minus projected peak KV
//!     minus the blocks its queue will demand) and its batch-slot
//!     headroom, both normalized.  This reuses the paper's §IV-B
//!     projection as the load signal instead of instantaneous counts.

/// Router policy selecting a replica per arrival.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RouterPolicy {
    /// Cycle over active replicas.
    #[default]
    RoundRobin,
    /// Fewest outstanding (resident + queued) requests.
    LeastLoaded,
    /// Largest projected KV/batch headroom (§IV-B projection signal).
    ProjectedHeadroom,
}

impl RouterPolicy {
    /// Parse a CLI spelling.
    pub fn parse(s: &str) -> anyhow::Result<Self> {
        Ok(match s {
            "round-robin" | "rr" => RouterPolicy::RoundRobin,
            "least-loaded" | "ll" => RouterPolicy::LeastLoaded,
            "projected-headroom" | "headroom" | "ph" => RouterPolicy::ProjectedHeadroom,
            other => anyhow::bail!(
                "unknown router policy {other:?} \
                 (expected round-robin | least-loaded | projected-headroom)"
            ),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::RoundRobin => "round-robin",
            RouterPolicy::LeastLoaded => "least-loaded",
            RouterPolicy::ProjectedHeadroom => "projected-headroom",
        }
    }
}

/// Normalized headroom score: the binding constraint of KV and batch
/// headroom (each in (-inf, 1], 1 = completely free). Negative values
/// mean the replica is already over-committed.
pub fn headroom_score(
    kv_capacity: u32,
    projected_peak_kv: u32,
    queued_blocks: u32,
    max_batch: u32,
    resident_batch: u32,
    queued_requests: usize,
) -> f64 {
    let kv = (kv_capacity as f64 - projected_peak_kv as f64 - queued_blocks as f64)
        / kv_capacity.max(1) as f64;
    let batch = (max_batch as f64 - resident_batch as f64 - queued_requests as f64)
        / max_batch.max(1) as f64;
    kv.min(batch)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_accepts_all_spellings() {
        assert_eq!(RouterPolicy::parse("rr").unwrap(), RouterPolicy::RoundRobin);
        assert_eq!(
            RouterPolicy::parse("round-robin").unwrap(),
            RouterPolicy::RoundRobin
        );
        assert_eq!(
            RouterPolicy::parse("least-loaded").unwrap(),
            RouterPolicy::LeastLoaded
        );
        assert_eq!(
            RouterPolicy::parse("projected-headroom").unwrap(),
            RouterPolicy::ProjectedHeadroom
        );
        assert!(RouterPolicy::parse("nope").is_err());
    }

    #[test]
    fn names_round_trip() {
        for p in [
            RouterPolicy::RoundRobin,
            RouterPolicy::LeastLoaded,
            RouterPolicy::ProjectedHeadroom,
        ] {
            assert_eq!(RouterPolicy::parse(p.name()).unwrap(), p);
        }
    }

    #[test]
    fn headroom_score_binds_on_the_scarcer_resource() {
        // Plenty of KV, batch nearly full -> batch binds.
        let s = headroom_score(1000, 100, 0, 8, 7, 0);
        assert!((s - 0.125).abs() < 1e-12);
        // Plenty of batch, KV nearly full -> KV binds.
        let s = headroom_score(100, 90, 5, 64, 1, 0);
        assert!((s - 0.05).abs() < 1e-12);
        // Over-committed queues push the score negative.
        let s = headroom_score(100, 90, 20, 64, 1, 0);
        assert!(s < 0.0);
    }

    #[test]
    fn headroom_score_survives_degenerate_capacities() {
        // Zero capacities must not divide by zero.
        let s = headroom_score(0, 0, 0, 0, 0, 0);
        assert!(s.is_finite());
    }
}
