//! Query scheduling & admission control (paper §IV-C2).
//!
//! Upon arrival, the new query is virtually appended to the Scoreboard
//! and three checks run against the resulting projection:
//!   1. KV capacity: no projected iteration may exceed the engine's
//!      block pool (prevents swapping);
//!   2. TBT SLO: mean predicted TBT at MAX frequency over the horizon
//!      must be within the SLO;
//!   3. E2E SLO: every scheduled query's predicted completion time
//!      (T_R at its final iteration, Eq. 3-4) must beat its deadline.
//! If only the NEW query's own E2E fails, it is admitted but marked
//! "lost" (ignored by future validations); if it would break others,
//! it is queued and the virtual entry rolled back.

use crate::config::{EngineSpec, SloSpec};
use crate::coordinator::perf_model::PerfModel;
use crate::coordinator::projection::{project, Projection};
use crate::coordinator::scoreboard::{Entry, Scoreboard};
use crate::engine::request::RequestId;
use crate::gpusim::dvfs::FREQ_MAX_MHZ;

/// Outcome of admission control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    Admit,
    /// Own E2E unmeetable but harmless to others (§IV-C2).
    AdmitLost,
    Queue(QueueReason),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueReason {
    KvCapacity,
    TbtSlo,
    E2eSlo,
}

/// SLO evaluation detail shared by the scheduler and the throttling
/// controller.
#[derive(Debug, Clone)]
pub struct SloEval {
    pub tbt_ok: bool,
    pub mean_tbt_s: f64,
    /// Queries whose predicted completion misses their deadline.
    pub e2e_violators: Vec<RequestId>,
}

impl SloEval {
    pub fn all_ok(&self) -> bool {
        self.tbt_ok && self.e2e_violators.is_empty()
    }
}

/// Evaluate TBT + E2E SLOs at `freq_mhz` for the visible scoreboard
/// entries under `proj`. "Lost" entries are skipped (§IV-C2).
pub fn evaluate_slo(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    sb: &Scoreboard,
    proj: &Projection,
    freq_mhz: u32,
    now: f64,
) -> SloEval {
    let visible: Vec<Entry> = sb.visible().copied().collect();
    evaluate_slo_entries(model, spec, slo, &visible, proj, freq_mhz, now, 1.0)
}

/// `evaluate_slo` over an explicit entry set.
///
/// `t_r_scale` inflates the predicted remaining times: the projection
/// assumes no new arrivals (§IV-B), but every future admission fuses a
/// prefill into an iteration and stalls decoding, so under sustained
/// load realized progress is systematically slower than T_R predicts.
/// The throttling controller passes `1 + λ·t_prefill` (expected
/// prefill-stall fraction); admission control keeps the paper's
/// optimistic 1.0.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_slo_entries(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    entries: &[Entry],
    proj: &Projection,
    freq_mhz: u32,
    now: f64,
    t_r_scale: f64,
) -> SloEval {
    let t = model.throughput_vector(spec, proj, freq_mhz);
    let mean_tbt = PerfModel::mean_tbt(&t);
    let tbt_ok = mean_tbt <= slo.tbt_avg || t.is_empty();
    let t_r = PerfModel::remaining_time_vector(&t);
    let mut violators = vec![];
    if !t_r.is_empty() {
        for e in entries {
            if e.lost {
                continue;
            }
            // Bounds-safe: the query's last iteration (end_iter - 1)
            // clamped into the horizon even when the entry outlives
            // the projection (with/without-candidate worlds, §IV-F
            // prediction bumps).
            let Some(idx) = proj.completion_index(e.scheduled_iter, e.predicted_gen)
            else {
                continue;
            };
            debug_assert!(idx < t_r.len(), "completion index out of horizon");
            if now + t_r[idx] * t_r_scale >= e.deadline_s {
                violators.push(e.id);
            }
        }
    }
    SloEval {
        tbt_ok,
        mean_tbt_s: mean_tbt,
        e2e_violators: violators,
    }
}

/// The scheduler: owns the SLO spec; stateless otherwise.
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub slo: SloSpec,
}

impl Scheduler {
    pub fn new(slo: SloSpec) -> Self {
        Self { slo }
    }

    /// Run admission control for a new query.
    ///
    /// The caller must have `virtual_append`ed the candidate entry (id
    /// `new_id`) to `sb`; this function neither commits nor rolls back
    /// — it only decides.
    ///
    /// The third returned value lists RESIDENT queries whose deadlines
    /// are unmeetable even *without* the candidate: they are de-facto
    /// lost (the continuous extension of the paper's "lost" marking)
    /// and the caller should mark them so; they do not block the
    /// candidate, which is only blamed for violations it newly causes.
    pub fn admission_check(
        &self,
        model: &PerfModel,
        spec: &EngineSpec,
        sb: &Scoreboard,
        current_iter: u64,
        now: f64,
        new_id: RequestId,
    ) -> (AdmissionDecision, Projection, Vec<RequestId>) {
        let proj = project(sb, current_iter, spec.block_tokens);

        // Check 1: KV cache capacity.
        if proj.peak_kv() > spec.kv_blocks {
            return (
                AdmissionDecision::Queue(QueueReason::KvCapacity),
                proj,
                vec![],
            );
        }

        // Checks 2-3 at maximum frequency (peak theoretical perf).
        let eval = evaluate_slo(model, spec, &self.slo, sb, &proj, FREQ_MAX_MHZ, now);
        if !eval.tbt_ok {
            return (AdmissionDecision::Queue(QueueReason::TbtSlo), proj, vec![]);
        }

        // Residents predicted to violate with the candidate on board.
        let mut blamed: Vec<RequestId> = eval
            .e2e_violators
            .iter()
            .copied()
            .filter(|&id| id != new_id)
            .collect();
        let mut already_lost: Vec<RequestId> = vec![];
        if !blamed.is_empty() {
            // Which of them violate even WITHOUT the candidate?
            let committed: Vec<Entry> = sb.committed().to_vec();
            let proj_wo =
                crate::coordinator::projection::project_entries(
                    &committed,
                    current_iter,
                    spec.block_tokens,
                );
            let eval_wo = evaluate_slo_entries(
                model,
                spec,
                &self.slo,
                &committed,
                &proj_wo,
                FREQ_MAX_MHZ,
                now,
                1.0,
            );
            blamed.retain(|id| {
                if eval_wo.e2e_violators.contains(id) {
                    already_lost.push(*id);
                    false
                } else {
                    true
                }
            });
        }

        let decision = if !blamed.is_empty() {
            AdmissionDecision::Queue(QueueReason::E2eSlo)
        } else if eval.e2e_violators.contains(&new_id) {
            // Only its own SLO unmeetable: schedule but mark lost.
            AdmissionDecision::AdmitLost
        } else {
            AdmissionDecision::Admit
        };
        (decision, proj, already_lost)
    }
}

/// Build a scoreboard entry for an arriving request.
pub fn entry_for(
    id: RequestId,
    prompt_tokens: u32,
    predicted_gen: u32,
    arrival_s: f64,
    current_iter: u64,
    slo: &SloSpec,
) -> Entry {
    Entry {
        id,
        scheduled_iter: current_iter,
        prompt_tokens,
        predicted_gen: predicted_gen.max(1),
        deadline_s: arrival_s + slo.e2e_p99,
        lost: false,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;

    fn setup() -> (PerfModel, EngineSpec, Scheduler) {
        let e = llama2_13b(2);
        let m = PerfModel::train(&[e.clone()], 40, 0);
        let s = Scheduler::new(SloSpec::new(0.2, 30.2));
        (m, e, s)
    }

    fn entry(id: u64, s_i: u64, prompt: u32, pred: u32, deadline: f64) -> Entry {
        Entry {
            id,
            scheduled_iter: s_i,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: deadline,
            lost: false,
        }
    }

    #[test]
    fn admits_easy_query() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        sb.virtual_append(entry(1, 0, 100, 50, 30.2));
        let (d, _, _) = sched.admission_check(&m, &e, &sb, 0, 0.0, 1);
        assert_eq!(d, AdmissionDecision::Admit);
    }

    #[test]
    fn queues_on_kv_overflow() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        // One giant resident query occupying most of the pool.
        sb.insert(entry(1, 0, 24_000, 900, 1e9));
        // Candidate whose projection overflows 439 blocks * 64 tokens.
        sb.virtual_append(entry(2, 0, 6_000, 900, 1e9));
        let (d, proj, _) = sched.admission_check(&m, &e, &sb, 0, 0.0, 2);
        assert_eq!(d, AdmissionDecision::Queue(QueueReason::KvCapacity));
        assert!(proj.peak_kv() > e.kv_blocks);
    }

    #[test]
    fn marks_lost_when_only_own_deadline_fails() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        // Candidate with an absurdly tight deadline (already passed).
        let mut cand = entry(7, 0, 100, 400, 0.001);
        cand.deadline_s = 0.001;
        sb.virtual_append(cand);
        let (d, _, _) = sched.admission_check(&m, &e, &sb, 0, 1.0, 7);
        assert_eq!(d, AdmissionDecision::AdmitLost);
    }

    #[test]
    fn queues_when_it_breaks_others() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        // Eight residents that finish JUST inside their deadlines when
        // alone; a huge new query inflates batch + KV enough to push
        // them over (the blame-the-candidate case).
        let now = 0.0;
        // Find the residents-alone completion estimate from the model
        // itself so the test is robust to calibration changes.
        for id in 0..8 {
            sb.insert(entry(id, 0, 1000, 600, 1e9));
        }
        let proj = project(&sb, 0, e.block_tokens);
        let t = m.throughput_vector(&e, &proj, FREQ_MAX_MHZ);
        let t_r = PerfModel::remaining_time_vector(&t);
        let alone = *t_r.last().unwrap();
        // Deadline with ~2.5% headroom over the alone-case estimate.
        let deadline = now + alone * 1.025;
        let mut sb = Scoreboard::new();
        for id in 0..8 {
            sb.insert(entry(id, 0, 1000, 600, deadline));
        }
        sb.virtual_append(entry(99, 0, 4000, 1024, now + 30.2));
        let (d, _, lost) = sched.admission_check(&m, &e, &sb, 0, now, 99);
        assert_eq!(d, AdmissionDecision::Queue(QueueReason::E2eSlo));
        assert!(lost.is_empty(), "residents were fine without candidate");
    }

    #[test]
    fn doomed_residents_do_not_block_admission() {
        // Residents whose deadlines are hopeless regardless of the
        // candidate must be reported de-facto lost, not blamed on the
        // candidate (otherwise one doomed query blocks all admissions
        // until it completes — the convoy pathology).
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 500, 600, 0.5)); // deadline long gone
        sb.virtual_append(entry(2, 0, 100, 100, 1000.0));
        let (d, _, lost) = sched.admission_check(&m, &e, &sb, 0, 5.0, 2);
        assert_eq!(d, AdmissionDecision::Admit);
        assert_eq!(lost, vec![1]);
    }

    #[test]
    fn lost_entries_ignored_in_validation() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        let mut hopeless = entry(1, 0, 3000, 600, 0.0);
        hopeless.lost = true;
        sb.insert(hopeless);
        sb.virtual_append(entry(2, 0, 100, 100, 1000.0));
        let (d, _, _) = sched.admission_check(&m, &e, &sb, 0, 1.0, 2);
        assert_eq!(d, AdmissionDecision::Admit);
    }

    #[test]
    fn evaluate_slo_mean_tbt_sane() {
        let (m, e, _s) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 100, 100, 1e9));
        let proj = project(&sb, 0, e.block_tokens);
        let eval = evaluate_slo(
            &m,
            &e,
            &SloSpec::new(0.2, 30.2),
            &sb,
            &proj,
            FREQ_MAX_MHZ,
            0.0,
        );
        // 13B TP2 at batch 1: TBT ~14 ms, far under 200 ms.
        assert!(eval.tbt_ok);
        assert!(eval.mean_tbt_s > 0.005 && eval.mean_tbt_s < 0.05);
        assert!(eval.all_ok());
    }
}
