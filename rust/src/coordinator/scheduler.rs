//! Query scheduling & admission control (paper §IV-C2).
//!
//! Upon arrival, the new query is virtually appended to the Scoreboard
//! and three checks run against the resulting projection:
//!   1. KV capacity: no projected iteration may exceed the engine's
//!      block pool (prevents swapping);
//!   2. TBT SLO: mean predicted TBT at MAX frequency over the horizon
//!      must be within the SLO;
//!   3. E2E SLO: every scheduled query's predicted completion time
//!      (T_R at its final iteration, Eq. 3-4) must beat its deadline.
//! If only the NEW query's own E2E fails, it is admitted but marked
//! "lost" (ignored by future validations); if it would break others,
//! it is queued and the virtual entry rolled back.
//!
//! The hot path is allocation-free: projections come from the
//! per-engine [`ProjectionTracker`] (both the with- and
//! without-candidate worlds materialize from one incrementally
//! maintained structure), and throughput / remaining-time vectors,
//! violator lists and GBDT inferences live in a reusable
//! [`EvalScratch`].

use crate::config::{EngineSpec, SloSpec};
use crate::coordinator::perf_model::{PerfModel, PredMemo};
use crate::coordinator::projection::{Projection, ProjectionTracker};
use crate::coordinator::scoreboard::{Entry, Scoreboard};
use crate::engine::request::RequestId;
use crate::gpusim::dvfs::FREQ_MAX_MHZ;

/// Outcome of admission control.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionDecision {
    Admit,
    /// Own E2E unmeetable but harmless to others (§IV-C2).
    AdmitLost,
    Queue(QueueReason),
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QueueReason {
    KvCapacity,
    TbtSlo,
    E2eSlo,
}

/// Reusable evaluation buffers: one per engine.  Holds the throughput
/// / remaining-time vectors, the violator scratch lists, and the GBDT
/// prediction memo with its validity stamp `(delta_seq, iteration)` —
/// the memo is cleared whenever the committed entry set or the
/// iteration index moves, because predictions are a function of the
/// projection those determine.
#[derive(Debug, Clone, Default)]
pub struct EvalScratch {
    t: Vec<f64>,
    t_r: Vec<f64>,
    violators: Vec<RequestId>,
    blamed: Vec<RequestId>,
    memo: PredMemo,
    /// Separate memo namespace for admission control's
    /// WITHOUT-candidate world: the two §IV-C2 worlds project
    /// different KV trajectories, and sharing one memo would let a
    /// with-candidate prediction (same (freq, batch, kv-bucket) key,
    /// different exact kv) answer a without-candidate query — the
    /// worlds must stay as independent as they were when each built
    /// its vectors from scratch.  `admission_check` swaps this in
    /// around its second evaluation.
    memo_without: PredMemo,
    stamp: Option<(u64, u64, u64)>,
}

impl EvalScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Invalidate the prediction memos if the projection identity
    /// moved.  Identity is (committed entry set via `delta_seq`,
    /// iteration, world): `world` is 0 for committed-only evaluations
    /// (§IV-E throttle search) and candidate-id + 1 for admission
    /// control's with-candidate world — a throttle evaluation and an
    /// admission evaluation at the same (seq, iter) project DIFFERENT
    /// KV trajectories, so their predictions must not answer each
    /// other's queries.
    pub fn ensure_stamp(&mut self, delta_seq: u64, iter: u64, world: u64) {
        if self.stamp != Some((delta_seq, iter, world)) {
            self.memo.clear();
            self.memo_without.clear();
            self.stamp = Some((delta_seq, iter, world));
        }
    }
}

/// Summary of one SLO evaluation; the violator ids live in the
/// [`EvalScratch`] the evaluation ran in.
#[derive(Debug, Clone, Copy)]
pub struct SloSummary {
    pub tbt_ok: bool,
    pub mean_tbt_s: f64,
    /// Number of E2E violators found (ids in `EvalScratch`).
    pub violations: usize,
}

impl SloSummary {
    pub fn all_ok(&self) -> bool {
        self.tbt_ok && self.violations == 0
    }
}

/// SLO evaluation detail shared by the scheduler and the throttling
/// controller (allocating convenience form of [`SloSummary`]).
#[derive(Debug, Clone)]
pub struct SloEval {
    pub tbt_ok: bool,
    pub mean_tbt_s: f64,
    /// Queries whose predicted completion misses their deadline.
    pub e2e_violators: Vec<RequestId>,
}

impl SloEval {
    pub fn all_ok(&self) -> bool {
        self.tbt_ok && self.e2e_violators.is_empty()
    }
}

/// Evaluate TBT + E2E SLOs at `freq_mhz` for the visible scoreboard
/// entries under `proj`. "Lost" entries are skipped (§IV-C2).
pub fn evaluate_slo(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    sb: &Scoreboard,
    proj: &Projection,
    freq_mhz: u32,
    now: f64,
) -> SloEval {
    let mut scratch = EvalScratch::new();
    let s = evaluate_slo_scratch(
        model,
        spec,
        slo,
        sb.visible(),
        proj,
        freq_mhz,
        now,
        1.0,
        &mut scratch,
    );
    SloEval {
        tbt_ok: s.tbt_ok,
        mean_tbt_s: s.mean_tbt_s,
        e2e_violators: scratch.violators,
    }
}

/// `evaluate_slo` over an explicit entry set (allocating convenience
/// wrapper around [`evaluate_slo_scratch`]).
#[allow(clippy::too_many_arguments)]
pub fn evaluate_slo_entries(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    entries: &[Entry],
    proj: &Projection,
    freq_mhz: u32,
    now: f64,
    t_r_scale: f64,
) -> SloEval {
    let mut scratch = EvalScratch::new();
    let s = evaluate_slo_scratch(
        model,
        spec,
        slo,
        entries.iter(),
        proj,
        freq_mhz,
        now,
        t_r_scale,
        &mut scratch,
    );
    SloEval {
        tbt_ok: s.tbt_ok,
        mean_tbt_s: s.mean_tbt_s,
        e2e_violators: scratch.violators,
    }
}

/// The allocation-free SLO evaluation core (§IV-C2 checks 2-3).
///
/// `t_r_scale` inflates the predicted remaining times: the projection
/// assumes no new arrivals (§IV-B), but every future admission fuses a
/// prefill into an iteration and stalls decoding, so under sustained
/// load realized progress is systematically slower than T_R predicts.
/// The throttling controller passes `1 + λ·t_prefill` (expected
/// prefill-stall fraction); admission control keeps the paper's
/// optimistic 1.0.
///
/// Violator ids are left in `scratch.violators`; `scratch.blamed` is
/// never touched, so callers may stash a prior evaluation's verdict
/// there across a second evaluation.
// detlint: hot
#[allow(clippy::too_many_arguments)]
pub fn evaluate_slo_scratch<'a>(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    entries: impl Iterator<Item = &'a Entry>,
    proj: &Projection,
    freq_mhz: u32,
    now: f64,
    t_r_scale: f64,
    scratch: &mut EvalScratch,
) -> SloSummary {
    model.throughput_vector_into(spec, proj, freq_mhz, &mut scratch.memo, &mut scratch.t);
    PerfModel::remaining_time_into(&scratch.t, &mut scratch.t_r);
    let n = scratch.t.len();
    // T_R's last element is sum(1/ips) in the same order mean_tbt
    // sums it, so the mean falls out of the cumulative pass for free.
    let mean_tbt = if n == 0 {
        0.0
    } else {
        scratch.t_r[n - 1] / n as f64
    };
    let tbt_ok = mean_tbt <= slo.tbt_avg || n == 0;
    scratch.violators.clear();
    if n > 0 {
        let t_r = &scratch.t_r;
        for e in entries {
            if e.lost {
                continue;
            }
            // Bounds-safe: the query's last iteration (end_iter - 1)
            // clamped into the horizon even when the entry outlives
            // the projection (with/without-candidate worlds, §IV-F
            // prediction bumps).
            let Some(idx) = proj.completion_index(e.scheduled_iter, e.predicted_gen)
            else {
                continue;
            };
            debug_assert!(idx < t_r.len(), "completion index out of horizon");
            if now + t_r[idx] * t_r_scale >= e.deadline_s {
                scratch.violators.push(e.id);
            }
        }
    }
    SloSummary {
        tbt_ok,
        mean_tbt_s: mean_tbt,
        violations: scratch.violators.len(),
    }
}

/// The scheduler: owns the SLO spec; stateless otherwise.
#[derive(Debug, Clone)]
pub struct Scheduler {
    pub slo: SloSpec,
}

impl Scheduler {
    pub fn new(slo: SloSpec) -> Self {
        Self { slo }
    }

    /// Run admission control for a new query.
    ///
    /// The caller must have `virtual_append`ed the candidate entry (id
    /// `new_id`) to `sb`; this function neither commits nor rolls back
    /// — it only decides.  Both the with-candidate world (committed +
    /// virtual) and, when needed, the without-candidate world come
    /// from `tracker`'s incrementally maintained projection; all
    /// evaluation buffers live in `scratch`, so the steady admit path
    /// performs no allocation.
    ///
    /// The second returned value lists RESIDENT queries whose
    /// deadlines are unmeetable even *without* the candidate: they are
    /// de-facto lost (the continuous extension of the paper's "lost"
    /// marking) and the caller should mark them so; they do not block
    /// the candidate, which is only blamed for violations it newly
    /// causes.
    // detlint: hot
    #[allow(clippy::too_many_arguments)]
    pub fn admission_check(
        &self,
        model: &PerfModel,
        spec: &EngineSpec,
        sb: &Scoreboard,
        tracker: &mut ProjectionTracker,
        scratch: &mut EvalScratch,
        current_iter: u64,
        now: f64,
        new_id: RequestId,
    ) -> (AdmissionDecision, Vec<RequestId>) {
        scratch.ensure_stamp(sb.delta_seq(), current_iter, new_id.wrapping_add(1));
        let proj = tracker.project(sb, current_iter, sb.virtual_entry());

        // Check 1: KV cache capacity.
        if proj.peak_kv() > spec.kv_blocks {
            // detlint: allow(r4, reason = "empty vec![] never allocates")
            return (AdmissionDecision::Queue(QueueReason::KvCapacity), vec![]);
        }

        // Checks 2-3 at maximum frequency (peak theoretical perf).
        let eval = evaluate_slo_scratch(
            model,
            spec,
            &self.slo,
            sb.visible(),
            proj,
            FREQ_MAX_MHZ,
            now,
            1.0,
            scratch,
        );
        if !eval.tbt_ok {
            // detlint: allow(r4, reason = "empty vec![] never allocates")
            return (AdmissionDecision::Queue(QueueReason::TbtSlo), vec![]);
        }

        // Residents predicted to violate with the candidate on board.
        // `blamed` is moved out of the scratch for the duration (the
        // second evaluation below refills `violators` but never
        // touches `blamed`), then returned so its capacity is reused.
        let own_violates = scratch.violators.contains(&new_id);
        let mut blamed = std::mem::take(&mut scratch.blamed);
        blamed.clear();
        blamed.extend(scratch.violators.iter().copied().filter(|&id| id != new_id));
        // detlint: allow(r4, reason = "empty vec![] never allocates; only the rare doomed-resident path pushes into it")
        let mut already_lost: Vec<RequestId> = vec![];
        if !blamed.is_empty() {
            // Which of them violate even WITHOUT the candidate?  The
            // without-world evaluates under its OWN memo namespace so
            // its GBDT predictions are computed from its own KV
            // trajectory, never borrowed from the with-world's.
            let proj_wo = tracker.project(sb, current_iter, None);
            std::mem::swap(&mut scratch.memo, &mut scratch.memo_without);
            evaluate_slo_scratch(
                model,
                spec,
                &self.slo,
                sb.committed().iter(),
                proj_wo,
                FREQ_MAX_MHZ,
                now,
                1.0,
                scratch,
            );
            std::mem::swap(&mut scratch.memo, &mut scratch.memo_without);
            blamed.retain(|id| {
                if scratch.violators.contains(id) {
                    already_lost.push(*id);
                    false
                } else {
                    true
                }
            });
        }

        let any_blamed = !blamed.is_empty();
        scratch.blamed = blamed;
        let decision = if any_blamed {
            AdmissionDecision::Queue(QueueReason::E2eSlo)
        } else if own_violates {
            // Only its own SLO unmeetable: schedule but mark lost.
            AdmissionDecision::AdmitLost
        } else {
            AdmissionDecision::Admit
        };
        (decision, already_lost)
    }
}

/// Build a scoreboard entry for an arriving request.
pub fn entry_for(
    id: RequestId,
    prompt_tokens: u32,
    predicted_gen: u32,
    arrival_s: f64,
    current_iter: u64,
    slo: &SloSpec,
) -> Entry {
    Entry {
        id,
        scheduled_iter: current_iter,
        prompt_tokens,
        predicted_gen: predicted_gen.max(1),
        deadline_s: arrival_s + slo.e2e_p99,
        lost: false,
        kv_discount_blocks: 0,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::coordinator::projection::project;

    fn setup() -> (PerfModel, EngineSpec, Scheduler) {
        let e = llama2_13b(2);
        let m = PerfModel::train(&[e.clone()], 40, 0);
        let s = Scheduler::new(SloSpec::new(0.2, 30.2));
        (m, e, s)
    }

    fn entry(id: u64, s_i: u64, prompt: u32, pred: u32, deadline: f64) -> Entry {
        Entry {
            id,
            scheduled_iter: s_i,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: deadline,
            lost: false,
            kv_discount_blocks: 0,
        }
    }

    fn check(
        sched: &Scheduler,
        m: &PerfModel,
        e: &EngineSpec,
        sb: &Scoreboard,
        k: u64,
        now: f64,
        new_id: u64,
    ) -> (AdmissionDecision, Vec<u64>) {
        let mut tracker = ProjectionTracker::new(e.block_tokens);
        let mut scratch = EvalScratch::new();
        sched.admission_check(m, e, sb, &mut tracker, &mut scratch, k, now, new_id)
    }

    #[test]
    fn admits_easy_query() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        sb.virtual_append(entry(1, 0, 100, 50, 30.2));
        let (d, _) = check(&sched, &m, &e, &sb, 0, 0.0, 1);
        assert_eq!(d, AdmissionDecision::Admit);
    }

    #[test]
    fn queues_on_kv_overflow() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        // One giant resident query occupying most of the pool.
        sb.insert(entry(1, 0, 24_000, 900, 1e9));
        // Candidate whose projection overflows 439 blocks * 64 tokens.
        sb.virtual_append(entry(2, 0, 6_000, 900, 1e9));
        let (d, _) = check(&sched, &m, &e, &sb, 0, 0.0, 2);
        assert_eq!(d, AdmissionDecision::Queue(QueueReason::KvCapacity));
        let proj = project(&sb, 0, e.block_tokens);
        assert!(proj.peak_kv() > e.kv_blocks);
    }

    #[test]
    fn marks_lost_when_only_own_deadline_fails() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        // Candidate with an absurdly tight deadline (already passed).
        let mut cand = entry(7, 0, 100, 400, 0.001);
        cand.deadline_s = 0.001;
        sb.virtual_append(cand);
        let (d, _) = check(&sched, &m, &e, &sb, 0, 1.0, 7);
        assert_eq!(d, AdmissionDecision::AdmitLost);
    }

    #[test]
    fn queues_when_it_breaks_others() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        // Eight residents that finish JUST inside their deadlines when
        // alone; a huge new query inflates batch + KV enough to push
        // them over (the blame-the-candidate case).
        let now = 0.0;
        // Find the residents-alone completion estimate from the model
        // itself so the test is robust to calibration changes.
        for id in 0..8 {
            sb.insert(entry(id, 0, 1000, 600, 1e9));
        }
        let proj = project(&sb, 0, e.block_tokens);
        let t = m.throughput_vector(&e, &proj, FREQ_MAX_MHZ);
        let t_r = PerfModel::remaining_time_vector(&t);
        let alone = *t_r.last().unwrap();
        // Deadline with ~2.5% headroom over the alone-case estimate.
        let deadline = now + alone * 1.025;
        let mut sb = Scoreboard::new();
        for id in 0..8 {
            sb.insert(entry(id, 0, 1000, 600, deadline));
        }
        sb.virtual_append(entry(99, 0, 4000, 1024, now + 30.2));
        let (d, lost) = check(&sched, &m, &e, &sb, 0, now, 99);
        assert_eq!(d, AdmissionDecision::Queue(QueueReason::E2eSlo));
        assert!(lost.is_empty(), "residents were fine without candidate");
    }

    #[test]
    fn doomed_residents_do_not_block_admission() {
        // Residents whose deadlines are hopeless regardless of the
        // candidate must be reported de-facto lost, not blamed on the
        // candidate (otherwise one doomed query blocks all admissions
        // until it completes — the convoy pathology).
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 500, 600, 0.5)); // deadline long gone
        sb.virtual_append(entry(2, 0, 100, 100, 1000.0));
        let (d, lost) = check(&sched, &m, &e, &sb, 0, 5.0, 2);
        assert_eq!(d, AdmissionDecision::Admit);
        assert_eq!(lost, vec![1]);
    }

    #[test]
    fn lost_entries_ignored_in_validation() {
        let (m, e, sched) = setup();
        let mut sb = Scoreboard::new();
        let mut hopeless = entry(1, 0, 3000, 600, 0.0);
        hopeless.lost = true;
        sb.insert(hopeless);
        sb.virtual_append(entry(2, 0, 100, 100, 1000.0));
        let (d, _) = check(&sched, &m, &e, &sb, 0, 1.0, 2);
        assert_eq!(d, AdmissionDecision::Admit);
    }

    #[test]
    fn repeated_checks_reuse_tracker_and_scratch() {
        // The serving loop keeps ONE tracker + scratch per engine and
        // runs every admission through them; decisions must be
        // identical to fresh-state checks (the tracker's debug
        // cross-check also pins the projections bit-for-bit).
        let (m, e, sched) = setup();
        let mut tracker = ProjectionTracker::new(e.block_tokens);
        let mut scratch = EvalScratch::new();
        let mut sb = Scoreboard::new();
        for round in 0..5u64 {
            let id = 100 + round;
            sb.virtual_append(entry(id, round, 400, 200, 1e9));
            let (d, _) = sched.admission_check(
                &m,
                &e,
                &sb,
                &mut tracker,
                &mut scratch,
                round,
                round as f64,
                id,
            );
            let (d_fresh, _) = check(&sched, &m, &e, &sb, round, round as f64, id);
            assert_eq!(d, d_fresh, "round {round}");
            assert_eq!(d, AdmissionDecision::Admit);
            sb.commit_virtual();
        }
        // A completion invalidates; the next check still agrees.
        sb.strike(100);
        sb.virtual_append(entry(990, 5, 400, 200, 1e9));
        let (d, _) = sched.admission_check(
            &m,
            &e,
            &sb,
            &mut tracker,
            &mut scratch,
            5,
            5.0,
            990,
        );
        let (d_fresh, _) = check(&sched, &m, &e, &sb, 5, 5.0, 990);
        assert_eq!(d, d_fresh);
    }

    #[test]
    fn evaluate_slo_mean_tbt_sane() {
        let (m, e, _s) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 100, 100, 1e9));
        let proj = project(&sb, 0, e.block_tokens);
        let eval = evaluate_slo(
            &m,
            &e,
            &SloSpec::new(0.2, 30.2),
            &sb,
            &proj,
            FREQ_MAX_MHZ,
            0.0,
        );
        // 13B TP2 at batch 1: TBT ~14 ms, far under 200 ms.
        assert!(eval.tbt_ok);
        assert!(eval.mean_tbt_s > 0.005 && eval.mean_tbt_s < 0.05);
        assert!(eval.all_ok());
    }

    #[test]
    fn scratch_matches_allocating_eval() {
        let (m, e, _s) = setup();
        let slo = SloSpec::new(0.2, 30.2);
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 100, 300, 4.0)); // likely violator
        sb.insert(entry(2, 0, 200, 100, 1e9));
        let proj = project(&sb, 0, e.block_tokens);
        let alloc = evaluate_slo_entries(
            &m,
            &e,
            &slo,
            sb.committed(),
            &proj,
            800,
            0.0,
            1.0,
        );
        let mut scratch = EvalScratch::new();
        let s = evaluate_slo_scratch(
            &m,
            &e,
            &slo,
            sb.committed().iter(),
            &proj,
            800,
            0.0,
            1.0,
            &mut scratch,
        );
        assert_eq!(alloc.tbt_ok, s.tbt_ok);
        assert_eq!(alloc.mean_tbt_s.to_bits(), s.mean_tbt_s.to_bits());
        assert_eq!(alloc.e2e_violators, scratch.violators);
        assert_eq!(alloc.e2e_violators.len(), s.violations);
    }
}
