//! The Scoreboard (paper §IV-B): metadata for every scheduled query,
//! with virtual append / commit / rollback used by admission control.
//!
//! Each entry tracks: the iteration the query was scheduled at (s_i),
//! its input length (|q_i|), its (conservatively adjusted) predicted
//! generation length (|r̂_i|), its E2E deadline, and whether it was
//! marked "lost".  When a query outlives its prediction, its entry is
//! bumped to `max_tokens` (§IV-F); when it terminates, the entry is
//! struck.
//!
//! Lookups are O(1) through an id→index map (strike/bump/get used to
//! be linear scans on the per-iteration hot path), and every committed
//! entry-set mutation is appended to a bounded delta journal so a
//! [`crate::coordinator::projection::ProjectionTracker`] can maintain
//! its incremental projection without diffing the entry set.  The
//! journal is capped: if a tracker falls further behind than
//! [`JOURNAL_CAP`] deltas, it rebuilds from scratch instead.

// Reviewed HashMap use: the id→index map is keyed lookup only and is
// never iterated (detlint r2 enforces that), so hash order cannot
// reach FleetOutcome.
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;

use crate::engine::request::RequestId;

/// One scheduled query's metadata.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub id: RequestId,
    /// Iteration at which the query was scheduled (s_i).
    pub scheduled_iter: u64,
    /// Input length |q_i| (tokens).
    pub prompt_tokens: u32,
    /// Predicted generation length |r̂_i| (tokens), conservatively
    /// adjusted; maintained >= tokens already generated + 1 while live.
    pub predicted_gen: u32,
    /// Absolute E2E deadline (arrival + E2E SLO), seconds.
    pub deadline_s: f64,
    /// "Lost" queries are ignored in later SLO validations (§IV-C2).
    pub lost: bool,
    /// KV blocks this entry does NOT occupy because a co-resident
    /// shares them (resident prefix blocks at admission).  The §IV-B
    /// projection subtracts this from the entry's block footprint so
    /// shared prefixes count once; 0 for ungrouped entries and for
    /// conservative paths (migration, crash re-placement).
    pub kv_discount_blocks: u32,
}

impl Entry {
    /// Final iteration (exclusive): the query completes at
    /// s_i + |r̂_i| (Eq. 1's upper bound).
    pub fn end_iter(&self) -> u64 {
        self.scheduled_iter + self.predicted_gen as u64
    }
}

/// One committed-entry-set mutation, as seen by projection consumers.
/// `lost`-flag changes are NOT journaled: projection (Eq. 1-2) does not
/// depend on the flag.  A prediction bump is `Remove(old)` + `Add(new)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Delta {
    Add(Entry),
    Remove(Entry),
}

/// Maximum journal length retained for incremental consumers.  When
/// exceeded, the OLDEST half is dropped (sliding window): a consumer
/// synced within the last `JOURNAL_CAP/2` deltas always replays
/// incrementally; one that fell further behind rebuilds from the
/// entry set.
pub const JOURNAL_CAP: usize = 256;

/// The scoreboard: committed entries + at most one virtual entry.
#[derive(Debug, Clone, Default)]
pub struct Scoreboard {
    entries: Vec<Entry>,
    /// id → position in `entries` (kept in sync via swap-remove on
    /// strike; `committed()` order is therefore arbitrary — everything
    /// downstream is order-independent sums / per-entry checks).
    index: HashMap<RequestId, usize>,
    virtual_entry: Option<Entry>,
    /// Committed entries currently marked lost (O(1) `any_lost`).
    lost_count: u32,
    /// Mutation counter: bumps on every entry-set change.  Consumers
    /// caching projection-derived state (the fleet router's headroom
    /// cache) key on it to invalidate on admission/completion without
    /// diffing the entries themselves.
    epoch: u64,
    /// Delta journal of committed-entry mutations (projection inputs
    /// only).  `journal[i]` carries sequence number
    /// `journal_start_seq + i`; `next_seq` is the sequence number the
    /// NEXT delta will get.
    journal: Vec<Delta>,
    journal_start_seq: u64,
    next_seq: u64,
}

impl Scoreboard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutation counter; changes whenever the visible entry set may
    /// have changed.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Sequence number of the next committed-entry delta.  Unlike
    /// [`Self::epoch`], this moves only on mutations that change the
    /// PROJECTION inputs (not on virtual append/rollback or lost
    /// marking), so it identifies the committed entry set exactly.
    pub fn delta_seq(&self) -> u64 {
        self.next_seq
    }

    /// The journal window available for incremental replay:
    /// `(start_seq, deltas, next_seq)` — `deltas[i]` has sequence
    /// number `start_seq + i`.  A consumer synced to `s < start_seq`
    /// missed dropped deltas and must rebuild from [`Self::committed`].
    pub fn journal(&self) -> (u64, &[Delta], u64) {
        (self.journal_start_seq, &self.journal, self.next_seq)
    }

    fn record(&mut self, d: Delta) {
        self.journal.push(d);
        self.next_seq += 1;
        if self.journal.len() > JOURNAL_CAP {
            // Slide the window: drop the OLDEST half in one batch
            // (amortized O(1) per record), keeping the most recent
            // JOURNAL_CAP/2 deltas so a tracker that syncs regularly
            // never falls off the window — only one that went
            // genuinely stale is forced to rebuild.
            let drop = JOURNAL_CAP / 2;
            self.journal.drain(..drop);
            self.journal_start_seq += drop as u64;
        }
    }

    /// Committed entries (excludes the virtual one).  Order is
    /// arbitrary (strike uses swap-remove).
    pub fn committed(&self) -> &[Entry] {
        &self.entries
    }

    /// All entries visible to projection: committed + virtual.
    pub fn visible(&self) -> impl Iterator<Item = &Entry> {
        self.entries.iter().chain(self.virtual_entry.iter())
    }

    /// The outstanding virtual entry, if any.
    pub fn virtual_entry(&self) -> Option<&Entry> {
        self.virtual_entry.as_ref()
    }

    pub fn len(&self) -> usize {
        self.entries.len() + usize::from(self.virtual_entry.is_some())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether any live (non-virtual) entry is marked lost.
    pub fn any_lost(&self) -> bool {
        self.lost_count > 0
    }

    fn push_committed(&mut self, e: Entry) {
        debug_assert!(
            !self.index.contains_key(&e.id),
            "duplicate scoreboard entry {}",
            e.id
        );
        self.index.insert(e.id, self.entries.len());
        if e.lost {
            self.lost_count += 1;
        }
        self.entries.push(e);
        self.record(Delta::Add(e));
        self.epoch += 1;
    }

    /// Add a committed entry directly (engine-side admission).
    pub fn insert(&mut self, e: Entry) {
        self.push_committed(e);
    }

    /// "Virtually" append a new query (paper: assess how future KV and
    /// batch would look if it were scheduled now). At most one virtual
    /// entry can be outstanding.
    pub fn virtual_append(&mut self, e: Entry) {
        assert!(
            self.virtual_entry.is_none(),
            "virtual entry already outstanding"
        );
        self.virtual_entry = Some(e);
        self.epoch += 1;
    }

    /// Commit the virtual entry (query admitted).
    pub fn commit_virtual(&mut self) -> Entry {
        let e = self
            .virtual_entry
            .take()
            .expect("no virtual entry to commit");
        self.push_committed(e);
        e
    }

    /// Roll back the virtual entry (query queued).
    pub fn rollback_virtual(&mut self) {
        assert!(
            self.virtual_entry.take().is_some(),
            "no virtual entry to roll back"
        );
        self.epoch += 1;
    }

    /// Mark the committed entry as lost.
    pub fn mark_lost(&mut self, id: RequestId) {
        if let Some(&i) = self.index.get(&id) {
            if !self.entries[i].lost {
                self.lost_count += 1;
            }
            self.entries[i].lost = true;
            self.epoch += 1;
        }
    }

    /// Strike a terminated query (§IV-B: signals block deallocation).
    pub fn strike(&mut self, id: RequestId) {
        if let Some(i) = self.index.remove(&id) {
            let e = self.entries.swap_remove(i);
            if i < self.entries.len() {
                self.index.insert(self.entries[i].id, i);
            }
            if e.lost {
                self.lost_count -= 1;
            }
            self.record(Delta::Remove(e));
        }
        self.epoch += 1;
    }

    /// §IV-F: the query at `generated` tokens has outlived |r̂_i| —
    /// bump its predicted length. The paper bumps straight to the
    /// model's `max_tokens` limit.
    pub fn bump_overrun(&mut self, id: RequestId, max_tokens: u32) {
        if let Some(&i) = self.index.get(&id) {
            let old = self.entries[i];
            self.entries[i].predicted_gen = max_tokens;
            self.record(Delta::Remove(old));
            let new = self.entries[i];
            self.record(Delta::Add(new));
            self.epoch += 1;
        }
    }

    /// Keep predictions consistent with reality: any live query that
    /// has already generated `generated` tokens must have
    /// |r̂_i| > generated (otherwise projection would claim it
    /// finished).  Allocation-free: takes the live view as an iterator
    /// and returns the number of bumped entries.
    pub fn sync_overruns_iter(
        &mut self,
        live: impl IntoIterator<Item = (RequestId, u32)>,
        max_tokens: u32,
    ) -> u32 {
        let mut bumped = 0u32;
        for (id, generated) in live {
            if let Some(&i) = self.index.get(&id) {
                if self.entries[i].predicted_gen <= generated {
                    let old = self.entries[i];
                    self.entries[i].predicted_gen = max_tokens.max(generated + 1);
                    self.record(Delta::Remove(old));
                    let new = self.entries[i];
                    self.record(Delta::Add(new));
                    bumped += 1;
                }
            }
        }
        if bumped > 0 {
            self.epoch += 1;
        }
        bumped
    }

    /// [`Self::sync_overruns_iter`] returning the bumped ids (test /
    /// diagnostic convenience; allocates).
    pub fn sync_overruns(
        &mut self,
        live: &[(RequestId, u32)],
        max_tokens: u32,
    ) -> Vec<RequestId> {
        let mut bumped = vec![];
        for &(id, generated) in live {
            if self.sync_overruns_iter(std::iter::once((id, generated)), max_tokens) > 0
            {
                bumped.push(id);
            }
        }
        bumped
    }

    pub fn get(&self, id: RequestId) -> Option<&Entry> {
        self.index.get(&id).map(|&i| &self.entries[i])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: u64, s: u64, prompt: u32, pred: u32) -> Entry {
        Entry {
            id,
            scheduled_iter: s,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: 30.0,
            lost: false,
            kv_discount_blocks: 0,
        }
    }

    #[test]
    fn end_iter_is_schedule_plus_prediction() {
        assert_eq!(entry(1, 10, 100, 50).end_iter(), 60);
    }

    #[test]
    fn virtual_commit_persists() {
        let mut sb = Scoreboard::new();
        sb.virtual_append(entry(1, 0, 10, 5));
        assert_eq!(sb.len(), 1);
        assert_eq!(sb.committed().len(), 0);
        assert_eq!(sb.virtual_entry().unwrap().id, 1);
        sb.commit_virtual();
        assert_eq!(sb.committed().len(), 1);
        assert!(sb.virtual_entry().is_none());
    }

    #[test]
    fn virtual_rollback_erases() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        sb.virtual_append(entry(2, 0, 10, 5));
        assert_eq!(sb.visible().count(), 2);
        sb.rollback_virtual();
        assert_eq!(sb.visible().count(), 1);
        assert!(sb.get(2).is_none());
    }

    #[test]
    #[should_panic(expected = "virtual entry already outstanding")]
    fn single_virtual_entry_enforced() {
        let mut sb = Scoreboard::new();
        sb.virtual_append(entry(1, 0, 1, 1));
        sb.virtual_append(entry(2, 0, 1, 1));
    }

    #[test]
    fn strike_removes() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        sb.insert(entry(2, 0, 10, 5));
        sb.strike(1);
        assert_eq!(sb.committed().len(), 1);
        assert!(sb.get(1).is_none());
        assert_eq!(sb.get(2).unwrap().id, 2);
    }

    #[test]
    fn index_survives_swap_remove() {
        let mut sb = Scoreboard::new();
        for id in 0..8 {
            sb.insert(entry(id, 0, 10 + id as u32, 5));
        }
        // Strike from the middle: the swapped-in tail entry must stay
        // reachable through the id→index map.
        sb.strike(2);
        sb.strike(5);
        for id in [0u64, 1, 3, 4, 6, 7] {
            assert_eq!(sb.get(id).unwrap().id, id, "lost id {id}");
        }
        assert!(sb.get(2).is_none() && sb.get(5).is_none());
        assert_eq!(sb.committed().len(), 6);
    }

    #[test]
    fn overrun_bumps_to_max_tokens() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        let bumped = sb.sync_overruns(&[(1, 5)], 1024);
        assert_eq!(bumped, vec![1]);
        assert_eq!(sb.get(1).unwrap().predicted_gen, 1024);
        // No bump while under prediction.
        let bumped = sb.sync_overruns(&[(1, 900)], 1024);
        assert!(bumped.is_empty());
    }

    #[test]
    fn sync_overruns_iter_counts_without_alloc() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        sb.insert(entry(2, 0, 10, 500));
        let e0 = sb.epoch();
        let n = sb.sync_overruns_iter([(1u64, 7u32), (2, 3)].into_iter(), 1024);
        assert_eq!(n, 1);
        assert_eq!(sb.get(1).unwrap().predicted_gen, 1024);
        assert_eq!(sb.get(2).unwrap().predicted_gen, 500);
        assert!(sb.epoch() > e0);
        // Nothing to bump: epoch untouched.
        let e1 = sb.epoch();
        assert_eq!(sb.sync_overruns_iter(std::iter::empty(), 1024), 0);
        assert_eq!(sb.epoch(), e1);
    }

    #[test]
    fn epoch_tracks_mutations() {
        let mut sb = Scoreboard::new();
        let e0 = sb.epoch();
        sb.insert(entry(1, 0, 10, 5));
        assert!(sb.epoch() > e0);
        let e1 = sb.epoch();
        sb.mark_lost(1);
        assert!(sb.epoch() > e1);
        let e2 = sb.epoch();
        sb.strike(1);
        assert!(sb.epoch() > e2);
        let e3 = sb.epoch();
        // Reads leave the epoch alone.
        let _ = sb.visible().count();
        let _ = sb.get(1);
        assert_eq!(sb.epoch(), e3);
    }

    #[test]
    fn mark_lost_sets_flag() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        assert!(!sb.any_lost());
        sb.mark_lost(1);
        assert!(sb.any_lost());
        sb.mark_lost(1); // idempotent on the counter
        assert!(sb.any_lost());
        sb.strike(1);
        assert!(!sb.any_lost());
    }

    #[test]
    fn journal_replays_committed_mutations() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        sb.virtual_append(entry(2, 0, 10, 5)); // not journaled
        sb.rollback_virtual(); // not journaled
        sb.virtual_append(entry(2, 0, 10, 5));
        sb.commit_virtual(); // journaled as Add
        sb.bump_overrun(2, 99); // Remove(old) + Add(new)
        sb.strike(1);
        let (start, deltas, next) = sb.journal();
        assert_eq!(start, 0);
        assert_eq!(next, deltas.len() as u64);
        assert_eq!(
            deltas.len(),
            5, // add, add, remove+add (bump), remove (strike)
        );
        // Replaying the journal over an empty set reproduces committed.
        let mut replay: Vec<Entry> = vec![];
        for d in deltas {
            match d {
                Delta::Add(e) => replay.push(*e),
                Delta::Remove(e) => {
                    let i = replay.iter().position(|x| x.id == e.id).unwrap();
                    replay.swap_remove(i);
                }
            }
        }
        let mut got: Vec<u64> = replay.iter().map(|e| e.id).collect();
        let mut want: Vec<u64> = sb.committed().iter().map(|e| e.id).collect();
        got.sort_unstable();
        want.sort_unstable();
        assert_eq!(got, want);
        assert_eq!(replay.iter().find(|e| e.id == 2).unwrap().predicted_gen, 99);
    }

    #[test]
    fn journal_caps_and_advances_start_seq() {
        let mut sb = Scoreboard::new();
        for id in 0..(JOURNAL_CAP as u64 + 10) {
            sb.insert(entry(id, 0, 10, 5));
        }
        let (start, deltas, next) = sb.journal();
        assert!(deltas.len() <= JOURNAL_CAP);
        assert_eq!(next, JOURNAL_CAP as u64 + 10);
        assert!(start > 0, "cap must have dropped old history");
        assert_eq!(start + deltas.len() as u64, next);
    }

    #[test]
    fn delta_seq_ignores_virtual_and_lost_churn() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        let s = sb.delta_seq();
        sb.virtual_append(entry(2, 0, 10, 5));
        sb.rollback_virtual();
        sb.mark_lost(1);
        assert_eq!(sb.delta_seq(), s, "projection inputs unchanged");
        sb.strike(1);
        assert!(sb.delta_seq() > s);
    }
}
