//! The Scoreboard (paper §IV-B): metadata for every scheduled query,
//! with virtual append / commit / rollback used by admission control.
//!
//! Each entry tracks: the iteration the query was scheduled at (s_i),
//! its input length (|q_i|), its (conservatively adjusted) predicted
//! generation length (|r̂_i|), its E2E deadline, and whether it was
//! marked "lost".  When a query outlives its prediction, its entry is
//! bumped to `max_tokens` (§IV-F); when it terminates, the entry is
//! struck.

use crate::engine::request::RequestId;

/// One scheduled query's metadata.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Entry {
    pub id: RequestId,
    /// Iteration at which the query was scheduled (s_i).
    pub scheduled_iter: u64,
    /// Input length |q_i| (tokens).
    pub prompt_tokens: u32,
    /// Predicted generation length |r̂_i| (tokens), conservatively
    /// adjusted; maintained >= tokens already generated + 1 while live.
    pub predicted_gen: u32,
    /// Absolute E2E deadline (arrival + E2E SLO), seconds.
    pub deadline_s: f64,
    /// "Lost" queries are ignored in later SLO validations (§IV-C2).
    pub lost: bool,
}

impl Entry {
    /// Final iteration (exclusive): the query completes at
    /// s_i + |r̂_i| (Eq. 1's upper bound).
    pub fn end_iter(&self) -> u64 {
        self.scheduled_iter + self.predicted_gen as u64
    }
}

/// The scoreboard: committed entries + at most one virtual entry.
#[derive(Debug, Clone, Default)]
pub struct Scoreboard {
    entries: Vec<Entry>,
    virtual_entry: Option<Entry>,
    /// Mutation counter: bumps on every entry-set change.  Consumers
    /// caching projection-derived state (the fleet router's headroom
    /// cache) key on it to invalidate on admission/completion without
    /// diffing the entries themselves.
    epoch: u64,
}

impl Scoreboard {
    pub fn new() -> Self {
        Self::default()
    }

    /// Mutation counter; changes whenever the visible entry set may
    /// have changed.
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Committed entries (excludes the virtual one).
    pub fn committed(&self) -> &[Entry] {
        &self.entries
    }

    /// All entries visible to projection: committed + virtual.
    pub fn visible(&self) -> impl Iterator<Item = &Entry> {
        self.entries.iter().chain(self.virtual_entry.iter())
    }

    pub fn len(&self) -> usize {
        self.entries.len() + usize::from(self.virtual_entry.is_some())
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Whether any live (non-virtual) entry is marked lost.
    pub fn any_lost(&self) -> bool {
        self.entries.iter().any(|e| e.lost)
    }

    /// Add a committed entry directly (engine-side admission).
    pub fn insert(&mut self, e: Entry) {
        debug_assert!(
            !self.entries.iter().any(|x| x.id == e.id),
            "duplicate scoreboard entry {}",
            e.id
        );
        self.entries.push(e);
        self.epoch += 1;
    }

    /// "Virtually" append a new query (paper: assess how future KV and
    /// batch would look if it were scheduled now). At most one virtual
    /// entry can be outstanding.
    pub fn virtual_append(&mut self, e: Entry) {
        assert!(
            self.virtual_entry.is_none(),
            "virtual entry already outstanding"
        );
        self.virtual_entry = Some(e);
        self.epoch += 1;
    }

    /// Commit the virtual entry (query admitted).
    pub fn commit_virtual(&mut self) -> Entry {
        let e = self
            .virtual_entry
            .take()
            .expect("no virtual entry to commit");
        self.entries.push(e);
        self.epoch += 1;
        e
    }

    /// Roll back the virtual entry (query queued).
    pub fn rollback_virtual(&mut self) {
        assert!(
            self.virtual_entry.take().is_some(),
            "no virtual entry to roll back"
        );
        self.epoch += 1;
    }

    /// Mark the committed entry as lost.
    pub fn mark_lost(&mut self, id: RequestId) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.id == id) {
            e.lost = true;
            self.epoch += 1;
        }
    }

    /// Strike a terminated query (§IV-B: signals block deallocation).
    pub fn strike(&mut self, id: RequestId) {
        self.entries.retain(|e| e.id != id);
        self.epoch += 1;
    }

    /// §IV-F: the query at `generated` tokens has outlived |r̂_i| —
    /// bump its predicted length. The paper bumps straight to the
    /// model's `max_tokens` limit.
    pub fn bump_overrun(&mut self, id: RequestId, max_tokens: u32) {
        if let Some(e) = self.entries.iter_mut().find(|e| e.id == id) {
            e.predicted_gen = max_tokens;
            self.epoch += 1;
        }
    }

    /// Keep predictions consistent with reality: any live query that
    /// has already generated `generated` tokens must have
    /// |r̂_i| > generated (otherwise projection would claim it
    /// finished). Returns ids that were bumped.
    pub fn sync_overruns(
        &mut self,
        live: &[(RequestId, u32)],
        max_tokens: u32,
    ) -> Vec<RequestId> {
        let mut bumped = vec![];
        for &(id, generated) in live {
            if let Some(e) = self.entries.iter_mut().find(|e| e.id == id) {
                if e.predicted_gen <= generated {
                    e.predicted_gen = max_tokens.max(generated + 1);
                    bumped.push(id);
                }
            }
        }
        if !bumped.is_empty() {
            self.epoch += 1;
        }
        bumped
    }

    pub fn get(&self, id: RequestId) -> Option<&Entry> {
        self.entries.iter().find(|e| e.id == id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn entry(id: u64, s: u64, prompt: u32, pred: u32) -> Entry {
        Entry {
            id,
            scheduled_iter: s,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: 30.0,
            lost: false,
        }
    }

    #[test]
    fn end_iter_is_schedule_plus_prediction() {
        assert_eq!(entry(1, 10, 100, 50).end_iter(), 60);
    }

    #[test]
    fn virtual_commit_persists() {
        let mut sb = Scoreboard::new();
        sb.virtual_append(entry(1, 0, 10, 5));
        assert_eq!(sb.len(), 1);
        assert_eq!(sb.committed().len(), 0);
        sb.commit_virtual();
        assert_eq!(sb.committed().len(), 1);
    }

    #[test]
    fn virtual_rollback_erases() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        sb.virtual_append(entry(2, 0, 10, 5));
        assert_eq!(sb.visible().count(), 2);
        sb.rollback_virtual();
        assert_eq!(sb.visible().count(), 1);
        assert!(sb.get(2).is_none());
    }

    #[test]
    #[should_panic(expected = "virtual entry already outstanding")]
    fn single_virtual_entry_enforced() {
        let mut sb = Scoreboard::new();
        sb.virtual_append(entry(1, 0, 1, 1));
        sb.virtual_append(entry(2, 0, 1, 1));
    }

    #[test]
    fn strike_removes() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        sb.insert(entry(2, 0, 10, 5));
        sb.strike(1);
        assert_eq!(sb.committed().len(), 1);
        assert!(sb.get(1).is_none());
    }

    #[test]
    fn overrun_bumps_to_max_tokens() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        let bumped = sb.sync_overruns(&[(1, 5)], 1024);
        assert_eq!(bumped, vec![1]);
        assert_eq!(sb.get(1).unwrap().predicted_gen, 1024);
        // No bump while under prediction.
        let bumped = sb.sync_overruns(&[(1, 900)], 1024);
        assert!(bumped.is_empty());
    }

    #[test]
    fn epoch_tracks_mutations() {
        let mut sb = Scoreboard::new();
        let e0 = sb.epoch();
        sb.insert(entry(1, 0, 10, 5));
        assert!(sb.epoch() > e0);
        let e1 = sb.epoch();
        sb.mark_lost(1);
        assert!(sb.epoch() > e1);
        let e2 = sb.epoch();
        sb.strike(1);
        assert!(sb.epoch() > e2);
        let e3 = sb.epoch();
        // Reads leave the epoch alone.
        let _ = sb.visible().count();
        let _ = sb.get(1);
        assert_eq!(sb.epoch(), e3);
    }

    #[test]
    fn mark_lost_sets_flag() {
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 0, 10, 5));
        assert!(!sb.any_lost());
        sb.mark_lost(1);
        assert!(sb.any_lost());
    }
}
