//! The serving event loop: throttLL'eM and the baseline policies over
//! a request trace (paper §V evaluation harness).
//!
//! Policies (the §V-D2 comparison matrix):
//!   * `triton()`            — KV-only admission, max frequency;
//!   * `triton_autoscale()`  — Triton + throttLL'eM autoscaling;
//!   * `throttle_only()`     — throttLL'eM w/o autoscaling (§V-D1);
//!   * `throttllem()`        — full system (§V-D2).
//!
//! The loop is a discrete-event simulation over virtual time: engines
//! execute iterations back-to-back while non-idle; arrivals, autoscaler
//! ticks and shadow-instance readiness are decision points.  Admission
//! happens at iteration boundaries, exactly as inflight batching allows.

use std::collections::VecDeque;

use crate::config::ServingConfig;
use crate::coordinator::autoscaler::{Autoscaler, ScaleDecision};
use crate::coordinator::perf_model::PerfModel;
use crate::coordinator::projection::project;
use crate::coordinator::scheduler::{entry_for, AdmissionDecision, Scheduler};
use crate::coordinator::scoreboard::Scoreboard;
use crate::coordinator::throttle::min_slo_frequency;
use crate::engine::request::{Request, RequestOutcome};
use crate::engine::sim::EngineSim;
use crate::gpusim::dvfs::FREQ_MAX_MHZ;
use crate::gpusim::power::idle_power_w;
use crate::metrics::ServingStats;
use crate::workload::predictor::conservative_adjust;

/// Serving policy knobs (the paper's ablation axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    /// SLO-aware admission control (vs KV-only, Triton-style).
    pub slo_admission: bool,
    /// GPU frequency throttling controller.
    pub throttling: bool,
    /// TP autoscaling over the configured scale set.
    pub autoscaling: bool,
}

impl Policy {
    pub fn triton() -> Self {
        Self {
            slo_admission: false,
            throttling: false,
            autoscaling: false,
        }
    }
    pub fn triton_autoscale() -> Self {
        Self {
            autoscaling: true,
            ..Self::triton()
        }
    }
    pub fn throttle_only() -> Self {
        Self {
            slo_admission: true,
            throttling: true,
            autoscaling: false,
        }
    }
    pub fn throttllem() -> Self {
        Self {
            slo_admission: true,
            throttling: true,
            autoscaling: true,
        }
    }

    pub fn name(&self) -> &'static str {
        match (self.slo_admission, self.throttling, self.autoscaling) {
            (false, false, false) => "triton",
            (false, false, true) => "triton+autoscale",
            (true, true, false) => "throttllem-noAS",
            (true, true, true) => "throttllem",
            _ => "custom",
        }
    }
}

/// One sampled point of the runtime timeline (Fig. 11).
#[derive(Debug, Clone)]
pub struct TimelinePoint {
    pub t: f64,
    /// Tensor parallelism of the engine that executed the iteration.
    pub engine_tp: u32,
    pub freq_mhz: u32,
    pub power_w: f64,
    /// Idle power of a warming shadow instance at this moment, W.
    pub shadow_power_w: f64,
    pub batch: u32,
    pub kv_blocks: u32,
}

/// Everything a serving run produces.
#[derive(Debug)]
pub struct ServeOutcome {
    pub stats: ServingStats,
    pub outcomes: Vec<RequestOutcome>,
    pub timeline: Vec<TimelinePoint>,
    /// Energy burned by warming shadow instances, J.
    pub shadow_energy_j: f64,
    /// Engine switches performed by the autoscaler.
    pub engine_switches: u32,
}

struct EngineRt {
    sim: EngineSim,
    sb: Scoreboard,
    /// Time its next iteration may start.
    cursor: f64,
    accepting: bool,
    /// Completions seen so far (admission-retry invalidation).
    completions: u64,
    /// Recent arrival timestamps (sliding window) for the throttle's
    /// prefill-load estimate.
    recent_arrivals: VecDeque<f64>,
    /// EMA of admitted prompt lengths (prefill-cost estimate input).
    prompt_ema: f64,
    /// Head-of-line request that failed admission, and the completion
    /// count at that moment.  Re-checking is pointless until another
    /// request completes (KV and batch only shrink on completion), so
    /// the hot loop skips redundant admission-control evaluations.
    blocked_head: Option<(u64, u64)>,
}

impl EngineRt {
    fn new(spec: crate::config::EngineSpec, at: f64) -> Self {
        let mut sim = EngineSim::new(spec, FREQ_MAX_MHZ);
        sim.account_idle(at.max(0.0)); // zero-cost: marks accounting start
        Self {
            sim,
            sb: Scoreboard::new(),
            cursor: at,
            accepting: true,
            completions: 0,
            blocked_head: None,
            recent_arrivals: VecDeque::new(),
            prompt_ema: 0.0,
        }
    }

    /// Expected slowdown factor from future-arrival prefill stalls:
    /// 1 + λ · t_prefill (the projection assumes no arrivals; under
    /// sustained load every admission fuses a prefill into an
    /// iteration, stalling all decodes — §IV-F's TTFT discussion).
    fn load_inflation(&mut self, now: f64) -> f64 {
        const WINDOW_S: f64 = 30.0;
        while self
            .recent_arrivals
            .front()
            .map(|&t| t < now - WINDOW_S)
            .unwrap_or(false)
        {
            self.recent_arrivals.pop_front();
        }
        // Relative margin on top of the arrival-driven term: long-
        // horizon T_R predictions are systematically optimistic (model
        // bias compounds over hundreds of iterations).
        const REL_MARGIN: f64 = 1.10;
        if self.recent_arrivals.is_empty() || self.prompt_ema <= 0.0 {
            return REL_MARGIN;
        }
        let span = (now - self.recent_arrivals.front().unwrap()).max(1.0);
        let lambda = self.recent_arrivals.len() as f64 / span.min(WINDOW_S);
        let t_prefill = crate::gpusim::latency::prefill_latency_s(
            self.sim.spec(),
            self.prompt_ema as u32,
            FREQ_MAX_MHZ,
        );
        (1.0 + lambda * t_prefill) * REL_MARGIN
    }
}

/// Serve `requests` (sorted by arrival) under `policy`; returns stats.
pub fn serve_trace(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    requests: &[Request],
) -> ServeOutcome {
    debug_assert!(requests.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    let sched = Scheduler::new(cfg.slo);

    let mut scaler = if policy.autoscaling {
        Some(Autoscaler::new(cfg.scale_set.clone(), 0))
    } else {
        None
    };
    let initial_spec = scaler
        .as_ref()
        .map(|s| s.current_spec().clone())
        .unwrap_or_else(|| cfg.engine.clone());

    let mut engines: Vec<EngineRt> = vec![EngineRt::new(initial_spec, 0.0)];
    let mut queue: VecDeque<Request> = VecDeque::new();
    let mut next_arrival = 0usize;
    let mut next_tick = scaler.as_ref().map(|s| s.interval_s);
    let mut window_arrivals = 0u64;

    let mut stats = ServingStats::default();
    let mut outcomes = Vec::new();
    let mut timeline = Vec::new();
    let mut shadow_energy = 0.0f64;
    let mut switches = 0u32;
    let mut now = 0.0f64;

    loop {
        let arrivals_done = next_arrival >= requests.len();
        let all_idle = engines.iter().all(|e| e.sim.is_idle());
        if arrivals_done && queue.is_empty() && all_idle {
            break;
        }

        // ---- next decision point -------------------------------------
        let mut decision = f64::INFINITY;
        if let Some(r) = requests.get(next_arrival) {
            decision = decision.min(r.arrival_s);
        }
        if let Some(t) = next_tick {
            if !arrivals_done || !queue.is_empty() || !all_idle {
                decision = decision.min(t);
            }
        }
        if let Some(s) = scaler.as_ref().and_then(|s| s.shadow()) {
            decision = decision.min(s.ready_at);
        }

        // ---- run engine iterations up to the decision point ----------
        let mut progressed = false;
        for idx in 0..engines.len() {
            loop {
                let e = &mut engines[idx];
                if e.sim.is_idle() || e.cursor >= decision {
                    break;
                }
                if e.accepting {
                    try_admissions(
                        e, &mut queue, cfg, policy, model, &sched, &mut stats,
                    );
                }
                let e = &mut engines[idx];
                if e.sim.is_idle() {
                    break;
                }
                let shadow_p = shadow_power(scaler.as_ref(), e.cursor);
                let report = e.sim.run_iteration(e.cursor);
                e.cursor = report.start_s + report.duration_s;
                progressed = true;
                // Telemetry
                stats.power.push(report.power_w);
                stats.freq.push(report.freq_mhz as f64);
                stats.iter_tbt.push(report.duration_s);
                timeline.push(TimelinePoint {
                    t: report.start_s,
                    engine_tp: e.sim.spec().tensor_parallel,
                    freq_mhz: report.freq_mhz,
                    power_w: report.power_w,
                    shadow_power_w: shadow_p,
                    batch: report.batch,
                    kv_blocks: report.kv_blocks,
                });
                e.completions += report.completed.len() as u64;
                // Recompute-preempted rows go back to the queue head,
                // BLOCKED until some request completes — re-admitting
                // immediately would re-consume the freed blocks and
                // livelock the evict/re-admit cycle.
                for req in &report.evicted {
                    e.sb.strike(req.id);
                    queue.push_front(req.clone());
                    e.blocked_head = Some((req.id, e.completions));
                }
                let had_completions =
                    !report.completed.is_empty() || !report.evicted.is_empty();
                for o in &report.completed {
                    e.sb.strike(o.id);
                    stats.record_outcome(o);
                    outcomes.push(o.clone());
                }
                // §IV-F: bump predictions the reality has outrun.
                let live: Vec<(u64, u32)> = e
                    .sim
                    .active_info()
                    .iter()
                    .map(|a| (a.id, a.generated))
                    .collect();
                let bumped = e.sb.sync_overruns(&live, cfg.max_tokens);
                // Re-evaluate the throttling controller when the batch
                // composition changed (completion or prediction bump):
                // without this, a frequency chosen under light load
                // would persist while a queue builds behind a full
                // batch (§IV-E is admission-triggered; completions are
                // the other composition-change event).
                if policy.throttling && (had_completions || !bumped.is_empty()) {
                    rethrottle(e, !queue.is_empty(), model, &sched);
                }
            }
        }

        // Drop drained non-accepting engines (graceful shutdown done).
        engines.retain(|e| e.accepting || !e.sim.is_idle());

        if decision.is_infinite() {
            if !progressed {
                // Queue blocked with every engine idle: resolve it.
                force_progress(
                    &mut engines, &mut queue, cfg, policy, model, &sched,
                    &mut stats, now,
                );
                if queue.is_empty() && engines.iter().all(|e| e.sim.is_idle()) {
                    continue;
                }
            }
            continue;
        }

        // ---- handle the decision point --------------------------------
        now = decision;

        // Arrivals at `now`.
        while let Some(r) = requests.get(next_arrival) {
            if r.arrival_s > now {
                break;
            }
            // Feed the accepting engine's load estimator.
            if let Some(e) = engines.iter_mut().find(|e| e.accepting) {
                e.recent_arrivals.push_back(r.arrival_s);
                e.prompt_ema = if e.prompt_ema == 0.0 {
                    r.prompt_tokens as f64
                } else {
                    0.9 * e.prompt_ema + 0.1 * r.prompt_tokens as f64
                };
            }
            queue.push_back(r.clone());
            window_arrivals += 1;
            next_arrival += 1;
        }
        // Wake idle accepting engines for immediate admission.
        for e in engines.iter_mut().filter(|e| e.accepting) {
            if e.sim.is_idle() && e.cursor < now {
                e.sim.account_idle(now);
                e.cursor = now;
            }
            if e.sim.is_idle() {
                try_admissions(e, &mut queue, cfg, policy, model, &sched, &mut stats);
            }
        }

        // Autoscaler tick.
        if let (Some(s), Some(t)) = (scaler.as_mut(), next_tick) {
            if now >= t {
                let rps = window_arrivals as f64 / s.interval_s;
                window_arrivals = 0;
                if let ScaleDecision::StartShadow { target } = s.tick(now, rps) {
                    let _ = target; // energy accounted at switch time
                }
                next_tick = Some(t + s.interval_s);
            }
        }

        // Shadow instance ready -> transition.
        if let Some(s) = scaler.as_mut() {
            if let Some(sh) = s.shadow() {
                if now >= sh.ready_at {
                    let warm = idle_power_w(&s.specs()[sh.target], FREQ_MAX_MHZ)
                        * (sh.ready_at - sh.started_at);
                    shadow_energy += warm;
                    let new_idx = s.poll_ready(now).expect("shadow was ready");
                    for e in engines.iter_mut() {
                        e.accepting = false;
                    }
                    engines.push(EngineRt::new(s.specs()[new_idx].clone(), now));
                    switches += 1;
                }
            }
        }

        // Blocked-queue guard at this decision point.
        let all_idle = engines.iter().all(|e| e.sim.is_idle());
        if all_idle && !queue.is_empty() {
            force_progress(
                &mut engines, &mut queue, cfg, policy, model, &sched, &mut stats,
                now,
            );
        }
    }

    stats.wall_s = engines
        .iter()
        .map(|e| e.cursor)
        .fold(now, f64::max);
    stats.total_energy_j = engines
        .iter()
        .map(|e| e.sim.total_energy_j())
        .sum::<f64>()
        + shadow_energy;
    outcomes.sort_by(|a, b| a.id.cmp(&b.id));
    ServeOutcome {
        stats,
        outcomes,
        timeline,
        shadow_energy_j: shadow_energy,
        engine_switches: switches,
    }
}

fn shadow_power(scaler: Option<&Autoscaler>, t: f64) -> f64 {
    match scaler.and_then(|s| s.shadow().map(|sh| (s, sh))) {
        Some((s, sh)) if t >= sh.started_at && t < sh.ready_at => {
            idle_power_w(&s.specs()[sh.target], FREQ_MAX_MHZ)
        }
        _ => 0.0,
    }
}

/// Admit as many queued requests as the policy allows (FIFO with
/// head-of-line blocking, matching the paper's single queue).
fn try_admissions(
    e: &mut EngineRt,
    queue: &mut VecDeque<Request>,
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    sched: &Scheduler,
    stats: &mut ServingStats,
) {
    let now = e.cursor;
    while let Some(req) = queue.front() {
        // Blocked-head fast path: nothing relevant changed since the
        // last failed check, so skip the expensive re-evaluation.
        if let Some((id, at)) = e.blocked_head {
            if id == req.id && at == e.completions {
                break;
            }
            e.blocked_head = None;
        }
        if e.sim.batch() >= e.sim.spec().max_batch {
            break;
        }
        let spec = e.sim.spec().clone();
        let adjusted =
            conservative_adjust(req.predicted_gen, cfg.predictor_p95_error, cfg.max_tokens);
        let k = e.sim.iter_index();
        let entry = entry_for(req.id, req.prompt_tokens, adjusted, req.arrival_s, k, &sched.slo);

        let lost = if policy.slo_admission {
            e.sb.virtual_append(entry);
            let (decision, _, already_lost) =
                sched.admission_check(model, &spec, &e.sb, k, now, req.id);
            // De-facto-lost residents stop blocking future admissions.
            for id in already_lost {
                e.sb.mark_lost(id);
            }
            match decision {
                AdmissionDecision::Admit => {
                    e.sb.commit_virtual();
                    false
                }
                AdmissionDecision::AdmitLost => {
                    e.sb.commit_virtual();
                    e.sb.mark_lost(req.id);
                    true
                }
                AdmissionDecision::Queue(_) => {
                    e.sb.rollback_virtual();
                    e.blocked_head = Some((req.id, e.completions));
                    break;
                }
            }
        } else {
            // Triton baseline: KV-capacity gate only.
            if !e.sim.kv_fits(req.prompt_tokens) {
                e.blocked_head = Some((req.id, e.completions));
                break;
            }
            e.sb.insert(entry);
            false
        };

        let req = queue.pop_front().unwrap();
        match e.sim.admit(req.clone(), now, lost) {
            Ok(()) => {}
            Err(_) => {
                // Engine-side admission raced (KV or batch slot): undo
                // everything and leave the request at the queue head.
                e.sb.strike(entry.id);
                queue.push_front(req);
                e.blocked_head = Some((entry.id, e.completions));
                break;
            }
        }

        // §IV-E: the throttling controller runs on admission.
        if policy.throttling {
            rethrottle(e, !queue.is_empty(), model, sched);
        }
    }
    let _ = stats;
}

/// Run the §IV-E controller for the engine's current scoreboard.
///
/// `queue_pressure`: when admission control could NOT place every
/// waiting query (the wait queue is non-empty), the engine runs at
/// maximum frequency — queued queries' deadlines are burning and the
/// fastest drain protects their SLOs (the paper observes "peak power
/// equal to that of Triton when under high system pressure").
fn rethrottle(e: &mut EngineRt, queue_pressure: bool, model: &PerfModel, sched: &Scheduler) {
    let now = e.cursor;
    let spec = e.sim.spec().clone();
    let f = if queue_pressure {
        FREQ_MAX_MHZ
    } else {
        let scale = e.load_inflation(now);
        let proj = project(&e.sb, e.sim.iter_index(), spec.block_tokens);
        min_slo_frequency(model, &spec, &sched.slo, &e.sb, &proj, now, scale)
    };
    e.sim.dvfs.set(now, f);
}

/// The engine is idle but the queue head cannot pass admission: admit
/// it marked lost when it physically fits, otherwise drop it (it could
/// never be served by this deployment).
fn force_progress(
    engines: &mut [EngineRt],
    queue: &mut VecDeque<Request>,
    cfg: &ServingConfig,
    _policy: Policy,
    model: &PerfModel,
    sched: &Scheduler,
    stats: &mut ServingStats,
    now: f64,
) {
    let Some(e) = engines.iter_mut().find(|e| e.accepting) else {
        return;
    };
    e.sim.account_idle(now);
    e.cursor = e.cursor.max(now);
    let Some(req) = queue.front() else { return };
    if e.sim.kv_fits(req.prompt_tokens) {
        let adjusted =
            conservative_adjust(req.predicted_gen, cfg.predictor_p95_error, cfg.max_tokens);
        let entry = entry_for(
            req.id,
            req.prompt_tokens,
            adjusted,
            req.arrival_s,
            e.sim.iter_index(),
            &sched.slo,
        );
        e.sb.insert(entry);
        e.sb.mark_lost(req.id);
        let req = queue.pop_front().unwrap();
        let id = req.id;
        if e.sim.admit(req, e.cursor, true).is_err() {
            e.sb.strike(id);
            stats.dropped += 1;
        } else {
            let spec = e.sim.spec().clone();
            let proj = project(&e.sb, e.sim.iter_index(), spec.block_tokens);
            let f = min_slo_frequency(model, &spec, &sched.slo, &e.sb, &proj, now, 1.0);
            e.sim.dvfs.set(now, f);
        }
    } else {
        queue.pop_front();
        stats.dropped += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::config::ServingConfig;
    use crate::workload::trace::{synth_trace, TraceParams};
    use crate::workload::LengthPredictor;

    fn quick_trace(peak: f64, secs: f64, seed: u64) -> Vec<Request> {
        let mut reqs = synth_trace(&TraceParams::short(secs, peak, seed));
        LengthPredictor::oracle().apply(&mut reqs, 1024);
        reqs
    }

    fn model_for(spec: &crate::config::EngineSpec) -> PerfModel {
        PerfModel::train(&[spec.clone()], 40, 0)
    }

    #[test]
    fn triton_serves_everything_at_max_freq() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::triton(spec.clone());
        let m = model_for(&spec);
        let reqs = quick_trace(2.0, 60.0, 0);
        let out = serve_trace(&cfg, Policy::triton(), &m, &reqs);
        assert_eq!(out.stats.completed as usize, reqs.len());
        assert_eq!(out.stats.dropped, 0);
        assert!(out.stats.freq.values().iter().all(|&f| f == 1410.0));
        assert!(out.stats.total_energy_j > 0.0);
    }

    #[test]
    fn throttllem_reduces_energy_and_meets_slo() {
        let spec = llama2_13b(2);
        let m = model_for(&spec);
        let reqs = quick_trace(2.0, 120.0, 1);

        let cfg_t = ServingConfig::triton(spec.clone());
        let triton = serve_trace(&cfg_t, Policy::triton(), &m, &reqs);

        let cfg = ServingConfig::throttllem(spec.clone());
        let ours = serve_trace(&cfg, Policy::throttle_only(), &m, &reqs);

        assert_eq!(ours.stats.completed as usize, reqs.len());
        // Energy strictly lower than Triton's.
        assert!(
            ours.stats.total_energy_j < triton.stats.total_energy_j,
            "ours={} triton={}",
            ours.stats.total_energy_j,
            triton.stats.total_energy_j
        );
        // Mean frequency visibly below max.
        assert!(ours.stats.freq.mean() < 1350.0);
        // TBT SLO comfortably met on average.
        assert!(ours.stats.tbt.mean() < cfg.slo.tbt_avg);
        // E2E p99 within the SLO at this moderate load.
        assert!(
            ours.stats.e2e.p99() <= cfg.slo.e2e_p99,
            "p99={} slo={}",
            ours.stats.e2e.p99(),
            cfg.slo.e2e_p99
        );
    }

    #[test]
    fn queueing_under_kv_pressure() {
        // TP1 has only 120 blocks: long prompts must queue.
        let spec = llama2_13b(1);
        let m = model_for(&spec);
        let cfg = ServingConfig::throttllem(spec.clone());
        let reqs = quick_trace(1.0, 120.0, 2);
        let out = serve_trace(&cfg, Policy::throttle_only(), &m, &reqs);
        assert_eq!(
            out.stats.completed + out.stats.dropped,
            reqs.len() as u64
        );
        // Some queueing must have occurred.
        assert!(out.stats.queue.max() > 0.0);
    }

    #[test]
    fn autoscaler_switches_engines_under_varying_load() {
        let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];
        let m = PerfModel::train(&set, 40, 0);
        let mut cfg = ServingConfig::autoscaled(set);
        cfg.slo = crate::config::SloSpec::new(0.2, 31.3);
        // RPS ramps 0.75 -> 7.5: all three engines should be visited.
        let reqs = crate::workload::trace::synth_trace_rps_range(
            &TraceParams::short(600.0, 8.25, 3),
            0.75,
            7.5,
        );
        let out = serve_trace(&cfg, Policy::throttllem(), &m, &reqs);
        assert!(out.engine_switches >= 1, "switches={}", out.engine_switches);
        assert!(out.shadow_energy_j > 0.0);
        let tps: Vec<u32> = out.timeline.iter().map(|p| p.engine_tp).collect();
        assert!(tps.contains(&1) && tps.contains(&4));
        assert_eq!(
            out.stats.completed + out.stats.dropped,
            reqs.len() as u64
        );
    }

    #[test]
    fn outcomes_complete_and_sorted() {
        let spec = llama2_13b(2);
        let m = model_for(&spec);
        let cfg = ServingConfig::throttllem(spec.clone());
        let reqs = quick_trace(1.5, 60.0, 4);
        let out = serve_trace(&cfg, Policy::throttle_only(), &m, &reqs);
        assert_eq!(out.outcomes.len() as u64, out.stats.completed);
        assert!(out.outcomes.windows(2).all(|w| w[0].id < w[1].id));
        for o in &out.outcomes {
            assert!(o.e2e_s > 0.0 && o.ttft_s > 0.0);
            assert!(o.e2e_s >= o.ttft_s);
        }
    }
}
