//! The serving event loop: throttLL'eM and the baseline policies over
//! a request trace (paper §V evaluation harness), generalized into a
//! multi-replica FLEET coordinator.
//!
//! Policies (the §V-D2 comparison matrix):
//!   * `triton()`            — KV-only admission, max frequency;
//!   * `triton_autoscale()`  — Triton + throttLL'eM autoscaling;
//!   * `throttle_only()`     — throttLL'eM w/o autoscaling (§V-D1);
//!   * `throttllem()`        — full system (§V-D2).
//!
//! The loop is a discrete-event simulation over virtual time: engines
//! execute iterations back-to-back while non-idle; arrivals, autoscaler
//! ticks, shadow-instance readiness and replica activations are
//! decision points.  Admission happens at iteration boundaries, exactly
//! as inflight batching allows.
//!
//! Fleet topology ([`serve_fleet`] / [`serve_fleet_plan`]): N
//! replicas, each owning its own `EngineSim`, `Scoreboard`, DVFS
//! state and §IV-E frequency controller, fronted by an admission
//! router ([`RouterPolicy`]) that picks a replica per arrival and
//! re-routes a request on universal rejection before ever dropping it.
//! Replicas need not be identical: a [`FleetPlan`] carries one
//! [`ReplicaSpec`] per replica (mixed TP sizes, mixed model families,
//! per-replica TP ladders and SLO overrides), and the router scores
//! each replica against its OWN capacity grid.  Autoscaling is
//! two-axis: every replica right-sizes its own tensor parallelism
//! through `Autoscaler` over ITS OWN ladder (shadow instancing per
//! replica), while a [`FleetScaler`] activates/drains whole replicas
//! against the aggregate arrival rate — scale-in picks its victim by
//! projected energy-per-token, not just queue depth.  `serve_trace`
//! (== a fleet of one) is the unchanged single-engine semantics: with
//! `replicas == 1` every code path below degenerates to the original
//! event loop, so the results are bit-identical —
//! `tests/fleet_equivalence.rs` pins this.
//!
//! Parallel execution ([`FleetPlan::threads`]): the RUN phase — every
//! replica stepping its engines to the next decision point — is
//! partitioned across worker threads by a
//! [`crate::coordinator::shard::ShardPool`], while ALL coordination
//! (routing, scaling, migration, reroutes, stats reduction) stays on
//! the coordinator thread between rounds.  `--threads N` is
//! bit-identical to `--threads 1` for every scenario and thread count
//! — `tests/fleet_threads.rs` pins this the same way
//! `fleet_equivalence.rs` pins the fleet-of-one path.

// Reviewed HashMap use: `reroutes` is keyed `entry()` access only and
// is never iterated (detlint r2 enforces that), so hash order cannot
// reach FleetOutcome.
#![allow(clippy::disallowed_types)]

use std::collections::{HashMap, VecDeque};

use crate::config::fleet::{FaultSpec, MigrationSpec, PredictSpec, PrefixSpec, ReplicaSpec};
use crate::config::{EngineSpec, ModelFamily, ServingConfig, SloSpec};
use crate::coordinator::autoscaler::{FleetDecision, FleetScaler};
use crate::coordinator::migration::{
    migration_entry, migration_slo_guard, MigrationCounters,
};
use crate::coordinator::perf_model::PerfModel;
use crate::coordinator::router::{headroom_score, select_with_affinity, RouterPolicy};
use crate::coordinator::scheduler::entry_for;
use crate::coordinator::scoreboard::Entry;
use crate::coordinator::shard::{
    effective_threads, rethrottle, EngineRt, Replica, ShardPool,
};
use crate::coordinator::throttle::min_slo_frequency_with;
use crate::engine::kv_cache::blocks_for;
use crate::engine::request::{Request, RequestId, RequestOutcome};
use crate::engine::sim::KvCheckpoint;
use crate::gpusim::dvfs::FREQ_MAX_MHZ;
use crate::gpusim::latency::{decode_latency_s, GpuState};
use crate::gpusim::power::{idle_power_w, power_w};
use crate::metrics::ServingStats;
use crate::sim::faults::{fault_schedule, FaultCounters, FaultKind};
use crate::workload::fleet_trace::{
    parse_fleet_trace_jsonl, synth_fleet_trace, ScenarioKind, SessionScenario,
};
use crate::workload::forecast::ArrivalForecaster;
use crate::workload::predictor::{conservative_adjust, LengthPredictor};

/// Serving policy knobs (the paper's ablation axes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Policy {
    /// SLO-aware admission control (vs KV-only, Triton-style).
    pub slo_admission: bool,
    /// GPU frequency throttling controller.
    pub throttling: bool,
    /// TP autoscaling over the configured scale set.
    pub autoscaling: bool,
}

impl Policy {
    pub fn triton() -> Self {
        Self {
            slo_admission: false,
            throttling: false,
            autoscaling: false,
        }
    }
    pub fn triton_autoscale() -> Self {
        Self {
            autoscaling: true,
            ..Self::triton()
        }
    }
    pub fn throttle_only() -> Self {
        Self {
            slo_admission: true,
            throttling: true,
            autoscaling: false,
        }
    }
    pub fn throttllem() -> Self {
        Self {
            slo_admission: true,
            throttling: true,
            autoscaling: true,
        }
    }

    pub fn name(&self) -> &'static str {
        match (self.slo_admission, self.throttling, self.autoscaling) {
            (false, false, false) => "triton",
            (false, false, true) => "triton+autoscale",
            (true, true, false) => "throttllem-noAS",
            (true, true, true) => "throttllem",
            _ => "custom",
        }
    }
}

/// One sampled point of the runtime timeline (Fig. 11).
#[derive(Debug, Clone)]
pub struct TimelinePoint {
    pub t: f64,
    /// Replica that executed the iteration (0 for single-engine runs).
    pub replica: usize,
    /// Tensor parallelism of the engine that executed the iteration.
    pub engine_tp: u32,
    pub freq_mhz: u32,
    pub power_w: f64,
    /// Idle power of a warming shadow instance at this moment, W.
    pub shadow_power_w: f64,
    pub batch: u32,
    pub kv_blocks: u32,
}

/// Everything a serving run produces.
#[derive(Debug)]
pub struct ServeOutcome {
    pub stats: ServingStats,
    pub outcomes: Vec<RequestOutcome>,
    pub timeline: Vec<TimelinePoint>,
    /// Energy burned by warming shadow instances, J.
    pub shadow_energy_j: f64,
    /// Engine switches performed by the autoscaler.
    pub engine_switches: u32,
}

/// Fleet topology: replica count and admission-router policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FleetSpec {
    /// Number of replicas provisioned (the fleet's maximum size).
    pub replicas: usize,
    /// Admission-router policy picking a replica per arrival.
    pub router: RouterPolicy,
    /// Enable the replica-count autoscaling axis (only meaningful with
    /// `Policy::autoscaling` and more than one replica).
    pub autoscale_replicas: bool,
}

impl FleetSpec {
    /// The single-engine deployment `serve_trace` runs on.
    pub fn single() -> Self {
        Self {
            replicas: 1,
            router: RouterPolicy::RoundRobin,
            autoscale_replicas: false,
        }
    }

    pub fn new(replicas: usize, router: RouterPolicy) -> Self {
        Self::homogeneous(replicas, router)
    }

    /// `n` identical replicas behind `router` (replica-count
    /// autoscaling enabled) — every `FleetSpec` fleet is homogeneous;
    /// mixed fleets are described by a [`FleetPlan`].
    pub fn homogeneous(replicas: usize, router: RouterPolicy) -> Self {
        assert!(replicas >= 1, "a fleet needs at least one replica");
        Self {
            replicas,
            router,
            autoscale_replicas: true,
        }
    }
}

impl Default for FleetSpec {
    fn default() -> Self {
        Self::single()
    }
}

/// Full fleet description with PER-REPLICA engine specs — the
/// heterogeneous generalization of [`FleetSpec`].  One fleet can mix
/// TP sizes and model families; each replica autoscales over its own
/// TP ladder and may enforce its own SLO.
#[derive(Debug, Clone)]
pub struct FleetPlan {
    /// One deployment description per replica.
    pub replicas: Vec<ReplicaSpec>,
    /// Admission-router policy picking a replica per arrival.
    pub router: RouterPolicy,
    /// Enable the replica-count autoscaling axis.
    pub autoscale_replicas: bool,
    /// Live KV migration of resident requests on fleet-axis scale-in
    /// (`--migration on|off` + modeled transfer costs).  `None` (the
    /// default) disables the subsystem: scale-in then drains,
    /// byte-identical to the pre-migration serving loop.  Every
    /// optional subsystem on the plan follows this one convention —
    /// `Option<Spec>` is the switch, the spec carries only tuning.
    pub migration: Option<MigrationSpec>,
    /// Deterministic fault injection (`--faults on|off` +
    /// `--fault-seed`): crashes, thermal throttles, migration-link
    /// failures and preemption notices, with checkpoint-based
    /// recovery.  `None` keeps the serving loop byte-identical to the
    /// fault-free path.
    pub faults: Option<FaultSpec>,
    /// Predictive fleet control (`--predict on|off`): an arrival
    /// forecaster feeds replica pre-warming ahead of ramps, proactive
    /// KV-pressure offload, and migration-cost-aware scale-in victim
    /// ranking — all resolved in the single-threaded coordination
    /// phase.  `None` keeps the serving loop byte-identical to the
    /// reactive path.
    pub predict: Option<PredictSpec>,
    /// Copy-on-write prefix sharing + session-affine routing
    /// (`--prefix-share on|off`): grouped requests share their common
    /// prefix's full KV blocks ref-counted per engine, prefill skips
    /// resident cached tokens, the §IV-B projection counts shared
    /// blocks once, and the router prefers the replica where a
    /// session's prefix is resident.  `None` keeps allocation order,
    /// prefill arithmetic and routing byte-identical to today's path.
    pub prefix: Option<PrefixSpec>,
    /// Worker threads for the RUN phase (`--threads`): replicas are
    /// partitioned into fixed contiguous shards stepped in parallel.
    /// `0` means auto (available parallelism); any value is
    /// bit-identical to `1` — the knob only affects wall-clock speed.
    pub threads: usize,
}

impl FleetPlan {
    /// A fleet of explicitly-described (typically mixed) replicas.
    /// Replica-count autoscaling defaults off: draining a replica of a
    /// hand-picked heterogeneous set silently changes the fleet's
    /// capacity mix (enable it explicitly when that is intended).
    pub fn heterogeneous(replicas: Vec<ReplicaSpec>, router: RouterPolicy) -> Self {
        assert!(!replicas.is_empty(), "a fleet needs at least one replica");
        Self {
            replicas,
            router,
            autoscale_replicas: false,
            migration: None,
            faults: None,
            predict: None,
            prefix: None,
            threads: 1,
        }
    }

    /// Enable/disable fleet-axis replica autoscaling (builder style).
    pub fn with_autoscale_replicas(mut self, on: bool) -> Self {
        self.autoscale_replicas = on;
        self
    }

    /// Replace the live-migration policy (builder style; `None` = off).
    pub fn with_migration(mut self, migration: Option<MigrationSpec>) -> Self {
        self.migration = migration;
        self
    }

    /// Replace the fault-injection policy (builder style; `None` = off).
    pub fn with_faults(mut self, faults: Option<FaultSpec>) -> Self {
        self.faults = faults;
        self
    }

    /// Replace the predictive-control policy (builder style; `None` =
    /// off).
    pub fn with_prediction(mut self, predict: Option<PredictSpec>) -> Self {
        self.predict = predict;
        self
    }

    /// Replace the prefix-sharing policy (builder style; `None` = off,
    /// byte-identical to the pre-sharing allocator and router).
    pub fn with_prefix_sharing(mut self, prefix: Option<PrefixSpec>) -> Self {
        self.prefix = prefix;
        self
    }

    /// Set the RUN-phase worker-thread count (builder style).  `0`
    /// means auto; every value produces bit-identical output.
    pub fn with_threads(mut self, threads: usize) -> Self {
        self.threads = threads;
        self
    }

    /// `n` identical replicas derived from `cfg` exactly as
    /// [`serve_fleet`] deploys them — bit-identical to the
    /// `FleetSpec::homogeneous(n)` path (`tests/hetero_fleet.rs` pins
    /// this).  `autoscale_replicas` enables the fleet (replica-count)
    /// autoscaling axis.
    pub fn homogeneous(
        n: usize,
        router: RouterPolicy,
        cfg: &ServingConfig,
        policy: Policy,
        autoscale_replicas: bool,
    ) -> Self {
        assert!(n >= 1, "a fleet needs at least one replica");
        Self {
            replicas: vec![ReplicaSpec::from_config(cfg, policy.autoscaling); n],
            router,
            autoscale_replicas,
            migration: None,
            faults: None,
            predict: None,
            prefix: None,
            threads: 1,
        }
    }

    fn from_fleet_spec(fleet: &FleetSpec, cfg: &ServingConfig, policy: Policy) -> Self {
        Self::homogeneous(
            fleet.replicas,
            fleet.router,
            cfg,
            policy,
            fleet.autoscale_replicas,
        )
    }

    /// Whether any replica differs from the first.
    pub fn is_heterogeneous(&self) -> bool {
        self.replicas.windows(2).any(|w| w[0] != w[1])
    }

    /// Unique engines across every replica's boot spec and TP ladder —
    /// the performance-model training set for this fleet.
    pub fn engines(&self) -> Vec<EngineSpec> {
        let mut out: Vec<EngineSpec> = Vec::new();
        for r in &self.replicas {
            for e in r.engines() {
                if !out.iter().any(|x| x.name == e.name) {
                    out.push(e);
                }
            }
        }
        out
    }

    /// Sum of the replicas' rated max loads (trace right-scaling).
    pub fn rated_rps(&self) -> f64 {
        self.replicas.iter().map(|r| r.engine.max_load_rps).sum()
    }
}

/// Per-replica slice of a fleet run.
#[derive(Debug, Clone)]
pub struct ReplicaOutcome {
    pub stats: ServingStats,
    pub shadow_energy_j: f64,
    pub engine_switches: u32,
    /// Arrivals the router assigned to this replica.
    pub routed: u64,
    /// Name of the engine the replica ended the run on.
    pub engine: String,
}

/// Aggregate serving stats for every replica of one model family
/// (the heterogeneous-fleet breakdown).
#[derive(Debug, Clone)]
pub struct FamilyStats {
    pub family: ModelFamily,
    /// Replicas of this family in the fleet.
    pub replicas: usize,
    /// Effective SLO those replicas enforce (the family's first
    /// replica's — per-replica overrides within a family can differ).
    pub slo: SloSpec,
    pub stats: ServingStats,
}

/// Everything a fleet run produces: the aggregate view plus the
/// per-replica breakdown.
#[derive(Debug)]
pub struct FleetOutcome {
    /// Fleet-aggregate outcome (identical to the single-engine outcome
    /// when `replicas == 1`).
    pub total: ServeOutcome,
    pub replicas: Vec<ReplicaOutcome>,
    /// Per-model-family aggregation (one entry per family, first-seen
    /// order; a single entry for homogeneous fleets).
    pub families: Vec<FamilyStats>,
    /// Requests moved between replicas on universal rejection.
    pub rerouted: u64,
    /// Fleet-axis scale events.
    pub replica_activations: u32,
    pub replica_deactivations: u32,
    /// Live-migration telemetry (all zero with `--migration off`).
    pub migrations: MigrationCounters,
    /// Fault-injection and recovery telemetry (all zero with
    /// `--faults off`).
    pub faults: FaultCounters,
    /// Predictive-control telemetry (all zero with `--predict off`).
    pub predict: PredictCounters,
}

/// Predictive-control telemetry for one serving run (all zero with
/// `--predict off` — `tests/fleet_threads.rs` pins that the whole
/// outcome, not just these counters, is byte-identical then).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PredictCounters {
    /// Fleet ticks on which the forecaster was fed and consulted.
    pub forecast_ticks: u64,
    /// Replica spawns started ahead of a forecast ramp (beyond the
    /// reactive scaler's own decision).
    pub prewarmed: u64,
    /// Residents proactively migrated off a KV-pressured replica
    /// before admission had to queue behind them.
    pub proactive_migrations: u64,
    /// Proactive moves refused (capacity, destination pressure, or
    /// the destination-side SLO guard).
    pub proactive_refused: u64,
    /// Scale-in victims chosen by the migration-cost-aware ranking.
    pub predictive_scale_ins: u64,
}

/// The workload a [`FleetPlan::serve`] call runs: an explicit request
/// trace, a synthesized scenario, or a recorded JSONL replay.  This is
/// the one front door the four legacy `serve_*` entry points now shim
/// onto (`tests/fleet_equivalence.rs` pins the shims bitwise).
#[derive(Debug)]
pub enum Workload<'a> {
    /// Pre-built requests, sorted by arrival.
    Trace(&'a [Request]),
    /// Synthesize a fleet scenario right-scaled to the plan's rated
    /// load, with the oracle length predictor applied — exactly what
    /// [`serve_scenario`] always did.
    Scenario {
        kind: ScenarioKind,
        duration_s: f64,
        utilization: f64,
        seed: u64,
    },
    /// Requests loaded from a recorded JSONL fleet trace
    /// ([`Workload::replay`]).
    Replay(Vec<Request>),
    /// Synthesize a multi-turn session scenario described by the
    /// [`Scenario::session()`] builder, right-scaled to the plan's
    /// rated load — the typed front door for prefix-sharing workloads
    /// (turn counts, think times and the shared system-prompt length
    /// ride on the builder instead of raw param-field plumbing).
    Session(SessionScenario),
}

impl Workload<'_> {
    /// Load a recorded fleet-trace JSONL file as a replay workload.
    /// File I/O happens here, at construction, so [`FleetPlan::serve`]
    /// itself stays infallible.
    pub fn replay(path: &str) -> anyhow::Result<Workload<'static>> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("replay {path:?}: {e}"))?;
        let (_, reqs) = parse_fleet_trace_jsonl(&text)
            .map_err(|e| anyhow::anyhow!("replay {path:?}: {e:#}"))?;
        Ok(Workload::Replay(reqs))
    }
}

impl FleetPlan {
    /// Serve `workload` on this plan — THE fleet serving entry point.
    /// `cfg` supplies the fleet-wide policy knobs (SLO default,
    /// predictor error, `max_tokens`); `policy` the paper's ablation
    /// axes; `model` the trained §IV-C performance model.  The legacy
    /// [`serve_trace`] / [`serve_fleet`] / [`serve_fleet_plan`] /
    /// [`serve_scenario`] entry points are thin shims over this,
    /// bit-identical by construction and pinned in
    /// `tests/fleet_equivalence.rs`.
    pub fn serve(
        &self,
        cfg: &ServingConfig,
        policy: Policy,
        model: &PerfModel,
        workload: Workload,
    ) -> FleetOutcome {
        match workload {
            Workload::Trace(reqs) => serve_requests(cfg, policy, model, reqs, self),
            Workload::Replay(reqs) => serve_requests(cfg, policy, model, &reqs, self),
            Workload::Scenario {
                kind,
                duration_s,
                utilization,
                seed,
            } => {
                let params = scenario_params(self, kind, duration_s, utilization, seed);
                let mut reqs = synth_fleet_trace(&params);
                LengthPredictor::oracle().apply(&mut reqs, cfg.max_tokens);
                serve_requests(cfg, policy, model, &reqs, self)
            }
            Workload::Session(s) => {
                let params = s.params(self.replicas.len(), self.rated_rps());
                let mut reqs = synth_fleet_trace(&params);
                LengthPredictor::oracle().apply(&mut reqs, cfg.max_tokens);
                serve_requests(cfg, policy, model, &reqs, self)
            }
        }
    }
}

/// Deprecated: thin shim over [`FleetPlan::serve`] with a
/// single-replica plan.  Serve `requests` (sorted by arrival) under
/// `policy` on a fleet of one; returns the single-engine outcome.
pub fn serve_trace(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    requests: &[Request],
) -> ServeOutcome {
    serve_fleet(cfg, policy, model, requests, &FleetSpec::single()).total
}

/// Deprecated: thin shim over [`FleetPlan::serve`] with a homogeneous
/// plan.  Serve `requests` (sorted by arrival) on `fleet.replicas`
/// identical replicas under `policy`; returns per-replica and
/// aggregate outcomes.
pub fn serve_fleet(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    requests: &[Request],
    fleet: &FleetSpec,
) -> FleetOutcome {
    FleetPlan::from_fleet_spec(fleet, cfg, policy).serve(
        cfg,
        policy,
        model,
        Workload::Trace(requests),
    )
}

/// Deprecated: thin shim over [`FleetPlan::serve`] with
/// [`Workload::Trace`].  Serve `requests` (sorted by arrival) on the
/// fleet `plan` describes — one [`ReplicaSpec`] per replica, mixed TP
/// sizes / model families allowed — under `policy`; returns
/// per-replica, per-family and aggregate outcomes.
pub fn serve_fleet_plan(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    requests: &[Request],
    plan: &FleetPlan,
) -> FleetOutcome {
    plan.serve(cfg, policy, model, Workload::Trace(requests))
}

/// Thread-count dispatch behind [`FleetPlan::serve`]: spin up the
/// RUN-phase shard pool when the plan asks for parallelism, else run
/// the literal inline loop.
fn serve_requests(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    requests: &[Request],
    plan: &FleetPlan,
) -> FleetOutcome {
    let threads = effective_threads(plan.threads, plan.replicas.len());
    if threads <= 1 {
        // The single-threaded path runs the literal inline loop — no
        // pool, no channels — so `--threads 1` IS the pre-sharding
        // serving loop.
        return serve_fleet_plan_inner(cfg, policy, model, requests, plan, &mut None);
    }
    std::thread::scope(|scope| {
        let mut pool = Some(ShardPool::spawn(
            scope,
            threads,
            plan.replicas.len(),
            cfg,
            policy,
            model,
        ));
        serve_fleet_plan_inner(cfg, policy, model, requests, plan, &mut pool)
    })
}

/// The fleet event loop.  `pool` carries the RUN-phase worker pool
/// (`None` = step replicas inline on this thread); every other phase
/// is identical in both modes, which is what keeps the thread count
/// unobservable in the output.
fn serve_fleet_plan_inner(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    requests: &[Request],
    plan: &FleetPlan,
    pool: &mut Option<ShardPool>,
) -> FleetOutcome {
    debug_assert!(requests.windows(2).all(|w| w[0].arrival_s <= w[1].arrival_s));
    assert!(!plan.replicas.is_empty(), "a fleet needs at least one replica");
    let n = plan.replicas.len();

    let mut replicas: Vec<Replica> = plan
        .replicas
        .iter()
        .enumerate()
        .map(|(id, rs)| Replica::new(id, rs, cfg.slo, policy, plan.prefix.is_some()))
        .collect();

    let fleet_scaling = plan.autoscale_replicas && policy.autoscaling && n > 1;
    let mut fleet_scaler = fleet_scaling.then(|| FleetScaler::new(n));
    let mut fleet_tick = fleet_scaler.as_ref().map(|s| s.interval_s);
    let mut fleet_window = 0u64;

    let mut rr_cursor = 0usize;
    // detlint r2 audit (2026-08): `reroutes` is touched ONLY through
    // keyed `entry()` lookups (see forward_or_drop) — never iterated —
    // so its per-instance hash order cannot leak into FleetOutcome;
    // the run-twice digest test in rust/tests/fleet_threads.rs
    // regression-guards this.
    let mut reroutes: HashMap<RequestId, usize> = HashMap::new();
    let mut rerouted = 0u64;
    let mut activations = 0u32;
    let mut deactivations = 0u32;
    let mut migrations = MigrationCounters::default();
    // Recent prompt lengths (sliding window) — the prompt-length mix
    // the heterogeneity-aware scale-out scoring fits candidates
    // against.  Only maintained when the fleet axis is active.
    let mut recent_prompts: VecDeque<(f64, u32)> = VecDeque::new();

    // Fault injection (`--faults on`): the schedule is generated up
    // front from the spec's own seed over the arrival horizon, so it
    // is a pure function of (spec, fleet size, trace) — independent of
    // thread count and of anything the serving loop does.  `None`
    // keeps every fault branch below dead and the loop byte-identical
    // to the fault-free path.
    let mut faults: Option<FaultRt> = plan.faults.as_ref().map(|fspec| {
        let horizon = requests.last().map(|r| r.arrival_s).unwrap_or(0.0);
        FaultRt {
            schedule: fault_schedule(fspec, n, horizon),
            cursor: 0,
            counters: FaultCounters::default(),
            retry_q: Vec::new(),
            pending: Vec::new(),
            link_down_until: 0.0,
            next_ckpt_s: (fspec.checkpoint_interval_s > 0.0)
                .then_some(fspec.checkpoint_interval_s),
            // Recovery still needs a priced link when live migration
            // is off; the default spec models it.
            link: plan.migration.unwrap_or_else(MigrationSpec::enabled_default),
        }
    });

    // Predictive control (`--predict on`): the forecaster observes the
    // per-tick arrival rate and feeds three coordination-phase
    // decisions — pre-warm ahead of forecast ramps, proactive
    // KV-pressure offload, migration-cost-aware victim ranking.
    // `None` keeps every predictive branch below dead and the loop
    // byte-identical to the reactive path.
    let mut predict: Option<PredictRt> = plan.predict.as_ref().map(|pspec| PredictRt {
        forecaster: ArrivalForecaster::new(pspec.alpha, pspec.period_s),
        counters: PredictCounters::default(),
    });

    let mut next_arrival = 0usize;
    let mut now = 0.0f64;

    loop {
        let arrivals_done = next_arrival >= requests.len();
        let faults_quiescent = faults
            .as_ref()
            .map(|f| f.retry_q.is_empty() && f.pending.is_empty())
            .unwrap_or(true);
        if arrivals_done && faults_quiescent && replicas.iter().all(Replica::drained)
        {
            break;
        }

        // ---- next decision point -------------------------------------
        let mut decision = f64::INFINITY;
        if let Some(r) = requests.get(next_arrival) {
            decision = decision.min(r.arrival_s);
        }
        for rp in &replicas {
            if let Some(t) = rp.next_tick {
                if !arrivals_done || !rp.queue.is_empty() || !rp.all_idle() {
                    decision = decision.min(t);
                }
            }
            if let Some(s) = rp.scaler.as_ref().and_then(|s| s.shadow()) {
                decision = decision.min(s.ready_at);
            }
            if let Some(at) = rp.activation_ready {
                decision = decision.min(at);
            }
        }
        if let Some(t) = fleet_tick {
            // Reaching this point means work remains somewhere.
            decision = decision.min(t);
        }
        if let Some(f) = faults.as_ref() {
            // Every fault instant is a coordination-phase decision
            // point: onsets, window ends, respawns, drain deadlines,
            // checkpoint ticks and retry fronts all interleave with
            // the RUN phase at exact virtual times, which is what
            // keeps `--threads N` bit-identical under chaos.
            if let Some(e) = f.schedule.get(f.cursor) {
                decision = decision.min(e.at_s);
            }
            if let Some(e) = f.retry_q.first() {
                decision = decision.min(e.0);
            }
            if let Some(t) = f.next_ckpt_s {
                decision = decision.min(t);
            }
            for rp in &replicas {
                if let Some(t) = rp.respawn_at {
                    decision = decision.min(t);
                }
                if let Some((_, t)) = rp.thermal {
                    decision = decision.min(t);
                }
                if let Some(t) = rp.preempt_deadline {
                    decision = decision.min(t);
                }
            }
        }

        // ---- run engine iterations up to the decision point ----------
        // Replicas are independent over this phase (each touches only
        // its own state), so the pool may step shards in parallel;
        // `run_round` hands the fleet back in index order.
        let progressed = match pool.as_mut() {
            Some(p) => p.run_round(&mut replicas, decision),
            None => {
                let mut progressed = false;
                for rp in replicas.iter_mut() {
                    progressed |= rp.run_until(decision, cfg, policy, model);
                }
                progressed
            }
        };

        if decision.is_infinite() {
            if !progressed {
                // Queues blocked with every engine idle: resolve them.
                for idx in 0..replicas.len() {
                    resolve_blocked(
                        &mut replicas,
                        idx,
                        cfg,
                        model,
                        now,
                        &mut reroutes,
                        &mut rerouted,
                    );
                }
            }
            continue;
        }

        // ---- handle the decision point --------------------------------
        now = decision;

        // Fault axis, first half: complete respawns, close thermal
        // windows, apply due fault events, enforce drain deadlines.
        if let (Some(f), Some(fspec)) = (faults.as_mut(), plan.faults.as_ref()) {
            fault_pre_pass(
                f,
                &mut replicas,
                now,
                fspec,
                cfg,
                policy,
                model,
                plan.router,
                &mut rr_cursor,
                &mut migrations,
            );
        }

        // Arrivals at `now`, routed to a replica each.
        while let Some(r) = requests.get(next_arrival) {
            if r.arrival_s > now {
                break;
            }
            if let Some(f) = faults.as_mut() {
                if !replicas
                    .iter()
                    .any(|rp| rp.active && rp.engines.iter().any(|e| e.accepting))
                {
                    // Graceful degradation under total outage: hold
                    // the arrival if capacity returns inside its SLO
                    // budget, shed it (a counted drop at admission)
                    // otherwise.
                    let deadline = r.arrival_s + cfg.slo.e2e_p99;
                    let earliest = replicas
                        .iter()
                        .flat_map(|rp| [rp.respawn_at, rp.activation_ready])
                        .flatten()
                        .fold(f64::INFINITY, f64::min);
                    if earliest <= deadline {
                        f.pending.push(r.clone());
                    } else {
                        f.counters.shed += 1;
                    }
                    next_arrival += 1;
                    continue;
                }
            }
            let target = route_arrival(
                plan.router,
                &mut rr_cursor,
                &mut replicas,
                r,
                plan.prefix.is_some(),
            );
            let rp = &mut replicas[target];
            // Feed the accepting engine's load estimator.
            if let Some(e) = rp.engines.iter_mut().find(|e| e.accepting) {
                e.recent_arrivals.push_back(r.arrival_s);
                e.prompt_ema = if e.prompt_ema == 0.0 {
                    r.prompt_tokens as f64
                } else {
                    0.9 * e.prompt_ema + 0.1 * r.prompt_tokens as f64
                };
            }
            rp.queue.push_back(r.clone());
            rp.route_epoch += 1;
            rp.window_arrivals += 1;
            rp.routed += 1;
            fleet_window += 1;
            if fleet_scaler.is_some() {
                recent_prompts.push_back((r.arrival_s, r.prompt_tokens));
                while recent_prompts
                    .front()
                    .map(|&(t, _)| t < r.arrival_s - PROMPT_MIX_WINDOW_S)
                    .unwrap_or(false)
                {
                    recent_prompts.pop_front();
                }
            }
            next_arrival += 1;
        }
        // Wake idle accepting engines for immediate admission.
        for rp in replicas.iter_mut() {
            rp.wake_and_admit(now, cfg, policy, model);
        }

        // TP-axis autoscaler ticks (active replicas only).
        for rp in replicas.iter_mut().filter(|r| r.active) {
            rp.tick_scaler(now);
        }

        // Shadow instances ready -> transitions.
        for rp in replicas.iter_mut().filter(|r| r.active) {
            rp.complete_shadow(now);
        }

        // Fleet-axis tick: activate/drain whole replicas.
        if let (Some(fs), Some(t)) = (fleet_scaler.as_mut(), fleet_tick) {
            if now >= t {
                let rps = fleet_window as f64 / fs.interval_s;
                fleet_window = 0;
                let active_count = replicas.iter().filter(|r| r.active).count();
                let pending = replicas
                    .iter()
                    .filter(|r| r.activation_ready.is_some())
                    .count();
                let per_replica_rps = if active_count == 0 {
                    cfg.engine.max_load_rps
                } else {
                    replicas
                        .iter()
                        .filter(|r| r.active)
                        .map(|r| r.respec().max_load_rps)
                        .sum::<f64>()
                        / active_count as f64
                };
                let provisioned = active_count + pending;
                // Feed the forecaster BEFORE the reactive decision so
                // the predictive passes below (and the scale-in veto)
                // see the freshest level.  The reactive `fs.tick`
                // itself never consults the forecaster.
                if let Some(pr) = predict.as_mut() {
                    pr.forecaster.observe(now, rps);
                    pr.counters.forecast_ticks += 1;
                }
                match fs.tick(now, rps, per_replica_rps, provisioned) {
                    FleetDecision::Hold => {}
                    FleetDecision::Activate { count } => {
                        // Heterogeneity-aware scale-out: activate the
                        // inactive replicas that best fit the current
                        // prompt-length mix by capacity and projected
                        // J/token — not whichever is inactive first
                        // (ties keep index order, so homogeneous
                        // fleets behave exactly as before).
                        let order = select_scale_out_order(
                            &replicas,
                            p95_prompt(&recent_prompts),
                        );
                        let mut remaining = count;
                        for i in order {
                            if remaining == 0 {
                                break;
                            }
                            replicas[i].activation_ready =
                                Some(now + fs.spawn_time_s);
                            remaining -= 1;
                        }
                    }
                    FleetDecision::Deactivate { count } => {
                        let mut remaining = count;
                        // Predictive veto: never shed capacity the
                        // forecast says the fleet needs again within
                        // the pre-warm horizon.  Without this, the
                        // reactive scaler cancels a pre-warmed spawn
                        // every tick (resetting its warm-up clock), so
                        // a pre-warmed replica could never finish
                        // spawning across a diurnal trough.
                        if let (Some(pr), Some(pspec)) =
                            (predict.as_ref(), plan.predict.as_ref())
                        {
                            let f = pr
                                .forecaster
                                .forecast_rps(now + pspec.lead_s);
                            let keep = fs
                                .desired_replicas(f, per_replica_rps)
                                .min(provisioned);
                            remaining =
                                remaining.min(provisioned.saturating_sub(keep));
                        }
                        // Cancel pending spawns first — the cheapest
                        // capacity to shed (FleetScaler's provisioned
                        // count includes them). The partial warm-up
                        // already burned is still charged.
                        for rp in replicas.iter_mut() {
                            if remaining == 0 {
                                break;
                            }
                            if let Some(at) = rp.activation_ready {
                                let warmed =
                                    (now - (at - fs.spawn_time_s)).max(0.0);
                                let spec = rp.respec();
                                rp.shadow_energy +=
                                    idle_power_w(&spec, FREQ_MAX_MHZ) * warmed;
                                rp.activation_ready = None;
                                remaining -= 1;
                            }
                        }
                        for _ in 0..remaining {
                            let actives =
                                replicas.iter().filter(|r| r.active).count();
                            if actives <= 1 {
                                break;
                            }
                            // Energy-aware victim selection (ROADMAP
                            // "Fleet-axis energy policy"); with
                            // `--predict on` the ranking also prices
                            // what evicting each candidate costs.
                            let choice = match predict.as_mut() {
                                Some(pr) => {
                                    // Eviction pricing uses the plan's
                                    // link model, or the default costs
                                    // when migration is off (the ranking
                                    // still discounts what moving each
                                    // candidate's state would cost).
                                    let link = plan
                                        .migration
                                        .unwrap_or_else(MigrationSpec::enabled_default);
                                    let v = select_scale_in_victim_predictive(
                                        &replicas,
                                        &link,
                                    );
                                    if v.is_some() {
                                        pr.counters.predictive_scale_ins += 1;
                                    }
                                    v
                                }
                                None => select_scale_in_victim(&replicas),
                            };
                            let Some(j) = choice else {
                                break;
                            };
                            replicas[j].deactivate(now);
                            deactivations += 1;
                            // Redistribute its queued work.
                            let moved: Vec<Request> =
                                replicas[j].queue.drain(..).collect();
                            for req in moved {
                                let tgt = route_arrival(
                                    plan.router,
                                    &mut rr_cursor,
                                    &mut replicas,
                                    &req,
                                    plan.prefix.is_some(),
                                );
                                replicas[tgt].catch_up_tick(now);
                                replicas[tgt].route_epoch += 1;
                                replicas[tgt].queue.push_back(req);
                            }
                            // Live-migrate the RESIDENT requests too
                            // (instead of waiting for drain), each
                            // behind the destination-side SLO guard.
                            if let Some(mspec) = plan.migration.as_ref() {
                                let link_ok = faults
                                    .as_ref()
                                    .map(|f| now >= f.link_down_until)
                                    .unwrap_or(true);
                                let mut rollbacks = 0u64;
                                migrate_residents(
                                    &mut replicas,
                                    j,
                                    now,
                                    policy,
                                    model,
                                    mspec,
                                    &mut migrations,
                                    link_ok,
                                    &mut rollbacks,
                                );
                                if let Some(f) = faults.as_mut() {
                                    f.counters.link_failures += rollbacks;
                                }
                            }
                        }
                    }
                }
                // Predictive passes (`--predict on`, coordination
                // phase): (a) pre-warm replicas ahead of a forecast
                // ramp so the SPAWN_TIME_S cold-start window overlaps
                // the remaining quiet period instead of the ramp
                // itself; (b) proactively offload residents from
                // KV-pressured replicas before admission queues
                // behind them.
                if let (Some(pr), Some(pspec)) =
                    (predict.as_mut(), plan.predict.as_ref())
                {
                    let forecast =
                        pr.forecaster.forecast_rps(now + pspec.lead_s);
                    // Only pre-warm on a genuine forecast RISE past
                    // what the fleet already provisions — never on
                    // the downslope the reactive scaler is shedding.
                    if forecast > rps {
                        let provisioned_now = replicas
                            .iter()
                            .filter(|r| {
                                r.active || r.activation_ready.is_some()
                            })
                            .count();
                        let desired =
                            fs.desired_replicas(forecast, per_replica_rps);
                        if desired > provisioned_now {
                            let order = select_scale_out_order(
                                &replicas,
                                p95_prompt(&recent_prompts),
                            );
                            let mut want = desired - provisioned_now;
                            for i in order {
                                if want == 0 {
                                    break;
                                }
                                replicas[i].activation_ready =
                                    Some(now + fs.spawn_time_s);
                                pr.counters.prewarmed += 1;
                                want -= 1;
                            }
                        }
                    }
                    if let Some(mspec) = plan.migration.as_ref() {
                        let link_ok = faults
                            .as_ref()
                            .map(|f| now >= f.link_down_until)
                            .unwrap_or(true);
                        if link_ok {
                            proactive_offload(
                                &mut replicas,
                                now,
                                policy,
                                model,
                                mspec,
                                pspec.kv_pressure,
                                &mut migrations,
                                &mut pr.counters,
                            );
                        }
                    }
                }
                fleet_tick = Some(t + fs.interval_s);
            }
        }

        // Replica activations completing their spawn.
        if let Some(fs) = fleet_scaler.as_ref() {
            for rp in replicas.iter_mut() {
                if let Some(at) = rp.activation_ready {
                    if now >= at {
                        rp.activation_ready = None;
                        let spec = rp.respec();
                        // Warm-up energy, same accounting as a shadow.
                        rp.shadow_energy +=
                            idle_power_w(&spec, FREQ_MAX_MHZ) * fs.spawn_time_s;
                        let share = rp.prefix_share;
                        rp.engines.push(EngineRt::new(spec, now, share));
                        rp.active = true;
                        rp.next_tick =
                            rp.scaler.as_ref().map(|s| now + s.interval_s);
                        rp.route_epoch += 1;
                        activations += 1;
                    }
                }
            }
        }

        // Fault axis, second half: flush held arrivals onto restored
        // capacity, take the periodic checkpoints, work the retry
        // queue.  Runs after activation completions so a spawn and the
        // work waiting on it meet at the same decision point.
        if let (Some(f), Some(fspec)) = (faults.as_mut(), plan.faults.as_ref()) {
            fault_post_pass(
                f,
                &mut replicas,
                now,
                fspec,
                plan.router,
                &mut rr_cursor,
            );
        }

        // Blocked-queue guard at this decision point.
        for idx in 0..replicas.len() {
            if replicas[idx].all_idle() && !replicas[idx].queue.is_empty() {
                resolve_blocked(
                    &mut replicas,
                    idx,
                    cfg,
                    model,
                    now,
                    &mut reroutes,
                    &mut rerouted,
                );
            }
        }
    }

    // ---- finalize -----------------------------------------------------
    let fault_counters = faults.map(|f| f.counters).unwrap_or_default();
    // Explicit ordered reduction: per-replica parts are tagged with
    // their replica index and sorted by it before merging, so the
    // aggregate is a pure function of the SET of parts — production
    // order can never leak into the output (`metrics` property-tests
    // the permutation invariance of the merge itself).
    let mut replica_outcomes = Vec::with_capacity(n);
    let mut parts: Vec<(usize, ServeOutcome)> = Vec::with_capacity(n);
    for mut rp in replicas {
        // Fleet clock for the aggregate (bit-identical to the single-
        // engine loop when replicas == 1).
        rp.stats.wall_s = rp.engines.iter().map(|e| e.cursor).fold(now, f64::max);
        rp.stats.total_energy_j = rp
            .engines
            .iter()
            .map(|e| e.sim.total_energy_j())
            .sum::<f64>()
            + rp.retired_energy
            + rp.shadow_energy
            + rp.migration_energy;
        rp.stats.migration_energy_j = rp.migration_energy;
        // Retired engines already folded their cached-prefill telemetry
        // into `stats` when they were dropped; engines still live at
        // the end of the run fold theirs here.
        rp.stats.prefix_cached_tokens += rp
            .engines
            .iter()
            .map(|e| e.sim.prefix_cached_tokens())
            .sum::<u64>();
        rp.outcomes.sort_by(|a, b| a.id.cmp(&b.id));
        // The per-replica view gets the replica's OWN serving-window
        // end, not the fleet's: a replica drained and powered off at
        // t=60 of a 240 s run reports wall_s ~60 (its throughput and
        // tokens/s stay meaningful).
        let mut replica_stats = rp.stats.clone();
        replica_stats.wall_s = rp
            .engines
            .iter()
            .map(|e| e.cursor)
            .fold(rp.last_event_s, f64::max);
        replica_outcomes.push(ReplicaOutcome {
            stats: replica_stats,
            shadow_energy_j: rp.shadow_energy,
            engine_switches: rp.switches,
            routed: rp.routed,
            engine: rp.respec().name,
        });
        parts.push((
            rp.id,
            ServeOutcome {
                stats: rp.stats,
                outcomes: rp.outcomes,
                timeline: rp.timeline,
                shadow_energy_j: rp.shadow_energy,
                engine_switches: rp.switches,
            },
        ));
    }
    // Pin the reduction order to the replica index regardless of how
    // the parts were produced (a no-op today, the contract forever).
    parts.sort_by_key(|&(id, _)| id);
    let mut total = if parts.len() == 1 {
        // Fleet of one: hand back the replica's outcome verbatim so the
        // single-engine path stays bit-identical.
        parts.pop().unwrap().1
    } else {
        let stats =
            ServingStats::merge_ordered(parts.iter().map(|(id, p)| (*id, &p.stats)));
        let mut outcomes = Vec::new();
        let mut timeline = Vec::new();
        let mut shadow = 0.0f64;
        let mut switches = 0u32;
        for (_, part) in parts {
            outcomes.extend(part.outcomes);
            timeline.extend(part.timeline);
            shadow += part.shadow_energy_j;
            switches += part.engine_switches;
        }
        outcomes.sort_by(|a, b| a.id.cmp(&b.id));
        timeline.sort_by(|a, b| {
            a.t.partial_cmp(&b.t).unwrap_or(std::cmp::Ordering::Equal)
        });
        ServeOutcome {
            stats,
            outcomes,
            timeline,
            shadow_energy_j: shadow,
            engine_switches: switches,
        }
    };
    // Shed and faulted-lost requests never reached any replica, so
    // they are fleet-level accounting carried by the aggregate only
    // (both zero with `--faults off`).
    total.stats.shed = fault_counters.shed;
    total.stats.faulted_lost = fault_counters.faulted_lost;
    // Per-model-family aggregation (heterogeneous fleets: the CLI and
    // demos break attainment and energy out per family).
    let mut families: Vec<FamilyStats> = Vec::new();
    for (ro, rs) in replica_outcomes.iter().zip(&plan.replicas) {
        match families.iter_mut().find(|f| f.family == rs.engine.family) {
            Some(f) => {
                f.replicas += 1;
                f.stats.merge_from(&ro.stats);
            }
            None => families.push(FamilyStats {
                family: rs.engine.family,
                replicas: 1,
                slo: rs.slo.unwrap_or(cfg.slo),
                stats: ro.stats.clone(),
            }),
        }
    }
    FleetOutcome {
        total,
        replicas: replica_outcomes,
        families,
        rerouted,
        replica_activations: activations,
        replica_deactivations: deactivations,
        migrations,
        faults: fault_counters,
        predict: predict.map(|p| p.counters).unwrap_or_default(),
    }
}

/// Mutable predictive-control state threaded through the event loop
/// (`--predict on` only; the loop carries `None` otherwise, keeping
/// every predictive branch dead and the run byte-identical to the
/// reactive path — the same gating discipline as [`FaultRt`]).  The
/// forecaster is fed and queried exclusively at fleet ticks, inside
/// the single-threaded coordination phase, so `--threads N` stays
/// bit-identical.
struct PredictRt {
    forecaster: ArrivalForecaster,
    counters: PredictCounters,
}

/// Mutable fault-injection state threaded through the event loop
/// (`--faults on` only; the loop carries `None` otherwise).
struct FaultRt {
    /// Precomputed fault schedule, sorted by onset.
    schedule: Vec<crate::sim::faults::FaultEvent>,
    /// First unapplied schedule entry.
    cursor: usize,
    counters: FaultCounters,
    /// `(retry_at, attempt, request)` sorted by `(retry_at, id)` —
    /// fault-orphaned requests awaiting bounded re-admission.
    retry_q: Vec<(f64, u32, Request)>,
    /// Arrivals held during a total outage, waiting on a respawn or a
    /// pending activation inside their SLO budget.
    pending: Vec<Request>,
    /// The migration/recovery link is down while `now < until`.
    link_down_until: f64,
    /// Next periodic-checkpoint instant (`None`: checkpointing off).
    next_ckpt_s: Option<f64>,
    /// Link model pricing recovery transfers (the fleet's migration
    /// spec, or the default one when live migration is off).
    link: MigrationSpec,
}

/// Insert into the retry queue keeping `(retry_at, id)` order — the
/// queue is processed front-first, so equal retry instants resolve by
/// request id, never by insertion history.
fn push_retry(q: &mut Vec<(f64, u32, Request)>, at: f64, attempt: u32, req: Request) {
    let pos = q.partition_point(|e| (e.0, e.2.id) <= (at, req.id));
    q.insert(pos, (at, attempt, req));
}

/// Route a fault-displaced request to a surviving replica now, or park
/// it on the retry queue when the fleet has no capacity.
fn requeue_or_route(
    f: &mut FaultRt,
    replicas: &mut [Replica],
    req: Request,
    now: f64,
    fspec: &FaultSpec,
    router: RouterPolicy,
    rr_cursor: &mut usize,
) {
    if replicas
        .iter()
        .any(|r| r.active && r.engines.iter().any(|e| e.accepting))
    {
        // Recovery re-placement routes policy-only (no affinity
        // overlay): the crashed source's shared blocks are gone, and
        // the prefix re-shares wherever the retry lands.
        let tgt = route_arrival(router, rr_cursor, replicas, &req, false);
        replicas[tgt].catch_up_tick(now);
        replicas[tgt].route_epoch += 1;
        replicas[tgt].queue.push_back(req);
    } else {
        push_retry(&mut f.retry_q, now + fspec.retry_backoff_s, 1, req);
    }
}

/// Re-place one crashed resident from its periodic checkpoint onto the
/// best surviving replica (capacity-gated, priced over the recovery
/// link).  Returns false when no survivor can take it — the caller
/// falls back to a from-scratch retry.
#[allow(clippy::too_many_arguments)]
fn recover_checkpoint(
    replicas: &mut [Replica],
    from: usize,
    ckpt: KvCheckpoint,
    now: f64,
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    link: &MigrationSpec,
) -> bool {
    let footprint = ckpt.kv_tokens.max(ckpt.req.prompt_tokens);
    let Some(to) = best_reroute_target(replicas, from, footprint) else {
        return false;
    };
    let dst = &mut replicas[to];
    // Same stale-tick hazard as live migration: fast-forward a drained
    // destination before restored work makes it non-idle.
    dst.catch_up_tick(now);
    let Some(d_idx) = dst.engines.iter().position(|e| e.accepting) else {
        return false;
    };
    let de = &mut dst.engines[d_idx];
    let need = blocks_for(footprint, de.sim.spec().block_tokens);
    if de.sim.batch() >= de.sim.spec().max_batch || need > de.sim.kv_blocks_free() {
        return false;
    }
    // A checkpointed pending prefill has no KV to stream.
    let stall = if ckpt.prefill_pending {
        link.base_latency_s
    } else {
        link.transfer_seconds(need)
    };
    if de.sim.is_idle() {
        de.sim.account_idle(now);
        de.cursor = de.cursor.max(now);
    }
    let k = de.sim.iter_index();
    // The source scoreboard died with the replica: rebuild the entry
    // from the checkpoint, crediting generation progress exactly as
    // `migration_entry` does (no SLO guard — recovery beats certain
    // loss, even at the destination's expense).
    let adjusted = conservative_adjust(
        ckpt.req.predicted_gen,
        cfg.predictor_p95_error,
        cfg.max_tokens,
    )
    .max(ckpt.generated + 1);
    let entry = Entry {
        id: ckpt.req.id,
        scheduled_iter: k.saturating_sub(ckpt.generated as u64),
        prompt_tokens: ckpt.req.prompt_tokens,
        predicted_gen: adjusted,
        deadline_s: ckpt.req.arrival_s + dst.sched.slo.e2e_p99,
        lost: ckpt.lost,
        kv_discount_blocks: 0,
    };
    match de.sim.restore(ckpt, now + stall) {
        Ok(()) => {
            de.sb.insert(entry);
            dst.migration_energy += link.transfer_energy_j(stall);
            dst.route_epoch += 1;
            if policy.throttling {
                rethrottle(de, !dst.queue.is_empty(), model, &dst.sched);
            }
            true
        }
        Err(_) => false,
    }
}

/// Tear down a dead replica and recover what the last checkpoint tick
/// saved: checkpointed residents are re-placed on survivors over the
/// recovery link, everything else (uncheckpointed residents, queued
/// work) re-enters through the bounded retry queue.  The replica stays
/// dark — blacklisted by the router via `active` — until `respawn_at`.
#[allow(clippy::too_many_arguments)]
fn crash_and_recover(
    f: &mut FaultRt,
    replicas: &mut [Replica],
    idx: usize,
    now: f64,
    respawn_at: f64,
    fspec: &FaultSpec,
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
) {
    let store = std::mem::take(&mut replicas[idx].ckpt_store);
    let orphans = replicas[idx].crash(now);
    let link_ok = now >= f.link_down_until;
    for req in orphans {
        let ckpt = if link_ok {
            store
                .iter()
                .find(|(id, _)| *id == req.id)
                .map(|(_, c)| c.clone())
        } else {
            None
        };
        let recovered = match ckpt {
            Some(c) => {
                recover_checkpoint(replicas, idx, c, now, cfg, policy, model, &f.link)
            }
            None => false,
        };
        if recovered {
            f.counters.crash_recoveries += 1;
        } else {
            f.counters.crash_requeues += 1;
            push_retry(&mut f.retry_q, now + fspec.retry_backoff_s, 1, req);
        }
    }
    replicas[idx].respawn_at = Some(respawn_at);
}

/// First-half fault processing at a decision point: respawns complete,
/// thermal windows close, due fault events apply, preemption drain
/// deadlines fire.  Coordination-phase only — never touched by RUN
/// workers — so thread count stays unobservable.
#[allow(clippy::too_many_arguments)]
fn fault_pre_pass(
    f: &mut FaultRt,
    replicas: &mut [Replica],
    now: f64,
    fspec: &FaultSpec,
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    router: RouterPolicy,
    rr_cursor: &mut usize,
    migrations: &mut MigrationCounters,
) {
    // Respawns: the machine is back, warmed up like a fleet
    // activation but WITHOUT counting as one — `respawns` is its own
    // counter precisely so the autoscaler's activation telemetry
    // keeps meaning "the scaler asked for capacity".
    for rp in replicas.iter_mut() {
        let Some(at) = rp.respawn_at else { continue };
        if now < at {
            continue;
        }
        rp.respawn_at = None;
        let espec = rp.respec();
        rp.shadow_energy += idle_power_w(&espec, FREQ_MAX_MHZ) * fspec.respawn_s;
        let share = rp.prefix_share;
        rp.engines.push(EngineRt::new(espec, now, share));
        if let Some((cap, _)) = rp.thermal {
            if let Some(e) = rp.engines.last_mut() {
                e.sim.dvfs.set_cap(now, cap);
            }
        }
        rp.active = true;
        rp.next_tick = rp.scaler.as_ref().map(|s| now + s.interval_s);
        rp.last_event_s = rp.last_event_s.max(now);
        rp.route_epoch += 1;
        f.counters.respawns += 1;
    }

    // Thermal windows closing: lift the cap and let the §IV-E
    // controller re-plan at full grid, exactly as an admission would.
    for rp in replicas.iter_mut() {
        let Some((_, until)) = rp.thermal else { continue };
        if now < until {
            continue;
        }
        rp.thermal = None;
        for e in rp.engines.iter_mut() {
            e.sim.dvfs.clear_cap();
            if policy.throttling && e.accepting {
                rethrottle(e, !rp.queue.is_empty(), model, &rp.sched);
            }
        }
        rp.route_epoch += 1;
    }

    // Due fault events.  Overlapping faults on a replica already dead
    // or draining toward a preemption deadline are skipped: the
    // machine can only be lost once per outage.
    while let Some(ev) = f.schedule.get(f.cursor) {
        if ev.at_s > now {
            break;
        }
        let ev = *ev;
        f.cursor += 1;
        match ev.kind {
            FaultKind::Crash => {
                let rp = &replicas[ev.replica];
                if rp.active && rp.respawn_at.is_none() && rp.preempt_deadline.is_none()
                {
                    f.counters.crashes += 1;
                    crash_and_recover(
                        f,
                        replicas,
                        ev.replica,
                        now,
                        now + fspec.respawn_s,
                        fspec,
                        cfg,
                        policy,
                        model,
                    );
                }
            }
            FaultKind::ThermalThrottle { cap_mhz, until_s } => {
                let rp = &mut replicas[ev.replica];
                // A dark replica has no silicon to throttle.
                if rp.respawn_at.is_none() && !rp.engines.is_empty() {
                    f.counters.throttle_events += 1;
                    rp.thermal = Some((cap_mhz, until_s));
                    for e in rp.engines.iter_mut() {
                        e.sim.dvfs.set_cap(now, cap_mhz);
                        if policy.throttling && e.accepting {
                            rethrottle(e, !rp.queue.is_empty(), model, &rp.sched);
                        }
                    }
                    rp.route_epoch += 1;
                }
            }
            FaultKind::LinkDown { until_s } => {
                f.link_down_until = f.link_down_until.max(until_s);
            }
            FaultKind::Preempt { deadline_s } => {
                let rp = &replicas[ev.replica];
                if rp.active && rp.respawn_at.is_none() && rp.preempt_deadline.is_none()
                {
                    f.counters.preemptions += 1;
                    // Stop accepting and blacklist immediately; queued
                    // work never started, so it moves for free.
                    replicas[ev.replica].deactivate(now);
                    replicas[ev.replica].preempt_deadline = Some(deadline_s);
                    let moved: Vec<Request> =
                        replicas[ev.replica].queue.drain(..).collect();
                    for req in moved {
                        requeue_or_route(
                            f, replicas, req, now, fspec, router, rr_cursor,
                        );
                    }
                    // Race the drain deadline: live-migrate residents
                    // out while the notice lasts.  A down link forces
                    // the rollback branch — the source stays coherent
                    // and keeps draining toward the deadline.
                    let link_ok = now >= f.link_down_until;
                    let link = f.link;
                    let mut rollbacks = 0u64;
                    migrate_residents(
                        replicas,
                        ev.replica,
                        now,
                        policy,
                        model,
                        &link,
                        migrations,
                        link_ok,
                        &mut rollbacks,
                    );
                    f.counters.link_failures += rollbacks;
                }
            }
        }
    }

    // Preemption drain deadlines: whatever is still resident is lost
    // with the machine, recovered from checkpoints like a crash (the
    // notice gave the checkpoint cadence time to cover it).
    for i in 0..replicas.len() {
        let Some(d) = replicas[i].preempt_deadline else {
            continue;
        };
        if now < d {
            continue;
        }
        crash_and_recover(
            f,
            replicas,
            i,
            now,
            now + fspec.respawn_s,
            fspec,
            cfg,
            policy,
            model,
        );
    }
}

/// Second-half fault processing at a decision point: flush held
/// arrivals onto restored capacity, take the periodic checkpoints,
/// work the bounded retry queue.
fn fault_post_pass(
    f: &mut FaultRt,
    replicas: &mut [Replica],
    now: f64,
    fspec: &FaultSpec,
    router: RouterPolicy,
    rr_cursor: &mut usize,
) {
    let capacity = |replicas: &[Replica]| {
        replicas
            .iter()
            .any(|r| r.active && r.engines.iter().any(|e| e.accepting))
    };

    // Held arrivals meet the capacity they were promised.
    if !f.pending.is_empty() {
        if capacity(replicas) {
            let held: Vec<Request> = f.pending.drain(..).collect();
            for req in held {
                let tgt = route_arrival(router, rr_cursor, replicas, &req, false);
                replicas[tgt].catch_up_tick(now);
                replicas[tgt].route_epoch += 1;
                replicas[tgt].queue.push_back(req);
            }
        } else if !replicas
            .iter()
            .any(|r| r.respawn_at.is_some() || r.activation_ready.is_some())
        {
            // The capacity the holds were waiting on evaporated (e.g.
            // a cancelled spawn): shed rather than wait forever.
            f.counters.shed += f.pending.len() as u64;
            f.pending.clear();
        }
    }

    // Periodic best-effort checkpoints: replace each live replica's
    // store with fresh snapshots of its residents.  Non-destructive —
    // the running batch never notices.
    if let Some(t) = f.next_ckpt_s {
        if now >= t {
            for rp in replicas.iter_mut().filter(|r| r.active) {
                rp.ckpt_store.clear();
                for ei in 0..rp.engines.len() {
                    for ri in rp.engines[ei].sim.residents() {
                        if let Some(ck) = rp.engines[ei].sim.snapshot(ri.id) {
                            rp.ckpt_store.push((ri.id, ck));
                        }
                    }
                }
            }
            let mut next = t;
            while next <= now {
                next += fspec.checkpoint_interval_s;
            }
            f.next_ckpt_s = Some(next);
        }
    }

    // Bounded deterministic retry: each due entry is re-admitted when
    // any replica accepts, re-armed with exponential backoff while the
    // budget lasts, and counted lost — never hung — once it runs out.
    let due = f.retry_q.partition_point(|e| e.0 <= now);
    if due > 0 {
        let batch: Vec<(f64, u32, Request)> = f.retry_q.drain(..due).collect();
        for (_, attempt, req) in batch {
            if capacity(replicas) {
                f.counters.retries += 1;
                let tgt = route_arrival(router, rr_cursor, replicas, &req, false);
                replicas[tgt].catch_up_tick(now);
                replicas[tgt].route_epoch += 1;
                replicas[tgt].queue.push_back(req);
            } else if attempt >= fspec.retry_budget {
                f.counters.faulted_lost += 1;
            } else {
                let backoff =
                    fspec.retry_backoff_s * (1u64 << attempt.min(20)) as f64;
                push_retry(&mut f.retry_q, now + backoff, attempt + 1, req);
            }
        }
    }
}

/// Fleet-trace parameters for running `plan` under a generated
/// scenario: the shared arrival stream is right-scaled to
/// `utilization x` the fleet's aggregate rated load, with one burst
/// channel per replica.
pub fn scenario_params(
    plan: &FleetPlan,
    kind: crate::workload::fleet_trace::ScenarioKind,
    duration_s: f64,
    utilization: f64,
    seed: u64,
) -> crate::workload::fleet_trace::FleetTraceParams {
    assert!(utilization > 0.0, "utilization must be positive");
    crate::workload::fleet_trace::FleetTraceParams::scenario(
        kind,
        plan.replicas.len(),
        utilization * plan.rated_rps(),
        duration_s,
        seed,
    )
}

/// Deprecated: thin shim over [`FleetPlan::serve`] with
/// [`Workload::Scenario`] semantics.  Serve a generated fleet scenario
/// on `plan`: synthesize the fleet's ONE shared arrival stream
/// (correlated bursts land on every replica at once — the per-replica
/// synthesizer decorrelated them by construction), apply the oracle
/// length predictor, and serve.  Returns the trace parameters and
/// requests so callers can record the scenario for bit-exact JSONL
/// replay (why this shim survives: [`Workload::Scenario`] does not
/// hand the synthesized trace back).
#[allow(clippy::too_many_arguments)]
pub fn serve_scenario(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    plan: &FleetPlan,
    kind: crate::workload::fleet_trace::ScenarioKind,
    duration_s: f64,
    utilization: f64,
    seed: u64,
) -> (
    crate::workload::fleet_trace::FleetTraceParams,
    Vec<Request>,
    FleetOutcome,
) {
    let params = scenario_params(plan, kind, duration_s, utilization, seed);
    let mut reqs = synth_fleet_trace(&params);
    LengthPredictor::oracle().apply(&mut reqs, cfg.max_tokens);
    let out = plan.serve(cfg, policy, model, Workload::Trace(&reqs));
    (params, reqs, out)
}

/// FNV-1a accumulator for [`outcome_digest`] (same constants as
/// `workload::fleet_trace::fnv1a64`, streamed field-by-field).
struct Fnv(u64);

impl Fnv {
    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }
    fn u32(&mut self, v: u32) {
        self.bytes(&v.to_le_bytes());
    }
    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }
    fn f64(&mut self, v: f64) {
        self.u64(v.to_bits());
    }
    fn series(&mut self, s: &crate::metrics::Series) {
        self.u64(s.values().len() as u64);
        for &v in s.values() {
            self.f64(v);
        }
    }
}

/// Order-sensitive digest of EVERYTHING a fleet run produced: every
/// counter, every float by bit pattern, every series sample, the full
/// timeline and request outcomes, the per-replica breakdown and the
/// migration telemetry.  Two runs digest equal iff their outcomes are
/// bit-identical — the `--threads N == --threads 1` determinism
/// contract the CI `threads-identity` job compares through the CLI's
/// `--outcome-digest` flag.
pub fn outcome_digest(out: &FleetOutcome) -> u64 {
    fn stats(h: &mut Fnv, s: &ServingStats) {
        h.u64(s.completed);
        h.u64(s.dropped);
        h.u64(s.lost);
        h.u64(s.total_tokens);
        h.f64(s.total_energy_j);
        h.f64(s.wall_s);
        h.u64(s.migrated_in);
        h.u64(s.migrated_out);
        h.f64(s.migration_energy_j);
        h.u64(s.shed);
        h.u64(s.faulted_lost);
        h.u64(s.peak_kv_blocks as u64);
        h.u64(s.prefix_cached_tokens);
        h.series(&s.e2e);
        h.series(&s.tbt);
        h.series(&s.ttft);
        h.series(&s.queue);
        h.series(&s.power);
        h.series(&s.freq);
        h.series(&s.iter_tbt);
        h.series(&s.migrated_e2e);
    }
    let mut h = Fnv(0xcbf2_9ce4_8422_2325);
    stats(&mut h, &out.total.stats);
    h.u64(out.total.outcomes.len() as u64);
    for o in &out.total.outcomes {
        h.u64(o.id);
        h.f64(o.e2e_s);
        h.f64(o.ttft_s);
        h.f64(o.tbt_avg_s);
        h.u64(o.lost as u64);
    }
    h.u64(out.total.timeline.len() as u64);
    for p in &out.total.timeline {
        h.f64(p.t);
        h.u64(p.replica as u64);
        h.u32(p.engine_tp);
        h.u32(p.freq_mhz);
        h.f64(p.power_w);
        h.f64(p.shadow_power_w);
        h.u32(p.batch);
        h.u32(p.kv_blocks);
    }
    h.f64(out.total.shadow_energy_j);
    h.u32(out.total.engine_switches);
    h.u64(out.replicas.len() as u64);
    for r in &out.replicas {
        h.u64(r.routed);
        h.u32(r.engine_switches);
        h.f64(r.shadow_energy_j);
        h.bytes(r.engine.as_bytes());
        stats(&mut h, &r.stats);
    }
    h.u64(out.rerouted);
    h.u32(out.replica_activations);
    h.u32(out.replica_deactivations);
    h.u64(out.migrations.migrations);
    h.u64(out.migrations.refused_slo);
    h.u64(out.migrations.refused_capacity);
    h.u64(out.faults.crashes);
    h.u64(out.faults.crash_recoveries);
    h.u64(out.faults.crash_requeues);
    h.u64(out.faults.retries);
    h.u64(out.faults.shed);
    h.u64(out.faults.faulted_lost);
    h.u64(out.faults.throttle_events);
    h.u64(out.faults.link_failures);
    h.u64(out.faults.preemptions);
    h.u64(out.faults.respawns);
    h.u64(out.predict.forecast_ticks);
    h.u64(out.predict.prewarmed);
    h.u64(out.predict.proactive_migrations);
    h.u64(out.predict.proactive_refused);
    h.u64(out.predict.predictive_scale_ins);
    h.0
}

/// Pick the replica an arrival is routed to.  The capacity-aware
/// policies score the request against each replica's OWN grid, so a
/// prompt that can never fit a small replica is not parked there while
/// a larger one exists.
///
/// With `--prefix-share on` (`prefix_affinity`), a session turn whose
/// prefix group is already resident somewhere gets the affinity
/// overlay first: it lands on the best-scoring resident replica when
/// one has genuine headroom, re-using the shared blocks instead of
/// re-allocating the prefix elsewhere.  When no resident replica has
/// headroom — or sharing is off — routing falls through to the
/// configured policy unchanged, so `--prefix-share off` stays
/// byte-identical to the pre-sharing router.
fn route_arrival(
    router: RouterPolicy,
    rr_cursor: &mut usize,
    replicas: &mut [Replica],
    req: &Request,
    prefix_affinity: bool,
) -> usize {
    let prompt_tokens = req.prompt_tokens;
    let active: Vec<usize> = replicas
        .iter()
        .enumerate()
        .filter(|(_, r)| r.active && r.engines.iter().any(|e| e.accepting))
        .map(|(i, _)| i)
        .collect();
    match active.len() {
        0 => 0, // unreachable: the fleet axis keeps >= 1 active
        1 => active[0],
        _ => {
            if prefix_affinity && req.prefix_group != 0 {
                // Coordination phase, replica-index order: scoring is
                // deterministic and thread-count independent.
                let mut scored = Vec::with_capacity(active.len());
                for &i in &active {
                    let resident = replicas[i].prefix_resident(req.prefix_group);
                    let score = replicas[i].headroom_for(prompt_tokens);
                    scored.push((i, score, resident));
                }
                if scored.iter().any(|&(_, s, r)| r && s > 0.0) {
                    if let Some(i) = select_with_affinity(scored) {
                        return i;
                    }
                }
            }
            match router {
                RouterPolicy::RoundRobin => {
                    let i = active[*rr_cursor % active.len()];
                    *rr_cursor += 1;
                    i
                }
                RouterPolicy::LeastLoaded => {
                    // Outstanding work normalized by each replica's own
                    // batch capacity (ties keep the lowest index,
                    // matching the unnormalized homogeneous behavior
                    // exactly).
                    let mut best = active[0];
                    let mut best_load = f64::INFINITY;
                    for &i in &active {
                        let cap = replicas[i].batch_capacity().max(1) as f64;
                        let load = replicas[i].outstanding() as f64 / cap;
                        if load < best_load {
                            best_load = load;
                            best = i;
                        }
                    }
                    best
                }
                RouterPolicy::ProjectedHeadroom => {
                    let mut best = active[0];
                    let mut best_score = f64::NEG_INFINITY;
                    for &i in &active {
                        let score = replicas[i].headroom_for(prompt_tokens);
                        if score > best_score {
                            best_score = score;
                            best = i;
                        }
                    }
                    best
                }
            }
        }
    }
}

/// Energy-aware scale-in victim: the ACTIVE replica that is least
/// energy-efficient at its current operating point — highest projected
/// J/token, with idle replicas infinitely inefficient (idle power for
/// zero tokens).  Exact ties (e.g. several idle replicas) fall back to
/// the least outstanding work, then to the highest index — the
/// pre-energy-policy drain order.
fn select_scale_in_victim(replicas: &[Replica]) -> Option<usize> {
    let mut victim: Option<(f64, u64, usize)> = None;
    for (i, r) in replicas.iter().enumerate() {
        if !r.active {
            continue;
        }
        let ept = r.energy_per_token();
        let out = r.outstanding();
        let better = match victim {
            None => true,
            Some((best_ept, best_out, best_i)) => {
                if ept != best_ept {
                    ept > best_ept
                } else if out != best_out {
                    out < best_out
                } else {
                    i > best_i
                }
            }
        };
        if better {
            victim = Some((ept, out, i));
        }
    }
    victim.map(|(_, _, i)| i)
}

/// Migration-latency-aware scale-in victim (`--predict on`): like
/// [`select_scale_in_victim`], ranks ACTIVE replicas by projected
/// J/token — but discounts each candidate by what evicting it costs
/// the survivors: the modeled transfer time of its residents' KV
/// footprints plus the queued work it displaces (priced at the link's
/// base latency per entry).  A slightly less efficient replica whose
/// state is cheap to move can therefore outrank the reactive choice.
/// Idle replicas stay infinitely inefficient (and cost nothing to
/// evict), so they are still shed first.  Exact ties keep the
/// reactive order: least outstanding work, then highest index.
fn select_scale_in_victim_predictive(
    replicas: &[Replica],
    mig: &MigrationSpec,
) -> Option<usize> {
    let mut victim: Option<(f64, u64, usize)> = None;
    for (i, r) in replicas.iter().enumerate() {
        if !r.active {
            continue;
        }
        let mut move_s = 0.0f64;
        for e in &r.engines {
            let block_tokens = e.sim.spec().block_tokens;
            for ri in e.sim.residents() {
                let blocks =
                    blocks_for(ri.kv_tokens.max(ri.prompt_tokens), block_tokens);
                move_s += if ri.prefill_pending {
                    mig.base_latency_s
                } else {
                    mig.transfer_seconds(blocks)
                };
            }
        }
        move_s += r.queue.len() as f64 * mig.base_latency_s;
        let score = r.energy_per_token() / (1.0 + move_s);
        let out = r.outstanding();
        let better = match victim {
            None => true,
            Some((best_score, best_out, best_i)) => {
                if score != best_score {
                    score > best_score
                } else if out != best_out {
                    out < best_out
                } else {
                    i > best_i
                }
            }
        };
        if better {
            victim = Some((score, out, i));
        }
    }
    victim.map(|(_, _, i)| i)
}

/// Replica (other than `from`) best suited to take a token footprint
/// no engine at `from` can hold (a queued prompt on universal
/// rejection, or a resident request's KV checkpoint on live
/// migration): must be active, accepting, and have the total KV
/// capacity for `tokens`.  Candidates are ranked by normalized
/// headroom AFTER taking the request — free KV minus queued demand
/// minus the request's own blocks, over the replica's OWN capacity,
/// min'd with the equivalent batch-slot slack — so a large half-busy
/// replica can outrank a small empty one the footprint would choke.
/// (The previous raw free-block comparison systematically favored
/// big-grid replicas for every reroute, even short prompts a
/// lightly-loaded small replica should absorb.)
fn best_reroute_target(
    replicas: &[Replica],
    from: usize,
    tokens: u32,
) -> Option<usize> {
    let mut best: Option<(f64, usize)> = None;
    for (j, rp) in replicas.iter().enumerate() {
        if j == from || !rp.active {
            continue;
        }
        let Some(e) = rp.engines.iter().find(|e| e.accepting) else {
            continue;
        };
        let spec = e.sim.spec();
        if spec.kv_blocks == 0 || spec.max_batch == 0 {
            continue; // degenerate replica: can never serve anything
        }
        let need = blocks_for(tokens, spec.block_tokens);
        if need > spec.kv_blocks {
            continue; // could never fit even empty
        }
        let queued_blocks: u32 = rp
            .queue
            .iter()
            .map(|r| blocks_for(r.prompt_tokens, spec.block_tokens))
            .sum();
        // Same normalized slack formula the router scores with, fed
        // with instantaneous KV usage instead of the projection (this
        // is the cold rescue path; the queue head is already stuck).
        let score = headroom_score(
            spec.kv_blocks,
            e.sim.kv_blocks_used(),
            queued_blocks.saturating_add(need),
            spec.max_batch,
            e.sim.batch(),
            rp.queue.len() + 1,
        );
        if best.map(|(bs, _)| score > bs).unwrap_or(true) {
            best = Some((score, j));
        }
    }
    best.map(|(_, j)| j)
}

/// Sliding window over arriving prompt lengths feeding the scale-out
/// capacity fit, seconds.
const PROMPT_MIX_WINDOW_S: f64 = 60.0;

/// p95 prompt length of the recent arrival window (the scale-out
/// scoring's capacity-fit input); 1 when the window is empty, making
/// every candidate feasible.
fn p95_prompt(recent: &VecDeque<(f64, u32)>) -> u32 {
    if recent.is_empty() {
        return 1;
    }
    let mut v: Vec<u32> = recent.iter().map(|&(_, p)| p).collect();
    v.sort_unstable();
    v[((v.len() - 1) as f64 * 0.95) as usize]
}

/// Rank the inactive replicas a fleet-axis Activate should boot, best
/// fit first (ROADMAP "heterogeneity-aware scale-out"; previously the
/// activation order was whichever replica was inactive first).
/// Candidates are scored against the CURRENT prompt-length mix:
///
///   1. specs whose KV pool cannot hold the mix's p95 prompt rank
///      strictly last (feasibility);
///   2. then by projected J/token at a representative half-full
///      operating point, ascending (energy fit);
///   3. then by normalized KV headroom beyond the mix, descending;
///   4. then by index — identical specs therefore keep the old
///      first-inactive order exactly, so homogeneous fleets are
///      byte-identical to the previous behavior.
///
/// Returns only replicas that are inactive with no pending spawn —
/// and not dark from a fault: a crashed or preempted replica is the
/// FAULT path's capacity (it comes back via respawn, not activation),
/// so the autoscaler never double-books it.
fn select_scale_out_order(replicas: &[Replica], mix_p95_prompt: u32) -> Vec<usize> {
    let mut cands: Vec<(bool, f64, f64, usize)> = replicas
        .iter()
        .enumerate()
        .filter(|(_, r)| {
            !r.active
                && r.activation_ready.is_none()
                && r.respawn_at.is_none()
                && r.preempt_deadline.is_none()
        })
        .map(|(i, r)| {
            let (feasible, ept, headroom) = scale_out_fit(&r.respec(), mix_p95_prompt);
            (feasible, ept, headroom, i)
        })
        .collect();
    cands.sort_by(|a, b| {
        b.0.cmp(&a.0)
            .then(a.1.partial_cmp(&b.1).unwrap_or(std::cmp::Ordering::Equal))
            .then(b.2.partial_cmp(&a.2).unwrap_or(std::cmp::Ordering::Equal))
            .then(a.3.cmp(&b.3))
    });
    cands.into_iter().map(|(_, _, _, i)| i).collect()
}

/// `(fits-the-mix, projected J/token, normalized KV headroom)` for one
/// scale-out candidate spec.  The J/token estimate prices the spec at
/// a half-full operating point at maximum frequency — the state a
/// freshly activated replica serves ramp load in before its own §IV-E
/// controller throttles down.
fn scale_out_fit(spec: &EngineSpec, mix_p95_prompt: u32) -> (bool, f64, f64) {
    if spec.kv_blocks == 0 || spec.max_batch == 0 {
        return (false, f64::INFINITY, f64::NEG_INFINITY);
    }
    let need = blocks_for(mix_p95_prompt.max(1), spec.block_tokens);
    let feasible = need <= spec.kv_blocks;
    let headroom = (spec.kv_blocks as f64 - need as f64) / spec.kv_blocks as f64;
    let batch = (spec.max_batch / 2).max(1);
    let kv = (spec.kv_blocks / 2).max(1);
    let st = GpuState {
        batch,
        kv_blocks: kv,
        freq_mhz: FREQ_MAX_MHZ,
    };
    let ept =
        power_w(spec, batch, kv, FREQ_MAX_MHZ) * decode_latency_s(spec, &st) / batch as f64;
    (feasible, ept, headroom)
}

/// Disjoint mutable borrows of two replicas (migration source and
/// destination).
fn two_replicas(
    replicas: &mut [Replica],
    a: usize,
    b: usize,
) -> (&mut Replica, &mut Replica) {
    assert_ne!(a, b);
    if a < b {
        let (lo, hi) = replicas.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    } else {
        let (lo, hi) = replicas.split_at_mut(a);
        (&mut hi[0], &mut lo[b])
    }
}

/// Live-migrate the deactivated replica `from`'s resident requests to
/// the best-fit surviving replicas (`--migration on`).  Each move is
/// gated by destination capacity and the [`migration_slo_guard`]; a
/// refused request stays on the victim and drains exactly as
/// drain-based scale-in would have it.  With `link_ok == false`
/// (fault-injected link outage) every transfer fails mid-flight: the
/// checkpoint rolls back onto the source — which stays coherent and
/// keeps draining — and `rollbacks` counts the failures.
#[allow(clippy::too_many_arguments)]
fn migrate_residents(
    replicas: &mut [Replica],
    from: usize,
    now: f64,
    policy: Policy,
    model: &PerfModel,
    mig: &MigrationSpec,
    counters: &mut MigrationCounters,
    link_ok: bool,
    rollbacks: &mut u64,
) {
    // Index-based iteration: the body needs disjoint &mut access to
    // the source and destination replicas per move.
    let n_engines = replicas[from].engines.len();
    for eng_idx in 0..n_engines {
        for ri in replicas[from].engines[eng_idx].sim.residents() {
            // The source-side scoreboard entry travels with the move
            // (it carries the conservatively adjusted, possibly bumped
            // prediction and the absolute deadline).
            let src_entry = match replicas[from].engines[eng_idx].sb.get(ri.id) {
                Some(e) => *e,
                None => continue,
            };
            let footprint = ri.kv_tokens.max(ri.prompt_tokens);
            let Some(to) = best_reroute_target(replicas, from, footprint) else {
                counters.refused_capacity += 1;
                continue;
            };
            let (src, dst) = two_replicas(replicas, from, to);
            // A drained destination's frozen TP-scaler tick must
            // fast-forward before migrated work can make it non-idle,
            // or the stale timestamp re-enters the decision min and
            // drags the fleet event clock backwards (same hazard as
            // handing rerouted queue work to a drained replica).
            // No-op for busy replicas, whose ticks are never stale.
            dst.catch_up_tick(now);
            let Some(d_idx) = dst.engines.iter().position(|e| e.accepting) else {
                counters.refused_capacity += 1;
                continue;
            };
            let de = &mut dst.engines[d_idx];
            let need = blocks_for(footprint, de.sim.spec().block_tokens);
            let full = de.sim.batch() >= de.sim.spec().max_batch;
            if full || need > de.sim.kv_blocks_free() {
                counters.refused_capacity += 1;
                continue;
            }
            // A pending prefill has no KV to stream (only the prompt
            // text moves); everything else pays the block transfer.
            let stall = if ri.prefill_pending {
                mig.base_latency_s
            } else {
                mig.transfer_seconds(need)
            };
            let k = de.sim.iter_index();
            let entry = migration_entry(&src_entry, ri.generated, k);
            if !migration_slo_guard(
                model,
                de.sim.spec(),
                &dst.sched.slo,
                &de.sb,
                &mut de.tracker,
                k,
                now,
                &entry,
                stall,
            ) {
                counters.refused_slo += 1;
                continue;
            }
            // An idle destination's clock is parked at its last event:
            // charge the idle gap and advance it to the migration
            // instant, or the restored row would replay the past.
            // (Non-idle engines were already driven to `now` by
            // run_until before this decision point.)
            if de.sim.is_idle() {
                de.sim.account_idle(now);
                de.cursor = de.cursor.max(now);
            }
            let se = &mut src.engines[eng_idx];
            let Some(ckpt) = se.sim.checkpoint(ri.id) else {
                continue;
            };
            if !link_ok {
                // Mid-transfer link failure: the destination never
                // sees the blocks.  Roll the restore back onto the
                // source — its allocator just freed exactly these
                // blocks, so the rollback cannot fail — leaving it
                // coherent to drain the request itself.
                se.sim
                    .restore(ckpt, now)
                    .expect("rollback restore onto the migration source");
                *rollbacks += 1;
                continue;
            }
            match de.sim.restore(ckpt, now + stall) {
                Ok(()) => {
                    // Scoreboard strike/insert ride the existing delta
                    // journal, keeping both projection trackers
                    // coherent without special cases.
                    se.sb.strike(ri.id);
                    de.sb.insert(entry);
                    src.route_epoch += 1;
                    dst.route_epoch += 1;
                    dst.migrated_ids.insert(ri.id);
                    dst.migration_energy += mig.transfer_energy_j(stall);
                    dst.stats.migrated_in += 1;
                    src.stats.migrated_out += 1;
                    counters.migrations += 1;
                    // The destination's batch composition changed:
                    // re-run the §IV-E controller, exactly as a
                    // completion or admission would.
                    if policy.throttling {
                        rethrottle(de, !dst.queue.is_empty(), model, &dst.sched);
                    }
                }
                Err(ckpt) => {
                    // Raced with the capacity pre-check (defensive):
                    // roll back onto the source, whose blocks the
                    // checkpoint just freed.
                    se.sim
                        .restore(ckpt, now)
                        .expect("rollback restore onto the migration source");
                    counters.refused_capacity += 1;
                }
            }
        }
    }
}

/// Proactively migrate residents off replicas whose §IV-B projected
/// peak KV demand crowds their pool (`--predict on` + `--migration
/// on`) — BEFORE admission has to queue behind the pressure, the open
/// edge the scale-in-only migration of PR 5 left.  Reuses the scale-in
/// machinery end to end: destination ranking by normalized headroom,
/// the destination-side [`migration_slo_guard`], and checkpoint /
/// restore.  Two extra rules keep it stable: moves go largest
/// footprint first (most relief per transfer), and a destination whose
/// own projected peak would cross the pressure threshold is refused —
/// every move strictly lowers fleet pressure, so two crowded replicas
/// can never trade residents forever.  The source stays live, so a
/// refusal simply leaves the request where it is.
#[allow(clippy::too_many_arguments)]
fn proactive_offload(
    replicas: &mut [Replica],
    now: f64,
    policy: Policy,
    model: &PerfModel,
    mig: &MigrationSpec,
    kv_pressure: f64,
    counters: &mut MigrationCounters,
    pc: &mut PredictCounters,
) {
    for from in 0..replicas.len() {
        if !replicas[from].active {
            continue;
        }
        for eng_idx in 0..replicas[from].engines.len() {
            // One attempted move per re-projection: every successful
            // move shrinks the source's resident set, so this loop
            // terminates; any refusal ends the engine's pass.
            loop {
                let pressured = {
                    let e = &mut replicas[from].engines[eng_idx];
                    let spec = e.sim.spec();
                    if spec.kv_blocks == 0 {
                        break;
                    }
                    let limit = (kv_pressure * spec.kv_blocks as f64) as u32;
                    let k = e.sim.iter_index();
                    e.tracker.project(&e.sb, k, None).peak_kv() > limit
                };
                if !pressured {
                    break;
                }
                // Largest footprint first; ties to the lowest id.
                let Some(ri) = replicas[from].engines[eng_idx]
                    .sim
                    .residents()
                    .into_iter()
                    .max_by_key(|ri| {
                        (
                            ri.kv_tokens.max(ri.prompt_tokens),
                            std::cmp::Reverse(ri.id),
                        )
                    })
                else {
                    break;
                };
                let src_entry = match replicas[from].engines[eng_idx].sb.get(ri.id)
                {
                    Some(e) => *e,
                    None => break,
                };
                let footprint = ri.kv_tokens.max(ri.prompt_tokens);
                let Some(to) = best_reroute_target(replicas, from, footprint)
                else {
                    counters.refused_capacity += 1;
                    pc.proactive_refused += 1;
                    break;
                };
                let (src, dst) = two_replicas(replicas, from, to);
                // Same stale-tick hazard as scale-in migration: a
                // drained destination's frozen TP-scaler tick must
                // fast-forward before it takes work.
                dst.catch_up_tick(now);
                let Some(d_idx) = dst.engines.iter().position(|e| e.accepting)
                else {
                    counters.refused_capacity += 1;
                    pc.proactive_refused += 1;
                    break;
                };
                let de = &mut dst.engines[d_idx];
                let d_spec_blocks = de.sim.spec().kv_blocks;
                let need = blocks_for(footprint, de.sim.spec().block_tokens);
                let full = de.sim.batch() >= de.sim.spec().max_batch;
                if full || need > de.sim.kv_blocks_free() {
                    counters.refused_capacity += 1;
                    pc.proactive_refused += 1;
                    break;
                }
                // Never offload ONTO a pressured destination: the
                // move must strictly lower fleet-wide pressure.
                let d_limit = (kv_pressure * d_spec_blocks as f64) as u32;
                let dk = de.sim.iter_index();
                let d_peak = de.tracker.project(&de.sb, dk, None).peak_kv();
                if d_peak.saturating_add(need) > d_limit {
                    counters.refused_capacity += 1;
                    pc.proactive_refused += 1;
                    break;
                }
                let stall = if ri.prefill_pending {
                    mig.base_latency_s
                } else {
                    mig.transfer_seconds(need)
                };
                let entry = migration_entry(&src_entry, ri.generated, dk);
                if !migration_slo_guard(
                    model,
                    de.sim.spec(),
                    &dst.sched.slo,
                    &de.sb,
                    &mut de.tracker,
                    dk,
                    now,
                    &entry,
                    stall,
                ) {
                    counters.refused_slo += 1;
                    pc.proactive_refused += 1;
                    break;
                }
                if de.sim.is_idle() {
                    de.sim.account_idle(now);
                    de.cursor = de.cursor.max(now);
                }
                let se = &mut src.engines[eng_idx];
                let Some(ckpt) = se.sim.checkpoint(ri.id) else {
                    break;
                };
                match de.sim.restore(ckpt, now + stall) {
                    Ok(()) => {
                        se.sb.strike(ri.id);
                        de.sb.insert(entry);
                        src.route_epoch += 1;
                        dst.route_epoch += 1;
                        dst.migrated_ids.insert(ri.id);
                        dst.migration_energy += mig.transfer_energy_j(stall);
                        dst.stats.migrated_in += 1;
                        src.stats.migrated_out += 1;
                        counters.migrations += 1;
                        pc.proactive_migrations += 1;
                        if policy.throttling {
                            // Both batch compositions changed: re-run
                            // the §IV-E controller on each side.
                            rethrottle(de, !dst.queue.is_empty(), model, &dst.sched);
                            rethrottle(se, !src.queue.is_empty(), model, &src.sched);
                        }
                    }
                    Err(ckpt) => {
                        se.sim
                            .restore(ckpt, now)
                            .expect("rollback restore onto the offload source");
                        counters.refused_capacity += 1;
                        pc.proactive_refused += 1;
                        break;
                    }
                }
            }
        }
    }
}

/// The replica's queue head cannot pass admission with every engine
/// idle: admit it marked lost when it physically fits; otherwise hand
/// it to another replica with enough total KV capacity.  A request is
/// dropped only on UNIVERSAL rejection — no replica could ever serve
/// it (or it has already been bounced through every other replica).
#[allow(clippy::too_many_arguments)]
fn resolve_blocked(
    replicas: &mut [Replica],
    idx: usize,
    cfg: &ServingConfig,
    model: &PerfModel,
    now: f64,
    reroutes: &mut HashMap<RequestId, usize>,
    rerouted: &mut u64,
) {
    let n = replicas.len();
    let unplaceable: Option<Request> = {
        let rp = &mut replicas[idx];
        if let Some(e) = rp.engines.iter_mut().find(|e| e.accepting) {
            e.sim.account_idle(now);
            e.cursor = e.cursor.max(now);
            if e.cursor > rp.last_event_s {
                rp.last_event_s = e.cursor;
            }
            let Some(req) = rp.queue.front() else { return };
            if e.sim.kv_fits(req.prompt_tokens) {
                let adjusted = conservative_adjust(
                    req.predicted_gen,
                    cfg.predictor_p95_error,
                    cfg.max_tokens,
                );
                let entry = entry_for(
                    req.id,
                    req.prompt_tokens,
                    adjusted,
                    req.arrival_s,
                    e.sim.iter_index(),
                    &rp.sched.slo,
                );
                e.sb.insert(entry);
                e.sb.mark_lost(req.id);
                let req = rp.queue.pop_front().unwrap();
                let id = req.id;
                if e.sim.admit(req, e.cursor, true).is_err() {
                    e.sb.strike(id);
                    rp.stats.dropped += 1;
                } else {
                    let k = e.sim.iter_index();
                    let proj = e.tracker.project(&e.sb, k, None);
                    let f = min_slo_frequency_with(
                        &e.grid,
                        model,
                        e.sim.spec(),
                        &rp.sched.slo,
                        &e.sb,
                        proj,
                        now,
                        1.0,
                        &mut e.scratch,
                    );
                    e.sim.dvfs.set(now, f);
                }
                None
            } else {
                rp.route_epoch += 1;
                rp.queue.pop_front()
            }
        } else {
            // No accepting engine (a deactivated replica still holding
            // re-queued evictions): hand the head to the fleet.
            rp.route_epoch += 1;
            rp.queue.pop_front()
        }
    };
    let Some(req) = unplaceable else { return };

    let hops = reroutes.entry(req.id).or_insert(0);
    let target = if *hops + 1 < n {
        best_reroute_target(replicas, idx, req.prompt_tokens)
    } else {
        None
    };
    match target {
        Some(j) => {
            *hops += 1;
            *rerouted += 1;
            replicas[j].catch_up_tick(now);
            replicas[j].route_epoch += 1;
            replicas[j].queue.push_back(req);
        }
        None => {
            replicas[idx].stats.dropped += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::config::ServingConfig;
    use crate::workload::trace::{synth_trace, TraceParams};
    use crate::workload::LengthPredictor;

    fn quick_trace(peak: f64, secs: f64, seed: u64) -> Vec<Request> {
        let mut reqs = synth_trace(&TraceParams::short(secs, peak, seed));
        LengthPredictor::oracle().apply(&mut reqs, 1024);
        reqs
    }

    fn model_for(spec: &crate::config::EngineSpec) -> PerfModel {
        PerfModel::train(&[spec.clone()], 40, 0)
    }

    #[test]
    fn triton_serves_everything_at_max_freq() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::triton(spec.clone());
        let m = model_for(&spec);
        let reqs = quick_trace(2.0, 60.0, 0);
        let out = serve_trace(&cfg, Policy::triton(), &m, &reqs);
        assert_eq!(out.stats.completed as usize, reqs.len());
        assert_eq!(out.stats.dropped, 0);
        assert!(out.stats.freq.values().iter().all(|&f| f == 1410.0));
        assert!(out.stats.total_energy_j > 0.0);
    }

    #[test]
    fn throttllem_reduces_energy_and_meets_slo() {
        let spec = llama2_13b(2);
        let m = model_for(&spec);
        let reqs = quick_trace(2.0, 120.0, 1);

        let cfg_t = ServingConfig::triton(spec.clone());
        let triton = serve_trace(&cfg_t, Policy::triton(), &m, &reqs);

        let cfg = ServingConfig::throttllem(spec.clone());
        let ours = serve_trace(&cfg, Policy::throttle_only(), &m, &reqs);

        assert_eq!(ours.stats.completed as usize, reqs.len());
        // Energy strictly lower than Triton's.
        assert!(
            ours.stats.total_energy_j < triton.stats.total_energy_j,
            "ours={} triton={}",
            ours.stats.total_energy_j,
            triton.stats.total_energy_j
        );
        // Mean frequency visibly below max.
        assert!(ours.stats.freq.mean() < 1350.0);
        // TBT SLO comfortably met on average.
        assert!(ours.stats.tbt.mean() < cfg.slo.tbt_avg);
        // E2E p99 within the SLO at this moderate load.
        assert!(
            ours.stats.e2e.p99() <= cfg.slo.e2e_p99,
            "p99={} slo={}",
            ours.stats.e2e.p99(),
            cfg.slo.e2e_p99
        );
    }

    #[test]
    fn queueing_under_kv_pressure() {
        // TP1 has only 120 blocks: long prompts must queue.
        let spec = llama2_13b(1);
        let m = model_for(&spec);
        let cfg = ServingConfig::throttllem(spec.clone());
        let reqs = quick_trace(1.0, 120.0, 2);
        let out = serve_trace(&cfg, Policy::throttle_only(), &m, &reqs);
        assert_eq!(
            out.stats.completed + out.stats.dropped,
            reqs.len() as u64
        );
        // Some queueing must have occurred.
        assert!(out.stats.queue.max() > 0.0);
    }

    #[test]
    fn autoscaler_switches_engines_under_varying_load() {
        let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];
        let m = PerfModel::train(&set, 40, 0);
        let mut cfg = ServingConfig::autoscaled(set);
        cfg.slo = crate::config::SloSpec::new(0.2, 31.3);
        // RPS ramps 0.75 -> 7.5: all three engines should be visited.
        let reqs = crate::workload::trace::synth_trace_rps_range(
            &TraceParams::short(600.0, 8.25, 3),
            0.75,
            7.5,
        );
        let out = serve_trace(&cfg, Policy::throttllem(), &m, &reqs);
        assert!(out.engine_switches >= 1, "switches={}", out.engine_switches);
        assert!(out.shadow_energy_j > 0.0);
        let tps: Vec<u32> = out.timeline.iter().map(|p| p.engine_tp).collect();
        assert!(tps.contains(&1) && tps.contains(&4));
        assert_eq!(
            out.stats.completed + out.stats.dropped,
            reqs.len() as u64
        );
    }

    #[test]
    fn outcomes_complete_and_sorted() {
        let spec = llama2_13b(2);
        let m = model_for(&spec);
        let cfg = ServingConfig::throttllem(spec.clone());
        let reqs = quick_trace(1.5, 60.0, 4);
        let out = serve_trace(&cfg, Policy::throttle_only(), &m, &reqs);
        assert_eq!(out.outcomes.len() as u64, out.stats.completed);
        assert!(out.outcomes.windows(2).all(|w| w[0].id < w[1].id));
        for o in &out.outcomes {
            assert!(o.e2e_s > 0.0 && o.ttft_s > 0.0);
            assert!(o.e2e_s >= o.ttft_s);
        }
    }

    #[test]
    fn fleet_round_robin_splits_arrivals_evenly() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::triton(spec.clone());
        let m = model_for(&spec);
        let reqs = quick_trace(3.0, 90.0, 5);
        let fleet = FleetSpec {
            replicas: 4,
            router: RouterPolicy::RoundRobin,
            autoscale_replicas: false,
        };
        let out = serve_fleet(&cfg, Policy::triton(), &m, &reqs, &fleet);
        assert_eq!(out.replicas.len(), 4);
        let routed: Vec<u64> = out.replicas.iter().map(|r| r.routed).collect();
        assert_eq!(routed.iter().sum::<u64>(), reqs.len() as u64);
        let max = *routed.iter().max().unwrap();
        let min = *routed.iter().min().unwrap();
        assert!(max - min <= 1, "uneven split: {routed:?}");
        // Conservation across the fleet.
        assert_eq!(
            out.total.stats.completed + out.total.stats.dropped,
            reqs.len() as u64
        );
        // Per-replica stats sum to the aggregate.
        let sum: u64 = out.replicas.iter().map(|r| r.stats.completed).sum();
        assert_eq!(sum, out.total.stats.completed);
        let energy: f64 =
            out.replicas.iter().map(|r| r.stats.total_energy_j).sum();
        assert!((energy - out.total.stats.total_energy_j).abs() < 1e-6);
    }

    #[test]
    fn fleet_least_loaded_and_headroom_serve_everything() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::throttllem(spec.clone());
        let m = model_for(&spec);
        let reqs = quick_trace(4.0, 90.0, 6);
        for router in [RouterPolicy::LeastLoaded, RouterPolicy::ProjectedHeadroom] {
            let fleet = FleetSpec {
                replicas: 2,
                router,
                autoscale_replicas: false,
            };
            let out = serve_fleet(&cfg, Policy::throttle_only(), &m, &reqs, &fleet);
            assert_eq!(
                out.total.stats.completed + out.total.stats.dropped,
                reqs.len() as u64,
                "router {:?}",
                router
            );
            // Both replicas must actually receive work at this load.
            assert!(out.replicas.iter().all(|r| r.routed > 0));
        }
    }

    #[test]
    fn fleet_deactivates_replicas_under_low_load() {
        let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];
        let m = PerfModel::train(&set, 40, 0);
        let cfg = ServingConfig::autoscaled(set);
        // ~0.5 RPS over 4 replicas: one TP1 replica suffices.
        let reqs = quick_trace(0.5, 240.0, 7);
        let fleet = FleetSpec::new(4, RouterPolicy::LeastLoaded);
        let out = serve_fleet(&cfg, Policy::throttllem(), &m, &reqs, &fleet);
        assert!(
            out.replica_deactivations >= 1,
            "expected fleet scale-in, got {} deactivations",
            out.replica_deactivations
        );
        assert_eq!(
            out.total.stats.completed + out.total.stats.dropped,
            reqs.len() as u64
        );
    }

    #[test]
    fn reroute_targets_prefer_capacity() {
        let policy = Policy::throttle_only();
        let slo = SloSpec::new(0.2, 30.2);
        let small = ReplicaSpec::fixed(llama2_13b(1)); // 120 blocks
        let big = ReplicaSpec::fixed(llama2_13b(2)); // 439 blocks
        let replicas = vec![
            Replica::new(0, &small, slo, policy, false),
            Replica::new(1, &big, slo, policy, false),
            Replica::new(2, &small, slo, policy, false),
        ];
        // 20k-token prompt: 313 blocks; only the TP2 replica can ever
        // hold it.
        assert_eq!(best_reroute_target(&replicas, 0, 20_000), Some(1));
        // 64k tokens: 1000 blocks; nobody can.
        assert_eq!(best_reroute_target(&replicas, 0, 64_000), None);
        // From the big replica itself: the small ones can hold a small
        // prompt; ties (equal normalized slack) prefer the first.
        assert_eq!(best_reroute_target(&replicas, 1, 64), Some(0));
    }

    fn test_replica(id: usize, spec: crate::config::EngineSpec) -> Replica {
        Replica::new(
            id,
            &ReplicaSpec::fixed(spec),
            SloSpec::new(0.2, 30.2),
            Policy::throttle_only(),
            false,
        )
    }

    fn test_request(id: u64, prompt: u32) -> Request {
        Request {
            id,
            prompt_tokens: prompt,
            gen_tokens: 200,
            predicted_gen: 200,
            arrival_s: 0.0,
            prefix_group: 0,
            shared_prefix_tokens: 0,
        }
    }

    #[test]
    fn headroom_cache_matches_uncached_and_tracks_mutations() {
        // The cached projected-headroom score must equal the uncached
        // one bit-for-bit (headroom_for also cross-checks internally
        // in debug builds on EVERY routing decision).
        let mut rp = test_replica(0, llama2_13b(2));
        rp.engines[0]
            .sim
            .admit(test_request(0, 640), 0.0, false)
            .unwrap();
        rp.engines[0]
            .sb
            .insert(entry_for(0, 640, 200, 0.0, 0, &SloSpec::new(0.2, 30.2)));
        let s1 = rp.headroom_for(64);
        let s2 = rp.headroom_for(64); // cache hit
        assert_eq!(s1.to_bits(), s2.to_bits());
        // A different request size against the same cached projection
        // still scores per-request.
        let s3 = rp.headroom_for(6400);
        assert!(s3 < s1);
        // Scoreboard mutation (an admission) invalidates: the score
        // must track the new projection.
        rp.engines[0]
            .sb
            .insert(entry_for(1, 1280, 300, 0.0, 0, &SloSpec::new(0.2, 30.2)));
        let s4 = rp.headroom_for(64);
        assert!(s4 < s1, "admission must lower headroom: {s4} vs {s1}");
        // Queue mutation invalidates via route_epoch.
        rp.queue.push_back(test_request(2, 640));
        rp.route_epoch += 1;
        let s5 = rp.headroom_for(64);
        assert!(s5 < s4, "queued work must lower headroom: {s5} vs {s4}");
    }

    #[test]
    fn headroom_rejects_prompts_that_can_never_fit() {
        let mut small = test_replica(0, llama2_13b(1)); // 120 blocks
        // 10k tokens -> 157 blocks: impossible on TP1, fine on TP2.
        assert_eq!(small.headroom_for(10_000), f64::NEG_INFINITY);
        let mut big = test_replica(1, llama2_13b(2));
        assert!(big.headroom_for(10_000) > f64::NEG_INFINITY);
    }

    #[test]
    fn scale_in_victim_prefers_energy_inefficient_replica() {
        // Replica 0: efficient operating point (1050 MHz sweet spot,
        // Fig. 2e), ONE resident row.  Replica 1: max frequency (high
        // J/token), one resident row plus one queued -> MORE
        // outstanding work.  The old least-loaded rule drained replica
        // 0; energy-aware selection must drain replica 1.
        let mut a = test_replica(0, llama2_13b(2));
        a.engines[0].sim.dvfs.set(0.0, 1050);
        a.engines[0]
            .sim
            .admit(test_request(0, 64), 0.0, false)
            .unwrap();
        let mut b = test_replica(1, llama2_13b(2));
        b.engines[0].sim.dvfs.set(0.0, FREQ_MAX_MHZ);
        b.engines[0]
            .sim
            .admit(test_request(1, 64), 0.0, false)
            .unwrap();
        b.queue.push_back(test_request(2, 64));
        assert!(b.energy_per_token() > a.energy_per_token());
        assert!(a.outstanding() < b.outstanding());
        let replicas = vec![a, b];
        assert_eq!(select_scale_in_victim(&replicas), Some(1));
    }

    #[test]
    fn scale_in_victim_idle_replica_is_infinitely_inefficient() {
        let mut busy = test_replica(0, llama2_13b(2));
        busy.engines[0].sim.dvfs.set(0.0, 1050);
        busy.engines[0]
            .sim
            .admit(test_request(0, 64), 0.0, false)
            .unwrap();
        let idle = test_replica(1, llama2_13b(2));
        assert_eq!(idle.energy_per_token(), f64::INFINITY);
        assert!(busy.energy_per_token().is_finite());
        // Idle burns power for zero tokens: always the first victim.
        let replicas = vec![busy, idle];
        assert_eq!(select_scale_in_victim(&replicas), Some(1));
        // Several idle replicas tie at infinity: fall back to the
        // least-loaded order (highest index on full ties).
        let replicas = vec![
            test_replica(0, llama2_13b(2)),
            test_replica(1, llama2_13b(2)),
        ];
        assert_eq!(select_scale_in_victim(&replicas), Some(1));
        // Inactive replicas are never victims.
        let mut replicas = vec![
            test_replica(0, llama2_13b(2)),
            test_replica(1, llama2_13b(2)),
        ];
        replicas[1].active = false;
        assert_eq!(select_scale_in_victim(&replicas), Some(0));
    }

    #[test]
    fn predictive_victim_discounts_expensive_moves() {
        // Replica 0: efficient operating point, one resident, empty
        // queue -> cheap to evict.  Replica 1: max frequency (the
        // reactive victim), one resident plus ten queued requests ->
        // expensive to evict once displacement is priced in.
        let mk = || {
            let mut a = test_replica(0, llama2_13b(2));
            a.engines[0].sim.dvfs.set(0.0, 1050);
            a.engines[0]
                .sim
                .admit(test_request(0, 64), 0.0, false)
                .unwrap();
            let mut b = test_replica(1, llama2_13b(2));
            b.engines[0].sim.dvfs.set(0.0, FREQ_MAX_MHZ);
            b.engines[0]
                .sim
                .admit(test_request(1, 64), 0.0, false)
                .unwrap();
            for id in 2..12 {
                b.queue.push_back(test_request(id, 64));
            }
            vec![a, b]
        };
        let replicas = mk();
        // Reactive ranking: J/token alone -> the max-frequency replica.
        assert_eq!(select_scale_in_victim(&replicas), Some(1));
        // A free link (zero orchestration latency, both residents
        // still in prefill) makes move cost vanish: the predictive
        // rule degenerates to the reactive one.
        let mut free = MigrationSpec::enabled_default();
        free.base_latency_s = 0.0;
        assert_eq!(select_scale_in_victim_predictive(&replicas, &free), Some(1));
        // An expensive link (100 s per displaced entry) swamps the
        // J/token gap: the cheap-to-move replica becomes the victim.
        let mut slow = MigrationSpec::enabled_default();
        slow.base_latency_s = 100.0;
        assert_eq!(select_scale_in_victim_predictive(&replicas, &slow), Some(0));
    }

    #[test]
    fn predictive_victim_sheds_idle_replicas_first() {
        let mig = MigrationSpec::enabled_default();
        // Idle replicas: infinite J/token, nothing to move -> still
        // the first victim, exactly as in the reactive rule.
        let mut busy = test_replica(0, llama2_13b(2));
        busy.engines[0].sim.dvfs.set(0.0, 1050);
        busy.engines[0]
            .sim
            .admit(test_request(0, 64), 0.0, false)
            .unwrap();
        let idle = test_replica(1, llama2_13b(2));
        let replicas = vec![busy, idle];
        assert_eq!(select_scale_in_victim_predictive(&replicas, &mig), Some(1));
        // Inactive replicas are never victims; an all-inactive fleet
        // yields none.
        let mut replicas = replicas;
        replicas[1].active = false;
        assert_eq!(select_scale_in_victim_predictive(&replicas, &mig), Some(0));
        replicas[0].active = false;
        assert_eq!(select_scale_in_victim_predictive(&replicas, &mig), None);
    }

    #[test]
    fn scale_out_order_is_capacity_and_energy_aware() {
        // Mixed inactive pool: TP1 (120 blocks), TP2 (439), TP4 (1050).
        let mut replicas = vec![
            test_replica(0, llama2_13b(4)),
            test_replica(1, llama2_13b(2)),
            test_replica(2, llama2_13b(1)),
        ];
        for r in replicas.iter_mut() {
            r.active = false;
        }
        // Long-prompt mix (10k tokens -> 157 blocks): TP1 is
        // infeasible and must rank strictly last, whatever its J/token.
        let order = select_scale_out_order(&replicas, 10_000);
        assert_eq!(order.len(), 3);
        assert_eq!(*order.last().unwrap(), 2, "infeasible TP1 ranks last");
        // Short mix: every spec is feasible; the order must follow the
        // projected J/token ranking of the fit function itself.
        let order = select_scale_out_order(&replicas, 64);
        let ept = |i: usize| scale_out_fit(&replicas[i].respec(), 64).1;
        assert!(
            ept(order[0]) <= ept(order[1]) && ept(order[1]) <= ept(order[2]),
            "order {order:?} not sorted by J/token"
        );
        // Identical specs tie -> index order (the pre-scoring
        // first-inactive behavior, byte-identical for homogeneous
        // fleets).
        let mut homo = vec![
            test_replica(0, llama2_13b(2)),
            test_replica(1, llama2_13b(2)),
            test_replica(2, llama2_13b(2)),
        ];
        for r in homo.iter_mut() {
            r.active = false;
        }
        assert_eq!(select_scale_out_order(&homo, 64), vec![0, 1, 2]);
        // Active replicas and pending spawns are not candidates.
        homo[0].active = true;
        homo[1].activation_ready = Some(5.0);
        assert_eq!(select_scale_out_order(&homo, 64), vec![2]);
    }

    #[test]
    fn p95_prompt_of_window() {
        let mut w: VecDeque<(f64, u32)> = VecDeque::new();
        assert_eq!(p95_prompt(&w), 1);
        for i in 1..=100u32 {
            w.push_back((i as f64, i * 10));
        }
        let p = p95_prompt(&w);
        assert!((900..=1000).contains(&p), "p95 {p}");
    }

    fn migration_test_pair() -> (Vec<Replica>, PerfModel) {
        let spec = llama2_13b(2);
        let model = PerfModel::train(&[spec.clone()], 40, 0);
        let replicas = vec![
            test_replica(0, spec.clone()),
            test_replica(1, spec.clone()),
        ];
        (replicas, model)
    }

    /// Admit a resident mid-generation onto replica `i`'s engine with a
    /// matching scoreboard entry (the state scale-in migration sees).
    fn seed_resident(rp: &mut Replica, id: u64, prompt: u32, deadline: f64) {
        rp.engines[0]
            .sim
            .admit(test_request(id, prompt), 0.0, false)
            .unwrap();
        rp.engines[0].sim.run_iteration(0.0); // prefill done
        rp.engines[0].sb.insert(Entry {
            id,
            scheduled_iter: 0,
            prompt_tokens: prompt,
            predicted_gen: 200,
            deadline_s: deadline,
            lost: false,
            kv_discount_blocks: 0,
        });
    }

    use crate::coordinator::scoreboard::Entry;

    #[test]
    fn migrate_residents_moves_request_to_survivor() {
        let (mut replicas, model) = migration_test_pair();
        seed_resident(&mut replicas[0], 7, 640, 1e9);
        replicas[0].deactivate(1.0);
        let mig = MigrationSpec::enabled_default();
        let mut counters = MigrationCounters::default();
        let mut rollbacks = 0u64;
        migrate_residents(
            &mut replicas,
            0,
            1.0,
            Policy::throttle_only(),
            &model,
            &mig,
            &mut counters,
            true,
            &mut rollbacks,
        );
        assert_eq!(counters.migrations, 1);
        assert_eq!(rollbacks, 0);
        assert_eq!(counters.refused_slo + counters.refused_capacity, 0);
        assert!(replicas[0].engines[0].sim.is_idle(), "victim freed");
        assert!(replicas[0].engines[0].sb.get(7).is_none());
        assert_eq!(replicas[1].engines[0].sim.batch(), 1);
        let e = replicas[1].engines[0].sb.get(7).expect("entry moved");
        assert!(e.predicted_gen >= 2);
        assert!(replicas[1].migrated_ids.contains(&7));
        assert!(replicas[1].migration_energy > 0.0);
        assert_eq!(replicas[0].stats.migrated_out, 1);
        assert_eq!(replicas[1].stats.migrated_in, 1);
        // The destination can run the request to completion.
        let mut now = 1.0;
        for _ in 0..500 {
            if replicas[1].engines[0].sim.is_idle() {
                break;
            }
            let r = replicas[1].engines[0].sim.run_iteration(now);
            now += r.duration_s;
        }
        assert!(replicas[1].engines[0].sim.is_idle());
    }

    #[test]
    fn migration_refused_without_destination_capacity() {
        // Destination pool (5 blocks) cannot hold the 640-token
        // resident: the request stays on the victim and drains.
        let spec = llama2_13b(2);
        let model = PerfModel::train(&[spec.clone()], 40, 0);
        let tiny = crate::config::EngineSpec {
            kv_blocks: 5,
            ..spec.clone()
        };
        let mut replicas = vec![test_replica(0, spec), test_replica(1, tiny)];
        seed_resident(&mut replicas[0], 7, 640, 1e9);
        replicas[0].deactivate(1.0);
        let mut counters = MigrationCounters::default();
        migrate_residents(
            &mut replicas,
            0,
            1.0,
            Policy::throttle_only(),
            &model,
            &MigrationSpec::enabled_default(),
            &mut counters,
            true,
            &mut 0,
        );
        assert_eq!(counters.migrations, 0);
        assert!(counters.refused_capacity >= 1);
        assert_eq!(replicas[0].engines[0].sim.batch(), 1, "stays and drains");
        assert!(replicas[0].engines[0].sb.get(7).is_some());
        assert_eq!(replicas[1].engines[0].sim.batch(), 0);
    }

    #[test]
    fn migration_refused_by_slo_guard() {
        // A transfer stall that pushes the request past its deadline:
        // the guard refuses and the request drains on the victim
        // instead.  The stall (≈25 s) stays BELOW the destination's
        // 30.2 s E2E budget, so the refusal flows through the
        // projection-based deadline check, not the stall-bound
        // short-circuit — exercising the tracker-reading guard path
        // (whose debug cross-checks also pin that it leaves the
        // destination's incremental projection intact).
        let (mut replicas, model) = migration_test_pair();
        seed_resident(&mut replicas[0], 7, 640, 20.0);
        replicas[0].deactivate(1.0);
        let mig = MigrationSpec {
            base_latency_s: 25.0,
            ..MigrationSpec::enabled_default()
        };
        let mut counters = MigrationCounters::default();
        migrate_residents(
            &mut replicas,
            0,
            1.0,
            Policy::throttle_only(),
            &model,
            &mig,
            &mut counters,
            true,
            &mut 0,
        );
        assert_eq!(counters.migrations, 0);
        assert_eq!(counters.refused_slo, 1);
        assert_eq!(replicas[0].engines[0].sim.batch(), 1, "stays and drains");
        assert_eq!(replicas[1].engines[0].sim.batch(), 0);
    }

    #[test]
    fn proactive_offload_relieves_kv_pressure() {
        // Two ~68-block residents project a ~136-block peak on the
        // source; at kv_pressure 0.25 the 439-block pool's limit is
        // 109 blocks -> pressured.  Moving ONE resident (need ~65
        // blocks, within the idle destination's own limit) relieves
        // the source below the threshold, so exactly one migrates.
        let (mut replicas, model) = migration_test_pair();
        seed_resident(&mut replicas[0], 7, 4096, 1e9);
        seed_resident(&mut replicas[0], 8, 4096, 1e9);
        let mig = MigrationSpec::enabled_default();
        let mut counters = MigrationCounters::default();
        let mut pc = PredictCounters::default();
        proactive_offload(
            &mut replicas,
            1.0,
            Policy::throttle_only(),
            &model,
            &mig,
            0.25,
            &mut counters,
            &mut pc,
        );
        assert_eq!(counters.migrations, 1);
        assert_eq!(pc.proactive_migrations, 1);
        assert_eq!(pc.proactive_refused, 0);
        // The source stays LIVE (this is the pre-queueing offload, not
        // a scale-in drain): one resident on each side afterwards.
        assert!(replicas[0].active);
        assert_eq!(replicas[0].engines[0].sim.batch(), 1);
        assert_eq!(replicas[1].engines[0].sim.batch(), 1);
        let moved_7 = replicas[1].engines[0].sb.get(7).is_some();
        let moved_8 = replicas[1].engines[0].sb.get(8).is_some();
        assert!(moved_7 ^ moved_8, "exactly one resident moves");
        assert_eq!(replicas[0].stats.migrated_out, 1);
        assert_eq!(replicas[1].stats.migrated_in, 1);
    }

    #[test]
    fn proactive_offload_refuses_pressured_destination() {
        // A single ~104-block resident carries ALL of the source's
        // pressure: at kv_pressure 0.2 (limit 87 blocks) the move
        // would push the destination past the same threshold, so the
        // anti-ping-pong rule refuses and the request stays put.
        let (mut replicas, model) = migration_test_pair();
        seed_resident(&mut replicas[0], 7, 6400, 1e9);
        let mig = MigrationSpec::enabled_default();
        let mut counters = MigrationCounters::default();
        let mut pc = PredictCounters::default();
        proactive_offload(
            &mut replicas,
            1.0,
            Policy::throttle_only(),
            &model,
            &mig,
            0.2,
            &mut counters,
            &mut pc,
        );
        assert_eq!(counters.migrations, 0);
        assert_eq!(pc.proactive_migrations, 0);
        assert_eq!(pc.proactive_refused, 1);
        assert!(counters.refused_capacity >= 1);
        assert_eq!(replicas[0].engines[0].sim.batch(), 1, "stays put");
        assert!(replicas[0].engines[0].sb.get(7).is_some());
        assert_eq!(replicas[1].engines[0].sim.batch(), 0);
    }

    #[test]
    fn proactive_offload_noop_below_pressure_threshold() {
        // A ~14-block resident against the default 0.85 threshold
        // (373 blocks): nothing is pressured, nothing moves, zero
        // telemetry on BOTH counter blocks.
        let (mut replicas, model) = migration_test_pair();
        seed_resident(&mut replicas[0], 7, 640, 1e9);
        let mig = MigrationSpec::enabled_default();
        let mut counters = MigrationCounters::default();
        let mut pc = PredictCounters::default();
        proactive_offload(
            &mut replicas,
            1.0,
            Policy::throttle_only(),
            &model,
            &mig,
            0.85,
            &mut counters,
            &mut pc,
        );
        assert_eq!(counters, MigrationCounters::default());
        assert_eq!(pc, PredictCounters::default());
        assert_eq!(replicas[0].engines[0].sim.batch(), 1);
        assert_eq!(replicas[1].engines[0].sim.batch(), 0);
    }

    #[test]
    fn heterogeneous_fleet_reports_per_family_stats() {
        let spec8b = crate::config::models::llama3_8b(1);
        let spec13b = llama2_13b(2);
        let cfg = ServingConfig::throttllem(spec13b.clone());
        let plan = FleetPlan::heterogeneous(
            vec![
                ReplicaSpec::fixed(spec8b.clone()).with_engine_slo(),
                ReplicaSpec::fixed(spec13b.clone()),
            ],
            RouterPolicy::LeastLoaded,
        );
        let m = PerfModel::train(&plan.engines(), 40, 0);
        let reqs = quick_trace(3.0, 60.0, 8);
        let out = serve_fleet_plan(&cfg, Policy::throttle_only(), &m, &reqs, &plan);
        assert_eq!(
            out.total.stats.completed + out.total.stats.dropped,
            reqs.len() as u64
        );
        assert_eq!(out.families.len(), 2);
        let completed: u64 = out.families.iter().map(|f| f.stats.completed).sum();
        assert_eq!(completed, out.total.stats.completed);
        // Family entries carry their effective SLOs.
        assert_eq!(out.families[0].family, spec8b.family);
        assert!((out.families[0].slo.e2e_p99 - spec8b.e2e_slo_p99).abs() < 1e-9);
        assert!((out.families[1].slo.e2e_p99 - cfg.slo.e2e_p99).abs() < 1e-9);
        // Replica outcomes name their engines.
        assert_eq!(out.replicas[0].engine, spec8b.name);
        assert_eq!(out.replicas[1].engine, spec13b.name);
        assert!(plan.is_heterogeneous());
    }

    fn test_fault_rt() -> FaultRt {
        FaultRt {
            schedule: Vec::new(),
            cursor: 0,
            counters: FaultCounters::default(),
            retry_q: Vec::new(),
            pending: Vec::new(),
            link_down_until: 0.0,
            next_ckpt_s: Some(5.0),
            link: MigrationSpec::enabled_default(),
        }
    }

    #[test]
    fn link_failure_rolls_back_transfer_onto_coherent_source() {
        let (mut replicas, model) = migration_test_pair();
        seed_resident(&mut replicas[0], 7, 640, 1e9);
        replicas[0].deactivate(1.0);
        let mut counters = MigrationCounters::default();
        let mut rollbacks = 0u64;
        migrate_residents(
            &mut replicas,
            0,
            1.0,
            Policy::throttle_only(),
            &model,
            &MigrationSpec::enabled_default(),
            &mut counters,
            false, // link down mid-transfer
            &mut rollbacks,
        );
        assert_eq!(rollbacks, 1);
        assert_eq!(counters.migrations, 0);
        // Source coherent: the request is still resident with its KV
        // and scoreboard row, and drains to completion locally.
        assert_eq!(replicas[0].engines[0].sim.batch(), 1);
        assert!(replicas[0].engines[0].sb.get(7).is_some());
        assert_eq!(replicas[1].engines[0].sim.batch(), 0);
        let mut now = 1.0;
        for _ in 0..500 {
            if replicas[0].engines[0].sim.is_idle() {
                break;
            }
            let r = replicas[0].engines[0].sim.run_iteration(now);
            now += r.duration_s;
        }
        assert!(replicas[0].engines[0].sim.is_idle(), "drains on source");
    }

    #[test]
    fn crash_recovers_checkpointed_and_requeues_the_rest() {
        let (mut replicas, model) = migration_test_pair();
        let cfg = ServingConfig::throttllem(llama2_13b(2));
        // Two residents on replica 0; only id 7 was checkpointed.
        seed_resident(&mut replicas[0], 7, 640, 1e9);
        seed_resident(&mut replicas[0], 8, 640, 1e9);
        let ck = replicas[0].engines[0].sim.snapshot(7).expect("snapshot");
        replicas[0].ckpt_store.push((7, ck));
        let mut f = test_fault_rt();
        let fspec = FaultSpec::enabled_default();
        crash_and_recover(
            &mut f,
            &mut replicas,
            0,
            10.0,
            35.0,
            &fspec,
            &cfg,
            Policy::throttle_only(),
            &model,
        );
        assert_eq!(f.counters.crash_recoveries, 1);
        assert_eq!(f.counters.crash_requeues, 1);
        // The dead replica is dark until its respawn.
        assert_eq!(replicas[0].respawn_at, Some(35.0));
        assert!(!replicas[0].active);
        assert!(replicas[0].engines.is_empty());
        // The checkpointed resident lives on the survivor, generation
        // progress credited.
        let e = replicas[1].engines[0].sb.get(7).expect("recovered entry");
        assert!(e.predicted_gen >= 2);
        assert_eq!(replicas[1].engines[0].sim.batch(), 1);
        assert!(replicas[1].migration_energy > 0.0);
        // The uncheckpointed one waits on the bounded retry queue.
        assert_eq!(f.retry_q.len(), 1);
        assert_eq!(f.retry_q[0].2.id, 8);
        assert_eq!(f.retry_q[0].1, 1);
        assert!((f.retry_q[0].0 - (10.0 + fspec.retry_backoff_s)).abs() < 1e-12);
    }

    #[test]
    fn crash_with_link_down_requeues_even_checkpointed_residents() {
        let (mut replicas, model) = migration_test_pair();
        let cfg = ServingConfig::throttllem(llama2_13b(2));
        seed_resident(&mut replicas[0], 7, 640, 1e9);
        let ck = replicas[0].engines[0].sim.snapshot(7).expect("snapshot");
        replicas[0].ckpt_store.push((7, ck));
        let mut f = test_fault_rt();
        f.link_down_until = 100.0; // outage covers the crash
        crash_and_recover(
            &mut f,
            &mut replicas,
            0,
            10.0,
            35.0,
            &FaultSpec::enabled_default(),
            &cfg,
            Policy::throttle_only(),
            &model,
        );
        assert_eq!(f.counters.crash_recoveries, 0);
        assert_eq!(f.counters.crash_requeues, 1);
        assert_eq!(replicas[1].engines[0].sim.batch(), 0, "nothing crossed");
    }

    #[test]
    fn fault_free_run_has_zero_fault_telemetry() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::throttllem(spec.clone());
        let m = model_for(&spec);
        let reqs = quick_trace(2.0, 60.0, 12);
        let plan = FleetPlan::homogeneous(
            2,
            RouterPolicy::RoundRobin,
            &cfg,
            Policy::throttle_only(),
            false,
        );
        let out = serve_fleet_plan(&cfg, Policy::throttle_only(), &m, &reqs, &plan);
        assert_eq!(out.faults, FaultCounters::default());
        assert_eq!(out.total.stats.shed, 0);
        assert_eq!(out.total.stats.faulted_lost, 0);
    }

    #[test]
    fn chaos_run_conserves_requests_and_recovers() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::throttllem(spec.clone());
        let m = model_for(&spec);
        let reqs = quick_trace(3.0, 240.0, 11);
        let fspec = FaultSpec {
            crash_mtbf_s: 30.0,
            throttle_mtbf_s: 40.0,
            link_mtbf_s: 60.0,
            preempt_mtbf_s: 90.0,
            ..FaultSpec::enabled_default()
        };
        let plan = FleetPlan::homogeneous(
            3,
            RouterPolicy::LeastLoaded,
            &cfg,
            Policy::throttle_only(),
            false,
        )
        .with_migration(Some(MigrationSpec::enabled_default()))
        .with_faults(Some(fspec));
        let out = serve_fleet_plan(&cfg, Policy::throttle_only(), &m, &reqs, &plan);
        let s = &out.total.stats;
        // Every request is accounted for exactly once: completed,
        // dropped at admission, shed during an outage, or lost after
        // exhausting its fault-retry budget.  No panics, no hangs.
        assert_eq!(
            s.completed + s.dropped + s.shed + s.faulted_lost,
            reqs.len() as u64,
            "conservation violated: {:?}",
            out.faults
        );
        assert!(out.faults.crashes >= 1, "no crashes injected: {:?}", out.faults);
        assert!(
            out.faults.crash_recoveries + out.faults.crash_requeues >= 1,
            "crashed residents must be recovered or requeued: {:?}",
            out.faults
        );
        assert!(out.faults.throttle_events >= 1, "{:?}", out.faults);
        // The run completes the overwhelming majority of traffic even
        // under chaos (three replicas cover single failures).
        assert!(
            s.completed as f64 >= 0.5 * reqs.len() as f64,
            "completed {}/{} under chaos",
            s.completed,
            reqs.len()
        );
    }

    #[test]
    fn faulted_run_is_reproducible_and_seed_sensitive() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::throttllem(spec.clone());
        let m = model_for(&spec);
        let reqs = quick_trace(2.0, 120.0, 13);
        let fspec = FaultSpec {
            crash_mtbf_s: 40.0,
            ..FaultSpec::enabled_default()
        };
        let mk = |seed: u64| {
            let plan = FleetPlan::homogeneous(
                2,
                RouterPolicy::RoundRobin,
                &cfg,
                Policy::throttle_only(),
                false,
            )
            .with_faults(Some(FaultSpec { seed, ..fspec }));
            outcome_digest(&serve_fleet_plan(
                &cfg,
                Policy::throttle_only(),
                &m,
                &reqs,
                &plan,
            ))
        };
        assert_eq!(mk(0), mk(0), "same fault seed, same outcome");
        assert_ne!(mk(0), mk(1), "fault seed must steer the run");
    }
}
