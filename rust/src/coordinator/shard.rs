//! Shard-local fleet execution: the per-replica serving state and the
//! deterministic worker pool that steps it in parallel.
//!
//! The fleet event loop in [`crate::coordinator::server`] alternates
//! two phases.  The RUN phase advances every replica's engines to the
//! next decision point — each replica touches only its own
//! [`EngineSim`]s, [`ProjectionTracker`]s, scratch buffers and queue,
//! so replicas are independent by construction.  The COORDINATION
//! phase (routing, autoscaler ticks, migration, reroutes) reads and
//! mutates replicas across the fleet and stays single-threaded.
//!
//! [`ShardPool`] parallelizes the RUN phase only: replicas are
//! partitioned into fixed contiguous index ranges (replica index →
//! shard, [`shard_ranges`]), each worker thread receives ownership of
//! its shard's replicas for the round, steps them in index order, and
//! hands them back.  The coordinator reassembles the fleet in shard
//! order, so the `Vec<Replica>` the coordination phase sees is
//! index-ordered and bit-identical to what the single-threaded loop
//! would have produced: `--threads N` equals `--threads 1` to the bit,
//! because no floating-point operation is reordered anywhere — the
//! only cross-thread communication is ownership transfer at the
//! barrier.  Router headroom queries therefore run on barrier-published
//! state (no live cross-thread reads): the snapshot IS the replica,
//! returned whole.

// Reviewed HashSet use: `migrated_ids` is keyed insert/remove only and
// is never iterated (detlint r2 enforces that), so hash order cannot
// reach FleetOutcome.
#![allow(clippy::disallowed_types)]

use std::collections::{HashSet, VecDeque};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::Scope;

use crate::config::fleet::ReplicaSpec;
use crate::config::{EngineSpec, ServingConfig, SloSpec};
use crate::coordinator::autoscaler::{Autoscaler, ScaleDecision};
use crate::coordinator::perf_model::PerfModel;
use crate::coordinator::projection::ProjectionTracker;
use crate::coordinator::router::{headroom_score, HeadroomCache};
use crate::coordinator::scheduler::{
    entry_for, AdmissionDecision, EvalScratch, Scheduler,
};
use crate::coordinator::scoreboard::Scoreboard;
use crate::coordinator::server::{Policy, TimelinePoint};
use crate::coordinator::throttle::min_slo_frequency_with;
use crate::engine::kv_cache::blocks_for;
use crate::engine::request::{Request, RequestId, RequestOutcome};
use crate::engine::sim::{EngineSim, KvCheckpoint};
use crate::gpusim::dvfs::{frequency_grid, FREQ_MAX_MHZ};
use crate::gpusim::latency::{decode_latency_s, GpuState};
use crate::gpusim::power::{idle_power_w, power_w};
use crate::metrics::ServingStats;
use crate::workload::predictor::conservative_adjust;

pub(crate) struct EngineRt {
    pub(crate) sim: EngineSim,
    pub(crate) sb: Scoreboard,
    /// Incrementally maintained §IV-B projection over `sb` (synced
    /// from the scoreboard's delta journal; debug builds bit-compare
    /// it against a from-scratch build on every use).
    pub(crate) tracker: ProjectionTracker,
    /// Reusable SLO-evaluation buffers + GBDT prediction memo.
    pub(crate) scratch: EvalScratch,
    /// The DVFS grid the §IV-E search runs over (built once; the
    /// per-rethrottle rebuild was an allocation on the hot path).
    pub(crate) grid: Vec<u32>,
    /// Time its next iteration may start.
    pub(crate) cursor: f64,
    pub(crate) accepting: bool,
    /// Completions seen so far (admission-retry invalidation).
    pub(crate) completions: u64,
    /// Recent arrival timestamps (sliding window) for the throttle's
    /// prefill-load estimate.
    pub(crate) recent_arrivals: VecDeque<f64>,
    /// EMA of admitted prompt lengths (prefill-cost estimate input).
    pub(crate) prompt_ema: f64,
    /// Head-of-line request that failed admission, and the completion
    /// count at that moment.  Re-checking is pointless until another
    /// request completes (KV and batch only shrink on completion), so
    /// the hot loop skips redundant admission-control evaluations.
    pub(crate) blocked_head: Option<(u64, u64)>,
}

impl EngineRt {
    pub(crate) fn new(spec: EngineSpec, at: f64, prefix_share: bool) -> Self {
        let block_tokens = spec.block_tokens;
        let mut sim =
            EngineSim::new(spec, FREQ_MAX_MHZ).with_prefix_sharing(prefix_share);
        sim.account_idle(at.max(0.0)); // zero-cost: marks accounting start
        Self {
            sim,
            sb: Scoreboard::new(),
            tracker: ProjectionTracker::new(block_tokens),
            scratch: EvalScratch::new(),
            grid: frequency_grid(),
            cursor: at,
            accepting: true,
            completions: 0,
            blocked_head: None,
            recent_arrivals: VecDeque::new(),
            prompt_ema: 0.0,
        }
    }

    /// Expected slowdown factor from future-arrival prefill stalls:
    /// 1 + λ · t_prefill (the projection assumes no arrivals; under
    /// sustained load every admission fuses a prefill into an
    /// iteration, stalling all decodes — §IV-F's TTFT discussion).
    pub(crate) fn load_inflation(&mut self, now: f64) -> f64 {
        const WINDOW_S: f64 = 30.0;
        while self
            .recent_arrivals
            .front()
            .map(|&t| t < now - WINDOW_S)
            .unwrap_or(false)
        {
            self.recent_arrivals.pop_front();
        }
        // Relative margin on top of the arrival-driven term: long-
        // horizon T_R predictions are systematically optimistic (model
        // bias compounds over hundreds of iterations).
        const REL_MARGIN: f64 = 1.10;
        if self.recent_arrivals.is_empty() || self.prompt_ema <= 0.0 {
            return REL_MARGIN;
        }
        let span = (now - self.recent_arrivals.front().unwrap()).max(1.0);
        let lambda = self.recent_arrivals.len() as f64 / span.min(WINDOW_S);
        let t_prefill = crate::gpusim::latency::prefill_latency_s(
            self.sim.spec(),
            self.prompt_ema as u32,
            FREQ_MAX_MHZ,
        );
        (1.0 + lambda * t_prefill) * REL_MARGIN
    }
}

/// One fleet replica: its engines (more than one only while an old
/// engine drains after a shadow-instancing switch), its FIFO queue,
/// its TP-axis autoscaler over ITS OWN ladder, its SLO scheduler, and
/// its telemetry.
pub(crate) struct Replica {
    pub(crate) id: usize,
    /// This replica's own deployment description.
    pub(crate) rspec: ReplicaSpec,
    /// Admission control against this replica's effective SLO.
    pub(crate) sched: Scheduler,
    pub(crate) engines: Vec<EngineRt>,
    pub(crate) queue: VecDeque<Request>,
    pub(crate) scaler: Option<Autoscaler>,
    pub(crate) next_tick: Option<f64>,
    pub(crate) window_arrivals: u64,
    pub(crate) stats: ServingStats,
    pub(crate) outcomes: Vec<RequestOutcome>,
    pub(crate) timeline: Vec<TimelinePoint>,
    pub(crate) shadow_energy: f64,
    /// Energy of engines already drained and retired (fixes the seed's
    /// leak where `engines.retain(..)` dropped their accumulated
    /// energy before the final sum).
    pub(crate) retired_energy: f64,
    pub(crate) switches: u32,
    pub(crate) routed: u64,
    /// Fleet axis: whether the router may assign new arrivals here.
    pub(crate) active: bool,
    /// Pending fleet-axis activation (spawn) completion time.
    pub(crate) activation_ready: Option<f64>,
    /// Last instant this replica did anything (iteration end, idle
    /// accounting while powered on, engine retirement) — the end of
    /// ITS serving window, unlike the fleet-global clock.
    pub(crate) last_event_s: f64,
    /// Bumps on routing-relevant events outside the scoreboard: queue
    /// mutations, engine switches, (de)activations.  Third component
    /// of the headroom-cache key.
    pub(crate) route_epoch: u64,
    /// Memoized §IV-B projection summary for router scoring.
    pub(crate) headroom: HeadroomCache,
    /// Resident requests that arrived here via live migration and have
    /// not completed yet (their completions feed the migrated-request
    /// attainment series).
    ///
    /// detlint r2 audit (2026-08): accessed ONLY by keyed
    /// `insert`/`remove` — never iterated — so its per-instance hash
    /// order cannot leak into `FleetOutcome`; the run-twice digest
    /// test in rust/tests/fleet_threads.rs regression-guards this.
    pub(crate) migrated_ids: HashSet<RequestId>,
    /// Modeled link/host energy of migrations INTO this replica, J.
    pub(crate) migration_energy: f64,
    /// Fault axis: pending respawn completion after a crash or a
    /// preemption took this replica (`None` = not dead).  Kept
    /// separate from `activation_ready` so the fleet autoscaler never
    /// mistakes a fault respawn for a voluntary scale-out it asked for.
    pub(crate) respawn_at: Option<f64>,
    /// Open thermal-throttle window: `(cap_mhz, until_s)`.  Engines
    /// created while the window is open inherit the cap — the ceiling
    /// is the silicon's, not any one `EngineRt`'s.
    pub(crate) thermal: Option<(u32, f64)>,
    /// Drain deadline of an in-progress preemption notice.
    pub(crate) preempt_deadline: Option<f64>,
    /// Periodic best-effort checkpoints of resident requests, replaced
    /// wholesale each checkpoint tick — what crash recovery restores
    /// from.  Always empty with `--faults off`.
    pub(crate) ckpt_store: Vec<(RequestId, KvCheckpoint)>,
    /// Whether engines booted on this replica share prefix KV blocks
    /// copy-on-write (`--prefix-share`).  Carried here so respawns and
    /// shadow-instancing switches inherit the fleet-wide setting.
    pub(crate) prefix_share: bool,
}

impl Replica {
    pub(crate) fn new(
        id: usize,
        rspec: &ReplicaSpec,
        fleet_slo: SloSpec,
        policy: Policy,
        prefix_share: bool,
    ) -> Self {
        let scaler = if policy.autoscaling && !rspec.scale_set.is_empty() {
            Some(Autoscaler::new(rspec.scale_set.clone(), 0))
        } else {
            None
        };
        let spec = scaler
            .as_ref()
            .map(|s| s.current_spec().clone())
            .unwrap_or_else(|| rspec.engine.clone());
        let next_tick = scaler.as_ref().map(|s| s.interval_s);
        Replica {
            id,
            sched: Scheduler::new(rspec.slo.unwrap_or(fleet_slo)),
            rspec: rspec.clone(),
            engines: vec![EngineRt::new(spec, 0.0, prefix_share)],
            queue: VecDeque::new(),
            scaler,
            next_tick,
            window_arrivals: 0,
            stats: ServingStats::default(),
            outcomes: Vec::new(),
            timeline: Vec::new(),
            shadow_energy: 0.0,
            retired_energy: 0.0,
            switches: 0,
            routed: 0,
            active: true,
            activation_ready: None,
            last_event_s: 0.0,
            route_epoch: 0,
            headroom: HeadroomCache::new(),
            migrated_ids: HashSet::new(),
            migration_energy: 0.0,
            respawn_at: None,
            thermal: None,
            preempt_deadline: None,
            ckpt_store: Vec::new(),
            prefix_share,
        }
    }

    pub(crate) fn all_idle(&self) -> bool {
        self.engines.iter().all(|e| e.sim.is_idle())
    }

    pub(crate) fn drained(&self) -> bool {
        self.queue.is_empty() && self.all_idle()
    }

    /// Spec a (re)activated replica boots with: its own autoscaler's
    /// current rung, or its own fixed engine.
    pub(crate) fn respec(&self) -> EngineSpec {
        self.scaler
            .as_ref()
            .map(|s| s.current_spec().clone())
            .unwrap_or_else(|| self.rspec.engine.clone())
    }

    /// Router signal: outstanding work (resident rows + queued).
    pub(crate) fn outstanding(&self) -> u64 {
        let resident: u64 = self.engines.iter().map(|e| e.sim.batch() as u64).sum();
        resident + self.queue.len() as u64
    }

    /// Batch slots of the accepting engine (least-loaded's normalizer:
    /// 10 outstanding on a 64-slot engine is lighter load than 5 on an
    /// 8-slot one).
    pub(crate) fn batch_capacity(&self) -> u32 {
        self.engines
            .iter()
            .find(|e| e.accepting)
            .map(|e| e.sim.spec().max_batch)
            .unwrap_or(0)
    }

    /// Router signal: whether `group`'s shared prefix blocks are
    /// resident on the ACCEPTING engine — the engine a routed arrival
    /// would actually admit into (a draining engine's residency cannot
    /// be joined).  Always false for ungrouped requests and with
    /// sharing off.
    pub(crate) fn prefix_resident(&self, group: u64) -> bool {
        group != 0
            && self
                .engines
                .iter()
                .any(|e| e.accepting && e.sim.shared_prefix_blocks(group) > 0)
    }

    /// Router signal: projected KV/batch headroom of the accepting
    /// engine (§IV-B projection) for an arriving request of
    /// `prompt_tokens`, normalized by THIS replica's own capacity grid
    /// — heterogeneous replicas compare capacity fractions, and a
    /// prompt that could never fit here scores `NEG_INFINITY`.
    ///
    /// The projection summary is memoized ([`HeadroomCache`]) and
    /// invalidated on admission/completion (scoreboard epoch),
    /// iteration boundaries, and queue/topology changes
    /// (`route_epoch`); rebuilding it per arrival was
    /// O(arrivals × replicas) projection builds on the hot path.
    pub(crate) fn headroom_for(&mut self, prompt_tokens: u32) -> f64 {
        let Some(idx) = self.engines.iter().position(|e| e.accepting) else {
            return f64::NEG_INFINITY;
        };
        let e = &mut self.engines[idx];
        let spec = e.sim.spec();
        let block_tokens = spec.block_tokens;
        let kv_capacity = spec.kv_blocks;
        let max_batch = spec.max_batch;
        let req_blocks = blocks_for(prompt_tokens, block_tokens);
        if req_blocks > kv_capacity {
            return f64::NEG_INFINITY; // could never fit, even empty
        }
        let key = (e.sim.iter_index(), e.sb.epoch(), self.route_epoch);
        let (peak_kv, queued_blocks, queued_requests) = match self.headroom.get(key) {
            Some(s) => s,
            None => {
                // Cache miss: peak projected KV comes from the
                // engine's incrementally maintained tracker instead of
                // a from-scratch projection build.
                let proj = e.tracker.project(&e.sb, e.sim.iter_index(), None);
                let s = (
                    proj.peak_kv(),
                    queued_blocks_sum(&self.queue, block_tokens),
                    self.queue.len(),
                );
                self.headroom.store(key, s);
                s
            }
        };
        let score = headroom_score(
            kv_capacity,
            peak_kv,
            queued_blocks.saturating_add(req_blocks),
            max_batch,
            e.sim.batch(),
            queued_requests + 1,
        );
        #[cfg(debug_assertions)]
        {
            // The cache AND the tracker must be unobservable: recompute
            // from an uncached, from-scratch projection and require bit
            // equality (every debug-mode fleet run cross-checks this on
            // every routing decision).
            let proj = crate::coordinator::projection::project(
                &e.sb,
                e.sim.iter_index(),
                block_tokens,
            );
            let fresh = headroom_score(
                kv_capacity,
                proj.peak_kv(),
                queued_blocks_sum(&self.queue, block_tokens)
                    .saturating_add(req_blocks),
                max_batch,
                e.sim.batch(),
                self.queue.len() + 1,
            );
            debug_assert!(
                score.to_bits() == fresh.to_bits(),
                "cached projected-headroom diverged from uncached: {score} vs {fresh}"
            );
        }
        score
    }

    /// Projected energy-per-token (J/token) at the replica's current
    /// operating point: total power at the engines' applied
    /// frequencies over total decode throughput.  An idle replica
    /// produces nothing and scores infinity — it burns idle power for
    /// zero tokens, the least efficient state a replica can be in.
    pub(crate) fn energy_per_token(&self) -> f64 {
        let mut power = 0.0f64;
        let mut tps = 0.0f64;
        for e in &self.engines {
            let spec = e.sim.spec();
            let freq = e.sim.dvfs.target();
            let batch = e.sim.batch();
            let kv = e.sim.kv_blocks_used();
            power += power_w(spec, batch, kv, freq);
            if batch > 0 {
                let st = GpuState {
                    batch,
                    kv_blocks: kv,
                    freq_mhz: freq,
                };
                tps += batch as f64 / decode_latency_s(spec, &st);
            }
        }
        if tps > 0.0 {
            power / tps
        } else {
            f64::INFINITY
        }
    }

    /// Run this replica's engines up to the decision point, then retire
    /// drained non-accepting engines (capturing their energy). Returns
    /// whether any iteration executed.
    ///
    /// This is the RUN-phase body [`ShardPool`] parallelizes: it
    /// touches ONLY `self` plus the shared immutable `cfg`/`policy`/
    /// `model`, which is what makes sharded execution bit-identical to
    /// the inline loop.
    pub(crate) fn run_until(
        &mut self,
        decision: f64,
        cfg: &ServingConfig,
        policy: Policy,
        model: &PerfModel,
    ) -> bool {
        let mut progressed = false;
        for idx in 0..self.engines.len() {
            loop {
                let e = &mut self.engines[idx];
                if e.sim.is_idle() || e.cursor >= decision {
                    break;
                }
                if e.accepting {
                    try_admissions(
                        e,
                        &mut self.queue,
                        cfg,
                        policy,
                        model,
                        &self.sched,
                        &mut self.stats,
                    );
                }
                let e = &mut self.engines[idx];
                if e.sim.is_idle() {
                    break;
                }
                let shadow_p = shadow_power(self.scaler.as_ref(), e.cursor);
                let report = e.sim.run_iteration(e.cursor);
                e.cursor = report.start_s + report.duration_s;
                if e.cursor > self.last_event_s {
                    self.last_event_s = e.cursor;
                }
                progressed = true;
                // Telemetry
                if report.kv_blocks > self.stats.peak_kv_blocks {
                    self.stats.peak_kv_blocks = report.kv_blocks;
                }
                self.stats.power.push(report.power_w);
                self.stats.freq.push(report.freq_mhz as f64);
                self.stats.iter_tbt.push(report.duration_s);
                self.timeline.push(TimelinePoint {
                    t: report.start_s,
                    replica: self.id,
                    engine_tp: e.sim.spec().tensor_parallel,
                    freq_mhz: report.freq_mhz,
                    power_w: report.power_w,
                    shadow_power_w: shadow_p,
                    batch: report.batch,
                    kv_blocks: report.kv_blocks,
                });
                e.completions += report.completed.len() as u64;
                // Recompute-preempted rows go back to the queue head,
                // BLOCKED until some request completes — re-admitting
                // immediately would re-consume the freed blocks and
                // livelock the evict/re-admit cycle.
                for req in &report.evicted {
                    e.sb.strike(req.id);
                    self.queue.push_front(req.clone());
                    e.blocked_head = Some((req.id, e.completions));
                    // The eviction may come from a DRAINING engine,
                    // whose scoreboard epoch is not in the headroom
                    // cache key (the key tracks the ACCEPTING
                    // engine): invalidate via route_epoch so the
                    // router sees the re-queued request.
                    self.route_epoch += 1;
                }
                let had_completions =
                    !report.completed.is_empty() || !report.evicted.is_empty();
                for o in &report.completed {
                    e.sb.strike(o.id);
                    self.stats.record_outcome(o);
                    // Migrated-request attainment: completions that
                    // arrived via live migration feed their own series
                    // (empty set lookup when migration is off).
                    if self.migrated_ids.remove(&o.id) {
                        self.stats.migrated_e2e.push(o.e2e_s);
                    }
                    self.outcomes.push(o.clone());
                }
                // §IV-F: bump predictions the reality has outrun.
                // Allocation-free: the engine's live view streams
                // straight into the scoreboard sync (the old path
                // collected an `active_info` Vec plus a `bumped` Vec
                // EVERY iteration, almost always to conclude nothing
                // changed).
                let bumped = e
                    .sb
                    .sync_overruns_iter(e.sim.active_overruns(), cfg.max_tokens);
                // Re-evaluate the throttling controller when the batch
                // composition changed (completion or prediction bump):
                // without this, a frequency chosen under light load
                // would persist while a queue builds behind a full
                // batch (§IV-E is admission-triggered; completions are
                // the other composition-change event).
                if policy.throttling && (had_completions || bumped > 0) {
                    rethrottle(e, !self.queue.is_empty(), model, &self.sched);
                }
            }
        }

        // Retire drained non-accepting engines (graceful shutdown
        // done), folding their accumulated energy, prefix-cache
        // savings and final clock into the replica.
        let retired = &mut self.retired_energy;
        let last = &mut self.last_event_s;
        let cached = &mut self.stats.prefix_cached_tokens;
        self.engines.retain(|e| {
            let keep = e.accepting || !e.sim.is_idle();
            if !keep {
                *retired += e.sim.total_energy_j();
                *cached += e.sim.prefix_cached_tokens();
                if e.cursor > *last {
                    *last = e.cursor;
                }
            }
            keep
        });
        progressed
    }

    /// Wake idle accepting engines at `now` for immediate admission.
    pub(crate) fn wake_and_admit(
        &mut self,
        now: f64,
        cfg: &ServingConfig,
        policy: Policy,
        model: &PerfModel,
    ) {
        let mut powered_on = false;
        for e in self.engines.iter_mut().filter(|e| e.accepting) {
            powered_on = true;
            if e.sim.is_idle() && e.cursor < now {
                e.sim.account_idle(now);
                e.cursor = now;
            }
            if e.sim.is_idle() {
                try_admissions(
                    e,
                    &mut self.queue,
                    cfg,
                    policy,
                    model,
                    &self.sched,
                    &mut self.stats,
                );
            }
        }
        // A powered-on replica is live (burning at least idle power)
        // even when no iteration runs: its serving window extends.
        if powered_on && now > self.last_event_s {
            self.last_event_s = now;
        }
    }

    /// Fast-forward a stale tick cadence before handing rerouted work
    /// to this replica.  A drained replica's `next_tick` is excluded
    /// from the decision min (nothing to do) and freezes; if work is
    /// later rerouted here, the frozen timestamp would re-enter the
    /// decision min and drag the fleet's event clock BACKWARDS.
    pub(crate) fn catch_up_tick(&mut self, now: f64) {
        if let (Some(s), Some(t)) = (self.scaler.as_ref(), self.next_tick) {
            if t < now {
                let intervals = ((now - t) / s.interval_s).ceil();
                self.next_tick = Some(t + intervals * s.interval_s);
            }
        }
    }

    /// TP-axis monitoring tick.
    pub(crate) fn tick_scaler(&mut self, now: f64) {
        if let (Some(s), Some(t)) = (self.scaler.as_mut(), self.next_tick) {
            if now >= t {
                let rps = self.window_arrivals as f64 / s.interval_s;
                self.window_arrivals = 0;
                if let ScaleDecision::StartShadow { target } = s.tick(now, rps) {
                    let _ = target; // energy accounted at switch time
                }
                self.next_tick = Some(t + s.interval_s);
            }
        }
    }

    /// Shadow instance ready -> transition to the new engine size.
    pub(crate) fn complete_shadow(&mut self, now: f64) {
        if let Some(s) = self.scaler.as_mut() {
            if let Some(sh) = s.shadow() {
                if now >= sh.ready_at {
                    let warm = idle_power_w(&s.specs()[sh.target], FREQ_MAX_MHZ)
                        * (sh.ready_at - sh.started_at);
                    self.shadow_energy += warm;
                    let new_idx = s.poll_ready(now).expect("shadow was ready");
                    let spec = s.specs()[new_idx].clone();
                    for e in self.engines.iter_mut() {
                        e.accepting = false;
                    }
                    self.engines.push(EngineRt::new(spec, now, self.prefix_share));
                    // The silicon's thermal ceiling outlives any one
                    // engine: a window opened on this replica caps the
                    // freshly-booted engine too.
                    if let Some((cap, _)) = self.thermal {
                        if let Some(e) = self.engines.last_mut() {
                            e.sim.dvfs.set_cap(now, cap);
                        }
                    }
                    self.switches += 1;
                    // The accepting engine changed: invalidate the
                    // router's cached projection summary.
                    self.route_epoch += 1;
                }
            }
        }
    }

    /// Fleet axis: stop accepting, drain, and power off when idle.
    pub(crate) fn deactivate(&mut self, now: f64) {
        self.active = false;
        self.activation_ready = None;
        for e in self.engines.iter_mut() {
            e.accepting = false;
        }
        if let Some(s) = self.scaler.as_mut() {
            // An in-flight TP shadow is discarded, but the warm-up
            // idle power it burned until now is real energy — charge
            // it, mirroring complete_shadow's lump accounting.
            if let Some(sh) = s.shadow() {
                let warmed = (now.min(sh.ready_at) - sh.started_at).max(0.0);
                self.shadow_energy +=
                    idle_power_w(&s.specs()[sh.target], FREQ_MAX_MHZ) * warmed;
            }
            s.cancel_shadow();
        }
        self.next_tick = None;
        self.window_arrivals = 0;
        self.route_epoch += 1;
    }

    /// Fault axis: the replica dies at `now`.  Every engine is torn
    /// down (its accumulated energy is retired — the joules were
    /// burned even though the work was lost), resident and queued
    /// requests are handed back for recovery, and the replica goes
    /// dark until its respawn.  The caller decides which orphans are
    /// recoverable from `ckpt_store` and sets `respawn_at`.
    pub(crate) fn crash(&mut self, now: f64) -> Vec<Request> {
        let mut orphans: Vec<Request> = Vec::new();
        for e in self.engines.iter_mut() {
            e.sim.account_idle(now);
            orphans.extend(e.sim.drain());
            self.retired_energy += e.sim.total_energy_j();
            self.stats.prefix_cached_tokens += e.sim.prefix_cached_tokens();
            if e.cursor > self.last_event_s {
                self.last_event_s = e.cursor;
            }
        }
        self.engines.clear();
        orphans.extend(self.queue.drain(..));
        self.active = false;
        self.activation_ready = None;
        if let Some(s) = self.scaler.as_mut() {
            // Same in-flight-shadow accounting as deactivate: the
            // warm-up idle power burned so far is real energy.
            if let Some(sh) = s.shadow() {
                let warmed = (now.min(sh.ready_at) - sh.started_at).max(0.0);
                self.shadow_energy +=
                    idle_power_w(&s.specs()[sh.target], FREQ_MAX_MHZ) * warmed;
            }
            s.cancel_shadow();
        }
        self.next_tick = None;
        self.window_arrivals = 0;
        self.preempt_deadline = None;
        self.last_event_s = self.last_event_s.max(now);
        self.route_epoch += 1;
        orphans
    }
}

/// Sum of KV blocks the queued prompts will demand — shared by the
/// cached router-scoring path and its debug cross-check (previously
/// duplicated inline in both).
fn queued_blocks_sum(queue: &VecDeque<Request>, block_tokens: u32) -> u32 {
    queue
        .iter()
        .map(|r| blocks_for(r.prompt_tokens, block_tokens))
        .sum()
}

fn shadow_power(scaler: Option<&Autoscaler>, t: f64) -> f64 {
    match scaler.and_then(|s| s.shadow().map(|sh| (s, sh))) {
        Some((s, sh)) if t >= sh.started_at && t < sh.ready_at => {
            idle_power_w(&s.specs()[sh.target], FREQ_MAX_MHZ)
        }
        _ => 0.0,
    }
}

/// Admit as many queued requests as the policy allows (FIFO with
/// head-of-line blocking, matching the paper's single queue).
// detlint: hot
fn try_admissions(
    e: &mut EngineRt,
    queue: &mut VecDeque<Request>,
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    sched: &Scheduler,
    stats: &mut ServingStats,
) {
    let now = e.cursor;
    while let Some(req) = queue.front() {
        // Field-level split of the engine runtime: admission_check
        // needs the spec (owned by the sim) alongside `&mut tracker`
        // and `&mut scratch`, which a whole-`e` borrow forbids — the
        // old workaround cloned the spec on every admission attempt,
        // an allocation on the hot path.
        let EngineRt {
            sim,
            sb,
            tracker,
            scratch,
            completions,
            blocked_head,
            ..
        } = &mut *e;
        // Blocked-head fast path: nothing relevant changed since the
        // last failed check, so skip the expensive re-evaluation.
        if let Some((id, at)) = *blocked_head {
            if id == req.id && at == *completions {
                break;
            }
            *blocked_head = None;
        }
        if sim.batch() >= sim.spec().max_batch {
            break;
        }
        let spec = sim.spec();
        let adjusted =
            conservative_adjust(req.predicted_gen, cfg.predictor_p95_error, cfg.max_tokens);
        let k = sim.iter_index();
        let mut entry =
            entry_for(req.id, req.prompt_tokens, adjusted, req.arrival_s, k, &sched.slo);
        // §IV-B prefix discount: full prefix blocks ALREADY resident
        // for this request's group are shared copy-on-write at admit,
        // so the projection must not count them a second time.  The
        // first group member finds nothing resident and pays the full
        // footprint; `shared_prefix_blocks` is 0 whenever sharing is
        // off, keeping the off path's arithmetic untouched.
        if req.prefix_group != 0 {
            entry.kv_discount_blocks = sim
                .shared_prefix_blocks(req.prefix_group)
                .min(req.shared_prefix_tokens.min(req.prompt_tokens) / spec.block_tokens);
        }

        let lost = if policy.slo_admission {
            sb.virtual_append(entry);
            let (decision, already_lost) =
                sched.admission_check(model, spec, sb, tracker, scratch, k, now, req.id);
            // De-facto-lost residents stop blocking future admissions.
            for id in already_lost {
                sb.mark_lost(id);
            }
            match decision {
                AdmissionDecision::Admit => {
                    sb.commit_virtual();
                    false
                }
                AdmissionDecision::AdmitLost => {
                    sb.commit_virtual();
                    sb.mark_lost(req.id);
                    true
                }
                AdmissionDecision::Queue(_) => {
                    sb.rollback_virtual();
                    *blocked_head = Some((req.id, *completions));
                    break;
                }
            }
        } else {
            // Triton baseline: KV-capacity gate only (prefix-aware —
            // a resident shared prefix only needs its private tail).
            if !sim.kv_fits_request(req) {
                *blocked_head = Some((req.id, *completions));
                break;
            }
            sb.insert(entry);
            false
        };

        let req = queue.pop_front().unwrap();
        // detlint: allow(r4, reason = "Request derives Clone over five scalar fields, so this is a memcpy kept only for the rare admission-race rollback")
        match sim.admit(req.clone(), now, lost) {
            Ok(()) => {}
            Err(_) => {
                // Engine-side admission raced (KV or batch slot): undo
                // everything and leave the request at the queue head.
                sb.strike(entry.id);
                queue.push_front(req);
                *blocked_head = Some((entry.id, *completions));
                break;
            }
        }

        // §IV-E: the throttling controller runs on admission.
        if policy.throttling {
            rethrottle(e, !queue.is_empty(), model, sched);
        }
    }
    let _ = stats;
}

/// Run the §IV-E controller for the engine's current scoreboard.
///
/// `queue_pressure`: when admission control could NOT place every
/// waiting query (the wait queue is non-empty), the engine runs at
/// maximum frequency — queued queries' deadlines are burning and the
/// fastest drain protects their SLOs (the paper observes "peak power
/// equal to that of Triton when under high system pressure").
// detlint: hot
pub(crate) fn rethrottle(
    e: &mut EngineRt,
    queue_pressure: bool,
    model: &PerfModel,
    sched: &Scheduler,
) {
    let now = e.cursor;
    let f = if queue_pressure {
        FREQ_MAX_MHZ
    } else {
        let scale = e.load_inflation(now);
        let k = e.sim.iter_index();
        let proj = e.tracker.project(&e.sb, k, None);
        min_slo_frequency_with(
            &e.grid,
            model,
            e.sim.spec(),
            &sched.slo,
            &e.sb,
            proj,
            now,
            scale,
            &mut e.scratch,
        )
    };
    e.sim.dvfs.set(now, f);
}

// ---------------------------------------------------------------------
// Deterministic worker pool
// ---------------------------------------------------------------------

/// One RUN-phase round: the shard's replicas (moved in whole) and the
/// decision point to step them to.
struct ShardCmd {
    decision: f64,
    replicas: Vec<Replica>,
}

/// The shard's replicas handed back after the round, in index order.
struct ShardResp {
    replicas: Vec<Replica>,
    progressed: bool,
}

struct ShardHandle {
    tx: Sender<ShardCmd>,
    rx: Receiver<ShardResp>,
}

/// Persistent worker threads stepping fixed contiguous replica ranges.
///
/// Per round, [`ShardPool::run_round`] moves each shard's replicas to
/// its worker, which steps them in index order via
/// [`Replica::run_until`] and moves them back; the coordinator
/// reassembles the fleet Vec in shard order.  `progressed` flags are
/// OR-reduced (order-independent).  Round-trip buffers ping-pong
/// through `bufs`, so steady-state rounds allocate nothing beyond the
/// channels' own nodes.
///
/// Dropping the pool closes the command channels; workers then exit
/// and the owning [`std::thread::scope`] joins them.
pub(crate) struct ShardPool {
    shards: Vec<ShardHandle>,
    ranges: Vec<(usize, usize)>,
    bufs: Vec<Vec<Replica>>,
}

impl ShardPool {
    /// Spawn one worker per shard inside `scope`.  `cfg` and `model`
    /// are shared read-only across workers; `Replica`s are moved per
    /// round, never shared.
    pub(crate) fn spawn<'scope, 'env>(
        scope: &'scope Scope<'scope, 'env>,
        threads: usize,
        n_replicas: usize,
        cfg: &'env ServingConfig,
        policy: Policy,
        model: &'env PerfModel,
    ) -> Self {
        let ranges = shard_ranges(n_replicas, threads);
        let mut shards = Vec::with_capacity(ranges.len());
        let mut bufs = Vec::with_capacity(ranges.len());
        for &(lo, hi) in &ranges {
            let (cmd_tx, cmd_rx) = channel::<ShardCmd>();
            let (resp_tx, resp_rx) = channel::<ShardResp>();
            scope.spawn(move || {
                while let Ok(ShardCmd {
                    decision,
                    mut replicas,
                }) = cmd_rx.recv()
                {
                    let mut progressed = false;
                    for rp in replicas.iter_mut() {
                        progressed |= rp.run_until(decision, cfg, policy, model);
                    }
                    if resp_tx
                        .send(ShardResp {
                            replicas,
                            progressed,
                        })
                        .is_err()
                    {
                        break; // pool dropped mid-round
                    }
                }
            });
            shards.push(ShardHandle {
                tx: cmd_tx,
                rx: resp_rx,
            });
            bufs.push(Vec::with_capacity(hi - lo));
        }
        Self {
            shards,
            ranges,
            bufs,
        }
    }

    /// Step every replica to `decision` across the workers and
    /// reassemble `replicas` in index order.  Returns whether any
    /// iteration executed anywhere (the OR over shards — a
    /// commutative reduction, so receive order cannot perturb it).
    pub(crate) fn run_round(&mut self, replicas: &mut Vec<Replica>, decision: f64) -> bool {
        debug_assert_eq!(
            replicas.len(),
            self.ranges.last().map(|&(_, hi)| hi).unwrap_or(0),
            "fleet size changed under a fixed shard assignment"
        );
        // Dispatch in REVERSE shard order: draining from the tail is a
        // cheap O(shard) move with no mid-Vec shifting.
        for s in (0..self.shards.len()).rev() {
            let (lo, _) = self.ranges[s];
            let mut buf = std::mem::take(&mut self.bufs[s]);
            buf.extend(replicas.drain(lo..));
            self.shards[s]
                .tx
                .send(ShardCmd {
                    decision,
                    replicas: buf,
                })
                .expect("shard worker alive");
        }
        // Receive in FORWARD shard order: appending shard 0, 1, ...
        // restores the exact replica index order every time, which is
        // what keeps the coordination phase bit-identical.
        let mut progressed = false;
        for s in 0..self.shards.len() {
            let mut resp = self.shards[s].rx.recv().expect("shard worker alive");
            progressed |= resp.progressed;
            replicas.append(&mut resp.replicas);
            // `append` drained the buffer but kept its capacity: store
            // it back for the next round (ping-pong, no reallocation).
            self.bufs[s] = resp.replicas;
        }
        progressed
    }
}

/// Fixed shard assignment: contiguous replica index ranges, sizes
/// differing by at most one (the first `n % t` shards get the extra
/// replica).  Purely a function of `(n_replicas, threads)` — never of
/// load — so the assignment is deterministic across runs.
pub(crate) fn shard_ranges(n_replicas: usize, threads: usize) -> Vec<(usize, usize)> {
    let t = threads.clamp(1, n_replicas.max(1));
    let base = n_replicas / t;
    let extra = n_replicas % t;
    let mut out = Vec::with_capacity(t);
    let mut lo = 0usize;
    for s in 0..t {
        let len = base + usize::from(s < extra);
        out.push((lo, lo + len));
        lo += len;
    }
    out
}

/// Resolve a requested `--threads` value against the fleet size:
/// `0` means auto (the machine's available parallelism), and more
/// threads than replicas would only idle, so the count is clamped to
/// `[1, n_replicas]`.  The RESULT never affects serving output — any
/// value is bit-identical to 1 — only wall-clock speed.
pub fn effective_threads(requested: usize, n_replicas: usize) -> usize {
    let req = if requested == 0 {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    } else {
        requested
    };
    req.clamp(1, n_replicas.max(1))
}

/// Allocation-audit hook for the `perf_hotpath` bench: build one warm
/// replica, pre-stock its queue, and drive repeated RUN-phase sweeps
/// (`run_until` + `wake_and_admit`) over fixed virtual-time rounds.
/// `mark` is called once when the `warmup_rounds` warm-up ends —
/// the bench snapshots its allocation counter there — and the
/// function returns the number of engine iterations executed after
/// the mark.
///
/// Steady-state stepping reuses per-replica scratch (EvalScratch, the
/// DVFS grid, the headroom cache, the queue's ring buffer), so the
/// measured window performs no per-iteration allocations beyond
/// amortized telemetry-Vec growth.
pub fn steady_state_sweep(
    cfg: &ServingConfig,
    policy: Policy,
    model: &PerfModel,
    warmup_rounds: u64,
    rounds: u64,
    mark: &mut dyn FnMut(),
) -> u64 {
    assert!(rounds > 0, "need at least one measured round");
    const ROUND_S: f64 = 0.25;
    let total = warmup_rounds + rounds;
    let rspec = ReplicaSpec::from_config(cfg, policy.autoscaling);
    let mut rp = Replica::new(0, &rspec, cfg.slo, policy, false);
    // Stock the queue up front (arrivals spread over the whole run so
    // admission deadlines stay live): measured rounds then only pop
    // from the front of a warm ring buffer — the sweep exercises
    // admission, iteration stepping, the throttle controller and
    // telemetry, without arrival-routing noise.
    let stock = (total * 8).max(256);
    let spacing = total as f64 * ROUND_S / stock as f64;
    for i in 0..stock {
        rp.queue.push_back(Request {
            id: i,
            prompt_tokens: 128,
            gen_tokens: 24,
            predicted_gen: 24,
            arrival_s: i as f64 * spacing,
            prefix_group: 0,
            shared_prefix_tokens: 0,
        });
    }
    rp.wake_and_admit(0.0, cfg, policy, model);
    let mut measured_from = 0usize;
    for round in 0..total {
        if round == warmup_rounds {
            mark();
            measured_from = rp.timeline.len();
        }
        let decision = (round + 1) as f64 * ROUND_S;
        rp.run_until(decision, cfg, policy, model);
        rp.wake_and_admit(decision, cfg, policy, model);
    }
    (rp.timeline.len() - measured_from) as u64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;

    #[test]
    fn shard_ranges_are_contiguous_and_balanced() {
        for n in 0..33usize {
            for t in 1..9usize {
                let r = shard_ranges(n, t);
                assert_eq!(r.len(), t.min(n.max(1)), "n={n} t={t}");
                assert_eq!(r.first().unwrap().0, 0);
                assert_eq!(r.last().unwrap().1, n);
                for w in r.windows(2) {
                    assert_eq!(w[0].1, w[1].0, "ranges must tile contiguously");
                }
                let sizes: Vec<usize> = r.iter().map(|&(lo, hi)| hi - lo).collect();
                let max = *sizes.iter().max().unwrap();
                let min = *sizes.iter().min().unwrap();
                assert!(max - min <= 1, "unbalanced shards: {sizes:?}");
            }
        }
    }

    #[test]
    fn shard_ranges_are_a_pure_function_of_shape() {
        assert_eq!(shard_ranges(8, 4), shard_ranges(8, 4));
        assert_eq!(shard_ranges(7, 3), vec![(0, 3), (3, 5), (5, 7)]);
        assert_eq!(shard_ranges(4, 1), vec![(0, 4)]);
    }

    #[test]
    fn effective_threads_clamps_to_fleet_and_floor() {
        assert_eq!(effective_threads(1, 64), 1);
        assert_eq!(effective_threads(4, 64), 4);
        assert_eq!(effective_threads(8, 3), 3);
        assert_eq!(effective_threads(3, 0), 1);
        assert!(effective_threads(0, 64) >= 1);
    }

    #[test]
    fn run_round_preserves_replica_index_order() {
        let spec = llama2_13b(1);
        let cfg = ServingConfig::throttllem(spec.clone());
        let policy = Policy::throttle_only();
        let model = PerfModel::train(&[spec], 40, 0);
        let rspec = ReplicaSpec::from_config(&cfg, false);
        let mut replicas: Vec<Replica> = (0..5)
            .map(|id| Replica::new(id, &rspec, cfg.slo, policy, false))
            .collect();
        std::thread::scope(|scope| {
            let mut pool =
                ShardPool::spawn(scope, 2, replicas.len(), &cfg, policy, &model);
            for _ in 0..3 {
                let progressed = pool.run_round(&mut replicas, 1.0);
                assert!(!progressed, "idle replicas must not progress");
                let ids: Vec<usize> = replicas.iter().map(|r| r.id).collect();
                assert_eq!(ids, vec![0, 1, 2, 3, 4]);
            }
        });
    }

    #[test]
    fn steady_state_sweep_executes_iterations() {
        let spec = llama2_13b(2);
        let cfg = ServingConfig::throttllem(spec.clone());
        let model = PerfModel::train(&[spec], 40, 0);
        let mut marked = 0u32;
        let iters = steady_state_sweep(
            &cfg,
            Policy::throttle_only(),
            &model,
            4,
            16,
            &mut || marked += 1,
        );
        assert_eq!(marked, 1, "mark fires exactly once");
        assert!(iters > 0, "measured window must execute iterations");
    }
}
