//! GPU frequency throttling controller (paper §IV-E).
//!
//! Triggered after a successful admission, it binary-searches the
//! frequency grid for the MINIMUM frequency that still satisfies the
//! TBT and E2E SLO checks (the scheduler guaranteed the maximum
//! frequency works, so a solution exists).  If any "lost" request is
//! resident, the search is bypassed and the maximum frequency selected.

use crate::config::{EngineSpec, SloSpec};
use crate::coordinator::perf_model::PerfModel;
use crate::coordinator::projection::Projection;
use crate::coordinator::scoreboard::Scoreboard;
use crate::gpusim::dvfs::{frequency_grid, FREQ_MAX_MHZ};

/// Safety slack subtracted from E2E deadlines during the frequency
/// search, covering performance-model error and T_R drift (the paper's
/// system lands ~1.45 s under its deadlines on average; a sub-second
/// margin keeps marginal deadline predictions from flipping into real
/// violations at the selected frequency).
pub const SAFETY_SLACK_S: f64 = 2.0;

/// Pick the minimum SLO-satisfying frequency for the current
/// scoreboard/projection. Returns the chosen frequency in MHz.
///
/// `t_r_scale` inflates predicted remaining times by the expected
/// prefill-stall overhead of future arrivals (`1 + λ·t_prefill`); pass
/// 1.0 when no load estimate is available.
pub fn min_slo_frequency(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    sb: &Scoreboard,
    proj: &Projection,
    now: f64,
    t_r_scale: f64,
) -> u32 {
    if sb.any_lost() {
        // Attempt to recover the lost query's SLO at peak performance.
        return FREQ_MAX_MHZ;
    }
    if proj.horizon() == 0 {
        return FREQ_MAX_MHZ;
    }
    let grid = frequency_grid();
    let entries: Vec<crate::coordinator::scoreboard::Entry> =
        sb.visible().copied().collect();
    // Deadlines are tightened by the safety slack (evaluate_slo
    // compares `now + T_R` against them) and remaining times inflated
    // by the load factor.
    let ok = |f: u32| {
        crate::coordinator::scheduler::evaluate_slo_entries(
            model,
            spec,
            slo,
            &entries,
            proj,
            f,
            now + SAFETY_SLACK_S,
            t_r_scale,
        )
        .all_ok()
    };

    // Monotone predicate (higher f => faster => SLOs easier):
    // binary search for the first passing grid index.
    let (mut lo, mut hi) = (0usize, grid.len() - 1);
    if ok(grid[lo]) {
        return grid[lo];
    }
    // invariant: grid[lo] fails, grid[hi] passes (guaranteed by the
    // scheduler's max-frequency validation; re-check defensively).
    if !ok(grid[hi]) {
        return FREQ_MAX_MHZ;
    }
    while hi - lo > 1 {
        let mid = (lo + hi) / 2;
        if ok(grid[mid]) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    grid[hi]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::coordinator::projection::project;
    use crate::coordinator::scheduler::evaluate_slo;
    use crate::coordinator::scoreboard::Entry;

    fn entry(id: u64, prompt: u32, pred: u32, deadline: f64) -> Entry {
        Entry {
            id,
            scheduled_iter: 0,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: deadline,
            lost: false,
        }
    }

    fn setup() -> (PerfModel, EngineSpec, SloSpec) {
        let e = llama2_13b(2);
        (
            PerfModel::train(&[e.clone()], 40, 0),
            e,
            SloSpec::new(0.2, 30.2),
        )
    }

    #[test]
    fn relaxed_deadlines_allow_low_frequency() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 200, 1e9));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert!(f < 700, "expected deep throttle, got {f} MHz");
    }

    #[test]
    fn tight_deadlines_force_high_frequency() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        // 600 iterations must finish within 8 s: needs ~75 IPS at
        // batch 1 (TBT <= 13.3 ms), feasible only near peak frequency
        // where the effective-bandwidth curve is saturated.
        sb.insert(entry(1, 100, 600, 8.0));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert!(f > 1000, "expected near-max frequency, got {f} MHz");
    }

    #[test]
    fn intermediate_deadline_intermediate_frequency() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        // ~600 iterations in 13 s: ~46 IPS at batch 1 -> mid frequency.
        sb.insert(entry(1, 100, 600, 13.0));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert!(
            (400..=1200).contains(&f),
            "expected mid-range frequency, got {f}"
        );
    }

    #[test]
    fn chosen_frequency_is_minimal() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 500, 400, 20.0));
        sb.insert(entry(2, 800, 300, 25.0));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        // The frequency 15 MHz below must fail the (slack-tightened)
        // checks the controller optimizes against.
        if f > 210 {
            let below = f - 15;
            let eval = evaluate_slo(&m, &e, &slo, &sb, &proj, below, SAFETY_SLACK_S);
            assert!(!eval.all_ok(), "f-15={below} should violate");
        }
        let eval = evaluate_slo(&m, &e, &slo, &sb, &proj, f, SAFETY_SLACK_S);
        assert!(eval.all_ok(), "chosen f={f} must satisfy");
    }

    #[test]
    fn lost_request_bypasses_search() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 200, 1e9));
        sb.mark_lost(1);
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert_eq!(f, FREQ_MAX_MHZ);
    }

    #[test]
    fn empty_projection_defaults_to_max() {
        let (m, e, slo) = setup();
        let sb = Scoreboard::new();
        let proj = project(&sb, 0, e.block_tokens);
        assert_eq!(
            min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0),
            FREQ_MAX_MHZ
        );
    }
}
