//! GPU frequency throttling controller (paper §IV-E).
//!
//! Triggered after a successful admission, it binary-searches the
//! frequency grid for the MINIMUM frequency that still satisfies the
//! TBT and E2E SLO checks (the scheduler guaranteed the maximum
//! frequency works, so a solution exists).  If any "lost" request is
//! resident, the search is bypassed and the maximum frequency selected.

use crate::config::{EngineSpec, SloSpec};
use crate::coordinator::perf_model::PerfModel;
use crate::coordinator::projection::Projection;
use crate::coordinator::scheduler::{evaluate_slo_scratch, EvalScratch};
use crate::coordinator::scoreboard::Scoreboard;
use crate::gpusim::dvfs::{frequency_grid, FREQ_MAX_MHZ};

/// Safety slack subtracted from E2E deadlines during the frequency
/// search, covering performance-model error and T_R drift (the paper's
/// system lands ~1.45 s under its deadlines on average; a sub-second
/// margin keeps marginal deadline predictions from flipping into real
/// violations at the selected frequency).
pub const SAFETY_SLACK_S: f64 = 2.0;

/// Pick the minimum SLO-satisfying frequency for the current
/// scoreboard/projection. Returns the chosen frequency in MHz.
///
/// `t_r_scale` inflates predicted remaining times by the expected
/// prefill-stall overhead of future arrivals (`1 + λ·t_prefill`); pass
/// 1.0 when no load estimate is available.
pub fn min_slo_frequency(
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    sb: &Scoreboard,
    proj: &Projection,
    now: f64,
    t_r_scale: f64,
) -> u32 {
    min_slo_frequency_on_grid(&frequency_grid(), model, spec, slo, sb, proj, now, t_r_scale)
}

/// [`min_slo_frequency`] over an explicit ascending frequency grid.
///
/// Hardened for degenerate grids: an empty grid falls back to
/// [`FREQ_MAX_MHZ`], a single-entry grid (lo == hi) returns that sole
/// setting without entering the search, and the bisection loop
/// maintains `lo < hi` so it can neither underflow nor spin.
#[allow(clippy::too_many_arguments)]
pub fn min_slo_frequency_on_grid(
    grid: &[u32],
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    sb: &Scoreboard,
    proj: &Projection,
    now: f64,
    t_r_scale: f64,
) -> u32 {
    let mut scratch = EvalScratch::new();
    min_slo_frequency_with(
        grid, model, spec, slo, sb, proj, now, t_r_scale, &mut scratch,
    )
}

/// [`min_slo_frequency_on_grid`] with caller-owned evaluation buffers:
/// the allocation-free serving-loop form.  Every probe of the
/// bisection evaluates the SAME projection, so GBDT inferences are
/// memoized per (freq, batch, kv-bucket) in the scratch across the
/// ~log₂(grid) probes — and across consecutive searches for as long as
/// the committed entry set and iteration stay put (the scratch stamp
/// clears the memo the moment either moves).
// detlint: hot
#[allow(clippy::too_many_arguments)]
pub fn min_slo_frequency_with(
    grid: &[u32],
    model: &PerfModel,
    spec: &EngineSpec,
    slo: &SloSpec,
    sb: &Scoreboard,
    proj: &Projection,
    now: f64,
    t_r_scale: f64,
    scratch: &mut EvalScratch,
) -> u32 {
    let Some(&fallback) = grid.last() else {
        // Empty grid: nothing to search; run flat out.
        return FREQ_MAX_MHZ;
    };
    if sb.any_lost() {
        // Attempt to recover the lost query's SLO at peak performance.
        return fallback;
    }
    if proj.horizon() == 0 {
        return fallback;
    }
    // Stamp with the window's iteration k (= start_iter - 1, the same
    // convention admission_check uses) and world 0 (committed-only):
    // consecutive searches over the same state reuse the memo, while
    // an admission evaluation at the same (seq, k) — which projects a
    // DIFFERENT trajectory (its candidate included) — clears it.
    scratch.ensure_stamp(sb.delta_seq(), proj.start_iter.saturating_sub(1), 0);
    // Deadlines are tightened by the safety slack (evaluate_slo
    // compares `now + T_R` against them) and remaining times inflated
    // by the load factor.  The entry set is iterated in place — no
    // per-probe collection.
    let ok = |scratch: &mut EvalScratch, f: u32| {
        evaluate_slo_scratch(
            model,
            spec,
            slo,
            sb.visible(),
            proj,
            f,
            now + SAFETY_SLACK_S,
            t_r_scale,
            scratch,
        )
        .all_ok()
    };

    // Monotone predicate (higher f => faster => SLOs easier):
    // binary search for the first passing grid index.
    if ok(scratch, grid[0]) {
        return grid[0];
    }
    // invariant: grid[lo] fails, grid[hi] passes (guaranteed by the
    // scheduler's max-frequency validation; re-check defensively).
    // Single-entry grids land here directly: grid[0] failed, so the
    // only setting doubles as the fallback.
    if grid.len() == 1 || !ok(scratch, fallback) {
        return fallback;
    }
    let (mut lo, mut hi) = (0usize, grid.len() - 1);
    while hi - lo > 1 {
        let mid = lo + (hi - lo) / 2;
        if ok(scratch, grid[mid]) {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    grid[hi]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::coordinator::projection::project;
    use crate::coordinator::scheduler::evaluate_slo;
    use crate::coordinator::scoreboard::Entry;

    fn entry(id: u64, prompt: u32, pred: u32, deadline: f64) -> Entry {
        Entry {
            id,
            scheduled_iter: 0,
            prompt_tokens: prompt,
            predicted_gen: pred,
            deadline_s: deadline,
            lost: false,
            kv_discount_blocks: 0,
        }
    }

    fn setup() -> (PerfModel, EngineSpec, SloSpec) {
        let e = llama2_13b(2);
        (
            PerfModel::train(&[e.clone()], 40, 0),
            e,
            SloSpec::new(0.2, 30.2),
        )
    }

    #[test]
    fn relaxed_deadlines_allow_low_frequency() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 200, 1e9));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert!(f < 700, "expected deep throttle, got {f} MHz");
    }

    #[test]
    fn tight_deadlines_force_high_frequency() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        // 600 iterations must finish within 8 s: needs ~75 IPS at
        // batch 1 (TBT <= 13.3 ms), feasible only near peak frequency
        // where the effective-bandwidth curve is saturated.
        sb.insert(entry(1, 100, 600, 8.0));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert!(f > 1000, "expected near-max frequency, got {f} MHz");
    }

    #[test]
    fn intermediate_deadline_intermediate_frequency() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        // ~600 iterations in 13 s: ~46 IPS at batch 1 -> mid frequency.
        sb.insert(entry(1, 100, 600, 13.0));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert!(
            (400..=1200).contains(&f),
            "expected mid-range frequency, got {f}"
        );
    }

    #[test]
    fn chosen_frequency_is_minimal() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 500, 400, 20.0));
        sb.insert(entry(2, 800, 300, 25.0));
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        // The frequency 15 MHz below must fail the (slack-tightened)
        // checks the controller optimizes against.
        if f > 210 {
            let below = f - 15;
            let eval = evaluate_slo(&m, &e, &slo, &sb, &proj, below, SAFETY_SLACK_S);
            assert!(!eval.all_ok(), "f-15={below} should violate");
        }
        let eval = evaluate_slo(&m, &e, &slo, &sb, &proj, f, SAFETY_SLACK_S);
        assert!(eval.all_ok(), "chosen f={f} must satisfy");
    }

    #[test]
    fn lost_request_bypasses_search() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 200, 1e9));
        sb.mark_lost(1);
        let proj = project(&sb, 0, e.block_tokens);
        let f = min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0);
        assert_eq!(f, FREQ_MAX_MHZ);
    }

    #[test]
    fn empty_projection_defaults_to_max() {
        let (m, e, slo) = setup();
        let sb = Scoreboard::new();
        let proj = project(&sb, 0, e.block_tokens);
        assert_eq!(
            min_slo_frequency(&m, &e, &slo, &sb, &proj, 0.0, 1.0),
            FREQ_MAX_MHZ
        );
    }

    #[test]
    fn empty_grid_falls_back_to_max() {
        let (m, e, slo) = setup();
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 200, 1e9));
        let proj = project(&sb, 0, e.block_tokens);
        assert_eq!(
            min_slo_frequency_on_grid(&[], &m, &e, &slo, &sb, &proj, 0.0, 1.0),
            FREQ_MAX_MHZ
        );
    }

    #[test]
    fn single_entry_grid_returns_sole_setting() {
        let (m, e, slo) = setup();
        // Feasible at the sole setting (relaxed deadline).
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 200, 1e9));
        let proj = project(&sb, 0, e.block_tokens);
        assert_eq!(
            min_slo_frequency_on_grid(&[1410], &m, &e, &slo, &sb, &proj, 0.0, 1.0),
            1410
        );
        // Infeasible even at the sole setting (deadline long gone):
        // must still terminate and return it, not underflow or spin.
        let mut sb = Scoreboard::new();
        sb.insert(entry(2, 100, 600, 0.001));
        let proj = project(&sb, 0, e.block_tokens);
        assert_eq!(
            min_slo_frequency_on_grid(&[210], &m, &e, &slo, &sb, &proj, 0.0, 1.0),
            210
        );
    }

    #[test]
    fn two_entry_grid_picks_the_boundary() {
        let (m, e, slo) = setup();
        // ~600 iterations in 8 s needs near-peak frequency: 210 fails,
        // 1410 passes -> the search must settle on 1410 without looping.
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 600, 8.0));
        let proj = project(&sb, 0, e.block_tokens);
        assert_eq!(
            min_slo_frequency_on_grid(&[210, 1410], &m, &e, &slo, &sb, &proj, 0.0, 1.0),
            1410
        );
    }

    #[test]
    fn truncated_grid_clamps_to_its_top() {
        let (m, e, slo) = setup();
        // Infeasible deadline on a grid whose top is NOT the global
        // max: fall back to the grid's own top, not FREQ_MAX_MHZ.
        let mut sb = Scoreboard::new();
        sb.insert(entry(1, 100, 600, 0.001));
        let proj = project(&sb, 0, e.block_tokens);
        assert_eq!(
            min_slo_frequency_on_grid(
                &[210, 420, 630],
                &m,
                &e,
                &slo,
                &sb,
                &proj,
                0.0,
                1.0
            ),
            630
        );
    }
}
