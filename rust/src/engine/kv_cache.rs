//! Paged KV-cache block allocator (the vLLM/TensorRT-LLM "paged
//! attention" substrate, paper §II).
//!
//! Blocks hold `N = block_tokens` tokens.  A request occupying `t`
//! tokens holds `ceil(t / N)` blocks — exactly the quantity Eq. (1) of
//! the paper projects.  Blocks are recycled through a free list; the
//! allocator refuses to overcommit (the scheduler's KV-capacity check
//! exists to keep swapping from ever happening).

// Reviewed HashMap use: `held` is keyed lookup only on the serving
// path; the sole iterations live in `check_invariants` and are
// order-independent (see the detlint r2 allows there).
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;

use crate::engine::request::RequestId;

/// Number of blocks needed for `tokens` tokens with `block_tokens` N.
#[inline]
pub fn blocks_for(tokens: u32, block_tokens: u32) -> u32 {
    tokens.div_ceil(block_tokens)
}

/// Paged block allocator.
#[derive(Debug, Clone)]
pub struct KvAllocator {
    capacity_blocks: u32,
    block_tokens: u32,
    free: Vec<u32>,
    /// request -> (token count, owned block ids)
    held: HashMap<RequestId, (u32, Vec<u32>)>,
}

/// Allocation failure: capacity would be exceeded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvExhausted {
    pub need: u32,
    pub free: u32,
}

impl std::fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV cache exhausted: need {} blocks, {} free",
            self.need, self.free
        )
    }
}

impl std::error::Error for KvExhausted {}

impl KvAllocator {
    pub fn new(capacity_blocks: u32, block_tokens: u32) -> Self {
        assert!(block_tokens > 0);
        Self {
            capacity_blocks,
            block_tokens,
            free: (0..capacity_blocks).rev().collect(),
            held: HashMap::new(),
        }
    }

    pub fn capacity_blocks(&self) -> u32 {
        self.capacity_blocks
    }

    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn used_blocks(&self) -> u32 {
        self.capacity_blocks - self.free_blocks()
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    /// Blocks held by one request.
    pub fn blocks_of(&self, id: RequestId) -> u32 {
        self.held.get(&id).map(|(_, b)| b.len() as u32).unwrap_or(0)
    }

    /// Token occupancy registered for one request (the checkpoint /
    /// restore unit: restoring at this count re-allocates exactly the
    /// blocks the request held).
    pub fn tokens_of(&self, id: RequestId) -> Option<u32> {
        self.held.get(&id).map(|(t, _)| *t)
    }

    /// Register a request at `tokens` occupancy (prompt after prefill).
    pub fn allocate(&mut self, id: RequestId, tokens: u32) -> Result<(), KvExhausted> {
        assert!(
            !self.held.contains_key(&id),
            "request {id} already allocated"
        );
        let need = blocks_for(tokens, self.block_tokens);
        if need > self.free_blocks() {
            return Err(KvExhausted {
                need,
                free: self.free_blocks(),
            });
        }
        let blocks = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.held.insert(id, (tokens, blocks));
        Ok(())
    }

    /// Grow a request to `tokens` total (decode appends one token per
    /// iteration; a new block is taken only on boundary crossings).
    pub fn grow_to(&mut self, id: RequestId, tokens: u32) -> Result<(), KvExhausted> {
        let (cur, blocks) = self
            .held
            .get_mut(&id)
            .unwrap_or_else(|| panic!("grow of unknown request {id}"));
        assert!(tokens >= *cur, "KV shrink not supported");
        let need_total = blocks_for(tokens, self.block_tokens);
        let extra = need_total.saturating_sub(blocks.len() as u32);
        if extra > self.free.len() as u32 {
            return Err(KvExhausted {
                need: extra,
                free: self.free.len() as u32,
            });
        }
        for _ in 0..extra {
            blocks.push(self.free.pop().unwrap());
        }
        *cur = tokens;
        Ok(())
    }

    /// Release every block of a completed request.
    pub fn release(&mut self, id: RequestId) {
        if let Some((_, blocks)) = self.held.remove(&id) {
            self.free.extend(blocks);
        }
    }

    /// Invariant check (used by property tests): no block is both free
    /// and held, and accounting adds up.
    pub fn check_invariants(&self) {
        // detlint: allow(r2, reason = "a sum over map values is commutative; iteration order cannot affect the assert")
        let held: u32 = self.held.values().map(|(_, b)| b.len() as u32).sum();
        assert_eq!(held + self.free_blocks(), self.capacity_blocks);
        let mut seen = vec![false; self.capacity_blocks as usize];
        for b in &self.free {
            assert!(!seen[*b as usize], "block {b} double-owned");
            seen[*b as usize] = true;
        }
        // detlint: allow(r2, reason = "double-ownership scan marks each block once; the verdict is order-independent")
        for (_id, (_tokens, blocks)) in &self.held {
            for b in blocks {
                assert!(!seen[*b as usize], "block {b} double-owned");
                seen[*b as usize] = true;
            }
        }
        // detlint: allow(r2, reason = "per-entry assert touches each request independently; order cannot affect the verdict")
        for (id, (tokens, blocks)) in &self.held {
            assert_eq!(
                blocks.len() as u32,
                blocks_for(*tokens, self.block_tokens),
                "request {id} block count mismatch"
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Pcg64;

    #[test]
    fn blocks_for_rounds_up() {
        assert_eq!(blocks_for(0, 64), 0);
        assert_eq!(blocks_for(1, 64), 1);
        assert_eq!(blocks_for(64, 64), 1);
        assert_eq!(blocks_for(65, 64), 2);
    }

    #[test]
    fn tokens_of_tracks_occupancy() {
        let mut kv = KvAllocator::new(10, 64);
        assert_eq!(kv.tokens_of(1), None);
        kv.allocate(1, 100).unwrap();
        assert_eq!(kv.tokens_of(1), Some(100));
        kv.grow_to(1, 130).unwrap();
        assert_eq!(kv.tokens_of(1), Some(130));
        kv.release(1);
        assert_eq!(kv.tokens_of(1), None);
    }

    #[test]
    fn allocate_grow_release_roundtrip() {
        let mut kv = KvAllocator::new(10, 64);
        kv.allocate(1, 100).unwrap(); // 2 blocks
        assert_eq!(kv.used_blocks(), 2);
        kv.grow_to(1, 128).unwrap(); // still 2
        assert_eq!(kv.used_blocks(), 2);
        kv.grow_to(1, 129).unwrap(); // 3
        assert_eq!(kv.used_blocks(), 3);
        kv.release(1);
        assert_eq!(kv.used_blocks(), 0);
        kv.check_invariants();
    }

    #[test]
    fn refuses_overcommit() {
        let mut kv = KvAllocator::new(2, 64);
        kv.allocate(1, 128).unwrap();
        assert!(kv.allocate(2, 1).is_err());
        kv.check_invariants();
        // failed allocation must not leak state
        kv.release(1);
        kv.allocate(2, 1).unwrap();
    }

    #[test]
    fn grow_failure_keeps_state() {
        let mut kv = KvAllocator::new(2, 64);
        kv.allocate(1, 64).unwrap();
        kv.allocate(2, 64).unwrap();
        assert!(kv.grow_to(1, 65).is_err());
        assert_eq!(kv.blocks_of(1), 1);
        kv.check_invariants();
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut kv = KvAllocator::new(4, 64);
        kv.release(99);
        kv.check_invariants();
    }

    /// Property test: random alloc/grow/release interleavings preserve
    /// allocator invariants (proptest substitute; see testutil).
    #[test]
    fn random_interleavings_preserve_invariants() {
        for seed in 0..20 {
            let mut rng = Pcg64::new(seed);
            let mut kv = KvAllocator::new(64, 16);
            let mut live: Vec<(RequestId, u32)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..500 {
                match rng.uniform_u64(0, 2) {
                    0 => {
                        let tokens = rng.uniform_u64(1, 200) as u32;
                        if kv.allocate(next_id, tokens).is_ok() {
                            live.push((next_id, tokens));
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let i = rng.uniform_usize(0, live.len() - 1);
                        let (id, t) = live[i];
                        let nt = t + rng.uniform_u64(1, 40) as u32;
                        if kv.grow_to(id, nt).is_ok() {
                            live[i].1 = nt;
                        }
                    }
                    _ if !live.is_empty() => {
                        let i = rng.uniform_usize(0, live.len() - 1);
                        kv.release(live.swap_remove(i).0);
                    }
                    _ => {}
                }
                kv.check_invariants();
            }
        }
    }
}
