//! Paged KV-cache block allocator (the vLLM/TensorRT-LLM "paged
//! attention" substrate, paper §II), with ref-counted copy-on-write
//! prefix sharing.
//!
//! Blocks hold `N = block_tokens` tokens.  A request occupying `t`
//! tokens holds `ceil(t / N)` blocks — exactly the quantity Eq. (1) of
//! the paper projects.  Blocks are recycled through a free list; the
//! allocator refuses to overcommit (the scheduler's KV-capacity check
//! exists to keep swapping from ever happening).
//!
//! ## Prefix sharing
//!
//! Requests carrying the same nonzero *prefix group* (a common system
//! prompt in a session workload) can share the FULL blocks of that
//! prefix: the first member pays for them ([`KvAllocator::share`]),
//! later members bump a ref count instead of allocating
//! ([`KvAllocator::allocate_in_group`]).  Only whole blocks are shared
//! — the prefix's trailing partial block would be written past by each
//! member's own tokens, so it stays private (block-granular CoW, as in
//! vLLM's prefix caching).  [`KvAllocator::release`] decrements the
//! group ref count and the LAST owner returns the shared blocks to the
//! free list; [`KvAllocator::fork`] detaches one member by copying the
//! shared blocks into private ones (live migration "copies, not
//! steals" — the departing resident takes a copy while co-residents
//! keep the original).
//!
//! A run that never calls the sharing API leaves `shared` empty and
//! pops the free list in exactly the pre-sharing order — the
//! `--prefix-share off` byte-identity contract (pinned by the
//! `sharing_off_is_bit_identical_to_the_pre_fork_allocator` property
//! test in `tests/kv_prefix.rs`).

// Reviewed HashMap use: `held` and `shared` are keyed lookup only on
// the serving path; the sole iterations live in `check_invariants` and
// are order-independent (see the detlint r2 allows there).
#![allow(clippy::disallowed_types)]

use std::collections::HashMap;

use crate::engine::request::RequestId;

/// Number of blocks needed for `tokens` tokens with `block_tokens` N.
#[inline]
pub fn blocks_for(tokens: u32, block_tokens: u32) -> u32 {
    tokens.div_ceil(block_tokens)
}

/// One request's holding: its registered token occupancy and the
/// blocks it PRIVATELY owns.  Members of a prefix group additionally
/// reference `group`'s shared blocks, which are not listed here.
#[derive(Debug, Clone)]
struct Held {
    tokens: u32,
    blocks: Vec<u32>,
    /// Prefix group whose shared blocks this request references
    /// (0 = none).
    group: u64,
}

/// A shared prefix: the full blocks of a common prompt prefix, owned
/// jointly by `refs` live requests.
#[derive(Debug, Clone)]
struct SharedPrefix {
    /// Prefix length in tokens (the shared part covers
    /// `blocks.len() * block_tokens` of these; the remainder lives in
    /// each member's private tail).
    tokens: u32,
    blocks: Vec<u32>,
    refs: u32,
}

/// Paged block allocator.
#[derive(Debug, Clone)]
pub struct KvAllocator {
    capacity_blocks: u32,
    block_tokens: u32,
    free: Vec<u32>,
    held: HashMap<RequestId, Held>,
    /// prefix group -> shared full-block prefix (absent when no member
    /// is resident; empty for sharing-off runs).
    shared: HashMap<u64, SharedPrefix>,
}

/// Allocation failure: capacity would be exceeded.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvExhausted {
    pub need: u32,
    pub free: u32,
}

impl std::fmt::Display for KvExhausted {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "KV cache exhausted: need {} blocks, {} free",
            self.need, self.free
        )
    }
}

impl std::error::Error for KvExhausted {}

impl KvAllocator {
    pub fn new(capacity_blocks: u32, block_tokens: u32) -> Self {
        assert!(block_tokens > 0);
        Self {
            capacity_blocks,
            block_tokens,
            free: (0..capacity_blocks).rev().collect(),
            held: HashMap::new(),
            shared: HashMap::new(),
        }
    }

    pub fn capacity_blocks(&self) -> u32 {
        self.capacity_blocks
    }

    pub fn free_blocks(&self) -> u32 {
        self.free.len() as u32
    }

    pub fn used_blocks(&self) -> u32 {
        self.capacity_blocks - self.free_blocks()
    }

    pub fn block_tokens(&self) -> u32 {
        self.block_tokens
    }

    /// The free list, top of stack last (test observability: the
    /// sharing-off identity property compares this against the
    /// pre-fork allocator's evolution step by step).
    pub fn free_list(&self) -> &[u32] {
        &self.free
    }

    /// Blocks PRIVATELY held by one request (shared prefix blocks it
    /// references are counted by [`Self::shared_blocks_of_group`]).
    pub fn blocks_of(&self, id: RequestId) -> u32 {
        self.held.get(&id).map(|h| h.blocks.len() as u32).unwrap_or(0)
    }

    /// Token occupancy registered for one request (the checkpoint /
    /// restore unit: restoring at this count re-allocates exactly the
    /// blocks the request held).
    pub fn tokens_of(&self, id: RequestId) -> Option<u32> {
        self.held.get(&id).map(|h| h.tokens)
    }

    /// Prefix group a held request references (0 = none).
    pub fn group_of(&self, id: RequestId) -> u64 {
        self.held.get(&id).map(|h| h.group).unwrap_or(0)
    }

    /// Resident shared full blocks of a prefix group (0 when absent).
    pub fn shared_blocks_of_group(&self, group: u64) -> u32 {
        self.shared.get(&group).map(|s| s.blocks.len() as u32).unwrap_or(0)
    }

    /// Registered prefix length of a resident group, tokens.
    pub fn shared_tokens_of_group(&self, group: u64) -> Option<u32> {
        self.shared.get(&group).map(|s| s.tokens)
    }

    /// How many blocks a NEW member of `group` at `tokens` occupancy
    /// would actually need from the free list: the full prefix blocks
    /// are free when the group is already resident.
    pub fn blocks_needed(&self, tokens: u32, group: u64, prefix_tokens: u32) -> u32 {
        let total = blocks_for(tokens, self.block_tokens);
        if group == 0 {
            return total;
        }
        let nshare = (prefix_tokens.min(tokens)) / self.block_tokens;
        if self.shared.contains_key(&group) {
            total - nshare.min(total)
        } else {
            total
        }
    }

    /// Register a request at `tokens` occupancy (prompt after prefill).
    pub fn allocate(&mut self, id: RequestId, tokens: u32) -> Result<(), KvExhausted> {
        assert!(
            !self.held.contains_key(&id),
            "request {id} already allocated"
        );
        let need = blocks_for(tokens, self.block_tokens);
        if need > self.free_blocks() {
            return Err(KvExhausted {
                need,
                free: self.free_blocks(),
            });
        }
        let blocks = (0..need).map(|_| self.free.pop().unwrap()).collect();
        self.held.insert(
            id,
            Held {
                tokens,
                blocks,
                group: 0,
            },
        );
        Ok(())
    }

    /// Take (or join) the shared full-block prefix of `group`: the
    /// first caller allocates `prefix_tokens / N` blocks, later
    /// callers bump the ref count.  Returns the number of shared
    /// blocks.  All members of a group must agree on `prefix_tokens`.
    pub fn share(&mut self, group: u64, prefix_tokens: u32) -> Result<u32, KvExhausted> {
        assert!(group != 0, "group 0 is reserved for ungrouped requests");
        if let Some(s) = self.shared.get_mut(&group) {
            assert_eq!(
                s.tokens, prefix_tokens,
                "prefix group {group} joined with a different prefix length"
            );
            s.refs += 1;
            return Ok(s.blocks.len() as u32);
        }
        let nshare = prefix_tokens / self.block_tokens;
        if nshare > self.free.len() as u32 {
            return Err(KvExhausted {
                need: nshare,
                free: self.free.len() as u32,
            });
        }
        let blocks: Vec<u32> = (0..nshare).map(|_| self.free.pop().unwrap()).collect();
        self.shared.insert(
            group,
            SharedPrefix {
                tokens: prefix_tokens,
                blocks,
                refs: 1,
            },
        );
        Ok(nshare)
    }

    /// Register a request at `tokens` occupancy as a member of
    /// `group`, sharing the group's full prefix blocks.  Returns the
    /// number of blocks shared (what admission saved).  Atomic: on
    /// exhaustion nothing changes.
    pub fn allocate_in_group(
        &mut self,
        id: RequestId,
        tokens: u32,
        group: u64,
        prefix_tokens: u32,
    ) -> Result<u32, KvExhausted> {
        assert!(
            !self.held.contains_key(&id),
            "request {id} already allocated"
        );
        assert!(group != 0, "use allocate() for ungrouped requests");
        assert!(
            prefix_tokens <= tokens,
            "shared prefix ({prefix_tokens}) longer than occupancy ({tokens})"
        );
        let total = blocks_for(tokens, self.block_tokens);
        let nshare = prefix_tokens / self.block_tokens;
        let priv_need = total - nshare.min(total);
        let share_need = if self.shared.contains_key(&group) {
            0
        } else {
            nshare
        };
        if priv_need + share_need > self.free.len() as u32 {
            return Err(KvExhausted {
                need: priv_need + share_need,
                free: self.free.len() as u32,
            });
        }
        let nshare = self.share(group, prefix_tokens).expect("checked above");
        let blocks = (0..priv_need).map(|_| self.free.pop().unwrap()).collect();
        self.held.insert(
            id,
            Held {
                tokens,
                blocks,
                group,
            },
        );
        Ok(nshare)
    }

    /// Grow a request to `tokens` total (decode appends one token per
    /// iteration; a new block is taken only on boundary crossings).
    /// Growth is always private — the shared prefix never grows.
    pub fn grow_to(&mut self, id: RequestId, tokens: u32) -> Result<(), KvExhausted> {
        let shared_len = {
            let h = self
                .held
                .get(&id)
                .unwrap_or_else(|| panic!("grow of unknown request {id}"));
            if h.group == 0 {
                0
            } else {
                self.shared_blocks_of_group(h.group)
            }
        };
        let h = self.held.get_mut(&id).unwrap();
        assert!(tokens >= h.tokens, "KV shrink not supported");
        let need_total = blocks_for(tokens, self.block_tokens);
        let extra = need_total.saturating_sub(shared_len + h.blocks.len() as u32);
        if extra > self.free.len() as u32 {
            return Err(KvExhausted {
                need: extra,
                free: self.free.len() as u32,
            });
        }
        for _ in 0..extra {
            h.blocks.push(self.free.pop().unwrap());
        }
        h.tokens = tokens;
        Ok(())
    }

    /// Detach a group member from its shared prefix by COPYING the
    /// shared blocks into private ones (copy-on-write fork: used when
    /// a resident leaves via checkpoint/migration while co-residents
    /// keep the original).  No-op for ungrouped requests.  Atomic on
    /// exhaustion.
    pub fn fork(&mut self, id: RequestId) -> Result<(), KvExhausted> {
        let (group, nshare) = {
            let h = self
                .held
                .get(&id)
                .unwrap_or_else(|| panic!("fork of unknown request {id}"));
            if h.group == 0 {
                return Ok(());
            }
            (h.group, self.shared_blocks_of_group(h.group))
        };
        if nshare > self.free.len() as u32 {
            return Err(KvExhausted {
                need: nshare,
                free: self.free.len() as u32,
            });
        }
        let mut copies: Vec<u32> = (0..nshare).map(|_| self.free.pop().unwrap()).collect();
        let h = self.held.get_mut(&id).unwrap();
        // The copied prefix blocks lead, mirroring token order.
        copies.extend(h.blocks.iter().copied());
        h.blocks = copies;
        h.group = 0;
        self.deref_group(group);
        Ok(())
    }

    fn deref_group(&mut self, group: u64) {
        let s = self
            .shared
            .get_mut(&group)
            .unwrap_or_else(|| panic!("deref of absent prefix group {group}"));
        s.refs -= 1;
        if s.refs == 0 {
            let s = self.shared.remove(&group).unwrap();
            self.free.extend(s.blocks);
        }
    }

    /// Release every block of a completed request.  The group ref
    /// count drops with it; the LAST member frees the shared prefix.
    pub fn release(&mut self, id: RequestId) {
        if let Some(h) = self.held.remove(&id) {
            self.free.extend(h.blocks);
            if h.group != 0 {
                self.deref_group(h.group);
            }
        }
    }

    /// Invariant check (used by property tests): no block is both free
    /// and held/shared, accounting adds up, and group ref counts match
    /// the membership.
    pub fn check_invariants(&self) {
        // detlint: allow(r2, reason = "a sum over map values is commutative; iteration order cannot affect the assert")
        let held: u32 = self.held.values().map(|h| h.blocks.len() as u32).sum();
        // detlint: allow(r2, reason = "a sum over map values is commutative; iteration order cannot affect the assert")
        let shared: u32 = self.shared.values().map(|s| s.blocks.len() as u32).sum();
        assert_eq!(held + shared + self.free_blocks(), self.capacity_blocks);
        let mut seen = vec![false; self.capacity_blocks as usize];
        for b in &self.free {
            assert!(!seen[*b as usize], "block {b} double-owned");
            seen[*b as usize] = true;
        }
        // detlint: allow(r2, reason = "double-ownership scan marks each block once; the verdict is order-independent")
        for (_id, h) in &self.held {
            for b in &h.blocks {
                assert!(!seen[*b as usize], "block {b} double-owned");
                seen[*b as usize] = true;
            }
        }
        // detlint: allow(r2, reason = "double-ownership scan marks each block once; the verdict is order-independent")
        for (_g, s) in &self.shared {
            for b in &s.blocks {
                assert!(!seen[*b as usize], "block {b} double-owned");
                seen[*b as usize] = true;
            }
        }
        // detlint: allow(r2, reason = "per-entry assert touches each request independently; order cannot affect the verdict")
        for (id, h) in &self.held {
            let shared_len = if h.group == 0 {
                0
            } else {
                let s = self
                    .shared
                    .get(&h.group)
                    .unwrap_or_else(|| panic!("request {id} references absent group {}", h.group));
                assert!(s.refs > 0, "group {} resident with zero refs", h.group);
                s.blocks.len() as u32
            };
            assert_eq!(
                shared_len + h.blocks.len() as u32,
                blocks_for(h.tokens, self.block_tokens),
                "request {id} block count mismatch"
            );
        }
        // detlint: allow(r2, reason = "per-group assert compares a count computed from the full membership; order cannot affect the verdict")
        for (g, s) in &self.shared {
            assert!(s.refs > 0, "group {g} resident with zero refs");
            // detlint: allow(r2, reason = "a membership count over map values is commutative")
            let members = self.held.values().filter(|h| h.group == *g).count() as u32;
            assert_eq!(
                s.refs, members,
                "group {g} ref count {} != membership {members}",
                s.refs
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Pcg64;

    #[test]
    fn blocks_for_rounds_up() {
        assert_eq!(blocks_for(0, 64), 0);
        assert_eq!(blocks_for(1, 64), 1);
        assert_eq!(blocks_for(64, 64), 1);
        assert_eq!(blocks_for(65, 64), 2);
    }

    #[test]
    fn tokens_of_tracks_occupancy() {
        let mut kv = KvAllocator::new(10, 64);
        assert_eq!(kv.tokens_of(1), None);
        kv.allocate(1, 100).unwrap();
        assert_eq!(kv.tokens_of(1), Some(100));
        kv.grow_to(1, 130).unwrap();
        assert_eq!(kv.tokens_of(1), Some(130));
        kv.release(1);
        assert_eq!(kv.tokens_of(1), None);
    }

    #[test]
    fn allocate_grow_release_roundtrip() {
        let mut kv = KvAllocator::new(10, 64);
        kv.allocate(1, 100).unwrap(); // 2 blocks
        assert_eq!(kv.used_blocks(), 2);
        kv.grow_to(1, 128).unwrap(); // still 2
        assert_eq!(kv.used_blocks(), 2);
        kv.grow_to(1, 129).unwrap(); // 3
        assert_eq!(kv.used_blocks(), 3);
        kv.release(1);
        assert_eq!(kv.used_blocks(), 0);
        kv.check_invariants();
    }

    #[test]
    fn refuses_overcommit() {
        let mut kv = KvAllocator::new(2, 64);
        kv.allocate(1, 128).unwrap();
        assert!(kv.allocate(2, 1).is_err());
        kv.check_invariants();
        // failed allocation must not leak state
        kv.release(1);
        kv.allocate(2, 1).unwrap();
    }

    #[test]
    fn grow_failure_keeps_state() {
        let mut kv = KvAllocator::new(2, 64);
        kv.allocate(1, 64).unwrap();
        kv.allocate(2, 64).unwrap();
        assert!(kv.grow_to(1, 65).is_err());
        assert_eq!(kv.blocks_of(1), 1);
        kv.check_invariants();
    }

    #[test]
    fn release_unknown_is_noop() {
        let mut kv = KvAllocator::new(4, 64);
        kv.release(99);
        kv.check_invariants();
    }

    #[test]
    fn shared_prefix_is_paid_once() {
        let mut kv = KvAllocator::new(20, 64);
        // 256-token prefix = 4 full blocks; each member adds its own
        // tail.  320 tokens total -> 5 blocks, 4 of them shared.
        let n = kv.allocate_in_group(1, 320, 7, 256).unwrap();
        assert_eq!(n, 4);
        assert_eq!(kv.used_blocks(), 5);
        assert_eq!(kv.blocks_of(1), 1);
        let n = kv.allocate_in_group(2, 320, 7, 256).unwrap();
        assert_eq!(n, 4);
        // Second member only pays its private tail.
        assert_eq!(kv.used_blocks(), 6);
        assert_eq!(kv.shared_blocks_of_group(7), 4);
        kv.check_invariants();
        // Unshared would have cost 10 blocks.
        assert_eq!(kv.blocks_needed(320, 7, 256), 1);
        assert_eq!(kv.blocks_needed(320, 8, 256), 5);
    }

    #[test]
    fn partial_prefix_block_stays_private() {
        let mut kv = KvAllocator::new(20, 64);
        // 100-token prefix: only 1 full block shared, the 36-token
        // tail is in each member's private part.
        kv.allocate_in_group(1, 150, 3, 100).unwrap();
        assert_eq!(kv.shared_blocks_of_group(3), 1);
        assert_eq!(kv.blocks_of(1), blocks_for(150, 64) - 1);
        kv.check_invariants();
    }

    #[test]
    fn last_owner_frees_the_prefix() {
        let mut kv = KvAllocator::new(20, 64);
        kv.allocate_in_group(1, 256, 5, 256).unwrap();
        kv.allocate_in_group(2, 300, 5, 256).unwrap();
        kv.release(1);
        // Prefix survives the first release...
        assert_eq!(kv.shared_blocks_of_group(5), 4);
        kv.check_invariants();
        kv.release(2);
        // ...and the last owner frees it.
        assert_eq!(kv.shared_blocks_of_group(5), 0);
        assert_eq!(kv.used_blocks(), 0);
        kv.check_invariants();
    }

    #[test]
    fn growth_is_private() {
        let mut kv = KvAllocator::new(20, 64);
        kv.allocate_in_group(1, 256, 5, 256).unwrap();
        kv.allocate_in_group(2, 256, 5, 256).unwrap();
        let used = kv.used_blocks();
        kv.grow_to(1, 257).unwrap();
        assert_eq!(kv.used_blocks(), used + 1);
        assert_eq!(kv.shared_blocks_of_group(5), 4);
        kv.check_invariants();
        kv.release(1);
        kv.release(2);
        assert_eq!(kv.used_blocks(), 0);
    }

    #[test]
    fn fork_copies_not_steals() {
        let mut kv = KvAllocator::new(20, 64);
        kv.allocate_in_group(1, 300, 5, 256).unwrap();
        kv.allocate_in_group(2, 300, 5, 256).unwrap();
        let used = kv.used_blocks();
        kv.fork(1).unwrap();
        // The forked member now owns private copies of all 4 prefix
        // blocks; the co-resident keeps the shared original.
        assert_eq!(kv.used_blocks(), used + 4);
        assert_eq!(kv.group_of(1), 0);
        assert_eq!(kv.blocks_of(1), blocks_for(300, 64));
        assert_eq!(kv.shared_blocks_of_group(5), 4);
        kv.check_invariants();
        // Releasing the forked copy leaves the shared prefix intact.
        kv.release(1);
        assert_eq!(kv.shared_blocks_of_group(5), 4);
        kv.check_invariants();
        // Forking the LAST member frees the shared original.
        kv.fork(2).unwrap();
        assert_eq!(kv.shared_blocks_of_group(5), 0);
        kv.check_invariants();
    }

    #[test]
    fn fork_of_solo_request_is_noop() {
        let mut kv = KvAllocator::new(4, 64);
        kv.allocate(1, 64).unwrap();
        let free_before = kv.free_list().to_vec();
        kv.fork(1).unwrap();
        assert_eq!(kv.free_list(), &free_before[..]);
        kv.check_invariants();
    }

    #[test]
    fn group_allocation_failures_are_atomic() {
        let mut kv = KvAllocator::new(5, 64);
        // 4-block prefix + 1 private fits exactly...
        kv.allocate_in_group(1, 320, 9, 256).unwrap();
        // ...a second member's private tail does not.
        let before = kv.free_list().to_vec();
        assert!(kv.allocate_in_group(2, 320, 9, 256).is_err());
        assert_eq!(kv.free_list(), &before[..]);
        assert_eq!(kv.shared_blocks_of_group(9), 4);
        kv.check_invariants();
        // fork with no free blocks also fails atomically.
        assert!(kv.fork(1).is_err());
        assert_eq!(kv.group_of(1), 9);
        kv.check_invariants();
    }

    /// Property test: random alloc/grow/release interleavings preserve
    /// allocator invariants (proptest substitute; see testutil).
    #[test]
    fn random_interleavings_preserve_invariants() {
        for seed in 0..20 {
            let mut rng = Pcg64::new(seed);
            let mut kv = KvAllocator::new(64, 16);
            let mut live: Vec<(RequestId, u32)> = vec![];
            let mut next_id = 0u64;
            for _ in 0..500 {
                match rng.uniform_u64(0, 2) {
                    0 => {
                        let tokens = rng.uniform_u64(1, 200) as u32;
                        if kv.allocate(next_id, tokens).is_ok() {
                            live.push((next_id, tokens));
                        }
                        next_id += 1;
                    }
                    1 if !live.is_empty() => {
                        let i = rng.uniform_usize(0, live.len() - 1);
                        let (id, t) = live[i];
                        let nt = t + rng.uniform_u64(1, 40) as u32;
                        if kv.grow_to(id, nt).is_ok() {
                            live[i].1 = nt;
                        }
                    }
                    _ if !live.is_empty() => {
                        let i = rng.uniform_usize(0, live.len() - 1);
                        kv.release(live.swap_remove(i).0);
                    }
                    _ => {}
                }
                kv.check_invariants();
            }
        }
    }
}
