//! LLM inference engine substrate: paged KV cache, inflight (fused)
//! batching, iteration-level execution.
//!
//! The engine mirrors the observable behaviour of Triton+TensorRT-LLM
//! (the paper's backend): requests enter/leave the running batch at
//! iteration boundaries (inflight batching, Orca-style), each request
//! holds `ceil((prompt + generated)/N)` KV blocks (paged attention),
//! a newly admitted request's prefill runs fused with the next
//! iteration and stalls decoding (the paper's explanation for TBT
//! outliers), and per-iteration timing/power comes from `gpusim`.
//!
//! The coordinator (both throttLL'eM and the Triton baseline) drives
//! `EngineSim::run_iteration` from its event loop and observes exactly
//! what Triton's metrics endpoint would expose: batch size, KV usage,
//! and iteration latency.

pub mod kv_cache;
pub mod request;
pub mod sim;

pub use kv_cache::KvAllocator;
pub use request::{Request, RequestId, RequestOutcome};
pub use sim::{EngineSim, IterationReport, KvCheckpoint, ResidentInfo};
