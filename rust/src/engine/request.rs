//! Request model shared by the workload generator, engine and
//! coordinator.

/// Unique request identifier.
pub type RequestId = u64;

/// An inference request (lengths in tokens, times in seconds).
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: RequestId,
    /// Prompt length |q_i| (known on arrival after tokenization).
    pub prompt_tokens: u32,
    /// ACTUAL generation length — ground truth from the trace; hidden
    /// from the coordinator, which only sees the predictor's estimate.
    pub gen_tokens: u32,
    /// Predicted generation length |r̂_i| (predictor output, possibly
    /// conservatively inflated — paper §IV-F).
    pub predicted_gen: u32,
    /// Arrival time.
    pub arrival_s: f64,
    /// Prefix-sharing group (0 = none).  Requests with the same
    /// nonzero group share the KV of their first
    /// `shared_prefix_tokens` prompt tokens — a common system prompt
    /// in a multi-turn session workload.
    pub prefix_group: u64,
    /// Length of the shared prefix in tokens (0 when ungrouped).
    /// Always <= `prompt_tokens`.
    pub shared_prefix_tokens: u32,
}

impl Request {
    /// Total KV tokens the request will occupy when fully generated.
    pub fn total_tokens(&self) -> u32 {
        self.prompt_tokens + self.gen_tokens
    }

    /// An ungrouped request (no shared prefix) — the construction every
    /// single-shot workload uses.
    pub fn solo(
        id: RequestId,
        prompt_tokens: u32,
        gen_tokens: u32,
        predicted_gen: u32,
        arrival_s: f64,
    ) -> Self {
        Self {
            id,
            prompt_tokens,
            gen_tokens,
            predicted_gen,
            arrival_s,
            prefix_group: 0,
            shared_prefix_tokens: 0,
        }
    }
}

/// Completion record with everything the evaluation needs.
#[derive(Debug, Clone)]
pub struct RequestOutcome {
    pub id: RequestId,
    pub prompt_tokens: u32,
    pub gen_tokens: u32,
    pub arrival_s: f64,
    /// When the scheduler admitted it to the engine.
    pub scheduled_s: f64,
    /// Time to first token (arrival -> end of its prefill iteration).
    pub ttft_s: f64,
    /// End-to-end latency (arrival -> last token).
    pub e2e_s: f64,
    /// Mean time between tokens over the generation phase.
    pub tbt_avg_s: f64,
    /// Whether the scheduler marked it "lost" (own E2E SLO unmeetable
    /// at admission; excluded from later SLO validations — §IV-C2).
    pub lost: bool,
}

impl RequestOutcome {
    /// Queueing delay before admission.
    pub fn queue_s(&self) -> f64 {
        self.scheduled_s - self.arrival_s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn total_tokens_sums_phases() {
        let r = Request::solo(1, 100, 50, 60, 0.0);
        assert_eq!(r.total_tokens(), 150);
    }

    #[test]
    fn queue_delay() {
        let o = RequestOutcome {
            id: 1,
            prompt_tokens: 10,
            gen_tokens: 10,
            arrival_s: 1.0,
            scheduled_s: 1.5,
            ttft_s: 0.7,
            e2e_s: 3.0,
            tbt_avg_s: 0.02,
            lost: false,
        };
        assert!((o.queue_s() - 0.5).abs() < 1e-12);
    }
}
