//! Iteration-level engine simulator with inflight fused batching.
//!
//! Semantics mirrored from Triton + TensorRT-LLM (paper §II, §V-A):
//!   * requests join/leave the batch only at iteration boundaries;
//!   * a newly admitted request's prefill runs fused with the next
//!     iteration, stalling decode for everyone (TBT outliers, §V-D1);
//!   * each live row generates one token per iteration and holds
//!     `ceil((prompt + generated) / N)` KV blocks;
//!   * if the KV pool is exhausted mid-generation (possible only under
//!     length mispredictions), affected rows STALL — they stop
//!     generating until blocks free up, modelling the severe
//!     degradation the paper's KV-capacity admission check exists to
//!     prevent;
//!   * iteration duration and power come from `gpusim`, at the
//!     frequency the DVFS actuator has made effective.

use crate::config::EngineSpec;
use crate::engine::kv_cache::KvAllocator;
use crate::engine::request::{Request, RequestId, RequestOutcome};
use crate::gpusim::dvfs::DvfsActuator;
use crate::gpusim::latency::{decode_latency_s, prefill_latency_s, GpuState};
use crate::gpusim::power::{idle_power_w, power_w};

/// A request resident in the engine.
#[derive(Debug, Clone)]
struct Active {
    req: Request,
    scheduled_iter: u64,
    scheduled_s: f64,
    /// Tokens generated so far (first token produced by prefill).
    generated: u32,
    prefill_pending: bool,
    /// Prompt tokens the prefill actually computes.  Equal to
    /// `req.prompt_tokens` except when a shared prefix was already
    /// resident at admission: prefix caching skips recomputing the
    /// cached tokens' KV (vLLM/SGLang prefix-cache semantics), so the
    /// fused prefill stall shrinks accordingly.
    prefill_tokens: u32,
    /// Absolute time of the first token (set by the prefill iteration).
    first_token_s: Option<f64>,
    lost: bool,
    /// Stalled by KV exhaustion in the previous iteration.
    stalled: bool,
    /// Live-migration transfer stall: until this instant the row holds
    /// its KV blocks (and counts in the batch) but produces no token —
    /// the KV pages are still streaming in from the source replica.
    /// 0.0 for every non-migrated request.
    resume_at_s: f64,
}

/// Public per-request view for the coordinator's scoreboard sync.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ActiveInfo {
    pub id: RequestId,
    pub scheduled_iter: u64,
    pub prompt_tokens: u32,
    pub generated: u32,
    pub predicted_gen: u32,
    pub lost: bool,
}

/// Serialized state of one resident request: its KV block ownership
/// (as a token occupancy — restoring re-allocates exactly the blocks
/// held) plus generation progress.  The unit of live migration: a
/// checkpoint taken on one [`EngineSim`] restores onto another with
/// re-allocation, preserving every latency-relevant timestamp so the
/// request's outcome metrics (TTFT, E2E, queue time) stay continuous
/// across the move.
#[derive(Debug, Clone, PartialEq)]
pub struct KvCheckpoint {
    pub req: Request,
    /// When the scheduler originally admitted the request.
    pub scheduled_s: f64,
    /// Tokens generated so far.
    pub generated: u32,
    /// Prefill had not run yet (the prompt KV does not exist; a
    /// restore re-runs prefill on the destination).
    pub prefill_pending: bool,
    pub first_token_s: Option<f64>,
    pub lost: bool,
    /// Token occupancy registered in the KV allocator at checkpoint
    /// time — what the destination must re-allocate.
    pub kv_tokens: u32,
}

impl KvCheckpoint {
    /// Blocks the checkpoint occupies on an engine with `block_tokens`
    /// tokens per block (the restore-side capacity requirement and the
    /// transfer-cost input).
    pub fn blocks(&self, block_tokens: u32) -> u32 {
        crate::engine::kv_cache::blocks_for(self.kv_tokens, block_tokens)
    }
}

/// Coordinator-visible snapshot of one resident request (migration
/// candidate enumeration).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResidentInfo {
    pub id: RequestId,
    pub prompt_tokens: u32,
    pub generated: u32,
    pub prefill_pending: bool,
    pub lost: bool,
    /// Token occupancy registered in the KV allocator.
    pub kv_tokens: u32,
}

/// What happened during one engine iteration.
#[derive(Debug, Clone)]
pub struct IterationReport {
    pub iter_index: u64,
    pub start_s: f64,
    pub duration_s: f64,
    /// Rows that were decoding this iteration.
    pub batch: u32,
    /// KV blocks allocated at the START of the iteration.
    pub kv_blocks: u32,
    pub freq_mhz: u32,
    pub power_w: f64,
    pub energy_j: f64,
    /// Number of fused prefills in this iteration.
    pub prefills: u32,
    /// Tokens emitted (decode rows that actually advanced + prefills).
    pub tokens: u32,
    /// Requests that finished in this iteration.
    pub completed: Vec<RequestOutcome>,
    /// Rows stalled by KV exhaustion this iteration.
    pub stalled: u32,
    /// Rows holding KV but still mid-migration-transfer (no token).
    pub in_transit: u32,
    /// Requests preempted to break a total KV deadlock (vLLM-style
    /// recompute preemption): their blocks are released and the caller
    /// must re-queue them (they re-run prefill from scratch).
    pub evicted: Vec<Request>,
}

/// The engine simulator.
#[derive(Debug)]
pub struct EngineSim {
    spec: EngineSpec,
    pub dvfs: DvfsActuator,
    kv: KvAllocator,
    active: Vec<Active>,
    iter_index: u64,
    total_energy_j: f64,
    /// Last time idle energy was accounted up to.
    accounted_until_s: f64,
    /// Copy-on-write prefix sharing across same-group requests.  Off
    /// by default: an engine that never turns it on is byte-identical
    /// to the pre-sharing simulator.
    prefix_share: bool,
    /// Prompt tokens whose prefill was skipped because their shared
    /// prefix was already resident (sums over the engine's lifetime).
    prefix_cached_tokens: u64,
}

impl EngineSim {
    pub fn new(spec: EngineSpec, initial_freq_mhz: u32) -> Self {
        let kv = KvAllocator::new(spec.kv_blocks, spec.block_tokens);
        Self {
            spec,
            dvfs: DvfsActuator::new(initial_freq_mhz),
            kv,
            active: Vec::new(),
            iter_index: 0,
            total_energy_j: 0.0,
            accounted_until_s: 0.0,
            prefix_share: false,
            prefix_cached_tokens: 0,
        }
    }

    /// Enable copy-on-write prefix sharing (builder form used at
    /// engine spawn; flipping it mid-run is not supported).
    pub fn with_prefix_sharing(mut self, on: bool) -> Self {
        self.prefix_share = on;
        self
    }

    pub fn prefix_share_enabled(&self) -> bool {
        self.prefix_share
    }

    /// Lifetime total of prompt tokens served from resident shared
    /// prefixes instead of recomputed by prefill.
    pub fn prefix_cached_tokens(&self) -> u64 {
        self.prefix_cached_tokens
    }

    /// Resident shared full blocks of a prefix group (0 when absent or
    /// sharing is off) — the router's session-affinity signal and the
    /// admission double-count discount.
    pub fn shared_prefix_blocks(&self, group: u64) -> u32 {
        if !self.prefix_share || group == 0 {
            return 0;
        }
        self.kv.shared_blocks_of_group(group)
    }

    pub fn spec(&self) -> &EngineSpec {
        &self.spec
    }

    pub fn batch(&self) -> u32 {
        self.active.len() as u32
    }

    pub fn kv_blocks_used(&self) -> u32 {
        self.kv.used_blocks()
    }

    pub fn kv_blocks_free(&self) -> u32 {
        self.kv.free_blocks()
    }

    pub fn iter_index(&self) -> u64 {
        self.iter_index
    }

    pub fn is_idle(&self) -> bool {
        self.active.is_empty()
    }

    pub fn total_energy_j(&self) -> f64 {
        self.total_energy_j
    }

    /// Coordinator-visible view of resident requests.
    pub fn active_info(&self) -> Vec<ActiveInfo> {
        self.active
            .iter()
            .map(|a| ActiveInfo {
                id: a.req.id,
                scheduled_iter: a.scheduled_iter,
                prompt_tokens: a.req.prompt_tokens,
                generated: a.generated,
                predicted_gen: a.req.predicted_gen,
                lost: a.lost,
            })
            .collect()
    }

    /// Allocation-free `(id, generated)` view of resident requests —
    /// the §IV-F overrun-sync input (`active_info` clones the full
    /// per-request records; the per-iteration sync only needs these
    /// two fields).
    pub fn active_overruns(&self) -> impl Iterator<Item = (RequestId, u32)> + '_ {
        self.active.iter().map(|a| (a.req.id, a.generated))
    }

    /// Whether a prompt of `prompt_tokens` currently fits in free KV.
    pub fn kv_fits(&self, prompt_tokens: u32) -> bool {
        let need =
            crate::engine::kv_cache::blocks_for(prompt_tokens, self.spec.block_tokens);
        need <= self.kv.free_blocks()
    }

    /// Prefix-aware [`Self::kv_fits`]: a request whose shared prefix
    /// is already resident only needs free blocks for its private
    /// tail.  Falls back to the plain prompt check when sharing is off
    /// or the request is ungrouped.
    pub fn kv_fits_request(&self, req: &Request) -> bool {
        if self.prefix_share && req.prefix_group != 0 {
            let pfx = req.shared_prefix_tokens.min(req.prompt_tokens);
            let need = self
                .kv
                .blocks_needed(req.prompt_tokens, req.prefix_group, pfx);
            need <= self.kv.free_blocks()
        } else {
            self.kv_fits(req.prompt_tokens)
        }
    }

    /// Admit a request: allocates prompt KV; prefill runs fused with
    /// the next iteration. Fails (leaving no state) on KV exhaustion.
    /// With prefix sharing on, a grouped request joins its group's
    /// shared prefix blocks and — when the prefix was ALREADY resident
    /// — skips recomputing the cached tokens' prefill.
    pub fn admit(&mut self, req: Request, now: f64, lost: bool) -> anyhow::Result<()> {
        if self.batch() >= self.spec.max_batch {
            anyhow::bail!("engine at max batch {}", self.spec.max_batch);
        }
        let mut cached_tokens = 0u32;
        if self.prefix_share && req.prefix_group != 0 && req.shared_prefix_tokens > 0 {
            let pfx = req.shared_prefix_tokens.min(req.prompt_tokens);
            let resident = self.kv.shared_blocks_of_group(req.prefix_group) > 0;
            let nshare = self
                .kv
                .allocate_in_group(req.id, req.prompt_tokens, req.prefix_group, pfx)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
            if resident {
                cached_tokens = nshare * self.spec.block_tokens;
            }
        } else {
            self.kv
                .allocate(req.id, req.prompt_tokens)
                .map_err(|e| anyhow::anyhow!("{e}"))?;
        }
        // At least one prompt token is always computed (the final
        // query token attends over the cached prefix).
        let prefill_tokens = req.prompt_tokens.saturating_sub(cached_tokens).max(1);
        self.prefix_cached_tokens +=
            req.prompt_tokens.saturating_sub(prefill_tokens) as u64;
        self.active.push(Active {
            scheduled_iter: self.iter_index,
            scheduled_s: now,
            generated: 0,
            prefill_pending: true,
            prefill_tokens,
            first_token_s: None,
            lost,
            stalled: false,
            resume_at_s: 0.0,
            req,
        });
        Ok(())
    }

    /// Resident requests eligible for checkpointing, with their KV
    /// occupancy (migration-candidate enumeration).
    pub fn residents(&self) -> Vec<ResidentInfo> {
        self.active
            .iter()
            .map(|a| ResidentInfo {
                id: a.req.id,
                prompt_tokens: a.req.prompt_tokens,
                generated: a.generated,
                prefill_pending: a.prefill_pending,
                lost: a.lost,
                kv_tokens: self.kv.tokens_of(a.req.id).unwrap_or(0),
            })
            .collect()
    }

    /// Serialize a resident request's KV ownership + generation
    /// progress and REMOVE it from this engine (its blocks are
    /// released).  Returns `None` for unknown ids.  The checkpoint
    /// restores onto any engine with room via [`Self::restore`];
    /// restoring back onto this engine is always possible (the blocks
    /// were just freed), so a failed migration can be rolled back.
    pub fn checkpoint(&mut self, id: RequestId) -> Option<KvCheckpoint> {
        let pos = self.active.iter().position(|a| a.req.id == id)?;
        let kv_tokens = self.kv.tokens_of(id).unwrap_or(0);
        let a = self.active.swap_remove(pos);
        self.kv.release(id);
        Some(KvCheckpoint {
            req: a.req,
            scheduled_s: a.scheduled_s,
            generated: a.generated,
            prefill_pending: a.prefill_pending,
            first_token_s: a.first_token_s,
            lost: a.lost,
            kv_tokens,
        })
    }

    /// Non-destructive checkpoint: serialize a resident request's KV
    /// ownership + generation progress WITHOUT removing it.  This is
    /// the periodic best-effort checkpoint the fault-recovery path
    /// replays after a crash — the original keeps running; only if the
    /// replica dies does the stored copy matter.  Returns `None` for
    /// unknown ids.
    pub fn snapshot(&self, id: RequestId) -> Option<KvCheckpoint> {
        let a = self.active.iter().find(|a| a.req.id == id)?;
        Some(KvCheckpoint {
            req: a.req.clone(),
            scheduled_s: a.scheduled_s,
            generated: a.generated,
            prefill_pending: a.prefill_pending,
            first_token_s: a.first_token_s,
            lost: a.lost,
            kv_tokens: self.kv.tokens_of(id).unwrap_or(0),
        })
    }

    /// Restore a checkpointed request onto this engine: re-allocates
    /// its KV blocks and re-joins the batch at the next iteration
    /// boundary.  `resume_at_s` models the KV transfer stall — until
    /// then the row holds its blocks but emits no token (pass the
    /// checkpoint instant for a free local restore).  On failure (KV or
    /// batch slot) the engine is untouched and the checkpoint is handed
    /// back so the caller can restore it elsewhere.
    pub fn restore(
        &mut self,
        ckpt: KvCheckpoint,
        resume_at_s: f64,
    ) -> Result<(), KvCheckpoint> {
        if self.batch() >= self.spec.max_batch {
            return Err(ckpt);
        }
        let tokens = ckpt.kv_tokens.max(ckpt.req.prompt_tokens).max(1);
        // A migrated member of a shared prefix COPIES: the source-side
        // checkpoint released its reference (co-residents keep the
        // original) and the destination re-shares with any resident
        // group here, or pays for a fresh private copy.
        let mut cached_tokens = 0u32;
        if self.prefix_share && ckpt.req.prefix_group != 0 && ckpt.req.shared_prefix_tokens > 0
        {
            let pfx = ckpt.req.shared_prefix_tokens.min(tokens);
            let resident = self.kv.shared_blocks_of_group(ckpt.req.prefix_group) > 0;
            match self
                .kv
                .allocate_in_group(ckpt.req.id, tokens, ckpt.req.prefix_group, pfx)
            {
                Ok(nshare) => {
                    if resident {
                        cached_tokens = nshare * self.spec.block_tokens;
                    }
                }
                Err(_) => return Err(ckpt),
            }
        } else if self.kv.allocate(ckpt.req.id, tokens).is_err() {
            return Err(ckpt);
        }
        let prefill_tokens = ckpt
            .req
            .prompt_tokens
            .saturating_sub(cached_tokens)
            .max(1);
        if ckpt.prefill_pending {
            self.prefix_cached_tokens +=
                ckpt.req.prompt_tokens.saturating_sub(prefill_tokens) as u64;
        }
        self.active.push(Active {
            scheduled_iter: self.iter_index,
            scheduled_s: ckpt.scheduled_s,
            generated: ckpt.generated,
            prefill_pending: ckpt.prefill_pending,
            prefill_tokens,
            first_token_s: ckpt.first_token_s,
            lost: ckpt.lost,
            stalled: false,
            // A pending prefill has no KV to transfer — it recomputes
            // here and may start immediately.
            resume_at_s: if ckpt.prefill_pending { 0.0 } else { resume_at_s },
            req: ckpt.req,
        });
        Ok(())
    }

    /// Account idle (no-batch) energy from the last accounted instant
    /// up to `now`. Call before admitting after an idle gap.
    pub fn account_idle(&mut self, now: f64) {
        if now > self.accounted_until_s {
            let freq = self.dvfs.effective(now);
            let dt = now - self.accounted_until_s;
            if self.active.is_empty() {
                self.total_energy_j += idle_power_w(&self.spec, freq) * dt;
            }
            self.accounted_until_s = now;
        }
    }

    /// Execute one iteration starting at `now`; returns the report.
    /// Panics if the engine is idle (callers gate on `is_idle`).
    pub fn run_iteration(&mut self, now: f64) -> IterationReport {
        assert!(!self.active.is_empty(), "iteration on idle engine");
        let freq = self.dvfs.effective(now);
        let kv_start = self.kv.used_blocks();
        let batch = self.batch();

        // Duration: fused prefills stall the whole batch, then one
        // decode step for every row.
        let mut prefills = 0u32;
        let mut duration = 0.0;
        for a in &self.active {
            if a.prefill_pending {
                // `prefill_tokens == prompt_tokens` unless a resident
                // shared prefix let this row skip the cached part.
                duration += prefill_latency_s(&self.spec, a.prefill_tokens, freq);
                prefills += 1;
            }
        }
        duration += decode_latency_s(
            &self.spec,
            &GpuState {
                batch,
                kv_blocks: kv_start,
                freq_mhz: freq,
            },
        );
        let end = now + duration;

        // Token bookkeeping.
        let mut tokens = 0u32;
        let mut stalled = 0u32;
        let mut in_transit = 0u32;
        let mut completed = Vec::new();
        let mut i = 0;
        while i < self.active.len() {
            let a = &mut self.active[i];
            if a.prefill_pending {
                // Prefill emits the first token.
                a.prefill_pending = false;
                a.generated = 1;
                a.first_token_s = Some(end);
                tokens += 1;
            } else if a.resume_at_s > now {
                // Live-migration transfer still in flight: the row
                // holds its blocks and occupies a batch slot but emits
                // no token this iteration (never true for non-migrated
                // rows, whose resume_at_s is 0).
                in_transit += 1;
            } else {
                // Decode: grow KV by one token, then emit.
                let want = a.req.prompt_tokens + a.generated + 1;
                match self.kv.grow_to(a.req.id, want) {
                    Ok(()) => {
                        a.generated += 1;
                        a.stalled = false;
                        tokens += 1;
                    }
                    Err(_) => {
                        // KV exhausted: row stalls this iteration.
                        a.stalled = true;
                        stalled += 1;
                    }
                }
            }
            if a.generated >= a.req.gen_tokens {
                let a = self.active.swap_remove(i);
                self.kv.release(a.req.id);
                completed.push(Self::outcome(&a, end));
            } else {
                i += 1;
            }
        }

        // Deadlock breaker: if every live decode row stalled and the
        // pool is exhausted, preempt the youngest stalled row
        // (recompute preemption — paged-attention engines swap or
        // recompute here; the admission KV check exists to make this
        // rare).
        let mut evicted = Vec::new();
        let live_decodes = self
            .active
            .iter()
            .filter(|a| !a.prefill_pending && a.resume_at_s <= now)
            .count() as u32;
        if stalled > 0 && stalled == live_decodes && self.kv.free_blocks() == 0 {
            if self.active.len() == 1 {
                // A sole resident request larger than the whole pool can
                // never finish: truncate it (the max_tokens limit of a
                // sane deployment keeps per-request footprints below
                // capacity, so this is a test-scale corner).
                let a = self.active.swap_remove(0);
                self.kv.release(a.req.id);
                let mut a = a;
                a.req.gen_tokens = a.generated;
                completed.push(Self::outcome(&a, end));
            } else if let Some(pos) = self
                .active
                .iter()
                .enumerate()
                .filter(|(_, a)| a.stalled)
                .max_by_key(|(_, a)| a.scheduled_iter)
                .map(|(i, _)| i)
            {
                let a = self.active.swap_remove(pos);
                self.kv.release(a.req.id);
                evicted.push(a.req);
            }
        }

        let p = power_w(&self.spec, batch, kv_start, freq);
        let energy = p * duration;
        self.total_energy_j += energy;
        self.accounted_until_s = end;
        let report = IterationReport {
            iter_index: self.iter_index,
            start_s: now,
            duration_s: duration,
            batch,
            kv_blocks: kv_start,
            freq_mhz: freq,
            power_w: p,
            energy_j: energy,
            prefills,
            tokens,
            completed,
            stalled,
            in_transit,
            evicted,
        };
        self.iter_index += 1;
        report
    }

    fn outcome(a: &Active, end: f64) -> RequestOutcome {
        let first = a.first_token_s.unwrap_or(end);
        let gen = a.req.gen_tokens.max(1);
        let tbt = if gen > 1 {
            (end - first) / (gen - 1) as f64
        } else {
            0.0
        };
        RequestOutcome {
            id: a.req.id,
            prompt_tokens: a.req.prompt_tokens,
            gen_tokens: a.req.gen_tokens,
            arrival_s: a.req.arrival_s,
            scheduled_s: a.scheduled_s,
            ttft_s: first - a.req.arrival_s,
            e2e_s: end - a.req.arrival_s,
            tbt_avg_s: tbt,
            lost: a.lost,
        }
    }

    /// Drain all residents (used when an engine shuts down after its
    /// shadow-instancing transition; callers re-route the returned
    /// requests). KV is fully released.
    pub fn drain(&mut self) -> Vec<Request> {
        let reqs: Vec<Request> = self.active.iter().map(|a| a.req.clone()).collect();
        for a in &self.active {
            self.kv.release(a.req.id);
        }
        self.active.clear();
        reqs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::gpusim::dvfs::FREQ_MAX_MHZ;

    fn req(id: u64, prompt: u32, gen: u32, at: f64) -> Request {
        Request {
            id,
            prompt_tokens: prompt,
            gen_tokens: gen,
            predicted_gen: gen,
            arrival_s: at,
            prefix_group: 0,
            shared_prefix_tokens: 0,
        }
    }

    fn engine() -> EngineSim {
        EngineSim::new(llama2_13b(2), FREQ_MAX_MHZ)
    }

    #[test]
    fn request_lifecycle_and_metrics() {
        let mut e = engine();
        e.admit(req(1, 100, 5, 0.0), 0.0, false).unwrap();
        let mut done = None;
        let mut t = 0.0;
        for _ in 0..5 {
            let r = e.run_iteration(t);
            t += r.duration_s;
            if !r.completed.is_empty() {
                done = Some(r.completed[0].clone());
            }
        }
        let o = done.expect("finished in 5 iterations");
        assert_eq!(o.gen_tokens, 5);
        assert!(o.ttft_s > 0.0);
        assert!(o.e2e_s > o.ttft_s);
        assert!(o.tbt_avg_s > 0.0);
        assert!(e.is_idle());
        assert_eq!(e.kv_blocks_used(), 0);
    }

    #[test]
    fn prefill_fused_into_first_iteration() {
        let mut e = engine();
        e.admit(req(1, 1000, 10, 0.0), 0.0, false).unwrap();
        let r1 = e.run_iteration(0.0);
        assert_eq!(r1.prefills, 1);
        let d1 = r1.duration_s;
        let r2 = e.run_iteration(d1);
        assert_eq!(r2.prefills, 0);
        // Prefill iteration much longer than a plain decode step.
        assert!(d1 > 3.0 * r2.duration_s, "d1={d1} d2={}", r2.duration_s);
    }

    #[test]
    fn kv_grows_one_token_per_iteration() {
        let mut e = engine();
        // 64-token blocks: a 64-token prompt uses exactly 1 block;
        // the first decode token (generated=2 overall) forces block 2.
        e.admit(req(1, 64, 4, 0.0), 0.0, false).unwrap();
        assert_eq!(e.kv_blocks_used(), 1);
        e.run_iteration(0.0); // prefill, no growth
        assert_eq!(e.kv_blocks_used(), 1);
        e.run_iteration(1.0); // decode token 2 -> 65 tokens
        assert_eq!(e.kv_blocks_used(), 2);
    }

    #[test]
    fn admission_rejected_when_kv_full() {
        let mut e = engine();
        // 439 blocks * 64 tokens = 28096 tokens capacity
        e.admit(req(1, 20_000, 8, 0.0), 0.0, false).unwrap();
        assert!(e.kv_fits(8_000));
        assert!(!e.kv_fits(9_000));
        assert!(e.admit(req(2, 9_000, 8, 0.0), 0.0, false).is_err());
        // Failed admit leaves no residue.
        assert_eq!(e.batch(), 1);
    }

    #[test]
    fn max_batch_enforced() {
        let mut e = engine();
        for i in 0..32 {
            e.admit(req(i, 10, 100, 0.0), 0.0, false).unwrap();
        }
        assert!(e.admit(req(99, 10, 100, 0.0), 0.0, false).is_err());
    }

    #[test]
    fn stall_on_kv_exhaustion_then_recover() {
        // 3-block pool, two 1-block prompts: on the first decode
        // iteration both rows cross into a second block but only one
        // spare block exists -> one row must stall.
        let spec = EngineSpec {
            kv_blocks: 3,
            ..llama2_13b(2)
        };
        let mut e = EngineSim::new(spec, FREQ_MAX_MHZ);
        e.admit(req(1, 64, 80, 0.0), 0.0, false).unwrap();
        e.admit(req(2, 64, 40, 0.0), 0.0, false).unwrap();
        let mut t = 0.0;
        let mut saw_stall = false;
        for _ in 0..12 {
            if e.is_idle() {
                break;
            }
            let r = e.run_iteration(t);
            t += r.duration_s;
            saw_stall |= r.stalled > 0;
        }
        assert!(saw_stall, "expected a KV stall");
        assert!(e.kv_blocks_used() <= 3);
    }

    #[test]
    fn energy_accumulates_and_idle_power_counts() {
        let mut e = engine();
        e.account_idle(1.0);
        let idle = e.total_energy_j();
        assert!(idle > 50.0, "idle energy {idle}"); // ~200W+ for 1 s
        e.admit(req(1, 10, 3, 1.0), 1.0, false).unwrap();
        let mut t = 1.0;
        while !e.is_idle() {
            t += e.run_iteration(t).duration_s;
        }
        assert!(e.total_energy_j() > idle);
    }

    #[test]
    fn tbt_reflects_iteration_duration() {
        let mut e = engine();
        e.admit(req(1, 10, 50, 0.0), 0.0, false).unwrap();
        let mut t = 0.0;
        let mut out = None;
        let mut decode_d = 0.0;
        while !e.is_idle() {
            let r = e.run_iteration(t);
            t += r.duration_s;
            if r.prefills == 0 {
                decode_d = r.duration_s;
            }
            if !r.completed.is_empty() {
                out = Some(r.completed[0].clone());
            }
        }
        let o = out.unwrap();
        assert!((o.tbt_avg_s - decode_d).abs() / decode_d < 0.05);
    }

    #[test]
    fn drain_returns_requests_and_frees_kv() {
        let mut e = engine();
        e.admit(req(1, 100, 50, 0.0), 0.0, false).unwrap();
        e.admit(req(2, 100, 50, 0.0), 0.0, false).unwrap();
        e.run_iteration(0.0);
        let drained = e.drain();
        assert_eq!(drained.len(), 2);
        assert!(e.is_idle());
        assert_eq!(e.kv_blocks_used(), 0);
    }

    #[test]
    fn checkpoint_removes_and_restore_rejoins() {
        let mut e = engine();
        e.admit(req(1, 640, 50, 0.0), 0.0, false).unwrap();
        e.admit(req(2, 64, 50, 0.0), 0.0, false).unwrap();
        let r = e.run_iteration(0.0);
        let t = r.duration_s;
        let used_before = e.kv_blocks_used();
        let ri = e
            .residents()
            .into_iter()
            .find(|r| r.id == 1)
            .expect("resident");
        assert_eq!(ri.generated, 1);
        assert!(!ri.prefill_pending);
        let ckpt = e.checkpoint(1).expect("checkpoint");
        assert_eq!(ckpt.req.id, 1);
        assert_eq!(ckpt.generated, 1);
        assert_eq!(ckpt.kv_tokens, 640);
        assert_eq!(e.batch(), 1);
        assert!(e.kv_blocks_used() < used_before);
        assert!(e.checkpoint(1).is_none(), "already checkpointed");
        // Restore with no stall: the row rejoins and finishes.
        e.restore(ckpt, t).unwrap();
        assert_eq!(e.batch(), 2);
        assert_eq!(e.kv_blocks_used(), used_before);
        let mut now = t;
        let mut done = vec![];
        for _ in 0..200 {
            if e.is_idle() {
                break;
            }
            let r = e.run_iteration(now);
            now += r.duration_s;
            done.extend(r.completed.into_iter().map(|o| o.id));
        }
        done.sort_unstable();
        assert_eq!(done, vec![1, 2]);
        assert_eq!(e.kv_blocks_used(), 0);
    }

    #[test]
    fn snapshot_is_non_destructive_and_matches_checkpoint() {
        let mut e = engine();
        e.admit(req(1, 640, 50, 0.0), 0.0, false).unwrap();
        e.run_iteration(0.0);
        let snap = e.snapshot(1).expect("snapshot");
        // The original keeps running.
        assert_eq!(e.batch(), 1);
        assert!(e.kv_blocks_used() > 0);
        assert!(e.snapshot(99).is_none());
        // A snapshot agrees with the destructive checkpoint field by
        // field — it is the same serialization without the removal.
        let ckpt = e.checkpoint(1).unwrap();
        assert_eq!(snap, ckpt);
        // And it restores onto a fresh engine like any checkpoint.
        let mut dst = engine();
        dst.restore(snap, 0.0).unwrap();
        assert_eq!(dst.batch(), 1);
        let ri = &dst.residents()[0];
        assert_eq!(ri.generated, 1);
        assert!(!ri.prefill_pending);
    }

    #[test]
    fn restore_rejected_without_capacity_returns_checkpoint() {
        let mut src = engine();
        src.admit(req(1, 640, 50, 0.0), 0.0, false).unwrap();
        src.run_iteration(0.0);
        let ckpt = src.checkpoint(1).unwrap();
        // Destination whose whole pool is smaller than the checkpoint.
        let spec = EngineSpec {
            kv_blocks: 5,
            ..llama2_13b(2)
        };
        let mut dst = EngineSim::new(spec, FREQ_MAX_MHZ);
        let ckpt = dst.restore(ckpt, 0.0).unwrap_err();
        assert_eq!(dst.batch(), 0);
        assert_eq!(dst.kv_blocks_used(), 0);
        // Rolling back onto the source always succeeds: its blocks
        // were freed by the checkpoint.
        src.restore(ckpt, 0.0).unwrap();
        assert_eq!(src.batch(), 1);
    }

    #[test]
    fn transit_stall_suppresses_tokens_until_resume() {
        let mut e = engine();
        e.admit(req(1, 64, 40, 0.0), 0.0, false).unwrap();
        let r = e.run_iteration(0.0);
        let t = r.duration_s;
        let ckpt = e.checkpoint(1).unwrap();
        // Restore with a transfer stall well past the next iterations.
        e.restore(ckpt, t + 1.0).unwrap();
        let mut now = t;
        let r = e.run_iteration(now);
        assert_eq!(r.in_transit, 1);
        assert_eq!(r.tokens, 0);
        assert_eq!(r.batch, 1, "transit rows still occupy the batch");
        now += r.duration_s;
        // Drive past the resume instant: tokens flow again.
        let mut produced = 0;
        for _ in 0..200 {
            if e.is_idle() {
                break;
            }
            let r = e.run_iteration(now);
            now += r.duration_s;
            produced += r.tokens;
        }
        assert!(e.is_idle());
        assert_eq!(produced, 39, "remaining tokens after the stall");
    }

    #[test]
    fn prefill_pending_checkpoint_recomputes_prefill() {
        let mut e = engine();
        e.admit(req(1, 500, 10, 0.0), 0.0, false).unwrap();
        // Checkpoint BEFORE any iteration: prefill never ran.
        let ckpt = e.checkpoint(1).unwrap();
        assert!(ckpt.prefill_pending);
        assert_eq!(ckpt.generated, 0);
        let mut dst = engine();
        // Even with a stall requested, a pending prefill restores
        // runnable immediately (there is no KV to transfer).
        dst.restore(ckpt, 5.0).unwrap();
        let r = dst.run_iteration(0.0);
        assert_eq!(r.prefills, 1);
        assert_eq!(r.tokens, 1);
    }

    fn grouped(id: u64, prompt: u32, gen: u32, group: u64, pfx: u32) -> Request {
        Request {
            prefix_group: group,
            shared_prefix_tokens: pfx,
            ..req(id, prompt, gen, 0.0)
        }
    }

    #[test]
    fn shared_prefix_counts_once_and_shortens_prefill() {
        let mut e = engine().with_prefix_sharing(true);
        // 1024-token shared prefix = 16 full blocks at N=64.
        e.admit(grouped(1, 1100, 10, 3, 1024), 0.0, false).unwrap();
        let first_used = e.kv_blocks_used();
        assert_eq!(first_used, blocks_for_spec(1100));
        let r1 = e.run_iteration(0.0);
        assert_eq!(r1.prefills, 1);
        // Second member: only its private tail is new KV...
        e.admit(grouped(2, 1100, 10, 3, 1024), 1.0, false).unwrap();
        assert_eq!(
            e.kv_blocks_used(),
            first_used + blocks_for_spec(1100) - 16
        );
        assert_eq!(e.shared_prefix_blocks(3), 16);
        // ...and its prefill skips the 1024 cached tokens.
        assert_eq!(e.prefix_cached_tokens(), 1024);
        let r2 = e.run_iteration(1.0);
        assert_eq!(r2.prefills, 1);
        assert!(
            r2.duration_s < r1.duration_s,
            "cached prefill must be shorter: {} vs {}",
            r2.duration_s,
            r1.duration_s
        );
    }

    fn blocks_for_spec(tokens: u32) -> u32 {
        crate::engine::kv_cache::blocks_for(tokens, llama2_13b(2).block_tokens)
    }

    #[test]
    fn sharing_off_ignores_groups() {
        let mut e = engine(); // sharing off
        e.admit(grouped(1, 1100, 10, 3, 1024), 0.0, false).unwrap();
        e.admit(grouped(2, 1100, 10, 3, 1024), 0.0, false).unwrap();
        assert_eq!(e.kv_blocks_used(), 2 * blocks_for_spec(1100));
        assert_eq!(e.shared_prefix_blocks(3), 0);
        assert_eq!(e.prefix_cached_tokens(), 0);
    }

    #[test]
    fn checkpoint_of_shared_member_copies_not_steals() {
        let mut e = engine().with_prefix_sharing(true);
        e.admit(grouped(1, 1100, 50, 3, 1024), 0.0, false).unwrap();
        e.admit(grouped(2, 1100, 50, 3, 1024), 0.0, false).unwrap();
        let r = e.run_iteration(0.0);
        let t = r.duration_s;
        // Checkpoint one member: the co-resident keeps the prefix.
        let ckpt = e.checkpoint(1).expect("checkpoint");
        assert_eq!(e.shared_prefix_blocks(3), 16);
        // The checkpoint carries the FULL occupancy (a copy, so the
        // transfer cost covers the whole KV).
        assert_eq!(ckpt.blocks(64), blocks_for_spec(1100));
        // Restoring onto a sharing destination re-shares with the
        // resident group: only the private tail is newly allocated.
        let used = e.kv_blocks_used();
        e.restore(ckpt, t).unwrap();
        assert_eq!(e.kv_blocks_used(), used + blocks_for_spec(1100) - 16);
        // Last-member releases free the prefix.
        e.drain();
        assert_eq!(e.kv_blocks_used(), 0);
        assert_eq!(e.shared_prefix_blocks(3), 0);
    }

    #[test]
    fn kv_fits_request_is_prefix_aware() {
        let spec = EngineSpec {
            kv_blocks: 20,
            ..llama2_13b(2)
        };
        let mut e = EngineSim::new(spec, FREQ_MAX_MHZ).with_prefix_sharing(true);
        e.admit(grouped(1, 1024, 10, 3, 1024), 0.0, false).unwrap();
        assert_eq!(e.kv_blocks_used(), 16);
        // 4 free blocks: a second member (16 shared + 1 private at
        // 1025 tokens... = 17 total, 16 resident) fits through the
        // prefix-aware check but not the naive one.
        let r2 = grouped(2, 1088, 10, 3, 1024);
        assert!(!e.kv_fits(r2.prompt_tokens));
        assert!(e.kv_fits_request(&r2));
        e.admit(r2, 0.0, false).unwrap();
        assert_eq!(e.kv_blocks_used(), 17);
    }

    #[test]
    fn lower_frequency_lengthens_iterations() {
        let mut hi = engine();
        let mut lo = EngineSim::new(llama2_13b(2), 210);
        hi.admit(req(1, 10, 4, 0.0), 0.0, false).unwrap();
        lo.admit(req(1, 10, 4, 0.0), 0.0, false).unwrap();
        hi.run_iteration(0.0);
        lo.run_iteration(0.0);
        let dh = hi.run_iteration(10.0).duration_s;
        let dl = lo.run_iteration(10.0).duration_s;
        assert!(dl > 1.5 * dh, "dl={dl} dh={dh}");
    }
}
