//! DVFS actuator: the `nvidia-smi -lgc`-style frequency knob.
//!
//! Frequencies are quantized to the A100's 15 MHz steps in
//! [210, 1410] MHz.  A frequency change takes effect after the paper's
//! measured ~200 ms switching latency; queries during the transition
//! observe the old frequency.

/// Minimum supported graphics clock, MHz.
pub const FREQ_MIN_MHZ: u32 = 210;
/// Maximum supported graphics clock, MHz.
pub const FREQ_MAX_MHZ: u32 = 1410;
/// Clock quantization step, MHz.
pub const FREQ_STEP_MHZ: u32 = 15;
/// Frequency-switch latency, seconds (paper §IV-F: avg. 200 ms).
pub const SWITCH_LATENCY_S: f64 = 0.200;

/// Snap an arbitrary MHz value to the supported grid (round to nearest).
pub fn quantize(freq_mhz: u32) -> u32 {
    let clamped = freq_mhz.clamp(FREQ_MIN_MHZ, FREQ_MAX_MHZ);
    let steps = (clamped - FREQ_MIN_MHZ + FREQ_STEP_MHZ / 2) / FREQ_STEP_MHZ;
    FREQ_MIN_MHZ + steps * FREQ_STEP_MHZ
}

/// All supported frequencies, ascending (81 settings).
pub fn frequency_grid() -> Vec<u32> {
    (FREQ_MIN_MHZ..=FREQ_MAX_MHZ)
        .step_by(FREQ_STEP_MHZ as usize)
        .collect()
}

/// Stateful frequency actuator with switching latency.
#[derive(Debug, Clone)]
pub struct DvfsActuator {
    current: u32,
    pending: Option<(f64, u32)>, // (effective_at, freq)
    switches: u64,
    /// Hardware-imposed ceiling (thermal throttle). Unlike `set`, a cap
    /// applies immediately — the silicon clamps, it doesn't negotiate —
    /// and it is not counted as a controller-issued switch.
    cap: Option<u32>,
}

impl DvfsActuator {
    /// New actuator pinned at `initial` MHz (quantized).
    pub fn new(initial: u32) -> Self {
        Self {
            current: quantize(initial),
            pending: None,
            switches: 0,
            cap: None,
        }
    }

    /// Request `freq_mhz` at time `now`; returns the quantized target.
    /// A no-op if the (quantized) target equals the current/pending one.
    /// The request is recorded uncapped so the controller's intent
    /// survives the throttle window; `effective` clamps.
    pub fn set(&mut self, now: f64, freq_mhz: u32) -> u32 {
        let target = quantize(freq_mhz);
        let effective_target = self.pending.map(|(_, f)| f).unwrap_or(self.current);
        if target != effective_target {
            // Collapse the transition: latest request wins.
            self.apply_pending(now);
            if target != self.current {
                self.pending = Some((now + SWITCH_LATENCY_S, target));
                self.switches += 1;
            } else {
                self.pending = None;
            }
        }
        self.clamp(target)
    }

    /// Impose a thermal ceiling of `cap_mhz` (quantized) starting now.
    /// Takes effect immediately — no switch latency, no switch count.
    pub fn set_cap(&mut self, now: f64, cap_mhz: u32) {
        self.apply_pending(now);
        self.cap = Some(quantize(cap_mhz));
    }

    /// Lift the thermal ceiling; the controller's last request resumes
    /// at the next `effective`/`set` with normal switch semantics.
    pub fn clear_cap(&mut self) {
        self.cap = None;
    }

    /// Current hardware ceiling, if throttled.
    pub fn cap(&self) -> Option<u32> {
        self.cap
    }

    fn clamp(&self, f: u32) -> u32 {
        match self.cap {
            Some(c) => f.min(c),
            None => f,
        }
    }

    fn apply_pending(&mut self, now: f64) {
        if let Some((at, f)) = self.pending {
            if now >= at {
                self.current = f;
                self.pending = None;
            }
        }
    }

    /// Frequency the GPU actually runs at, at time `now`.
    pub fn effective(&mut self, now: f64) -> u32 {
        self.apply_pending(now);
        self.clamp(self.current)
    }

    /// Last requested (target) frequency (uncapped controller intent).
    pub fn target(&self) -> u32 {
        self.pending.map(|(_, f)| f).unwrap_or(self.current)
    }

    /// Number of frequency switches issued (telemetry).
    pub fn switch_count(&self) -> u64 {
        self.switches
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_snaps_and_clamps() {
        assert_eq!(quantize(210), 210);
        assert_eq!(quantize(1410), 1410);
        assert_eq!(quantize(100), 210);
        assert_eq!(quantize(9999), 1410);
        assert_eq!(quantize(1049), 1050);
        assert_eq!(quantize(1057), 1050);
        assert_eq!(quantize(1058), 1065);
    }

    #[test]
    fn grid_has_81_settings() {
        let g = frequency_grid();
        assert_eq!(g.len(), 81);
        assert_eq!(g[0], 210);
        assert_eq!(*g.last().unwrap(), 1410);
        assert!(g.windows(2).all(|w| w[1] - w[0] == 15));
    }

    #[test]
    fn switch_takes_200ms() {
        let mut a = DvfsActuator::new(1410);
        a.set(0.0, 1050);
        assert_eq!(a.effective(0.1), 1410, "old freq during transition");
        assert_eq!(a.effective(0.21), 1050, "new freq after 200 ms");
    }

    #[test]
    fn redundant_set_is_noop() {
        let mut a = DvfsActuator::new(1410);
        a.set(0.0, 1410);
        assert_eq!(a.switch_count(), 0);
        a.set(0.0, 1050);
        a.set(0.05, 1050);
        assert_eq!(a.switch_count(), 1);
    }

    #[test]
    fn latest_request_wins() {
        let mut a = DvfsActuator::new(1410);
        a.set(0.0, 210);
        a.set(0.05, 900);
        assert_eq!(a.target(), 900);
        // First transition superseded; 900 effective 200 ms after the
        // second request.
        assert_eq!(a.effective(0.20), 1410);
        assert_eq!(a.effective(0.26), 900);
    }

    #[test]
    fn cap_clamps_immediately_without_counting_a_switch() {
        let mut a = DvfsActuator::new(1410);
        a.set_cap(0.0, 600);
        assert_eq!(a.cap(), Some(600));
        assert_eq!(a.effective(0.0), 600, "cap applies with no latency");
        assert_eq!(a.switch_count(), 0);
        assert_eq!(a.target(), 1410, "controller intent survives the cap");
        // Requests above the cap are recorded but clamped.
        assert_eq!(a.set(1.0, 1200), 600);
        assert_eq!(a.effective(1.3), 600);
        // Requests below the cap pass through.
        assert_eq!(a.set(2.0, 450), 450);
        assert_eq!(a.effective(2.3), 450);
    }

    #[test]
    fn clear_cap_restores_controller_intent() {
        let mut a = DvfsActuator::new(1410);
        a.set_cap(0.0, 600);
        assert_eq!(a.effective(0.0), 600);
        a.clear_cap();
        assert_eq!(a.cap(), None);
        assert_eq!(a.effective(0.0), 1410, "pinned freq resumes uncapped");
    }

    #[test]
    fn cap_is_quantized() {
        let mut a = DvfsActuator::new(1410);
        a.set_cap(0.0, 601);
        assert_eq!(a.cap(), Some(quantize(601)));
    }

    #[test]
    fn target_tracks_pending() {
        let mut a = DvfsActuator::new(600);
        assert_eq!(a.target(), 600);
        a.set(0.0, 1200);
        assert_eq!(a.target(), 1200);
        assert_eq!(a.effective(1.0), 1200);
        assert_eq!(a.target(), 1200);
    }
}
