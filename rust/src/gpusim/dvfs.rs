//! DVFS actuator: the `nvidia-smi -lgc`-style frequency knob.
//!
//! Frequencies are quantized to the A100's 15 MHz steps in
//! [210, 1410] MHz.  A frequency change takes effect after the paper's
//! measured ~200 ms switching latency; queries during the transition
//! observe the old frequency.

/// Minimum supported graphics clock, MHz.
pub const FREQ_MIN_MHZ: u32 = 210;
/// Maximum supported graphics clock, MHz.
pub const FREQ_MAX_MHZ: u32 = 1410;
/// Clock quantization step, MHz.
pub const FREQ_STEP_MHZ: u32 = 15;
/// Frequency-switch latency, seconds (paper §IV-F: avg. 200 ms).
pub const SWITCH_LATENCY_S: f64 = 0.200;

/// Snap an arbitrary MHz value to the supported grid (round to nearest).
pub fn quantize(freq_mhz: u32) -> u32 {
    let clamped = freq_mhz.clamp(FREQ_MIN_MHZ, FREQ_MAX_MHZ);
    let steps = (clamped - FREQ_MIN_MHZ + FREQ_STEP_MHZ / 2) / FREQ_STEP_MHZ;
    FREQ_MIN_MHZ + steps * FREQ_STEP_MHZ
}

/// All supported frequencies, ascending (81 settings).
pub fn frequency_grid() -> Vec<u32> {
    (FREQ_MIN_MHZ..=FREQ_MAX_MHZ)
        .step_by(FREQ_STEP_MHZ as usize)
        .collect()
}

/// Stateful frequency actuator with switching latency.
#[derive(Debug, Clone)]
pub struct DvfsActuator {
    current: u32,
    pending: Option<(f64, u32)>, // (effective_at, freq)
    switches: u64,
}

impl DvfsActuator {
    /// New actuator pinned at `initial` MHz (quantized).
    pub fn new(initial: u32) -> Self {
        Self {
            current: quantize(initial),
            pending: None,
            switches: 0,
        }
    }

    /// Request `freq_mhz` at time `now`; returns the quantized target.
    /// A no-op if the (quantized) target equals the current/pending one.
    pub fn set(&mut self, now: f64, freq_mhz: u32) -> u32 {
        let target = quantize(freq_mhz);
        let effective_target = self.pending.map(|(_, f)| f).unwrap_or(self.current);
        if target != effective_target {
            // Collapse the transition: latest request wins.
            self.apply_pending(now);
            if target != self.current {
                self.pending = Some((now + SWITCH_LATENCY_S, target));
                self.switches += 1;
            } else {
                self.pending = None;
            }
        }
        target
    }

    fn apply_pending(&mut self, now: f64) {
        if let Some((at, f)) = self.pending {
            if now >= at {
                self.current = f;
                self.pending = None;
            }
        }
    }

    /// Frequency the GPU actually runs at, at time `now`.
    pub fn effective(&mut self, now: f64) -> u32 {
        self.apply_pending(now);
        self.current
    }

    /// Last requested (target) frequency.
    pub fn target(&self) -> u32 {
        self.pending.map(|(_, f)| f).unwrap_or(self.current)
    }

    /// Number of frequency switches issued (telemetry).
    pub fn switch_count(&self) -> u64 {
        self.switches
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_snaps_and_clamps() {
        assert_eq!(quantize(210), 210);
        assert_eq!(quantize(1410), 1410);
        assert_eq!(quantize(100), 210);
        assert_eq!(quantize(9999), 1410);
        assert_eq!(quantize(1049), 1050);
        assert_eq!(quantize(1057), 1050);
        assert_eq!(quantize(1058), 1065);
    }

    #[test]
    fn grid_has_81_settings() {
        let g = frequency_grid();
        assert_eq!(g.len(), 81);
        assert_eq!(g[0], 210);
        assert_eq!(*g.last().unwrap(), 1410);
        assert!(g.windows(2).all(|w| w[1] - w[0] == 15));
    }

    #[test]
    fn switch_takes_200ms() {
        let mut a = DvfsActuator::new(1410);
        a.set(0.0, 1050);
        assert_eq!(a.effective(0.1), 1410, "old freq during transition");
        assert_eq!(a.effective(0.21), 1050, "new freq after 200 ms");
    }

    #[test]
    fn redundant_set_is_noop() {
        let mut a = DvfsActuator::new(1410);
        a.set(0.0, 1410);
        assert_eq!(a.switch_count(), 0);
        a.set(0.0, 1050);
        a.set(0.05, 1050);
        assert_eq!(a.switch_count(), 1);
    }

    #[test]
    fn latest_request_wins() {
        let mut a = DvfsActuator::new(1410);
        a.set(0.0, 210);
        a.set(0.05, 900);
        assert_eq!(a.target(), 900);
        // First transition superseded; 900 effective 200 ms after the
        // second request.
        assert_eq!(a.effective(0.20), 1410);
        assert_eq!(a.effective(0.26), 900);
    }

    #[test]
    fn target_tracks_pending() {
        let mut a = DvfsActuator::new(600);
        assert_eq!(a.target(), 600);
        a.set(0.0, 1200);
        assert_eq!(a.target(), 1200);
        assert_eq!(a.effective(1.0), 1200);
        assert_eq!(a.target(), 1200);
    }
}
