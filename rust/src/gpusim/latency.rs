//! Iteration-latency model: `t_iter(batch, kv, freq, engine)`.
//!
//! Decode is memory-bound (paper §II): the dominant term is weight +
//! KV-cache traffic, which scales with a *saturating* effective-
//! bandwidth curve in core frequency (DRAM clocks are constant, but a
//! lower SM clock issues fewer outstanding loads, starving the memory
//! pipeline at the bottom of the range).  The compute term scales
//! inversely with frequency.  Prefill is compute-bound and scales ~1/f.

use crate::config::{EngineSpec, PartitionKind};

/// Reference calibration constants (Llama2-13B TP2, milliseconds at
/// normalized frequency fn = f/1410).  See module docs for anchors.
mod cal {
    /// Compute time: (C0 + C1 * batch) / fn.
    pub const C0: f64 = 0.30;
    pub const C1: f64 = 0.028;
    /// Memory time: (M0 + M1 * batch + M2 * kv_frac) / bw(fn).
    pub const M0: f64 = 11.90;
    pub const M1: f64 = 0.187;
    pub const M2: f64 = 3.47;
    /// Effective-bandwidth knee.
    pub const BW_KNEE: f64 = 0.35;
    /// Prefill: (P0 + P1 * prompt_tokens) / fn.
    pub const P0: f64 = 3.0;
    pub const P1: f64 = 0.16;
}

/// Saturating effective-bandwidth factor in [0, 1]; bw(1) = 1.
#[inline]
pub fn bandwidth_factor(fnorm: f64) -> f64 {
    (1.0 + cal::BW_KNEE) * fnorm / (fnorm + cal::BW_KNEE)
}

/// Instantaneous GPU/engine state a latency query depends on.
#[derive(Debug, Clone, Copy)]
pub struct GpuState {
    /// Current batch size (live decode rows).
    pub batch: u32,
    /// Allocated KV blocks.
    pub kv_blocks: u32,
    /// Core frequency in MHz.
    pub freq_mhz: u32,
}

impl GpuState {
    pub fn kv_fraction(&self, spec: &EngineSpec) -> f64 {
        (self.kv_blocks as f64 / spec.kv_blocks as f64).min(1.0)
    }
}

#[inline]
fn fnorm(freq_mhz: u32) -> f64 {
    (freq_mhz as f64 / super::dvfs::FREQ_MAX_MHZ as f64).clamp(0.05, 1.0)
}

/// One decode iteration (one token for every row in the batch), seconds.
pub fn decode_latency_s(spec: &EngineSpec, st: &GpuState) -> f64 {
    assert!(st.batch >= 1, "decode with empty batch");
    let fnn = fnorm(st.freq_mhz);
    let kv = st.kv_fraction(spec);

    // DDP replicas each run a slice of the batch in parallel; the
    // iteration completes when the widest replica completes.
    let (eff_batch, scale) = match spec.partition {
        PartitionKind::DataParallel => {
            let replicas = spec.tensor_parallel as f64;
            ((st.batch as f64 / replicas).ceil(), spec.latency_scale)
        }
        _ => (st.batch as f64, spec.latency_scale),
    };

    let compute_ms = (cal::C0 + cal::C1 * eff_batch) / fnn;
    let memory_ms =
        (cal::M0 + cal::M1 * eff_batch + cal::M2 * kv) / bandwidth_factor(fnn);
    let mut ms = scale * (compute_ms + memory_ms);
    if spec.partition == PartitionKind::Pipeline {
        ms *= 1.0 + spec.pipeline_bubble;
    }
    ms / 1e3
}

/// Prompt-phase latency for one request, seconds (compute-bound).
pub fn prefill_latency_s(spec: &EngineSpec, prompt_tokens: u32, freq_mhz: u32) -> f64 {
    let fnn = fnorm(freq_mhz);
    let mut ms = spec.latency_scale * (cal::P0 + cal::P1 * prompt_tokens as f64) / fnn;
    if spec.partition == PartitionKind::Pipeline {
        ms *= 1.0 + spec.pipeline_bubble;
    }
    ms / 1e3
}

/// Iterations/second the engine sustains in a given state — the ground
/// truth the performance-prediction model `M` learns to approximate.
pub fn ips(spec: &EngineSpec, st: &GpuState) -> f64 {
    1.0 / decode_latency_s(spec, st)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::{llama2_13b, llama2_13b_partitioned};
    use crate::gpusim::dvfs::FREQ_MAX_MHZ;

    fn st(batch: u32, kv_blocks: u32, freq: u32) -> GpuState {
        GpuState {
            batch,
            kv_blocks,
            freq_mhz: freq,
        }
    }

    #[test]
    fn tbt_band_at_max_freq() {
        // Paper Fig. 2c: 13B TP2 TBT is ~15-30 ms at high frequency.
        let e = llama2_13b(2);
        let t1 = decode_latency_s(&e, &st(1, 220, FREQ_MAX_MHZ));
        let t32 = decode_latency_s(&e, &st(32, 220, FREQ_MAX_MHZ));
        assert!((0.012..0.018).contains(&t1), "t1={t1}");
        assert!((0.018..0.025).contains(&t32), "t32={t32}");
    }

    #[test]
    fn batch_worsens_tbt_about_45_percent() {
        let e = llama2_13b(2);
        let t1 = decode_latency_s(&e, &st(1, 220, FREQ_MAX_MHZ));
        let t32 = decode_latency_s(&e, &st(32, 220, FREQ_MAX_MHZ));
        let ratio = t32 / t1;
        assert!((1.35..1.60).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn full_kv_degrades_about_18_percent() {
        // Paper §III-B: up to 18.2% performance degradation.
        let e = llama2_13b(2);
        let lo = decode_latency_s(&e, &st(32, 0, FREQ_MAX_MHZ));
        let hi = decode_latency_s(&e, &st(32, e.kv_blocks, FREQ_MAX_MHZ));
        let degr = hi / lo - 1.0;
        assert!((0.15..0.21).contains(&degr), "degradation={degr}");
    }

    #[test]
    fn tbt_monotone_in_batch_kv_and_inverse_freq() {
        let e = llama2_13b(2);
        let base = decode_latency_s(&e, &st(8, 100, 1050));
        assert!(decode_latency_s(&e, &st(16, 100, 1050)) > base);
        assert!(decode_latency_s(&e, &st(8, 300, 1050)) > base);
        assert!(decode_latency_s(&e, &st(8, 100, 840)) > base);
        assert!(decode_latency_s(&e, &st(8, 100, 1410)) < base);
    }

    #[test]
    fn low_freq_tbt_roughly_doubles() {
        // (high f, low B) -> (low f, high B): E2E/TBT ~2x (paper §III-A1).
        let e = llama2_13b(2);
        let fast = decode_latency_s(&e, &st(1, 220, FREQ_MAX_MHZ));
        let slow = decode_latency_s(&e, &st(32, 220, 210));
        let ratio = slow / fast;
        assert!((1.8..4.5).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn tp_scaling_reduces_latency() {
        let s = st(8, 100, FREQ_MAX_MHZ);
        let t1 = decode_latency_s(&llama2_13b(1), &s);
        let t2 = decode_latency_s(&llama2_13b(2), &s);
        let t4 = decode_latency_s(&llama2_13b(4), &s);
        assert!(t1 > t2 && t2 > t4);
    }

    #[test]
    fn pipeline_slower_than_tensor() {
        use crate::config::PartitionKind::*;
        let s = st(16, 200, FREQ_MAX_MHZ);
        let tp2 = decode_latency_s(&llama2_13b_partitioned(Tensor, 2), &s);
        let pp2 = decode_latency_s(&llama2_13b_partitioned(Pipeline, 2), &s);
        assert!(pp2 > tp2 * 1.5, "pp2={pp2} tp2={tp2}");
    }

    #[test]
    fn ddp_parallelizes_batch() {
        use crate::config::PartitionKind::*;
        let ddp2 = llama2_13b_partitioned(DataParallel, 2);
        let tp1 = llama2_13b(1);
        // 16 requests over 2 replicas behave like 8 on one TP1 engine;
        // compare at the same KV *fraction* (200/240 vs 100/120).
        let t_ddp = decode_latency_s(&ddp2, &st(16, 200, FREQ_MAX_MHZ));
        let t_tp1 = decode_latency_s(&tp1, &st(8, 100, FREQ_MAX_MHZ));
        assert!(
            (t_ddp / t_tp1 - 1.0).abs() < 0.01,
            "t_ddp={t_ddp} t_tp1={t_tp1}"
        );
    }

    #[test]
    fn prefill_is_compute_bound_and_in_band() {
        // Paper §IV-F: avg prefill ~175 ms (at ~1k-token prompts).
        let e = llama2_13b(2);
        let t = prefill_latency_s(&e, 1000, FREQ_MAX_MHZ);
        assert!((0.13..0.22).contains(&t), "t={t}");
        // compute-bound: halving frequency ~doubles it.
        let t_half = prefill_latency_s(&e, 1000, FREQ_MAX_MHZ / 2);
        assert!((t_half / t - 2.0).abs() < 0.1);
    }
}
