//! Analytical A100 GPU model + DVFS actuator (the hardware substrate).
//!
//! The paper's testbed (8x NVIDIA A100 with per-GPU frequency control)
//! is unavailable; this module reproduces the *behavioural surface* the
//! coordinator observes and actuates: iteration latency as a function of
//! (batch, KV usage, frequency, parallelism), power as a function of
//! (frequency, KV usage), and a frequency actuator with the paper's
//! 200 ms switching overhead and 15 MHz quantization.
//!
//! Calibration anchors (all from paper §III, Llama2-13B TP2):
//!   * TBT in the 15-30 ms band at max frequency (Fig. 2c);
//!   * batch 1 -> 32 worsens TBT by ~45% at fixed frequency (§III-A1);
//!   * full KV cache degrades performance by ~18.2% (§III-B);
//!   * power: >2x between 210 and 1410 MHz, ~flat vs batch (Fig. 2d);
//!   * tokens/Joule sweet spot at ~1050 MHz, +37.4% vs 1410 MHz at
//!     batch 32; below ~840 MHz efficiency decays again (Fig. 2e);
//!   * Pearson(KV, TBT) ~ 0.92 at constant batch (Fig. 3d).
//!
//! `tests/gpusim_calibration.rs` asserts each anchor.

pub mod dvfs;
pub mod latency;
pub mod power;

pub use dvfs::{DvfsActuator, FREQ_MAX_MHZ, FREQ_MIN_MHZ, FREQ_STEP_MHZ};
pub use latency::{decode_latency_s, prefill_latency_s, GpuState};
pub use power::power_w;
