//! Power model: `P(freq, kv, batch, engine)` in Watts.
//!
//! Shape (paper §III-A1, Fig. 2d and §III-B, Fig. 3c):
//!   * power rises >2x from 210 MHz to 1410 MHz;
//!   * ~flat across batch sizes at fixed frequency;
//!   * positive correlation with allocated KV blocks (DRAM reads),
//!     steeper at higher frequencies;
//!   * a voltage floor below ~1100 MHz makes dynamic power ~linear in
//!     f at the bottom of the range and ~f*V(f)^2 at the top — this is
//!     what creates the tokens/Joule sweet spot at ~1050 MHz instead of
//!     at the minimum frequency.

use crate::config::EngineSpec;

/// Per-GPU static/idle power (SMs gated but HBM + board active), W.
const P_STATIC_W: f64 = 100.0;
/// Per-GPU dynamic-power span at fn=1, W.
const P_DYN_W: f64 = 138.0;
/// Per-GPU KV-traffic power at full cache and fn=1, W.
const P_KV_W: f64 = 15.0;
/// Per-request power (scheduling overhead), W — small: power is
/// "primarily influenced by the GPU's operating frequency rather than
/// workload size" (paper).
const P_BATCH_W: f64 = 0.15;

/// DVFS voltage floor: below this normalized frequency the voltage
/// rail is pinned (A100 V/F curves flatten near ~1100 MHz).
const V_FLOOR_FN: f64 = 0.78;
const V_FLOOR: f64 = 0.78;
const V_SLOPE: f64 = 1.1;

/// Normalized dynamic-power factor fn * V(fn)^2, scaled so pdyn(1) = 1.
#[inline]
fn pdyn_norm(fnorm: f64) -> f64 {
    let v = if fnorm > V_FLOOR_FN {
        V_FLOOR + V_SLOPE * (fnorm - V_FLOOR_FN)
    } else {
        V_FLOOR
    };
    let v_max = V_FLOOR + V_SLOPE * (1.0 - V_FLOOR_FN);
    (fnorm * v * v) / (1.0 * v_max * v_max)
}

/// Whole-engine power draw, Watts (sums every GPU the engine occupies).
pub fn power_w(spec: &EngineSpec, batch: u32, kv_blocks: u32, freq_mhz: u32) -> f64 {
    let fnorm =
        (freq_mhz as f64 / super::dvfs::FREQ_MAX_MHZ as f64).clamp(0.05, 1.0);
    let kv_frac = (kv_blocks as f64 / spec.kv_blocks as f64).min(1.0);
    // detlint: allow(r1, reason = "load-bearing std math: energy golden digests are blessed against std powf here")
    let kv_term = P_KV_W * kv_frac * fnorm.powf(1.5);
    let per_gpu = P_STATIC_W
        + P_DYN_W * pdyn_norm(fnorm)
        + kv_term
        + P_BATCH_W * batch as f64 / spec.n_gpus as f64;
    per_gpu * spec.n_gpus as f64
}

/// Idle power of a (shadow/warm) engine holding no batch, Watts.
pub fn idle_power_w(spec: &EngineSpec, freq_mhz: u32) -> f64 {
    power_w(spec, 0, 0, freq_mhz)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::models::llama2_13b;
    use crate::gpusim::dvfs::{FREQ_MAX_MHZ, FREQ_MIN_MHZ};
    use crate::gpusim::latency::{decode_latency_s, GpuState};

    #[test]
    fn power_more_than_doubles_over_freq_range() {
        let e = llama2_13b(2);
        let lo = power_w(&e, 16, 220, FREQ_MIN_MHZ);
        let hi = power_w(&e, 16, 220, FREQ_MAX_MHZ);
        let ratio = hi / lo;
        assert!(ratio > 2.0, "ratio={ratio}");
        assert!(ratio < 3.0, "ratio={ratio}");
    }

    #[test]
    fn power_roughly_flat_in_batch() {
        let e = llama2_13b(2);
        let p1 = power_w(&e, 1, 220, FREQ_MAX_MHZ);
        let p32 = power_w(&e, 32, 220, FREQ_MAX_MHZ);
        assert!((p32 - p1) / p1 < 0.03, "p1={p1} p32={p32}");
    }

    #[test]
    fn power_increases_with_kv_steeper_at_high_freq() {
        let e = llama2_13b(2);
        let slope_hi = power_w(&e, 32, e.kv_blocks, FREQ_MAX_MHZ)
            - power_w(&e, 32, 0, FREQ_MAX_MHZ);
        let slope_lo =
            power_w(&e, 32, e.kv_blocks, 420) - power_w(&e, 32, 0, 420);
        assert!(slope_hi > 0.0 && slope_lo > 0.0);
        assert!(slope_hi > 2.0 * slope_lo, "hi={slope_hi} lo={slope_lo}");
    }

    #[test]
    fn power_scales_with_gpu_count() {
        let p2 = power_w(&llama2_13b(2), 8, 100, FREQ_MAX_MHZ);
        let p4 = power_w(&llama2_13b(4), 8, 100, FREQ_MAX_MHZ);
        assert!((p4 / p2 - 2.0).abs() < 0.1);
    }

    #[test]
    fn efficiency_sweet_spot_near_1050() {
        // Paper Fig. 2e: tokens/J peaks ~1050 MHz, +37.4% vs 1410 at
        // batch 32; low frequencies are inefficient again.
        let e = llama2_13b(2);
        let tpj = |f: u32| {
            let st = GpuState {
                batch: 32,
                kv_blocks: 220,
                freq_mhz: f,
            };
            let tbt = decode_latency_s(&e, &st);
            let tps = 32.0 / tbt;
            tps / power_w(&e, 32, 220, f)
        };
        // argmax over the frequency grid
        let mut best_f = 0;
        let mut best = 0.0;
        let mut f = FREQ_MIN_MHZ;
        while f <= FREQ_MAX_MHZ {
            let v = tpj(f);
            if v > best {
                best = v;
                best_f = f;
            }
            f += 15;
        }
        assert!(
            (930..=1170).contains(&best_f),
            "sweet spot at {best_f} MHz"
        );
        let boost = tpj(1050) / tpj(FREQ_MAX_MHZ) - 1.0;
        assert!((0.25..0.50).contains(&boost), "boost={boost}");
        // 210 MHz is NOT efficient (within ~15% of max-freq TPJ).
        let low = tpj(FREQ_MIN_MHZ) / tpj(FREQ_MAX_MHZ);
        assert!(low < 1.15, "low-freq TPJ ratio={low}");
    }

    #[test]
    fn idle_power_positive_but_below_loaded() {
        let e = llama2_13b(2);
        assert!(idle_power_w(&e, 210) > 0.0);
        assert!(idle_power_w(&e, 1410) < power_w(&e, 32, 400, 1410));
    }
}
