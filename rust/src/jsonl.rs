//! Minimal JSON writer + reader (serde substitute, offline build).
//!
//! Covers exactly what the repo needs: emitting experiment results as
//! JSON/JSONL, and parsing `artifacts/manifest.json` (objects, arrays,
//! strings, numbers, bools, null — no exotic escapes beyond \uXXXX).

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|x| x as u64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize (compact).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document.
pub fn parse(text: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        b: text.as_bytes(),
        i: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != p.b.len() {
        anyhow::bail!("trailing characters at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && self.b[self.i].is_ascii_whitespace() {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> anyhow::Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|x| x as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> anyhow::Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            anyhow::bail!("bad literal at byte {}", self.i)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit()
                || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E')
            {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.b
                                    .get(self.i + 1..self.i + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(
                                char::from_u32(code).unwrap_or('\u{fffd}'),
                            );
                            self.i += 4;
                        }
                        other => anyhow::bail!("bad escape {other:?}"),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance one UTF-8 char
                    let s = std::str::from_utf8(&self.b[self.i..])?;
                    let c = s.chars().next().ok_or_else(|| {
                        anyhow::anyhow!("truncated UTF-8 at byte {}", self.i)
                    })?;
                    out.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("bad array sep {other:?}"),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("bad object sep {other:?}"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("name", Json::Str("throttLL'eM".into())),
            ("n", Json::Num(42.0)),
            ("pi", Json::Num(3.25)),
            ("ok", Json::Bool(true)),
            ("none", Json::Null),
            (
                "arr",
                Json::Arr(vec![Json::Num(1.0), Json::Num(2.0)]),
            ),
        ]);
        let text = j.to_string();
        let back = parse(&text).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parses_manifest_like_document() {
        let text = r#"{
            "model": "tiny-llama-sim",
            "config": {"vocab": 256, "d_model": 64},
            "batches": [1, 2, 4, 8],
            "weights": {"file": "weights.bin", "count": 115072}
        }"#;
        let j = parse(text).unwrap();
        assert_eq!(j.get("model").unwrap().as_str(), Some("tiny-llama-sim"));
        assert_eq!(
            j.get("config").unwrap().get("vocab").unwrap().as_u64(),
            Some(256)
        );
        assert_eq!(j.get("batches").unwrap().as_arr().unwrap().len(), 4);
    }

    #[test]
    fn string_escapes() {
        let j = Json::Str("a\"b\\c\nd\te".into());
        let back = parse(&j.to_string()).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn unicode_escape() {
        let j = parse(r#""Aé""#).unwrap();
        assert_eq!(j.as_str(), Some("Aé"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn negative_and_exponent_numbers() {
        let j = parse("[-1.5, 2e3, -4E-2]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(-1.5));
        assert_eq!(a[1].as_f64(), Some(2000.0));
        assert!((a[2].as_f64().unwrap() + 0.04).abs() < 1e-12);
    }
}
