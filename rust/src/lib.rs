//! # throttLL'eM — SLO-aware GPU frequency scaling for energy-efficient
//! LLM inference serving (paper reproduction).
//!
//! Layer-3 (Rust) of the three-layer Rust + JAX + Pallas stack.  The
//! crate implements the paper's coordination contribution — KV/batch
//! projection, an iteration-level GBDT performance model, SLO admission
//! control, a binary-search GPU frequency throttling controller, and a
//! tensor-parallelism autoscaler — together with every substrate it
//! depends on: a discrete-event A100/DVFS simulator, a paged-KV inflight
//! batching engine, an Azure-like workload synthesizer, a Triton-like
//! baseline, gradient-boosted decision trees, and a PJRT runtime that
//! executes the AOT-compiled tiny-llama-sim artifacts (Python never runs
//! on the request path).
//!
//! Start at [`coordinator::server::serve_fleet`] for the full system
//! (a fleet of one is the paper's single-engine deployment), or
//! `examples/quickstart.rs` for a 5-minute tour.

pub mod bench_util;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod engine;
pub mod gpusim;
pub mod jsonl;
pub mod lint;
pub mod metrics;
pub mod mlmodel;
pub mod runtime;
pub mod sim;
pub mod testutil;
pub mod workload;

pub mod baseline {
    //! Triton-like baseline servers (max frequency, KV-only admission).
    pub use crate::coordinator::server::{serve_trace, Policy, ServeOutcome};
}

/// Crate-wide result alias.
pub type Result<T> = anyhow::Result<T>;
