// detlint-fixture: virtual-path = rust/src/sim/faults_fixture.rs
// detlint-expect: r2 @ 11
// detlint-expect: r3 @ 15

// The fault module sits inside detlint's outcome-affecting scope
// (rust/src/sim/): hash-ordered iteration over per-replica fault state
// and wall-clock stamps in the schedule are exactly the bugs that would
// break the --threads N identity of a faulted run.

pub fn total_downtime(by_replica: &std::collections::HashMap<u32, f64>) -> f64 {
    by_replica.values().sum()
}

pub fn fault_stamp() -> std::time::Instant {
    std::time::Instant::now()
}
