// detlint-fixture: virtual-path = rust/src/workload/forecast_clean_fixture.rs

// The deterministic shape of the same forecaster logic: detmath free
// functions for the harmonic basis (bit-identical on every platform),
// IEEE-exact float arithmetic for the exponential smoothing, and time
// taken from the simulation clock the caller passes in.

use crate::sim::detmath::{cos_det, sin_det};

pub fn harmonic_basis(t_s: f64, period_s: f64) -> (f64, f64) {
    let phase = core::f64::consts::TAU * (t_s / period_s);
    (sin_det(phase), cos_det(phase))
}

pub fn ewma(level: f64, sample: f64, alpha: f64) -> f64 {
    alpha * sample + (1.0 - alpha) * level
}

pub fn bucket(t_s: f64, interval_s: f64) -> u64 {
    (t_s / interval_s).floor() as u64
}
