// detlint-fixture: virtual-path = rust/src/workload/forecast_fixture.rs
// detlint-expect: r1 @ 14
// detlint-expect: r1 @ 18
// detlint-expect: r3 @ 22

// The arrival forecaster sits inside detlint's outcome-affecting
// scope (rust/src/workload/): a std-library harmonic fit (libm
// sin/cos differs across platforms in the last ulp) or OS entropy in
// the observation path are exactly the bugs that would break the
// --threads N bit-identity of a predictive run.  The real forecaster
// uses sim/detmath and simulated time exclusively.

pub fn harmonic_sin(phase: f64) -> f64 {
    phase.sin()
}

pub fn harmonic_cos(phase: f64) -> f64 {
    phase.cos()
}

pub fn jitter(bound: f64) -> f64 {
    let mut rng = rand::thread_rng();
    rand::Rng::gen_range(&mut rng, 0.0..bound)
}
