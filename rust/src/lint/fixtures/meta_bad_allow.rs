// detlint-fixture: virtual-path = rust/src/sim/fixture_bad_allow.rs
// detlint-expect: bad-allow @ 5
// detlint-expect: r1 @ 6

// detlint: allow(r1)
pub fn f(x: f64) -> f64 { x.exp() }
