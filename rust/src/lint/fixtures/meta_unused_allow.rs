// detlint-fixture: virtual-path = rust/src/sim/fixture_unused_allow.rs
// detlint-expect: unused-allow @ 4

// detlint: allow(r1, reason = "nothing underneath violates r1")
pub fn f(x: f64) -> f64 { x.sqrt() }
