// detlint-fixture: virtual-path = rust/src/gpusim/fixture_r1_clean.rs

pub fn safe(p: f64) -> f64 {
    // detlint: allow(r1, reason = "fixture: std exp is load-bearing here")
    let e = p.exp();
    // sqrt is IEEE-exact (correctly rounded on every platform): exempt.
    e + p.sqrt()
}
