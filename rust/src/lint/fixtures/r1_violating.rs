// detlint-fixture: virtual-path = rust/src/gpusim/fixture_r1.rs
// detlint-expect: r1 @ 5

pub fn energy(p: f64) -> f64 {
    p.exp() * 2.0
}
