// detlint-fixture: virtual-path = rust/src/engine/fixture_r2_clean.rs

pub fn lookup(m: &std::collections::HashMap<u64, u64>, k: u64) -> Option<u64> {
    // Keyed access never observes iteration order.
    m.get(&k).copied()
}

pub fn count(m: &std::collections::HashMap<u64, u64>) -> usize {
    // detlint: allow(r2, reason = "fixture: count is order-independent")
    m.values().count()
}
