// detlint-fixture: virtual-path = rust/src/coordinator/fixture_r2.rs
// detlint-expect: r2 @ 7
// detlint-expect: r2 @ 10

pub fn sum_all(m: &std::collections::HashMap<u64, u64>) -> u64 {
    let mut total = 0;
    for (_, v) in m {
        total += v;
    }
    total + m.values().sum::<u64>()
}
