// detlint-fixture: virtual-path = rust/benches/fixture_r3_clean.rs

// Benches run on the wall clock by definition: out of r3's scope.
pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
