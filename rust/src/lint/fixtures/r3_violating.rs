// detlint-fixture: virtual-path = rust/src/sim/fixture_r3.rs
// detlint-expect: r3 @ 5

pub fn stamp() -> std::time::Instant {
    std::time::Instant::now()
}
