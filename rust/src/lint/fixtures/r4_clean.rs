// detlint-fixture: virtual-path = rust/src/coordinator/fixture_r4_clean.rs

// detlint: hot
pub fn hot_accumulate(acc: &mut Vec<u64>, x: u64) {
    // Push into caller-owned capacity only grows amortized; the
    // runtime audit in perf_hotpath checks steady-state counts.
    acc.push(x);
    let y = x.clone();
    acc.push(y);
}

pub fn cold_alloc() -> Vec<u64> {
    vec![1, 2, 3]
}
