// detlint-fixture: virtual-path = rust/src/coordinator/fixture_r4.rs
// detlint-expect: r4 @ 7
// detlint-expect: r4 @ 9

// detlint: hot
pub fn hot_sum(xs: &[u64]) -> Vec<u64> {
    let mut out = vec![0u64];
    out.extend(xs.iter().map(|x| x * 2));
    let flat: Vec<u64> = out.iter().copied().collect();
    flat
}
