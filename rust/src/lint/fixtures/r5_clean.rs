// detlint-fixture: virtual-path = rust/benches/perf_hotpath.rs

// The counting allocator's file is the one whitelisted unsafe site.
pub fn counted() -> u64 {
    unsafe { core::mem::transmute::<i64, u64>(-1) }
}
