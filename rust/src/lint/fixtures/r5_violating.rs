// detlint-fixture: virtual-path = rust/src/engine/fixture_r5.rs
// detlint-expect: r5 @ 5

pub fn peek(xs: &[u64]) -> u64 {
    unsafe { *xs.get_unchecked(0) }
}
