//! A minimal, line/column-tracking Rust lexer for `detlint`.
//!
//! This is not a full Rust lexer — it is exactly enough to let the
//! rule engine in [`super::rules`] match token *sequences* (`.` `exp`
//! `(`) instead of raw text, which is what makes the rules immune to
//! pattern strings appearing inside string literals or comments.  The
//! tricky cases it does handle correctly:
//!
//! - line comments, nested block comments (captured separately so the
//!   directive parser can see `// detlint: ...` annotations),
//! - string literals with escapes, raw strings `r#"..."#` (any hash
//!   depth), byte strings,
//! - lifetimes (`'a`) vs. char literals (`'x'`, `'\n'`),
//! - numeric literals including float forms (`1.5`, `1e-9`, `10.0f64`)
//!   so `1.5.powf(...)` and `0..n` tokenize unambiguously.
//!
//! Everything else becomes single-character punctuation tokens.

/// Token classes the rule engine distinguishes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TokKind {
    /// Identifier or keyword (`fn`, `for`, `unsafe`, `HashMap`, ...).
    Ident,
    /// Numeric literal (integer or float, with suffix).
    Num,
    /// String literal of any flavor (`"..."`, `r#"..."#`, `b"..."`).
    Str,
    /// Char or byte-char literal (`'x'`, `b'\n'`).
    Char,
    /// Lifetime (`'a`, `'static`).
    Lifetime,
    /// A single punctuation character (`.`, `:`, `{`, `!`, ...).
    Punct,
}

/// One token with its 1-based source position.
#[derive(Debug, Clone)]
pub struct Tok {
    pub kind: TokKind,
    pub text: String,
    pub line: u32,
    pub col: u32,
}

impl Tok {
    /// True for a punctuation token of exactly this character.
    pub fn is_punct(&self, c: char) -> bool {
        self.kind == TokKind::Punct && self.text.len() == 1 && self.text.starts_with(c)
    }

    /// True for an identifier token with exactly this text.
    pub fn is_ident(&self, s: &str) -> bool {
        self.kind == TokKind::Ident && self.text == s
    }
}

/// One comment (text without the `//` / `/* */` markers, trimmed).
#[derive(Debug, Clone)]
pub struct Comment {
    pub text: String,
    pub line: u32,
    pub col: u32,
}

/// Lexer output: code tokens plus the comment stream.
#[derive(Debug, Default)]
pub struct Lexed {
    pub tokens: Vec<Tok>,
    pub comments: Vec<Comment>,
}

/// Lex `src` into tokens and comments.  Never fails: unterminated
/// constructs simply run to end-of-file (the rule engine tolerates a
/// truncated tail — real compilation errors are rustc's job).
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let mut out = Lexed::default();
    let mut i = 0usize;
    let mut line = 1u32;
    let mut col = 1u32;

    // Advance one char, maintaining line/col.
    macro_rules! bump {
        () => {{
            if chars[i] == '\n' {
                line += 1;
                col = 1;
            } else {
                col += 1;
            }
            i += 1;
        }};
    }

    while i < chars.len() {
        let c = chars[i];
        let (tline, tcol) = (line, col);

        // Whitespace.
        if c.is_whitespace() {
            bump!();
            continue;
        }

        // Line comment.
        if c == '/' && chars.get(i + 1) == Some(&'/') {
            let start = i + 2;
            let mut end = start;
            while end < chars.len() && chars[end] != '\n' {
                end += 1;
            }
            out.comments.push(Comment {
                text: chars[start..end].iter().collect::<String>().trim().to_string(),
                line: tline,
                col: tcol,
            });
            while i < end {
                bump!();
            }
            continue;
        }

        // Block comment (nested, per Rust).
        if c == '/' && chars.get(i + 1) == Some(&'*') {
            let start = i + 2;
            let mut depth = 1u32;
            bump!();
            bump!();
            let mut text_end = i;
            while i < chars.len() && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    bump!();
                    bump!();
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    bump!();
                    bump!();
                    if depth == 0 {
                        break;
                    }
                } else {
                    bump!();
                }
                text_end = i;
            }
            out.comments.push(Comment {
                text: chars[start..text_end.min(chars.len())]
                    .iter()
                    .collect::<String>()
                    .trim()
                    .to_string(),
                line: tline,
                col: tcol,
            });
            continue;
        }

        // Raw strings and byte strings: r"..", r#".."#, br#".."#, b"..".
        if c == 'r' || c == 'b' {
            let mut j = i + 1;
            if c == 'b' && chars.get(j) == Some(&'r') {
                j += 1;
            }
            let mut hashes = 0usize;
            while chars.get(j) == Some(&'#') {
                hashes += 1;
                j += 1;
            }
            let is_raw = j > i + 1 || (chars.get(i + 1) == Some(&'"') && c == 'r');
            if chars.get(j) == Some(&'"') && (is_raw || c == 'b') {
                // Consume prefix + opening quote.
                while i <= j {
                    bump!();
                }
                if hashes == 0 && !is_raw {
                    // b"..." — escaped string body.
                    while i < chars.len() {
                        if chars[i] == '\\' && i + 1 < chars.len() {
                            bump!();
                            bump!();
                        } else if chars[i] == '"' {
                            bump!();
                            break;
                        } else {
                            bump!();
                        }
                    }
                } else {
                    // Raw body: ends at `"` followed by `hashes` hashes.
                    while i < chars.len() {
                        if chars[i] == '"' {
                            let mut k = 0usize;
                            while k < hashes && chars.get(i + 1 + k) == Some(&'#') {
                                k += 1;
                            }
                            if k == hashes {
                                for _ in 0..=hashes {
                                    bump!();
                                }
                                break;
                            }
                        }
                        bump!();
                    }
                }
                out.tokens.push(Tok {
                    kind: TokKind::Str,
                    text: String::new(),
                    line: tline,
                    col: tcol,
                });
                continue;
            }
            if c == 'b' && chars.get(i + 1) == Some(&'\'') {
                // Byte char b'x'.
                bump!(); // b
                bump!(); // '
                while i < chars.len() {
                    if chars[i] == '\\' && i + 1 < chars.len() {
                        bump!();
                        bump!();
                    } else if chars[i] == '\'' {
                        bump!();
                        break;
                    } else {
                        bump!();
                    }
                }
                out.tokens.push(Tok {
                    kind: TokKind::Char,
                    text: String::new(),
                    line: tline,
                    col: tcol,
                });
                continue;
            }
            // Fall through: plain identifier starting with r/b.
        }

        // Plain string literal.
        if c == '"' {
            bump!();
            while i < chars.len() {
                if chars[i] == '\\' && i + 1 < chars.len() {
                    bump!();
                    bump!();
                } else if chars[i] == '"' {
                    bump!();
                    break;
                } else {
                    bump!();
                }
            }
            out.tokens.push(Tok {
                kind: TokKind::Str,
                text: String::new(),
                line: tline,
                col: tcol,
            });
            continue;
        }

        // Lifetime vs. char literal.
        if c == '\'' {
            let next = chars.get(i + 1).copied();
            let after = chars.get(i + 2).copied();
            let is_char = match next {
                Some('\\') => true,
                Some(n) if (n.is_alphanumeric() || n == '_') && after == Some('\'') => true,
                Some(n) if !n.is_alphabetic() && n != '_' => true, // e.g. '(' — malformed, treat as char
                _ => false,
            };
            if is_char {
                bump!(); // '
                while i < chars.len() {
                    if chars[i] == '\\' && i + 1 < chars.len() {
                        bump!();
                        bump!();
                    } else if chars[i] == '\'' {
                        bump!();
                        break;
                    } else {
                        bump!();
                    }
                }
                out.tokens.push(Tok {
                    kind: TokKind::Char,
                    text: String::new(),
                    line: tline,
                    col: tcol,
                });
            } else {
                // Lifetime: `'` + ident chars.
                bump!();
                let start = i;
                while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                    bump!();
                }
                out.tokens.push(Tok {
                    kind: TokKind::Lifetime,
                    text: chars[start..i].iter().collect(),
                    line: tline,
                    col: tcol,
                });
            }
            continue;
        }

        // Identifier / keyword.
        if c.is_alphabetic() || c == '_' {
            let start = i;
            while i < chars.len() && (chars[i].is_alphanumeric() || chars[i] == '_') {
                bump!();
            }
            out.tokens.push(Tok {
                kind: TokKind::Ident,
                text: chars[start..i].iter().collect(),
                line: tline,
                col: tcol,
            });
            continue;
        }

        // Number: digits, `_`, hex/oct/bin, float `.` + digit, exponent,
        // and trailing type suffix (`1.5f64`, `10u32`).
        if c.is_ascii_digit() {
            let start = i;
            bump!();
            if chars.get(i).map(|c| *c == 'x' || *c == 'o' || *c == 'b') == Some(true)
                && chars[start] == '0'
            {
                bump!();
                while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    bump!();
                }
            } else {
                while i < chars.len() && (chars[i].is_ascii_digit() || chars[i] == '_') {
                    bump!();
                }
                // Fractional part ONLY if `.` is followed by a digit —
                // so `1.5` is one token while `0..n` and `1.max(x)` are not.
                if chars.get(i) == Some(&'.')
                    && chars.get(i + 1).map(|c| c.is_ascii_digit()) == Some(true)
                {
                    bump!();
                    while i < chars.len() && (chars[i].is_ascii_digit() || chars[i] == '_') {
                        bump!();
                    }
                }
                // Exponent.
                if chars.get(i).map(|c| *c == 'e' || *c == 'E') == Some(true)
                    && chars
                        .get(i + 1)
                        .map(|c| c.is_ascii_digit() || *c == '+' || *c == '-')
                        == Some(true)
                {
                    bump!();
                    bump!();
                    while i < chars.len() && (chars[i].is_ascii_digit() || chars[i] == '_') {
                        bump!();
                    }
                }
                // Suffix (`f64`, `u32`, ...).
                while i < chars.len() && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                    bump!();
                }
            }
            out.tokens.push(Tok {
                kind: TokKind::Num,
                text: chars[start..i].iter().collect(),
                line: tline,
                col: tcol,
            });
            continue;
        }

        // Single punctuation char.
        out.tokens.push(Tok {
            kind: TokKind::Punct,
            text: c.to_string(),
            line: tline,
            col: tcol,
        });
        bump!();
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn idents(src: &str) -> Vec<String> {
        lex(src)
            .tokens
            .into_iter()
            .filter(|t| t.kind == TokKind::Ident)
            .map(|t| t.text)
            .collect()
    }

    #[test]
    fn comments_are_not_tokens() {
        let l = lex("let x = 1; // trailing .exp()\n/* block .ln() */ let y = 2;");
        assert_eq!(l.comments.len(), 2);
        assert_eq!(l.comments[0].text, "trailing .exp()");
        assert_eq!(l.comments[1].text, "block .ln()");
        assert!(l.tokens.iter().all(|t| t.text != "exp" && t.text != "ln"));
    }

    #[test]
    fn nested_block_comments() {
        let l = lex("/* a /* b */ c */ fn f() {}");
        assert_eq!(l.comments.len(), 1);
        assert_eq!(idents("/* a /* b */ c */ fn f() {}"), vec!["fn", "f"]);
    }

    #[test]
    fn strings_hide_their_contents() {
        let src = r##"let s = "call .exp() here"; let r = r#"raw .ln()"#;"##;
        let l = lex(src);
        assert!(l.tokens.iter().all(|t| t.text != "exp" && t.text != "ln"));
        assert_eq!(
            l.tokens.iter().filter(|t| t.kind == TokKind::Str).count(),
            2
        );
    }

    #[test]
    fn lifetime_vs_char_literal() {
        let l = lex("fn f<'a>(x: &'a str) { let c = 'x'; let n = '\\n'; }");
        let lifetimes: Vec<_> = l
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Lifetime)
            .collect();
        let chars: Vec<_> = l.tokens.iter().filter(|t| t.kind == TokKind::Char).collect();
        assert_eq!(lifetimes.len(), 2);
        assert_eq!(chars.len(), 2);
    }

    #[test]
    fn float_method_calls_tokenize() {
        // `1.5.powf(2.0)` → Num(1.5) Punct(.) Ident(powf) ...
        let l = lex("let y = 1.5.powf(2.0); let r = 0..n;");
        let toks: Vec<_> = l.tokens.iter().map(|t| t.text.as_str()).collect();
        assert!(toks.contains(&"1.5"));
        assert!(toks.contains(&"powf"));
        assert!(toks.contains(&"2.0"));
        // Range `0..n` keeps its two dots as punctuation.
        let dots = l.tokens.iter().filter(|t| t.is_punct('.')).count();
        assert_eq!(dots, 3);
    }

    #[test]
    fn number_suffixes_and_exponents() {
        let l = lex("let a = 10.0f64; let b = 1e-9; let c = 0xff_u32;");
        let nums: Vec<_> = l
            .tokens
            .iter()
            .filter(|t| t.kind == TokKind::Num)
            .map(|t| t.text.as_str())
            .collect();
        assert_eq!(nums, vec!["10.0f64", "1e-9", "0xff_u32"]);
    }

    #[test]
    fn positions_are_one_based_and_tracked() {
        let l = lex("fn f() {\n    let x = 1;\n}");
        let x = l.tokens.iter().find(|t| t.is_ident("x")).unwrap();
        assert_eq!((x.line, x.col), (2, 9));
    }
}
