//! `detlint` — the repo's in-tree determinism & hot-path static
//! analyzer (no external deps).
//!
//! The determinism contract (byte-identical traces cross-platform,
//! `--threads N` bit-identical to `--threads 1`) is otherwise enforced
//! only at runtime, *after* a nondeterminism hazard has shipped and
//! broken a golden hash.  This pass turns the contract into
//! source-level rules: [`rules::lint_source`] runs a hand-rolled lexer
//! ([`lexer`]) plus five token-sequence rules over every `.rs` file
//! under `rust/src`, `rust/tests`, `rust/benches`, and `examples`.
//!
//! Entry points:
//! - [`run_lint`] — walk the repo and collect diagnostics (used by the
//!   `detlint` binary and by the tier-1 `repo_is_lint_clean` test).
//! - [`selftest`] — lint the committed fixture snippets in
//!   `rust/src/lint/fixtures/` and check each produces exactly its
//!   `// detlint-expect:` diagnostics (violating fixtures) or none
//!   (clean fixtures).
//!
//! Run it locally with `cargo run --bin detlint` (see README "Static
//! analysis" for the rule catalog and annotation syntax).

pub mod lexer;
pub mod rules;

use std::path::{Path, PathBuf};

pub use rules::{lint_source, Diag, RULE_NAMES};

/// Directories scanned by [`run_lint`], relative to the repo root.
pub const SCAN_DIRS: [&str; 4] = ["rust/src", "rust/tests", "rust/benches", "examples"];

/// Fixture snippets live here and are excluded from [`run_lint`]
/// (they are *supposed* to violate; [`selftest`] lints them under
/// their `detlint-fixture: virtual-path` instead).
pub const FIXTURES_DIR: &str = "rust/src/lint/fixtures";

/// Result of a full repo lint.
#[derive(Debug)]
pub struct LintReport {
    /// All diagnostics, sorted by (path, line, col).
    pub diags: Vec<Diag>,
    /// Number of files scanned.
    pub files: usize,
}

impl LintReport {
    pub fn clean(&self) -> bool {
        self.diags.is_empty()
    }
}

/// Recursively collect `.rs` files under `dir` in sorted order (the
/// walk order is part of the deterministic-output contract of the
/// tool itself).
fn collect_rs(dir: &Path, out: &mut Vec<PathBuf>) -> anyhow::Result<()> {
    if !dir.is_dir() {
        return Ok(());
    }
    let mut entries: Vec<PathBuf> = std::fs::read_dir(dir)
        .map_err(|e| anyhow::anyhow!("{}: {e}", dir.display()))?
        .collect::<Result<Vec<_>, _>>()
        .map_err(|e| anyhow::anyhow!("{}: {e}", dir.display()))?
        .into_iter()
        .map(|e| e.path())
        .collect();
    entries.sort();
    for p in entries {
        if p.is_dir() {
            collect_rs(&p, out)?;
        } else if p.extension().map(|x| x == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
    Ok(())
}

/// Repo-relative, '/'-separated path (the form the path-scoped rules
/// and the whitelists match against).
fn rel_path(root: &Path, p: &Path) -> String {
    let rel = p.strip_prefix(root).unwrap_or(p);
    rel.components()
        .map(|c| c.as_os_str().to_string_lossy().into_owned())
        .collect::<Vec<_>>()
        .join("/")
}

/// Lint every scanned file under `root`.  Diagnostics come back sorted
/// by (path, line, col); an empty list means the repo is lint-clean.
pub fn run_lint(root: &Path) -> anyhow::Result<LintReport> {
    let mut files = Vec::new();
    for d in SCAN_DIRS {
        collect_rs(&root.join(d), &mut files)?;
    }
    let mut diags = Vec::new();
    let mut scanned = 0usize;
    for f in &files {
        let rel = rel_path(root, f);
        if rel.starts_with(FIXTURES_DIR) {
            continue;
        }
        let src = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("{}: {e}", f.display()))?;
        diags.extend(lint_source(&rel, &src));
        scanned += 1;
    }
    diags.sort_by(|a, b| {
        (a.path.as_str(), a.line, a.col, a.rule).cmp(&(b.path.as_str(), b.line, b.col, b.rule))
    });
    Ok(LintReport {
        diags,
        files: scanned,
    })
}

/// Outcome of linting one fixture against its expectations.
#[derive(Debug)]
pub struct FixtureResult {
    pub file: String,
    pub virtual_path: String,
    pub expects: usize,
    pub ok: bool,
    pub detail: String,
}

/// Lint every fixture in `<root>/rust/src/lint/fixtures/` under its
/// declared virtual path and diff the produced diagnostics against the
/// `// detlint-expect: <rule> @ <line>` annotations.  Also checks the
/// fixture set itself covers all of r1..r5 plus the bad-allow and
/// unused-allow meta-rules, with at least one clean fixture per rule.
pub fn selftest(root: &Path) -> anyhow::Result<Vec<FixtureResult>> {
    let dir = root.join(FIXTURES_DIR);
    let mut files = Vec::new();
    collect_rs(&dir, &mut files)?;
    anyhow::ensure!(
        !files.is_empty(),
        "no fixtures found under {}",
        dir.display()
    );

    let mut results = Vec::new();
    let mut rules_violated: Vec<&str> = Vec::new();
    let mut clean_count = 0usize;
    for f in &files {
        let name = f
            .file_name()
            .map(|n| n.to_string_lossy().into_owned())
            .unwrap_or_default();
        let src = std::fs::read_to_string(f)
            .map_err(|e| anyhow::anyhow!("{}: {e}", f.display()))?;
        let lexed = lexer::lex(&src);

        let mut virtual_path: Option<String> = None;
        let mut expects: Vec<(String, u32)> = Vec::new();
        let mut header_err: Option<String> = None;
        for c in &lexed.comments {
            if let Some(vp) = c.text.strip_prefix("detlint-fixture:") {
                match vp.trim().strip_prefix("virtual-path").map(|s| s.trim_start()) {
                    Some(rest) => match rest.strip_prefix('=') {
                        Some(p) => virtual_path = Some(p.trim().to_string()),
                        None => header_err = Some(format!("bad fixture header {vp:?}")),
                    },
                    None => header_err = Some(format!("bad fixture header {vp:?}")),
                }
            } else if let Some(e) = c.text.strip_prefix("detlint-expect:") {
                match parse_expect(e.trim()) {
                    Ok(pair) => expects.push(pair),
                    Err(why) => header_err = Some(why),
                }
            }
        }

        let (ok, detail, vp, n_expect) = match (header_err, virtual_path) {
            (Some(e), _) => (false, e, String::new(), expects.len()),
            (None, None) => (
                false,
                "missing `// detlint-fixture: virtual-path = ...` header".to_string(),
                String::new(),
                expects.len(),
            ),
            (None, Some(vp)) => {
                let mut got: Vec<(String, u32)> = lint_source(&vp, &src)
                    .into_iter()
                    .map(|d| (d.rule.to_string(), d.line))
                    .collect();
                got.sort();
                expects.sort();
                if got == expects {
                    (true, String::new(), vp, expects.len())
                } else {
                    (
                        false,
                        format!("expected {expects:?}, got {got:?}"),
                        vp,
                        expects.len(),
                    )
                }
            }
        };
        for (r, _) in &expects {
            if !rules_violated.iter().any(|x| x == r) {
                // Only count the five real rules for coverage.
                if let Some(r) = RULE_NAMES.iter().find(|n| **n == r.as_str()) {
                    rules_violated.push(r);
                }
            }
        }
        if ok && n_expect == 0 {
            clean_count += 1;
        }
        results.push(FixtureResult {
            file: name,
            virtual_path: vp,
            expects: n_expect,
            ok,
            detail,
        });
    }

    // Coverage bars: one violating fixture per rule, one clean fixture
    // per rule, and the two meta-rules exercised.
    for r in RULE_NAMES {
        anyhow::ensure!(
            rules_violated.contains(&r),
            "fixture coverage gap: no violating fixture for {r}"
        );
    }
    anyhow::ensure!(
        clean_count >= RULE_NAMES.len(),
        "fixture coverage gap: expected at least {} clean fixtures, found {clean_count}",
        RULE_NAMES.len()
    );
    for meta in ["bad-allow", "unused-allow"] {
        let covered = results.iter().any(|r| r.ok && r.file.contains(meta.replace('-', "_").as_str()));
        anyhow::ensure!(
            covered,
            "fixture coverage gap: no passing fixture exercises {meta}"
        );
    }
    Ok(results)
}

fn parse_expect(s: &str) -> Result<(String, u32), String> {
    let (rule, line) = s
        .split_once('@')
        .ok_or_else(|| format!("bad expect {s:?}: want `<rule> @ <line>`"))?;
    let rule = rule.trim().to_string();
    let line: u32 = line
        .trim()
        .parse()
        .map_err(|e| format!("bad expect line in {s:?}: {e}"))?;
    Ok((rule, line))
}

/// Convenience wrapper: error (with a rendered failure list) unless
/// every fixture passed.
pub fn selftest_ok(root: &Path) -> anyhow::Result<Vec<FixtureResult>> {
    let results = selftest(root)?;
    let failures: Vec<String> = results
        .iter()
        .filter(|r| !r.ok)
        .map(|r| format!("  {}: {}", r.file, r.detail))
        .collect();
    anyhow::ensure!(
        failures.is_empty(),
        "detlint selftest failed:\n{}",
        failures.join("\n")
    );
    Ok(results)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn repo_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR"))
    }

    /// The tier-1 lint gate: the repo itself must be detlint-clean.
    /// Every pre-existing violation is either fixed or carries a
    /// reviewed `// detlint: allow(...)` with a written reason.
    #[test]
    fn repo_is_lint_clean() {
        let report = run_lint(&repo_root()).expect("lint walk");
        assert!(report.files > 50, "suspiciously few files scanned: {}", report.files);
        let rendered: Vec<String> = report.diags.iter().map(|d| d.render()).collect();
        assert!(
            report.clean(),
            "detlint found {} violation(s):\n{}",
            rendered.len(),
            rendered.join("\n")
        );
    }

    /// Every committed fixture produces exactly its expected
    /// diagnostics; the set covers all rules plus both meta-rules.
    #[test]
    fn fixtures_selftest_passes() {
        let results = selftest_ok(&repo_root()).expect("selftest");
        assert!(results.len() >= 12, "expected >= 12 fixtures, got {}", results.len());
    }

    /// Violating fixtures are what make `detlint` exit non-zero: each
    /// one, linted under its virtual path, must yield at least one
    /// diagnostic.
    #[test]
    fn violating_fixtures_fail_the_lint() {
        let results = selftest(&repo_root()).expect("selftest");
        let violating = results.iter().filter(|r| r.expects > 0).count();
        assert!(violating >= 5, "expected >= 5 violating fixtures, got {violating}");
    }

    #[test]
    fn walk_is_sorted_and_excludes_fixtures() {
        let report = run_lint(&repo_root()).expect("lint walk");
        // Sorted diagnostics imply a deterministic walk; also assert
        // the fixtures never leak into the repo lint (they violate on
        // purpose, so a leak would show up as diagnostics — check the
        // path prefix explicitly for a sharper failure).
        for d in &report.diags {
            assert!(!d.path.starts_with(FIXTURES_DIR), "fixture leaked: {}", d.path);
        }
    }
}
