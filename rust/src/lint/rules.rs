//! The `detlint` rule engine: five determinism / hot-path rules over
//! the token stream of [`super::lexer`], plus the
//! `// detlint: allow(<rule>, reason = "...")` escape hatch.
//!
//! Rules (see README "Static analysis" for the catalog):
//!
//! - **r1** — no std float transcendentals (`.exp()`, `.ln()`, `.sin()`,
//!   `.cos()`, `.powf()`, `.powi()`; `.sqrt()` is IEEE-exact and
//!   exempt) outside `sim/detmath.rs`.  Std libm differs across
//!   platforms in the last ulp, which breaks the golden-hash contract.
//! - **r2** — no `HashMap`/`HashSet` *iteration* in outcome-affecting
//!   modules (`coordinator/`, `sim/`, `workload/`, `engine/`): the
//!   per-instance `RandomState` seed makes iteration order
//!   nondeterministic even within one process.  Keyed lookup is fine.
//! - **r3** — no wall-clock or OS entropy (`Instant::now`,
//!   `SystemTime`, `thread_rng`, `std::env` reads) in those same
//!   modules: RNG only via `sim/rng.rs`, time only via `sim/clock.rs`.
//! - **r4** — functions tagged `// detlint: hot` reject allocating
//!   constructs (`Vec::new`, `vec![]`, `.collect()`, `.to_vec()`,
//!   `.clone()` on non-`Copy`-hinted receivers, `format!`, `Box::new`,
//!   `String::from`) — the static complement of the
//!   `THROTTLLEM_STRICT_ALLOC` runtime audit in `perf_hotpath`.
//! - **r5** — no `unsafe` outside the reviewed whitelist (currently
//!   only the counting allocator in `rust/benches/perf_hotpath.rs`).
//!
//! Every rule is a *heuristic over tokens* (no type information): it is
//! tuned to have zero false negatives on the constructs above at the
//! cost of occasional false positives, which is what the mandatory-
//! reason `allow` annotation is for.  An `allow` that suppresses
//! nothing is itself an error (`unused-allow`), so annotations cannot
//! rot in place.

use super::lexer::{lex, Tok, TokKind};

/// The five lintable rules (allow annotations must name one of these).
pub const RULE_NAMES: [&str; 5] = ["r1", "r2", "r3", "r4", "r5"];

/// File that R1 exempts (the deterministic math implementation itself,
/// whose tests compare against std as a sanity oracle).
pub const R1_EXEMPT: &str = "rust/src/sim/detmath.rs";

/// Module prefixes where R2/R3 apply: everything whose state can reach
/// `FleetOutcome` or the recorded trace.
pub const OUTCOME_SCOPE: [&str; 4] = [
    "rust/src/coordinator/",
    "rust/src/sim/",
    "rust/src/workload/",
    "rust/src/engine/",
];

/// R5 whitelist: files allowed to contain `unsafe` without annotation.
pub const UNSAFE_WHITELIST: [&str; 1] = ["rust/benches/perf_hotpath.rs"];

const R1_METHODS: [&str; 6] = ["exp", "ln", "sin", "cos", "powf", "powi"];
const R2_METHODS: [&str; 10] = [
    "iter",
    "iter_mut",
    "keys",
    "values",
    "values_mut",
    "drain",
    "into_iter",
    "into_keys",
    "into_values",
    "retain",
];
const COPY_PRIMS: [&str; 17] = [
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize", "f32",
    "f64", "bool", "char", "str",
];

/// One diagnostic, printable as `path:line:col rule message`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Diag {
    pub path: String,
    pub line: u32,
    pub col: u32,
    /// "r1".."r5", or the meta-rules "bad-allow" / "unused-allow".
    pub rule: &'static str,
    pub msg: String,
}

impl Diag {
    pub fn render(&self) -> String {
        format!(
            "{}:{}:{} {} {}",
            self.path, self.line, self.col, self.rule, self.msg
        )
    }
}

/// A parsed `// detlint: allow(rule, reason = "...")` annotation.
struct Allow {
    rule: &'static str,
    line: u32,
    col: u32,
    used: bool,
}

/// Lint one file's source.  `path` must be the repo-relative,
/// '/'-separated path (fixtures substitute a virtual path here so the
/// path-scoped rules can be exercised from the fixtures directory).
pub fn lint_source(path: &str, src: &str) -> Vec<Diag> {
    let lexed = lex(src);
    let toks = &lexed.tokens;

    // ---- directives -------------------------------------------------
    let mut allows: Vec<Allow> = Vec::new();
    let mut hot_lines: Vec<(u32, u32)> = Vec::new(); // (line, col)
    let mut diags: Vec<Diag> = Vec::new();

    for c in &lexed.comments {
        let Some(body) = c.text.strip_prefix("detlint:") else {
            continue;
        };
        let body = body.trim();
        if body == "hot" {
            hot_lines.push((c.line, c.col));
            continue;
        }
        match parse_allow(body) {
            Ok(rule) => allows.push(Allow {
                rule,
                line: c.line,
                col: c.col,
                used: false,
            }),
            Err(why) => diags.push(Diag {
                path: path.to_string(),
                line: c.line,
                col: c.col,
                rule: "bad-allow",
                msg: why,
            }),
        }
    }

    // ---- raw rule diagnostics --------------------------------------
    let mut raw: Vec<Diag> = Vec::new();
    rule_r1(path, toks, &mut raw);
    rule_r2(path, toks, &mut raw);
    rule_r3(path, toks, &mut raw);
    rule_r4(path, toks, &hot_lines, &mut raw, &mut diags);
    rule_r5(path, toks, &mut raw);

    // ---- apply allows ----------------------------------------------
    // An allow suppresses matching-rule diagnostics on its own line
    // (trailing-comment form); otherwise on the next token-bearing
    // line (comment-above form, stackable because comments are not
    // tokens).
    let mut token_lines: Vec<u32> = toks.iter().map(|t| t.line).collect();
    token_lines.sort_unstable();
    token_lines.dedup();
    let next_code_line = |after: u32| -> Option<u32> {
        let idx = token_lines.partition_point(|&l| l <= after);
        token_lines.get(idx).copied()
    };

    let mut suppressed = vec![false; raw.len()];
    for a in &mut allows {
        let same_line_hit = raw
            .iter()
            .enumerate()
            .any(|(i, d)| !suppressed[i] && d.rule == a.rule && d.line == a.line);
        let target = if same_line_hit {
            Some(a.line)
        } else {
            next_code_line(a.line)
        };
        if let Some(t) = target {
            for (i, d) in raw.iter().enumerate() {
                if d.rule == a.rule && d.line == t {
                    suppressed[i] = true;
                    a.used = true;
                }
            }
        }
    }
    for (i, d) in raw.into_iter().enumerate() {
        if !suppressed[i] {
            diags.push(d);
        }
    }
    for a in &allows {
        if !a.used {
            diags.push(Diag {
                path: path.to_string(),
                line: a.line,
                col: a.col,
                rule: "unused-allow",
                msg: format!(
                    "allow({}) suppressed no diagnostic; remove it or fix the annotation placement",
                    a.rule
                ),
            });
        }
    }

    diags.sort_by(|a, b| (a.line, a.col, a.rule).cmp(&(b.line, b.col, b.rule)));
    diags
}

/// Parse the inside of a `detlint:` comment body of the allow form.
/// Returns the canonical rule name, or a human-readable error.
fn parse_allow(body: &str) -> Result<&'static str, String> {
    let inner = body
        .strip_prefix("allow(")
        .and_then(|s| s.strip_suffix(')'))
        .ok_or_else(|| {
            format!(
                "malformed detlint directive {body:?}: expected `hot` or \
                 `allow(<rule>, reason = \"...\")`"
            )
        })?;
    let (rule_part, rest) = inner.split_once(',').ok_or_else(|| {
        "allow is missing its mandatory reason: `allow(<rule>, reason = \"...\")`".to_string()
    })?;
    let rule_part = rule_part.trim();
    let rule = RULE_NAMES
        .iter()
        .find(|r| **r == rule_part)
        .copied()
        .ok_or_else(|| format!("unknown rule {rule_part:?} (expected one of r1..r5)"))?;
    let rest = rest.trim();
    let reason = rest
        .strip_prefix("reason")
        .map(|s| s.trim_start())
        .and_then(|s| s.strip_prefix('='))
        .map(|s| s.trim())
        .ok_or_else(|| "allow is missing `reason = \"...\"`".to_string())?;
    let quoted = reason.strip_prefix('"').and_then(|s| s.strip_suffix('"'));
    match quoted {
        Some(q) if !q.trim().is_empty() => Ok(rule),
        Some(_) => Err("allow reason must not be empty".to_string()),
        None => Err("allow reason must be a quoted string".to_string()),
    }
}

fn in_outcome_scope(path: &str) -> bool {
    OUTCOME_SCOPE.iter().any(|p| path.starts_with(p))
}

/// R1: `.exp(` / `.ln(` / `.sin(` / `.cos(` / `.powf(` / `.powi(`
/// anywhere outside `sim/detmath.rs`.
fn rule_r1(path: &str, toks: &[Tok], out: &mut Vec<Diag>) {
    if path == R1_EXEMPT {
        return;
    }
    for i in 0..toks.len().saturating_sub(2) {
        if toks[i].is_punct('.')
            && toks[i + 1].kind == TokKind::Ident
            && R1_METHODS.contains(&toks[i + 1].text.as_str())
            && toks[i + 2].is_punct('(')
        {
            out.push(Diag {
                path: path.to_string(),
                line: toks[i + 1].line,
                col: toks[i + 1].col,
                rule: "r1",
                msg: format!(
                    "std float `.{}()` is platform-dependent in the last ulp and \
                     breaks golden-hash bit-identity; use sim/detmath or annotate \
                     why std math is load-bearing",
                    toks[i + 1].text
                ),
            });
        }
    }
}

/// R2: iteration over identifiers declared as `HashMap`/`HashSet` in
/// outcome-affecting modules.  Receiver typing is a file-scoped name
/// heuristic: any identifier that appears as `name: HashMap<...>`,
/// `name: &HashSet<...>`, or `name = HashMap::new()` (with or without
/// a `std::collections::` path) is treated as a hash collection for
/// the rest of the file.
fn rule_r2(path: &str, toks: &[Tok], out: &mut Vec<Diag>) {
    if !in_outcome_scope(path) {
        return;
    }
    // Pass 1: collect hash-collection identifier names.
    let mut names: Vec<String> = Vec::new();
    for i in 0..toks.len() {
        if !(toks[i].is_ident("HashMap") || toks[i].is_ident("HashSet")) {
            continue;
        }
        // Unwind a leading `std :: collections ::` style path.
        let mut j = i;
        while j >= 3
            && toks[j - 1].is_punct(':')
            && toks[j - 2].is_punct(':')
            && toks[j - 3].kind == TokKind::Ident
        {
            j -= 3;
        }
        if j == 0 {
            continue;
        }
        // Skip `&`, `&'a`, `mut` between the `:` and the type.
        let mut k = j - 1;
        while k > 0
            && (toks[k].is_punct('&')
                || toks[k].is_ident("mut")
                || toks[k].kind == TokKind::Lifetime)
        {
            k -= 1;
        }
        // `name : <type>` (let binding, field, or fn param) or
        // `name = HashMap::new()` (inferred let binding).
        if (toks[k].is_punct(':') || toks[k].is_punct('='))
            && k > 0
            && toks[k - 1].kind == TokKind::Ident
        {
            let name = toks[k - 1].text.clone();
            if !names.contains(&name) {
                names.push(name);
            }
        }
    }
    if names.is_empty() {
        return;
    }
    let is_map = |t: &Tok| t.kind == TokKind::Ident && names.iter().any(|n| *n == t.text);

    // Pass 2a: `<name> . <iterating-method> (`.
    for i in 0..toks.len().saturating_sub(3) {
        if is_map(&toks[i])
            && toks[i + 1].is_punct('.')
            && toks[i + 2].kind == TokKind::Ident
            && R2_METHODS.contains(&toks[i + 2].text.as_str())
            && toks[i + 3].is_punct('(')
        {
            out.push(Diag {
                path: path.to_string(),
                line: toks[i + 2].line,
                col: toks[i + 2].col,
                rule: "r2",
                msg: format!(
                    "`.{}()` iterates hash collection `{}` in an outcome-affecting \
                     module; iteration order is per-instance random — use a sorted \
                     or Vec-backed structure, or annotate why order never escapes \
                     into FleetOutcome",
                    toks[i + 2].text, toks[i].text
                ),
            });
        }
    }

    // Pass 2b: `for <pat> in [&][mut] [self.]<name> {`.
    for i in 0..toks.len() {
        if !toks[i].is_ident("in") {
            continue;
        }
        let mut j = i + 1;
        while j < toks.len() && (toks[j].is_punct('&') || toks[j].is_ident("mut")) {
            j += 1;
        }
        // Read a dotted/pathed chain of idents; remember the last one.
        let mut last: Option<usize> = None;
        while j < toks.len() {
            if toks[j].kind == TokKind::Ident {
                last = Some(j);
                j += 1;
            } else if toks[j].is_punct('.') || toks[j].is_punct(':') {
                j += 1;
            } else {
                break;
            }
        }
        // Only a bare collection expression directly iterated counts:
        // a following `(` means a method call (handled by pass 2a).
        if j < toks.len() && toks[j].is_punct('{') {
            if let Some(l) = last {
                if is_map(&toks[l]) {
                    out.push(Diag {
                        path: path.to_string(),
                        line: toks[l].line,
                        col: toks[l].col,
                        rule: "r2",
                        msg: format!(
                            "`for .. in` over hash collection `{}` in an \
                             outcome-affecting module; iteration order is \
                             per-instance random — use a sorted or Vec-backed \
                             structure, or annotate why order never escapes into \
                             FleetOutcome",
                            toks[l].text
                        ),
                    });
                }
            }
        }
    }
}

/// R3: wall-clock / OS entropy in outcome-affecting modules.
fn rule_r3(path: &str, toks: &[Tok], out: &mut Vec<Diag>) {
    if !in_outcome_scope(path) {
        return;
    }
    let mut push = |t: &Tok, what: &str| {
        out.push(Diag {
            path: path.to_string(),
            line: t.line,
            col: t.col,
            rule: "r3",
            msg: format!(
                "{what} injects wall-clock/OS entropy into a deterministic \
                 module; RNG must come from sim/rng.rs and time from sim/clock.rs"
            ),
        });
    };
    for i in 0..toks.len() {
        let t = &toks[i];
        if t.is_ident("SystemTime") {
            push(t, "`SystemTime`");
        } else if t.is_ident("thread_rng") {
            push(t, "`thread_rng`");
        } else if t.is_ident("Instant")
            && i + 3 < toks.len()
            && toks[i + 1].is_punct(':')
            && toks[i + 2].is_punct(':')
            && toks[i + 3].is_ident("now")
        {
            push(t, "`Instant::now`");
        } else if t.is_ident("env")
            && i + 3 < toks.len()
            && toks[i + 1].is_punct(':')
            && toks[i + 2].is_punct(':')
            && ["var", "vars", "var_os", "args", "args_os"]
                .contains(&toks[i + 3].text.as_str())
        {
            push(t, "`std::env` read");
        }
    }
}

/// R4: allocating constructs inside `// detlint: hot` functions.
fn rule_r4(
    path: &str,
    toks: &[Tok],
    hot_lines: &[(u32, u32)],
    out: &mut Vec<Diag>,
    meta: &mut Vec<Diag>,
) {
    for &(hline, hcol) in hot_lines {
        // The tag binds to the first `fn` at or after its line
        // (trailing-comment form binds to the same line).
        let Some(fn_idx) = toks
            .iter()
            .position(|t| t.is_ident("fn") && t.line >= hline)
        else {
            meta.push(Diag {
                path: path.to_string(),
                line: hline,
                col: hcol,
                rule: "bad-allow",
                msg: "`detlint: hot` tag is not followed by a function".to_string(),
            });
            continue;
        };
        let fn_name = toks
            .get(fn_idx + 1)
            .map(|t| t.text.clone())
            .unwrap_or_default();
        // Find the body: first `{` after the signature; a `;` first
        // means a bodiless trait method.
        let mut open = None;
        let mut paren = 0i32;
        for (i, t) in toks.iter().enumerate().skip(fn_idx) {
            if t.is_punct('(') || t.is_punct('[') {
                paren += 1;
            } else if t.is_punct(')') || t.is_punct(']') {
                paren -= 1;
            } else if t.is_punct('{') {
                open = Some(i);
                break;
            } else if t.is_punct(';') && paren == 0 {
                break;
            }
        }
        let Some(open) = open else {
            meta.push(Diag {
                path: path.to_string(),
                line: hline,
                col: hcol,
                rule: "bad-allow",
                msg: format!("`detlint: hot` tagged fn `{fn_name}` has no body"),
            });
            continue;
        };
        let mut depth = 1i32;
        let mut close = toks.len();
        for (i, t) in toks.iter().enumerate().skip(open + 1) {
            if t.is_punct('{') {
                depth += 1;
            } else if t.is_punct('}') {
                depth -= 1;
                if depth == 0 {
                    close = i;
                    break;
                }
            }
        }
        let body = &toks[open + 1..close];
        let fn_range = &toks[fn_idx..close];
        check_hot_body(path, &fn_name, body, fn_range, out);
    }
}

/// Whether `name` is hinted `Copy` inside the tagged function: declared
/// with a primitive type annotation (`name: u64`, `name: &f64`).
fn copy_hinted(name: &str, fn_range: &[Tok]) -> bool {
    for i in 0..fn_range.len().saturating_sub(2) {
        if fn_range[i].is_ident(name) && fn_range[i + 1].is_punct(':') {
            let mut j = i + 2;
            while j < fn_range.len()
                && (fn_range[j].is_punct('&')
                    || fn_range[j].is_ident("mut")
                    || fn_range[j].kind == TokKind::Lifetime)
            {
                j += 1;
            }
            if j < fn_range.len()
                && fn_range[j].kind == TokKind::Ident
                && COPY_PRIMS.contains(&fn_range[j].text.as_str())
            {
                return true;
            }
        }
    }
    false
}

fn check_hot_body(path: &str, fn_name: &str, body: &[Tok], fn_range: &[Tok], out: &mut Vec<Diag>) {
    let mut push = |t: &Tok, what: String| {
        out.push(Diag {
            path: path.to_string(),
            line: t.line,
            col: t.col,
            rule: "r4",
            msg: format!(
                "allocating construct {what} in hot function `{fn_name}` \
                 (steady-state sweep must stay allocation-free; see \
                 THROTTLLEM_STRICT_ALLOC in perf_hotpath)"
            ),
        });
    };
    for i in 0..body.len() {
        let t = &body[i];
        // `Vec::new` / `Box::new` / `String::from`.
        if (t.is_ident("Vec") || t.is_ident("Box") || t.is_ident("String"))
            && i + 3 < body.len()
            && body[i + 1].is_punct(':')
            && body[i + 2].is_punct(':')
        {
            let m = &body[i + 3];
            let hit = (t.is_ident("Vec") && (m.is_ident("new") || m.is_ident("with_capacity")))
                || (t.is_ident("Box") && m.is_ident("new"))
                || (t.is_ident("String") && (m.is_ident("from") || m.is_ident("new")));
            if hit {
                push(t, format!("`{}::{}`", t.text, m.text));
            }
        }
        // `vec!` / `format!`.
        if (t.is_ident("vec") || t.is_ident("format"))
            && i + 1 < body.len()
            && body[i + 1].is_punct('!')
        {
            push(t, format!("`{}!`", t.text));
        }
        // `.collect()` / `.to_vec()` / `.clone()`.
        if t.is_punct('.') && i + 2 < body.len() && body[i + 2].is_punct('(') {
            let m = &body[i + 1];
            if m.is_ident("collect") || m.is_ident("to_vec") || m.is_ident("to_string") {
                push(m, format!("`.{}()`", m.text));
            } else if m.is_ident("clone") {
                // Copy-hinted receivers (primitive-typed locals/params)
                // are memcpys, not allocations.
                let receiver_ok = i > 0
                    && body[i - 1].kind == TokKind::Ident
                    && copy_hinted(&body[i - 1].text, fn_range);
                if !receiver_ok {
                    push(m, "`.clone()` on a non-Copy-hinted receiver".to_string());
                }
            }
        }
    }
}

/// R5: `unsafe` outside the whitelist.
fn rule_r5(path: &str, toks: &[Tok], out: &mut Vec<Diag>) {
    if UNSAFE_WHITELIST.contains(&path) {
        return;
    }
    for t in toks {
        if t.is_ident("unsafe") {
            out.push(Diag {
                path: path.to_string(),
                line: t.line,
                col: t.col,
                rule: "r5",
                msg: "`unsafe` outside the reviewed whitelist \
                      (rust/benches/perf_hotpath.rs); extend the whitelist only \
                      with a reviewed justification"
                    .to_string(),
            });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules_of(diags: &[Diag]) -> Vec<&'static str> {
        diags.iter().map(|d| d.rule).collect()
    }

    #[test]
    fn r1_flags_transcendentals_and_exempts_sqrt() {
        let d = lint_source(
            "rust/src/gpusim/x.rs",
            "fn f(x: f64) -> f64 { x.exp() + x.sqrt() + x.powf(1.5) }",
        );
        assert_eq!(rules_of(&d), vec!["r1", "r1"]);
        assert!(d[0].msg.contains(".exp()"));
        assert!(d[1].msg.contains(".powf()"));
    }

    #[test]
    fn r1_exempts_detmath() {
        let d = lint_source(
            "rust/src/sim/detmath.rs",
            "fn f(x: f64) -> f64 { x.exp() }",
        );
        assert!(d.is_empty());
    }

    #[test]
    fn r1_ignores_strings_and_comments() {
        let d = lint_source(
            "rust/src/sim/x.rs",
            "// calls .exp() conceptually\nfn f() -> &'static str { \".exp()\" }",
        );
        assert!(d.is_empty(), "{d:?}");
    }

    #[test]
    fn r2_tracks_declarations_and_flags_iteration() {
        let src = r#"
            use std::collections::HashMap;
            fn f() {
                let mut m: HashMap<u64, u64> = HashMap::new();
                m.insert(1, 2);          // keyed access: fine
                let _ = m.get(&1);       // fine
                for (k, v) in &m {       // flagged
                    let _ = (k, v);
                }
                let _: Vec<_> = m.keys().collect(); // flagged
            }
        "#;
        let d = lint_source("rust/src/coordinator/x.rs", src);
        assert_eq!(rules_of(&d), vec!["r2", "r2"]);
    }

    #[test]
    fn r2_only_in_outcome_scope() {
        let src = "fn f(m: &std::collections::HashMap<u64, u64>) { for x in m.keys() { let _ = x; } }";
        assert!(lint_source("rust/src/metrics/x.rs", src).is_empty());
        assert_eq!(rules_of(&lint_source("rust/src/engine/x.rs", src)), vec!["r2"]);
    }

    #[test]
    fn r2_self_field_iteration() {
        let src = r#"
            struct S { held: std::collections::HashSet<u64> }
            impl S {
                fn f(&self) { for x in &self.held { let _ = x; } }
                fn g(&self) -> usize { self.held.values().count() }
            }
        "#;
        let d = lint_source("rust/src/engine/x.rs", src);
        assert_eq!(rules_of(&d), vec!["r2", "r2"]);
    }

    #[test]
    fn r3_flags_entropy_sources() {
        let src = r#"
            fn f() {
                let t = std::time::Instant::now();
                let e = std::env::var("X");
                let _ = (t, e);
            }
        "#;
        let d = lint_source("rust/src/workload/x.rs", src);
        assert_eq!(rules_of(&d), vec!["r3", "r3"]);
        // Out of scope: benches may time things.
        assert!(lint_source("rust/benches/x.rs", src).is_empty());
    }

    #[test]
    fn r4_flags_allocations_only_in_hot_fns() {
        let src = r#"
            // detlint: hot
            fn hot_one(n: u64) -> u64 {
                let v = vec![1, 2];
                let w = Vec::new();
                let s = format!("{n}");
                let _ = (v, w, s);
                n
            }
            fn cold(n: u64) -> Vec<u64> { vec![n] }
        "#;
        let d = lint_source("rust/src/coordinator/x.rs", src);
        assert_eq!(rules_of(&d), vec!["r4", "r4", "r4"]);
    }

    #[test]
    fn r4_clone_copy_hint() {
        let src = r#"
            // detlint: hot
            fn hot_one(a: u64, req: &Request) -> u64 {
                let b = a.clone();      // Copy-hinted: fine
                let r = req.clone();    // flagged
                let _ = r;
                b
            }
        "#;
        let d = lint_source("rust/src/coordinator/x.rs", src);
        assert_eq!(rules_of(&d), vec!["r4"]);
        assert!(d[0].msg.contains("clone"));
    }

    #[test]
    fn r4_hot_without_fn_is_bad() {
        let d = lint_source("rust/src/coordinator/x.rs", "// detlint: hot\nconst X: u64 = 1;\n");
        assert_eq!(rules_of(&d), vec!["bad-allow"]);
    }

    #[test]
    fn r5_unsafe_whitelist() {
        let src = "fn f() { unsafe { core::hint::unreachable_unchecked() } }";
        assert_eq!(rules_of(&lint_source("rust/src/engine/x.rs", src)), vec!["r5"]);
        assert!(lint_source("rust/benches/perf_hotpath.rs", src).is_empty());
    }

    #[test]
    fn allow_suppresses_and_must_be_used() {
        let src = r#"
            // detlint: allow(r1, reason = "test of suppression")
            fn f(x: f64) -> f64 { x.exp() }
        "#;
        assert!(lint_source("rust/src/sim/x.rs", src).is_empty());

        let unused = r#"
            // detlint: allow(r1, reason = "nothing to suppress")
            fn f(x: f64) -> f64 { x.sqrt() }
        "#;
        let d = lint_source("rust/src/sim/x.rs", unused);
        assert_eq!(rules_of(&d), vec!["unused-allow"]);
    }

    #[test]
    fn allow_trailing_comment_form() {
        let src = "fn f(x: f64) -> f64 { x.exp() } // detlint: allow(r1, reason = \"same line\")";
        assert!(lint_source("rust/src/sim/x.rs", src).is_empty());
    }

    #[test]
    fn stacked_allows_bind_to_next_code_line() {
        let src = r#"
            // detlint: allow(r1, reason = "std ln is load-bearing here")
            // detlint: allow(r2, reason = "order-independent sum")
            fn f(m: &std::collections::HashMap<u64, f64>) -> f64 {
                m.values().map(|v| v.ln()).sum()
            }
        "#;
        // Binding is line-precise: both allows bind past the comments
        // to the `fn` signature line, which has no violations — the
        // violations sit one line further down and stay flagged, and
        // the misplaced allows are reported as unused.
        let d = lint_source("rust/src/coordinator/x.rs", src);
        assert_eq!(
            rules_of(&d),
            vec!["unused-allow", "unused-allow", "r2", "r1"]
        );
    }

    #[test]
    fn missing_reason_is_bad_allow() {
        let src = "// detlint: allow(r1)\nfn f(x: f64) -> f64 { x.exp() }";
        let d = lint_source("rust/src/sim/x.rs", src);
        assert_eq!(rules_of(&d), vec!["bad-allow", "r1"]);
    }

    #[test]
    fn unknown_rule_is_bad_allow() {
        let src = "// detlint: allow(r9, reason = \"nope\")\nfn f() {}";
        let d = lint_source("rust/src/sim/x.rs", src);
        assert_eq!(rules_of(&d), vec!["bad-allow"]);
    }

    #[test]
    fn empty_reason_is_bad_allow() {
        let src = "// detlint: allow(r1, reason = \"  \")\nfn f(x: f64) -> f64 { x.exp() }";
        let d = lint_source("rust/src/sim/x.rs", src);
        assert_eq!(rules_of(&d), vec!["bad-allow", "r1"]);
    }
}
