//! `throttllem` CLI: the deployment launcher and experiment driver.
//!
//! Subcommands:
//!   serve        — replay a synthetic Azure-like trace under a policy
//!                  and print the serving report
//!   profile      — run the §IV-C1 profiling campaign for an engine
//!   train-model  — train + evaluate the performance model (Table III)
//!   engines      — list the Table II engine descriptors
//!   real-serve   — serve real batched requests through the PJRT
//!                  tiny-llama-sim artifacts
//!
//! Examples:
//!   throttllem serve --engine llama2-13b-tp2 --policy throttllem \
//!       --duration 600 --error 0.15
//!   throttllem serve --policy throttllem --autoscale
//!   throttllem train-model --engine llama2-13b-tp2
//!   throttllem real-serve --artifacts artifacts --batch 4 --steps 32

use throttllem::cli::Args;
use throttllem::config::models::{engine_by_name, llama2_13b, table2_engines};
use throttllem::config::{
    parse_fleet_jsonl, parse_replica_spec, EngineSpec, FaultSpec, MigrationSpec, PredictSpec,
    PrefixSpec, ReplicaSpec, ServingConfig,
};
use throttllem::coordinator::{
    outcome_digest, FleetOutcome, FleetPlan, PerfModel, Policy, RouterPolicy, Workload,
};
use throttllem::engine::request::Request;
use throttllem::mlmodel::{mae, mape, r2_score};
use throttllem::sim::Pcg64;
use throttllem::workload::fleet_trace::{
    record_fleet_trace, scenario_requests, synth_fleet_trace, FleetTraceMeta,
    FleetTraceParams, Scenario, ScenarioKind,
};
use throttllem::workload::trace::{synth_trace, synth_trace_rps_range, TraceParams};
use throttllem::workload::{collect_training_data, LengthPredictor};

/// `--record <file>`: write the (pre-predictor) trace as replayable
/// JSONL.  Recording a replayed trace re-serializes it byte-identically.
fn maybe_record(
    args: &Args,
    meta: &FleetTraceMeta,
    reqs: &[Request],
) -> anyhow::Result<()> {
    if let Some(path) = args.get("record") {
        record_fleet_trace(path, meta, reqs)?;
        eprintln!("recorded fleet trace: {path}");
    }
    Ok(())
}

/// The `--scenario`/`--record` dispatch shared by the homogeneous and
/// heterogeneous serve paths: build or replay the scenario's shared
/// stream (recording it when asked), falling back to `legacy` trace
/// synthesis when no scenario is requested.
fn cli_scenario_requests(
    args: &Args,
    replicas: usize,
    peak: f64,
    duration: f64,
    seed: u64,
    legacy: impl FnOnce() -> Vec<Request>,
) -> anyhow::Result<Vec<Request>> {
    match args.get("scenario").map(Scenario::parse).transpose()? {
        Some(sc) => {
            let (meta, reqs) = if sc == Scenario::Generate(ScenarioKind::Session) {
                // The session family takes extra knobs (`--session-turns`,
                // `--session-think`, `--session-prefix`) the generic
                // scenario surface has no field for.
                let mut p = FleetTraceParams::scenario(
                    ScenarioKind::Session,
                    replicas,
                    peak,
                    duration,
                    seed,
                );
                p.session_turns_mean =
                    args.get_f64("session-turns", p.session_turns_mean)?;
                p.session_think_s = args.get_f64("session-think", p.session_think_s)?;
                p.session_prefix_tokens =
                    args.get_u64("session-prefix", p.session_prefix_tokens as u64)? as u32;
                anyhow::ensure!(
                    p.session_turns_mean >= 1.0,
                    "--session-turns must be >= 1"
                );
                anyhow::ensure!(p.session_think_s >= 0.0, "--session-think must be >= 0");
                let reqs = synth_fleet_trace(&p);
                (p.meta(), reqs)
            } else {
                scenario_requests(&sc, replicas, peak, duration, seed)?
            };
            maybe_record(args, &meta, &reqs)?;
            eprintln!(
                "scenario {}: {} requests (peak ~{:.1} RPS over {:.0} s)",
                meta.scenario,
                reqs.len(),
                meta.peak_rps,
                meta.duration_s
            );
            Ok(reqs)
        }
        None => {
            anyhow::ensure!(
                args.get("record").is_none(),
                "--record requires --scenario"
            );
            Ok(legacy())
        }
    }
}

/// `--outcome-digest <file>`: write the run's [`outcome_digest`] as a
/// 16-hex-digit line.  The CI threads-identity job serves the same
/// trace at `--threads 1` and `--threads 4` and compares the files
/// bitwise — the cheapest cross-process form of the determinism
/// contract.
fn maybe_write_digest(args: &Args, out: &FleetOutcome) -> anyhow::Result<()> {
    if let Some(path) = args.get("outcome-digest") {
        let hex = format!("{:016x}\n", outcome_digest(out));
        std::fs::write(path, &hex)
            .map_err(|e| anyhow::anyhow!("--outcome-digest {path:?}: {e}"))?;
        eprintln!("outcome digest: {} -> {path}", hex.trim());
    }
    Ok(())
}

/// Parse the `--migration on|off` switch plus its cost knobs
/// (`--migration-base-ms`, `--migration-gbps`, `--migration-power`)
/// into the plan's `Option<MigrationSpec>`.  Off (`None`) is the
/// default: scale-in drains, and the cost knobs are ignored.
fn migration_from_args(args: &Args) -> anyhow::Result<Option<MigrationSpec>> {
    let mut spec = match args.get("migration") {
        Some(v) => MigrationSpec::parse_enabled(v)?,
        None => None,
    };
    if let Some(m) = spec.as_mut() {
        m.base_latency_s = args.get_f64("migration-base-ms", m.base_latency_s * 1e3)? / 1e3;
        m.gb_per_s = args.get_f64("migration-gbps", m.gb_per_s)?;
        m.link_power_w = args.get_f64("migration-power", m.link_power_w)?;
        anyhow::ensure!(m.gb_per_s > 0.0, "--migration-gbps must be positive");
        anyhow::ensure!(m.base_latency_s >= 0.0, "--migration-base-ms must be >= 0");
        anyhow::ensure!(m.link_power_w >= 0.0, "--migration-power must be >= 0");
    }
    Ok(spec)
}

/// Parse the `--faults on|off` switch plus `--fault-seed <n>` into the
/// plan's `Option<FaultSpec>`.  Off (`None`) is the default: the
/// serving path is byte-identical to a run without the fault
/// subsystem.
fn faults_from_args(args: &Args) -> anyhow::Result<Option<FaultSpec>> {
    let mut spec = match args.get("faults") {
        Some(v) => FaultSpec::parse_enabled(v)?,
        None => None,
    };
    if let Some(f) = spec.as_mut() {
        f.seed = args.get_u64("fault-seed", f.seed)?;
    }
    Ok(spec)
}

/// Parse the `--predict on|off` switch plus its forecaster knobs
/// (`--predict-lead <s>`, `--predict-period <s>`) into the plan's
/// `Option<PredictSpec>`.  Off (`None`) is the default: the serving
/// path is byte-identical to the reactive loop.
fn predict_from_args(args: &Args) -> anyhow::Result<Option<PredictSpec>> {
    let mut spec = match args.get("predict") {
        Some(v) => PredictSpec::parse_enabled(v)?,
        None => None,
    };
    if let Some(p) = spec.as_mut() {
        p.lead_s = args.get_f64("predict-lead", p.lead_s)?;
        p.period_s = args.get_f64("predict-period", p.period_s)?;
        anyhow::ensure!(p.lead_s >= 0.0, "--predict-lead must be >= 0");
        anyhow::ensure!(p.period_s > 0.0, "--predict-period must be positive");
    }
    Ok(spec)
}

/// Parse the `--prefix-share on|off` switch into the plan's
/// `Option<PrefixSpec>`.  Off (`None`) is the default and keeps KV
/// allocation order, prefill arithmetic and routing byte-identical to
/// the pre-sharing path.
fn prefix_from_args(args: &Args) -> anyhow::Result<Option<PrefixSpec>> {
    match args.get("prefix-share") {
        Some(v) => PrefixSpec::parse_enabled(v),
        None => Ok(None),
    }
}

/// Parse `--predictor oracle|noisy:<p95>` into the generation-length
/// predictor the admission path sees.  Defaults preserve the legacy
/// `--error` behavior: noisy at `--error` when positive, else oracle.
/// The caller must also set `cfg.predictor_p95_error` from the
/// returned predictor so the §IV-F conservative adjustment assumes
/// exactly the noise the predictor injects.
fn predictor_from_args(args: &Args, error: f64, seed: u64) -> anyhow::Result<LengthPredictor> {
    match args.get("predictor") {
        None => Ok(if error > 0.0 {
            LengthPredictor::noisy(error, seed)
        } else {
            LengthPredictor::oracle()
        }),
        Some("oracle") => Ok(LengthPredictor::oracle()),
        Some(v) => match v.strip_prefix("noisy:") {
            Some(p95) => {
                let p: f64 = p95
                    .parse()
                    .map_err(|e| anyhow::anyhow!("--predictor noisy:{p95:?}: {e}"))?;
                anyhow::ensure!(
                    (0.0..1.0).contains(&p),
                    "--predictor noisy:<p95> needs 0 <= p95 < 1, got {p}"
                );
                Ok(LengthPredictor::noisy(p, seed))
            }
            None => {
                anyhow::bail!("--predictor {v:?} (expected oracle | noisy:<p95>)")
            }
        },
    }
}

fn policy_by_name(name: &str) -> anyhow::Result<Policy> {
    Ok(match name {
        "triton" => Policy::triton(),
        "triton-autoscale" => Policy::triton_autoscale(),
        "throttle-only" | "throttllem-noas" => Policy::throttle_only(),
        "throttllem" => Policy::throttllem(),
        other => anyhow::bail!("unknown policy {other:?}"),
    })
}

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        eprintln!("hint: run `throttllem` without arguments for usage");
        std::process::exit(1);
    }
}

fn run() -> anyhow::Result<()> {
    let args = Args::from_env()?;
    match args.subcommand.as_deref() {
        Some("serve") => cmd_serve(&args),
        Some("profile") => cmd_profile(&args),
        Some("train-model") => cmd_train(&args),
        Some("engines") => cmd_engines(),
        Some("real-serve") => cmd_real_serve(&args),
        Some(other) => anyhow::bail!("unknown subcommand {other:?}\n{USAGE}"),
        None => {
            println!("{USAGE}");
            Ok(())
        }
    }
}

const USAGE: &str = "throttllem — SLO-aware GPU frequency scaling for LLM serving
usage: throttllem <serve|profile|train-model|engines|real-serve> [--options]
  serve:       --engine <name> --policy <triton|triton-autoscale|throttle-only|throttllem>
               --duration <s> --error <p95 frac> --seed <n> [--autoscale]
               --replicas <n> --router <round-robin|least-loaded|projected-headroom>
               --peak <rps>   (default: rated max load x replicas)
               --scenario <steady|burst|flash|diurnal|session|replay:<file>>
                 (fleet-level trace: correlated bursts / flash crowds /
                  diurnal idle / multi-turn sessions; replay:<file>
                  replays a recorded trace bit-exactly)
               --session-turns <mean> --session-think <s>
               --session-prefix <tokens>  (session scenario knobs: mean
                 turns per session, think time between turns, shared
                 system-prompt length)
               --record <file>  (write the generated trace as replayable JSONL)
               heterogeneous fleets (mixed TP / model families):
               --replica-spec tp=2[,model=<m>][,count=<n>][,slo=engine]  (repeatable;
                 tp=1+2+4 declares a per-replica TP autoscale ladder)
               --fleet <file.jsonl>  (one replica group per line, e.g.
                 {\"model\":\"llama2-13b\",\"tp\":2,\"count\":2})
               --autoscale-replicas  (opt in to fleet-axis scale in/out on an
                 explicit fleet; off by default to keep the capacity mix)
               --migration on|off  (live KV migration of resident requests on
                 fleet scale-in; off = drain-based scale-in, the default)
               --migration-base-ms <ms> --migration-gbps <GB/s>
               --migration-power <W>   (modeled transfer cost knobs)
               --faults on|off  (deterministic fault injection: replica
                 crashes, thermal throttles, link degradation and
                 preemption notices; off = today's fault-free path,
                 byte-identical, the default)
               --fault-seed <n>  (fault-schedule seed, independent of
                 --seed; same seed => same schedule at any --threads)
               --predict on|off  (predictive fleet control: forecast-driven
                 replica pre-warming, proactive KV-pressure migration and
                 migration-cost-aware scale-in; off = today's reactive
                 path, byte-identical, the default)
               --predict-lead <s> --predict-period <s>  (forecast horizon
                 and assumed diurnal period of the arrival forecaster)
               --prefix-share on|off  (copy-on-write sharing of session
                 prefixes: shared system-prompt blocks stored once per
                 engine, cached prefill skip, session-affine routing;
                 off = today's allocator byte-identically, the default)
               --predictor oracle|noisy:<p95>  (generation-length predictor
                 for admission; default: noisy at --error when positive,
                 else oracle; sets the conservative adjustment to the
                 predictor's own p95 error)
               --threads <n>  (RUN-phase worker threads, 0 = auto; any
                 value is bit-identical to --threads 1)
               --outcome-digest <file>  (write the run's 64-bit outcome
                 digest as hex; equal digests = bit-identical runs)
  profile:     --engine <name> --samples <n>
  train-model: --engine <name> [--samples <n>]
  real-serve:  --artifacts <dir> --batch <n> --steps <n>";

fn cmd_engines() -> anyhow::Result<()> {
    println!(
        "{:<16} {:>3} {:>9} {:>9} {:>10} {:>9}",
        "engine", "TP", "maxRPS", "E2E SLO", "KV blocks", "maxBatch"
    );
    for e in table2_engines() {
        println!(
            "{:<16} {:>3} {:>9.3} {:>9.1} {:>10} {:>9}",
            e.name, e.tensor_parallel, e.max_load_rps, e.e2e_slo_p99, e.kv_blocks, e.max_batch
        );
    }
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    let policy = policy_by_name(args.get_or("policy", "throttllem"))?;
    let duration = args.get_f64("duration", 600.0)?;
    let error = args.get_f64("error", 0.0)?;
    let seed = args.get_u64("seed", 0)?;
    let router = RouterPolicy::parse(args.get_or("router", "round-robin"))?;

    // Heterogeneous fleet: repeatable --replica-spec and/or a --fleet
    // JSONL file (mixed TP sizes / model families, per-replica TP
    // ladders and SLO overrides).
    let mut replica_specs: Vec<ReplicaSpec> = Vec::new();
    for s in args.get_all("replica-spec") {
        replica_specs.extend(parse_replica_spec(s)?);
    }
    if let Some(path) = args.get("fleet") {
        anyhow::ensure!(
            replica_specs.is_empty(),
            "--fleet and --replica-spec are mutually exclusive"
        );
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("--fleet {path:?}: {e}"))?;
        replica_specs = parse_fleet_jsonl(&text)?;
    }
    if !replica_specs.is_empty() {
        anyhow::ensure!(
            args.get("replicas").is_none(),
            "--replicas conflicts with an explicit fleet description"
        );
        anyhow::ensure!(
            args.get("engine").is_none(),
            "--engine conflicts with an explicit fleet description \
             (name engines inside --replica-spec / --fleet instead)"
        );
        anyhow::ensure!(
            !args.flag("autoscale"),
            "--autoscale conflicts with an explicit fleet description \
             (give replicas a tp ladder, e.g. --replica-spec tp=1+2+4, \
             and an autoscaling --policy instead)"
        );
        return cmd_serve_hetero(args, policy, router, replica_specs, duration, error, seed);
    }

    let replicas = args.get_u64("replicas", 1)? as usize;
    anyhow::ensure!(replicas >= 1, "--replicas must be >= 1");

    let autoscale = policy.autoscaling || args.flag("autoscale");
    let (cfg, engines) = if autoscale {
        let set = vec![llama2_13b(1), llama2_13b(2), llama2_13b(4)];
        (ServingConfig::autoscaled(set.clone()), set)
    } else {
        let engine = engine_by_name(args.get_or("engine", "llama2-13b-tp2"))?;
        let c = if policy.throttling {
            ServingConfig::throttllem(engine.clone())
        } else {
            ServingConfig::triton(engine.clone())
        };
        (c, vec![engine])
    };
    // The trace is right-scaled to the deployment: rated max load (7.5
    // for the autoscaled set) times the fleet size, unless overridden.
    let base_peak = if autoscale { 7.5 } else { cfg.engine.max_load_rps };
    let peak = args.get_f64("peak", base_peak * replicas as f64)?;
    let plan = FleetPlan::homogeneous(
        replicas,
        router,
        &cfg,
        policy,
        policy.autoscaling && replicas > 1,
    )
    .with_migration(migration_from_args(args)?)
    .with_faults(faults_from_args(args)?)
    .with_prediction(predict_from_args(args)?)
    .with_prefix_sharing(prefix_from_args(args)?)
    .with_threads(args.get_u64("threads", 1)? as usize);
    run_serve_plan(
        args,
        policy,
        router,
        plan,
        cfg,
        engines,
        peak,
        duration,
        error,
        seed,
        "replica(s)",
        |peak| {
            let params = TraceParams::short(duration, peak, seed);
            if autoscale {
                synth_trace_rps_range(&params, 0.75, peak)
            } else {
                synth_trace(&params)
            }
        },
    )
}

/// Serve on an explicitly-described (typically mixed) fleet.
fn cmd_serve_hetero(
    args: &Args,
    policy: Policy,
    router: RouterPolicy,
    specs: Vec<ReplicaSpec>,
    duration: f64,
    error: f64,
    seed: u64,
) -> anyhow::Result<()> {
    let n = specs.len();
    // A TP ladder only does anything under an autoscaling policy —
    // reject the combination instead of silently pinning the replica
    // to the ladder's largest rung.
    if !policy.autoscaling {
        anyhow::ensure!(
            specs.iter().all(|r| r.scale_set.is_empty()),
            "a per-replica tp ladder (tp=a+b+...) requires an autoscaling \
             policy; use --policy throttllem or --policy triton-autoscale"
        );
    }
    // Fleet-axis autoscaling stays OFF for hand-picked fleets unless
    // explicitly requested: draining a replica of a heterogeneous set
    // silently changes the fleet's capacity mix (a scale-in could
    // power off the only replica a long prompt fits on).
    let plan = FleetPlan::heterogeneous(specs, router)
        .with_autoscale_replicas(
            policy.autoscaling && n > 1 && args.flag("autoscale-replicas"),
        )
        .with_migration(migration_from_args(args)?)
        .with_faults(faults_from_args(args)?)
        .with_prediction(predict_from_args(args)?)
        .with_prefix_sharing(prefix_from_args(args)?)
        .with_threads(args.get_u64("threads", 1)? as usize);
    let engines = plan.engines();
    // Fleet-wide knobs anchor on the highest-capacity engine; replicas
    // with slo=engine overrides enforce their own Table II SLOs.
    let anchor = engines
        .iter()
        .max_by(|a, b| a.max_load_rps.partial_cmp(&b.max_load_rps).unwrap())
        .unwrap()
        .clone();
    let cfg = if policy.throttling {
        ServingConfig::throttllem(anchor)
    } else {
        ServingConfig::triton(anchor)
    };
    // Right-scale to the fleet's aggregate rated load by default.
    let peak = args.get_f64("peak", plan.rated_rps())?;
    run_serve_plan(
        args,
        policy,
        router,
        plan,
        cfg,
        engines,
        peak,
        duration,
        error,
        seed,
        "heterogeneous replica(s)",
        |peak| synth_trace(&TraceParams::short(duration, peak, seed)),
    )
}

/// The shared serve tail both fleet shapes run once their `FleetPlan`
/// is built: length predictor, performance-model training,
/// scenario/trace synthesis, the serve itself, the optional outcome
/// digest and the report.  The homogeneous `--replicas` path and the
/// explicit `--replica-spec`/`--fleet` path used to duplicate all of
/// this; now they only differ in how the plan and its `legacy`
/// fallback trace are constructed.
#[allow(clippy::too_many_arguments)]
fn run_serve_plan(
    args: &Args,
    policy: Policy,
    router: RouterPolicy,
    plan: FleetPlan,
    mut cfg: ServingConfig,
    engines: Vec<EngineSpec>,
    peak: f64,
    duration: f64,
    error: f64,
    seed: u64,
    fleet_label: &str,
    legacy: impl FnOnce(f64) -> Vec<Request>,
) -> anyhow::Result<()> {
    let n = plan.replicas.len();
    let predictor = predictor_from_args(args, error, seed)?;
    cfg.predictor_p95_error = predictor.p95_rel_error();

    eprintln!("training performance model on {} engine(s)...", engines.len());
    let model = PerfModel::train(&engines, 120, seed);

    let mut reqs = cli_scenario_requests(args, n, peak, duration, seed, || legacy(peak))?;
    predictor.apply(&mut reqs, cfg.max_tokens);
    eprintln!(
        "replaying {} requests over {:.0} s under policy {} on {} {fleet_label} ({})...",
        reqs.len(),
        duration,
        policy.name(),
        n,
        router.name()
    );

    let fleet_out = plan.serve(&cfg, policy, &model, Workload::Trace(&reqs));
    maybe_write_digest(args, &fleet_out)?;
    print_serve_report(&cfg, policy, router, n, &fleet_out);
    Ok(())
}

fn print_serve_report(
    cfg: &ServingConfig,
    policy: Policy,
    router: RouterPolicy,
    replicas: usize,
    fleet_out: &FleetOutcome,
) {
    let out = &fleet_out.total;
    let s = &out.stats;
    println!("policy             : {}", policy.name());
    println!("replicas / router  : {} / {}", replicas, router.name());
    println!("completed/dropped  : {}/{}", s.completed, s.dropped);
    println!("lost (SLO waived)  : {}", s.lost);
    println!(
        "E2E p50/p99 [s]    : {:.2} / {:.2}  (SLO {:.1})",
        s.e2e.p50(),
        s.e2e.p99(),
        cfg.slo.e2e_p99
    );
    println!(
        "E2E SLO attainment : {:.1}%",
        s.e2e_slo_attainment(cfg.slo.e2e_p99) * 100.0
    );
    println!(
        "TBT avg [ms]       : {:.1}  (SLO {:.0}, attainment {:.1}%)",
        s.tbt.mean() * 1e3,
        cfg.slo.tbt_avg * 1e3,
        s.tbt_slo_attainment(cfg.slo.tbt_avg) * 100.0
    );
    println!("TTFT p50 [ms]      : {:.0}", s.ttft.p50() * 1e3);
    println!("queue p99 [s]      : {:.2}", s.queue.p99());
    println!("mean freq [MHz]    : {:.0}", s.freq.mean());
    println!("mean power [W]     : {:.0}", s.power.mean());
    println!("energy [kJ]        : {:.1}", s.total_energy_j / 1e3);
    println!("tokens/J           : {:.3}", s.tokens_per_joule());
    println!("engine switches    : {}", out.engine_switches);
    let fc = &fleet_out.faults;
    if fc.crashes + fc.throttle_events + fc.preemptions + fc.link_failures + fc.shed + fc.faulted_lost
        > 0
    {
        println!(
            "faults             : {} crashes ({} recovered / {} requeued, {} retries), \
             {} throttles, {} preemptions, {} link failures",
            fc.crashes,
            fc.crash_recoveries,
            fc.crash_requeues,
            fc.retries,
            fc.throttle_events,
            fc.preemptions,
            fc.link_failures
        );
        println!(
            "shed / fault-lost / respawns : {} / {} / {}",
            fc.shed, fc.faulted_lost, fc.respawns
        );
    }
    let pc = &fleet_out.predict;
    if pc.forecast_ticks > 0 {
        println!(
            "predictive control : {} forecast ticks, {} pre-warmed, \
             {} proactive migrations ({} refused), {} cost-aware scale-ins",
            pc.forecast_ticks,
            pc.prewarmed,
            pc.proactive_migrations,
            pc.proactive_refused,
            pc.predictive_scale_ins
        );
    }
    if replicas > 1 {
        println!(
            "rerouted / replica scale in+out : {} / {}+{}",
            fleet_out.rerouted,
            fleet_out.replica_activations,
            fleet_out.replica_deactivations
        );
        let mg = &fleet_out.migrations;
        if mg.migrations + mg.refused_slo + mg.refused_capacity > 0 {
            // No completed migrated request yet -> the attainment
            // fraction is undefined; print a dash, not NaN%.
            let att = s.migrated_e2e_attainment(cfg.slo.e2e_p99);
            let att = if att.is_nan() {
                "--".to_string()
            } else {
                format!("{:.1}%", att * 100.0)
            };
            println!(
                "live migrations    : {} ok / {} slo-refused / {} capacity-refused \
                 | migrated E2E att. {att} | link energy {:.1} J",
                mg.migrations, mg.refused_slo, mg.refused_capacity, s.migration_energy_j
            );
        }
        println!(
            "{:<8} {:<16} {:>8} {:>10} {:>8} {:>10} {:>10} {:>9}",
            "replica",
            "engine",
            "routed",
            "completed",
            "dropped",
            "freq[MHz]",
            "energy[kJ]",
            "switches"
        );
        for (i, r) in fleet_out.replicas.iter().enumerate() {
            println!(
                "{:<8} {:<16} {:>8} {:>10} {:>8} {:>10.0} {:>10.1} {:>9}",
                i,
                r.engine,
                r.routed,
                r.stats.completed,
                r.stats.dropped,
                r.stats.freq.mean(),
                r.stats.total_energy_j / 1e3,
                r.engine_switches
            );
        }
    }
    // Heterogeneous fleets: break attainment and energy out per model
    // family against each family's effective SLO.
    if fleet_out.families.len() > 1 {
        println!(
            "{:<14} {:>8} {:>10} {:>12} {:>10} {:>8}",
            "family", "replicas", "completed", "E2E att.[%]", "energy[kJ]", "TPJ"
        );
        for f in &fleet_out.families {
            println!(
                "{:<14} {:>8} {:>10} {:>12.1} {:>10.1} {:>8.3}",
                f.family.name(),
                f.replicas,
                f.stats.completed,
                f.stats.e2e_slo_attainment(f.slo.e2e_p99) * 100.0,
                f.stats.total_energy_j / 1e3,
                f.stats.tokens_per_joule()
            );
        }
    }
}

fn cmd_profile(args: &Args) -> anyhow::Result<()> {
    let engine = engine_by_name(args.get_or("engine", "llama2-13b-tp2"))?;
    let samples = args.get_u64("samples", 200)? as u32;
    let data = collect_training_data(&engine, samples, args.get_u64("seed", 0)?);
    println!("# engine batch kv_blocks freq_mhz ips");
    for (f, t) in data.features.iter().zip(&data.targets) {
        println!("{} {} {} {} {:.3}", f[0], f[1], f[2], f[3], t);
    }
    eprintln!("{} samples for {}", data.len(), engine.name);
    Ok(())
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    let engine = engine_by_name(args.get_or("engine", "llama2-13b-tp2"))?;
    let samples = args.get_u64("samples", 300)? as u32;
    let seed = args.get_u64("seed", 0)?;
    let data = collect_training_data(&engine, samples, seed);
    for (label, frac) in [("train=90%", 0.9), ("train=10%", 0.1)] {
        let mut rng = Pcg64::new(seed + 1);
        let (train, test) = data.split(frac, &mut rng);
        let model = PerfModel::train_on(&train);
        let pred: Vec<f64> = test.features.iter().map(|f| model.predict_raw(f)).collect();
        println!(
            "{} {}: R2={:.3} MAPE={:.1}% MAE={:.2} iters/s",
            engine.name,
            label,
            r2_score(&test.targets, &pred),
            mape(&test.targets, &pred),
            mae(&test.targets, &pred),
        );
    }
    Ok(())
}

fn cmd_real_serve(args: &Args) -> anyhow::Result<()> {
    let dir = std::path::PathBuf::from(args.get_or("artifacts", "artifacts"));
    let batch = args.get_u64("batch", 4)? as usize;
    let steps = args.get_u64("steps", 32)? as usize;
    let rt = throttllem::runtime::ModelRuntime::load(&dir)?;
    println!("platform: {}", rt.platform());
    let mut rng = Pcg64::new(args.get_u64("seed", 0)?);
    let vocab = rt.config().vocab;
    let prompts: Vec<Vec<i32>> = (0..batch)
        .map(|_| {
            (0..rng.uniform_usize(3, rt.config().prompt_len as usize))
                .map(|_| rng.uniform_u64(1, vocab as u64 - 1) as i32)
                .collect()
        })
        .collect();
    // Wall-clock reports user-facing runtime only; simulated outcomes
    // never see it.
    #[allow(clippy::disallowed_methods)]
    let t0 = std::time::Instant::now();
    let rows = rt.greedy_generate(&prompts, steps)?;
    let dt = t0.elapsed().as_secs_f64();
    for (i, row) in rows.iter().enumerate() {
        println!("row {i}: {row:?}");
    }
    let tokens = batch * steps;
    println!(
        "{} tokens in {:.3} s -> {:.1} tok/s ({:.2} ms/decode-iter)",
        tokens,
        dt,
        tokens as f64 / dt,
        dt * 1e3 / steps as f64
    );
    Ok(())
}
