//! Metrics substrate: streaming recorders for latency/power series,
//! percentiles, energy accounting, and the serving-level summary used
//! by every experiment (E2E, TBT, TTFT, queue time, TPJ).

use crate::engine::request::RequestOutcome;

/// A recorded sample series with percentile/summary queries.
#[derive(Debug, Clone, Default)]
pub struct Series {
    values: Vec<f64>,
}

impl Series {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64) {
        debug_assert!(x.is_finite(), "non-finite sample {x}");
        self.values.push(x);
    }

    pub fn len(&self) -> usize {
        self.values.len()
    }

    pub fn is_empty(&self) -> bool {
        self.values.is_empty()
    }

    pub fn values(&self) -> &[f64] {
        &self.values
    }

    pub fn mean(&self) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        self.values.iter().sum::<f64>() / self.values.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.values.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.values
            .iter()
            .copied()
            .fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn std(&self) -> f64 {
        let m = self.mean();
        if self.values.len() < 2 {
            return 0.0;
        }
        (self
            .values
            .iter()
            .map(|x| (x - m) * (x - m))
            .sum::<f64>()
            / self.values.len() as f64)
            .sqrt()
    }

    /// Percentile in [0, 100] by linear interpolation (NaN if empty).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let mut sorted = self.values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        percentile_of_sorted(&sorted, p)
    }

    pub fn p50(&self) -> f64 {
        self.percentile(50.0)
    }
    pub fn p95(&self) -> f64 {
        self.percentile(95.0)
    }
    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }

    /// Append every sample of `other` (fleet aggregation).
    pub fn extend_from(&mut self, other: &Series) {
        self.values.extend_from_slice(&other.values);
    }

    /// Fraction of samples at or below `bound` (NaN if empty).
    pub fn frac_within(&self, bound: f64) -> f64 {
        if self.values.is_empty() {
            return f64::NAN;
        }
        let ok = self.values.iter().filter(|&&x| x <= bound).count();
        ok as f64 / self.values.len() as f64
    }
}

/// Percentile of an already-sorted slice.
pub fn percentile_of_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if sorted.is_empty() {
        return f64::NAN;
    }
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    let frac = rank - lo as f64;
    sorted[lo] * (1.0 - frac) + sorted[hi] * frac
}

/// Full serving-run summary (one per policy/engine/trace combination).
#[derive(Debug, Clone, Default)]
pub struct ServingStats {
    pub e2e: Series,
    pub tbt: Series,
    pub ttft: Series,
    pub queue: Series,
    /// Per-iteration power samples, W.
    pub power: Series,
    /// Per-iteration applied frequency, MHz.
    pub freq: Series,
    /// Per-iteration duration samples (token-level TBT distribution).
    pub iter_tbt: Series,
    pub total_energy_j: f64,
    pub total_tokens: u64,
    pub completed: u64,
    pub lost: u64,
    /// Requests that could never fit the engine (oversized even when
    /// idle) and were rejected.
    pub dropped: u64,
    pub wall_s: f64,
    /// Requests live-migrated INTO this replica on fleet scale-in.
    pub migrated_in: u64,
    /// Requests live-migrated AWAY from this replica on fleet scale-in.
    pub migrated_out: u64,
    /// Modeled link/host energy of inbound KV migrations, J (already
    /// included in `total_energy_j`).
    pub migration_energy_j: f64,
    /// E2E latencies of completions that arrived via live migration —
    /// the migrated-request attainment series.
    pub migrated_e2e: Series,
    /// Arrivals shed at admission under fault-degraded capacity
    /// (graceful degradation: refused against the SLO budget instead of
    /// queueing unboundedly). Zero when faults are off.
    pub shed: u64,
    /// Requests lost to faults after their recovery retry budget ran
    /// out. Zero when faults are off.
    pub faulted_lost: u64,
    /// Highest per-iteration KV-block occupancy observed on any one
    /// engine (fleet merge takes the max — it is a peak, not a sum).
    /// The prefix-compare gate reads this: CoW sharing must show a
    /// strictly lower peak on session workloads.
    pub peak_kv_blocks: u32,
    /// Prompt tokens served from resident shared prefixes instead of
    /// recomputed by prefill (sums across replicas). Zero with
    /// `--prefix-share off`.
    pub prefix_cached_tokens: u64,
}

impl ServingStats {
    pub fn record_outcome(&mut self, o: &RequestOutcome) {
        self.e2e.push(o.e2e_s);
        if o.gen_tokens > 1 {
            self.tbt.push(o.tbt_avg_s);
        }
        self.ttft.push(o.ttft_s);
        self.queue.push(o.queue_s());
        self.total_tokens += o.gen_tokens as u64;
        self.completed += 1;
        if o.lost {
            self.lost += 1;
        }
    }

    /// Tokens per Joule — the paper's energy-efficiency metric.
    pub fn tokens_per_joule(&self) -> f64 {
        if self.total_energy_j <= 0.0 {
            return f64::NAN;
        }
        self.total_tokens as f64 / self.total_energy_j
    }

    /// Aggregate throughput, tokens/s.
    pub fn tokens_per_second(&self) -> f64 {
        if self.wall_s <= 0.0 {
            return f64::NAN;
        }
        self.total_tokens as f64 / self.wall_s
    }

    /// Fraction of completions whose E2E beats `slo` (p99 target check).
    pub fn e2e_slo_attainment(&self, slo: f64) -> f64 {
        self.e2e.frac_within(slo)
    }

    /// Fraction of completions whose mean TBT beats `slo`.
    pub fn tbt_slo_attainment(&self, slo: f64) -> f64 {
        self.tbt.frac_within(slo)
    }

    /// Fraction of live-migrated completions whose E2E beats `slo`
    /// (NaN when nothing migrated — the `--migration off` case).
    pub fn migrated_e2e_attainment(&self, slo: f64) -> f64 {
        self.migrated_e2e.frac_within(slo)
    }

    /// Fold another replica's serving stats into this one (fleet
    /// aggregation): sample series concatenate, counters and energy
    /// add, and the wall clock is the latest replica to drain.
    pub fn merge_from(&mut self, other: &ServingStats) {
        self.e2e.extend_from(&other.e2e);
        self.tbt.extend_from(&other.tbt);
        self.ttft.extend_from(&other.ttft);
        self.queue.extend_from(&other.queue);
        self.power.extend_from(&other.power);
        self.freq.extend_from(&other.freq);
        self.iter_tbt.extend_from(&other.iter_tbt);
        self.total_energy_j += other.total_energy_j;
        self.total_tokens += other.total_tokens;
        self.completed += other.completed;
        self.lost += other.lost;
        self.dropped += other.dropped;
        self.wall_s = self.wall_s.max(other.wall_s);
        self.migrated_in += other.migrated_in;
        self.migrated_out += other.migrated_out;
        self.migration_energy_j += other.migration_energy_j;
        self.migrated_e2e.extend_from(&other.migrated_e2e);
        self.shed += other.shed;
        self.faulted_lost += other.faulted_lost;
        self.peak_kv_blocks = self.peak_kv_blocks.max(other.peak_kv_blocks);
        self.prefix_cached_tokens += other.prefix_cached_tokens;
    }

    /// Order-independent fleet reduction: merge `(replica_index,
    /// stats)` parts into one aggregate, sorting by replica index
    /// FIRST so the result is a pure function of the part set.
    /// Float accumulation order is thereby pinned — handing parts in
    /// any permutation produces bit-identical output (property-tested
    /// below), which is what lets the sharded coordinator reduce
    /// worker results without caring how rounds interleaved.
    pub fn merge_ordered<'a, I>(parts: I) -> ServingStats
    where
        I: IntoIterator<Item = (usize, &'a ServingStats)>,
    {
        let mut parts: Vec<(usize, &ServingStats)> = parts.into_iter().collect();
        parts.sort_by_key(|&(id, _)| id);
        let mut total = ServingStats::default();
        for (_, part) in parts {
            total.merge_from(part);
        }
        total
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn outcome(e2e: f64, gen: u32) -> RequestOutcome {
        RequestOutcome {
            id: 0,
            prompt_tokens: 10,
            gen_tokens: gen,
            arrival_s: 0.0,
            scheduled_s: 0.1,
            ttft_s: 0.3,
            e2e_s: e2e,
            tbt_avg_s: 0.02,
            lost: false,
        }
    }

    #[test]
    fn percentiles_interpolate() {
        let mut s = Series::new();
        for i in 1..=100 {
            s.push(i as f64);
        }
        assert!((s.p50() - 50.5).abs() < 1e-9);
        assert!((s.p99() - 99.01).abs() < 0.02);
        assert_eq!(s.percentile(0.0), 1.0);
        assert_eq!(s.percentile(100.0), 100.0);
    }

    #[test]
    fn single_sample_percentiles() {
        let mut s = Series::new();
        s.push(7.0);
        assert_eq!(s.p50(), 7.0);
        assert_eq!(s.p99(), 7.0);
    }

    #[test]
    fn empty_series_is_nan() {
        let s = Series::new();
        assert!(s.mean().is_nan());
        assert!(s.p99().is_nan());
    }

    #[test]
    fn mean_std() {
        let mut s = Series::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            s.push(x);
        }
        assert!((s.mean() - 5.0).abs() < 1e-12);
        assert!((s.std() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn stats_aggregate_outcomes() {
        let mut st = ServingStats::default();
        st.record_outcome(&outcome(1.0, 10));
        st.record_outcome(&outcome(3.0, 20));
        st.total_energy_j = 60.0;
        st.wall_s = 10.0;
        assert_eq!(st.completed, 2);
        assert_eq!(st.total_tokens, 30);
        assert!((st.tokens_per_joule() - 0.5).abs() < 1e-12);
        assert!((st.tokens_per_second() - 3.0).abs() < 1e-12);
        assert!((st.e2e_slo_attainment(2.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn single_token_requests_skip_tbt() {
        let mut st = ServingStats::default();
        st.record_outcome(&outcome(1.0, 1));
        assert!(st.tbt.is_empty());
        assert_eq!(st.completed, 1);
    }

    #[test]
    fn merge_concatenates_and_sums() {
        let mut a = ServingStats::default();
        a.record_outcome(&outcome(1.0, 10));
        a.total_energy_j = 100.0;
        a.wall_s = 5.0;
        let mut b = ServingStats::default();
        b.record_outcome(&outcome(3.0, 20));
        b.record_outcome(&outcome(4.0, 5));
        b.total_energy_j = 50.0;
        b.wall_s = 9.0;
        b.dropped = 2;
        a.merge_from(&b);
        assert_eq!(a.completed, 3);
        assert_eq!(a.dropped, 2);
        assert_eq!(a.total_tokens, 35);
        assert!((a.total_energy_j - 150.0).abs() < 1e-12);
        assert!((a.wall_s - 9.0).abs() < 1e-12);
        assert_eq!(a.e2e.len(), 3);
        assert_eq!(a.e2e.values(), &[1.0, 3.0, 4.0]);
    }

    #[test]
    fn migration_fields_merge_and_attain() {
        let mut a = ServingStats::default();
        a.migrated_in = 2;
        a.migrated_e2e.push(1.0);
        a.migrated_e2e.push(5.0);
        a.migration_energy_j = 10.0;
        a.shed = 1;
        let mut b = ServingStats::default();
        b.migrated_out = 3;
        b.migrated_e2e.push(2.0);
        b.migration_energy_j = 4.0;
        b.shed = 2;
        b.faulted_lost = 1;
        a.merge_from(&b);
        assert_eq!(a.migrated_in, 2);
        assert_eq!(a.migrated_out, 3);
        assert_eq!(a.shed, 3);
        assert_eq!(a.faulted_lost, 1);
        assert_eq!(a.migrated_e2e.len(), 3);
        assert!((a.migration_energy_j - 14.0).abs() < 1e-12);
        // Peak KV takes the max across replicas; cached tokens sum.
        let mut c = ServingStats::default();
        c.peak_kv_blocks = 40;
        c.prefix_cached_tokens = 1024;
        let mut d = ServingStats::default();
        d.peak_kv_blocks = 25;
        d.prefix_cached_tokens = 512;
        c.merge_from(&d);
        assert_eq!(c.peak_kv_blocks, 40);
        assert_eq!(c.prefix_cached_tokens, 1536);
        // 2 of 3 migrated completions inside a 3 s SLO.
        assert!((a.migrated_e2e_attainment(3.0) - 2.0 / 3.0).abs() < 1e-12);
        assert!(ServingStats::default().migrated_e2e_attainment(1.0).is_nan());
    }

    /// Bit-level equality of two stats (floats compared via to_bits —
    /// the fleet determinism contract, not approximate equality).
    fn assert_stats_bit_identical(a: &ServingStats, b: &ServingStats) {
        let series = |s: &ServingStats| {
            [
                s.e2e.values().to_vec(),
                s.tbt.values().to_vec(),
                s.ttft.values().to_vec(),
                s.queue.values().to_vec(),
                s.power.values().to_vec(),
                s.freq.values().to_vec(),
                s.iter_tbt.values().to_vec(),
                s.migrated_e2e.values().to_vec(),
            ]
        };
        for (x, y) in series(a).iter().zip(series(b).iter()) {
            assert_eq!(x.len(), y.len());
            for (u, v) in x.iter().zip(y.iter()) {
                assert_eq!(u.to_bits(), v.to_bits());
            }
        }
        assert_eq!(a.total_energy_j.to_bits(), b.total_energy_j.to_bits());
        assert_eq!(a.wall_s.to_bits(), b.wall_s.to_bits());
        assert_eq!(
            a.migration_energy_j.to_bits(),
            b.migration_energy_j.to_bits()
        );
        assert_eq!(a.total_tokens, b.total_tokens);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.lost, b.lost);
        assert_eq!(a.dropped, b.dropped);
        assert_eq!(a.migrated_in, b.migrated_in);
        assert_eq!(a.migrated_out, b.migrated_out);
        assert_eq!(a.shed, b.shed);
        assert_eq!(a.faulted_lost, b.faulted_lost);
        assert_eq!(a.peak_kv_blocks, b.peak_kv_blocks);
        assert_eq!(a.prefix_cached_tokens, b.prefix_cached_tokens);
    }

    #[test]
    fn merge_ordered_is_permutation_invariant() {
        // Property: merging any permutation of tagged per-replica parts
        // produces BIT-identical aggregates.  Values are chosen to make
        // float-order sensitivity visible (summing doubles of very
        // different magnitudes does not commute bitwise), so an
        // unsorted reduction would fail this test.
        const SCALES: [f64; 7] = [1e-9, 1e-6, 1e-3, 1.0, 1e3, 1e6, 1e9];
        let k = SCALES.len();
        let mut parts: Vec<ServingStats> = Vec::new();
        for i in 0..k {
            let mut s = ServingStats::default();
            let scale = SCALES[i];
            s.record_outcome(&outcome(0.1 + scale, 10 + i as u32));
            s.record_outcome(&outcome(3.0 * scale + 0.7, 20));
            s.total_energy_j = 1e-4 + scale * 7.3;
            s.migration_energy_j = scale / 3.0;
            s.wall_s = 5.0 + i as f64 * 0.1;
            s.dropped = i as u64 % 3;
            s.migrated_in = i as u64;
            s.migrated_e2e.push(scale + 0.01);
            s.peak_kv_blocks = ((i * 37) % 50) as u32;
            s.prefix_cached_tokens = i as u64 * 192;
            parts.push(s);
        }
        let tagged: Vec<(usize, &ServingStats)> =
            parts.iter().enumerate().collect();
        let reference = ServingStats::merge_ordered(tagged.clone());

        // Identity, reversed, and every rotation of the part list.
        let mut orders: Vec<Vec<(usize, &ServingStats)>> = vec![
            tagged.clone(),
            tagged.iter().rev().cloned().collect(),
        ];
        for r in 1..k {
            let mut rot = tagged.clone();
            rot.rotate_left(r);
            orders.push(rot);
        }
        for order in orders {
            let merged = ServingStats::merge_ordered(order);
            assert_stats_bit_identical(&reference, &merged);
        }

        // And the pinned order matches today's plain index-order fold
        // (the pre-refactor aggregation), bit for bit.
        let mut plain = ServingStats::default();
        for p in &parts {
            plain.merge_from(p);
        }
        assert_stats_bit_identical(&reference, &plain);
    }

    #[test]
    fn attainment_fractions() {
        let mut st = ServingStats::default();
        for e2e in [1.0, 2.0, 3.0, 10.0] {
            st.record_outcome(&outcome(e2e, 10));
        }
        assert!((st.e2e_slo_attainment(3.0) - 0.75).abs() < 1e-12);
        // All recorded outcomes share tbt_avg 0.02.
        assert!((st.tbt_slo_attainment(0.2) - 1.0).abs() < 1e-12);
        assert!((st.tbt_slo_attainment(0.01) - 0.0).abs() < 1e-12);
        let empty = ServingStats::default();
        assert!(empty.e2e_slo_attainment(1.0).is_nan());
        assert!(empty.tbt_slo_attainment(1.0).is_nan());
    }
}
