//! Tabular dataset container with train/test splitting.

use crate::sim::Pcg64;

/// A dense (rows x features) dataset with a scalar target per row.
#[derive(Debug, Clone, Default)]
pub struct Dataset {
    pub features: Vec<Vec<f64>>,
    pub targets: Vec<f64>,
}

impl Dataset {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, row: Vec<f64>, target: f64) {
        if let Some(first) = self.features.first() {
            assert_eq!(first.len(), row.len(), "inconsistent feature count");
        }
        self.features.push(row);
        self.targets.push(target);
    }

    pub fn len(&self) -> usize {
        self.targets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.targets.is_empty()
    }

    pub fn n_features(&self) -> usize {
        self.features.first().map(|r| r.len()).unwrap_or(0)
    }

    /// Random split: first element holds `train_frac` of rows.
    /// Mirrors the paper's 90/10 and 10/90 protocols (Table III).
    pub fn split(&self, train_frac: f64, rng: &mut Pcg64) -> (Dataset, Dataset) {
        assert!((0.0..=1.0).contains(&train_frac));
        let mut idx: Vec<usize> = (0..self.len()).collect();
        rng.shuffle(&mut idx);
        let n_train = ((self.len() as f64) * train_frac).round() as usize;
        let mut train = Dataset::new();
        let mut test = Dataset::new();
        for (pos, &i) in idx.iter().enumerate() {
            let dst = if pos < n_train { &mut train } else { &mut test };
            dst.push(self.features[i].clone(), self.targets[i]);
        }
        (train, test)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy(n: usize) -> Dataset {
        let mut d = Dataset::new();
        for i in 0..n {
            d.push(vec![i as f64, (i * 2) as f64], i as f64);
        }
        d
    }

    #[test]
    fn push_and_shape() {
        let d = toy(10);
        assert_eq!(d.len(), 10);
        assert_eq!(d.n_features(), 2);
    }

    #[test]
    fn split_fractions() {
        let d = toy(100);
        let mut rng = Pcg64::new(0);
        let (tr, te) = d.split(0.9, &mut rng);
        assert_eq!(tr.len(), 90);
        assert_eq!(te.len(), 10);
        let (tr2, te2) = d.split(0.1, &mut rng);
        assert_eq!(tr2.len(), 10);
        assert_eq!(te2.len(), 90);
    }

    #[test]
    fn split_partitions_rows() {
        let d = toy(50);
        let mut rng = Pcg64::new(1);
        let (tr, te) = d.split(0.5, &mut rng);
        let mut all: Vec<f64> =
            tr.targets.iter().chain(te.targets.iter()).copied().collect();
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, (0..50).map(|i| i as f64).collect::<Vec<_>>());
    }

    #[test]
    #[should_panic(expected = "inconsistent feature count")]
    fn rejects_ragged_rows() {
        let mut d = Dataset::new();
        d.push(vec![1.0], 0.0);
        d.push(vec![1.0, 2.0], 0.0);
    }
}
