//! Regression metrics: R², MAE, MAPE (Table III protocol).

/// Coefficient of determination.
pub fn r2_score(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    assert!(!truth.is_empty());
    let mean = truth.iter().sum::<f64>() / truth.len() as f64;
    let ss_res: f64 = truth
        .iter()
        .zip(pred)
        .map(|(t, p)| (t - p) * (t - p))
        .sum();
    let ss_tot: f64 = truth.iter().map(|t| (t - mean) * (t - mean)).sum();
    if ss_tot == 0.0 {
        return if ss_res == 0.0 { 1.0 } else { 0.0 };
    }
    1.0 - ss_res / ss_tot
}

/// Mean absolute error.
pub fn mae(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    assert!(!truth.is_empty());
    truth
        .iter()
        .zip(pred)
        .map(|(t, p)| (t - p).abs())
        .sum::<f64>()
        / truth.len() as f64
}

/// Mean absolute percentage error, in percent.
pub fn mape(truth: &[f64], pred: &[f64]) -> f64 {
    assert_eq!(truth.len(), pred.len());
    assert!(!truth.is_empty());
    let mut total = 0.0;
    let mut n = 0usize;
    for (t, p) in truth.iter().zip(pred) {
        if t.abs() > 1e-12 {
            total += ((t - p) / t).abs();
            n += 1;
        }
    }
    assert!(n > 0, "mape: all targets zero");
    100.0 * total / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction() {
        let t = [1.0, 2.0, 3.0];
        assert_eq!(r2_score(&t, &t), 1.0);
        assert_eq!(mae(&t, &t), 0.0);
        assert_eq!(mape(&t, &t), 0.0);
    }

    #[test]
    fn mean_prediction_gives_zero_r2() {
        let t = [1.0, 2.0, 3.0];
        let p = [2.0, 2.0, 2.0];
        assert!(r2_score(&t, &p).abs() < 1e-12);
    }

    #[test]
    fn known_values() {
        let t = [10.0, 20.0];
        let p = [11.0, 18.0];
        assert!((mae(&t, &p) - 1.5).abs() < 1e-12);
        assert!((mape(&t, &p) - 10.0).abs() < 1e-9); // (10% + 10%) / 2
    }

    #[test]
    fn mape_skips_zero_targets() {
        let t = [0.0, 10.0];
        let p = [1.0, 9.0];
        assert!((mape(&t, &p) - 10.0).abs() < 1e-9);
    }

    #[test]
    fn constant_truth_r2() {
        let t = [5.0, 5.0];
        assert_eq!(r2_score(&t, &[5.0, 5.0]), 1.0);
        assert_eq!(r2_score(&t, &[4.0, 6.0]), 0.0);
    }
}
