//! Gradient boosting over regression trees (squared loss).

use crate::mlmodel::dataset::Dataset;
use crate::mlmodel::tree::{RegressionTree, TreeParams};
use crate::sim::Pcg64;

/// Boosting hyperparameters.
#[derive(Debug, Clone, Copy)]
pub struct GbdtParams {
    pub n_trees: u32,
    pub learning_rate: f64,
    pub tree: TreeParams,
    /// Row subsample fraction per tree (1.0 = deterministic boosting).
    pub subsample: f64,
    pub seed: u64,
}

impl Default for GbdtParams {
    fn default() -> Self {
        Self {
            n_trees: 200,
            learning_rate: 0.1,
            tree: TreeParams::default(),
            subsample: 1.0,
            seed: 0,
        }
    }
}

/// A fitted gradient-boosted ensemble.
#[derive(Debug, Clone)]
pub struct Gbdt {
    base: f64,
    learning_rate: f64,
    trees: Vec<RegressionTree>,
}

impl Gbdt {
    /// Fit with squared loss: each tree regresses the current residual.
    pub fn fit(data: &Dataset, params: &GbdtParams) -> Self {
        assert!(!data.is_empty(), "empty training set");
        assert!(params.learning_rate > 0.0 && params.subsample > 0.0);
        let n = data.len();
        let base = data.targets.iter().sum::<f64>() / n as f64;
        let mut pred = vec![base; n];
        let mut trees = Vec::with_capacity(params.n_trees as usize);
        let mut rng = Pcg64::new(params.seed);
        let mut residual = vec![0.0; n];
        let mut all_idx: Vec<usize> = (0..n).collect();

        for _ in 0..params.n_trees {
            for i in 0..n {
                residual[i] = data.targets[i] - pred[i];
            }
            let idx: Vec<usize> = if params.subsample >= 1.0 {
                all_idx.clone()
            } else {
                rng.shuffle(&mut all_idx);
                let take = ((n as f64) * params.subsample).ceil() as usize;
                all_idx[..take.max(2 * params.tree.min_samples_leaf).min(n)].to_vec()
            };
            let tree = RegressionTree::fit(&data.features, &residual, &idx, &params.tree);
            for i in 0..n {
                pred[i] += params.learning_rate * tree.predict(&data.features[i]);
            }
            trees.push(tree);
        }
        Gbdt {
            base,
            learning_rate: params.learning_rate,
            trees,
        }
    }

    /// Predict a single row.
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut y = self.base;
        for t in &self.trees {
            y += self.learning_rate * t.predict(row);
        }
        y
    }

    /// Predict many rows.
    pub fn predict_batch(&self, rows: &[Vec<f64>]) -> Vec<f64> {
        rows.iter().map(|r| self.predict(r)).collect()
    }

    pub fn n_trees(&self) -> usize {
        self.trees.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mlmodel::eval::{mae, r2_score};

    fn synthetic(n: usize, seed: u64) -> Dataset {
        // A bounded 4-feature surface shaped like the serving problem:
        // ips = g(engine, batch, kv, freq) with feature interactions.
        let mut rng = Pcg64::new(seed);
        let mut d = Dataset::new();
        for _ in 0..n {
            let engine = rng.uniform_u64(1, 4) as f64;
            let batch = rng.uniform_u64(1, 32) as f64;
            let kv = rng.next_f64();
            let freq = rng.uniform_f64(210.0, 1410.0);
            let fn_ = freq / 1410.0;
            let ips = 1000.0
                / (2.0 / engine / fn_ + (10.0 + 0.2 * batch + 3.0 * kv) / engine
                    / (0.3 + 0.7 * fn_));
            d.push(vec![engine, batch, kv, freq], ips);
        }
        d
    }

    #[test]
    fn fits_serving_like_surface_with_high_r2() {
        let data = synthetic(4000, 0);
        let mut rng = Pcg64::new(1);
        let (train, test) = data.split(0.9, &mut rng);
        let model = Gbdt::fit(&train, &GbdtParams::default());
        let pred = model.predict_batch(&test.features);
        let r2 = r2_score(&test.targets, &pred);
        assert!(r2 > 0.97, "r2={r2}");
    }

    #[test]
    fn sparse_training_still_generalizes() {
        // The paper's 10/90 split protocol.
        let data = synthetic(4000, 2);
        let mut rng = Pcg64::new(3);
        let (train, test) = data.split(0.1, &mut rng);
        let model = Gbdt::fit(&train, &GbdtParams::default());
        let pred = model.predict_batch(&test.features);
        let r2 = r2_score(&test.targets, &pred);
        assert!(r2 > 0.93, "r2={r2}");
    }

    #[test]
    fn more_trees_reduce_training_error() {
        let data = synthetic(1000, 4);
        let small = Gbdt::fit(
            &data,
            &GbdtParams {
                n_trees: 5,
                ..Default::default()
            },
        );
        let big = Gbdt::fit(
            &data,
            &GbdtParams {
                n_trees: 100,
                ..Default::default()
            },
        );
        let mae_small = mae(&data.targets, &small.predict_batch(&data.features));
        let mae_big = mae(&data.targets, &big.predict_batch(&data.features));
        assert!(mae_big < mae_small * 0.5, "{mae_big} vs {mae_small}");
    }

    #[test]
    fn subsampling_works() {
        let data = synthetic(2000, 5);
        let model = Gbdt::fit(
            &data,
            &GbdtParams {
                subsample: 0.5,
                ..Default::default()
            },
        );
        let r2 = r2_score(&data.targets, &model.predict_batch(&data.features));
        assert!(r2 > 0.95, "r2={r2}");
    }

    #[test]
    fn deterministic_for_seed() {
        let data = synthetic(500, 6);
        let p = GbdtParams {
            subsample: 0.7,
            seed: 9,
            n_trees: 20,
            ..Default::default()
        };
        let a = Gbdt::fit(&data, &p);
        let b = Gbdt::fit(&data, &p);
        for row in data.features.iter().take(50) {
            assert_eq!(a.predict(row), b.predict(row));
        }
    }

    #[test]
    fn base_prediction_is_mean_with_zero_trees() {
        let data = synthetic(100, 7);
        let model = Gbdt::fit(
            &data,
            &GbdtParams {
                n_trees: 0,
                ..Default::default()
            },
        );
        let mean = data.targets.iter().sum::<f64>() / data.len() as f64;
        assert_eq!(model.predict(&data.features[0]), mean);
    }
}
