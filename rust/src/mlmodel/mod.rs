//! Gradient-boosted decision trees, from scratch (XGBoost substitute).
//!
//! The paper's performance-prediction model `M` is a Gradient Boosted
//! Decision Tree (§IV-C1, refs [10], [20]) over four bounded features —
//! engine size, batch size, KV cache usage, GPU frequency — predicting
//! iterations/second.  This module implements the model class:
//! regression trees greedily split on exact sorted thresholds
//! (variance gain), boosted under squared loss with shrinkage and
//! optional row subsampling.  Inference is a few hundred shallow-tree
//! traversals — microseconds, far inside the paper's ~3 ms budget.

pub mod dataset;
pub mod eval;
pub mod gbdt;
pub mod tree;

pub use dataset::Dataset;
pub use eval::{mae, mape, r2_score};
pub use gbdt::{Gbdt, GbdtParams};
pub use tree::{RegressionTree, TreeParams};
