//! Regression tree with exact greedy splits (variance gain).

/// Tree growth parameters.
#[derive(Debug, Clone, Copy)]
pub struct TreeParams {
    pub max_depth: u32,
    pub min_samples_leaf: usize,
    /// Minimum variance gain to accept a split.
    pub min_gain: f64,
}

impl Default for TreeParams {
    fn default() -> Self {
        Self {
            max_depth: 5,
            min_samples_leaf: 5,
            min_gain: 1e-12,
        }
    }
}

#[derive(Debug, Clone)]
enum Node {
    Leaf {
        value: f64,
    },
    Split {
        feature: usize,
        threshold: f64,
        left: usize,
        right: usize,
    },
}

/// A fitted regression tree (flat node arena, root at 0).
#[derive(Debug, Clone)]
pub struct RegressionTree {
    nodes: Vec<Node>,
}

impl RegressionTree {
    /// Fit on rows `idx` of (x, y).
    pub fn fit(x: &[Vec<f64>], y: &[f64], idx: &[usize], params: &TreeParams) -> Self {
        assert_eq!(x.len(), y.len());
        assert!(!idx.is_empty(), "empty training set");
        let mut tree = RegressionTree { nodes: Vec::new() };
        let mut scratch = idx.to_vec();
        tree.grow(x, y, &mut scratch, 0, params);
        tree
    }

    /// Recursively grow; returns the index of the created node.
    fn grow(
        &mut self,
        x: &[Vec<f64>],
        y: &[f64],
        idx: &mut [usize],
        depth: u32,
        params: &TreeParams,
    ) -> usize {
        let mean = idx.iter().map(|&i| y[i]).sum::<f64>() / idx.len() as f64;
        if depth >= params.max_depth || idx.len() < 2 * params.min_samples_leaf {
            return self.leaf(mean);
        }
        match best_split(x, y, idx, params) {
            None => self.leaf(mean),
            Some(split) => {
                // Partition idx in-place around the chosen threshold.
                let mid = partition(x, idx, split.feature, split.threshold);
                debug_assert!(mid > 0 && mid < idx.len());
                let node_id = self.nodes.len();
                self.nodes.push(Node::Leaf { value: mean }); // placeholder
                let (l_idx, r_idx) = idx.split_at_mut(mid);
                let left = self.grow(x, y, l_idx, depth + 1, params);
                let right = self.grow(x, y, r_idx, depth + 1, params);
                self.nodes[node_id] = Node::Split {
                    feature: split.feature,
                    threshold: split.threshold,
                    left,
                    right,
                };
                node_id
            }
        }
    }

    fn leaf(&mut self, value: f64) -> usize {
        self.nodes.push(Node::Leaf { value });
        self.nodes.len() - 1
    }

    /// Predict one row.
    pub fn predict(&self, row: &[f64]) -> f64 {
        let mut node = 0usize;
        loop {
            match &self.nodes[node] {
                Node::Leaf { value } => return *value,
                Node::Split {
                    feature,
                    threshold,
                    left,
                    right,
                } => {
                    node = if row[*feature] <= *threshold {
                        *left
                    } else {
                        *right
                    };
                }
            }
        }
    }

    pub fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub fn depth(&self) -> u32 {
        fn d(nodes: &[Node], i: usize) -> u32 {
            match &nodes[i] {
                Node::Leaf { .. } => 0,
                Node::Split { left, right, .. } => {
                    1 + d(nodes, *left).max(d(nodes, *right))
                }
            }
        }
        d(&self.nodes, 0)
    }
}

struct Split {
    feature: usize,
    threshold: f64,
}

/// Exact best split by variance gain over all features.
fn best_split(
    x: &[Vec<f64>],
    y: &[f64],
    idx: &[usize],
    params: &TreeParams,
) -> Option<Split> {
    let n = idx.len() as f64;
    let total_sum: f64 = idx.iter().map(|&i| y[i]).sum();
    let total_sq: f64 = idx.iter().map(|&i| y[i] * y[i]).sum();
    let parent_sse = total_sq - total_sum * total_sum / n;

    let n_features = x[idx[0]].len();
    let mut best: Option<(f64, Split)> = None;
    let mut order: Vec<usize> = idx.to_vec();
    for f in 0..n_features {
        order.sort_by(|&a, &b| x[a][f].partial_cmp(&x[b][f]).unwrap());
        let mut left_sum = 0.0;
        let mut left_sq = 0.0;
        for (k, &i) in order.iter().enumerate().take(order.len() - 1) {
            left_sum += y[i];
            left_sq += y[i] * y[i];
            let nl = (k + 1) as f64;
            let nr = n - nl;
            // Can't split between equal feature values.
            if x[i][f] == x[order[k + 1]][f] {
                continue;
            }
            if (k + 1) < params.min_samples_leaf
                || (order.len() - k - 1) < params.min_samples_leaf
            {
                continue;
            }
            let right_sum = total_sum - left_sum;
            let right_sq = total_sq - left_sq;
            let sse = (left_sq - left_sum * left_sum / nl)
                + (right_sq - right_sum * right_sum / nr);
            let gain = parent_sse - sse;
            if gain > params.min_gain
                && best.as_ref().map(|(g, _)| gain > *g).unwrap_or(true)
            {
                best = Some((
                    gain,
                    Split {
                        feature: f,
                        threshold: 0.5 * (x[i][f] + x[order[k + 1]][f]),
                    },
                ));
            }
        }
    }
    best.map(|(_, s)| s)
}

/// Partition `idx` so rows with x[f] <= t come first; returns the
/// boundary position.
fn partition(x: &[Vec<f64>], idx: &mut [usize], feature: usize, t: f64) -> usize {
    let mut mid = 0;
    for k in 0..idx.len() {
        if x[idx[k]][feature] <= t {
            idx.swap(mid, k);
            mid += 1;
        }
    }
    mid
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::Pcg64;

    fn fit_all(x: &[Vec<f64>], y: &[f64], p: &TreeParams) -> RegressionTree {
        let idx: Vec<usize> = (0..y.len()).collect();
        RegressionTree::fit(x, y, &idx, p)
    }

    #[test]
    fn constant_target_gives_single_leaf() {
        let x: Vec<Vec<f64>> = (0..20).map(|i| vec![i as f64]).collect();
        let y = vec![3.5; 20];
        let t = fit_all(&x, &y, &TreeParams::default());
        assert_eq!(t.n_nodes(), 1);
        assert_eq!(t.predict(&[100.0]), 3.5);
    }

    #[test]
    fn learns_step_function() {
        let x: Vec<Vec<f64>> = (0..100).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..100).map(|i| if i < 50 { 1.0 } else { 9.0 }).collect();
        let t = fit_all(&x, &y, &TreeParams::default());
        assert!((t.predict(&[10.0]) - 1.0).abs() < 1e-9);
        assert!((t.predict(&[90.0]) - 9.0).abs() < 1e-9);
    }

    #[test]
    fn respects_max_depth() {
        let mut rng = Pcg64::new(4);
        let x: Vec<Vec<f64>> = (0..500).map(|_| vec![rng.next_f64()]).collect();
        let y: Vec<f64> = x
            .iter()
            .map(|r| crate::sim::detmath::sin_det(10.0 * r[0]))
            .collect();
        let p = TreeParams {
            max_depth: 3,
            ..Default::default()
        };
        let t = fit_all(&x, &y, &p);
        assert!(t.depth() <= 3);
    }

    #[test]
    fn splits_on_informative_feature() {
        // Feature 1 is noise; feature 0 drives the target.
        let mut rng = Pcg64::new(5);
        let x: Vec<Vec<f64>> = (0..400)
            .map(|_| vec![rng.next_f64(), rng.next_f64()])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| if r[0] > 0.5 { 4.0 } else { -4.0 }).collect();
        let t = fit_all(&x, &y, &TreeParams::default());
        // Evaluate: predictions should track feature 0.
        for probe in [0.1, 0.3, 0.7, 0.9] {
            let want = if probe > 0.5 { 4.0 } else { -4.0 };
            assert!((t.predict(&[probe, 0.5]) - want).abs() < 0.5);
        }
    }

    #[test]
    fn min_samples_leaf_enforced() {
        let x: Vec<Vec<f64>> = (0..10).map(|i| vec![i as f64]).collect();
        let y: Vec<f64> = (0..10).map(|i| i as f64).collect();
        let p = TreeParams {
            min_samples_leaf: 6,
            ..Default::default()
        };
        // 10 rows cannot split into two leaves of >= 6.
        let t = fit_all(&x, &y, &p);
        assert_eq!(t.n_nodes(), 1);
    }

    #[test]
    fn interpolates_smooth_function() {
        let mut rng = Pcg64::new(6);
        let x: Vec<Vec<f64>> = (0..2000)
            .map(|_| vec![rng.uniform_f64(0.0, 1.0), rng.uniform_f64(0.0, 1.0)])
            .collect();
        let y: Vec<f64> = x.iter().map(|r| 3.0 * r[0] + r[1] * r[1]).collect();
        let p = TreeParams {
            max_depth: 8,
            min_samples_leaf: 4,
            min_gain: 1e-12,
        };
        let t = fit_all(&x, &y, &p);
        let mut err = 0.0;
        for r in x.iter().take(200) {
            err += (t.predict(r) - (3.0 * r[0] + r[1] * r[1])).abs();
        }
        assert!(err / 200.0 < 0.1, "mae={}", err / 200.0);
    }
}
