//! Artifact discovery: `artifacts/manifest.json`, HLO text files and
//! the flat weights binary emitted by `python/compile/aot.py`.

use std::path::{Path, PathBuf};

use crate::jsonl::{parse, Json};

/// Architecture of the AOT-compiled model (mirrors python ModelConfig).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TinyConfig {
    pub vocab: u32,
    pub d_model: u32,
    pub n_heads: u32,
    pub n_layers: u32,
    pub d_ff: u32,
    pub max_seq: u32,
    pub prompt_len: u32,
}

impl TinyConfig {
    pub fn head_dim(&self) -> u32 {
        self.d_model / self.n_heads
    }

    /// Elements in one KV cache tensor for batch bucket `b`:
    /// [n_layers, b, n_heads, max_seq, head_dim].
    pub fn cache_elems(&self, b: u32) -> usize {
        (self.n_layers * b * self.n_heads * self.max_seq * self.head_dim()) as usize
    }

    pub fn cache_dims(&self, b: u32) -> [i64; 5] {
        [
            self.n_layers as i64,
            b as i64,
            self.n_heads as i64,
            self.max_seq as i64,
            self.head_dim() as i64,
        ]
    }
}

/// Parsed manifest + resolved paths.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub config: TinyConfig,
    pub num_params: usize,
    pub batches: Vec<u32>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        let j = parse(&text)?;
        let cfg = j
            .get("config")
            .ok_or_else(|| anyhow::anyhow!("manifest missing config"))?;
        let num = |k: &str| -> anyhow::Result<u32> {
            cfg.get(k)
                .and_then(Json::as_u64)
                .map(|x| x as u32)
                .ok_or_else(|| anyhow::anyhow!("manifest config missing {k}"))
        };
        let config = TinyConfig {
            vocab: num("vocab")?,
            d_model: num("d_model")?,
            n_heads: num("n_heads")?,
            n_layers: num("n_layers")?,
            d_ff: num("d_ff")?,
            max_seq: num("max_seq")?,
            prompt_len: num("prompt_len")?,
        };
        let batches = j
            .get("batches")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow::anyhow!("manifest missing batches"))?
            .iter()
            .filter_map(Json::as_u64)
            .map(|b| b as u32)
            .collect::<Vec<_>>();
        anyhow::ensure!(!batches.is_empty(), "no batch buckets in manifest");
        let num_params = j
            .get("num_params")
            .and_then(Json::as_u64)
            .ok_or_else(|| anyhow::anyhow!("manifest missing num_params"))?
            as usize;
        Ok(Self {
            dir: dir.to_path_buf(),
            config,
            num_params,
            batches,
        })
    }

    pub fn hlo_path(&self, kind: &str, batch: u32) -> PathBuf {
        self.dir.join(format!("{kind}_b{batch}.hlo.txt"))
    }

    /// Read `weights.bin` as little-endian f32.
    pub fn load_weights(&self) -> anyhow::Result<Vec<f32>> {
        let path = self.dir.join("weights.bin");
        let bytes = std::fs::read(&path)
            .map_err(|e| anyhow::anyhow!("read {}: {e}", path.display()))?;
        anyhow::ensure!(
            bytes.len() == self.num_params * 4,
            "weights.bin: {} bytes, expected {}",
            bytes.len(),
            self.num_params * 4
        );
        Ok(bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Smallest batch bucket >= `batch`.
    pub fn bucket_for(&self, batch: u32) -> anyhow::Result<u32> {
        self.batches
            .iter()
            .copied()
            .filter(|&b| b >= batch)
            .min()
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "batch {batch} exceeds largest bucket {:?}",
                    self.batches.iter().max()
                )
            })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn manifest_loads_when_artifacts_built() {
        let dir = artifacts_dir();
        if !dir.join("manifest.json").exists() {
            eprintln!("skipped: run `make artifacts` first");
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.config.vocab > 0);
        assert_eq!(m.batches, vec![1, 2, 4, 8]);
        let w = m.load_weights().unwrap();
        assert_eq!(w.len(), m.num_params);
        assert!(w.iter().all(|x| x.is_finite()));
        assert!(m.hlo_path("decode", 1).exists());
    }

    #[test]
    fn bucket_selection() {
        let m = Manifest {
            dir: PathBuf::new(),
            config: TinyConfig {
                vocab: 8,
                d_model: 8,
                n_heads: 2,
                n_layers: 1,
                d_ff: 8,
                max_seq: 8,
                prompt_len: 4,
            },
            num_params: 0,
            batches: vec![1, 2, 4, 8],
        };
        assert_eq!(m.bucket_for(1).unwrap(), 1);
        assert_eq!(m.bucket_for(3).unwrap(), 4);
        assert_eq!(m.bucket_for(8).unwrap(), 8);
        assert!(m.bucket_for(9).is_err());
    }

    #[test]
    fn cache_dims_shape() {
        let c = TinyConfig {
            vocab: 256,
            d_model: 64,
            n_heads: 4,
            n_layers: 2,
            d_ff: 128,
            max_seq: 256,
            prompt_len: 32,
        };
        assert_eq!(c.head_dim(), 16);
        assert_eq!(c.cache_dims(4), [2, 4, 4, 256, 16]);
        assert_eq!(c.cache_elems(1), 2 * 4 * 256 * 16);
    }
}
