//! PJRT runtime: loads the AOT HLO-text artifacts and serves the
//! tiny-llama-sim model from Rust — Python never runs at request time.
//!
//! Pipeline (see /opt/xla-example/load_hlo and DESIGN.md):
//!   `HloModuleProto::from_text_file` -> `XlaComputation::from_proto`
//!   -> `PjRtClient::compile` (once per batch bucket, cached)
//!   -> `execute` per prefill/decode step.
//!
//! HLO *text* is the interchange format: jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids.

pub mod artifacts;
pub mod model;

pub use artifacts::{Manifest, TinyConfig};
pub use model::{DecodeState, ModelRuntime};
