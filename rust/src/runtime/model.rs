//! Model execution: compiled prefill/decode executables per batch
//! bucket, KV-cache state management, greedy sampling.
//!
//! The real execution path goes through the `xla` PJRT FFI
//! (`HloModuleProto::from_text_file` -> `XlaComputation::from_proto`
//! -> `PjRtClient::compile` -> `execute`) and is gated behind the
//! `pjrt` cargo feature because that toolchain is not vendored in the
//! offline build.  The default build ships an API-compatible stub:
//! artifact discovery (`Manifest`) still works, but
//! [`ModelRuntime::load`] returns an explanatory error, and the
//! runtime integration tests self-skip (they already skip when
//! `make artifacts` has not been run).

#[cfg(feature = "pjrt")]
mod imp {
    // Reviewed HashMap use: executable caches are keyed lookup only
    // and are never iterated, so hash order cannot reach outcomes.
    #![allow(clippy::disallowed_types)]

    use std::collections::HashMap;
    use std::path::Path;

    use anyhow::Context;

    use super::super::artifacts::{Manifest, TinyConfig};

    /// Live decode state for a batch (dense KV caches + positions).
    pub struct DecodeState {
        /// Batch bucket the caches are shaped for.
        pub bucket: u32,
        /// Live rows (<= bucket); padded rows are ignored.
        pub live: usize,
        /// Per-row write position (== tokens so far) for live rows.
        pub positions: Vec<i32>,
        k_cache: xla::Literal,
        v_cache: xla::Literal,
    }

    /// The PJRT-backed model runtime.
    pub struct ModelRuntime {
        pub manifest: Manifest,
        client: xla::PjRtClient,
        weights: xla::Literal,
        decode: HashMap<u32, xla::PjRtLoadedExecutable>,
        prefill: HashMap<u32, xla::PjRtLoadedExecutable>,
    }

    impl ModelRuntime {
        /// Load artifacts from `dir` and compile every batch bucket.
        pub fn load(dir: &Path) -> anyhow::Result<Self> {
            let manifest = Manifest::load(dir)?;
            let client = xla::PjRtClient::cpu()?;
            let w = manifest.load_weights()?;
            let weights = xla::Literal::vec1(&w);

            let mut decode = HashMap::new();
            let mut prefill = HashMap::new();
            for &b in &manifest.batches {
                decode.insert(b, Self::compile(&client, &manifest.hlo_path("decode", b))?);
                prefill.insert(b, Self::compile(&client, &manifest.hlo_path("prefill", b))?);
            }
            Ok(Self {
                manifest,
                client,
                weights,
                decode,
                prefill,
            })
        }

        fn compile(
            client: &xla::PjRtClient,
            path: &Path,
        ) -> anyhow::Result<xla::PjRtLoadedExecutable> {
            let proto = xla::HloModuleProto::from_text_file(path)
                .with_context(|| format!("parse {}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            client
                .compile(&comp)
                .with_context(|| format!("compile {}", path.display()))
        }

        pub fn config(&self) -> &TinyConfig {
            &self.manifest.config
        }

        pub fn platform(&self) -> String {
            self.client.platform_name()
        }

        /// Run the prompt phase for `prompts` (token ids per row).
        /// Prompts are truncated/padded to the `prompt_len` bucket.
        /// Returns the decode state and the first generated token per
        /// row (greedy).
        pub fn prefill(
            &self,
            prompts: &[Vec<i32>],
        ) -> anyhow::Result<(DecodeState, Vec<i32>)> {
            anyhow::ensure!(!prompts.is_empty(), "empty prompt batch");
            let cfg = *self.config();
            let bucket = self.manifest.bucket_for(prompts.len() as u32)?;
            let plen = cfg.prompt_len as usize;

            let mut tokens = vec![0i32; bucket as usize * plen];
            let mut lengths = vec![1i32; bucket as usize];
            for (r, p) in prompts.iter().enumerate() {
                anyhow::ensure!(!p.is_empty(), "empty prompt row {r}");
                let n = p.len().min(plen);
                tokens[r * plen..r * plen + n].copy_from_slice(&p[..n]);
                lengths[r] = n as i32;
            }
            let tok_lit =
                xla::Literal::vec1(&tokens).reshape(&[bucket as i64, plen as i64])?;
            let len_lit = xla::Literal::vec1(&lengths);

            let exe = &self.prefill[&bucket];
            let result = exe.execute(&[&self.weights, &tok_lit, &len_lit])?;
            let out = result[0][0].to_literal_sync()?;
            let (logits, k_cache, v_cache) = out.to_tuple3()?;

            let first = argmax_rows(&logits, bucket as usize, cfg.vocab as usize)?;
            let positions: Vec<i32> = lengths.clone();
            Ok((
                DecodeState {
                    bucket,
                    live: prompts.len(),
                    positions,
                    k_cache,
                    v_cache,
                },
                first[..prompts.len()].to_vec(),
            ))
        }

        /// One decode iteration: feed the last generated token per live
        /// row; returns the next greedy token per live row.
        pub fn decode_step(
            &self,
            state: &mut DecodeState,
            last_tokens: &[i32],
        ) -> anyhow::Result<Vec<i32>> {
            let cfg = *self.config();
            anyhow::ensure!(
                last_tokens.len() == state.live,
                "expected {} tokens, got {}",
                state.live,
                last_tokens.len()
            );
            let b = state.bucket as usize;
            let mut toks = vec![0i32; b];
            toks[..state.live].copy_from_slice(last_tokens);
            let tok_lit = xla::Literal::vec1(&toks);
            let pos_lit = xla::Literal::vec1(&state.positions);

            let exe = &self.decode[&state.bucket];
            let result = exe.execute(&[
                &self.weights,
                &state.k_cache,
                &state.v_cache,
                &tok_lit,
                &pos_lit,
            ])?;
            let out = result[0][0].to_literal_sync()?;
            let (logits, k, v) = out.to_tuple3()?;
            state.k_cache = k;
            state.v_cache = v;
            for p in state.positions.iter_mut().take(state.live) {
                *p = (*p + 1).min(cfg.max_seq as i32 - 1);
            }
            let next = argmax_rows(&logits, b, cfg.vocab as usize)?;
            Ok(next[..state.live].to_vec())
        }

        /// Greedy generation: prefill + `steps - 1` decode iterations.
        /// Returns `steps` generated tokens per row.
        pub fn greedy_generate(
            &self,
            prompts: &[Vec<i32>],
            steps: usize,
        ) -> anyhow::Result<Vec<Vec<i32>>> {
            anyhow::ensure!(steps >= 1);
            let (mut state, first) = self.prefill(prompts)?;
            let mut rows: Vec<Vec<i32>> = first.iter().map(|&t| vec![t]).collect();
            let mut last = first;
            for _ in 1..steps {
                last = self.decode_step(&mut state, &last)?;
                for (row, &t) in rows.iter_mut().zip(&last) {
                    row.push(t);
                }
            }
            Ok(rows)
        }
    }

    /// Row-wise argmax over a [rows, vocab] f32 literal.
    fn argmax_rows(
        logits: &xla::Literal,
        rows: usize,
        vocab: usize,
    ) -> anyhow::Result<Vec<i32>> {
        let data: Vec<f32> = logits.to_vec()?;
        anyhow::ensure!(
            data.len() == rows * vocab,
            "logits size {} != {rows}x{vocab}",
            data.len()
        );
        Ok((0..rows)
            .map(|r| {
                let row = &data[r * vocab..(r + 1) * vocab];
                row.iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(i, _)| i as i32)
                    .unwrap_or(0)
            })
            .collect())
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use std::path::Path;

    use super::super::artifacts::{Manifest, TinyConfig};

    /// Live decode state for a batch (stub: never constructed).
    pub struct DecodeState {
        /// Batch bucket the caches are shaped for.
        pub bucket: u32,
        /// Live rows (<= bucket); padded rows are ignored.
        pub live: usize,
        /// Per-row write position (== tokens so far) for live rows.
        pub positions: Vec<i32>,
    }

    /// Stub runtime: discovers artifacts but cannot execute them.
    pub struct ModelRuntime {
        pub manifest: Manifest,
    }

    fn unavailable<T>() -> anyhow::Result<T> {
        anyhow::bail!(
            "PJRT runtime unavailable: this build has no `xla` FFI toolchain \
             (rebuild with `--features pjrt` in an environment that provides \
             the xla_extension crate)"
        )
    }

    impl ModelRuntime {
        /// Load artifacts from `dir`. The stub validates the manifest
        /// and weights, then reports that execution is unavailable.
        pub fn load(dir: &Path) -> anyhow::Result<Self> {
            let manifest = Manifest::load(dir)?;
            let _ = manifest.load_weights()?;
            unavailable()
        }

        pub fn config(&self) -> &TinyConfig {
            &self.manifest.config
        }

        pub fn platform(&self) -> String {
            "unavailable (built without the `pjrt` feature)".to_string()
        }

        pub fn prefill(
            &self,
            _prompts: &[Vec<i32>],
        ) -> anyhow::Result<(DecodeState, Vec<i32>)> {
            unavailable()
        }

        pub fn decode_step(
            &self,
            _state: &mut DecodeState,
            _last_tokens: &[i32],
        ) -> anyhow::Result<Vec<i32>> {
            unavailable()
        }

        pub fn greedy_generate(
            &self,
            _prompts: &[Vec<i32>],
            _steps: usize,
        ) -> anyhow::Result<Vec<Vec<i32>>> {
            unavailable()
        }
    }
}

pub use imp::{DecodeState, ModelRuntime};
