//! Virtual clock + binary-heap event queue (the tokio substitute for
//! trace-level experiments).
//!
//! Time is `f64` seconds since simulation start.  Events carry an
//! opaque payload; owners interpret them.  The queue is stable for
//! equal timestamps (FIFO by sequence number) so replays are exactly
//! reproducible.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// Monotonic virtual clock (seconds).
#[derive(Debug, Clone, Default)]
pub struct VirtualClock {
    now: f64,
}

impl VirtualClock {
    pub fn new() -> Self {
        Self { now: 0.0 }
    }

    /// Current virtual time in seconds.
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Advance to an absolute time. Panics on time travel.
    pub fn advance_to(&mut self, t: f64) {
        assert!(
            t >= self.now - 1e-12,
            "clock moved backwards: {} -> {}",
            self.now,
            t
        );
        self.now = self.now.max(t);
    }

    /// Advance by a delta (seconds).
    pub fn advance_by(&mut self, dt: f64) {
        assert!(dt >= 0.0, "negative advance {dt}");
        self.now += dt;
    }
}

/// A scheduled event with payload `T`.
#[derive(Debug, Clone)]
struct Scheduled<T> {
    at: f64,
    seq: u64,
    payload: T,
}

impl<T> PartialEq for Scheduled<T> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<T> Eq for Scheduled<T> {}
impl<T> PartialOrd for Scheduled<T> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<T> Ord for Scheduled<T> {
    fn cmp(&self, other: &Self) -> Ordering {
        // Min-heap: earliest time first; ties broken FIFO by seq.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Time-ordered event queue.
#[derive(Debug)]
pub struct EventQueue<T> {
    heap: BinaryHeap<Scheduled<T>>,
    seq: u64,
}

impl<T> Default for EventQueue<T> {
    fn default() -> Self {
        Self::new()
    }
}

impl<T> EventQueue<T> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    /// Schedule `payload` at absolute time `at` (seconds).
    pub fn push(&mut self, at: f64, payload: T) {
        assert!(at.is_finite(), "non-finite event time");
        self.heap.push(Scheduled {
            at,
            seq: self.seq,
            payload,
        });
        self.seq += 1;
    }

    /// Pop the earliest event as `(time, payload)`.
    pub fn pop(&mut self) -> Option<(f64, T)> {
        self.heap.pop().map(|s| (s.at, s.payload))
    }

    /// Time of the next event, if any.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|s| s.at)
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clock_advances() {
        let mut c = VirtualClock::new();
        assert_eq!(c.now(), 0.0);
        c.advance_by(1.5);
        c.advance_to(2.0);
        assert!((c.now() - 2.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "clock moved backwards")]
    fn clock_rejects_time_travel() {
        let mut c = VirtualClock::new();
        c.advance_to(5.0);
        c.advance_to(4.0);
    }

    #[test]
    fn queue_orders_by_time() {
        let mut q = EventQueue::new();
        q.push(3.0, "c");
        q.push(1.0, "a");
        q.push(2.0, "b");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((3.0, "c")));
        assert_eq!(q.pop(), None);
    }

    #[test]
    fn queue_is_fifo_for_ties() {
        let mut q = EventQueue::new();
        for i in 0..10 {
            q.push(1.0, i);
        }
        for i in 0..10 {
            assert_eq!(q.pop(), Some((1.0, i)));
        }
    }

    #[test]
    fn peek_matches_pop() {
        let mut q = EventQueue::new();
        q.push(7.0, ());
        q.push(4.0, ());
        assert_eq!(q.peek_time(), Some(4.0));
        q.pop();
        assert_eq!(q.peek_time(), Some(7.0));
    }
}
