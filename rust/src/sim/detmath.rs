//! Deterministic transcendental functions (platform-stable math).
//!
//! `f64::exp` / `ln` / `sin` / `cos` call the platform libm, whose
//! results may differ in the last ulps between OSes and libc versions.
//! That is fine for simulation statistics but fatal for the fleet-trace
//! record/replay contract: CI asserts that a generated trace's JSONL is
//! *byte-identical* for a given (seed, params) on every platform
//! (`tests/fleet_trace_determinism.rs`).  These implementations use
//! only IEEE-754 basic operations (+ − × ÷, sqrt, rounding, bit
//! manipulation), which are exactly specified, so every platform
//! produces the same bits.
//!
//! Accuracy is ~1e-12 relative — far beyond what a synthetic workload
//! needs — but the point is *stability*, not precision: the same input
//! always yields the same output everywhere.

const LN2: f64 = std::f64::consts::LN_2;
const TAU: f64 = std::f64::consts::TAU;

/// 2^k for integer k, via exponent-bit construction (exact).
fn pow2i(k: i32) -> f64 {
    if k > 1023 {
        f64::INFINITY
    } else if k < -1074 {
        0.0
    } else if k < -1022 {
        // Subnormal range: build 2^-1022 and scale down exactly.
        f64::from_bits(1u64 << (52 - (-1022 - k) as u64))
    } else {
        f64::from_bits(((k + 1023) as u64) << 52)
    }
}

/// Deterministic e^x (|relative error| ~1e-13 over the finite range).
pub fn exp_det(x: f64) -> f64 {
    if x.is_nan() {
        return f64::NAN;
    }
    if x > 709.8 {
        return f64::INFINITY;
    }
    if x < -745.0 {
        return 0.0;
    }
    // Range reduction: x = k*ln2 + r, |r| <= ln2/2.
    let k = (x / LN2).round();
    let r = x - k * LN2;
    // Taylor with fixed term count (Horner), deterministic order.
    // |r| <= 0.347: 14 terms give ~1e-16 truncation error.
    let mut acc = 1.0f64;
    let mut n = 14.0f64;
    while n >= 1.0 {
        acc = 1.0 + acc * r / n;
        n -= 1.0;
    }
    // Split the 2^k scale at the exponent-range edges: k can be 1024
    // (x in ~[709.44, 709.78], exp finite but pow2i(1024) = inf) or
    // below -1074 pre-multiplication (subnormal results); two finite
    // factors keep the product correct at both boundaries.
    let k = k as i32;
    if k > 1023 {
        acc * pow2i(1023) * pow2i(k - 1023)
    } else if k < -1022 {
        acc * pow2i(-1022) * pow2i(k + 1022)
    } else {
        acc * pow2i(k)
    }
}

/// Deterministic natural log (x > 0; returns -inf at 0, NaN below).
pub fn ln_det(x: f64) -> f64 {
    if x.is_nan() || x < 0.0 {
        return f64::NAN;
    }
    if x == 0.0 {
        return f64::NEG_INFINITY;
    }
    if x.is_infinite() {
        return f64::INFINITY;
    }
    // Normalize subnormals exactly (2^54 is a power of two).
    let (x, sub_adj) = if x < f64::MIN_POSITIVE {
        (x * pow2i(54), -54.0f64)
    } else {
        (x, 0.0)
    };
    let bits = x.to_bits();
    let mut e = ((bits >> 52) & 0x7ff) as i32 - 1023;
    // Mantissa m in [1, 2).
    let mut m = f64::from_bits((bits & 0x000f_ffff_ffff_ffff) | (1023u64 << 52));
    // Keep m in [sqrt(1/2), sqrt(2)) so |s| stays small.
    if m > std::f64::consts::SQRT_2 {
        m *= 0.5;
        e += 1;
    }
    // ln m via the atanh series: s = (m-1)/(m+1), |s| <= 0.1716.
    let s = (m - 1.0) / (m + 1.0);
    let s2 = s * s;
    // 2 * (s + s^3/3 + s^5/5 + ... + s^17/17): truncation ~1e-16.
    let mut acc = 0.0f64;
    let mut k = 17.0f64;
    while k >= 1.0 {
        acc = acc * s2 + 1.0 / k;
        k -= 2.0;
    }
    2.0 * s * acc + (e as f64 + sub_adj) * LN2
}

/// Reduce to r in [-pi, pi) deterministically (adequate for the
/// bounded arguments the workload generator uses; not a full Payne-
/// Hanek reduction for astronomically large inputs).
fn reduce_tau(x: f64) -> f64 {
    x - TAU * ((x + std::f64::consts::PI) / TAU).floor()
}

/// Deterministic sin(x) (absolute error ~1e-11 on [-pi, pi]).
pub fn sin_det(x: f64) -> f64 {
    if !x.is_finite() {
        return f64::NAN;
    }
    let r = reduce_tau(x);
    let r2 = r * r;
    // Taylor to r^23/23!, fixed term count and evaluation order.
    let mut term = r;
    let mut sum = r;
    let mut k = 1.0f64;
    while k <= 11.0 {
        term = -term * r2 / ((2.0 * k) * (2.0 * k + 1.0));
        sum += term;
        k += 1.0;
    }
    sum
}

/// Deterministic cos(x) (absolute error ~1e-11 on [-pi, pi]).
pub fn cos_det(x: f64) -> f64 {
    if !x.is_finite() {
        return f64::NAN;
    }
    let r = reduce_tau(x);
    let r2 = r * r;
    let mut term = 1.0f64;
    let mut sum = 1.0f64;
    let mut k = 1.0f64;
    while k <= 12.0 {
        term = -term * r2 / ((2.0 * k - 1.0) * (2.0 * k));
        sum += term;
        k += 1.0;
    }
    sum
}

#[cfg(test)]
mod tests {
    use super::*;

    fn close(a: f64, b: f64, tol: f64) -> bool {
        if b == 0.0 {
            a.abs() < tol
        } else {
            ((a - b) / b).abs() < tol || (a - b).abs() < tol
        }
    }

    #[test]
    fn exp_matches_std() {
        for i in -200..=200 {
            let x = i as f64 * 0.173;
            assert!(
                close(exp_det(x), x.exp(), 1e-11),
                "exp({x}) = {} vs {}",
                exp_det(x),
                x.exp()
            );
        }
        assert_eq!(exp_det(0.0), 1.0);
        assert_eq!(exp_det(f64::NEG_INFINITY), 0.0);
        assert_eq!(exp_det(800.0), f64::INFINITY);
        // Exponent-range edges: finite just inside, inf/0 just outside.
        assert!(close(exp_det(709.5), 709.5f64.exp(), 1e-11));
        assert!(exp_det(709.5).is_finite());
        assert_eq!(exp_det(709.79), f64::INFINITY);
        assert!(exp_det(-740.0) > 0.0, "deep negative exp stays nonzero");
        // Subnormal result: one rounding step costs up to ~2^-11
        // relative, so only a coarse agreement check is meaningful.
        assert!(close(exp_det(-740.0), (-740.0f64).exp(), 1e-2));
    }

    #[test]
    fn ln_matches_std() {
        for i in 1..=400 {
            let x = i as f64 * 0.37;
            assert!(
                close(ln_det(x), x.ln(), 1e-11),
                "ln({x}) = {} vs {}",
                ln_det(x),
                x.ln()
            );
        }
        // Small magnitudes (the exponential sampler feeds uniforms).
        for i in 1..=60 {
            let x = (2.0f64).powi(-i);
            assert!(close(ln_det(x), x.ln(), 1e-11), "ln(2^-{i})");
        }
        assert_eq!(ln_det(1.0), 0.0);
        assert_eq!(ln_det(0.0), f64::NEG_INFINITY);
        assert!(ln_det(-1.0).is_nan());
    }

    #[test]
    fn ln_exp_roundtrip() {
        for i in -40..=40 {
            let x = i as f64 * 0.25;
            assert!(close(ln_det(exp_det(x)), x, 1e-10), "roundtrip {x}");
        }
    }

    #[test]
    fn sin_cos_match_std() {
        for i in -300..=300 {
            let x = i as f64 * 0.217;
            assert!(
                close(sin_det(x), x.sin(), 1e-9),
                "sin({x}) = {} vs {}",
                sin_det(x),
                x.sin()
            );
            assert!(
                close(cos_det(x), x.cos(), 1e-9),
                "cos({x}) = {} vs {}",
                cos_det(x),
                x.cos()
            );
        }
        assert_eq!(sin_det(0.0), 0.0);
        assert_eq!(cos_det(0.0), 1.0);
    }

    #[test]
    fn pythagorean_identity() {
        for i in 0..100 {
            let x = i as f64 * 0.63 - 31.5;
            let s = sin_det(x);
            let c = cos_det(x);
            assert!((s * s + c * c - 1.0).abs() < 1e-9, "x={x}");
        }
    }

    #[test]
    fn deterministic_bits() {
        // Same input, same bits — trivially true in one process, but
        // pins the API contract the fleet-trace golden test relies on.
        for i in 0..50 {
            let x = 0.31 * i as f64;
            assert_eq!(exp_det(x).to_bits(), exp_det(x).to_bits());
            assert_eq!(sin_det(x).to_bits(), sin_det(x).to_bits());
        }
    }
}
