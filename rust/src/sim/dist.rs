//! Distribution helpers shared by the workload synthesizer and tests:
//! empirical histograms, truncated samplers, Pearson correlation.

use super::rng::Pcg64;

/// Sample a truncated log-normal, clamped to [lo, hi].
pub fn lognormal_clamped(
    rng: &mut Pcg64,
    mu: f64,
    sigma: f64,
    lo: f64,
    hi: f64,
) -> f64 {
    rng.lognormal(mu, sigma).clamp(lo, hi)
}

/// Pearson correlation coefficient of two equal-length series.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len(), "pearson: length mismatch");
    let n = xs.len() as f64;
    assert!(n > 1.0, "pearson: need at least 2 points");
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let (mut sxy, mut sxx, mut syy) = (0.0, 0.0, 0.0);
    for (x, y) in xs.iter().zip(ys) {
        let dx = x - mx;
        let dy = y - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Fixed-width histogram over [lo, hi) with `bins` buckets.
#[derive(Debug, Clone)]
pub struct Histogram {
    pub lo: f64,
    pub hi: f64,
    pub counts: Vec<u64>,
    pub underflow: u64,
    pub overflow: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, bins: usize) -> Self {
        assert!(hi > lo && bins > 0);
        Self {
            lo,
            hi,
            counts: vec![0; bins],
            underflow: 0,
            overflow: 0,
        }
    }

    pub fn add(&mut self, x: f64) {
        if x < self.lo {
            self.underflow += 1;
        } else if x >= self.hi {
            self.overflow += 1;
        } else {
            let bins = self.counts.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * bins as f64) as usize;
            let idx = idx.min(bins - 1);
            self.counts[idx] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.counts.iter().sum::<u64>() + self.underflow + self.overflow
    }

    /// Bin centers for plotting/printing.
    pub fn centers(&self) -> Vec<f64> {
        let w = (self.hi - self.lo) / self.counts.len() as f64;
        (0..self.counts.len())
            .map(|i| self.lo + w * (i as f64 + 0.5))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pearson_perfect_and_anti() {
        let xs: Vec<f64> = (0..50).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 2.0 * x + 1.0).collect();
        let zs: Vec<f64> = xs.iter().map(|x| -x).collect();
        assert!((pearson(&xs, &ys) - 1.0).abs() < 1e-12);
        assert!((pearson(&xs, &zs) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_uncorrelated_near_zero() {
        let mut rng = Pcg64::new(5);
        let xs: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        let ys: Vec<f64> = (0..20_000).map(|_| rng.normal()).collect();
        assert!(pearson(&xs, &ys).abs() < 0.03);
    }

    #[test]
    fn pearson_constant_series_is_zero() {
        let xs = vec![1.0; 10];
        let ys: Vec<f64> = (0..10).map(|i| i as f64).collect();
        assert_eq!(pearson(&xs, &ys), 0.0);
    }

    #[test]
    fn histogram_counts_and_edges() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [0.0, 0.5, 9.99, 10.0, -0.1, 5.0] {
            h.add(x);
        }
        assert_eq!(h.counts[0], 2);
        assert_eq!(h.counts[9], 1);
        assert_eq!(h.counts[5], 1);
        assert_eq!(h.overflow, 1);
        assert_eq!(h.underflow, 1);
        assert_eq!(h.total(), 6);
    }

    #[test]
    fn lognormal_clamp_respected() {
        let mut rng = Pcg64::new(9);
        for _ in 0..1000 {
            let x = lognormal_clamped(&mut rng, 5.0, 2.0, 10.0, 700.0);
            assert!((10.0..=700.0).contains(&x));
        }
    }
}
