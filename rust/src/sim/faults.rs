//! Deterministic fault-injection schedules for the fleet coordinator.
//!
//! The paper's controller assumes replicas that never fail; production
//! fleets lose GPUs to crashes, thermal throttling and preemption
//! constantly (AGFT and GreenLLM both motivate online control with
//! exactly these runtime perturbations).  This module generates a
//! **reproducible fault schedule** up front from a
//! [`FaultSpec`](crate::config::FaultSpec): four independent Poisson
//! processes (one PCG64 stream per fault family, `detmath`-backed
//! exponential gaps — no platform libm), merged and sorted by onset.
//!
//! Because the schedule is a pure function of `(spec, replicas,
//! duration)` computed before serving starts, it is byte-identical
//! across platforms and across `--threads N` — the same determinism
//! contract as `workload/fleet_trace.rs`.  The coordinator replays the
//! events as additional decision points in its coordination phase, so
//! fault handling never races the RUN phase.
//!
//! Fault kinds:
//!   * **Crash** — the replica dies instantly; un-checkpointed
//!     resident KV is lost, checkpointed residents are re-placed on
//!     surviving replicas, the rest re-queue with bounded retry.
//!   * **ThermalThrottle** — the DVFS grid is forcibly capped below
//!     the controller's chosen frequency for a window; the throttle
//!     loop must re-plan around a ceiling it did not pick.
//!   * **LinkDown** — the migration fabric fails fleet-wide for a
//!     window; mid-transfer moves roll back onto a coherent source.
//!   * **Preempt** — a drain deadline with notice that races the
//!     migration path; residents still aboard at the deadline take
//!     the crash path.

use crate::config::FaultSpec;
use crate::sim::detmath::ln_det;
use crate::sim::Pcg64;

/// PCG64 stream ids, one per fault family (disjoint from the fleet
/// trace generator's 0xb425/0x0b1e/0xf1ee streams).
const STREAM_CRASH: u64 = 0xfa01;
const STREAM_THROTTLE: u64 = 0xfa02;
const STREAM_LINK: u64 = 0xfa03;
const STREAM_PREEMPT: u64 = 0xfa04;

/// What a scheduled fault does when its instant arrives.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultKind {
    /// The replica dies; recovery re-places checkpointed residents and
    /// re-queues the rest.  Respawns after `FaultSpec::respawn_s`.
    Crash,
    /// DVFS forcibly capped at `cap_mhz` until `until_s`.
    ThermalThrottle { cap_mhz: u32, until_s: f64 },
    /// The migration link is down fleet-wide until `until_s` (the
    /// event's `replica` is ignored — the fabric is shared).
    LinkDown { until_s: f64 },
    /// Drain notice: the replica stops accepting work now and is taken
    /// at `deadline_s`; residents race the migration path out.
    Preempt { deadline_s: f64 },
}

impl FaultKind {
    /// Stable tie-break rank for same-instant events.
    fn rank(&self) -> u8 {
        match self {
            FaultKind::Crash => 0,
            FaultKind::ThermalThrottle { .. } => 1,
            FaultKind::LinkDown { .. } => 2,
            FaultKind::Preempt { .. } => 3,
        }
    }
}

/// One scheduled fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FaultEvent {
    pub at_s: f64,
    /// Target replica index (ignored by [`FaultKind::LinkDown`]).
    pub replica: usize,
    pub kind: FaultKind,
}

/// Fleet-level fault/recovery telemetry (one per `serve_fleet_plan`
/// run); folded into the outcome digest, so any divergence in fault
/// handling breaks the determinism tests loudly.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Replica crashes applied (events targeting inactive replicas are
    /// no-ops and not counted).
    pub crashes: u64,
    /// Residents re-placed onto surviving replicas from a checkpoint.
    pub crash_recoveries: u64,
    /// Residents and queued requests re-queued after a crash or
    /// preemption (KV lost; they re-run prefill elsewhere).
    pub crash_requeues: u64,
    /// Re-admission attempts made for requeued requests.
    pub retries: u64,
    /// Arrivals shed at admission because post-fault capacity could
    /// not meet their SLO budget.
    pub shed: u64,
    /// Requeued requests whose retry budget ran out — counted loss,
    /// never a panic or a hang.
    pub faulted_lost: u64,
    /// Thermal-throttle windows applied.
    pub throttle_events: u64,
    /// Transfers rolled back because the migration link was down.
    pub link_failures: u64,
    /// Preemption notices applied.
    pub preemptions: u64,
    /// Crashed/preempted replicas brought back after the respawn
    /// latency (distinguished from voluntary fleet-axis activations).
    pub respawns: u64,
}

/// Deterministic exponential gap with mean `mean_s` (detmath `ln`, the
/// fleet-trace sampler idiom — never std `ln`, which differs across
/// platforms in the last ulp).
fn exponential_gap(rng: &mut Pcg64, mean_s: f64) -> f64 {
    debug_assert!(mean_s > 0.0);
    -ln_det(rng.next_f64().max(1e-300)) * mean_s
}

/// One Poisson fault family: onsets with mean gap `mtbf_s` over
/// `[0, duration_s)`, each targeting a uniform replica.
fn family(
    spec: &FaultSpec,
    replicas: usize,
    duration_s: f64,
    mtbf_s: f64,
    stream: u64,
    mk: impl Fn(f64, &FaultSpec) -> FaultKind,
) -> Vec<FaultEvent> {
    let mut out = Vec::new();
    if mtbf_s <= 0.0 {
        return out;
    }
    let mut rng = Pcg64::with_stream(spec.seed, stream);
    let mut t = 0.0f64;
    loop {
        t += exponential_gap(&mut rng, mtbf_s);
        if t >= duration_s {
            break;
        }
        let replica = rng.uniform_usize(0, replicas - 1);
        out.push(FaultEvent {
            at_s: t,
            replica,
            kind: mk(t, spec),
        });
    }
    out
}

/// Generate the full fault schedule: the four families merged and
/// sorted by `(onset, replica, kind)`.  A pure function of its inputs
/// — same spec, fleet size and duration give byte-identical schedules
/// on every platform and thread count.
pub fn fault_schedule(
    spec: &FaultSpec,
    replicas: usize,
    duration_s: f64,
) -> Vec<FaultEvent> {
    if replicas == 0 || duration_s <= 0.0 {
        return Vec::new();
    }
    let mut events = family(
        spec,
        replicas,
        duration_s,
        spec.crash_mtbf_s,
        STREAM_CRASH,
        |_, _| FaultKind::Crash,
    );
    events.extend(family(
        spec,
        replicas,
        duration_s,
        spec.throttle_mtbf_s,
        STREAM_THROTTLE,
        |t, s| FaultKind::ThermalThrottle {
            cap_mhz: s.throttle_cap_mhz,
            until_s: t + s.throttle_window_s,
        },
    ));
    events.extend(family(
        spec,
        replicas,
        duration_s,
        spec.link_mtbf_s,
        STREAM_LINK,
        |t, s| FaultKind::LinkDown {
            until_s: t + s.link_window_s,
        },
    ));
    events.extend(family(
        spec,
        replicas,
        duration_s,
        spec.preempt_mtbf_s,
        STREAM_PREEMPT,
        |t, s| FaultKind::Preempt {
            deadline_s: t + s.preempt_notice_s,
        },
    ));
    events.sort_by(|a, b| {
        a.at_s
            .total_cmp(&b.at_s)
            .then(a.replica.cmp(&b.replica))
            .then(a.kind.rank().cmp(&b.kind.rank()))
    });
    events
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(seed: u64) -> FaultSpec {
        FaultSpec {
            seed,
            ..FaultSpec::enabled_default()
        }
    }

    #[test]
    fn schedule_is_deterministic_and_seed_sensitive() {
        let a = fault_schedule(&spec(0), 4, 600.0);
        let b = fault_schedule(&spec(0), 4, 600.0);
        assert_eq!(a, b);
        assert!(!a.is_empty(), "default mix over 600 s must fault");
        let c = fault_schedule(&spec(1), 4, 600.0);
        assert_ne!(a, c);
    }

    #[test]
    fn schedule_is_sorted_and_bounded() {
        let ev = fault_schedule(&spec(3), 4, 600.0);
        assert!(ev.windows(2).all(|w| w[0].at_s <= w[1].at_s));
        for e in &ev {
            assert!(e.at_s >= 0.0 && e.at_s < 600.0);
            assert!(e.replica < 4);
            match e.kind {
                FaultKind::ThermalThrottle { cap_mhz, until_s } => {
                    assert_eq!(cap_mhz, 600);
                    assert!(until_s > e.at_s);
                }
                FaultKind::LinkDown { until_s } => assert!(until_s > e.at_s),
                FaultKind::Preempt { deadline_s } => assert!(deadline_s > e.at_s),
                FaultKind::Crash => {}
            }
        }
    }

    #[test]
    fn degenerate_inputs_schedule_nothing() {
        // (`--faults off` is `None` on the plan now — the scheduler is
        // simply never called.)
        assert!(fault_schedule(&spec(0), 0, 600.0).is_empty());
        assert!(fault_schedule(&spec(0), 4, 0.0).is_empty());
    }

    #[test]
    fn zero_mtbf_disables_one_family() {
        let mut s = spec(0);
        s.crash_mtbf_s = 0.0;
        s.preempt_mtbf_s = 0.0;
        let ev = fault_schedule(&s, 4, 600.0);
        assert!(!ev.is_empty());
        assert!(ev.iter().all(|e| !matches!(
            e.kind,
            FaultKind::Crash | FaultKind::Preempt { .. }
        )));
    }

    #[test]
    fn all_families_present_over_long_horizon() {
        let ev = fault_schedule(&spec(0), 4, 3600.0);
        let has = |f: fn(&FaultKind) -> bool| ev.iter().any(|e| f(&e.kind));
        assert!(has(|k| matches!(k, FaultKind::Crash)));
        assert!(has(|k| matches!(k, FaultKind::ThermalThrottle { .. })));
        assert!(has(|k| matches!(k, FaultKind::LinkDown { .. })));
        assert!(has(|k| matches!(k, FaultKind::Preempt { .. })));
    }

    #[test]
    fn counters_default_to_zero() {
        let c = FaultCounters::default();
        assert_eq!(c.crashes + c.crash_recoveries + c.crash_requeues, 0);
        assert_eq!(c.retries + c.shed + c.faulted_lost, 0);
        assert_eq!(c.throttle_events + c.link_failures + c.preemptions, 0);
        assert_eq!(c.respawns, 0);
    }
}
