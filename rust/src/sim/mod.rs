//! Discrete-event simulation substrate: virtual clock, event queue,
//! PRNG and samplers.
//!
//! The serving stack runs against virtual time so trace-level
//! experiments (60-minute Azure traces) replay in milliseconds while
//! preserving every iteration-level interleaving the paper's system
//! reacts to.  The same coordinator code drives the real PJRT engine in
//! wall-clock mode (`runtime`).

pub mod clock;
pub mod detmath;
pub mod dist;
pub mod faults;
pub mod rng;

pub use clock::{EventQueue, VirtualClock};
pub use faults::{fault_schedule, FaultCounters, FaultEvent, FaultKind};
pub use rng::Pcg64;
